//! Cluster simulation sweeps: paper-scale throughput studies (Figures 9 and
//! 10 style) on configurable virtual clusters — change the interconnect and
//! watch the crossovers move.
//!
//! ```bash
//! cargo run --release --example cluster_sim                 # paper testbed
//! cargo run --release --example cluster_sim -- --ib-gbps 50 # faster fabric
//! cargo run --release --example cluster_sim -- --model gpt-96
//! ```

use bitpipe::config::{ClusterConfig, ModelConfig, ParallelConfig, BERT_64};
use bitpipe::schedule::ScheduleKind;
use bitpipe::sim::{simulate, SimConfig};
use bitpipe::util::Table;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let mut model = BERT_64;
    let mut ib_gbps = 200.0f64;
    let mut i = 0;
    while i < args.len() {
        match args[i].as_str() {
            "--model" => {
                model = ModelConfig::by_name(&args[i + 1]).expect("unknown model");
                i += 2;
            }
            "--ib-gbps" => {
                ib_gbps = args[i + 1].parse()?;
                i += 2;
            }
            other => anyhow::bail!("unknown flag {other}"),
        }
    }
    let b = if model.name == "gpt-96" { 1 } else { 4 };

    println!("model = {} (B = {b}), inter-node fabric = {ib_gbps} Gbps\n", model.name);

    // Fig 9 style: pipeline-only on 8 devices, mini-batch scaling.
    println!("-- pipeline-only, 8 devices (Fig 9 style) --");
    let mut t = Table::new(vec!["N", "dapple", "1f1b-int", "chimera", "mixpipe", "bitpipe"]);
    for n in [8usize, 16, 32] {
        let mut row = vec![n.to_string()];
        for kind in [
            ScheduleKind::Dapple,
            ScheduleKind::Interleaved,
            ScheduleKind::Chimera,
            ScheduleKind::MixPipe,
            ScheduleKind::BitPipe,
        ] {
            let mut cluster = ClusterConfig::paper_testbed(8);
            cluster.ib_bw = ib_gbps * 1e9 / 8.0;
            let parallel = ParallelConfig::new(kind, 1, 8, b, n);
            let r = simulate(&SimConfig::new(model, parallel, cluster))?;
            row.push(format!("{:.2}", r.throughput));
        }
        t.row(row);
    }
    println!("{}", t.render());

    // Fig 10 style: weak scaling with data parallelism.
    println!("-- with data parallelism, D=8, N=D (Fig 10 style) --");
    let mut t = Table::new(vec!["GPUs", "W", "dapple", "1f1b-int", "mixpipe", "bitpipe"]);
    for gpus in [8usize, 16, 32, 64] {
        let w = gpus / 8;
        let mut row = vec![gpus.to_string(), w.to_string()];
        for kind in [
            ScheduleKind::Dapple,
            ScheduleKind::Interleaved,
            ScheduleKind::MixPipe,
            ScheduleKind::BitPipe,
        ] {
            let mut cluster = ClusterConfig::paper_testbed(gpus);
            cluster.ib_bw = ib_gbps * 1e9 / 8.0;
            let parallel = ParallelConfig::new(kind, w, 8, b, 8);
            let r = simulate(&SimConfig::new(model, parallel, cluster))?;
            row.push(format!("{:.2}", r.throughput));
        }
        t.row(row);
    }
    println!("{}", t.render());

    println!(
        "Expected shape (paper Figs 9-10): BitPipe leads everywhere; its edge narrows as\n\
         N grows (more P2P) and as the inter-node share grows (allreduce on slower links)."
    );
    Ok(())
}
