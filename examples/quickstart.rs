//! Quickstart: the smallest full-stack BitPipe run.
//!
//! Loads the AOT artifacts (`make artifacts` first), builds the BitPipe
//! schedule for 4 devices, validates it, trains the tiny GPT for a few
//! iterations on 4 worker threads, and prints the loss curve plus the
//! communication counters — proving all three layers (Pallas kernel ->
//! JAX chunk HLO -> rust PJRT coordinator) compose.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```

use bitpipe::schedule::{self, ScheduleConfig, ScheduleKind};
use bitpipe::train::{run, DatasetKind, TrainConfig};

fn main() -> anyhow::Result<()> {
    // 1. Build + validate the paper's schedule (pure coordination logic).
    let cfg = ScheduleConfig::new(ScheduleKind::BitPipe, 4, 4);
    let sched = schedule::build(&cfg)?;
    schedule::validate::validate(&sched)?;
    let report = schedule::analysis::report(&sched, &schedule::Costs::default())?;
    println!(
        "BitPipe D=4 N=4: bubble ratio {:.3} (closed form {:.3}), {} P2P msgs, {} local copies",
        report.bubble_ratio_measured,
        report.bubble_ratio_formula,
        report.comm_measured.p2p_messages,
        report.comm_measured.local_copies,
    );

    // 2. Execute it for real: 4 threads, each running its device's
    //    instruction stream over the AOT-compiled XLA chunk executables.
    let mut tcfg = TrainConfig::new("artifacts", ScheduleKind::BitPipe, 4, 4);
    tcfg.steps = 3;
    tcfg.dataset = DatasetKind::Synthetic;
    tcfg.log_every = 1;
    println!("\ntraining gpt-tiny for {} iterations on 4 threads...", tcfg.steps);
    let report = run(&tcfg)?;

    println!("\nloss curve: {:?}", report.losses);
    let c = &report.counters;
    println!(
        "counters: {} forwards, {} backwards, {} P2P messages, {} local copies, {} allreduces",
        c.forwards, c.backwards, c.p2p_msgs, c.local_copies, c.allreduces
    );
    println!(
        "wall time {:.1}s ({:.2}s/iter steady-state)",
        report.total_time,
        report.iter_times.last().copied().unwrap_or(0.0)
    );
    Ok(())
}
