//! Schedule explorer: render ASCII timelines and analytic reports for every
//! schedule family — the paper's Figures 1, 2, 3 and 13 as text.
//!
//! ```bash
//! cargo run --release --example schedule_explorer            # D=4, N=4 and N=8
//! cargo run --release --example schedule_explorer -- 8 16    # D=8, N=16
//! ```

use bitpipe::schedule::{
    self, analysis, timeline, Costs, ScheduleConfig, ScheduleKind,
};
use bitpipe::util::Table;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let d: usize = args.first().map(|s| s.parse()).transpose()?.unwrap_or(4);
    let ns: Vec<usize> = if let Some(n) = args.get(1) {
        vec![n.parse()?]
    } else {
        vec![d, 2 * d]
    };
    let costs = Costs::default();

    for &n in &ns {
        println!("================ D={d}, N={n} ================\n");
        let mut summary = Table::new(vec![
            "schedule",
            "makespan",
            "bubble (measured)",
            "bubble (formula)",
            "P2P",
            "copies",
            "peak stash /M_a",
        ]);
        for kind in ScheduleKind::ALL {
            let cfg = ScheduleConfig::new(kind, d, n);
            let s = match schedule::build(&cfg) {
                Ok(s) => s,
                Err(e) => {
                    println!("{kind}: skipped ({e})\n");
                    continue;
                }
            };
            schedule::validate::validate(&s)?;
            let opts = timeline::RenderOpts {
                ticks_per_col: if n > d { 3 } else { 1 },
                show_stage: false,
            };
            println!("--- {kind} ---");
            println!("{}", timeline::render(&s, &costs, &opts)?);
            let r = analysis::report(&s, &costs)?;
            summary.row(vec![
                kind.name().to_string(),
                r.makespan.to_string(),
                format!("{:.3}", r.bubble_ratio_measured),
                format!("{:.3}", r.bubble_ratio_formula),
                r.comm_measured.p2p_messages.to_string(),
                r.comm_measured.local_copies.to_string(),
                format!("{:.1}", r.act_mem_measured.1),
            ]);
        }
        println!("{}", summary.render());
    }
    Ok(())
}
