//! End-to-end training driver: train the tiny GPT (~21M parameters) for a
//! few hundred steps under the BitPipe schedule and log the loss curve —
//! the repository's full-system validation run (recorded in
//! EXPERIMENTS.md).
//!
//! ```bash
//! make artifacts
//! cargo run --release --example train_gpt_tiny -- [steps] [kind] [dataset]
//! # e.g.  cargo run --release --example train_gpt_tiny -- 200 bitpipe corpus
//! ```
//!
//! Writes `train_loss.csv` (iteration, loss, seconds) to the working
//! directory. Any schedule kind with v*D = 8 chunks works against the
//! default artifacts: `bitpipe`/`1f1b-int`/`v-shaped` (D=4, v=2),
//! `dapple`/`gpipe`/`chimera`/`mixpipe` (D=8, v=1).

use bitpipe::schedule::ScheduleKind;
use bitpipe::train::{run, DatasetKind, TrainConfig};
use std::io::Write as _;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let steps: usize = args.first().map(|s| s.parse()).transpose()?.unwrap_or(200);
    let kind = args
        .get(1)
        .map(|s| ScheduleKind::parse(s).expect("unknown schedule kind"))
        .unwrap_or(ScheduleKind::BitPipe);
    let dataset = match args.get(2).map(|s| s.as_str()) {
        Some("corpus") => DatasetKind::Corpus,
        _ => DatasetKind::Synthetic,
    };

    // v*D must equal the artifact chunk count (8 for gpt-tiny).
    let d = if kind.default_v() == 2 { 4 } else { 8 };
    let mut cfg = TrainConfig::new("artifacts", kind, d, 8);
    cfg.steps = steps;
    cfg.dataset = dataset;
    cfg.adam.lr = 1e-3;
    cfg.log_every = 10;

    println!(
        "end-to-end training: kind={kind} D={d} N={} v={} steps={steps} dataset={dataset:?}",
        cfg.n, cfg.v
    );
    let report = run(&cfg)?;

    let mut csv = std::fs::File::create("train_loss.csv")?;
    writeln!(csv, "iter,loss,seconds")?;
    let mut t = 0.0;
    for (i, (loss, dt)) in report.losses.iter().zip(&report.iter_times).enumerate() {
        t += dt;
        writeln!(csv, "{},{:.6},{:.2}", i + 1, loss, t)?;
    }
    println!("\nwrote train_loss.csv ({} iterations)", report.losses.len());

    let first = report.losses.first().copied().unwrap_or(f64::NAN);
    let last = report.losses.last().copied().unwrap_or(f64::NAN);
    let window = report.losses.len().min(10);
    let tail: f64 =
        report.losses.iter().rev().take(window).sum::<f64>() / window as f64;
    println!("loss: first {first:.4} -> last {last:.4} (mean of final {window}: {tail:.4})");
    println!(
        "throughput: {:.2} samples/s over {:.1}s",
        report.throughput(4, cfg.n),
        report.total_time
    );
    assert!(tail < first, "loss did not decrease — training is broken");
    println!("loss decreased ✓");
    Ok(())
}
