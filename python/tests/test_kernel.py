"""Layer-1 correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes/dtypes; assert_allclose against ref.py — the core
correctness signal gating the AOT artifacts.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import flash_attention, layernorm
from compile.kernels.ref import attention_ref, layernorm_ref

jax.config.update("jax_platform_name", "cpu")


def _rand(rng, shape, dtype):
    return jnp.asarray(rng.normal(size=shape), dtype)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

attn_shapes = st.tuples(
    st.integers(1, 3),                      # batch
    st.integers(1, 4),                      # heads
    st.sampled_from([16, 32, 64, 128]),     # seq
    st.sampled_from([8, 16, 32, 64]),       # head dim
)


@settings(max_examples=25, deadline=None)
@given(shape=attn_shapes, causal=st.booleans(), seed=st.integers(0, 2**31))
def test_attention_matches_ref(shape, causal, seed):
    b, h, s, d = shape
    rng = np.random.default_rng(seed)
    q, k, v = (_rand(rng, (b, h, s, d), jnp.float32) for _ in range(3))
    out = flash_attention(q, k, v, causal)
    ref = attention_ref(q, k, v, causal)
    np.testing.assert_allclose(out, ref, atol=2e-5, rtol=2e-5)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31), causal=st.booleans())
def test_attention_grads_match_ref(seed, causal):
    rng = np.random.default_rng(seed)
    q, k, v = (_rand(rng, (2, 2, 32, 16), jnp.float32) for _ in range(3))
    co = jnp.asarray(rng.normal(size=(2, 2, 32, 16)), jnp.float32)

    def f(q, k, v):
        return (flash_attention(q, k, v, causal) * co).sum()

    def fr(q, k, v):
        return (attention_ref(q, k, v, causal) * co).sum()

    got = jax.grad(f, argnums=(0, 1, 2))(q, k, v)
    want = jax.grad(fr, argnums=(0, 1, 2))(q, k, v)
    for g, w, name in zip(got, want, "qkv"):
        np.testing.assert_allclose(g, w, atol=5e-5, rtol=5e-5,
                                   err_msg=f"d{name}")


def test_attention_block_sizes_agree():
    # Different panel tilings must give the same function value.
    rng = np.random.default_rng(0)
    q, k, v = (_rand(rng, (1, 2, 128, 32), jnp.float32) for _ in range(3))
    base = flash_attention(q, k, v, True, 64, 64)
    for bq, bk in [(32, 32), (128, 64), (64, 128), (128, 128), (32, 64)]:
        out = flash_attention(q, k, v, True, bq, bk)
        np.testing.assert_allclose(out, base, atol=2e-5, rtol=2e-5,
                                   err_msg=f"bq={bq} bk={bk}")


def test_attention_causal_ignores_future():
    # Perturbing position j must not change outputs at positions < j.
    rng = np.random.default_rng(1)
    q, k, v = (_rand(rng, (1, 1, 64, 16), jnp.float32) for _ in range(3))
    out1 = flash_attention(q, k, v, True)
    k2 = k.at[:, :, 50:, :].add(100.0)
    v2 = v.at[:, :, 50:, :].add(100.0)
    out2 = flash_attention(q, k2, v2, True)
    np.testing.assert_allclose(out1[:, :, :50], out2[:, :, :50],
                               atol=1e-6, rtol=1e-6)
    assert not np.allclose(out1[:, :, 50:], out2[:, :, 50:])


def test_attention_jit_and_lower():
    # The kernel must lower inside jit (the AOT requirement).
    rng = np.random.default_rng(2)
    q, k, v = (_rand(rng, (1, 2, 32, 16), jnp.float32) for _ in range(3))
    f = jax.jit(lambda q, k, v: flash_attention(q, k, v, True))
    np.testing.assert_allclose(f(q, k, v), attention_ref(q, k, v, True),
                               atol=2e-5, rtol=2e-5)
    hlo = f.lower(q, k, v).compiler_ir("stablehlo")
    assert "stablehlo" in str(hlo)


# ---------------------------------------------------------------------------
# fused layernorm
# ---------------------------------------------------------------------------

ln_shapes = st.tuples(
    st.integers(1, 4),                      # batch
    st.sampled_from([1, 7, 16, 64, 128]),   # rows
    st.sampled_from([8, 32, 64, 256]),      # hidden
)


@settings(max_examples=25, deadline=None)
@given(shape=ln_shapes, seed=st.integers(0, 2**31))
def test_layernorm_matches_ref(shape, seed):
    b, s, h = shape
    rng = np.random.default_rng(seed)
    x = _rand(rng, (b, s, h), jnp.float32)
    g = _rand(rng, (h,), jnp.float32)
    be = _rand(rng, (h,), jnp.float32)
    np.testing.assert_allclose(layernorm(x, g, be), layernorm_ref(x, g, be),
                               atol=1e-5, rtol=1e-5)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31))
def test_layernorm_grads_match_ref(seed):
    rng = np.random.default_rng(seed)
    x = _rand(rng, (2, 16, 32), jnp.float32)
    g = _rand(rng, (32,), jnp.float32)
    be = _rand(rng, (32,), jnp.float32)
    co = _rand(rng, (2, 16, 32), jnp.float32)

    def f(x, g, b):
        return (layernorm(x, g, b) * co).sum()

    def fr(x, g, b):
        return (layernorm_ref(x, g, b) * co).sum()

    got = jax.grad(f, argnums=(0, 1, 2))(x, g, be)
    want = jax.grad(fr, argnums=(0, 1, 2))(x, g, be)
    for a, b_, name in zip(got, want, ["dx", "dgamma", "dbeta"]):
        np.testing.assert_allclose(a, b_, atol=2e-4, rtol=2e-4, err_msg=name)


def test_layernorm_normalizes():
    rng = np.random.default_rng(3)
    x = _rand(rng, (4, 64, 32), jnp.float32) * 10 + 5
    y = layernorm(x, jnp.ones(32), jnp.zeros(32))
    np.testing.assert_allclose(np.mean(y, -1), 0.0, atol=1e-5)
    np.testing.assert_allclose(np.std(y, -1), 1.0, atol=1e-3)


def test_layernorm_odd_row_counts():
    # Row counts not divisible by the default block must still work.
    rng = np.random.default_rng(4)
    for rows in [1, 3, 13, 63, 65, 127]:
        x = _rand(rng, (rows, 16), jnp.float32)
        g, b = jnp.ones(16), jnp.zeros(16)
        np.testing.assert_allclose(layernorm(x, g, b),
                                   layernorm_ref(x, g, b),
                                   atol=1e-5, rtol=1e-5,
                                   err_msg=f"rows={rows}")
