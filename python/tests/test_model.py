"""Layer-2 correctness: chunked GPT decomposition vs composed-model
autodiff, parameter packing round-trips, and basic trainability.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model

jax.config.update("jax_platform_name", "cpu")

DIMS = model.Dims(batch=2, seq=16, hidden=32, heads=4, vocab=64,
                  layers_per_chunk=1)


def _batch(rng, d):
    tokens = jnp.asarray(rng.integers(0, d.vocab, (d.batch, d.seq)), jnp.int32)
    targets = jnp.asarray(rng.integers(0, d.vocab, (d.batch, d.seq)), jnp.int32)
    return tokens, targets


def _flats(d, n_chunks, seed=100):
    roles = ["embed"] + ["mid"] * (n_chunks - 2) + ["head"]
    return roles, [jnp.asarray(model.init_chunk(r, d, seed + i))
                   for i, r in enumerate(roles)]


# ---------------------------------------------------------------------------
# packing
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("role", ["embed", "mid", "head"])
def test_pack_unpack_roundtrip(role):
    rng = np.random.default_rng(0)
    flat = jnp.asarray(rng.normal(size=model.param_len(role, DIMS)),
                       jnp.float32)
    tree = model.unpack(flat, role, DIMS)
    back = model.pack(tree, role, DIMS)
    np.testing.assert_array_equal(flat, back)


@pytest.mark.parametrize("role", ["embed", "mid", "head"])
def test_param_len_matches_spec(role):
    spec = model.chunk_spec(role, DIMS)
    assert model.param_len(role, DIMS) == sum(
        int(np.prod(s)) for _, s in spec)
    # distinct names
    names = [n for n, _ in spec]
    assert len(names) == len(set(names))


def test_init_layernorm_gains_are_one():
    flat = model.init_chunk("mid", DIMS, 0)
    tree = model.unpack(jnp.asarray(flat), "mid", DIMS)
    np.testing.assert_array_equal(tree["l0.ln1_g"], np.ones(DIMS.hidden))
    np.testing.assert_array_equal(tree["l0.mlp1_b"],
                                  np.zeros(4 * DIMS.hidden))


def test_init_deterministic_per_seed():
    a = model.init_chunk("mid", DIMS, 5)
    b = model.init_chunk("mid", DIMS, 5)
    c = model.init_chunk("mid", DIMS, 6)
    np.testing.assert_array_equal(a, b)
    assert not np.array_equal(a, c)


# ---------------------------------------------------------------------------
# chunk decomposition == composed model
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n_chunks", [2, 3, 4])
def test_chunked_forward_matches_composed(n_chunks):
    rng = np.random.default_rng(1)
    tokens, targets = _batch(rng, DIMS)
    _, flats = _flats(DIMS, n_chunks)
    want = model.full_model_loss(tokens, targets, flats, DIMS)

    x = model.embed_fwd(tokens, flats[0], DIMS)
    for f in flats[1:-1]:
        x = model.mid_fwd(x, f, DIMS)
    got = model.head_fwd(x, targets, flats[-1], DIMS)
    np.testing.assert_allclose(got, want, atol=1e-6, rtol=1e-6)


@pytest.mark.parametrize("n_chunks", [2, 4])
def test_chunked_backward_matches_composed(n_chunks):
    rng = np.random.default_rng(2)
    tokens, targets = _batch(rng, DIMS)
    _, flats = _flats(DIMS, n_chunks)
    loss_want, dflats_want = model.full_model_grads(tokens, targets, flats,
                                                    DIMS)

    # Pipeline-style: forward chain stashing chunk inputs, then backward.
    acts = [model.embed_fwd(tokens, flats[0], DIMS)]
    for f in flats[1:-1]:
        acts.append(model.mid_fwd(acts[-1], f, DIMS))
    loss, dx, dlast = model.head_bwd(acts[-1], targets, flats[-1], DIMS)
    np.testing.assert_allclose(loss, loss_want, atol=1e-6, rtol=1e-6)
    dflats = [dlast]
    for i in range(n_chunks - 2, 0, -1):
        dx, df = model.mid_bwd(acts[i - 1], dx, flats[i], DIMS)
        dflats.append(df)
    dflats.append(model.embed_bwd(tokens, dx, flats[0], DIMS))
    dflats.reverse()
    for i, (got, want) in enumerate(zip(dflats, dflats_want)):
        np.testing.assert_allclose(got, want, atol=1e-5, rtol=1e-5,
                                   err_msg=f"chunk {i}")


def test_grad_accumulation_linearity():
    # Gradient of the mean loss over two micro-batches equals the mean of
    # per-micro-batch gradients — the property pipeline grad-accum relies on.
    rng = np.random.default_rng(3)
    t1, y1 = _batch(rng, DIMS)
    t2, y2 = _batch(rng, DIMS)
    _, flats = _flats(DIMS, 2)

    _, d1 = model.full_model_grads(t1, y1, flats, DIMS)
    _, d2 = model.full_model_grads(t2, y2, flats, DIMS)

    def mean_loss(fs):
        return 0.5 * (model.full_model_loss(t1, y1, fs, DIMS)
                      + model.full_model_loss(t2, y2, fs, DIMS))

    dm = jax.grad(mean_loss)(list(flats))
    for a, b, c in zip(d1, d2, dm):
        np.testing.assert_allclose(0.5 * (a + b), c, atol=1e-6, rtol=1e-5)


# ---------------------------------------------------------------------------
# trainability / loss sanity
# ---------------------------------------------------------------------------

def test_initial_loss_near_uniform():
    rng = np.random.default_rng(4)
    tokens, targets = _batch(rng, DIMS)
    _, flats = _flats(DIMS, 3)
    loss = model.full_model_loss(tokens, targets, flats, DIMS)
    assert abs(float(loss) - np.log(DIMS.vocab)) < 0.5


def test_sgd_steps_reduce_loss():
    rng = np.random.default_rng(5)
    tokens = jnp.asarray(rng.integers(0, DIMS.vocab, (DIMS.batch, DIMS.seq)),
                         jnp.int32)
    targets = jnp.roll(tokens, -1, axis=1)  # learnable shift task
    _, flats = _flats(DIMS, 2)
    flats = list(flats)
    first = float(model.full_model_loss(tokens, targets, flats, DIMS))
    for _ in range(20):
        _, grads = model.full_model_grads(tokens, targets, flats, DIMS)
        flats = [f - 0.5 * g for f, g in zip(flats, grads)]
    last = float(model.full_model_loss(tokens, targets, flats, DIMS))
    assert last < first - 0.2, f"loss did not drop: {first} -> {last}"


# ---------------------------------------------------------------------------
# jit/lowering entry points
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", model.ARTIFACT_NAMES)
def test_jitted_entry_points_lower(name):
    fn = model.jitted(name, DIMS)
    args = model.example_args(name, DIMS)
    lowered = fn.lower(*args)
    assert "stablehlo" in str(lowered.compiler_ir("stablehlo"))


def test_jitted_outputs_are_tuples():
    rng = np.random.default_rng(6)
    tokens, targets = _batch(rng, DIMS)
    flat = jnp.asarray(model.init_chunk("embed", DIMS, 1))
    out = model.jitted("fwd_embed", DIMS)(tokens, flat)
    assert isinstance(out, tuple) and len(out) == 1
    assert out[0].shape == (DIMS.batch, DIMS.seq, DIMS.hidden)
    hflat = jnp.asarray(model.init_chunk("head", DIMS, 2))
    out = model.jitted("bwd_head", DIMS)(out[0], targets, hflat)
    assert len(out) == 3  # (loss, dx, dflat)
    assert out[0].shape == ()
