"""Layer-2: the chunked GPT transformer (build-time JAX, calls the Layer-1
Pallas kernels), plus flat parameter packing.

The model is split into ``n_chunks`` pipeline stages:

* stage 0 (role ``embed``): token + position embeddings, then
  ``layers_per_chunk`` transformer layers;
* stages 1..n-2 (role ``mid``): ``layers_per_chunk`` transformer layers;
* stage n-1 (role ``head``): ``layers_per_chunk`` layers, final LayerNorm,
  LM head projection, mean cross-entropy loss.

Every chunk exposes a *flat f32 vector* parameter interface so the rust
coordinator never needs to know the pytree structure: the AOT artifacts
take/return ``f32[P]`` alongside activations. Backward functions recompute
the chunk forward from the stashed chunk *input* (per-chunk
rematerialization) — the activation stash the schedules account for is
exactly one chunk input per in-flight micro-batch, matching the paper's
`M_a` accounting.

All shapes are static (AOT): ``Dims(batch, seq, hidden, heads, vocab,
layers_per_chunk)``.
"""

import dataclasses
import functools
from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import flash_attention, layernorm


@dataclasses.dataclass(frozen=True)
class Dims:
    """Static chunk geometry."""
    batch: int
    seq: int
    hidden: int
    heads: int
    vocab: int
    layers_per_chunk: int

    def __post_init__(self):
        assert self.hidden % self.heads == 0, "hidden must divide by heads"

    @property
    def head_dim(self) -> int:
        return self.hidden // self.heads


# --------------------------------------------------------------------------
# Parameter specs and flat packing
# --------------------------------------------------------------------------

def layer_spec(d: Dims) -> List[Tuple[str, Tuple[int, ...]]]:
    """(name, shape) list for one transformer layer."""
    h = d.hidden
    return [
        ("ln1_g", (h,)), ("ln1_b", (h,)),
        ("qkv_w", (h, 3 * h)), ("qkv_b", (3 * h,)),
        ("proj_w", (h, h)), ("proj_b", (h,)),
        ("ln2_g", (h,)), ("ln2_b", (h,)),
        ("mlp1_w", (h, 4 * h)), ("mlp1_b", (4 * h,)),
        ("mlp2_w", (4 * h, h)), ("mlp2_b", (h,)),
    ]


def chunk_spec(role: str, d: Dims) -> List[Tuple[str, Tuple[int, ...]]]:
    """(name, shape) list for a chunk of the given role."""
    spec: List[Tuple[str, Tuple[int, ...]]] = []
    if role == "embed":
        spec.append(("tok_emb", (d.vocab, d.hidden)))
        spec.append(("pos_emb", (d.seq, d.hidden)))
    for i in range(d.layers_per_chunk):
        spec.extend((f"l{i}.{n}", s) for n, s in layer_spec(d))
    if role == "head":
        spec.append(("lnf_g", (d.hidden,)))
        spec.append(("lnf_b", (d.hidden,)))
        spec.append(("out_w", (d.hidden, d.vocab)))
    return spec


def param_len(role: str, d: Dims) -> int:
    """Flat parameter count of a chunk role."""
    return sum(int(np.prod(s)) for _, s in chunk_spec(role, d))


def unpack(flat, role: str, d: Dims) -> dict:
    """Flat f32[P] -> {name: array} for the chunk."""
    out = {}
    off = 0
    for name, shape in chunk_spec(role, d):
        n = int(np.prod(shape))
        out[name] = flat[off:off + n].reshape(shape)
        off += n
    assert off == flat.shape[0], f"{role}: flat len {flat.shape[0]} != {off}"
    return out


def pack(params: dict, role: str, d: Dims):
    """{name: array} -> flat f32[P] (inverse of :func:`unpack`)."""
    return jnp.concatenate(
        [params[name].reshape(-1) for name, _ in chunk_spec(role, d)])


def init_chunk(role: str, d: Dims, seed: int) -> np.ndarray:
    """Deterministic initialization of one chunk's flat parameter vector.

    Matmul weights ~ N(0, 0.02^2) (GPT-2 style), embedding rows likewise,
    biases zero, LayerNorm gains one. Returned as numpy so the AOT step can
    dump it straight to ``init_stage<k>.bin``.
    """
    rng = np.random.default_rng(seed)
    parts = []
    for name, shape in chunk_spec(role, d):
        leaf = name.rsplit(".", 1)[-1]
        if leaf.endswith("_g"):                 # LayerNorm gains
            parts.append(np.ones(shape, np.float32))
        elif leaf.endswith("_b"):               # biases / LayerNorm shifts
            parts.append(np.zeros(shape, np.float32))
        else:                                   # matmuls and embeddings
            parts.append(rng.normal(0.0, 0.02, shape).astype(np.float32))
    flat = np.concatenate([p.reshape(-1) for p in parts])
    assert flat.shape[0] == param_len(role, d)
    return flat


# --------------------------------------------------------------------------
# Chunk forward functions
# --------------------------------------------------------------------------

def _transformer_layer(x, p: dict, prefix: str, d: Dims):
    """Pre-LN transformer layer: x [B, S, H] -> [B, S, H]."""
    g = lambda n: p[f"{prefix}.{n}"]
    h = layernorm(x, g("ln1_g"), g("ln1_b"))
    qkv = h @ g("qkv_w") + g("qkv_b")                       # [B, S, 3H]
    b, s, _ = x.shape
    qkv = qkv.reshape(b, s, 3, d.heads, d.head_dim)
    q, k, v = (qkv[:, :, i].transpose(0, 2, 1, 3) for i in range(3))
    att = flash_attention(q, k, v, True)                    # [B, Hh, S, dh]
    att = att.transpose(0, 2, 1, 3).reshape(b, s, d.hidden)
    x = x + att @ g("proj_w") + g("proj_b")
    h = layernorm(x, g("ln2_g"), g("ln2_b"))
    h = jax.nn.gelu(h @ g("mlp1_w") + g("mlp1_b"))
    return x + h @ g("mlp2_w") + g("mlp2_b")


def _run_layers(x, p: dict, d: Dims):
    for i in range(d.layers_per_chunk):
        x = _transformer_layer(x, p, f"l{i}", d)
    return x


def embed_fwd(tokens, flat, d: Dims):
    """tokens i32[B, S], flat f32[Pe] -> activation f32[B, S, H]."""
    p = unpack(flat, "embed", d)
    x = p["tok_emb"][tokens] + p["pos_emb"][None, :, :]
    return _run_layers(x, p, d)


def mid_fwd(x, flat, d: Dims):
    """x f32[B, S, H], flat f32[Pm] -> f32[B, S, H]."""
    return _run_layers(x, unpack(flat, "mid", d), d)


def head_fwd(x, targets, flat, d: Dims):
    """x f32[B, S, H], targets i32[B, S], flat f32[Ph] -> mean NLL f32[]."""
    p = unpack(flat, "head", d)
    x = _run_layers(x, p, d)
    x = layernorm(x, p["lnf_g"], p["lnf_b"])
    logits = x @ p["out_w"]                                 # [B, S, V]
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)
    return nll.mean()


# --------------------------------------------------------------------------
# Chunk backward functions (recompute-from-input)
# --------------------------------------------------------------------------

def embed_bwd(tokens, g, flat, d: Dims):
    """VJP of embed_fwd w.r.t. flat params. Returns dflat f32[Pe]."""
    _, vjp = jax.vjp(lambda f: embed_fwd(tokens, f, d), flat)
    (dflat,) = vjp(g)
    return dflat


def mid_bwd(x, g, flat, d: Dims):
    """VJP of mid_fwd. Returns (dx, dflat)."""
    _, vjp = jax.vjp(lambda xi, f: mid_fwd(xi, f, d), x, flat)
    return vjp(g)


def head_bwd(x, targets, flat, d: Dims):
    """Loss + VJP of head_fwd (upstream gradient is 1.0).

    Returns (loss f32[], dx f32[B,S,H], dflat f32[Ph]).
    """
    loss, vjp = jax.vjp(lambda xi, f: head_fwd(xi, targets, f, d), x, flat)
    dx, dflat = vjp(jnp.ones_like(loss))
    return loss, dx, dflat


# --------------------------------------------------------------------------
# Whole-model reference (pytest oracle for the chunked decomposition)
# --------------------------------------------------------------------------

def full_model_loss(tokens, targets, flats: List, d: Dims):
    """Compose all chunks sequentially: the unpipelined ground truth."""
    x = embed_fwd(tokens, flats[0], d)
    for flat in flats[1:-1]:
        x = mid_fwd(x, flat, d)
    return head_fwd(x, targets, flats[-1], d)


def full_model_grads(tokens, targets, flats: List, d: Dims):
    """Loss and per-chunk flat gradients of the composed model."""
    loss, vjp = jax.vjp(
        lambda fs: full_model_loss(tokens, targets, fs, d), list(flats))
    (dflats,) = vjp(jnp.ones_like(loss))
    return loss, dflats


# --------------------------------------------------------------------------
# Jitted entry points (what aot.py lowers)
# --------------------------------------------------------------------------

def jitted(role_fn: str, d: Dims):
    """Return the jitted chunk function named by the artifact key."""
    fns = {
        "fwd_embed": lambda t, f: (embed_fwd(t, f, d),),
        "fwd_mid": lambda x, f: (mid_fwd(x, f, d),),
        "fwd_head": lambda x, t, f: (head_fwd(x, t, f, d),),
        "bwd_embed": lambda t, g, f: (embed_bwd(t, g, f, d),),
        "bwd_mid": lambda x, g, f: mid_bwd(x, g, f, d),
        "bwd_head": lambda x, t, f: head_bwd(x, t, f, d),
    }
    return jax.jit(fns[role_fn])


def example_args(role_fn: str, d: Dims):
    """ShapeDtypeStructs matching :func:`jitted`'s signature."""
    f32, i32 = jnp.float32, jnp.int32
    act = jax.ShapeDtypeStruct((d.batch, d.seq, d.hidden), f32)
    tok = jax.ShapeDtypeStruct((d.batch, d.seq), i32)
    p = lambda role: jax.ShapeDtypeStruct((param_len(role, d),), f32)
    return {
        "fwd_embed": (tok, p("embed")),
        "fwd_mid": (act, p("mid")),
        "fwd_head": (act, tok, p("head")),
        "bwd_embed": (tok, act, p("embed")),
        "bwd_mid": (act, act, p("mid")),
        "bwd_head": (act, tok, p("head")),
    }[role_fn]


ARTIFACT_NAMES = ["fwd_embed", "fwd_mid", "fwd_head",
                  "bwd_embed", "bwd_mid", "bwd_head"]
