"""AOT lowering: chunk functions -> HLO text artifacts + manifest + init
parameter vectors.

Run once at build time (``make artifacts``); the rust coordinator is fully
self-contained afterwards. Interchange format is HLO **text**, not
serialized ``HloModuleProto``: jax >= 0.5 emits protos with 64-bit
instruction ids which the crate's xla_extension 0.5.1 rejects; the text
parser reassigns ids and round-trips cleanly (see
/opt/xla-example/README.md).

Outputs in ``--out`` (default ``artifacts/``):

* ``<name>.hlo.txt``      — one per entry in :data:`model.ARTIFACT_NAMES`;
* ``init_stage<k>.bin``   — raw little-endian f32 initial parameter vector
  for pipeline stage ``k`` (deterministic seed per stage);
* ``manifest.txt``        — key=value contract consumed by
  ``rust/src/runtime/manifest.rs``: geometry, artifact files, flat param
  lengths, init files, and a self-check loss for the rust integration test.

Usage::

    python -m compile.aot --model gpt-tiny --out artifacts
    python -m compile.aot --hidden 256 --seq 128 --batch 4 --vocab 512 \
        --heads 8 --layers 8 --n-chunks 8 --out artifacts
"""

import argparse
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

if __package__ in (None, ""):
    sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    from compile import model  # type: ignore
else:
    from . import model

# Named presets mirroring rust/src/config/model.rs.
PRESETS = {
    # name: (batch, seq, hidden, heads, vocab, layers, n_chunks)
    "gpt-tiny": (4, 128, 256, 8, 512, 8, 8),
    "gpt-small": (2, 256, 768, 12, 2048, 12, 4),
}


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple=True)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def role_of_stage(stage: int, n_chunks: int) -> str:
    if stage == 0:
        return "embed"
    if stage + 1 == n_chunks:
        return "head"
    return "mid"


def selfcheck_loss(d: model.Dims, n_chunks: int, seed_base: int) -> float:
    """Composed-model loss on a fixed batch with the init params — the
    number the rust integration test must reproduce through the artifacts.
    """
    rng = np.random.default_rng(12345)
    tokens = jnp.asarray(rng.integers(0, d.vocab, (d.batch, d.seq)), jnp.int32)
    targets = jnp.asarray(rng.integers(0, d.vocab, (d.batch, d.seq)), jnp.int32)
    flats = [
        jnp.asarray(model.init_chunk(role_of_stage(k, n_chunks), d, seed_base + k))
        for k in range(n_chunks)
    ]
    return float(model.full_model_loss(tokens, targets, flats, d))


def build(out_dir: str, d: model.Dims, n_chunks: int, seed_base: int = 1000,
          model_name: str = "custom") -> None:
    os.makedirs(out_dir, exist_ok=True)
    lines = [
        f"# BitPipe AOT artifacts — model={model_name}",
        f"model={model_name}",
        f"hidden={d.hidden}",
        f"seq={d.seq}",
        f"batch={d.batch}",
        f"vocab={d.vocab}",
        f"heads={d.heads}",
        f"n_chunks={n_chunks}",
        f"layers_per_chunk={d.layers_per_chunk}",
    ]

    for role in ("embed", "mid", "head"):
        lines.append(f"params.{role}={model.param_len(role, d)}")

    for name in model.ARTIFACT_NAMES:
        fn = model.jitted(name, d)
        args = model.example_args(name, d)
        lowered = fn.lower(*args)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        lines.append(f"artifact.{name}={fname}")
        print(f"  lowered {name:10s} -> {fname} ({len(text)/1e6:.1f} MB)")

    for k in range(n_chunks):
        role = role_of_stage(k, n_chunks)
        flat = model.init_chunk(role, d, seed_base + k)
        fname = f"init_stage{k}.bin"
        flat.astype("<f4").tofile(os.path.join(out_dir, fname))
        lines.append(f"init.{k}={fname}")
    print(f"  wrote {n_chunks} init vectors")

    loss = selfcheck_loss(d, n_chunks, seed_base)
    lines.append(f"selfcheck.loss={loss:.6f}")
    print(f"  selfcheck loss = {loss:.6f} (~ln V = {np.log(d.vocab):.3f})")

    with open(os.path.join(out_dir, "manifest.txt"), "w") as f:
        f.write("\n".join(lines) + "\n")
    print(f"  manifest.txt written to {out_dir}/")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--model", choices=sorted(PRESETS), default=None)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--hidden", type=int, default=256)
    ap.add_argument("--heads", type=int, default=8)
    ap.add_argument("--vocab", type=int, default=512)
    ap.add_argument("--layers", type=int, default=8)
    ap.add_argument("--n-chunks", type=int, default=8)
    ap.add_argument("--seed-base", type=int, default=1000)
    ap.add_argument("--out", default="artifacts")
    args = ap.parse_args()

    if args.model:
        (args.batch, args.seq, args.hidden, args.heads, args.vocab,
         args.layers, args.n_chunks) = PRESETS[args.model]
    assert args.layers % args.n_chunks == 0, \
        f"layers={args.layers} must divide into n_chunks={args.n_chunks}"
    assert args.n_chunks >= 2, "need at least embed + head chunks"

    d = model.Dims(batch=args.batch, seq=args.seq, hidden=args.hidden,
                   heads=args.heads, vocab=args.vocab,
                   layers_per_chunk=args.layers // args.n_chunks)
    name = args.model or "custom"
    print(f"AOT: model={name} B={d.batch} S={d.seq} H={d.hidden} "
          f"heads={d.heads} V={d.vocab} layers/chunk={d.layers_per_chunk} "
          f"chunks={args.n_chunks} -> {args.out}/")
    build(args.out, d, args.n_chunks, args.seed_base, name)


if __name__ == "__main__":
    main()
