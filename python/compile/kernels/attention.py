"""Layer-1 Pallas flash-attention kernel (TPU-style, interpret mode on CPU).

The paper (BitPipe) targets A800 GPUs, but its contribution is the
*schedule*; the per-micro-batch hot spot is transformer-layer compute.
Following the hardware-adaptation rule, the attention core is written as a
Pallas kernel re-thought for the TPU memory hierarchy:

* the grid tiles queries into ``block_q`` panels per (batch*head) program —
  the BlockSpec expresses the HBM->VMEM schedule a CUDA flash-attention
  does with threadblocks;
* keys/values stream through VMEM in ``block_k`` panels with online-softmax
  accumulation (never materializing the S x S score matrix);
* panel contractions are plain ``jnp.dot`` so they lower onto the MXU
  systolic array on real hardware.

``interpret=True`` is mandatory here: the kernel lowers to plain HLO that
the CPU PJRT client (and the rust ``xla`` crate) can execute. Real-TPU
lowering would emit a Mosaic custom-call instead; VMEM footprint and MXU
utilization for that target are estimated in DESIGN.md §Perf.

The backward pass recomputes attention from the stashed q/k/v
(flash-attention-style rematerialization) using the closed-form softmax
VJP; it is registered through ``jax.custom_vjp`` so the kernel is
differentiable inside the Layer-2 chunk functions.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default panel sizes. 128 x head_dim f32 panels keep the working set
# (q panel + k/v panels + accumulators) comfortably under 1 MiB of VMEM
# (see DESIGN.md §Perf for the footprint math) while feeding the MXU
# full-width 128-lane contractions. On the CPU validation target the
# larger panels also halve interpret-mode loop overhead (§Perf: 18.2 ms ->
# 11.9 ms per attention call at B=4, H=8, S=128, d=32).
DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128

_NEG_INF = -1e30


def _attn_fwd_kernel(q_ref, k_ref, v_ref, o_ref, *, block_k: int, scale: float,
                     causal: bool):
    """One program: one q panel against all k/v panels (online softmax).

    Ref shapes (leading batch*head dim mapped by the BlockSpec):
      q_ref: [1, block_q, d]    o_ref: [1, block_q, d]
      k_ref: [1, S, d]          v_ref: [1, S, d]
    """
    q = q_ref[0].astype(jnp.float32) * scale          # [bq, d]
    block_q, d = q.shape
    s_len = k_ref.shape[1]
    qi = pl.program_id(1)
    n_kb = s_len // block_k

    def body(kb, carry):
        m_prev, l_prev, acc = carry
        k = k_ref[0, pl.dslice(kb * block_k, block_k), :].astype(jnp.float32)
        v = v_ref[0, pl.dslice(kb * block_k, block_k), :].astype(jnp.float32)
        s = jnp.dot(q, k.T)                            # [bq, bk] -> MXU
        if causal:
            rows = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
            cols = kb * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
            s = jnp.where(cols <= rows, s, _NEG_INF)
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_new = l_prev * alpha + p.sum(axis=-1)
        acc = acc * alpha[:, None] + jnp.dot(p, v)     # [bq, d] -> MXU
        return m_new, l_new, acc

    m0 = jnp.full((block_q,), _NEG_INF, jnp.float32)
    l0 = jnp.zeros((block_q,), jnp.float32)
    acc0 = jnp.zeros((block_q, d), jnp.float32)
    if causal:
        # Panels strictly above the diagonal contribute nothing; stop the
        # scan at the last panel intersecting this q panel.
        upper = (qi + 1) * block_q + block_k - 1
        n_iter = jnp.minimum(n_kb, upper // block_k)
    else:
        n_iter = n_kb
    m, l, acc = jax.lax.fori_loop(0, n_iter, body, (m0, l0, acc0))
    o_ref[0] = (acc / l[:, None]).astype(o_ref.dtype)


def _flash_attention_fwd(q, k, v, *, causal: bool, block_q: int, block_k: int):
    """Pallas forward over merged batch*head leading dim.

    q, k, v: [BH, S, d] -> out [BH, S, d].
    """
    bh, s_len, d = q.shape
    assert s_len % block_q == 0 and s_len % block_k == 0, (
        f"seq len {s_len} must be a multiple of block sizes "
        f"({block_q}, {block_k})")
    scale = 1.0 / (d ** 0.5)
    kernel = functools.partial(
        _attn_fwd_kernel, block_k=block_k, scale=scale, causal=causal)
    return pl.pallas_call(
        kernel,
        grid=(bh, s_len // block_q),
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, s_len, d), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((1, s_len, d), lambda b, i: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda b, i: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        interpret=True,
    )(q, k, v)


def _attention_bwd_math(q, k, v, do, *, causal: bool):
    """Closed-form attention VJP (recompute-from-inputs, O(S^2) per head).

    All inputs [BH, S, d]. Returns (dq, dk, dv).
    """
    d = q.shape[-1]
    scale = 1.0 / (d ** 0.5)
    s = jnp.einsum("bqd,bkd->bqk", q, k) * scale
    if causal:
        s_len = q.shape[1]
        mask = jnp.tril(jnp.ones((s_len, s_len), bool))
        s = jnp.where(mask[None], s, _NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    dv = jnp.einsum("bqk,bqd->bkd", p, do)
    dp = jnp.einsum("bqd,bkd->bqk", do, v)
    ds = p * (dp - jnp.sum(dp * p, axis=-1, keepdims=True))
    dq = jnp.einsum("bqk,bkd->bqd", ds, k) * scale
    dk = jnp.einsum("bqk,bqd->bkd", ds, q) * scale
    return dq, dk, dv


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def flash_attention(q, k, v, causal: bool = True,
                    block_q: int = DEFAULT_BLOCK_Q,
                    block_k: int = DEFAULT_BLOCK_K):
    """Multi-head attention core.

    q, k, v: [B, H, S, d]; returns [B, H, S, d]. ``causal=True`` applies the
    autoregressive mask (GPT); ``False`` gives full bidirectional attention
    (BERT).
    """
    b, h, s_len, d = q.shape
    bq = min(block_q, s_len)
    bk = min(block_k, s_len)
    merged = lambda t: t.reshape(b * h, s_len, d)
    out = _flash_attention_fwd(merged(q), merged(k), merged(v),
                               causal=causal, block_q=bq, block_k=bk)
    return out.reshape(b, h, s_len, d)


def _fa_fwd(q, k, v, causal, block_q, block_k):
    out = flash_attention(q, k, v, causal, block_q, block_k)
    return out, (q, k, v)


def _fa_bwd(causal, block_q, block_k, res, g):
    q, k, v = res
    b, h, s_len, d = q.shape
    merged = lambda t: t.reshape(b * h, s_len, d)
    dq, dk, dv = _attention_bwd_math(
        merged(q), merged(k), merged(v), merged(g), causal=causal)
    unmerge = lambda t: t.reshape(b, h, s_len, d)
    return unmerge(dq), unmerge(dk), unmerge(dv)


flash_attention.defvjp(_fa_fwd, _fa_bwd)
