"""Layer-1 Pallas fused LayerNorm kernel (interpret mode on CPU).

LayerNorm is the second memory-bound hot spot of the transformer layer
(after attention); the fused kernel reads each row of the activation once,
computes mean/variance in registers, and writes the normalized+affine
result — one HBM round-trip instead of the four a naive composition makes.

The grid tiles rows (token positions); each program normalizes a
``block_rows`` x H panel held in VMEM. Differentiation goes through
``jax.custom_vjp`` with the closed-form LayerNorm VJP.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_ROWS = 64


def _layernorm_kernel(x_ref, g_ref, b_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)                 # [rows, H]
    mean = x.mean(axis=-1, keepdims=True)
    xc = x - mean
    var = (xc * xc).mean(axis=-1, keepdims=True)
    inv = jax.lax.rsqrt(var + eps)
    y = xc * inv * g_ref[...][None, :] + b_ref[...][None, :]
    o_ref[...] = y.astype(o_ref.dtype)


def _layernorm_fwd_pallas(x2d, gamma, beta, *, eps: float, block_rows: int):
    n, h = x2d.shape
    assert n % block_rows == 0, f"{n} rows not a multiple of {block_rows}"
    kernel = functools.partial(_layernorm_kernel, eps=eps)
    return pl.pallas_call(
        kernel,
        grid=(n // block_rows,),
        in_specs=[
            pl.BlockSpec((block_rows, h), lambda i: (i, 0)),
            pl.BlockSpec((h,), lambda i: (0,)),
            pl.BlockSpec((h,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows, h), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(x2d.shape, x2d.dtype),
        interpret=True,
    )(x2d, gamma, beta)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def layernorm(x, gamma, beta, eps: float = 1e-5):
    """LayerNorm over the last axis. x: [..., H]; gamma, beta: [H]."""
    shape = x.shape
    h = shape[-1]
    n = x.size // h
    rows = min(DEFAULT_BLOCK_ROWS, n)
    while n % rows != 0:  # degrade gracefully for odd row counts
        rows -= 1
    y = _layernorm_fwd_pallas(x.reshape(n, h), gamma, beta,
                              eps=eps, block_rows=rows)
    return y.reshape(shape)


def _ln_fwd(x, gamma, beta, eps):
    return layernorm(x, gamma, beta, eps), (x, gamma)


def _ln_bwd(eps, res, g):
    x, gamma = res
    mean = x.mean(axis=-1, keepdims=True)
    xc = x - mean
    var = (xc * xc).mean(axis=-1, keepdims=True)
    inv = jax.lax.rsqrt(var + eps)
    xhat = xc * inv
    dgamma = jnp.sum(g * xhat, axis=tuple(range(x.ndim - 1)))
    dbeta = jnp.sum(g, axis=tuple(range(x.ndim - 1)))
    h = x.shape[-1]
    gg = g * gamma
    dx = inv * (gg - gg.mean(axis=-1, keepdims=True)
                - xhat * (gg * xhat).mean(axis=-1, keepdims=True))
    del h
    return dx, dgamma, dbeta


layernorm.defvjp(_ln_fwd, _ln_bwd)
