"""Layer-1 Pallas kernels (build-time only; lowered into the L2 HLO)."""

from .attention import flash_attention
from .fused_ops import layernorm

__all__ = ["flash_attention", "layernorm"]
