"""Pure-jnp oracles for the Pallas kernels (Layer-1 correctness ground
truth). Every kernel in this package is checked against these references by
``python/tests/test_kernel.py`` (hypothesis shape/dtype sweeps) before the
AOT artifacts are considered valid.
"""

import jax
import jax.numpy as jnp

_NEG_INF = -1e30


def attention_ref(q, k, v, causal: bool = True):
    """Naive softmax attention. q, k, v: [B, H, S, d]."""
    d = q.shape[-1]
    s = jnp.einsum("bhqd,bhkd->bhqk", q, k) / (d ** 0.5)
    if causal:
        s_len = q.shape[2]
        mask = jnp.tril(jnp.ones((s_len, s_len), bool))
        s = jnp.where(mask[None, None], s, _NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v)


def layernorm_ref(x, gamma, beta, eps: float = 1e-5):
    """LayerNorm over the last axis."""
    mean = x.mean(axis=-1, keepdims=True)
    var = x.var(axis=-1, keepdims=True)
    return (x - mean) / jnp.sqrt(var + eps) * gamma + beta
