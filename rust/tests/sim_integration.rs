//! Integration tests over the simulator + eval harness: the paper's
//! headline *shapes* (who wins, orderings, trends) must hold end to end.

use bitpipe::config::{ClusterConfig, ParallelConfig, BERT_64, GPT_96};
use bitpipe::schedule::ScheduleKind;
use bitpipe::sim::{grid_search, simulate, GridSpace, SimConfig};

fn thr(kind: ScheduleKind, w: usize, d: usize, b: usize, n: usize, gpus: usize) -> f64 {
    let parallel = ParallelConfig::new(kind, w, d, b, n);
    let cluster = ClusterConfig::paper_testbed(gpus);
    simulate(&SimConfig::new(BERT_64, parallel, cluster)).unwrap().throughput
}

#[test]
fn fig9_bitpipe_leads_all_minibatch_sizes_bert() {
    // Paper Fig 9 headline: pipeline-only on 8 GPUs, BitPipe beats every
    // baseline at B-hat in {32, 64, 128}.
    for n in [8usize, 16, 32] {
        let bit = thr(ScheduleKind::BitPipe, 1, 8, 4, n, 8);
        for kind in [ScheduleKind::Dapple, ScheduleKind::Interleaved, ScheduleKind::Chimera] {
            let base = thr(kind, 1, 8, 4, n, 8);
            assert!(
                bit > base,
                "N={n}: BitPipe {bit:.2} !> {kind} {base:.2}"
            );
        }
    }
}

#[test]
fn fig9_lead_narrows_with_minibatch() {
    // Paper: "the leading edge of BitPipe slows down with the increase in
    // mini-batch size" (more P2P per unit of compute).
    let lead = |n: usize| {
        thr(ScheduleKind::BitPipe, 1, 8, 4, n, 8) / thr(ScheduleKind::Dapple, 1, 8, 4, n, 8)
    };
    assert!(lead(8) > lead(32), "lead at N=8 {:.3} !> lead at N=32 {:.3}", lead(8), lead(32));
}

#[test]
fn fig10_bitpipe_leads_at_all_scales() {
    for gpus in [8usize, 16, 32] {
        let w = gpus / 8;
        let bit = thr(ScheduleKind::BitPipe, w, 8, 4, 8, gpus);
        for kind in [ScheduleKind::Dapple, ScheduleKind::Interleaved, ScheduleKind::MixPipe] {
            let base = thr(kind, w, 8, 4, 8, gpus);
            assert!(bit > base, "{gpus} GPUs: BitPipe {bit:.2} !> {kind} {base:.2}");
        }
    }
}

#[test]
fn fig10_multinode_degrades_lead() {
    // Paper: BitPipe's advantage shrinks under multi-node settings.
    let lead_1node = thr(ScheduleKind::BitPipe, 1, 8, 4, 8, 8)
        / thr(ScheduleKind::Interleaved, 1, 8, 4, 8, 8);
    let lead_4node = thr(ScheduleKind::BitPipe, 4, 8, 4, 8, 32)
        / thr(ScheduleKind::Interleaved, 4, 8, 4, 8, 32);
    assert!(
        lead_4node < lead_1node + 0.02,
        "multi-node lead {lead_4node:.3} did not shrink vs single-node {lead_1node:.3}"
    );
}

#[test]
fn fig8_bitpipe_memory_narrowest_spread() {
    // Fig 8: BitPipe's per-device memory spread is the narrowest of the
    // pipeline-only approaches at D=8.
    let spread = |kind: ScheduleKind| {
        let parallel = ParallelConfig::new(kind, 1, 8, 4, 8);
        let cluster = ClusterConfig::paper_testbed(8);
        simulate(&SimConfig::new(BERT_64, parallel, cluster)).unwrap().memory.spread()
    };
    let bit = spread(ScheduleKind::BitPipe);
    for kind in [ScheduleKind::Dapple, ScheduleKind::Interleaved] {
        assert!(
            bit < spread(kind),
            "BitPipe spread {bit} !< {kind} {}",
            spread(kind)
        );
    }
}

#[test]
fn table4_grid_search_prefers_d8_for_bitpipe_on_32() {
    // Paper Tables 4/7: D=8 is the throughput sweet spot on 32 GPUs.
    let points = grid_search(
        ScheduleKind::BitPipe,
        &BERT_64,
        &GridSpace::bert64(),
        32,
        128,
    )
    .unwrap();
    let best = points.first().expect("no feasible point");
    assert_eq!(best.parallel.d, 8, "best D is {}", best.parallel.d);
}

#[test]
fn gpt96_fits_and_bitpipe_wins() {
    // GPT-96 (11B) at D=8 B=1 must fit in 80 GB and BitPipe must lead.
    let cluster = ClusterConfig::paper_testbed(8);
    let mk = |kind| {
        simulate(&SimConfig::new(GPT_96, ParallelConfig::new(kind, 1, 8, 1, 8), cluster))
            .unwrap()
    };
    let bit = mk(ScheduleKind::BitPipe);
    assert!(bit.fits(&cluster), "GPT-96 OOM: {} GiB", bit.peak_memory() >> 30);
    for kind in [ScheduleKind::Dapple, ScheduleKind::Interleaved, ScheduleKind::Chimera] {
        assert!(bit.throughput > mk(kind).throughput, "vs {kind}");
    }
}

#[test]
fn table5_ablation_ordering() {
    // Full BitPipe >= both ablations on a single NVLink node.
    use bitpipe::schedule::SyncPolicy;
    let run = |kind: ScheduleKind, sync: SyncPolicy| {
        let mut parallel = ParallelConfig::new(kind, 1, 8, 4, 16);
        parallel.sync = sync;
        let cluster = ClusterConfig::single_node(8);
        simulate(&SimConfig::new(BERT_64, parallel, cluster)).unwrap().throughput
    };
    let full = run(ScheduleKind::BitPipe, SyncPolicy::Eager);
    let no_v = run(ScheduleKind::BitPipeNoV, SyncPolicy::Eager);
    let no_e = run(ScheduleKind::BitPipe, SyncPolicy::Lazy);
    // The paper's own single-node ablation deltas are <1% (Table 5); allow
    // the same order of noise in the simulated comparison.
    assert!(full >= no_v * 0.995, "full {full:.2} < w/o V {no_v:.2}");
    assert!(full >= no_e * 0.995, "full {full:.2} < w/o E {no_e:.2}");
}

#[test]
fn eval_harness_regenerates_everything() {
    for out in bitpipe::eval::run("all").unwrap() {
        assert!(!out.body.is_empty(), "{}: empty", out.id);
    }
}
