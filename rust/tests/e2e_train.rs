//! End-to-end integration over the real three-layer stack: AOT artifacts
//! (Pallas kernels inside JAX chunk HLO) executed by the threaded rust
//! coordinator. Requires `make artifacts`; tests skip politely if the
//! artifact directory is absent.

use bitpipe::runtime::Manifest;
use bitpipe::schedule::ScheduleKind;
use bitpipe::train::{run, DatasetKind, TrainConfig};

fn artifacts_dir() -> Option<std::path::PathBuf> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.txt").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: no artifacts/ (run `make artifacts` first)");
        None
    }
}

fn base_cfg(kind: ScheduleKind, d: usize, n: usize, steps: usize) -> Option<TrainConfig> {
    let dir = artifacts_dir()?;
    let mut cfg = TrainConfig::new(dir, kind, d, n);
    cfg.steps = steps;
    cfg.dataset = DatasetKind::Synthetic;
    // Fail fast on schedule deadlocks: seconds, not the default 30 s.
    cfg.recv_timeout = std::time::Duration::from_secs(5);
    Some(cfg)
}

#[test]
fn initial_loss_is_near_uniform() {
    // First-iteration loss must sit near ln(vocab) — the untrained model's
    // entropy — proving the whole artifact chain computes the right thing.
    let Some(cfg) = base_cfg(ScheduleKind::BitPipe, 4, 4, 1) else { return };
    let manifest = Manifest::load(cfg.artifacts.join("manifest.txt")).unwrap();
    let report = run(&cfg).unwrap();
    let expect = (manifest.vocab as f64).ln();
    let got = report.losses[0];
    assert!(
        (got - expect).abs() < 0.5,
        "first loss {got:.3} far from ln(V) = {expect:.3}"
    );
}

#[test]
fn schedules_are_numerically_equivalent() {
    // Synchronous semantics: every schedule computes the same mini-batch
    // gradient, so different schedules from the same init + data produce
    // the same loss curve (up to f32 reduction-order noise). This is the
    // strongest correctness statement about the coordinator: BitPipe's
    // fused bidirectional execution == plain 1F1B execution.
    let Some(cfg_a) = base_cfg(ScheduleKind::BitPipe, 4, 8, 3) else { return };
    let Some(cfg_b) = base_cfg(ScheduleKind::Dapple, 8, 8, 3) else { return };
    let a = run(&cfg_a).unwrap();
    let b = run(&cfg_b).unwrap();
    for (i, (la, lb)) in a.losses.iter().zip(&b.losses).enumerate() {
        assert!(
            (la - lb).abs() < 2e-3,
            "iter {i}: bitpipe {la:.5} vs dapple {lb:.5}"
        );
    }
}

#[test]
fn v_shape_does_fewer_p2p_transfers_for_real() {
    // The V-shape's local-copy saving must show up in the real runtime's
    // counters, not just the analytical model.
    let Some(cfg_v) = base_cfg(ScheduleKind::VShaped, 4, 4, 1) else { return };
    let Some(cfg_l) = base_cfg(ScheduleKind::Interleaved, 4, 4, 1) else { return };
    let v = run(&cfg_v).unwrap();
    let l = run(&cfg_l).unwrap();
    assert!(v.counters.local_copies > 0, "no local copies in V-shaped run");
    assert!(
        v.counters.p2p_msgs < l.counters.p2p_msgs,
        "V-shape sent {} msgs, looping sent {}",
        v.counters.p2p_msgs,
        l.counters.p2p_msgs
    );
    assert_eq!(
        v.counters.p2p_msgs + v.counters.local_copies,
        l.counters.p2p_msgs,
        "hand-off count must be conserved"
    );
}

#[test]
fn loss_decreases_over_training() {
    let Some(mut cfg) = base_cfg(ScheduleKind::BitPipe, 4, 8, 10) else { return };
    cfg.adam.lr = 2e-3;
    let report = run(&cfg).unwrap();
    let first = report.losses[0];
    let last = *report.losses.last().unwrap();
    assert!(
        last < first - 0.05,
        "loss did not decrease: {first:.4} -> {last:.4} ({:?})",
        report.losses
    );
}

#[test]
fn counters_match_schedule_accounting() {
    // Real-run counters must equal the schedule's analytical op counts.
    use bitpipe::schedule::{self, ScheduleConfig};
    let Some(cfg) = base_cfg(ScheduleKind::BitPipe, 4, 4, 2) else { return };
    let report = run(&cfg).unwrap();
    let s = schedule::build(&ScheduleConfig::new(ScheduleKind::BitPipe, 4, 4)).unwrap();
    let per_iter_p2p: usize = schedule::comm_pass::p2p_send_counts(&s).iter().sum();
    let per_iter_copies: usize = schedule::comm_pass::local_copy_counts(&s).iter().sum();
    let chunk_ops = 4 * 2 * 4; // N * v * D forwards per iteration
    assert_eq!(report.counters.forwards, (2 * chunk_ops) as u64);
    assert_eq!(report.counters.backwards, (2 * chunk_ops) as u64);
    assert_eq!(report.counters.p2p_msgs, (2 * per_iter_p2p) as u64);
    assert_eq!(report.counters.local_copies, (2 * per_iter_copies) as u64);
    // 8 stages, each all-reduced once per iteration across its twin pair
    // (2 devices) => 16 device-side completions per iteration.
    assert_eq!(report.counters.allreduces, 2 * 16);
    assert_eq!(report.counters.optim_steps, 2 * 16);
}

#[test]
fn checkpoint_resume_is_bit_exact() {
    // Interrupted training (save after 2 iters, resume for 2 more) must
    // match 4 uninterrupted iterations exactly: same losses, since data is
    // a pure function of (seed, iter) and the checkpoint carries the full
    // optimizer state.
    let Some(mut cfg_full) = base_cfg(ScheduleKind::BitPipe, 4, 4, 4) else { return };
    cfg_full.adam.lr = 2e-3;
    let full = run(&cfg_full).unwrap();

    let dir = std::env::temp_dir().join("bitpipe_e2e_ckpt");
    let _ = std::fs::remove_dir_all(&dir);
    let mut cfg_a = cfg_full.clone();
    cfg_a.steps = 2;
    cfg_a.save_to = Some(dir.clone());
    let first = run(&cfg_a).unwrap();

    let mut cfg_b = cfg_full.clone();
    cfg_b.steps = 2;
    cfg_b.resume_from = Some(dir.clone());
    let second = run(&cfg_b).unwrap();

    let resumed: Vec<f64> =
        first.losses.iter().chain(&second.losses).copied().collect();
    for (i, (a, b)) in full.losses.iter().zip(&resumed).enumerate() {
        assert!(
            (a - b).abs() < 1e-6,
            "iter {i}: uninterrupted {a:.6} vs resumed {b:.6}"
        );
    }
    // (Holds because the worker advances data/tags by the *global*
    // iteration index carried in the checkpoint.)
}

#[test]
fn worker_death_fails_fast_not_on_timeout() {
    // When one worker dies, its PoisonGuard poisons the fabric and every
    // peer blocked in recv aborts immediately — the run must fail well
    // under the recv timeout, and the reported error must be the root
    // cause (the dead worker), not a peer's collateral Poisoned error.
    let Some(mut cfg) = base_cfg(ScheduleKind::BitPipe, 4, 4, 3) else { return };
    cfg.recv_timeout = std::time::Duration::from_secs(30);
    cfg.inject_fail = Some((2, 1)); // device 2 dies entering iteration 1
    let start = std::time::Instant::now();
    let err = run(&cfg).expect_err("run with a dead worker must fail");
    let elapsed = start.elapsed();
    let msg = format!("{err:#}");
    assert!(
        msg.contains("injected failure"),
        "expected the root-cause error, got: {msg}"
    );
    assert!(
        elapsed < std::time::Duration::from_secs(10),
        "fail-fast took {elapsed:?} — peers waited toward the 30 s timeout"
    );
}

#[test]
fn recovery_from_mid_run_checkpoint_is_bit_exact() {
    // Kill a worker mid-run; the periodic checkpoint published before the
    // crash must be complete (atomic swap) and resuming from it must
    // finish identically to the uninterrupted run.
    let Some(mut cfg_full) = base_cfg(ScheduleKind::BitPipe, 4, 4, 4) else { return };
    cfg_full.adam.lr = 2e-3;
    let full = run(&cfg_full).unwrap();

    let dir = std::env::temp_dir().join("bitpipe_e2e_recovery");
    let _ = std::fs::remove_dir_all(&dir);
    let mut cfg_crash = cfg_full.clone();
    cfg_crash.save_to = Some(dir.clone());
    cfg_crash.save_every = 2;
    cfg_crash.inject_fail = Some((0, 3)); // dies after the iter-2 snapshot
    run(&cfg_crash).expect_err("injected crash must surface");

    // The snapshot on disk is the complete iteration-2 state.
    let ckpt = bitpipe::train::checkpoint::Checkpoint::load(&dir).unwrap();
    assert_eq!(ckpt.iteration, 2, "published snapshot is not the iter-2 boundary");

    let mut cfg_resume = cfg_full.clone();
    cfg_resume.steps = 2;
    cfg_resume.resume_from = Some(dir.clone());
    let tail = run(&cfg_resume).unwrap();
    for (i, (a, b)) in full.losses[2..].iter().zip(&tail.losses).enumerate() {
        assert!(
            (a - b).abs() < 1e-6,
            "iter {}: uninterrupted {a:.6} vs recovered {b:.6}",
            i + 2
        );
    }
}

#[test]
fn eager_and_lazy_sync_same_numerics() {
    use bitpipe::schedule::SyncPolicy;
    let Some(cfg_e) = base_cfg(ScheduleKind::BitPipe, 4, 4, 2) else { return };
    let mut cfg_l = cfg_e.clone();
    cfg_l.sync = SyncPolicy::Lazy;
    let e = run(&cfg_e).unwrap();
    let l = run(&cfg_l).unwrap();
    for (i, (le, ll)) in e.losses.iter().zip(&l.losses).enumerate() {
        assert!((le - ll).abs() < 1e-5, "iter {i}: eager {le} vs lazy {ll}");
    }
}
