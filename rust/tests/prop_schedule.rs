//! Property-based tests over the schedule generators: random
//! configurations are drawn, built, and checked against the full invariant
//! suite (`schedule::validate`) plus cross-cutting properties the paper
//! states. Failures shrink to a minimal reproducer.

use bitpipe::schedule::{
    self, analysis, build, Costs, ScheduleConfig, ScheduleKind, SyncPolicy,
};
use bitpipe::util::{forall, Gen};

/// A randomly drawable schedule configuration.
#[derive(Debug, Clone, Copy, PartialEq)]
struct Draw {
    kind_idx: usize,
    d_idx: usize,
    k_idx: usize,
    lazy: bool,
    no_ef: bool,
}

const DS: [usize; 3] = [2, 4, 8];
const KS: [usize; 3] = [1, 2, 4]; // N = K * D

fn cfg_of(draw: &Draw) -> ScheduleConfig {
    let kind = ScheduleKind::ALL[draw.kind_idx];
    let d = DS[draw.d_idx];
    let n = KS[draw.k_idx] * d;
    ScheduleConfig::new(kind, d, n)
        .with_sync(if draw.lazy { SyncPolicy::Lazy } else { SyncPolicy::Eager })
        .with_early_forward(!draw.no_ef)
}

fn gen_draw() -> Gen<Draw> {
    Gen {
        draw: Box::new(|r| Draw {
            kind_idx: r.range(0, ScheduleKind::ALL.len()),
            d_idx: r.range(0, DS.len()),
            k_idx: r.range(0, KS.len()),
            lazy: r.chance(0.3),
            no_ef: r.chance(0.3),
        }),
        shrink: Box::new(|d| {
            let mut out = Vec::new();
            // Shrink toward the smallest/simplest config.
            if d.d_idx > 0 {
                out.push(Draw { d_idx: d.d_idx - 1, ..*d });
            }
            if d.k_idx > 0 {
                out.push(Draw { k_idx: d.k_idx - 1, ..*d });
            }
            if d.lazy {
                out.push(Draw { lazy: false, ..*d });
            }
            if d.no_ef {
                out.push(Draw { no_ef: false, ..*d });
            }
            out
        }),
    }
}

#[test]
fn random_configs_build_and_validate() {
    forall(0xB17, 100, &gen_draw(), |draw| {
        let cfg = cfg_of(draw);
        match build(&cfg) {
            Ok(s) => schedule::validate::validate(&s).map_err(|e| format!("{cfg:?}: {e}")),
            Err(e) => Err(format!("{cfg:?} failed to build: {e}")),
        }
    });
}

#[test]
fn random_configs_are_lint_clean() {
    // The static analyzer is strictly stronger than validate (it also
    // checks deadlock-freedom, FIFO hazards, memory ceilings, and eager
    // placement): every generated family must come out of it with zero
    // errors AND zero warnings, under every draw.
    forall(0x117, 80, &gen_draw(), |draw| {
        let cfg = cfg_of(draw);
        let s = build(&cfg).map_err(|e| format!("{cfg:?} failed to build: {e}"))?;
        let r = schedule::lint(&s);
        let (e, w, _) = r.counts();
        if e > 0 || w > 0 {
            let worst: Vec<String> = r
                .diags
                .iter()
                .filter(|d| d.severity != schedule::Severity::Info)
                .map(ToString::to_string)
                .collect();
            return Err(format!("{cfg:?}: lint not clean: {worst:?}"));
        }
        Ok(())
    });
}

#[test]
fn device_ops_retime_and_simulate() {
    use bitpipe::config::{ClusterConfig, ParallelConfig, BERT_64};
    use bitpipe::sim::{simulate_schedule, CostModel};
    forall(0xCAFE, 40, &gen_draw(), |draw| {
        let cfg = cfg_of(draw);
        let s = build(&cfg).map_err(|e| e.to_string())?;
        let p = ParallelConfig::new(cfg.kind, 1, cfg.d, 1, cfg.n);
        let cm = CostModel::new(&BERT_64, &p, &ClusterConfig::paper_testbed(cfg.d));
        let t = simulate_schedule(&s, &cm).map_err(|e| format!("{cfg:?}: sim {e}"))?;
        if t.makespan <= 0.0 {
            return Err(format!("{cfg:?}: non-positive makespan"));
        }
        // Makespan can never beat the per-device serial compute.
        for (dev, tr) in t.devices.iter().enumerate() {
            if tr.compute_busy > t.makespan + 1e-9 {
                return Err(format!("{cfg:?}: dev {dev} busier than makespan"));
            }
        }
        Ok(())
    });
}

#[test]
fn bubble_ratio_never_below_formula_floor() {
    // The closed forms are *lower bounds* for our generators (exact for
    // the explicit constructions, within tolerance for the fused ones).
    forall(0xF00D, 60, &gen_draw(), |draw| {
        let cfg = cfg_of(draw);
        if cfg.kind == ScheduleKind::Gems {
            return Ok(()); // GEMS has no closed form in the paper
        }
        let s = build(&cfg).map_err(|e| e.to_string())?;
        let measured = analysis::bubble_ratio_measured(&s, &Costs::default())
            .map_err(|e| e.to_string())?;
        let formula =
            analysis::bubble_ratio_formula(cfg.kind, cfg.d, cfg.n, cfg.early_forward);
        if measured + 1e-9 < formula * 0.999 {
            return Err(format!(
                "{cfg:?}: measured {measured:.4} below the closed-form floor {formula:.4}"
            ));
        }
        Ok(())
    });
}

#[test]
fn send_recv_pairing_is_total() {
    // Stronger restatement of comm pairing: per (src,dst) edge, counts of
    // sends and receives match exactly.
    use bitpipe::schedule::Instr;
    use std::collections::HashMap;
    forall(0xBEEF, 80, &gen_draw(), |draw| {
        let cfg = cfg_of(draw);
        let s = build(&cfg).map_err(|e| e.to_string())?;
        let mut edges: HashMap<(usize, usize), i64> = HashMap::new();
        for (dev, ops) in s.device_ops.iter().enumerate() {
            for op in ops {
                match *op {
                    Instr::SendAct { to, .. } | Instr::SendGrad { to, .. } => {
                        *edges.entry((dev, to)).or_default() += 1;
                    }
                    Instr::RecvAct { from, .. } | Instr::RecvGrad { from, .. } => {
                        *edges.entry((from, dev)).or_default() -= 1;
                    }
                    _ => {}
                }
            }
        }
        for (edge, imbalance) in edges {
            if imbalance != 0 {
                return Err(format!("{cfg:?}: edge {edge:?} imbalance {imbalance}"));
            }
        }
        Ok(())
    });
}

#[test]
fn local_copies_only_in_v_family() {
    forall(0xD00D, 60, &gen_draw(), |draw| {
        let cfg = cfg_of(draw);
        let s = build(&cfg).map_err(|e| e.to_string())?;
        let copies: usize = schedule::comm_pass::local_copy_counts(&s).iter().sum();
        let is_v = matches!(cfg.kind, ScheduleKind::VShaped | ScheduleKind::BitPipe);
        if is_v && copies == 0 {
            return Err(format!("{cfg:?}: V-shaped schedule produced no local copies"));
        }
        if !is_v && copies != 0 {
            return Err(format!("{cfg:?}: non-V schedule produced {copies} local copies"));
        }
        Ok(())
    });
}

#[test]
fn weights_per_device_match_table2() {
    forall(0xABBA, 60, &gen_draw(), |draw| {
        let cfg = cfg_of(draw);
        let s = build(&cfg).map_err(|e| e.to_string())?;
        let weights = analysis::weights_memory_measured(&s);
        let want = if cfg.kind.bidirectional() { 2.0 } else { 1.0 };
        for (dev, w) in weights.iter().enumerate() {
            if (w - want).abs() > 1e-9 {
                return Err(format!("{cfg:?}: dev {dev} holds {w} x M_theta, want {want}"));
            }
        }
        Ok(())
    });
}
