//! Differential tests for the compiled-DAG backend (`sim::dag`): on every
//! valid schedule the weighted longest-path evaluation must be
//! **bit-identical** to the uncontended event-queue engine — makespan,
//! per-device accounting, and multi-iteration boundaries alike — and must
//! report the same deadlocks. Random configurations are drawn through the
//! in-tree property harness (`bitpipe::util::prop`) and shrunk on failure.

use bitpipe::config::{ClusterConfig, MappingPolicy, ParallelConfig, BERT_64};
use bitpipe::schedule::{build, ScheduleConfig, ScheduleKind, SyncPolicy};
use bitpipe::sim::{
    simulate_schedule, simulate_schedule_iters, CompiledDag, CostModel, DagWeights, LinkTopology,
    MultiIterTrace,
};
use bitpipe::util::{forall, Gen};

/// A randomly drawable (kind, D, N, sync, B) configuration. N sweeps the
/// issue's {4, 8, 16} set; D covers the shallow and paper-default depths;
/// B varies the weights over a fixed structure.
#[derive(Debug, Clone, Copy, PartialEq)]
struct Draw {
    kind_idx: usize,
    d_idx: usize,
    n_idx: usize,
    b_idx: usize,
    lazy: bool,
}

const DS: [usize; 2] = [4, 8];
const NS: [usize; 3] = [4, 8, 16];
const BS: [usize; 3] = [1, 4, 8];

fn cfg_of(draw: &Draw) -> ScheduleConfig {
    let d = DS[draw.d_idx];
    // The generators target the paper's N >= D regime (N a multiple of D);
    // clamp shallower draws up to N = D.
    let n = NS[draw.n_idx].max(d);
    ScheduleConfig::new(ScheduleKind::ALL[draw.kind_idx], d, n)
        .with_sync(if draw.lazy { SyncPolicy::Lazy } else { SyncPolicy::Eager })
}

fn gen_draw() -> Gen<Draw> {
    Gen {
        draw: Box::new(|r| Draw {
            kind_idx: r.range(0, ScheduleKind::ALL.len()),
            d_idx: r.range(0, DS.len()),
            n_idx: r.range(0, NS.len()),
            b_idx: r.range(0, BS.len()),
            lazy: r.chance(0.3),
        }),
        shrink: Box::new(|d| {
            let mut out = Vec::new();
            if d.d_idx > 0 {
                out.push(Draw { d_idx: d.d_idx - 1, ..*d });
            }
            if d.n_idx > 0 {
                out.push(Draw { n_idx: d.n_idx - 1, ..*d });
            }
            if d.b_idx > 0 {
                out.push(Draw { b_idx: d.b_idx - 1, ..*d });
            }
            if d.lazy {
                out.push(Draw { lazy: false, ..*d });
            }
            out
        }),
    }
}

fn costs_for(cfg: &ScheduleConfig, b: usize) -> CostModel {
    let p = ParallelConfig::new(cfg.kind, 1, cfg.d, b, cfg.n);
    CostModel::new(&BERT_64, &p, &ClusterConfig::paper_testbed(cfg.d))
}

/// Cost model with expensive collectives (W=4 over IB via PipesTogether):
/// the eager streams then thread one heavyweight all-reduce per stage
/// through the DAG's collective barrier + comm-engine chain nodes.
fn collective_heavy_costs(cfg: &ScheduleConfig) -> CostModel {
    let p = ParallelConfig::new(cfg.kind, 4, cfg.d, 4, cfg.n);
    let mut cluster = ClusterConfig::paper_testbed(4 * cfg.d);
    cluster.mapping = MappingPolicy::PipesTogether;
    CostModel::new(&BERT_64, &p, &cluster)
}

/// Bit-exact agreement between the compiled DAG and the event engine on
/// one (schedule, cost model, iters) point.
fn check_equivalence(cfg: &ScheduleConfig, b: usize, iters: usize) -> Result<(), String> {
    let c = costs_for(cfg, b);
    check_equivalence_with(cfg, &c, iters)
}

/// Bit-exact comparison of two multi-iteration traces: makespan, every
/// iteration boundary, and every per-device field.
fn cmp_traces(label: &str, got: &MultiIterTrace, want: &MultiIterTrace) -> Result<(), String> {
    if got.makespan.to_bits() != want.makespan.to_bits() {
        return Err(format!("{label}: makespan {} != {}", got.makespan, want.makespan));
    }
    for (k, (x, y)) in got.iter_finish.iter().zip(&want.iter_finish).enumerate() {
        if x.to_bits() != y.to_bits() {
            return Err(format!("{label}: iteration {k} boundary {x} != {y}"));
        }
    }
    for (dev, (a, b)) in got.devices.iter().zip(&want.devices).enumerate() {
        for (what, x, y) in [
            ("finish", a.finish, b.finish),
            ("compute_busy", a.compute_busy, b.compute_busy),
            ("recv_blocked", a.recv_blocked, b.recv_blocked),
            ("allreduce_blocked", a.allreduce_blocked, b.allreduce_blocked),
        ] {
            if x.to_bits() != y.to_bits() {
                return Err(format!("{label}: dev {dev} {what}: {x} vs {y}"));
            }
        }
        if (a.sends, a.local_copies) != (b.sends, b.local_copies) {
            return Err(format!("{label}: dev {dev} op counters diverge"));
        }
    }
    Ok(())
}

/// [`check_equivalence`] under an explicit cost model.
fn check_equivalence_with(
    cfg: &ScheduleConfig,
    c: &CostModel,
    iters: usize,
) -> Result<(), String> {
    let s = build(cfg).map_err(|e| format!("{cfg:?}: build failed: {e}"))?;
    let dag = CompiledDag::compile(&s)
        .map_err(|e| format!("{cfg:?}: dag compile refused a generated schedule: {e}"))?;
    if !dag.multi_iter_safe() {
        return Err(format!("{cfg:?}: generated schedule flagged multi-iteration unsafe"));
    }
    let got = dag
        .evaluate(&dag.weights(c), iters)
        .map_err(|e| format!("{cfg:?}: dag evaluate: {e}"))?;
    let want = simulate_schedule_iters(&s, c, iters)
        .map_err(|e| format!("{cfg:?}: event engine: {e}"))?;
    cmp_traces(&format!("{cfg:?} iters={iters}"), &got, &want)
}

#[test]
fn dag_matches_event_engine_exhaustive_single_iter() {
    // The issue's acceptance grid, exhaustively: every schedule family
    // x N in {4, 8, 16} (D = 4, plus the paper-default D = 8 where the
    // N >= D regime allows).
    for kind in ScheduleKind::ALL {
        for &d in &DS {
            for &n in &NS {
                if n < d {
                    continue;
                }
                let cfg = ScheduleConfig::new(kind, d, n);
                check_equivalence(&cfg, 4, 1).unwrap_or_else(|e| panic!("{e}"));
            }
        }
    }
}

#[test]
fn dag_matches_event_engine_exhaustive_multi_iter() {
    // Same grid, 3 iterations unrolled over the same node arena.
    for kind in ScheduleKind::ALL {
        for &d in &DS {
            for &n in &NS {
                if n < d {
                    continue;
                }
                let cfg = ScheduleConfig::new(kind, d, n);
                check_equivalence(&cfg, 4, 3).unwrap_or_else(|e| panic!("{e}"));
            }
        }
    }
}

#[test]
fn dag_matches_event_engine_random() {
    // Random draws add the lazy-sync and micro-batch axes and shrink
    // failures minimal; alternate single- and multi-iteration runs.
    forall(0xDA6E, 80, &gen_draw(), |draw| {
        let iters = if draw.n_idx % 2 == 0 { 1 } else { 2 };
        check_equivalence(&cfg_of(draw), BS[draw.b_idx], iters)
    });
}

#[test]
fn dag_matches_event_engine_collective_heavy_multi_iter() {
    // Banked differential coverage toward retiring the reference executor:
    // the acceptance grid priced with W=4 IB collectives, eager sync,
    // unrolled over 3 iterations — the heaviest traffic the collective
    // barrier/chain machinery sees, bit-exact on both backends.
    for kind in ScheduleKind::ALL {
        for &d in &DS {
            for &n in &NS {
                if n < d {
                    continue;
                }
                let cfg = ScheduleConfig::new(kind, d, n);
                let c = collective_heavy_costs(&cfg);
                check_equivalence_with(&cfg, &c, 3).unwrap_or_else(|e| panic!("{e}"));
            }
        }
    }
}

#[test]
fn lazy_sync_matches_too() {
    // Lazy sync routes every collective through the end-of-stream barrier
    // chain — the comm-engine serialization the DAG models with chain
    // edges, exercised here explicitly for the bidirectional families.
    for kind in [
        ScheduleKind::Chimera,
        ScheduleKind::MixPipe,
        ScheduleKind::BitPipe,
        ScheduleKind::BitPipeNoV,
    ] {
        let cfg = ScheduleConfig::new(kind, 8, 16).with_sync(SyncPolicy::Lazy);
        check_equivalence(&cfg, 4, 1).unwrap_or_else(|e| panic!("{e}"));
        check_equivalence(&cfg, 4, 2).unwrap_or_else(|e| panic!("{e}"));
    }
}

#[test]
fn deadlocks_agree_with_event_engine() {
    // Removing one send must deadlock both backends on the same devices.
    let kind = ScheduleKind::Dapple;
    let mut s = build(&ScheduleConfig::new(kind, 4, 4)).unwrap();
    let idx = s.device_ops[0]
        .iter()
        .position(|i| matches!(i, bitpipe::schedule::Instr::SendAct { .. }))
        .unwrap();
    s.device_ops[0].remove(idx);
    let c = costs_for(&ScheduleConfig::new(kind, 4, 4), 4);
    let dag = CompiledDag::compile(&s).unwrap();
    let got = dag.evaluate(&dag.weights(&c), 1).unwrap_err();
    let want = simulate_schedule(&s, &c).unwrap_err();
    let devs = |e: &bitpipe::sim::SimError| {
        let mut v: Vec<usize> = e.stuck.iter().map(|&(dv, _, _)| dv).collect();
        v.sort_unstable();
        v
    };
    assert_eq!(devs(&got), devs(&want));
}

/// Lane counts swept by the batched-evaluation battery: a degenerate
/// single lane, an odd width, and the full `RECOST_LANES` stride.
const KS: [usize; 3] = [1, 3, 8];

/// Bit-exact agreement between every lane of `evaluate_batch` and k
/// sequential scalar `evaluate` calls under the same per-lane tables.
fn check_lanes(cfg: &ScheduleConfig, k: usize, iters: usize) -> Result<(), String> {
    let s = build(cfg).map_err(|e| format!("{cfg:?}: build failed: {e}"))?;
    let dag = CompiledDag::compile(&s)
        .map_err(|e| format!("{cfg:?}: dag compile refused a generated schedule: {e}"))?;
    let ws: Vec<DagWeights> =
        (0..k).map(|lane| dag.weights(&costs_for(cfg, BS[lane % BS.len()]))).collect();
    let batch = dag
        .evaluate_batch(&ws, iters)
        .map_err(|e| format!("{cfg:?} k={k}: evaluate_batch: {e}"))?;
    if batch.len() != k {
        return Err(format!("{cfg:?}: evaluate_batch returned {} lanes, want {k}", batch.len()));
    }
    for (lane, got) in batch.iter().enumerate() {
        let want = dag
            .evaluate(&ws[lane], iters)
            .map_err(|e| format!("{cfg:?} lane {lane}: scalar evaluate: {e}"))?;
        cmp_traces(&format!("{cfg:?} iters={iters} k={k} lane {lane}"), got, &want)?;
    }
    Ok(())
}

#[test]
fn evaluate_batch_lanes_match_sequential_evaluate_bitwise() {
    // The acceptance grid again, through the batched evaluator: every
    // schedule family x D x N, lanes of k in {1, 3, 8} with the weight
    // tables varying B per lane, single- and multi-iteration carried
    // state. Every lane must reproduce the scalar f64 bits exactly.
    for kind in ScheduleKind::ALL {
        for &d in &DS {
            for &n in &NS {
                if n < d {
                    continue;
                }
                let cfg = ScheduleConfig::new(kind, d, n);
                for &k in &KS {
                    for iters in [1usize, 3] {
                        check_lanes(&cfg, k, iters).unwrap_or_else(|e| panic!("{e}"));
                    }
                }
            }
        }
    }
}

#[test]
fn evaluate_batch_tail_padding_is_inert() {
    // `grid_search_batched` pads short tail chunks by repeating the last
    // real table. The padded lanes must reproduce that lane bit-for-bit
    // and must not perturb the real lanes.
    let cfg = ScheduleConfig::new(ScheduleKind::BitPipe, 8, 16);
    let s = build(&cfg).unwrap();
    let dag = CompiledDag::compile(&s).unwrap();
    let real: Vec<DagWeights> = BS.iter().map(|&b| dag.weights(&costs_for(&cfg, b))).collect();
    let mut padded = real.clone();
    while padded.len() < 8 {
        padded.push(real.last().unwrap().clone());
    }
    let got = dag.evaluate_batch(&padded, 2).unwrap();
    let bare = dag.evaluate_batch(&real, 2).unwrap();
    for lane in 0..real.len() {
        cmp_traces(&format!("real lane {lane} with vs without padding"), &got[lane], &bare[lane])
            .unwrap_or_else(|e| panic!("{e}"));
    }
    for lane in real.len()..padded.len() {
        cmp_traces(&format!("pad lane {lane} vs source lane"), &got[lane], &got[real.len() - 1])
            .unwrap_or_else(|e| panic!("{e}"));
    }
}

#[test]
fn rebuild_for_batch_size_matches_full_weights_bitwise() {
    // Incremental re-pricing: starting from a B=1 table and chaining
    // `rebuild_for_batch_size` through a random B walk must match a full
    // `weights()` rebuild at every step, bit for bit — including the
    // B-independent tail (optimizer, collectives) staying untouched.
    forall(0xBA7C, 40, &gen_draw(), |draw| {
        let cfg = cfg_of(draw);
        let s = build(&cfg).map_err(|e| format!("{cfg:?}: build failed: {e}"))?;
        let dag = CompiledDag::compile(&s)
            .map_err(|e| format!("{cfg:?}: dag compile refused a generated schedule: {e}"))?;
        let cluster = ClusterConfig::paper_testbed(cfg.d);
        let topo = LinkTopology::new(&cluster, 1, cfg.d);
        let p0 = ParallelConfig::new(cfg.kind, 1, cfg.d, 1, cfg.n);
        let mut w = dag.weights(&CostModel::with_topology(&BERT_64, &p0, &cluster, &topo));
        for b in [BS[draw.b_idx], 16, 2, 3] {
            let p = ParallelConfig::new(cfg.kind, 1, cfg.d, b, cfg.n);
            w.rebuild_for_batch_size(&topo.batch_pricing(&BERT_64, &p, &cluster));
            let full = dag.weights(&CostModel::with_topology(&BERT_64, &p, &cluster, &topo));
            for (i, (x, y)) in w.table().iter().zip(full.table()).enumerate() {
                if x.to_bits() != y.to_bits() {
                    return Err(format!("{cfg:?} B={b}: weight class {i}: {x} vs {y}"));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn weights_reuse_over_one_structure_matches_fresh_runs() {
    // The grid-search contract: one compiled structure re-priced under
    // several cost models must match a fresh event-engine run for each.
    let cfg = ScheduleConfig::new(ScheduleKind::BitPipe, 8, 16);
    let s = build(&cfg).unwrap();
    let dag = CompiledDag::compile(&s).unwrap();
    for b in BS {
        let c = costs_for(&cfg, b);
        let got = dag.evaluate(&dag.weights(&c), 1).unwrap();
        let want = simulate_schedule(&s, &c).unwrap();
        assert_eq!(
            got.makespan.to_bits(),
            want.makespan.to_bits(),
            "B={b}: {} vs {}",
            got.makespan,
            want.makespan
        );
    }
}
