//! CLI-level tests for `bitpipe inspect` (the missing-artifact error path
//! must be a proper error naming the available artifacts, not a panic) and
//! the heterogeneity flags on `bitpipe simulate`.

use std::path::PathBuf;
use std::process::Command;

const MANIFEST: &str = "\
model=gpt-tiny
hidden=256
seq=128
batch=4
vocab=512
heads=8
n_chunks=4
layers_per_chunk=2
artifact.fwd_embed=fwd_embed.hlo.txt
artifact.bwd_embed=bwd_embed.hlo.txt
params.embed=137216
selfcheck.loss=6.291064
";

/// Write a minimal artifact dir and return its path.
fn artifact_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("bitpipe-cli-{tag}-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(dir.join("manifest.txt"), MANIFEST).unwrap();
    dir
}

fn bitpipe(args: &[&str]) -> std::process::Output {
    Command::new(env!("CARGO_BIN_EXE_bitpipe")).args(args).output().expect("spawn bitpipe")
}

#[test]
fn inspect_missing_artifact_is_an_error_listing_names() {
    let dir = artifact_dir("missing");
    let out = bitpipe(&["inspect", "--artifacts", dir.to_str().unwrap(), "--artifact", "nope"]);
    assert!(!out.status.success(), "missing artifact must fail, not panic");
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("nope"), "error must name the request: {err}");
    assert!(
        err.contains("bwd_embed") && err.contains("fwd_embed"),
        "error must list the available artifacts: {err}"
    );
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn inspect_selects_one_artifact() {
    let dir = artifact_dir("select");
    let out =
        bitpipe(&["inspect", "--artifacts", dir.to_str().unwrap(), "--artifact", "fwd_embed"]);
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("fwd_embed.hlo.txt"), "selector output: {text}");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn simulate_straggler_and_link_override_smoke() {
    let out = bitpipe(&[
        "simulate", "--kind", "bitpipe", "--d", "4", "--n", "8", "--straggler", "0:1.2",
        "--link-override", "ib:0.5", "--contention",
    ]);
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("iteration time"), "simulate output: {text}");
}

#[test]
fn simulate_rejects_malformed_hetero_flags() {
    for args in [
        ["simulate", "--d", "4", "--n", "8", "--straggler", "banana"].as_slice(),
        ["simulate", "--d", "4", "--n", "8", "--straggler", "9:1.2"].as_slice(),
        ["simulate", "--d", "4", "--n", "8", "--link-override", "ib:-1"].as_slice(),
        ["simulate", "--d", "4", "--n", "8", "--link-override", "0:0.5"].as_slice(),
    ] {
        let out = bitpipe(args);
        assert!(!out.status.success(), "{args:?} must be rejected");
    }
}
