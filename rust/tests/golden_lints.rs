//! Golden-lint snapshot: pins the full `bitpipe lint --json` report of
//! every paper-baseline schedule family, byte for byte, so any drift in
//! the static analyzer — diagnostic set, ordering, message wording, JSON
//! shape, or the liveness high-water numbers — fails CI instead of
//! silently changing the tool's output contract. The Python mirror
//! (`.claude/skills/verify/pymirror/verify_lint.py`) reproduces the same
//! bytes independently, so the snapshot also pins Rust/Python agreement.
//!
//! The pinned lines live in `rust/tests/golden_lints.txt` (one JSON line
//! per configuration). Like the makespan snapshot, the file is recorded
//! by the test itself on first run — or with `BITPIPE_BLESS=1` after an
//! intentional analyzer change — and any divergence afterwards is a hard
//! failure.

use bitpipe::schedule::{build, lint, ScheduleConfig, ScheduleKind};
use std::fmt::Write as _;
use std::path::PathBuf;

/// The pinned grid: every paper baseline at the shallow and default
/// depths (the same points the makespan snapshot covers).
const GRID: [(usize, usize); 2] = [(4, 8), (8, 8)];

fn golden_path() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("rust/tests/golden_lints.txt")
}

/// Families pinned by the snapshot: the paper baselines plus the
/// zero-bubble split-backward family (appended so pre-existing lines keep
/// their keys and values).
fn golden_families() -> impl Iterator<Item = ScheduleKind> {
    ScheduleKind::PAPER_BASELINES.into_iter().chain([ScheduleKind::ZeroBubble])
}

fn current_snapshot() -> Vec<(String, String)> {
    let mut out = Vec::new();
    for (d, n) in GRID {
        for kind in golden_families() {
            let cfg = ScheduleConfig::new(kind, d, n);
            let s = build(&cfg).unwrap_or_else(|e| panic!("{kind} D={d} N={n}: {e}"));
            let r = lint(&s);
            assert!(!r.has_errors(), "{kind} D={d} N={n}: generator emitted errors: {:?}", r.diags);
            out.push((format!("{} d{} n{}", kind.name(), d, n), r.to_json(&s)));
        }
    }
    out
}

fn render(snapshot: &[(String, String)]) -> String {
    let mut s = String::from(
        "# Golden lint reports — `bitpipe lint --json` per paper baseline.\n\
         # Format: <key> <json line>\n\
         # Recorded by rust/tests/golden_lints.rs; regenerate with\n\
         # BITPIPE_BLESS=1 cargo test --test golden_lints after an\n\
         # intentional analyzer change. The Python mirror\n\
         # (.claude/skills/verify/pymirror/verify_lint.py) must agree.\n",
    );
    for (key, json) in snapshot {
        let _ = writeln!(s, "{key} {json}");
    }
    s
}

fn parse(text: &str) -> Vec<(String, String)> {
    let mut out = Vec::new();
    for line in text.lines() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        // The JSON payload starts at the first '{'.
        match line.find('{') {
            Some(p) => out.push((line[..p].trim().to_string(), line[p..].to_string())),
            None => out.push((line.to_string(), String::new())),
        }
    }
    out
}

#[test]
fn lint_reports_match_golden_snapshot() {
    let snapshot = current_snapshot();

    // Unconditional invariants: every baseline is error- and warning-free
    // and reports a positive stash high-water somewhere.
    for (key, json) in &snapshot {
        assert!(json.contains("\"error\":0,\"warn\":0"), "{key}: {json}");
        assert!(json.contains("\"stash_high_water\":["), "{key}: {json}");
    }

    let path = golden_path();
    let bless = std::env::var("BITPIPE_BLESS").is_ok();
    if bless || !path.exists() {
        std::fs::write(&path, render(&snapshot)).expect("write golden snapshot");
        eprintln!(
            "golden_lints: recorded {} entries to {} — commit the file to arm the gate",
            snapshot.len(),
            path.display()
        );
        return;
    }

    let want = parse(&std::fs::read_to_string(&path).expect("read golden snapshot"));
    assert_eq!(
        want.len(),
        snapshot.len(),
        "golden file entry count changed; re-record with BITPIPE_BLESS=1 if intentional"
    );
    let mut drift = String::new();
    for ((gk, gv), (ck, cv)) in want.iter().zip(&snapshot) {
        assert_eq!(gk, ck, "golden file order changed; re-record if intentional");
        if gv != cv {
            let _ = writeln!(drift, "  {ck}:\n    golden  {gv}\n    current {cv}");
        }
    }
    assert!(
        drift.is_empty(),
        "lint-report drift against the golden snapshot:\n{drift}\
         If this change is intentional, re-record with BITPIPE_BLESS=1 and commit."
    );
}
