//! Differential tests for the incremental-settlement contended network
//! against the PR-4 global-settlement oracle (`NetworkImpl::Global`).
//!
//! The two strategies do the same arithmetic over different interval
//! splits: global settlement chips every in-flight flow at every network
//! event, incremental settlement charges a flow one fused `dt/k` per
//! share change. Floating-point addition is not associative, so the
//! results agree to rounding — <= 1e-9 relative — rather than bitwise,
//! *except* where a flow is touched by every network event of its
//! lifetime (solo flows, solo rings, fully-overlapped pinned scenarios),
//! where the interval splits coincide and agreement is exact.
//!
//! Also pinned here: the contended grid search is bit-identical across
//! thread counts (the canonical-order collection makes worker scheduling
//! unobservable), and across the StreamCache fast path vs a serial sweep.

use bitpipe::config::{ClusterConfig, IbModel, LinkKind, MappingPolicy, ParallelConfig, BERT_64};
use bitpipe::schedule::{build, placement_for, Instr, Schedule, ScheduleConfig, ScheduleKind};
use bitpipe::sim::{
    grid_search_contended_serial, grid_search_opts, grid_search_opts_baseline,
    simulate_schedule, simulate_schedule_iters_network, simulate_schedule_network, Contention,
    CostModel, GridSpace, NetworkImpl,
};

/// Relative agreement required between the two settlement strategies.
const TOL: f64 = 1e-9;

fn rel(a: f64, b: f64) -> f64 {
    (a - b).abs() / b.abs().max(1e-12)
}

/// Cost model for one simulated pipeline group (single- or multi-node;
/// same shape as rust/tests/contention.rs).
fn costs_for(kind: ScheduleKind, d: usize, n: usize, multi_node: bool) -> CostModel {
    let w = if multi_node { 2 } else { 1 };
    let p = ParallelConfig::new(kind, w, d, 4, n);
    let mut cluster = ClusterConfig::paper_testbed(w * d);
    cluster.mapping = MappingPolicy::ReplicasTogether;
    CostModel::new(&BERT_64, &p, &cluster)
}

/// Run both settlement strategies on `s` and assert <= `TOL` relative
/// agreement on the makespan, every iteration boundary, and every
/// per-device accounting channel, plus bitwise determinism of the
/// incremental run.
fn check_impls_agree(tag: &str, s: &Schedule, c: &CostModel, iters: usize, mode: Contention) {
    let inc = simulate_schedule_iters_network(s, c, iters, mode, NetworkImpl::Incremental)
        .unwrap_or_else(|e| panic!("{tag}: incremental failed: {e}"));
    let glo = simulate_schedule_iters_network(s, c, iters, mode, NetworkImpl::Global)
        .unwrap_or_else(|e| panic!("{tag}: global failed: {e}"));
    assert!(
        rel(inc.makespan, glo.makespan) <= TOL,
        "{tag}: makespan incremental {} vs global {} (rel {:.3e})",
        inc.makespan,
        glo.makespan,
        rel(inc.makespan, glo.makespan)
    );
    for (k, (a, b)) in inc.iter_finish.iter().zip(&glo.iter_finish).enumerate() {
        assert!(rel(*a, *b) <= TOL, "{tag}: iteration {k} boundary {a} vs {b}");
    }
    for (dev, (a, b)) in inc.devices.iter().zip(&glo.devices).enumerate() {
        for (what, x, y) in [
            ("finish", a.finish, b.finish),
            ("recv_blocked", a.recv_blocked, b.recv_blocked),
            ("allreduce_blocked", a.allreduce_blocked, b.allreduce_blocked),
        ] {
            assert!(
                (x - y).abs() <= TOL * y.abs().max(1e-12),
                "{tag}: dev {dev} {what}: incremental {x} vs global {y}"
            );
        }
        assert_eq!(
            (a.sends, a.local_copies),
            (b.sends, b.local_copies),
            "{tag}: dev {dev} op counters diverge"
        );
    }
    // Incremental settlement is deterministic, bit for bit.
    let inc2 = simulate_schedule_iters_network(s, c, iters, mode, NetworkImpl::Incremental)
        .unwrap_or_else(|e| panic!("{tag}: incremental rerun failed: {e}"));
    assert_eq!(inc.makespan.to_bits(), inc2.makespan.to_bits(), "{tag}: not deterministic");
}

#[test]
fn incremental_matches_global_on_generated_grid() {
    // The dense differential grid from the issue: every schedule family x
    // N in {4, 8, 16} (D = 4 and the paper-default D = 8 where N >= D
    // allows) x {P2pOnly, Full} x single/multi-node cost models.
    for kind in ScheduleKind::ALL {
        for d in [4usize, 8] {
            for n in [4usize, 8, 16] {
                if n < d {
                    continue;
                }
                let s = build(&ScheduleConfig::new(kind, d, n)).unwrap();
                for multi_node in [false, true] {
                    let c = costs_for(kind, d, n, multi_node);
                    for mode in [Contention::P2pOnly, Contention::Full] {
                        let tag =
                            format!("{kind} D={d} N={n} multi_node={multi_node} {mode:?}");
                        check_impls_agree(&tag, &s, &c, 1, mode);
                    }
                }
            }
        }
    }
}

#[test]
fn incremental_matches_global_multi_iteration() {
    // Free-running iterations pile up cross-iteration flow overlap — the
    // worst case for settlement drift.
    let kind = ScheduleKind::BitPipe;
    let s = build(&ScheduleConfig::new(kind, 8, 16)).unwrap();
    let c = costs_for(kind, 8, 16, true);
    check_impls_agree("bitpipe D=8 N=16 x3", &s, &c, 3, Contention::Full);
}

/// The queued-rings scenario from rust/tests/contention.rs: back-to-back
/// all-reduce rounds on one stage's twin devices, every ring crossing the
/// node0<->node1 NICs.
fn rings_only_schedule(stages: &[usize], rounds: usize) -> (Schedule, CostModel) {
    let placement = placement_for(ScheduleKind::Chimera, 8, 1);
    let cfg = ScheduleConfig::new(ScheduleKind::Chimera, 8, 8);
    let mut device_ops = vec![Vec::new(); 8];
    for &stage in stages {
        for dev in [stage, 7 - stage] {
            for _ in 0..rounds {
                device_ops[dev].push(Instr::AllReduceStart { stage });
                device_ops[dev].push(Instr::AllReduceWait { stage });
            }
        }
    }
    let s = Schedule {
        cfg,
        placement,
        compute_order: vec![Vec::new(); 8],
        device_ops,
        pipe_of_mb: vec![0; 8],
    };
    let p = ParallelConfig::new(ScheduleKind::Chimera, 1, 8, 4, 8);
    let cluster = ClusterConfig { n_devices: 8, devices_per_node: 4, ..Default::default() };
    (s, CostModel::new(&BERT_64, &p, &cluster))
}

#[test]
fn queued_rings_agree_and_keep_the_solo_anchor() {
    // Solo rings never share a wire: both strategies project each hop
    // once at insertion, so they are bitwise equal to each other AND to
    // the uncontended scalar chain — the solo-ring anchor, re-pinned
    // under the incremental default.
    for rounds in [1usize, 3] {
        let (s, c) = rings_only_schedule(&[1], rounds);
        check_impls_agree(&format!("queued rings x{rounds}"), &s, &c, 1, Contention::Full);
        let off = simulate_schedule(&s, &c).unwrap();
        for imp in [NetworkImpl::Incremental, NetworkImpl::Global] {
            let on = simulate_schedule_network(&s, &c, Contention::Full, imp).unwrap();
            assert_eq!(
                on.makespan.to_bits(),
                off.makespan.to_bits(),
                "rounds={rounds} {imp:?}: solo ring drifted from the scalar formula"
            );
        }
    }
    // Two concurrent rings through one NIC pair: shared wires, both
    // strategies within tolerance and both ~2x the solo duration.
    let (solo_s, c) = rings_only_schedule(&[1], 1);
    let (both_s, _) = rings_only_schedule(&[1, 2], 1);
    check_impls_agree("two rings one NIC pair", &both_s, &c, 1, Contention::Full);
    let solo = simulate_schedule_network(&solo_s, &c, Contention::Full, NetworkImpl::Incremental)
        .unwrap()
        .makespan;
    let both = simulate_schedule_network(&both_s, &c, Contention::Full, NetworkImpl::Incremental)
        .unwrap()
        .makespan;
    let ratio = both / solo;
    assert!(
        (1.95..=2.05).contains(&ratio),
        "incremental: two rings through one NIC pair ratio {ratio}"
    );
}

#[test]
fn k_sharers_pay_latency_once() {
    // The latency-split pin: k concurrent transfers over one IB pipe
    // finish ~(l + k*w) after launch — wire latency is a fixed term paid
    // once, only the byte-time w fair-shares — not k*(l + w). The
    // historical (k-1) x latency overcharge would add 8 or 16 us here,
    // far outside the asserted l/2 window.
    let build_case = |k: usize| {
        let placement = placement_for(ScheduleKind::Dapple, 4, 1);
        let cfg = ScheduleConfig::new(ScheduleKind::Dapple, 4, 4);
        let mut device_ops = vec![Vec::new(); 4];
        for mb in 0..k {
            device_ops[0].push(Instr::SendAct { to: 2, pipe: 0, stage: 0, mb });
            device_ops[2].push(Instr::RecvAct { from: 0, pipe: 0, stage: 1, mb });
        }
        Schedule {
            cfg,
            placement,
            compute_order: vec![Vec::new(); 4],
            device_ops,
            pipe_of_mb: vec![0; 4],
        }
    };
    let p = ParallelConfig::new(ScheduleKind::Dapple, 1, 4, 4, 4);
    let cluster = ClusterConfig { n_devices: 4, devices_per_node: 2, ..Default::default() };
    let c = CostModel::new(&BERT_64, &p, &cluster);
    let l = cluster.lat(LinkKind::InfiniBand);
    let w = BERT_64.message_bytes(4) as f64 / cluster.bw(LinkKind::InfiniBand);
    let mks = |k: usize, imp: NetworkImpl| {
        simulate_schedule_network(&build_case(k), &c, Contention::Full, imp)
            .unwrap()
            .makespan
    };
    for imp in [NetworkImpl::Incremental, NetworkImpl::Global] {
        // Solo anchor: the unshared scalar transfer time plus launch skew.
        let solo = mks(1, imp);
        assert!((solo - (l + w)).abs() <= 2e-6, "{imp:?}: solo {solo} vs l+w {}", l + w);
        for k in [2usize, 3] {
            let extra = mks(k, imp) - solo;
            let shared = (k - 1) as f64 * w;
            assert!(extra >= shared - 1e-9, "{imp:?} k={k}: extra {extra} < {shared}");
            assert!(
                extra <= shared + 0.5 * l,
                "{imp:?} k={k}: extra {extra} vs byte-share {shared} — \
                 latency charged per sharer?"
            );
        }
    }
    // Both settlement strategies agree on the shared case too.
    check_impls_agree("k=3 sharers one IB pipe", &build_case(3), &c, 1, Contention::Full);
}

#[test]
fn nic_fanout_agrees_across_impls() {
    // The NIC fan-out scenario from rust/tests/contention.rs: one node
    // sending to two different peers shares its single egress NIC.
    let build_case = |both: bool| {
        let placement = placement_for(ScheduleKind::Dapple, 6, 1);
        let cfg = ScheduleConfig::new(ScheduleKind::Dapple, 6, 6);
        let mut device_ops = vec![Vec::new(); 6];
        device_ops[0].push(Instr::SendAct { to: 2, pipe: 0, stage: 0, mb: 0 });
        device_ops[2] = vec![Instr::RecvAct { from: 0, pipe: 0, stage: 1, mb: 0 }];
        if both {
            device_ops[0].push(Instr::SendAct { to: 4, pipe: 0, stage: 0, mb: 1 });
            device_ops[4] = vec![Instr::RecvAct { from: 0, pipe: 0, stage: 1, mb: 1 }];
        }
        Schedule {
            cfg,
            placement,
            compute_order: vec![Vec::new(); 6],
            device_ops,
            pipe_of_mb: vec![0; 6],
        }
    };
    for ib_model in [IbModel::NodeNic, IbModel::NodePair] {
        let p = ParallelConfig::new(ScheduleKind::Dapple, 1, 6, 4, 6);
        let cluster =
            ClusterConfig { n_devices: 6, devices_per_node: 2, ib_model, ..Default::default() };
        let c = CostModel::new(&BERT_64, &p, &cluster);
        for both in [false, true] {
            let s = build_case(both);
            let tag = format!("fan-out both={both} {ib_model:?}");
            check_impls_agree(&tag, &s, &c, 1, Contention::Full);
        }
    }
    // The aggregation ratio itself survives on the incremental default.
    let p = ParallelConfig::new(ScheduleKind::Dapple, 1, 6, 4, 6);
    let cluster = ClusterConfig { n_devices: 6, devices_per_node: 2, ..Default::default() };
    let c = CostModel::new(&BERT_64, &p, &cluster);
    let inc = NetworkImpl::Incremental;
    let solo = simulate_schedule_network(&build_case(false), &c, Contention::Full, inc)
        .unwrap()
        .makespan;
    let fan = simulate_schedule_network(&build_case(true), &c, Contention::Full, inc)
        .unwrap()
        .makespan;
    let ratio = fan / solo;
    assert!((1.9..=2.1).contains(&ratio), "incremental NIC fan-out ratio {ratio}");
}

#[test]
fn contended_grid_search_is_thread_count_invariant() {
    // The StreamCache sweep collects worker results in canonical
    // candidate order: the threaded default must be byte-for-byte the
    // single-threaded sweep.
    for (gpus, minibatch) in [(16usize, 64usize), (32, 128)] {
        let par = grid_search_opts(
            ScheduleKind::BitPipe,
            &BERT_64,
            &GridSpace::bert64(),
            gpus,
            minibatch,
            true,
        )
        .unwrap();
        let ser = grid_search_contended_serial(
            ScheduleKind::BitPipe,
            &BERT_64,
            &GridSpace::bert64(),
            gpus,
            minibatch,
        )
        .unwrap();
        assert_eq!(par.len(), ser.len());
        assert!(!par.is_empty());
        for (a, b) in par.iter().zip(&ser) {
            assert_eq!(
                (a.parallel.w, a.parallel.d, a.parallel.b, a.parallel.n),
                (b.parallel.w, b.parallel.d, b.parallel.b, b.parallel.n)
            );
            assert_eq!(a.result.throughput.to_bits(), b.result.throughput.to_bits());
            assert_eq!(a.result.iter_time.to_bits(), b.result.iter_time.to_bits());
            assert_eq!(a.result.peak_memory(), b.result.peak_memory());
        }
    }
}

#[test]
fn fast_contended_sweep_tracks_the_baseline_within_tolerance() {
    // Same candidates, same feasibility filter, same ordering decisions:
    // the StreamCache + incremental sweep differs from the PR-4 baseline
    // (rebuild per point + global settlement) only by settlement
    // rounding, so per-point throughputs agree to <= 1e-9 relative.
    let fast = grid_search_opts(
        ScheduleKind::BitPipe,
        &BERT_64,
        &GridSpace::bert64(),
        16,
        64,
        true,
    )
    .unwrap();
    let base = grid_search_opts_baseline(
        ScheduleKind::BitPipe,
        &BERT_64,
        &GridSpace::bert64(),
        16,
        64,
    )
    .unwrap();
    assert_eq!(fast.len(), base.len());
    assert!(!fast.is_empty());
    for a in &fast {
        let key = (a.parallel.w, a.parallel.d, a.parallel.b, a.parallel.n);
        let b = base
            .iter()
            .find(|p| (p.parallel.w, p.parallel.d, p.parallel.b, p.parallel.n) == key)
            .expect("point missing from baseline sweep");
        assert!(
            rel(a.result.throughput, b.result.throughput) <= TOL,
            "{key:?}: fast {} vs baseline {}",
            a.result.throughput,
            b.result.throughput
        );
    }
}
