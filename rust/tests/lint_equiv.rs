//! Differential tests for the static schedule analyzer (`schedule::lint`):
//! the linter must agree with actual execution. Lint-clean schedules run
//! to completion on the event engine and their static memory high-water
//! upper-bounds (here: equals) the simulated peak; injected mutants —
//! dropped sends, dropped receives, circular waits, misplaced all-reduce
//! starts, duplicated message tags, delayed eager starts — are flagged
//! statically with a concrete instruction witness, matching what the
//! engine would do dynamically (deadlock vs complete).

use bitpipe::config::{ClusterConfig, ParallelConfig, BERT_64};
use bitpipe::schedule::{
    analysis, build, lint, Instr, Schedule, ScheduleConfig, ScheduleKind, Severity,
};
use bitpipe::sim::{simulate_schedule, CompiledDag, CostModel};

const DS: [usize; 2] = [4, 8];
const NS: [usize; 3] = [4, 8, 16];

fn costs_for(cfg: &ScheduleConfig) -> CostModel {
    let p = ParallelConfig::new(cfg.kind, 1, cfg.d, 4, cfg.n);
    CostModel::new(&BERT_64, &p, &ClusterConfig::paper_testbed(cfg.d))
}

/// Every buildable family x D x N point of the acceptance grid.
fn grid() -> Vec<(ScheduleConfig, Schedule)> {
    let mut out = Vec::new();
    for kind in ScheduleKind::ALL {
        for d in DS {
            for n in NS {
                if n < d {
                    continue;
                }
                let cfg = ScheduleConfig::new(kind, d, n);
                let s = build(&cfg).unwrap_or_else(|e| panic!("{kind} D={d} N={n}: {e}"));
                out.push((cfg, s));
            }
        }
    }
    out
}

#[test]
fn lint_clean_implies_engine_completes() {
    for (cfg, s) in grid() {
        let r = lint(&s);
        let (e, w, _) = r.counts();
        assert_eq!((e, w), (0, 0), "{cfg:?} not lint-clean: {:?}", r.diags);
        let c = costs_for(&cfg);
        simulate_schedule(&s, &c).unwrap_or_else(|e| panic!("{cfg:?}: engine stuck: {e}"));
    }
}

#[test]
fn static_high_water_bounds_simulated_peak() {
    for (cfg, s) in grid() {
        let r = lint(&s);
        let dag = CompiledDag::compile(&s).unwrap_or_else(|e| panic!("{cfg:?}: {e}"));
        let v = s.placement.v as f64;
        for (dv, &sim_peak) in dag.peak_stash().iter().enumerate() {
            assert!(
                r.stash_high_water[dv] >= u64::from(sim_peak),
                "{cfg:?} dev {dv}: static {} < simulated {sim_peak}",
                r.stash_high_water[dv]
            );
            // The analysis-module measurement (micro-batch units) must
            // agree exactly once rescaled to chunks.
            let chunks = (analysis::peak_activation_stash(&s)[dv] * v).round() as u64;
            assert_eq!(r.stash_high_water[dv], chunks, "{cfg:?} dev {dv}");
        }
    }
}

fn built(kind: ScheduleKind, d: usize, n: usize) -> (ScheduleConfig, Schedule) {
    let cfg = ScheduleConfig::new(kind, d, n);
    let s = build(&cfg).unwrap();
    (cfg, s)
}

#[test]
fn dropped_send_flags_parked_recv_and_engine_deadlocks() {
    let (cfg, mut s) = built(ScheduleKind::Dapple, 4, 4);
    let ix = s.device_ops[0].iter().position(|i| matches!(i, Instr::SendAct { .. })).unwrap();
    let dropped = s.device_ops[0].remove(ix);
    let Instr::SendAct { mb, pipe, .. } = dropped else { unreachable!() };

    let r = lint(&s);
    let parked = r.with_code("deadlock-parked");
    assert!(!parked.is_empty(), "{:?}", r.diags);
    // The witness is the receive of exactly the dropped message.
    assert_eq!(
        parked[0].site.instr,
        format!("RA{mb}(p{pipe},s1)<-d0"),
        "{}",
        parked[0].site.instr
    );
    assert_eq!(parked[0].site.device, Some(1));

    let c = costs_for(&cfg);
    let stuck = simulate_schedule(&s, &c).unwrap_err();
    assert!(stuck.stuck.iter().any(|&(dv, _, _)| dv == 1), "{stuck:?}");
}

#[test]
fn dropped_recv_flags_the_unreceived_send_statically() {
    let (cfg, mut s) = built(ScheduleKind::Dapple, 4, 4);
    let ix = s.device_ops[1].iter().position(|i| matches!(i, Instr::RecvAct { .. })).unwrap();
    let Instr::RecvAct { mb, pipe, .. } = s.device_ops[1].remove(ix) else { unreachable!() };

    let r = lint(&s);
    let unpaired = r.with_code("fifo-unpaired-send");
    assert_eq!(unpaired.len(), 1, "{:?}", r.diags);
    assert_eq!(unpaired[0].site.instr, format!("SA{mb}(p{pipe},s0)->d1"));
    assert_eq!(unpaired[0].site.device, Some(0));

    // Dynamically this is NOT a deadlock — the send parks in scratch and
    // every stream completes. Only the static pairing view catches it.
    let c = costs_for(&cfg);
    simulate_schedule(&s, &c).unwrap();
}

#[test]
fn recv_hoisted_to_front_is_a_cycle_with_witness() {
    let (cfg, mut s) = built(ScheduleKind::Dapple, 4, 4);
    // Device 0 (entry stage) waits for its gradient before sending any
    // activation: a circular wait through the whole pipeline.
    let ix = s.device_ops[0].iter().position(|i| matches!(i, Instr::RecvGrad { .. })).unwrap();
    let rg = s.device_ops[0].remove(ix);
    s.device_ops[0].insert(0, rg);

    // Stream-level validation alone cannot see it: pairing is balanced
    // and compute_order untouched.
    bitpipe::schedule::validate::validate(&s).unwrap();

    let r = lint(&s);
    let cyc = r.with_code("deadlock-cycle");
    assert_eq!(cyc.len(), 1, "{:?}", r.diags);
    assert!(cyc[0].witness.len() >= 2, "{:?}", cyc[0].witness);
    assert!(
        cyc[0].witness.iter().any(|w| w.instr.starts_with("RG")),
        "cycle witness misses the hoisted recv: {:?}",
        cyc[0].witness
    );

    let c = costs_for(&cfg);
    simulate_schedule(&s, &c).unwrap_err();
}

#[test]
fn allreduce_start_before_backward_is_flagged_at_the_start() {
    let (_, mut s) = built(ScheduleKind::BitPipe, 4, 8);
    let dev = 0;
    let ix =
        s.device_ops[dev].iter().position(|i| matches!(i, Instr::AllReduceStart { .. })).unwrap();
    let ar = s.device_ops[dev].remove(ix);
    s.device_ops[dev].insert(0, ar);

    let r = lint(&s);
    let sync = r.with_code("sync-order");
    assert!(!sync.is_empty(), "{:?}", r.diags);
    assert_eq!(sync[0].severity, Severity::Error);
    assert!(sync[0].site.instr.starts_with("AR+"), "{}", sync[0].site.instr);
    assert!(sync[0].message.contains("before last backward"), "{}", sync[0].message);
}

#[test]
fn duplicated_message_pair_warns_fifo_ambiguity() {
    let (cfg, mut s) = built(ScheduleKind::Dapple, 4, 4);
    let six = s.device_ops[0].iter().position(|i| matches!(i, Instr::SendAct { .. })).unwrap();
    let send = s.device_ops[0][six];
    s.device_ops[0].insert(six, send);
    let rix = s.device_ops[1].iter().position(|i| matches!(i, Instr::RecvAct { .. })).unwrap();
    let recv = s.device_ops[1][rix];
    s.device_ops[1].insert(rix, recv);

    let r = lint(&s);
    assert_eq!(r.counts().0, 0, "duplicate pair must stay legal: {:?}", r.diags);
    let amb = r.with_code("fifo-reorder-ambiguity");
    assert_eq!(amb.len(), 1, "{:?}", r.diags);
    assert_eq!(amb[0].witness.len(), 4, "{:?}", amb[0].witness);

    // FIFO pairing keeps the engine running.
    let c = costs_for(&cfg);
    simulate_schedule(&s, &c).unwrap();
}

#[test]
fn zero_bubble_stash_matches_ceiling() {
    // Acceptance pin: the measured stash high-water of the zero-bubble
    // generator reaches its closed-form family ceiling exactly (device 0:
    // D in-flight activations + D weight-grad pins).
    for d in DS {
        for n in NS {
            if n < d {
                continue;
            }
            let (_, s) = built(ScheduleKind::ZeroBubble, d, n);
            let measured = lint(&s).stash_high_water.into_iter().max().unwrap();
            let ceiling =
                bitpipe::schedule::lint::family_stash_ceiling(ScheduleKind::ZeroBubble, d, n, 1);
            assert_eq!(measured, ceiling, "D={d} N={n}");
        }
    }
}

#[test]
fn weight_grad_before_its_bi_is_unmatched() {
    // Hoist a W ahead of the Bi that feeds it: the WeightGradStore is
    // empty at dequeue time. Statically an error; dynamically the stream
    // still completes (W needs no message), so only the lint catches it.
    let (cfg, mut s) = built(ScheduleKind::ZeroBubble, 4, 8);
    let ops = &mut s.device_ops[0];
    let wix = ops.iter().position(|i| matches!(i, Instr::BackwardWeight { .. })).unwrap();
    let Instr::BackwardWeight { pipe, stage, mb } = ops[wix] else { unreachable!() };
    let bix = ops
        .iter()
        .position(|i| {
            matches!(i, Instr::BackwardInput { pipe: p, stage: st, mb: m }
                if (*p, *st, *m) == (pipe, stage, mb))
        })
        .unwrap();
    assert!(bix < wix, "generator must emit Bi before its W");
    let w = ops.remove(wix);
    ops.insert(bix, w);

    let r = lint(&s);
    let un = r.with_code("bw-unmatched-weight");
    assert!(!un.is_empty(), "{:?}", r.diags);
    assert!(un[0].site.instr.starts_with('W'), "{}", un[0].site.instr);
    assert_eq!(un[0].site.device, Some(0));

    let c = costs_for(&cfg);
    simulate_schedule(&s, &c).unwrap();
}

#[test]
fn dropped_weight_grads_leak_past_the_ceiling() {
    // Delete every W on device 0: each Bi's pin is never released. The
    // pairing pass flags every orphan and the memory pass sees the stash
    // climb past the 2D family ceiling; the engine still completes.
    let (cfg, mut s) = built(ScheduleKind::ZeroBubble, 4, 16);
    s.device_ops[0].retain(|i| !matches!(i, Instr::BackwardWeight { .. }));

    let r = lint(&s);
    let missing = r.with_code("bw-missing-weight");
    assert_eq!(missing.len(), 16, "{:?}", r.diags);
    assert!(missing[0].site.instr.starts_with("Bi"), "{}", missing[0].site.instr);
    assert!(
        !r.with_code("mem-ceiling-exceeded").is_empty(),
        "leaked pins must push the high-water past the family ceiling: {:?}",
        r.diags
    );
    assert_eq!(r.stash_high_water[0], 16);

    let c = costs_for(&cfg);
    simulate_schedule(&s, &c).unwrap();
}

#[test]
fn weight_grad_on_mismatched_chunk_flags_both_sides() {
    // Retarget one W to a chunk its device never ran a Bi for: the W
    // dequeues from an empty queue (unmatched) and its real Bi is left
    // orphaned (missing) — both sides of the pairing invariant fire.
    let (cfg, mut s) = built(ScheduleKind::ZeroBubble, 4, 8);
    let ops = &mut s.device_ops[1];
    let wix = ops.iter().position(|i| matches!(i, Instr::BackwardWeight { .. })).unwrap();
    let Instr::BackwardWeight { pipe, stage, mb } = ops[wix] else { unreachable!() };
    ops[wix] = Instr::BackwardWeight { pipe, stage: stage + 1, mb };

    let r = lint(&s);
    assert!(!r.with_code("bw-unmatched-weight").is_empty(), "{:?}", r.diags);
    assert!(!r.with_code("bw-missing-weight").is_empty(), "{:?}", r.diags);

    let c = costs_for(&cfg);
    simulate_schedule(&s, &c).unwrap();
}

#[test]
fn eager_start_delayed_past_a_recv_warns_but_validates() {
    // Regression for the one-sided eager check: validate only rejects a
    // start delayed past *compute*, so swapping an AllReduceStart with the
    // receive right after it stays validate-clean — the lint must warn.
    let mut found = false;
    for kind in ScheduleKind::ALL {
        for (d, n) in [(4usize, 8usize), (8, 8), (4, 16)] {
            if n < d {
                continue;
            }
            let (_, mut s) = built(kind, d, n);
            let Some((dev, a)) = s.device_ops.iter().enumerate().find_map(|(dev, ops)| {
                ops.windows(2).enumerate().find_map(|(i, w)| {
                    (matches!(w[0], Instr::AllReduceStart { .. })
                        && matches!(w[1], Instr::RecvAct { .. } | Instr::RecvGrad { .. }))
                    .then_some((dev, i))
                })
            }) else {
                continue;
            };
            s.device_ops[dev].swap(a, a + 1);
            found = true;

            bitpipe::schedule::validate::validate(&s)
                .unwrap_or_else(|e| panic!("{kind} D={d} N={n}: mutant not validate-clean: {e}"));
            let r = lint(&s);
            assert_eq!(r.counts().0, 0, "{kind} D={d} N={n}: {:?}", r.diags);
            let warn = r.with_code("eager-delayed-start");
            assert!(!warn.is_empty(), "{kind} D={d} N={n}: missed delayed start: {:?}", r.diags);
            assert!(warn[0].site.instr.starts_with("AR+"), "{}", warn[0].site.instr);
        }
    }
    assert!(found, "grid contains no [AllReduceStart, Recv] adjacency to mutate");
}
