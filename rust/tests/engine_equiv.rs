//! Differential tests: the event-queue engine (`simulate_schedule`) must be
//! makespan-equivalent to the pre-event-queue spin-loop executor
//! (`simulate_schedule_reference`) on every valid schedule, and the
//! multi-iteration engine must degrade gracefully into the single-shot
//! case. Random configurations are drawn through the in-tree property
//! harness (`bitpipe::util::prop`) and shrunk on failure.
//!
//! The reference executor is retired from the public surface: it is
//! compiled under `cfg(any(test, feature = "reference-sim"))`, and this
//! suite sees it because the dev-dependency self-reference in Cargo.toml
//! enables that feature for test builds.

use bitpipe::config::{ClusterConfig, MappingPolicy, ParallelConfig, BERT_64};
use bitpipe::schedule::{build, ScheduleConfig, ScheduleKind, SyncPolicy};
use bitpipe::sim::{
    simulate_schedule, simulate_schedule_iters, simulate_schedule_reference, CostModel,
};
use bitpipe::util::{forall, Gen};

/// A randomly drawable (kind, D, N, sync) configuration. N sweeps the
/// issue's {4, 8, 16} set; D covers the shallow and paper-default depths.
#[derive(Debug, Clone, Copy, PartialEq)]
struct Draw {
    kind_idx: usize,
    d_idx: usize,
    n_idx: usize,
    lazy: bool,
}

const DS: [usize; 2] = [4, 8];
const NS: [usize; 3] = [4, 8, 16];

fn cfg_of(draw: &Draw) -> ScheduleConfig {
    let d = DS[draw.d_idx];
    // The generators target the paper's N >= D regime (N a multiple of D);
    // clamp shallower draws up to N = D.
    let n = NS[draw.n_idx].max(d);
    ScheduleConfig::new(ScheduleKind::ALL[draw.kind_idx], d, n)
        .with_sync(if draw.lazy { SyncPolicy::Lazy } else { SyncPolicy::Eager })
}

fn gen_draw() -> Gen<Draw> {
    Gen {
        draw: Box::new(|r| Draw {
            kind_idx: r.range(0, ScheduleKind::ALL.len()),
            d_idx: r.range(0, DS.len()),
            n_idx: r.range(0, NS.len()),
            lazy: r.chance(0.3),
        }),
        shrink: Box::new(|d| {
            let mut out = Vec::new();
            if d.d_idx > 0 {
                out.push(Draw { d_idx: d.d_idx - 1, ..*d });
            }
            if d.n_idx > 0 {
                out.push(Draw { n_idx: d.n_idx - 1, ..*d });
            }
            if d.lazy {
                out.push(Draw { lazy: false, ..*d });
            }
            out
        }),
    }
}

fn costs_for(cfg: &ScheduleConfig) -> CostModel {
    let p = ParallelConfig::new(cfg.kind, 1, cfg.d, 4, cfg.n);
    CostModel::new(&BERT_64, &p, &ClusterConfig::paper_testbed(cfg.d))
}

/// Cost model with expensive collectives: W=4 data parallelism under the
/// PipesTogether mapping routes every all-reduce ring over Infiniband, so
/// the collective state machinery carries real weight in the comparison.
fn collective_heavy_costs(cfg: &ScheduleConfig) -> CostModel {
    let p = ParallelConfig::new(cfg.kind, 4, cfg.d, 4, cfg.n);
    let mut cluster = ClusterConfig::paper_testbed(4 * cfg.d);
    cluster.mapping = MappingPolicy::PipesTogether;
    CostModel::new(&BERT_64, &p, &cluster)
}

/// Relative makespan agreement between the two executors.
fn check_equivalence(cfg: &ScheduleConfig) -> Result<(), String> {
    let c = costs_for(cfg);
    check_equivalence_with(cfg, &c)
}

/// [`check_equivalence`] under an explicit cost model.
fn check_equivalence_with(cfg: &ScheduleConfig, c: &CostModel) -> Result<(), String> {
    let s = build(cfg).map_err(|e| format!("{cfg:?}: build failed: {e}"))?;
    let new = simulate_schedule(&s, c).map_err(|e| format!("{cfg:?}: event-queue: {e}"))?;
    let old = simulate_schedule_reference(&s, c)
        .map_err(|e| format!("{cfg:?}: reference: {e}"))?;
    let rel = (new.makespan - old.makespan).abs() / old.makespan.max(1e-12);
    if rel > 1e-9 {
        return Err(format!(
            "{cfg:?}: event-queue makespan {} != reference {} (rel {rel:.3e})",
            new.makespan, old.makespan
        ));
    }
    // Per-device accounting must agree too: both engines execute the same
    // per-device instruction sequences at the same virtual times.
    for (dev, (a, b)) in new.devices.iter().zip(&old.devices).enumerate() {
        for (what, x, y) in [
            ("finish", a.finish, b.finish),
            ("recv_blocked", a.recv_blocked, b.recv_blocked),
            ("allreduce_blocked", a.allreduce_blocked, b.allreduce_blocked),
        ] {
            if (x - y).abs() > 1e-9 * y.abs().max(1e-12) {
                return Err(format!("{cfg:?}: dev {dev} {what}: {x} vs {y}"));
            }
        }
        if (a.sends, a.local_copies) != (b.sends, b.local_copies) {
            return Err(format!("{cfg:?}: dev {dev} op counters diverge"));
        }
    }
    Ok(())
}

#[test]
fn event_queue_matches_reference_exhaustive() {
    // The issue's acceptance grid, exhaustively: every schedule family
    // x N in {4, 8, 16} (D = 4, plus the paper-default D = 8 where the
    // N >= D regime allows).
    for kind in ScheduleKind::ALL {
        for &d in &DS {
            for &n in &NS {
                if n < d {
                    continue;
                }
                let cfg = ScheduleConfig::new(kind, d, n);
                check_equivalence(&cfg).unwrap_or_else(|e| panic!("{e}"));
            }
        }
    }
}

#[test]
fn event_queue_matches_reference_collective_heavy() {
    // Banked differential coverage toward retiring the reference executor:
    // the same exhaustive grid priced with W=4 IB collectives (the eager
    // streams then carry one expensive all-reduce per stage through the
    // comm-engine serialization), plus the lazy end-of-stream chains.
    for kind in ScheduleKind::ALL {
        for &d in &DS {
            for &n in &NS {
                if n < d {
                    continue;
                }
                for lazy in [false, true] {
                    let sync = if lazy { SyncPolicy::Lazy } else { SyncPolicy::Eager };
                    let cfg = ScheduleConfig::new(kind, d, n).with_sync(sync);
                    let c = collective_heavy_costs(&cfg);
                    check_equivalence_with(&cfg, &c).unwrap_or_else(|e| panic!("{e}"));
                }
            }
        }
    }
}

#[test]
fn event_queue_matches_reference_random() {
    // Random draws add the lazy-sync axis and shrink failures minimal.
    forall(0xE5E4, 80, &gen_draw(), |draw| check_equivalence(&cfg_of(draw)));
}

#[test]
fn single_iteration_multi_trace_degenerates() {
    forall(0x51A6, 40, &gen_draw(), |draw| {
        let cfg = cfg_of(draw);
        let s = build(&cfg).map_err(|e| e.to_string())?;
        let c = costs_for(&cfg);
        let one = simulate_schedule(&s, &c).map_err(|e| e.to_string())?;
        let multi = simulate_schedule_iters(&s, &c, 1).map_err(|e| e.to_string())?;
        if (multi.makespan - one.makespan).abs() > 0.0 {
            return Err(format!(
                "{cfg:?}: iters=1 makespan {} != single-shot {}",
                multi.makespan, one.makespan
            ));
        }
        if multi.iter_finish.len() != 1 {
            return Err(format!("{cfg:?}: expected one iteration boundary"));
        }
        Ok(())
    });
}

#[test]
fn multi_iteration_monotone_and_sane() {
    forall(0x171E4, 30, &gen_draw(), |draw| {
        let cfg = cfg_of(draw);
        let s = build(&cfg).map_err(|e| e.to_string())?;
        let c = costs_for(&cfg);
        let t = simulate_schedule_iters(&s, &c, 3).map_err(|e| e.to_string())?;
        // Iteration boundaries are monotone and each iteration takes time.
        let mut prev = 0.0;
        for (k, &f) in t.iter_finish.iter().enumerate() {
            if f <= prev {
                return Err(format!("{cfg:?}: iteration {k} boundary {f} <= {prev}"));
            }
            prev = f;
        }
        // Per-device serial compute lower-bounds the run.
        for (dev, tr) in t.devices.iter().enumerate() {
            if tr.compute_busy > t.makespan + 1e-9 {
                return Err(format!("{cfg:?}: dev {dev} busier than the whole run"));
            }
        }
        Ok(())
    });
}
