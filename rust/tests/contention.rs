//! Integration tests for the flow-level link-contention model: a pinned
//! bandwidth-sharing scenario over one Infiniband pipe, and the
//! monotonicity property (contended makespan >= uncontended makespan)
//! across every schedule family x N in {4, 8, 16}, on both single-node
//! (NVLink-only) and multi-node (IB at the V-fold) cost models.

use bitpipe::config::{ClusterConfig, MappingPolicy, ParallelConfig, BERT_64};
use bitpipe::schedule::{build, placement_for, Instr, Schedule, ScheduleConfig, ScheduleKind};
use bitpipe::sim::{
    simulate_schedule, simulate_schedule_iters, simulate_schedule_iters_with,
    simulate_schedule_with, CostModel,
};

/// Hand-built four-device schedule: transfers 0->2 and (optionally) 1->3,
/// with two devices per node so both flows cross the single node0->node1
/// Infiniband pipe.
fn cross_node_schedule(both: bool) -> (Schedule, CostModel) {
    let placement = placement_for(ScheduleKind::Dapple, 4, 1);
    let cfg = ScheduleConfig::new(ScheduleKind::Dapple, 4, 4);
    let mut device_ops = vec![
        vec![Instr::SendAct { to: 2, pipe: 0, stage: 0, mb: 0 }],
        Vec::new(),
        vec![Instr::RecvAct { from: 0, pipe: 0, stage: 1, mb: 0 }],
        Vec::new(),
    ];
    if both {
        device_ops[1] = vec![Instr::SendAct { to: 3, pipe: 0, stage: 0, mb: 1 }];
        device_ops[3] = vec![Instr::RecvAct { from: 1, pipe: 0, stage: 1, mb: 1 }];
    }
    let s = Schedule {
        cfg,
        placement,
        compute_order: vec![Vec::new(); 4],
        device_ops,
        pipe_of_mb: vec![0, 0, 0, 0],
    };
    let p = ParallelConfig::new(ScheduleKind::Dapple, 1, 4, 4, 4);
    let cluster = ClusterConfig { n_devices: 4, devices_per_node: 2, ..Default::default() };
    (s, CostModel::new(&BERT_64, &p, &cluster))
}

#[test]
fn pinned_two_transfers_share_one_ib_pipe() {
    // The acceptance scenario: two simultaneous transfers over one IB link
    // take ~2x the solo time under contention, ~1x without.
    let (solo_s, c) = cross_node_schedule(false);
    let (both_s, _) = cross_node_schedule(true);
    let solo = simulate_schedule_with(&solo_s, &c, true).unwrap().makespan;
    let off = simulate_schedule(&both_s, &c).unwrap().makespan;
    let on = simulate_schedule_with(&both_s, &c, true).unwrap().makespan;
    assert!(off / solo < 1.05, "fixed-duration: {off} vs solo {solo}");
    let ratio = on / solo;
    assert!((1.95..=2.05).contains(&ratio), "sharing ratio {ratio} ({on} vs solo {solo})");
}

/// Cost model for one simulated pipeline group of depth `d`.
///
/// * `multi_node` false: W=1 on one 8-GPU node — every hop is NVLink.
/// * `multi_node` true: W=2 replicas under the paper's ReplicasTogether
///   mapping — pipeline hops stride across devices, some crossing the
///   node boundary, so concurrent flows funnel onto shared IB pipes
///   (exactly where the V-fold concentrates traffic).
fn costs_for(kind: ScheduleKind, d: usize, n: usize, multi_node: bool) -> CostModel {
    let w = if multi_node { 2 } else { 1 };
    let p = ParallelConfig::new(kind, w, d, 4, n);
    let mut cluster = ClusterConfig::paper_testbed(w * d);
    cluster.mapping = MappingPolicy::ReplicasTogether;
    CostModel::new(&BERT_64, &p, &cluster)
}

#[test]
fn contended_makespan_never_below_uncontended() {
    // The issue's property, exhaustively: every schedule family x
    // N in {4, 8, 16} (D = 4 and the paper-default D = 8 where N >= D
    // allows), single- and multi-node cost models.
    for kind in ScheduleKind::ALL {
        for d in [4usize, 8] {
            for n in [4usize, 8, 16] {
                if n < d {
                    continue;
                }
                let s = build(&ScheduleConfig::new(kind, d, n)).unwrap();
                for multi_node in [false, true] {
                    let c = costs_for(kind, d, n, multi_node);
                    let off = simulate_schedule(&s, &c).unwrap();
                    let on = simulate_schedule_with(&s, &c, true).unwrap();
                    assert!(
                        on.makespan >= off.makespan - 1e-12,
                        "{kind} D={d} N={n} multi_node={multi_node}: \
                         contended {} < uncontended {}",
                        on.makespan,
                        off.makespan
                    );
                }
            }
        }
    }
}

#[test]
fn contended_multi_iteration_monotone_and_deterministic() {
    let kind = ScheduleKind::BitPipe;
    let s = build(&ScheduleConfig::new(kind, 8, 16)).unwrap();
    let c = costs_for(kind, 8, 16, true);
    let off = simulate_schedule_iters(&s, &c, 3).unwrap();
    let on = simulate_schedule_iters_with(&s, &c, 3, true).unwrap();
    assert_eq!(on.iter_finish.len(), 3);
    // Every iteration boundary is monotone and at-or-after the
    // uncontended boundary.
    let mut prev = 0.0;
    for (k, (&a, &b)) in on.iter_finish.iter().zip(&off.iter_finish).enumerate() {
        assert!(a > prev, "iteration {k} boundary not monotone");
        assert!(a >= b - 1e-12, "iteration {k}: contended {a} < uncontended {b}");
        prev = a;
    }
    // Deterministic: re-running is bit-identical.
    let on2 = simulate_schedule_iters_with(&s, &c, 3, true).unwrap();
    assert_eq!(on.makespan.to_bits(), on2.makespan.to_bits());
    for (x, y) in on.iter_finish.iter().zip(&on2.iter_finish) {
        assert_eq!(x.to_bits(), y.to_bits());
    }
}
