//! Integration tests for the flow-level link-contention model: pinned
//! bandwidth-sharing scenarios (two P2P transfers over one NIC pair, a
//! node fanning out to two peers, two all-reduce rings through one NIC),
//! solo-ring bit-equality against the scalar formula, and the
//! monotonicity ladder `uncontended <= p2p-only <= fully contended`
//! across every schedule family x N in {4, 8, 16}, on both single-node
//! (NVLink-only) and multi-node (IB at the V-fold) cost models.

use bitpipe::config::{ClusterConfig, IbModel, MappingPolicy, ParallelConfig, BERT_64};
use bitpipe::schedule::{build, placement_for, Instr, Schedule, ScheduleConfig, ScheduleKind};
use bitpipe::sim::{
    simulate_schedule, simulate_schedule_contended, simulate_schedule_iters,
    simulate_schedule_iters_with, simulate_schedule_with, Contention, CostModel,
};

/// Hand-built four-device schedule: transfers 0->2 and (optionally) 1->3,
/// with two devices per node so both flows cross the single node0->node1
/// Infiniband pipe.
fn cross_node_schedule(both: bool) -> (Schedule, CostModel) {
    let placement = placement_for(ScheduleKind::Dapple, 4, 1);
    let cfg = ScheduleConfig::new(ScheduleKind::Dapple, 4, 4);
    let mut device_ops = vec![
        vec![Instr::SendAct { to: 2, pipe: 0, stage: 0, mb: 0 }],
        Vec::new(),
        vec![Instr::RecvAct { from: 0, pipe: 0, stage: 1, mb: 0 }],
        Vec::new(),
    ];
    if both {
        device_ops[1] = vec![Instr::SendAct { to: 3, pipe: 0, stage: 0, mb: 1 }];
        device_ops[3] = vec![Instr::RecvAct { from: 1, pipe: 0, stage: 1, mb: 1 }];
    }
    let s = Schedule {
        cfg,
        placement,
        compute_order: vec![Vec::new(); 4],
        device_ops,
        pipe_of_mb: vec![0, 0, 0, 0],
    };
    let p = ParallelConfig::new(ScheduleKind::Dapple, 1, 4, 4, 4);
    let cluster = ClusterConfig { n_devices: 4, devices_per_node: 2, ..Default::default() };
    (s, CostModel::new(&BERT_64, &p, &cluster))
}

#[test]
fn pinned_two_transfers_share_one_ib_pipe() {
    // The acceptance scenario: two simultaneous transfers over one IB link
    // take ~2x the solo time under contention, ~1x without.
    let (solo_s, c) = cross_node_schedule(false);
    let (both_s, _) = cross_node_schedule(true);
    let solo = simulate_schedule_with(&solo_s, &c, true).unwrap().makespan;
    let off = simulate_schedule(&both_s, &c).unwrap().makespan;
    let on = simulate_schedule_with(&both_s, &c, true).unwrap().makespan;
    assert!(off / solo < 1.05, "fixed-duration: {off} vs solo {solo}");
    let ratio = on / solo;
    assert!((1.95..=2.05).contains(&ratio), "sharing ratio {ratio} ({on} vs solo {solo})");
}

/// Cost model for one simulated pipeline group of depth `d`.
///
/// * `multi_node` false: W=1 on one 8-GPU node — every hop is NVLink.
/// * `multi_node` true: W=2 replicas under the paper's ReplicasTogether
///   mapping — pipeline hops stride across devices, some crossing the
///   node boundary, so concurrent flows funnel onto shared IB pipes
///   (exactly where the V-fold concentrates traffic).
fn costs_for(kind: ScheduleKind, d: usize, n: usize, multi_node: bool) -> CostModel {
    let w = if multi_node { 2 } else { 1 };
    let p = ParallelConfig::new(kind, w, d, 4, n);
    let mut cluster = ClusterConfig::paper_testbed(w * d);
    cluster.mapping = MappingPolicy::ReplicasTogether;
    CostModel::new(&BERT_64, &p, &cluster)
}

#[test]
fn contention_modes_form_a_monotone_ladder() {
    // The issue's property, exhaustively: every schedule family x
    // N in {4, 8, 16} (D = 4 and the paper-default D = 8 where N >= D
    // allows), single- and multi-node cost models. Turning contention up
    // one traffic class at a time can only slow an iteration down:
    // uncontended <= P2P-contended <= P2P+collective-contended, and the
    // fully contended run is deterministic.
    for kind in ScheduleKind::ALL {
        for d in [4usize, 8] {
            for n in [4usize, 8, 16] {
                if n < d {
                    continue;
                }
                let s = build(&ScheduleConfig::new(kind, d, n)).unwrap();
                for multi_node in [false, true] {
                    let c = costs_for(kind, d, n, multi_node);
                    let off = simulate_schedule(&s, &c).unwrap();
                    let p2p = simulate_schedule_contended(&s, &c, Contention::P2pOnly).unwrap();
                    let full = simulate_schedule_contended(&s, &c, Contention::Full).unwrap();
                    let tag = format!("{kind} D={d} N={n} multi_node={multi_node}");
                    assert!(
                        p2p.makespan >= off.makespan - 1e-12,
                        "{tag}: p2p-contended {} < uncontended {}",
                        p2p.makespan,
                        off.makespan
                    );
                    assert!(
                        full.makespan >= p2p.makespan - 1e-12,
                        "{tag}: fully contended {} < p2p-contended {}",
                        full.makespan,
                        p2p.makespan
                    );
                    let full2 = simulate_schedule_with(&s, &c, true).unwrap();
                    assert_eq!(
                        full.makespan.to_bits(),
                        full2.makespan.to_bits(),
                        "{tag}: contended run not deterministic"
                    );
                }
            }
        }
    }
}

/// Hand-built schedule running only collectives: each listed stage's twin
/// devices start and wait on its all-reduce, `rounds` times back to back.
/// Placement: Chimera D=8 (stage s on devices {s, 7-s}); the cluster packs
/// 4 devices per node, so every twin pair straddles the node boundary and
/// its ring crosses the Infiniband NICs.
fn rings_only_schedule(stages: &[usize], rounds: usize) -> (Schedule, CostModel) {
    let placement = placement_for(ScheduleKind::Chimera, 8, 1);
    let cfg = ScheduleConfig::new(ScheduleKind::Chimera, 8, 8);
    let mut device_ops = vec![Vec::new(); 8];
    for &stage in stages {
        for dev in [stage, 7 - stage] {
            for _ in 0..rounds {
                device_ops[dev].push(Instr::AllReduceStart { stage });
                device_ops[dev].push(Instr::AllReduceWait { stage });
            }
        }
    }
    let s = Schedule {
        cfg,
        placement,
        compute_order: vec![Vec::new(); 8],
        device_ops,
        pipe_of_mb: vec![0; 8],
    };
    let p = ParallelConfig::new(ScheduleKind::Chimera, 1, 8, 4, 8);
    let cluster = ClusterConfig { n_devices: 8, devices_per_node: 4, ..Default::default() };
    (s, CostModel::new(&BERT_64, &p, &cluster))
}

#[test]
fn solo_ring_reproduces_scalar_formula_bitwise() {
    // The acceptance anchor: a single all-reduce ring on an otherwise idle
    // network must complete in exactly the scalar formula's duration — the
    // contended run is bit-identical to the uncontended one. Three
    // back-to-back rounds also pin the comm-engine queue: each round's
    // flows launch at the previous round's completion, exactly the
    // analytic `comm_free` chain.
    for rounds in [1usize, 3] {
        let (s, c) = rings_only_schedule(&[1], rounds);
        let off = simulate_schedule(&s, &c).unwrap();
        let on = simulate_schedule_with(&s, &c, true).unwrap();
        assert_eq!(
            on.makespan.to_bits(),
            off.makespan.to_bits(),
            "rounds={rounds}: solo ring drifted from the scalar formula"
        );
        for (a, b) in on.devices.iter().zip(&off.devices) {
            assert_eq!(a.finish.to_bits(), b.finish.to_bits());
            assert_eq!(a.allreduce_blocked.to_bits(), b.allreduce_blocked.to_bits());
        }
        assert!(on.makespan > 0.0);
    }
}

#[test]
fn out_of_table_collectives_serialize_with_ring_flows() {
    // A hand-built stream whose placement has more stages than the cost
    // model (placement v=2, costs v=1): stage 1 is ring-lowered from the
    // table, stage 9 falls outside it and takes the engine-group fallback
    // ring. Both sit on the same twin devices, so under full contention
    // they must serialize through the comm queues exactly like the
    // analytic comm_free chain — on an idle network, bit-identically.
    let placement = placement_for(ScheduleKind::BitPipe, 8, 2);
    let cfg = ScheduleConfig::new(ScheduleKind::BitPipe, 8, 8);
    let mut device_ops = vec![Vec::new(); 8];
    for dev in [1usize, 6] {
        device_ops[dev] = vec![
            Instr::AllReduceStart { stage: 1 },
            Instr::AllReduceStart { stage: 9 },
            Instr::AllReduceWait { stage: 1 },
            Instr::AllReduceWait { stage: 9 },
        ];
    }
    let s = Schedule {
        cfg,
        placement,
        compute_order: vec![Vec::new(); 8],
        device_ops,
        pipe_of_mb: vec![0; 8],
    };
    let mut p = ParallelConfig::new(ScheduleKind::BitPipe, 1, 8, 4, 8);
    p.v = 1; // cost model sees 8 stages; the placement has 16
    let cluster = ClusterConfig { n_devices: 8, devices_per_node: 4, ..Default::default() };
    let c = CostModel::new(&BERT_64, &p, &cluster);
    assert!(c.ring_hops(9).is_none(), "stage 9 must be outside the cost table");
    let off = simulate_schedule(&s, &c).unwrap();
    let on = simulate_schedule_with(&s, &c, true).unwrap();
    assert_eq!(
        on.makespan.to_bits(),
        off.makespan.to_bits(),
        "queued in-table + fallback rings on an idle network must match the analytic chain"
    );
    assert!(on.makespan > 1.5 * c.allreduce_time(1), "two collectives must serialize");
}

#[test]
fn pinned_two_rings_share_one_nic_pair() {
    // Two concurrent body-stage rings (disjoint member devices, so no
    // comm-engine serialization) both cross the node0<->node1 NIC pair:
    // under full contention each ring's two IB hops share the two NICs
    // with the other ring's, so both take ~2x their solo duration.
    let (solo_s, c) = rings_only_schedule(&[1], 1);
    let (both_s, _) = rings_only_schedule(&[1, 2], 1);
    let solo = simulate_schedule_with(&solo_s, &c, true).unwrap().makespan;
    let off = simulate_schedule(&both_s, &c).unwrap().makespan;
    let on = simulate_schedule_with(&both_s, &c, true).unwrap().makespan;
    assert!(off / solo < 1.05, "scalar pricing: {off} vs solo {solo}");
    let ratio = on / solo;
    assert!(
        (1.95..=2.05).contains(&ratio),
        "two rings through one NIC pair: ratio {ratio} ({on} vs solo {solo})"
    );
}

#[test]
fn ring_flows_squeeze_concurrent_p2p() {
    // A body-stage ring (devices {1, 6}) and a P2P transfer 2 -> 5 cross
    // the same node0 -> node1 NICs. Under P2pOnly the collective is scalar
    // and invisible to the flow network; under Full its ring flows halve
    // the P2P transfer's bandwidth — the fidelity gap this PR closes.
    let placement = placement_for(ScheduleKind::Chimera, 8, 1);
    let cfg = ScheduleConfig::new(ScheduleKind::Chimera, 8, 8);
    let mut device_ops = vec![Vec::new(); 8];
    for dev in [1usize, 6] {
        device_ops[dev].push(Instr::AllReduceStart { stage: 1 });
        device_ops[dev].push(Instr::AllReduceWait { stage: 1 });
    }
    device_ops[2] = vec![Instr::SendAct { to: 5, pipe: 0, stage: 2, mb: 0 }];
    device_ops[5] = vec![Instr::RecvAct { from: 2, pipe: 0, stage: 3, mb: 0 }];
    let s = Schedule {
        cfg,
        placement,
        compute_order: vec![Vec::new(); 8],
        device_ops,
        pipe_of_mb: vec![0; 8],
    };
    let p = ParallelConfig::new(ScheduleKind::Chimera, 1, 8, 4, 8);
    let cluster = ClusterConfig { n_devices: 8, devices_per_node: 4, ..Default::default() };
    let c = CostModel::new(&BERT_64, &p, &cluster);
    let p2p_only = simulate_schedule_contended(&s, &c, Contention::P2pOnly).unwrap();
    let full = simulate_schedule_contended(&s, &c, Contention::Full).unwrap();
    assert!(
        full.devices[5].finish > 1.5 * p2p_only.devices[5].finish,
        "receiver finish: full {} vs p2p-only {}",
        full.devices[5].finish,
        p2p_only.devices[5].finish
    );
}

#[test]
fn node_fanout_shares_one_egress_nic() {
    // One node fans out to two different peer nodes. Under the default
    // NIC-aggregation model both flows ride the node's single egress NIC
    // (~2x solo); the legacy per-node-pair model keeps them independent
    // (~1x) — preserved behind `IbModel::NodePair` for differential
    // comparison.
    let build_case = |both: bool| {
        let placement = placement_for(ScheduleKind::Dapple, 6, 1);
        let cfg = ScheduleConfig::new(ScheduleKind::Dapple, 6, 6);
        let mut device_ops = vec![Vec::new(); 6];
        device_ops[0].push(Instr::SendAct { to: 2, pipe: 0, stage: 0, mb: 0 });
        device_ops[2] = vec![Instr::RecvAct { from: 0, pipe: 0, stage: 1, mb: 0 }];
        if both {
            device_ops[0].push(Instr::SendAct { to: 4, pipe: 0, stage: 0, mb: 1 });
            device_ops[4] = vec![Instr::RecvAct { from: 0, pipe: 0, stage: 1, mb: 1 }];
        }
        Schedule {
            cfg,
            placement,
            compute_order: vec![Vec::new(); 6],
            device_ops,
            pipe_of_mb: vec![0; 6],
        }
    };
    let costs_with = |ib_model: IbModel| {
        let p = ParallelConfig::new(ScheduleKind::Dapple, 1, 6, 4, 6);
        let cluster =
            ClusterConfig { n_devices: 6, devices_per_node: 2, ib_model, ..Default::default() };
        CostModel::new(&BERT_64, &p, &cluster)
    };
    let solo_s = build_case(false);
    let both_s = build_case(true);

    let nic = costs_with(IbModel::NodeNic);
    let solo = simulate_schedule_with(&solo_s, &nic, true).unwrap().makespan;
    let shared = simulate_schedule_with(&both_s, &nic, true).unwrap().makespan;
    let ratio = shared / solo;
    assert!(
        (1.9..=2.1).contains(&ratio),
        "NIC aggregation: fan-out ratio {ratio} ({shared} vs solo {solo})"
    );

    let pair = costs_with(IbModel::NodePair);
    let solo_pair = simulate_schedule_with(&solo_s, &pair, true).unwrap().makespan;
    let both_pair = simulate_schedule_with(&both_s, &pair, true).unwrap().makespan;
    assert!(
        both_pair / solo_pair < 1.05,
        "per-pair model must keep fan-out independent: {both_pair} vs {solo_pair}"
    );
    // Distinct node pairs price identically in both models when alone.
    assert_eq!(solo.to_bits(), solo_pair.to_bits());
}

#[test]
fn contended_multi_iteration_monotone_and_deterministic() {
    let kind = ScheduleKind::BitPipe;
    let s = build(&ScheduleConfig::new(kind, 8, 16)).unwrap();
    let c = costs_for(kind, 8, 16, true);
    let off = simulate_schedule_iters(&s, &c, 3).unwrap();
    let on = simulate_schedule_iters_with(&s, &c, 3, true).unwrap();
    assert_eq!(on.iter_finish.len(), 3);
    // Every iteration boundary is monotone and at-or-after the
    // uncontended boundary.
    let mut prev = 0.0;
    for (k, (&a, &b)) in on.iter_finish.iter().zip(&off.iter_finish).enumerate() {
        assert!(a > prev, "iteration {k} boundary not monotone");
        assert!(a >= b - 1e-12, "iteration {k}: contended {a} < uncontended {b}");
        prev = a;
    }
    // Deterministic: re-running is bit-identical.
    let on2 = simulate_schedule_iters_with(&s, &c, 3, true).unwrap();
    assert_eq!(on.makespan.to_bits(), on2.makespan.to_bits());
    for (x, y) in on.iter_finish.iter().zip(&on2.iter_finish) {
        assert_eq!(x.to_bits(), y.to_bits());
    }
}
