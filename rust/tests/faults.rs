//! Fault-injection battery: the three guarantees the fault subsystem
//! makes (`rust/src/sim/engine.rs` § Fault injection).
//!
//! 1. **Empty-plan identity** — replaying an empty [`FaultPlan`] is
//!    bit-identical to the plain engine on every mode and settlement
//!    strategy (the engine attaches no fault state at all), so the golden
//!    makespans and every pre-PR ordering stand untouched.
//! 2. **Determinism** — a fixed plan (explicit or seeded) produces
//!    bitwise-identical traces across repeated runs and across thread
//!    counts: traces are expanded before the run and the event order is
//!    total.
//! 3. **Monotonicity** — faults only ever slow things down: faulted
//!    makespan >= healthy makespan across the full family x D x N ladder
//!    (mirroring `contention.rs`), and the seeded generator's
//!    prefix-monotone intensity ladder never speeds an uncontended run up.

use bitpipe::config::{
    ClusterConfig, FaultEvent, FaultPlan, FaultTarget, LinkKind, ParallelConfig, BERT_64,
};
use bitpipe::schedule::{build, ScheduleConfig, ScheduleKind};
use bitpipe::sim::{
    simulate_schedule_iters_faulted, simulate_schedule_iters_network, Contention, CostModel,
    MultiIterTrace, NetworkImpl,
};

fn assert_traces_identical(tag: &str, a: &MultiIterTrace, b: &MultiIterTrace) {
    assert_eq!(a.makespan.to_bits(), b.makespan.to_bits(), "{tag}: makespan");
    for (x, y) in a.iter_finish.iter().zip(&b.iter_finish) {
        assert_eq!(x.to_bits(), y.to_bits(), "{tag}: iteration boundary");
    }
    for (dev, (x, y)) in a.devices.iter().zip(&b.devices).enumerate() {
        assert_eq!(x.finish.to_bits(), y.finish.to_bits(), "{tag}: dev {dev} finish");
        assert_eq!(
            x.compute_busy.to_bits(),
            y.compute_busy.to_bits(),
            "{tag}: dev {dev} compute_busy"
        );
        assert_eq!(
            x.recv_blocked.to_bits(),
            y.recv_blocked.to_bits(),
            "{tag}: dev {dev} recv_blocked"
        );
    }
}

/// An explicit plan scaled into a run of makespan `m`: a flapping IB
/// window, one slowed device, one mid-run stall — every fault shape, all
/// overlapping actual execution.
fn plan_within(m: f64, d: usize) -> FaultPlan {
    FaultPlan::from_events(vec![
        FaultEvent::LinkDegrade {
            target: FaultTarget::LinkClass(LinkKind::InfiniBand),
            mult: 0.25,
            t_start: 0.1 * m,
            t_end: 0.7 * m,
        },
        FaultEvent::DeviceSlow { dev: d - 1, mult: 1.5, t_start: 0.0, t_end: 0.5 * m },
        FaultEvent::DeviceStall { dev: 0, t: 0.3 * m, dur: 0.2 * m },
    ])
}

#[test]
fn empty_plan_is_bit_identical_on_every_mode() {
    let empty = FaultPlan::empty();
    for kind in ScheduleKind::ALL {
        for d in [4usize, 8] {
            for n in [8usize, 16] {
                if n < d {
                    continue;
                }
                let s = build(&ScheduleConfig::new(kind, d, n)).unwrap();
                let p = ParallelConfig::new(kind, 1, d, 4, n);
                let costs = CostModel::new(&BERT_64, &p, &ClusterConfig::paper_testbed(d));
                for (mode, net) in [
                    (Contention::Off, NetworkImpl::Incremental),
                    (Contention::P2pOnly, NetworkImpl::Incremental),
                    (Contention::Full, NetworkImpl::Incremental),
                    (Contention::Full, NetworkImpl::Global),
                ] {
                    let base = simulate_schedule_iters_network(&s, &costs, 2, mode, net).unwrap();
                    let faulted =
                        simulate_schedule_iters_faulted(&s, &costs, 2, mode, net, &empty).unwrap();
                    let tag = format!("{kind} D={d} N={n} {mode:?}/{net:?}");
                    assert_traces_identical(&tag, &base, &faulted);
                }
            }
        }
    }
}

#[test]
fn explicit_plan_is_deterministic_across_runs_and_threads() {
    let (kind, d, n) = (ScheduleKind::BitPipe, 8usize, 16usize);
    let s = build(&ScheduleConfig::new(kind, d, n)).unwrap();
    let p = ParallelConfig::new(kind, 1, d, 4, n);
    let costs = CostModel::new(&BERT_64, &p, &ClusterConfig::paper_testbed(d));
    let healthy =
        simulate_schedule_iters_network(&s, &costs, 2, Contention::Off, NetworkImpl::default())
            .unwrap();
    let plan = plan_within(healthy.makespan, d);

    for (mode, net) in [
        (Contention::Off, NetworkImpl::Incremental),
        (Contention::Full, NetworkImpl::Incremental),
        (Contention::Full, NetworkImpl::Global),
    ] {
        let reference =
            simulate_schedule_iters_faulted(&s, &costs, 2, mode, net, &plan).unwrap();
        // Repeated runs in this thread.
        for run in 0..3 {
            let again = simulate_schedule_iters_faulted(&s, &costs, 2, mode, net, &plan).unwrap();
            assert_traces_identical(&format!("{mode:?}/{net:?} rerun {run}"), &reference, &again);
        }
        // Concurrent runs on fresh threads, each rebuilding everything
        // from scratch — the bits may not depend on thread identity,
        // scheduling, or allocator state.
        let handles: Vec<_> = (0..4)
            .map(|_| {
                let plan = plan.clone();
                std::thread::spawn(move || {
                    let s = build(&ScheduleConfig::new(kind, d, n)).unwrap();
                    let p = ParallelConfig::new(kind, 1, d, 4, n);
                    let costs = CostModel::new(&BERT_64, &p, &ClusterConfig::paper_testbed(d));
                    simulate_schedule_iters_faulted(&s, &costs, 2, mode, net, &plan)
                        .unwrap()
                        .makespan
                        .to_bits()
                })
            })
            .collect();
        for h in handles {
            assert_eq!(
                h.join().unwrap(),
                reference.makespan.to_bits(),
                "{mode:?}/{net:?}: thread run diverged"
            );
        }
    }
}

#[test]
fn seeded_traces_are_reproducible_and_prefix_monotone() {
    for seed in [0u64, 7, 123456789] {
        let a = FaultPlan::random(seed, 0.7, 4.0, 8).unwrap();
        let b = FaultPlan::random(seed, 0.7, 4.0, 8).unwrap();
        assert_eq!(a, b, "seed {seed}: generator not reproducible");
        // A lower intensity draws a prefix of the same candidates.
        let lo = FaultPlan::random(seed, 0.3, 4.0, 8).unwrap();
        assert!(lo.events.len() <= a.events.len());
    }
    assert!(FaultPlan::random(1, 0.0, 4.0, 8).unwrap().is_empty());
    // Replaying the same seeded trace is bit-deterministic end to end.
    let (kind, d, n) = (ScheduleKind::ZeroBubble, 4usize, 8usize);
    let s = build(&ScheduleConfig::new(kind, d, n)).unwrap();
    let p = ParallelConfig::new(kind, 1, d, 4, n);
    let costs = CostModel::new(&BERT_64, &p, &ClusterConfig::paper_testbed(d));
    let plan = FaultPlan::random(99, 0.8, 1.0, d).unwrap();
    let r1 = simulate_schedule_iters_faulted(
        &s,
        &costs,
        2,
        Contention::Full,
        NetworkImpl::Incremental,
        &plan,
    )
    .unwrap();
    let r2 = simulate_schedule_iters_faulted(
        &s,
        &costs,
        2,
        Contention::Full,
        NetworkImpl::Incremental,
        &plan,
    )
    .unwrap();
    assert_traces_identical("seeded replay", &r1, &r2);
}

#[test]
fn faulted_makespan_never_beats_healthy_across_family_ladder() {
    for kind in ScheduleKind::ALL {
        for d in [4usize, 8] {
            for n in [d, 2 * d] {
                let s = build(&ScheduleConfig::new(kind, d, n)).unwrap();
                let p = ParallelConfig::new(kind, 1, d, 4, n);
                let costs = CostModel::new(&BERT_64, &p, &ClusterConfig::paper_testbed(d));
                for (mode, net) in [
                    (Contention::Off, NetworkImpl::Incremental),
                    (Contention::Full, NetworkImpl::Incremental),
                ] {
                    let healthy =
                        simulate_schedule_iters_network(&s, &costs, 1, mode, net).unwrap();
                    let plan = plan_within(healthy.makespan, d);
                    let hurt =
                        simulate_schedule_iters_faulted(&s, &costs, 1, mode, net, &plan).unwrap();
                    assert!(
                        hurt.makespan >= healthy.makespan * (1.0 - 1e-12),
                        "{kind} D={d} N={n} {mode:?}: faulted {} < healthy {}",
                        hurt.makespan,
                        healthy.makespan
                    );
                }
            }
        }
    }
}

#[test]
fn seeded_intensity_ladder_is_monotone_uncontended() {
    for kind in [ScheduleKind::Dapple, ScheduleKind::BitPipe, ScheduleKind::ZeroBubble] {
        let (d, n) = (4usize, 8usize);
        let s = build(&ScheduleConfig::new(kind, d, n)).unwrap();
        let p = ParallelConfig::new(kind, 1, d, 4, n);
        let costs = CostModel::new(&BERT_64, &p, &ClusterConfig::paper_testbed(d));
        let horizon = simulate_schedule_iters_network(
            &s,
            &costs,
            1,
            Contention::Off,
            NetworkImpl::default(),
        )
        .unwrap()
        .makespan;
        let mut prev = f64::NEG_INFINITY;
        for intensity in [0.0, 0.25, 0.5, 0.75, 1.0] {
            let plan = FaultPlan::random(9, intensity, horizon, d).unwrap();
            let r = simulate_schedule_iters_faulted(
                &s,
                &costs,
                1,
                Contention::Off,
                NetworkImpl::default(),
                &plan,
            )
            .unwrap();
            assert!(
                r.makespan >= prev - 1e-12,
                "{kind}: intensity {intensity} makespan {} < previous {prev}",
                r.makespan
            );
            prev = r.makespan;
        }
    }
}

#[test]
fn stall_on_idle_device_is_free_and_plans_validate() {
    // A stall entirely before a device's first dispatch (or after its
    // last) costs nothing: the clock pin maxes against `now`.
    let (kind, d, n) = (ScheduleKind::Dapple, 4usize, 4usize);
    let s = build(&ScheduleConfig::new(kind, d, n)).unwrap();
    let p = ParallelConfig::new(kind, 1, d, 4, n);
    let costs = CostModel::new(&BERT_64, &p, &ClusterConfig::paper_testbed(d));
    let healthy =
        simulate_schedule_iters_network(&s, &costs, 1, Contention::Off, NetworkImpl::default())
            .unwrap();
    // Device d-1 (last stage) starts late: a tiny stall at t=0 is absorbed.
    let free = FaultPlan::from_events(vec![FaultEvent::DeviceStall {
        dev: d - 1,
        t: 0.0,
        dur: 1e-6,
    }]);
    let r = simulate_schedule_iters_faulted(
        &s,
        &costs,
        1,
        Contention::Off,
        NetworkImpl::default(),
        &free,
    )
    .unwrap();
    assert_eq!(r.makespan.to_bits(), healthy.makespan.to_bits(), "absorbed stall re-timed run");

    // Validation rejects speed-ups and out-of-range devices.
    assert!(FaultPlan::parse("link:ib:1.5@0.0..1.0").unwrap().validate(d).is_err());
    assert!(FaultPlan::parse("dev:0:slow:0.5@0.0..1.0").unwrap().validate(d).is_err());
    assert!(FaultPlan::parse("dev:9:stall@0.5+0.1").unwrap().validate(4).is_err());
}
