//! The uniform-identity guarantee behind the heterogeneity support: a
//! [`ClusterConfig`] whose straggler / link-multiplier / link-override
//! tables are populated but all-neutral (every multiplier exactly 1.0)
//! must be **bit-identical** to the plain paper testbed on every backend —
//! uncontended event engine, compiled DAG, batched DAG lanes, and the
//! contended network. This is what lets `golden_makespans.txt` and the
//! table4/table7 orderings stand without a re-bless: x1.0 and /1.0 are
//! IEEE-exact identities, and uniform cost models skip the per-node scale
//! row entirely ([`DagWeights::node_scale`] stays `None`).

use bitpipe::config::{ClusterConfig, LinkKind, ParallelConfig, BERT_64};
use bitpipe::schedule::{build, ScheduleConfig, ScheduleKind};
use bitpipe::sim::{
    grid_search_cached, grid_search_on_cluster, simulate_schedule_iters,
    simulate_schedule_network, CompiledDag, Contention, CostModel, DagCache, GridSpace,
    NetworkImpl,
};

/// The paper testbed with every heterogeneity table populated but neutral.
fn neutral_cluster(n: usize) -> ClusterConfig {
    ClusterConfig::paper_testbed(n)
        .with_straggler(0, 1.0)
        .unwrap()
        .with_straggler(n - 1, 1.0)
        .unwrap()
        .with_link_mult(LinkKind::NvLink, 1.0)
        .unwrap()
        .with_link_mult(LinkKind::InfiniBand, 1.0)
        .unwrap()
        .with_link_override(0, 1, 1.0)
        .unwrap()
}

fn assert_traces_identical(tag: &str, a: &bitpipe::sim::MultiIterTrace, b: &bitpipe::sim::MultiIterTrace) {
    assert_eq!(a.makespan.to_bits(), b.makespan.to_bits(), "{tag}: makespan");
    for (x, y) in a.iter_finish.iter().zip(&b.iter_finish) {
        assert_eq!(x.to_bits(), y.to_bits(), "{tag}: iteration boundary");
    }
    for (dev, (x, y)) in a.devices.iter().zip(&b.devices).enumerate() {
        assert_eq!(x.finish.to_bits(), y.finish.to_bits(), "{tag}: dev {dev} finish");
        assert_eq!(
            x.compute_busy.to_bits(),
            y.compute_busy.to_bits(),
            "{tag}: dev {dev} compute_busy"
        );
        assert_eq!(
            x.recv_blocked.to_bits(),
            y.recv_blocked.to_bits(),
            "{tag}: dev {dev} recv_blocked"
        );
        assert_eq!(
            x.allreduce_blocked.to_bits(),
            y.allreduce_blocked.to_bits(),
            "{tag}: dev {dev} allreduce_blocked"
        );
        assert_eq!((x.sends, x.local_copies), (y.sends, y.local_copies), "{tag}: dev {dev}");
    }
}

#[test]
fn neutral_overrides_are_bit_identical_on_every_backend() {
    for kind in ScheduleKind::ALL {
        for d in [4usize, 8] {
            for n in [4usize, 8, 16] {
                if n < d {
                    continue;
                }
                let s = build(&ScheduleConfig::new(kind, d, n)).unwrap();
                let p = ParallelConfig::new(kind, 1, d, 4, n);
                let cb = CostModel::new(&BERT_64, &p, &ClusterConfig::paper_testbed(d));
                let cn = CostModel::new(&BERT_64, &p, &neutral_cluster(d));
                assert!(cn.uniform_compute(), "{kind}: neutral model must stay uniform");
                let tag = format!("{kind} D={d} N={n}");

                // Uncontended event engine, multi-iteration.
                let eb = simulate_schedule_iters(&s, &cb, 2).unwrap();
                let en = simulate_schedule_iters(&s, &cn, 2).unwrap();
                assert_traces_identical(&format!("{tag} event"), &eb, &en);

                // Contended event engine (incremental network).
                let kb =
                    simulate_schedule_network(&s, &cb, Contention::Full, NetworkImpl::Incremental)
                        .unwrap();
                let kn =
                    simulate_schedule_network(&s, &cn, Contention::Full, NetworkImpl::Incremental)
                        .unwrap();
                assert_eq!(kb.makespan.to_bits(), kn.makespan.to_bits(), "{tag}: contended");

                // Compiled DAG, scalar and batched lanes.
                if let Ok(dag) = CompiledDag::compile(&s) {
                    let wb = dag.weights(&cb);
                    let wn = dag.weights(&cn);
                    assert!(wn.node_scale().is_none(), "{tag}: neutral weights grew a scale row");
                    for (x, y) in wb.table().iter().zip(wn.table()) {
                        assert_eq!(x.to_bits(), y.to_bits(), "{tag}: weight table");
                    }
                    let db = dag.evaluate(&wb, 1).unwrap();
                    let dn = dag.evaluate(&wn, 1).unwrap();
                    assert_traces_identical(&format!("{tag} dag"), &db, &dn);
                    let batch = dag.evaluate_batch(&[wb, wn], 1).unwrap();
                    assert_traces_identical(&format!("{tag} batched[0]"), &batch[0], &db);
                    assert_traces_identical(&format!("{tag} batched[1]"), &batch[1], &dn);
                }
            }
        }
    }
}

#[test]
fn neutral_grid_sweep_matches_plain_sweep_bitwise() {
    // The sweep-level identity: grid_search_on_cluster with a neutral
    // cluster reproduces the plain cached sweep byte for byte — points,
    // order, and every f64 — so table4/table7 orderings cannot move.
    let space = GridSpace::bert64();
    let mut cache = DagCache::new();
    let plain =
        grid_search_cached(ScheduleKind::BitPipe, &BERT_64, &space, 16, 64, &mut cache).unwrap();
    let neutral = neutral_cluster(16);
    let hetero = grid_search_on_cluster(
        ScheduleKind::BitPipe,
        &BERT_64,
        &space,
        64,
        &neutral,
        &mut cache,
    )
    .unwrap();
    assert!(!plain.is_empty());
    assert_eq!(plain.len(), hetero.len());
    for (a, b) in plain.iter().zip(&hetero) {
        assert_eq!(
            (a.parallel.w, a.parallel.d, a.parallel.b, a.parallel.n),
            (b.parallel.w, b.parallel.d, b.parallel.b, b.parallel.n)
        );
        assert_eq!(a.result.throughput.to_bits(), b.result.throughput.to_bits());
        assert_eq!(a.result.iter_time.to_bits(), b.result.iter_time.to_bits());
        assert_eq!(a.result.peak_memory(), b.result.peak_memory());
    }
}
