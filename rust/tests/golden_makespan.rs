//! Golden-makespan snapshot: pins the simulated iteration time of every
//! paper-baseline schedule family on the paper testbed, bit for bit, so
//! silent cost-model drift fails CI instead of quietly shifting every
//! figure and table.
//!
//! The pinned numbers live in `rust/tests/golden_makespans.txt` (one line
//! per configuration, `f64` bits in hex so the comparison is exact). The
//! file is *recorded by the test itself*: on first run — or with
//! `BITPIPE_BLESS=1` after an intentional cost-model change — it writes
//! the current values and passes with a notice; once the file is
//! committed, any divergence is a hard failure. Ordering invariants that
//! hold regardless of the exact numbers (BitPipe fastest, sane
//! magnitudes) are asserted unconditionally so the test has teeth even
//! before the snapshot is armed.

use bitpipe::config::{ClusterConfig, ParallelConfig, BERT_64};
use bitpipe::schedule::ScheduleKind;
use bitpipe::sim::{simulate, Engine, SimConfig};
use std::fmt::Write as _;
use std::path::PathBuf;

/// The pinned grid: every paper baseline at the shallow and default
/// depths, BERT-64, B=4, W=1, paper testbed.
const GRID: [(usize, usize); 2] = [(4, 8), (8, 8)];

fn golden_path() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("rust/tests/golden_makespans.txt")
}

fn measure(kind: ScheduleKind, d: usize, n: usize) -> f64 {
    let cfg = SimConfig::new(
        BERT_64,
        ParallelConfig::new(kind, 1, d, 4, n),
        ClusterConfig::paper_testbed(d),
    );
    let r = simulate(&cfg).unwrap();
    // The snapshot pins the *shared* number: both backends must agree
    // bitwise before it is worth pinning either.
    let ev = simulate(&cfg.with_engine(Engine::Event)).unwrap();
    assert_eq!(
        r.iter_time.to_bits(),
        ev.iter_time.to_bits(),
        "{kind} D={d} N={n}: dag and event backends disagree"
    );
    r.iter_time
}

/// Families pinned by the snapshot: the paper baselines plus the
/// zero-bubble split-backward family (appended so pre-existing lines keep
/// their keys and values).
fn golden_families() -> impl Iterator<Item = ScheduleKind> {
    ScheduleKind::PAPER_BASELINES.into_iter().chain([ScheduleKind::ZeroBubble])
}

fn current_snapshot() -> Vec<(String, f64)> {
    let mut out = Vec::new();
    for (d, n) in GRID {
        for kind in golden_families() {
            let key = format!("{} d{} n{} b4 bert-64", kind.name(), d, n);
            out.push((key, measure(kind, d, n)));
        }
    }
    out
}

fn render(snapshot: &[(String, f64)]) -> String {
    let mut s = String::from(
        "# Golden makespans (seconds) — paper testbed, BERT-64, W=1, B=4.\n\
         # Format: <key> <f64 bits as hex> # <decimal for humans>\n\
         # Recorded by rust/tests/golden_makespan.rs; regenerate with\n\
         # BITPIPE_BLESS=1 cargo test --test golden_makespan after an\n\
         # intentional cost-model change.\n",
    );
    for (key, v) in snapshot {
        let _ = writeln!(s, "{key} {:016x} # {v:.9}", v.to_bits());
    }
    s
}

fn parse(text: &str) -> Vec<(String, f64)> {
    let mut out = Vec::new();
    for line in text.lines() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let mut parts = line.split(" # ").next().unwrap_or(line).rsplitn(2, ' ');
        let bits = parts.next().unwrap_or("");
        let key = parts.next().unwrap_or("").trim().to_string();
        let v = u64::from_str_radix(bits.trim(), 16)
            .map(f64::from_bits)
            .unwrap_or(f64::NAN);
        out.push((key, v));
    }
    out
}

#[test]
fn makespans_match_golden_snapshot() {
    let snapshot = current_snapshot();

    // Unconditional invariants (hold whether or not the snapshot is armed):
    // BitPipe is the fastest family at each grid point, and every makespan
    // is a sane O(0.1s..10s) BERT-64 iteration on the modeled hardware.
    for (d, n) in GRID {
        let at = |kind: ScheduleKind| {
            snapshot
                .iter()
                .find(|(k, _)| k.starts_with(kind.name()) && k.contains(&format!("d{d} n{n}")))
                .map(|&(_, v)| v)
                .unwrap()
        };
        let bit = at(ScheduleKind::BitPipe);
        assert!(bit.is_finite() && bit > 0.01 && bit < 10.0, "D={d}: BitPipe {bit}");
        for kind in ScheduleKind::PAPER_BASELINES {
            let v = at(kind);
            assert!(v.is_finite() && v > 0.0, "{kind} D={d}: {v}");
            if kind != ScheduleKind::BitPipe {
                assert!(bit < v, "D={d} N={n}: BitPipe {bit} !< {kind} {v}");
            }
        }
        // The deferred weight grads must pay off: zero-bubble beats plain
        // 1F1B at every grid point.
        let zb = at(ScheduleKind::ZeroBubble);
        let dap = at(ScheduleKind::Dapple);
        assert!(zb < dap, "D={d} N={n}: zero-bubble {zb} !< dapple {dap}");
    }

    let path = golden_path();
    let bless = std::env::var("BITPIPE_BLESS").is_ok();
    if bless || !path.exists() {
        std::fs::write(&path, render(&snapshot)).expect("write golden snapshot");
        eprintln!(
            "golden_makespan: recorded {} entries to {} — commit the file to arm the gate",
            snapshot.len(),
            path.display()
        );
        return;
    }

    let want = parse(&std::fs::read_to_string(&path).expect("read golden snapshot"));
    assert_eq!(
        want.len(),
        snapshot.len(),
        "golden file entry count changed; re-record with BITPIPE_BLESS=1 if intentional"
    );
    let mut drift = String::new();
    for ((gk, gv), (ck, cv)) in want.iter().zip(&snapshot) {
        assert_eq!(gk, ck, "golden file order changed; re-record if intentional");
        if gv.to_bits() != cv.to_bits() {
            let _ = writeln!(drift, "  {ck}: golden {gv:.9} -> current {cv:.9}");
        }
    }
    assert!(
        drift.is_empty(),
        "cost-model drift against the golden snapshot:\n{drift}\
         If this change is intentional, re-record with BITPIPE_BLESS=1 and commit."
    );
}
