//! Schedule generation: `ScheduleConfig` -> per-device compute orders.
//!
//! Unidirectional baselines use the explicit textbook constructions in
//! [`super::unidir`]; bidirectional schedules follow the paper's own
//! recipe — schedule each pipeline replica independently (1F1B / 1F1B-Int
//! greedy), mirror the up pipeline, and *fuse* the two on the shared time
//! axis (paper Fig 3). For `N > D` the schedule is scaled by concatenating
//! `K = N/D` basic units (Fig 7), or, with early forwarding (Appendix B),
//! by letting later units' forwards fill earlier units' bubbles under a
//! peak-memory cap.
//!
//! The zero-bubble family ([`ScheduleKind::ZeroBubble`]) has its own
//! generator, [`zero_bubble_order`]: a 1F1B skeleton whose backward is
//! split into the critical-path activation-grad `Bi` and a deferred
//! weight-grad `W`. The deferral follows a per-device `WeightGradStore`
//! FIFO — each `Bi` enqueues its micro-batch, each `W` dequeues the head —
//! and `W`s are released only to fill bubbles, when the queue exceeds its
//! steady-state bound, or in the final drain. See the function docs for
//! the exact discipline.

use super::asap::{retime, Costs};
use super::greedy::{greedy_order, greedy_pipe_order, GreedyPolicy, PipeJob};
use super::ir::{
    CompOp, MicroBatch, OpKind, PipeId, Placement, Schedule, ScheduleConfig, ScheduleKind,
};
use super::slotted::slotted_order;
use super::unidir::{dapple_order, gpipe_order, interleaved_order};
use anyhow::{bail, ensure, Result};
use std::collections::{HashMap, VecDeque};

/// Stage -> device map for one *down* pipe of the given kind.
fn down_device(kind: ScheduleKind, d: usize, s: usize) -> usize {
    match kind {
        // One stage per device, in order.
        ScheduleKind::GPipe | ScheduleKind::Dapple | ScheduleKind::Gems | ScheduleKind::Chimera
        | ScheduleKind::MixPipe | ScheduleKind::ZeroBubble => s,
        // Looping: chunk c of device x is stage c*D + x.
        ScheduleKind::Interleaved | ScheduleKind::BitPipeNoV => s % d,
        // V-shape: forward through devices, then zig-zag back (Fig 4b).
        ScheduleKind::VShaped | ScheduleKind::BitPipe => {
            let round = s / d;
            let pos = s % d;
            if round % 2 == 0 {
                pos
            } else {
                d - 1 - pos
            }
        }
    }
}

/// Build the placement for a schedule kind.
pub fn placement_for(kind: ScheduleKind, d: usize, v: usize) -> Placement {
    let n_pipes = if kind.bidirectional() { 2 } else { 1 };
    Placement::from_fn(d, v, n_pipes, |p, s| {
        let down = down_device(kind, d, s);
        if p == 0 {
            down
        } else {
            // Up pipe: "strikingly opposite order" — mirror the devices.
            d - 1 - down
        }
    })
}

/// Which pipe each micro-batch is injected into.
fn pipe_assignment(kind: ScheduleKind, d: usize, n: usize) -> Vec<PipeId> {
    if !kind.bidirectional() {
        return vec![0; n];
    }
    if kind == ScheduleKind::Gems {
        // GEMS alternates replicas micro-batch by micro-batch.
        return (0..n).map(|m| m % 2).collect();
    }
    // Chimera / MixPipe / BitPipe: each basic unit of `u = min(N, D)`
    // micro-batches is split half down, half up.
    let u = n.min(d);
    (0..n).map(|m| if m % u < u / 2 { 0 } else { 1 }).collect()
}

/// Injection cap (in-flight micro-batches per pipe) for BitPipe's
/// early-forwarding scaling (Appendix B): pulling later units' forwards
/// into earlier units' bubbles while keeping peak activations at
/// (3D-3)/2 * M_a per device *across both pipes*. Per pipe that is
/// (3D-3)/4 micro-batches; fractional budget rounds **up** (the schedule
/// admits the partially-filled slot), so the cap is
///
/// ```text
/// ceil(3(D-1)/4)  ==  (3(D-1) + 3)/4  ==  floor(3D/4)
/// ```
///
/// (the three forms coincide for every D — 3D/4 differs from 3(D-1)/4 by
/// exactly 3/4, which the ceiling absorbs). D=4 -> 3, D=8 -> 6,
/// D=16 -> 12, D=32 -> 24; pinned by `early_forward_cap_matches_appendix_b`.
fn early_forward_cap(d: usize) -> usize {
    // ceil(3(D-1)/4), written with the usual (a + b - 1)/b idiom.
    (3 * (d - 1) + 3) / 4
}

/// Generate the fused compute orders for one *basic unit* of a
/// bidirectional schedule: both pipes scheduled jointly by the greedy
/// 1F1B engine over the shared devices. The paper's no-conflict fusion is
/// emergent — each pipe's ops land in the other's bubbles; the joint
/// generator reproduces the closed-form makespans exactly at D=4 (the
/// published figure) and within ~2% above for larger D.
fn bidir_basic_unit(
    placement: &Placement,
    down_mbs: &[MicroBatch],
    up_mbs: &[MicroBatch],
    costs: &Costs,
    cap: Option<usize>,
) -> Result<Vec<Vec<CompOp>>> {
    let policy = GreedyPolicy { inflight_cap: cap, extra_deps: None };
    let jobs = [
        PipeJob { pipe: 0, mbs: down_mbs.to_vec() },
        PipeJob { pipe: 1, mbs: up_mbs.to_vec() },
    ];
    let order = greedy_order(placement, &jobs, &policy, costs);
    // Tripwire: the fused order must re-time (deadlock-free by design).
    retime(&order, placement, costs)?;
    Ok(order)
}

/// Software-pipelined concatenation of basic units (paper Fig 7 and
/// Appendix B): re-time each unit independently, shift unit `k`'s virtual
/// times by `k * period` (period = the unit's ideal per-device busy time,
/// i.e. the steady-state initiation interval), and interleave per-device
/// orders by shifted start time. Later units' warmup forwards thereby fill
/// earlier units' trailing bubbles; cross-unit dataflow deps do not exist,
/// so the merged order always re-times.
fn pipelined_concat(
    units: Vec<Vec<Vec<CompOp>>>,
    placement: &Placement,
    costs: &Costs,
    period: u64,
) -> Result<Vec<Vec<CompOp>>> {
    let d = placement.d;
    let k_units = units.len();
    let mut timed: Vec<Vec<Vec<(u64, CompOp)>>> = Vec::with_capacity(k_units);
    let mut unit_makespan = 0u64;
    for unit in &units {
        let t = retime(unit, placement, costs)?;
        unit_makespan = unit_makespan.max(t.makespan);
        timed.push(
            t.devices
                .iter()
                .map(|ops| ops.iter().map(|top| (top.start, top.op)).collect())
                .collect(),
        );
    }
    if k_units == 1 {
        return Ok(units.into_iter().next().unwrap());
    }

    // The initiation interval can't beat the steady-state busy time
    // (`period`), but unit gap structures rarely tile perfectly; search the
    // smallest shift in [period, unit_makespan] whose merged ASAP makespan
    // is minimal. Dataflow deps never cross units, so every candidate
    // re-times; this is classic modulo-scheduling interval search.
    let step = costs.chunk_f(placement.v).max(1);
    let mut best: Option<(u64, Vec<Vec<CompOp>>)> = None;
    let mut shift = period;
    while shift <= unit_makespan {
        let mut merged: Vec<Vec<(u64, usize, usize, CompOp)>> = vec![Vec::new(); d];
        for (k, unit) in timed.iter().enumerate() {
            for (dev, ops) in unit.iter().enumerate() {
                for (i, &(start, op)) in ops.iter().enumerate() {
                    merged[dev].push((start + k as u64 * shift, k, i, op));
                }
            }
        }
        for devops in &mut merged {
            // Stable within-unit order (k, i) breaks start-time ties.
            devops.sort_by_key(|&(start, k, i, _)| (start, k, i));
        }
        let order: Vec<Vec<CompOp>> = merged
            .into_iter()
            .map(|v| v.into_iter().map(|(_, _, _, op)| op).collect())
            .collect();
        let m = retime(&order, placement, costs)?.makespan;
        if best.as_ref().map_or(true, |(bm, _)| m < *bm) {
            best = Some((m, order));
        }
        shift += step;
    }
    Ok(best.expect("at least one shift candidate").1)
}

/// Peak per-device activation-stash depth of an order, in chunk units
/// (one chunk-input per forward not yet consumed by its backward).
fn peak_chunk_stash(order: &[Vec<CompOp>]) -> usize {
    let mut peak = 0i64;
    for dev in order {
        let mut depth = 0i64;
        for op in dev {
            match op.kind {
                OpKind::Forward => depth += 1,
                OpKind::Backward | OpKind::BackwardWeight => depth -= 1,
                // The stash slot transitions to a weight-grad pin: no net
                // change until the matching W.
                OpKind::BackwardInput => {}
            }
            peak = peak.max(depth);
        }
    }
    peak.max(0) as usize
}

/// Zero-bubble (ZB-H1-style) compute order: a 1F1B skeleton with the
/// backward split into `Bi` (activation grad, critical path) and `W`
/// (weight grad, deferred). Unidirectional, one stage per device (v = 1).
///
/// Discipline, per device `i` hosting stage `i`:
///   * forwards are admitted under an in-flight cap of `D - i`, the 1F1B
///     warmup depth — the activation ceiling this family inherits;
///   * every `Bi` pushes its micro-batch onto the device's
///     `WeightGradStore` FIFO; every `W` pops the head (strict FIFO per
///     device chunk);
///   * a queued `W` becomes a candidate only when (a) the queue is deeper
///     than the deferral bound `D - 1 - i` — in steady state a device
///     keeps one deferred `W` per downstream stage to absorb the ramp-down
///     bubble — or (b) the device would otherwise idle (every other
///     candidate starts strictly later than the `W` could), including the
///     final drain when nothing else remains.
///
/// Emission is a deterministic global list schedule in integer ticks:
/// repeatedly pick the candidate with the earliest dataflow-feasible start,
/// breaking ties by lower device, then `Bi` < forced-`W` < `F` <
/// idle-fill-`W`. The result re-times by construction and is mirrored
/// line-for-line in the pymirror (`verify_streams_lib.py`).
fn zero_bubble_order(
    placement: &Placement,
    mbs: &[MicroBatch],
    costs: &Costs,
) -> Vec<Vec<CompOp>> {
    let d = placement.d;
    let n_stages = placement.n_stages();
    debug_assert_eq!(n_stages, d, "zero-bubble is v = 1, one stage per device");
    let v = placement.v;
    let n = mbs.len();
    let mut done: HashMap<CompOp, u64> = HashMap::with_capacity(3 * n * d);
    let mut avail = vec![0u64; d];
    let mut next_f = vec![0usize; d];
    let mut next_bi = vec![0usize; d];
    let mut wstore: Vec<VecDeque<MicroBatch>> = vec![VecDeque::new(); d];
    let mut out: Vec<Vec<CompOp>> = vec![Vec::new(); d];
    let total = 3 * n * d;

    // Earliest dataflow-feasible start of `op` on `dev`; None while a
    // dependency has not been emitted yet.
    let ready_at = |op: &CompOp, dev: usize, done: &HashMap<CompOp, u64>, avail: &[u64]| {
        let mut start = avail[dev];
        for dep in super::asap::deps_of(op, n_stages) {
            match done.get(&dep) {
                Some(&end) => start = start.max(end),
                None => return None,
            }
        }
        Some(start)
    };

    for _ in 0..total {
        // (start, dev, class, op) — class: Bi 0, forced W 1, F 2, idle W 3.
        let mut best: Option<(u64, usize, u8, CompOp)> = None;
        for dev in 0..d {
            let stage = dev;
            let mut cands: Vec<(u64, u8, CompOp)> = Vec::new();
            if next_bi[dev] < n {
                let op = CompOp::bwd_input(0, stage, mbs[next_bi[dev]]);
                if let Some(start) = ready_at(&op, dev, &done, &avail) {
                    cands.push((start, 0, op));
                }
            }
            if next_f[dev] < n && next_f[dev] - next_bi[dev] < d - dev {
                let op = CompOp::fwd(0, stage, mbs[next_f[dev]]);
                if let Some(start) = ready_at(&op, dev, &done, &avail) {
                    cands.push((start, 2, op));
                }
            }
            if let Some(&m) = wstore[dev].front() {
                // A W's dependency is its own Bi, already emitted on this
                // device, so it can always start at `avail[dev]`.
                let start = avail[dev];
                let forced = wstore[dev].len() > d - 1 - dev;
                let idle_fill = cands.iter().all(|&(s, _, _)| start < s);
                if forced || idle_fill {
                    cands.push((start, if forced { 1 } else { 3 }, CompOp::bwd_weight(0, stage, m)));
                }
            }
            for (start, class, op) in cands {
                let better = match &best {
                    None => true,
                    Some(&(bs, bd, bc, _)) => (start, dev, class) < (bs, bd, bc),
                };
                if better {
                    best = Some((start, dev, class, op));
                }
            }
        }
        let (start, dev, _, op) =
            best.expect("zero-bubble scheduler stuck: no emittable candidate");
        let end = start + costs.of(&op, v);
        done.insert(op, end);
        avail[dev] = end;
        out[dev].push(op);
        match op.kind {
            OpKind::Forward => next_f[dev] += 1,
            OpKind::BackwardInput => {
                next_bi[dev] += 1;
                wstore[dev].push_back(op.mb);
            }
            OpKind::BackwardWeight => {
                wstore[dev].pop_front();
            }
            OpKind::Backward => unreachable!("zero-bubble emits split backwards only"),
        }
    }
    out
}

/// Generate a schedule's compute orders (no comm ops yet; see
/// [`super::comm_pass`]).
pub fn generate_compute(cfg: &ScheduleConfig, costs: &Costs) -> Result<Schedule> {
    let ScheduleConfig { kind, d, n, v, .. } = *cfg;
    ensure!(d >= 2, "need at least 2 pipeline devices (got {d})");
    ensure!(n >= 1, "need at least 1 micro-batch");
    ensure!(v >= 1, "v must be >= 1");
    if kind.bidirectional() {
        ensure!(d % 2 == 0, "{kind}: bidirectional schedules need even D (got {d})");
        ensure!(n % 2 == 0, "{kind}: bidirectional schedules need even N (got {n})");
    }
    match kind {
        ScheduleKind::GPipe | ScheduleKind::Dapple | ScheduleKind::Gems | ScheduleKind::Chimera
        | ScheduleKind::MixPipe | ScheduleKind::ZeroBubble => {
            ensure!(v == 1, "{kind} is non-interleaved; v must be 1 (got {v})")
        }
        _ => ensure!(v >= 2, "{kind} is interleaved; v must be >= 2 (got {v})"),
    }
    if n > d {
        ensure!(
            n % d == 0,
            "N must be a multiple of D when N > D (paper's setting; got N={n}, D={d})"
        );
    }

    let placement = placement_for(kind, d, v);
    let pipe_of_mb = pipe_assignment(kind, d, n);
    let all_mbs: Vec<usize> = (0..n).collect();

    let compute_order: Vec<Vec<CompOp>> = match kind {
        ScheduleKind::GPipe => gpipe_order(&placement, 0, &all_mbs),
        ScheduleKind::Dapple => dapple_order(&placement, 0, &all_mbs),
        ScheduleKind::ZeroBubble => zero_bubble_order(&placement, &all_mbs, costs),
        ScheduleKind::Interleaved => interleaved_order(&placement, 0, &all_mbs),
        ScheduleKind::VShaped => {
            // The V placement re-orders the second chunk round across
            // devices, so Megatron's looping warmup arithmetic does not
            // apply; the greedy 1F1B policy (backward-first, depth-first
            // through co-located turns) produces the Fig 4(b) schedule.
            // Cap in-flight stashes at D*v chunks — 1F1B-Int's D x M_a
            // activation ceiling (Table 2).
            let policy = GreedyPolicy { inflight_cap: Some(d * v), extra_deps: None };
            greedy_pipe_order(&placement, 0, &all_mbs, &policy, costs)
        }
        ScheduleKind::Gems => {
            // Cross-replica gate: forward of micro-batch m may enter its
            // pipe only after micro-batch m-2 (same replica) fully drained
            // and m-1's forward (other replica) left the shared entry
            // device. We encode the published behaviour — at most two
            // micro-batches in flight — with a direct dependency on the
            // previous same-replica backward at the entry stage.
            let gate = move |op: &CompOp| -> Vec<CompOp> {
                if op.kind == OpKind::Forward && op.stage == 0 && op.mb >= 2 {
                    vec![CompOp::bwd(op.pipe, 0, op.mb - 2)]
                } else {
                    vec![]
                }
            };
            let jobs = [
                PipeJob { pipe: 0, mbs: all_mbs.iter().copied().filter(|m| m % 2 == 0).collect() },
                PipeJob { pipe: 1, mbs: all_mbs.iter().copied().filter(|m| m % 2 == 1).collect() },
            ];
            let policy = GreedyPolicy { inflight_cap: None, extra_deps: Some(&gate) };
            greedy_order(&placement, &jobs, &policy, costs)
        }
        ScheduleKind::Chimera => {
            // Forward doubling when scaling (Chimera's own N > D scheme):
            // up to D micro-batches in flight per pipe, 2D * M_a peak.
            let cap = Some(d);
            let down: Vec<usize> = by_pipe(&pipe_of_mb, 0);
            let up: Vec<usize> = by_pipe(&pipe_of_mb, 1);
            bidir_basic_unit(&placement, &down, &up, costs, cap)?
        }
        ScheduleKind::MixPipe => {
            // K-maximizing: software-pipelined basic units; the period is
            // the unit's ideal busy time per device.
            let units = split_units(&pipe_of_mb, d, n);
            let unit_n = n.min(d) as u64;
            let period = unit_n * (costs.chunk_f(v) + costs.chunk_b(v)) * v as u64;
            let mut unit_orders = Vec::new();
            for (down, up) in units {
                unit_orders.push(bidir_basic_unit(&placement, &down, &up, costs, None)?);
            }
            pipelined_concat(unit_orders, &placement, costs, period)?
        }
        ScheduleKind::BitPipe | ScheduleKind::BitPipeNoV => {
            let units = split_units(&pipe_of_mb, d, n);
            let unit_n = n.min(d) as u64;
            let period = unit_n * (costs.chunk_f(v) + costs.chunk_b(v)) * v as u64;
            let mut unit_orders = Vec::new();
            for (down, up) in units {
                unit_orders.push(bidir_basic_unit(&placement, &down, &up, costs, None)?);
            }
            let concat = pipelined_concat(unit_orders, &placement, costs, period)?;
            if n <= d || !cfg.early_forward {
                // Fig 7: software-pipelined concatenation — trailing
                // bubbles of unit k absorb the first forwards of unit k+1.
                concat
            } else {
                // Appendix B early forwarding: pull later units' forwards
                // deeper into earlier units' bubbles. A portfolio of
                // injection caps is generated; every candidate must respect
                // Table 2's D x M_a activation ceiling, and the fastest one
                // wins. (EXPERIMENTS.md records measured-vs-formula for
                // each regime.)
                let down: Vec<usize> = by_pipe(&pipe_of_mb, 0);
                let up: Vec<usize> = by_pipe(&pipe_of_mb, 1);
                let jobs = [
                    PipeJob { pipe: 0, mbs: down.clone() },
                    PipeJob { pipe: 1, mbs: up.clone() },
                ];
                let mut best = concat;
                let mut best_span = retime(&best, &placement, costs)?.makespan;
                // Activation ceiling for the scaling regime: the paper's
                // Appendix-B claim is (3D-3)/2 x M_a (already above Table
                // 2's D x M_a, which holds for N = D); we admit candidates
                // up to the bidirectional family's scaling ceiling of
                // 2D x M_a (Chimera forward doubling) and report measured
                // peaks honestly (Fig 8 / EXPERIMENTS.md). In M_a units a
                // chunk stash is 1/v.
                let ceiling_chunks = 2 * d * v;
                // Slotted steady-state candidates (the Appendix-B
                // discipline) over a few injection caps...
                for cap in [early_forward_cap(d), d / 2 + 1, 3 * d / 4, d] {
                    let Ok(cand) = slotted_order(&placement, &jobs, cap, costs) else {
                        continue;
                    };
                    if peak_chunk_stash(&cand) > ceiling_chunks {
                        continue;
                    }
                    let span = retime(&cand, &placement, costs)?.makespan;
                    if span < best_span {
                        best = cand;
                        best_span = span;
                    }
                }
                // ...plus plain joint-greedy candidates.
                for cap in [Some(early_forward_cap(d)), Some(d), None] {
                    let cand = bidir_basic_unit(&placement, &down, &up, costs, cap)?;
                    if peak_chunk_stash(&cand) > ceiling_chunks {
                        continue;
                    }
                    let span = retime(&cand, &placement, costs)?.makespan;
                    if span < best_span {
                        best = cand;
                        best_span = span;
                    }
                }
                best
            }
        }
    };

    // Sanity: the fused order must re-time without deadlock.
    match retime(&compute_order, &placement, costs) {
        Ok(_) => {}
        Err(e) => bail!("generated {kind} schedule does not re-time: {e}"),
    }

    Ok(Schedule { cfg: *cfg, placement, compute_order, device_ops: Vec::new(), pipe_of_mb })
}

fn by_pipe(pipe_of_mb: &[PipeId], pipe: PipeId) -> Vec<MicroBatch> {
    pipe_of_mb
        .iter()
        .enumerate()
        .filter(|&(_, &p)| p == pipe)
        .map(|(m, _)| m)
        .collect()
}

/// Split micro-batches into basic units of `min(N, D)` and return each
/// unit's (down, up) micro-batch lists.
fn split_units(
    pipe_of_mb: &[PipeId],
    d: usize,
    n: usize,
) -> Vec<(Vec<MicroBatch>, Vec<MicroBatch>)> {
    let u = n.min(d);
    let k = n / u;
    (0..k)
        .map(|i| {
            let lo = i * u;
            let hi = lo + u;
            let down = (lo..hi).filter(|&m| pipe_of_mb[m] == 0).collect();
            let up = (lo..hi).filter(|&m| pipe_of_mb[m] == 1).collect();
            (down, up)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::schedule::TimedSchedule;

    fn geom(kind: ScheduleKind, d: usize, n: usize) -> TimedSchedule {
        let cfg = ScheduleConfig::new(kind, d, n);
        let costs = Costs::default();
        let s = generate_compute(&cfg, &costs).unwrap();
        retime(&s.compute_order, &s.placement, &costs).unwrap()
    }

    #[test]
    fn all_kinds_generate_n_eq_d() {
        for kind in ScheduleKind::ALL {
            let cfg = ScheduleConfig::new(kind, 4, 4);
            let costs = Costs::default();
            let s = generate_compute(&cfg, &costs)
                .unwrap_or_else(|e| panic!("{kind} failed: {e}"));
            let total: usize = s.compute_order.iter().map(|o| o.len()).sum();
            assert_eq!(total, 2 * 4 * cfg.v * 4, "{kind}: op count");
        }
    }

    #[test]
    fn all_kinds_generate_n_eq_4d() {
        for kind in ScheduleKind::ALL {
            let cfg = ScheduleConfig::new(kind, 4, 16);
            let costs = Costs::default();
            let s = generate_compute(&cfg, &costs)
                .unwrap_or_else(|e| panic!("{kind} failed: {e}"));
            let total: usize = s.compute_order.iter().map(|o| o.len()).sum();
            assert_eq!(total, 2 * 16 * cfg.v * 4, "{kind}: op count");
        }
    }

    #[test]
    fn bitpipe_basic_unit_bubble_claim() {
        // Paper: BitPipe with N=D incurs D-2 ticks of bubble per device
        // (in tf units: (D-2)/2 forward bubbles + (D-2)/4 backward bubbles,
        // tb=2tf), so makespan = 3N*tf + (D-2)*tf. The generator matches
        // the closed form exactly at D=4 (the published figure) and stays
        // within 2% above it for larger D (see EXPERIMENTS.md).
        for d in [4usize, 8, 16] {
            let t = geom(ScheduleKind::BitPipe, d, d);
            let tf = 12u64; // full-stage forward ticks
            let want = 3 * (d as u64) * tf + (d as u64 - 2) * tf;
            if d == 4 {
                assert_eq!(t.makespan, want, "D=4 must match the paper exactly");
            } else {
                assert!(
                    t.makespan >= want && (t.makespan as f64) <= want as f64 * 1.02,
                    "D={d}: makespan {} not within 2% of {want}",
                    t.makespan
                );
            }
        }
    }

    #[test]
    fn early_forward_cap_matches_appendix_b() {
        // Appendix B: ceil(3(D-1)/4) in-flight micro-batches per pipe keeps
        // the peak activation stash at (3D-3)/2 x M_a across both pipes.
        for (d, want) in [(4usize, 3usize), (8, 6), (16, 12), (32, 24)] {
            assert_eq!(early_forward_cap(d), want, "D={d}");
            // The closed forms in the doc comment agree: the implemented
            // ceil(3(D-1)/4) equals floor(3D/4) for every D.
            assert_eq!(early_forward_cap(d), 3 * d / 4, "floor(3D/4), D={d}");
        }
    }

    #[test]
    fn bidirectional_placements_mirror() {
        let p = placement_for(ScheduleKind::BitPipe, 4, 2);
        for s in 0..8 {
            assert_eq!(p.device(1, s), 3 - p.device(0, s));
        }
        // V-shape: stages 0..4 forward, 4..8 zig-zag back.
        assert_eq!(p.device(0, 0), 0);
        assert_eq!(p.device(0, 3), 3);
        assert_eq!(p.device(0, 4), 3);
        assert_eq!(p.device(0, 7), 0);
    }

    #[test]
    fn chimera_no_conflict_basic_unit() {
        // Chimera's fused basic unit must land exactly on its closed-form
        // bubble ratio (D-2)/(1.5N + D-2): with tf=12, tb=24 that is a
        // makespan of 24*(1.5N + D-2).
        let costs = Costs::default();
        for d in [4usize, 8, 16] {
            let cfg = ScheduleConfig::new(ScheduleKind::Chimera, d, d);
            let s = generate_compute(&cfg, &costs).unwrap();
            let t = retime(&s.compute_order, &s.placement, &costs).unwrap();
            let want = 24 * (3 * d as u64 / 2 + d as u64 - 2);
            assert_eq!(t.makespan, want, "D={d}: Chimera basic unit");
        }
    }

    #[test]
    fn gems_two_inflight() {
        let cfg = ScheduleConfig::new(ScheduleKind::Gems, 4, 8);
        let costs = Costs::default();
        let s = generate_compute(&cfg, &costs).unwrap();
        // Count global in-flight micro-batches over virtual time.
        let t = retime(&s.compute_order, &s.placement, &costs).unwrap();
        let mut events: Vec<(u64, i64)> = Vec::new();
        for dev in &t.devices {
            for top in dev {
                if top.op.stage == 0 && top.op.is_fwd() {
                    events.push((top.start, 1));
                }
                if top.op.stage == 0 && !top.op.is_fwd() {
                    events.push((top.end, -1));
                }
            }
        }
        events.sort();
        let mut cur = 0i64;
        let mut peak = 0i64;
        for (_, delta) in events {
            cur += delta;
            peak = peak.max(cur);
        }
        assert!(peak <= 3, "GEMS in-flight {peak} > 3");
    }

    #[test]
    fn invalid_configs_rejected() {
        let costs = Costs::default();
        // Odd D bidirectional.
        assert!(generate_compute(&ScheduleConfig::new(ScheduleKind::BitPipe, 3, 4), &costs)
            .is_err());
        // Ragged N.
        assert!(generate_compute(&ScheduleConfig::new(ScheduleKind::Dapple, 4, 10), &costs)
            .is_err());
        // v on non-interleaved.
        assert!(generate_compute(
            &ScheduleConfig::new(ScheduleKind::Chimera, 4, 4).with_v(2),
            &costs
        )
        .is_err());
    }
}
