//! Slotted steady-state generator for fused bidirectional pipelines.
//!
//! The BitPipe steady state (Appendix B) has every device cycling through
//! four op classes — forward/backward of the down pipe, forward/backward of
//! the up pipe — so that each pipe runs at half rate and the two mirrored
//! pipes mesh without conflicts. A plain greedy (backward-first,
//! earliest-start) does not discover this discipline for N > D: it drains
//! basic units too eagerly and leaves a per-unit seam bubble.
//!
//! This generator *enforces* the rotation: per device, a phase pointer
//! cycles `(F,down) -> (B,down) -> (F,up) -> (B,up)`; at each step the
//! device runs the oldest immediately-startable op of the phased class
//! (skipping to the next class when none is startable), subject to a
//! per-pipe in-flight micro-batch cap that bounds the activation stash.
//! When no device can start anything, virtual clocks advance to the next
//! enabling time.
//!
//! On the paper's own configurations this reproduces the Appendix-B
//! early-forwarding geometry: e.g. D=4/N=8 lands exactly on the
//! `(D-2)/(4N+D-2)` bubble-ratio makespan. The BitPipe generator uses it
//! as one candidate in its scaling portfolio (see `generate.rs`).

use super::asap::{deps_of, Costs};
use super::greedy::PipeJob;
use super::ir::{CompOp, OpKind, Placement};
use anyhow::{bail, Result};
use std::collections::HashMap;

/// Op class in the per-device rotation.
fn class_of(op: &CompOp) -> usize {
    match (op.kind, op.pipe) {
        (OpKind::Forward, 0) => 0,
        (OpKind::Backward, 0) => 1,
        (OpKind::Forward, _) => 2,
        (OpKind::Backward, _) => 3,
        // The slotted rotation only cycles fused F/B classes.
        _ => unreachable!("split backward in slotted rotation"),
    }
}

fn class_kind(cls: usize) -> (OpKind, usize) {
    match cls {
        0 => (OpKind::Forward, 0),
        1 => (OpKind::Backward, 0),
        2 => (OpKind::Forward, 1),
        _ => (OpKind::Backward, 1),
    }
}

/// Generate per-device compute orders under the slotted rotation.
///
/// `cap_mb` bounds in-flight micro-batches per pipe (injection gate:
/// entry-stage forward to entry-stage backward), which in turn bounds the
/// per-device activation stash.
pub fn slotted_order(
    placement: &Placement,
    jobs: &[PipeJob],
    cap_mb: usize,
    costs: &Costs,
) -> Result<Vec<Vec<CompOp>>> {
    let d = placement.d;
    let v = placement.v;
    let n_stages = placement.n_stages();

    // Frontier per (pipe, mb): only the lowest unscheduled forward stage
    // and highest unscheduled backward stage can be ready (see greedy.rs).
    let mut rank: HashMap<(usize, usize), usize> = HashMap::new();
    let mut mbs_of_pipe: [Vec<usize>; 2] = [Vec::new(), Vec::new()];
    for job in jobs {
        for (i, &m) in job.mbs.iter().enumerate() {
            rank.insert((job.pipe, m), i);
            mbs_of_pipe[job.pipe].push(m);
        }
    }
    let n_mbs: usize = mbs_of_pipe.iter().map(|v| v.len()).sum();
    let total = n_mbs * 2 * n_stages;
    let mut next_f: HashMap<(usize, usize), usize> =
        rank.keys().map(|&k| (k, 0usize)).collect();
    let mut next_b: HashMap<(usize, usize), usize> =
        rank.keys().map(|&k| (k, n_stages)).collect();

    let mut done: HashMap<CompOp, u64> = HashMap::with_capacity(total);
    let mut avail = vec![0u64; d];
    let mut order: Vec<Vec<CompOp>> = vec![Vec::new(); d];
    let mut inflight = vec![0usize; 2];
    let mut phase = vec![0usize; d];
    let mut scheduled = 0usize;
    let mut stalls = 0usize;

    while scheduled < total {
        let mut progressed = false;
        let mut devs: Vec<usize> = (0..d).collect();
        devs.sort_by_key(|&x| avail[x]);
        'outer: for &dev in &devs {
            for off in 0..4 {
                let cls = (phase[dev] + off) % 4;
                let (kind, pipe) = class_kind(cls);
                // Oldest startable-now frontier op of this class on this
                // device (rank order; forwards ascending stage, backwards
                // descending — the drain direction).
                let mut best: Option<(usize, usize, CompOp)> = None;
                for &m in &mbs_of_pipe[pipe] {
                    let stage = match kind {
                        OpKind::Forward => {
                            let nf = next_f[&(pipe, m)];
                            if nf >= n_stages {
                                continue;
                            }
                            nf
                        }
                        OpKind::Backward => {
                            let nb = next_b[&(pipe, m)];
                            if nb == 0 {
                                continue;
                            }
                            nb - 1
                        }
                        _ => unreachable!("split backward in slotted rotation"),
                    };
                    let op = CompOp { kind, pipe, stage, mb: m };
                    if placement.device(pipe, stage) != dev {
                        continue;
                    }
                    if kind == OpKind::Forward && stage == 0 && inflight[pipe] >= cap_mb {
                        continue;
                    }
                    let mut ready = avail[dev];
                    let mut ok = true;
                    for dep in deps_of(&op, n_stages) {
                        match done.get(&dep) {
                            Some(&e) => ready = ready.max(e),
                            None => {
                                ok = false;
                                break;
                            }
                        }
                    }
                    if !ok || ready > avail[dev] {
                        continue;
                    }
                    let key = (
                        rank[&(pipe, m)],
                        if kind == OpKind::Forward { stage } else { n_stages - stage },
                    );
                    if best.as_ref().map_or(true, |b| (b.0, b.1) > key) {
                        best = Some((key.0, key.1, op));
                    }
                }
                if let Some((_, _, op)) = best {
                    let dur = costs.of(&op, v);
                    done.insert(op, avail[dev] + dur);
                    avail[dev] += dur;
                    if op.stage == 0 {
                        match op.kind {
                            OpKind::Forward => inflight[op.pipe] += 1,
                            OpKind::Backward => {
                                inflight[op.pipe] = inflight[op.pipe].saturating_sub(1)
                            }
                            _ => unreachable!("split backward in slotted rotation"),
                        }
                    }
                    match op.kind {
                        OpKind::Forward => *next_f.get_mut(&(op.pipe, op.mb)).unwrap() += 1,
                        OpKind::Backward => *next_b.get_mut(&(op.pipe, op.mb)).unwrap() -= 1,
                        _ => unreachable!("split backward in slotted rotation"),
                    }
                    order[dev].push(op);
                    scheduled += 1;
                    phase[dev] = (class_of(&op) + 1) % 4;
                    progressed = true;
                    break 'outer;
                }
            }
        }
        if !progressed {
            // Nothing startable at current clocks: advance stalled devices
            // to the earliest enabling time among frontier ops.
            let mut best_t = u64::MAX;
            let mut frontier_ops: Vec<CompOp> = Vec::new();
            for (&(pipe, m), &nf) in &next_f {
                if nf < n_stages {
                    frontier_ops.push(CompOp::fwd(pipe, nf, m));
                }
            }
            for (&(pipe, m), &nb) in &next_b {
                if nb > 0 {
                    frontier_ops.push(CompOp::bwd(pipe, nb - 1, m));
                }
            }
            for op in &frontier_ops {
                if op.kind == OpKind::Forward && op.stage == 0 && inflight[op.pipe] >= cap_mb {
                    continue;
                }
                let dev = placement.device(op.pipe, op.stage);
                let mut ready = avail[dev];
                let mut ok = true;
                for dep in deps_of(op, n_stages) {
                    match done.get(&dep) {
                        Some(&e) => ready = ready.max(e),
                        None => {
                            ok = false;
                            break;
                        }
                    }
                }
                if ok {
                    best_t = best_t.min(ready);
                }
            }
            if best_t == u64::MAX {
                bail!(
                    "slotted generator deadlocked with cap_mb={cap_mb} \
                     ({} of {} ops scheduled)",
                    scheduled,
                    total
                );
            }
            for dev in 0..d {
                if avail[dev] < best_t {
                    avail[dev] = best_t;
                }
            }
            stalls += 1;
            if stalls > total * 8 {
                bail!("slotted generator livelocked with cap_mb={cap_mb}");
            }
        }
    }
    Ok(order)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::schedule::asap::retime;
    use crate::schedule::generate::placement_for;
    use crate::schedule::ScheduleKind;

    fn bitpipe_jobs(d: usize, n: usize) -> (Placement, Vec<PipeJob>) {
        let p = placement_for(ScheduleKind::BitPipe, d, 2);
        let u = d.min(n);
        let down: Vec<usize> = (0..n).filter(|m| m % u < u / 2).collect();
        let up: Vec<usize> = (0..n).filter(|m| m % u >= u / 2).collect();
        (p, vec![PipeJob { pipe: 0, mbs: down }, PipeJob { pipe: 1, mbs: up }])
    }

    #[test]
    fn slotted_d4_n8_hits_appendix_b_formula() {
        // The Appendix-B early-forwarding geometry: bubble ratio
        // (D-2)/(4N+D-2) => makespan 36N + 9(D-2) ticks at tf=12.
        let (p, jobs) = bitpipe_jobs(4, 8);
        let costs = Costs::default();
        let order = slotted_order(&p, &jobs, 4, &costs).unwrap();
        let t = retime(&order, &p, &costs).unwrap();
        assert_eq!(t.makespan, 36 * 8 + 9 * 2, "D=4 N=8 early forwarding");
    }

    #[test]
    fn slotted_d4_n16_hits_appendix_b_formula() {
        let (p, jobs) = bitpipe_jobs(4, 16);
        let costs = Costs::default();
        let order = slotted_order(&p, &jobs, 4, &costs).unwrap();
        let t = retime(&order, &p, &costs).unwrap();
        assert_eq!(t.makespan, 36 * 16 + 9 * 2, "D=4 N=16 early forwarding");
    }

    #[test]
    fn slotted_all_ops_exactly_once() {
        let (p, jobs) = bitpipe_jobs(4, 8);
        let costs = Costs::default();
        let order = slotted_order(&p, &jobs, 4, &costs).unwrap();
        let mut seen = std::collections::HashSet::new();
        for ops in &order {
            for op in ops {
                assert!(seen.insert(*op), "duplicate {op}");
            }
        }
        assert_eq!(seen.len(), 2 * 8 * 8);
    }

    #[test]
    fn slotted_beats_greedy_at_2d_and_4d() {
        // The discipline pays off at scale: strictly better than the
        // software-pipelined concat result on D=8 (see generate.rs tests).
        let costs = Costs::default();
        for (n, bound) in [(16usize, 702u64), (32, 1374)] {
            let (p, jobs) = bitpipe_jobs(8, n);
            let order = slotted_order(&p, &jobs, 8, &costs).unwrap();
            let t = retime(&order, &p, &costs).unwrap();
            assert!(t.makespan < bound, "N={n}: slotted {} !< {bound}", t.makespan);
        }
    }

    #[test]
    fn tight_cap_reports_deadlock_not_hang() {
        let (p, jobs) = bitpipe_jobs(4, 8);
        let costs = Costs::default();
        // cap 0 can never inject anything.
        assert!(slotted_order(&p, &jobs, 0, &costs).is_err());
    }
}
