//! Pipeline-parallel schedules: the paper's contribution (BitPipe) and all
//! baselines, over a shared instruction IR.
//!
//! Pipeline: `generate` (compute orders) -> `comm_pass` (P2P/collective
//! instructions) -> consumers (`validate`, `analysis`, `timeline`,
//! `crate::sim`, `crate::train`).

pub mod analysis;
pub mod asap;
pub mod comm_pass;
pub mod generate;
pub mod greedy;
pub mod ir;
pub mod lint;
pub mod slotted;
pub mod timeline;
pub mod unidir;
pub mod validate;

pub use asap::{retime, Costs, TimedOp, TimedSchedule};
pub use generate::{generate_compute, placement_for};
pub use ir::{
    CompOp, DeviceId, Instr, MicroBatch, OpKind, PipeId, Placement, Schedule, ScheduleConfig,
    ScheduleKind, StageId, SyncPolicy,
};
pub use lint::{lint, LintReport};

use anyhow::Result;
use std::fmt;

/// Severity of a [`Diagnostic`]. Ordered most-severe first, so sorting a
/// report ascending puts errors at the top.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Severity {
    /// The schedule is wrong: it deadlocks, drops data, or breaks the
    /// synchronous-training semantics. [`validate::validate`] fails on the
    /// first of these.
    Error,
    /// Legal but suspicious: the schedule completes, yet something is
    /// weaker than the family promises (a delayed eager start, ambiguous
    /// FIFO pairing, a memory ceiling exceeded).
    Warn,
    /// Facts the analyzer derived while proving the above (graph size,
    /// static memory high-water).
    Info,
}

impl Severity {
    pub fn name(self) -> &'static str {
        match self {
            Severity::Error => "error",
            Severity::Warn => "warn",
            Severity::Info => "info",
        }
    }
}

/// Anchor of a diagnostic: a concrete instruction in a device stream
/// (`device` + `index` + rendered `instr`), a device alone, or nothing
/// for schedule-level facts. Synthetic nodes (collective barriers) carry
/// a label in `instr` with no stream position.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct Site {
    pub device: Option<usize>,
    pub index: Option<usize>,
    /// Rendered instruction or synthetic-node label; empty when N/A.
    pub instr: String,
}

impl Site {
    /// Anchor at instruction `ix` of device `dev`'s stream.
    pub fn at(dev: usize, ix: usize, ins: &Instr) -> Site {
        Site { device: Some(dev), index: Some(ix), instr: ins.to_string() }
    }

    /// Anchor at a device with no specific instruction.
    pub fn device(dev: usize) -> Site {
        Site { device: Some(dev), index: None, instr: String::new() }
    }

    /// No anchor (schedule-level diagnostic).
    pub fn none() -> Site {
        Site::default()
    }
}

impl fmt::Display for Site {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match (self.device, self.index) {
            (Some(d), Some(i)) => write!(f, "d{d}#{i}")?,
            (Some(d), None) => write!(f, "d{d}")?,
            _ => {}
        }
        if !self.instr.is_empty() {
            if self.device.is_some() {
                f.write_str(" ")?;
            }
            f.write_str(&self.instr)?;
        }
        Ok(())
    }
}

/// One finding of the static analyzer / validator.
#[derive(Debug, Clone)]
pub struct Diagnostic {
    pub severity: Severity,
    /// Stable kebab-case identifier (`deadlock-cycle`,
    /// `eager-delayed-start`, ...) — what tests and tools match on.
    pub code: &'static str,
    pub message: String,
    pub site: Site,
    /// Supporting instruction chain, e.g. the shortest dependence cycle
    /// for `deadlock-cycle` or the blocking op for `eager-delayed-start`.
    pub witness: Vec<Site>,
}

impl fmt::Display for Diagnostic {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}[{}]", self.severity.name(), self.code)?;
        let anchor = self.site.to_string();
        if !anchor.is_empty() {
            write!(f, " {anchor}")?;
        }
        write!(f, ": {}", self.message)
    }
}

/// An ordered collection of diagnostics. Insertion order is preserved so
/// [`Diagnostics::first_error`] reproduces the historical fail-fast
/// `validate` behaviour; [`Diagnostics::sort_for_report`] re-orders for
/// stable presentation.
#[derive(Debug, Default)]
pub struct Diagnostics {
    items: Vec<Diagnostic>,
}

impl Diagnostics {
    pub fn new() -> Diagnostics {
        Diagnostics::default()
    }

    pub fn push(&mut self, d: Diagnostic) {
        self.items.push(d);
    }

    pub fn error(&mut self, code: &'static str, message: impl Into<String>, site: Site) {
        self.push(Diagnostic {
            severity: Severity::Error,
            code,
            message: message.into(),
            site,
            witness: Vec::new(),
        });
    }

    pub fn warn(&mut self, code: &'static str, message: impl Into<String>, site: Site) {
        self.push(Diagnostic {
            severity: Severity::Warn,
            code,
            message: message.into(),
            site,
            witness: Vec::new(),
        });
    }

    pub fn info(&mut self, code: &'static str, message: impl Into<String>, site: Site) {
        self.push(Diagnostic {
            severity: Severity::Info,
            code,
            message: message.into(),
            site,
            witness: Vec::new(),
        });
    }

    /// First `Error`-severity diagnostic in insertion order.
    pub fn first_error(&self) -> Option<&Diagnostic> {
        self.items.iter().find(|d| d.severity == Severity::Error)
    }

    /// (errors, warnings, infos).
    pub fn counts(&self) -> (usize, usize, usize) {
        let mut c = (0, 0, 0);
        for d in &self.items {
            match d.severity {
                Severity::Error => c.0 += 1,
                Severity::Warn => c.1 += 1,
                Severity::Info => c.2 += 1,
            }
        }
        c
    }

    /// Deterministic presentation order: severity, then code, then site
    /// (unanchored last), then message.
    pub fn sort_for_report(&mut self) {
        self.items.sort_by(|a, b| {
            let ka = (a.severity, a.code, a.site.device.unwrap_or(usize::MAX),
                      a.site.index.unwrap_or(usize::MAX));
            let kb = (b.severity, b.code, b.site.device.unwrap_or(usize::MAX),
                      b.site.index.unwrap_or(usize::MAX));
            ka.cmp(&kb).then_with(|| a.message.cmp(&b.message))
        });
    }

    pub fn iter(&self) -> impl Iterator<Item = &Diagnostic> {
        self.items.iter()
    }

    pub fn into_vec(self) -> Vec<Diagnostic> {
        self.items
    }

    pub fn len(&self) -> usize {
        self.items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.items.is_empty()
    }
}

/// Escape a string for inclusion in a JSON string literal. The diagnostic
/// JSON is hand-rolled (no serde in the vendored dependency set) and must
/// render byte-identically in the Python mirror, so the escaping rules are
/// exactly: `\\`, `\"`, and `\u00XX` for control characters.
pub(crate) fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for ch in s.chars() {
        match ch {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32));
            }
            c => out.push(c),
        }
    }
    out
}

/// Full schedule build: compute order generation + communication pass.
pub fn build(cfg: &ScheduleConfig) -> Result<Schedule> {
    let costs = Costs::default();
    build_with_costs(cfg, &costs)
}

/// Full schedule build with explicit geometry costs.
pub fn build_with_costs(cfg: &ScheduleConfig, costs: &Costs) -> Result<Schedule> {
    let mut s = generate_compute(cfg, costs)?;
    comm_pass::insert_comm(&mut s)?;
    Ok(s)
}
