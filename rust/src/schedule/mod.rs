//! Pipeline-parallel schedules: the paper's contribution (BitPipe) and all
//! baselines, over a shared instruction IR.
//!
//! Pipeline: `generate` (compute orders) -> `comm_pass` (P2P/collective
//! instructions) -> consumers (`validate`, `analysis`, `timeline`,
//! `crate::sim`, `crate::train`).

pub mod analysis;
pub mod asap;
pub mod comm_pass;
pub mod generate;
pub mod greedy;
pub mod ir;
pub mod slotted;
pub mod timeline;
pub mod unidir;
pub mod validate;

pub use asap::{retime, Costs, TimedOp, TimedSchedule};
pub use generate::{generate_compute, placement_for};
pub use ir::{
    CompOp, DeviceId, Instr, MicroBatch, OpKind, PipeId, Placement, Schedule, ScheduleConfig,
    ScheduleKind, StageId, SyncPolicy,
};

use anyhow::Result;

/// Full schedule build: compute order generation + communication pass.
pub fn build(cfg: &ScheduleConfig) -> Result<Schedule> {
    let costs = Costs::default();
    build_with_costs(cfg, &costs)
}

/// Full schedule build with explicit geometry costs.
pub fn build_with_costs(cfg: &ScheduleConfig, costs: &Costs) -> Result<Schedule> {
    let mut s = generate_compute(cfg, costs)?;
    comm_pass::insert_comm(&mut s)?;
    Ok(s)
}
