//! Analytic engine: the paper's closed-form schedule properties and the
//! corresponding quantities *measured* from generated schedules.
//!
//! * **Table 2** — bubble ratio, weights memory, activations memory range;
//! * **Table 6** — P2P + collective communication overhead;
//! * **Appendix B Eq. (1)–(2)** — BitPipe's bubble count with early
//!   forwarding.
//!
//! Every closed-form has a `*_formula` function and a measured counterpart
//! extracted from a generated [`Schedule`]; the eval harness
//! (`repro eval-paper --table 2/6`) cross-checks the two.

use super::asap::{retime, Costs, TimedSchedule};
use super::comm_pass::{local_copy_counts, p2p_send_counts};
use super::ir::{Instr, OpKind, Schedule, ScheduleKind};
use anyhow::Result;

/// Closed-form bubble ratio of each approach (paper Table 2), with the
/// paper's assumption t_b = 2 t_f. `d` = pipeline devices, `n` =
/// micro-batches per iteration.
///
/// BitPipe's entry is (D-2)/(3N+D-2) for direct concatenation and
/// (D-2)/(4N+D-2) with early forwarding (Appendix B Eq. (2)).
pub fn bubble_ratio_formula(kind: ScheduleKind, d: usize, n: usize, early_forward: bool) -> f64 {
    let d = d as f64;
    let n = n as f64;
    match kind {
        ScheduleKind::GPipe | ScheduleKind::Dapple => (d - 1.0) / (n + d - 1.0),
        // 1F1B-Int with v=2: bubble shrinks by v (paper writes the v=2 case
        // as (D-1)/(2N+D-1)).
        ScheduleKind::Interleaved | ScheduleKind::VShaped => (d - 1.0) / (2.0 * n + d - 1.0),
        ScheduleKind::Chimera => (d - 2.0) / (1.5 * n + d - 2.0),
        // MixPipe sits between Chimera and BitPipe; with full injection
        // (M = D) its basic-unit geometry matches Chimera's.
        ScheduleKind::MixPipe => (d - 2.0) / (1.5 * n + d - 2.0),
        ScheduleKind::BitPipe | ScheduleKind::BitPipeNoV => {
            if early_forward {
                (d - 2.0) / (4.0 * n + d - 2.0)
            } else {
                (d - 2.0) / (3.0 * n + d - 2.0)
            }
        }
        // GEMS: at most two concurrent micro-batches; bubble ratio is high,
        // approximately (paper: "much higher than the other approaches").
        // With N micro-batches alternating over two replicas the busy
        // fraction per device is ~ (tf+tb)/(D*(tf+tb)) per micro-batch slot.
        ScheduleKind::Gems => (d - 1.0) / (n + d - 1.0), // lower bound; GEMS >= GPipe
        // ZB-H1's bubble is (D-1)(t_F + t_Bi - 2 t_W); under this repo's
        // cost geometry (t_B = 2 t_F split evenly, so t_F = t_Bi = t_W)
        // that is exactly zero. The greedy generator does not always reach
        // it, so this is a lower bound on the measured ratio.
        ScheduleKind::ZeroBubble => 0.0,
    }
}

/// Weights memory per device in units of `M_theta` (one stage's weights) —
/// paper Table 2 column 2.
pub fn weights_memory_formula(kind: ScheduleKind) -> f64 {
    if kind.bidirectional() {
        2.0
    } else {
        1.0
    }
}

/// Activation-memory range `[lo, hi]` per device in units of `M_a`
/// (one stage-micro-batch's activations) — paper Table 2 column 3.
pub fn activations_memory_formula(kind: ScheduleKind, d: usize, n: usize) -> (f64, f64) {
    let df = d as f64;
    match kind {
        ScheduleKind::GPipe => (n as f64, n as f64),
        ScheduleKind::Dapple => (1.0, df),
        ScheduleKind::Interleaved | ScheduleKind::VShaped => ((df + 1.0) / 2.0, df),
        ScheduleKind::Chimera => ((df + 2.0) / 2.0, df),
        ScheduleKind::MixPipe => ((df + 2.0) / 2.0, df),
        ScheduleKind::BitPipe | ScheduleKind::BitPipeNoV => ((df + 3.0) / 2.0, df),
        ScheduleKind::Gems => (1.0, 2.0),
        // Split backward: device i stashes up to D-i in-flight activations
        // plus deferred weight-grad pins (freed only at W); the forced
        // queue release keeps the sum at D-i+1, so the range runs from 1
        // on the last device (tight F/Bi/W rotation) to min(N, D+1) on
        // the first.
        ScheduleKind::ZeroBubble => (1.0, ((d + 1).min(n)) as f64),
    }
}

/// P2P message count per iteration (total across devices), the count Table 6
/// prices at `message_size / W_inter`. Collective gradient traffic is
/// returned separately (in units of `M_grad` transfers).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CommVolume {
    /// Number of P2P activation+gradient messages.
    pub p2p_messages: usize,
    /// Local copies (V-shape saving; zero-cost hand-offs).
    pub local_copies: usize,
    /// Gradient bytes all-reduced, in units of one full model-replica
    /// gradient (0 for unidirectional approaches, 1 for bidirectional).
    pub allreduce_grads: f64,
}

/// Closed-form Table 6 message counts.
///
/// The paper counts per-boundary traffic: DAPPLE has `2N + 2(D-1)`-ish
/// messages *on the critical path*; totals across the pipeline are
/// `2N(D-1)` for v=1 and double that for v=2 interleaving (each of the
/// `2vD-1` chunk boundaries carries N activations + N gradients, minus
/// boundaries served by local copies).
pub fn comm_volume_formula(kind: ScheduleKind, d: usize, n: usize, v: usize) -> CommVolume {
    let boundaries = |chunks: usize, colocated: usize| -> usize {
        // chunk boundaries crossing devices.
        chunks - 1 - colocated
    };
    match kind {
        // Zero-bubble is wire-identical to 1F1B: the weight-grad half of
        // the split backward stays local, so only F and Bi cross devices.
        ScheduleKind::GPipe | ScheduleKind::Dapple | ScheduleKind::ZeroBubble => CommVolume {
            p2p_messages: 2 * n * boundaries(d, 0),
            local_copies: 0,
            allreduce_grads: 0.0,
        },
        ScheduleKind::Interleaved => CommVolume {
            p2p_messages: 2 * n * boundaries(v * d, 0),
            local_copies: 0,
            allreduce_grads: 0.0,
        },
        ScheduleKind::VShaped => {
            // V-shape: v-1 turn points are co-located.
            CommVolume {
                p2p_messages: 2 * n * boundaries(v * d, v - 1),
                local_copies: 2 * n * (v - 1),
                allreduce_grads: 0.0,
            }
        }
        ScheduleKind::Gems | ScheduleKind::Chimera | ScheduleKind::MixPipe => CommVolume {
            p2p_messages: 2 * n * boundaries(d, 0),
            local_copies: 0,
            allreduce_grads: 1.0,
        },
        ScheduleKind::BitPipe | ScheduleKind::BitPipeNoV => {
            let colocated = if kind == ScheduleKind::BitPipe { v - 1 } else { 0 };
            CommVolume {
                p2p_messages: 2 * n * boundaries(v * d, colocated),
                local_copies: 2 * n * colocated,
                allreduce_grads: 1.0,
            }
        }
    }
}

/// Communication volume measured from a generated schedule.
pub fn comm_volume_measured(s: &Schedule) -> CommVolume {
    let p2p: usize = p2p_send_counts(s).iter().sum();
    let copies: usize = local_copy_counts(s).iter().sum();
    let allreduce = if s.placement.n_pipes > 1 { 1.0 } else { 0.0 };
    CommVolume { p2p_messages: p2p, local_copies: copies, allreduce_grads: allreduce }
}

/// Bubble ratio measured from re-timed geometry.
pub fn bubble_ratio_measured(s: &Schedule, costs: &Costs) -> Result<f64> {
    let t = retime(&s.compute_order, &s.placement, costs)
        .map_err(|e| anyhow::anyhow!("retime: {e}"))?;
    Ok(t.bubble_ratio())
}

/// Static liveness high-water per device, in *chunk* units, walked over
/// the full instruction streams (`device_ops`): an activation stash is
/// born at each `Forward` and freed at the matching `Backward` — or,
/// under a split backward, carried through `Bi` as a weight-grad pin and
/// freed at the matching `W`. The streams execute in order per device, so
/// the program-order walk is exact — it equals (and therefore
/// upper-bounds) the peak of any execution. Integer-exact;
/// [`peak_activation_stash`] reports the same quantity in `M_a` units
/// measured from `compute_order`, and `schedule::lint` cross-checks the
/// two.
pub fn stash_high_water_chunks(s: &Schedule) -> Vec<u64> {
    s.device_ops
        .iter()
        .map(|ops| {
            let (mut depth, mut peak) = (0i64, 0i64);
            for op in ops {
                match op {
                    Instr::Forward { .. } => depth += 1,
                    Instr::Backward { .. } | Instr::BackwardWeight { .. } => depth -= 1,
                    _ => {}
                }
                peak = peak.max(depth);
            }
            peak.max(0) as u64
        })
        .collect()
}

/// Per-device peak activation stash depth, in units of one chunk's
/// activations (M_a / v for interleaved). Converted to M_a units so
/// numbers are comparable across schedules (Table 2's unit).
pub fn peak_activation_stash(s: &Schedule) -> Vec<f64> {
    let v = s.placement.v as f64;
    s.compute_order
        .iter()
        .map(|ops| {
            let mut depth = 0i64;
            let mut peak = 0i64;
            for op in ops {
                match op.kind {
                    OpKind::Forward => depth += 1,
                    OpKind::Backward | OpKind::BackwardWeight => depth -= 1,
                    // Bi hands its stash slot to the weight-grad pin.
                    OpKind::BackwardInput => {}
                }
                peak = peak.max(depth);
            }
            peak as f64 / v
        })
        .collect()
}

/// Per-device weights memory in units of M_theta: chunks held / v.
pub fn weights_memory_measured(s: &Schedule) -> Vec<f64> {
    let v = s.placement.v as f64;
    s.placement
        .chunks_on
        .iter()
        .map(|chunks| chunks.len() as f64 / v)
        .collect()
}

/// Full analytic summary for one configuration (one Table 2 row).
#[derive(Debug, Clone)]
pub struct ScheduleReport {
    pub kind: ScheduleKind,
    pub d: usize,
    pub n: usize,
    pub v: usize,
    pub bubble_ratio_formula: f64,
    pub bubble_ratio_measured: f64,
    pub weights_mem_formula: f64,
    pub weights_mem_measured_max: f64,
    pub act_mem_formula: (f64, f64),
    pub act_mem_measured: (f64, f64),
    pub comm_formula: CommVolume,
    pub comm_measured: CommVolume,
    pub makespan: u64,
}

/// Build the report for a generated schedule.
pub fn report(s: &Schedule, costs: &Costs) -> Result<ScheduleReport> {
    let cfg = s.cfg;
    let t: TimedSchedule = retime(&s.compute_order, &s.placement, costs)
        .map_err(|e| anyhow::anyhow!("retime: {e}"))?;
    let stash = peak_activation_stash(s);
    let lo = stash.iter().cloned().fold(f64::INFINITY, f64::min);
    let hi = stash.iter().cloned().fold(0.0f64, f64::max);
    let wmem = weights_memory_measured(s);
    Ok(ScheduleReport {
        kind: cfg.kind,
        d: cfg.d,
        n: cfg.n,
        v: cfg.v,
        bubble_ratio_formula: bubble_ratio_formula(cfg.kind, cfg.d, cfg.n, cfg.early_forward),
        bubble_ratio_measured: t.bubble_ratio(),
        weights_mem_formula: weights_memory_formula(cfg.kind),
        weights_mem_measured_max: wmem.iter().cloned().fold(0.0, f64::max),
        act_mem_formula: activations_memory_formula(cfg.kind, cfg.d, cfg.n),
        act_mem_measured: (lo, hi),
        comm_formula: comm_volume_formula(cfg.kind, cfg.d, cfg.n, cfg.v),
        comm_measured: comm_volume_measured(s),
        makespan: t.makespan,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::schedule::ir::ScheduleConfig;
    use crate::schedule::build;

    fn rpt(kind: ScheduleKind, d: usize, n: usize) -> ScheduleReport {
        let cfg = ScheduleConfig::new(kind, d, n);
        let s = build(&cfg).unwrap();
        report(&s, &Costs::default()).unwrap()
    }

    #[test]
    fn table2_bubble_formulas() {
        // Spot values straight from the paper's Table 2 at D=8, N=8.
        let (d, n) = (8, 8);
        assert!((bubble_ratio_formula(ScheduleKind::Dapple, d, n, true) - 7.0 / 15.0).abs() < 1e-12);
        assert!(
            (bubble_ratio_formula(ScheduleKind::Interleaved, d, n, true) - 7.0 / 23.0).abs()
                < 1e-12
        );
        assert!(
            (bubble_ratio_formula(ScheduleKind::Chimera, d, n, true) - 6.0 / 18.0).abs() < 1e-12
        );
        assert!(
            (bubble_ratio_formula(ScheduleKind::BitPipe, d, n, false) - 6.0 / 30.0).abs() < 1e-12
        );
        assert!(
            (bubble_ratio_formula(ScheduleKind::BitPipe, d, n, true) - 6.0 / 38.0).abs() < 1e-12
        );
    }

    #[test]
    fn bitpipe_has_lowest_formula_bubble() {
        for d in [4usize, 8, 16] {
            for n in [d, 2 * d, 4 * d] {
                let bit = bubble_ratio_formula(ScheduleKind::BitPipe, d, n, true);
                for kind in [
                    ScheduleKind::GPipe,
                    ScheduleKind::Dapple,
                    ScheduleKind::Interleaved,
                    ScheduleKind::Chimera,
                    ScheduleKind::MixPipe,
                ] {
                    assert!(
                        bit < bubble_ratio_formula(kind, d, n, true) + 1e-12,
                        "D={d} N={n}: BitPipe not lowest vs {kind}"
                    );
                }
            }
        }
    }

    #[test]
    fn measured_matches_formula_unidirectional() {
        // GPipe / DAPPLE measured bubble ratio equals (D-1)/(N+D-1) exactly
        // under tb=2tf geometry.
        for (kind, d, n) in [
            (ScheduleKind::GPipe, 4, 4),
            (ScheduleKind::GPipe, 4, 8),
            (ScheduleKind::Dapple, 4, 8),
            (ScheduleKind::Dapple, 8, 8),
        ] {
            let r = rpt(kind, d, n);
            assert!(
                (r.bubble_ratio_formula - r.bubble_ratio_measured).abs() < 1e-9,
                "{kind} D={d} N={n}: formula {} vs measured {}",
                r.bubble_ratio_formula,
                r.bubble_ratio_measured
            );
        }
    }

    #[test]
    fn measured_matches_formula_interleaved() {
        for (d, n) in [(4usize, 4usize), (4, 8), (8, 8)] {
            let r = rpt(ScheduleKind::Interleaved, d, n);
            assert!(
                (r.bubble_ratio_formula - r.bubble_ratio_measured).abs() < 1e-9,
                "1F1B-Int D={d} N={n}: {} vs {}",
                r.bubble_ratio_formula,
                r.bubble_ratio_measured
            );
        }
    }

    #[test]
    fn bitpipe_measured_basic_unit() {
        // N=D: direct basic unit has (D-2) tf-ticks of bubble per device
        // => ratio (D-2)/(3N + D-2). Exact at D=4 (the published figure);
        // within 0.02 absolute for larger D (generator tolerance).
        for d in [4usize, 8] {
            let r = rpt(ScheduleKind::BitPipe, d, d);
            let want = (d as f64 - 2.0) / (3.0 * d as f64 + d as f64 - 2.0);
            let tol = if d == 4 { 1e-9 } else { 0.02 };
            assert!(
                (r.bubble_ratio_measured - want).abs() < tol,
                "D={d}: measured {} want {want}",
                r.bubble_ratio_measured
            );
        }
    }

    #[test]
    fn comm_formula_matches_measured() {
        for kind in ScheduleKind::ALL {
            if kind == ScheduleKind::MixPipe || kind == ScheduleKind::Gems {
                continue; // injection-regulated variants counted below
            }
            let r = rpt(kind, 4, 8);
            assert_eq!(
                r.comm_formula.p2p_messages, r.comm_measured.p2p_messages,
                "{kind}: p2p formula vs measured"
            );
            assert_eq!(
                r.comm_formula.local_copies, r.comm_measured.local_copies,
                "{kind}: local copies"
            );
        }
    }

    #[test]
    fn weights_memory_measured_matches_table2() {
        for kind in ScheduleKind::ALL {
            let r = rpt(kind, 4, 4);
            assert!(
                (r.weights_mem_formula - r.weights_mem_measured_max).abs() < 1e-9,
                "{kind}: weights mem {} vs {}",
                r.weights_mem_formula,
                r.weights_mem_measured_max
            );
        }
    }

    #[test]
    fn bitpipe_activation_balance_narrower_than_dapple() {
        // Fig 8 claim: BitPipe's per-device activation footprint spread is
        // narrower than DAPPLE's.
        let bit = rpt(ScheduleKind::BitPipe, 8, 8);
        let dap = rpt(ScheduleKind::Dapple, 8, 8);
        let spread = |r: &ScheduleReport| r.act_mem_measured.1 - r.act_mem_measured.0;
        assert!(
            spread(&bit) < spread(&dap),
            "BitPipe spread {} !< DAPPLE spread {}",
            spread(&bit),
            spread(&dap)
        );
    }

    #[test]
    fn gems_memory_lowest() {
        let gems = rpt(ScheduleKind::Gems, 4, 8);
        assert!(gems.act_mem_measured.1 <= 2.0, "GEMS peak stash {}", gems.act_mem_measured.1);
    }
}
