//! Timeline rendering: ASCII pipeline diagrams (the paper's Figs 1, 2, 3,
//! 13) and CSV dumps for external plotting.
//!
//! One character column = one tick of the [`Costs`] geometry; each device
//! is one row. Forwards print the micro-batch id (down pipe) or letter
//! (up pipe, mirroring the paper's black/white text distinction), backwards
//! print the id in brackets-free lowercase-hex-style but twice as wide
//! (t_b = 2 t_f).

use super::asap::{retime, Costs, TimedSchedule};
use super::ir::{OpKind, Schedule};
use anyhow::Result;
use std::fmt::Write as _;

/// Render options.
#[derive(Debug, Clone, Copy)]
pub struct RenderOpts {
    /// Ticks per character column (compresses long schedules).
    pub ticks_per_col: u64,
    /// Show chunk (stage) id instead of micro-batch id.
    pub show_stage: bool,
}

impl Default for RenderOpts {
    fn default() -> Self {
        RenderOpts { ticks_per_col: 1, show_stage: false }
    }
}

/// Character for an op cell. Down pipe: digits/uppercase; up pipe:
/// lowercase letters. Forward cells use the plain symbol, backward cells
/// the same symbol (the doubled width already distinguishes them visually);
/// second chunk (odd rounds) renders in a distinct alphabet when
/// `show_stage` is off, mirroring the paper's dark/light shading.
fn cell_symbol(pipe: usize, stage: usize, mb: usize, d: usize, show_stage: bool) -> char {
    let idx = if show_stage { stage } else { mb };
    let second_chunk = (stage / d) % 2 == 1;
    match (pipe, second_chunk) {
        (0, false) => char::from_digit((idx % 10) as u32, 10).unwrap(),
        (0, true) => (b'A' + (idx % 26) as u8) as char,
        (1, false) => (b'a' + (idx % 26) as u8) as char,
        (1, true) => {
            const SYM: &[u8] = b"!@#$%^&*()+=~<>?/|{}[]";
            SYM[idx % SYM.len()] as char
        }
        _ => '?',
    }
}

/// Render a timed schedule as an ASCII grid.
pub fn render_timed(t: &TimedSchedule, d_hint: usize, opts: &RenderOpts) -> String {
    let cols = (t.makespan + opts.ticks_per_col - 1) / opts.ticks_per_col;
    let mut out = String::new();
    for (dev, ops) in t.devices.iter().enumerate() {
        let mut row = vec!['.'; cols as usize];
        for top in ops {
            let c = cell_symbol(top.op.pipe, top.op.stage, top.op.mb, d_hint, opts.show_stage);
            let c0 = top.start / opts.ticks_per_col;
            let c1 = ((top.end + opts.ticks_per_col - 1) / opts.ticks_per_col).min(cols);
            for col in c0..c1 {
                row[col as usize] = c;
            }
        }
        let _ = writeln!(out, "P{:<2} {}", dev + 1, row.iter().collect::<String>());
    }
    let _ = writeln!(out, "    makespan={} ticks, bubble_ratio={:.4}", t.makespan, t.bubble_ratio());
    out
}

/// Render a schedule (re-times internally).
pub fn render(s: &Schedule, costs: &Costs, opts: &RenderOpts) -> Result<String> {
    let t = retime(&s.compute_order, &s.placement, costs)
        .map_err(|e| anyhow::anyhow!("retime: {e}"))?;
    let mut header = format!(
        "{} D={} N={} v={} ({})\n",
        s.cfg.kind,
        s.cfg.d,
        s.cfg.n,
        s.cfg.v,
        if s.placement.n_pipes == 2 { "bidirectional" } else { "unidirectional" }
    );
    header.push_str(&render_timed(&t, s.cfg.d, opts));
    Ok(header)
}

/// CSV dump: one row per op — device,start,end,kind,pipe,stage,mb.
pub fn to_csv(s: &Schedule, costs: &Costs) -> Result<String> {
    let t = retime(&s.compute_order, &s.placement, costs)
        .map_err(|e| anyhow::anyhow!("retime: {e}"))?;
    let mut out = String::from("device,start,end,kind,pipe,stage,mb\n");
    for (dev, ops) in t.devices.iter().enumerate() {
        for top in ops {
            let k = match top.op.kind {
                OpKind::Forward => "F",
                OpKind::Backward => "B",
                OpKind::BackwardInput => "Bi",
                OpKind::BackwardWeight => "W",
            };
            let _ = writeln!(
                out,
                "{},{},{},{},{},{},{}",
                dev, top.start, top.end, k, top.op.pipe, top.op.stage, top.op.mb
            );
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::schedule::ir::{ScheduleConfig, ScheduleKind};
    use crate::schedule::build;

    #[test]
    fn render_has_one_row_per_device() {
        let s = build(&ScheduleConfig::new(ScheduleKind::BitPipe, 4, 4)).unwrap();
        let txt = render(&s, &Costs::default(), &RenderOpts::default()).unwrap();
        let rows = txt.lines().filter(|l| l.starts_with('P')).count();
        assert_eq!(rows, 4);
    }

    #[test]
    fn render_width_matches_makespan() {
        let s = build(&ScheduleConfig::new(ScheduleKind::Dapple, 4, 4)).unwrap();
        let costs = Costs::default();
        let t = retime(&s.compute_order, &s.placement, &costs).unwrap();
        let txt = render_timed(&t, 4, &RenderOpts::default());
        let first = txt.lines().next().unwrap();
        // "Pn  " prefix is 4 chars.
        assert_eq!(first.len() as u64 - 4, t.makespan);
    }

    #[test]
    fn compression_shrinks_output() {
        let s = build(&ScheduleConfig::new(ScheduleKind::GPipe, 4, 8)).unwrap();
        let costs = Costs::default();
        let full = render(&s, &costs, &RenderOpts::default()).unwrap();
        let half = render(&s, &costs, &RenderOpts { ticks_per_col: 6, show_stage: false }).unwrap();
        assert!(half.len() < full.len());
    }

    #[test]
    fn csv_row_count() {
        let s = build(&ScheduleConfig::new(ScheduleKind::Chimera, 4, 4)).unwrap();
        let csv = to_csv(&s, &Costs::default()).unwrap();
        // header + 2 ops per (stage, mb): D stages * N mbs * 2.
        assert_eq!(csv.lines().count(), 1 + 2 * 4 * 4);
    }

    #[test]
    fn bidirectional_renders_both_alphabets() {
        let s = build(&ScheduleConfig::new(ScheduleKind::BitPipe, 4, 4)).unwrap();
        let txt = render(&s, &Costs::default(), &RenderOpts::default()).unwrap();
        let grid: String = txt.lines().filter(|l| l.starts_with('P')).map(|l| &l[4..]).collect();
        assert!(grid.contains('0'), "down-pipe digits missing");
        assert!(grid.chars().any(|c| c.is_ascii_lowercase()), "up-pipe letters missing");
    }
}
