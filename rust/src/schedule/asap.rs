//! ASAP (as-soon-as-possible) re-timing of a per-device compute order.
//!
//! Given each device's *order* of compute ops, dependency edges between ops
//! (pipeline dataflow), and per-op costs, this computes the earliest start
//! time of every op. The resulting timed schedule is the geometric ground
//! truth used by the analysis engine (bubble ratios, Table 2), the timeline
//! renderer (Figs 1–3, 13), and as the skeleton the simulator refines with
//! a cluster cost model.
//!
//! Costs are expressed in integer *ticks*. A full (non-interleaved) stage
//! forward is [`Costs::f_full`] ticks; a chunk in a `v`-way interleaved
//! schedule costs `f_full / v` (the paper's premise that splitting a stage
//! into `v` chunks divides the per-op time by `v`). Backward cost is
//! `b_num/b_den` times forward (paper assumes 2×).

use super::ir::{CompOp, OpKind, Placement};
use std::collections::HashMap;

/// Integer tick cost model for schedule geometry.
#[derive(Debug, Clone, Copy)]
pub struct Costs {
    /// Ticks for a full-stage forward (must be divisible by every `v` used;
    /// 12 covers v ∈ {1,2,3,4,6,12}).
    pub f_full: u64,
    /// Backward/forward cost ratio, as a fraction `b_num / b_den`.
    pub b_num: u64,
    pub b_den: u64,
    /// Extra latency (ticks) on cross-device dependency edges; 0 for pure
    /// geometry (the paper's schedule diagrams ignore P2P latency).
    pub comm_lat: u64,
}

impl Default for Costs {
    fn default() -> Self {
        Costs { f_full: 12, b_num: 2, b_den: 1, comm_lat: 0 }
    }
}

impl Costs {
    pub fn chunk_f(&self, v: usize) -> u64 {
        assert!(
            self.f_full % v as u64 == 0,
            "f_full={} not divisible by v={v}",
            self.f_full
        );
        self.f_full / v as u64
    }

    pub fn chunk_b(&self, v: usize) -> u64 {
        self.chunk_f(v) * self.b_num / self.b_den
    }

    /// Activation-grad half of a split backward: half the fused backward
    /// (with the default 2x ratio, `Bi` == one forward).
    pub fn chunk_bi(&self, v: usize) -> u64 {
        self.chunk_b(v) / 2
    }

    /// Weight-grad half: the remainder, so `Bi + W == B` exactly even for
    /// odd tick counts.
    pub fn chunk_w(&self, v: usize) -> u64 {
        self.chunk_b(v) - self.chunk_bi(v)
    }

    pub fn of(&self, op: &CompOp, v: usize) -> u64 {
        match op.kind {
            OpKind::Forward => self.chunk_f(v),
            OpKind::Backward => self.chunk_b(v),
            OpKind::BackwardInput => self.chunk_bi(v),
            OpKind::BackwardWeight => self.chunk_w(v),
        }
    }
}

/// A compute op with its assigned time interval `[start, end)`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TimedOp {
    pub op: CompOp,
    pub start: u64,
    pub end: u64,
}

/// Result of ASAP re-timing: per-device timelines (in device-order).
#[derive(Debug, Clone)]
pub struct TimedSchedule {
    pub devices: Vec<Vec<TimedOp>>,
    pub makespan: u64,
}

impl TimedSchedule {
    /// Busy ticks per device.
    pub fn busy(&self) -> Vec<u64> {
        self.devices
            .iter()
            .map(|ops| ops.iter().map(|t| t.end - t.start).sum())
            .collect()
    }

    /// Idle (bubble) ticks per device over the full iteration `[0, makespan)`.
    pub fn bubbles(&self) -> Vec<u64> {
        self.busy().iter().map(|b| self.makespan - b).collect()
    }

    /// Paper's bubble ratio: total bubble / (D * makespan), equivalently
    /// mean over devices of idle share.
    pub fn bubble_ratio(&self) -> f64 {
        if self.makespan == 0 || self.devices.is_empty() {
            return 0.0;
        }
        let total_bubble: u64 = self.bubbles().iter().sum();
        total_bubble as f64 / (self.makespan as f64 * self.devices.len() as f64)
    }

    /// End time of a specific op (None if absent).
    pub fn end_of(&self, op: &CompOp) -> Option<u64> {
        for dev in &self.devices {
            for t in dev {
                if &t.op == op {
                    return Some(t.end);
                }
            }
        }
        None
    }
}

/// Dataflow dependencies of a compute op within its pipeline replica.
///
/// * `F(p,s,m)` for `s>0` depends on `F(p,s-1,m)`;
/// * `B(p,S-1,m)` depends on `F(p,S-1,m)` (loss is computed at the last
///   stage — its stash is the forward input);
/// * `B(p,s,m)` for `s<S-1` depends on `B(p,s+1,m)` *and* `F(p,s,m)`;
/// * split backward: `Bi(p,s,m)` depends on `F(p,s,m)` and (for `s<S-1`)
///   `Bi(p,s+1,m)` — the activation-grad chain is the critical path — and
///   `W(p,s,m)` depends only on its own `Bi(p,s,m)` (weight-grad work is
///   free to defer).
pub fn deps_of(op: &CompOp, n_stages: usize) -> Vec<CompOp> {
    let mut d = Vec::with_capacity(2);
    match op.kind {
        OpKind::Forward => {
            if op.stage > 0 {
                d.push(CompOp::fwd(op.pipe, op.stage - 1, op.mb));
            }
        }
        OpKind::Backward => {
            d.push(CompOp::fwd(op.pipe, op.stage, op.mb));
            if op.stage + 1 < n_stages {
                d.push(CompOp::bwd(op.pipe, op.stage + 1, op.mb));
            }
        }
        OpKind::BackwardInput => {
            d.push(CompOp::fwd(op.pipe, op.stage, op.mb));
            if op.stage + 1 < n_stages {
                d.push(CompOp::bwd_input(op.pipe, op.stage + 1, op.mb));
            }
        }
        OpKind::BackwardWeight => {
            d.push(CompOp::bwd_input(op.pipe, op.stage, op.mb));
        }
    }
    d
}

/// Errors from re-timing.
#[derive(Debug)]
pub enum AsapError {
    /// No device can progress; the payload lists the stuck ops.
    Deadlock(String),
    /// An op appears on a device other than its placement.
    Misplaced(CompOp, usize, usize),
}

impl std::fmt::Display for AsapError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            AsapError::Deadlock(stuck) => {
                write!(f, "schedule deadlock: no device can progress; stuck ops: {stuck}")
            }
            AsapError::Misplaced(op, dev, want) => {
                write!(f, "op {op} appears on device {dev} but is placed on device {want}")
            }
        }
    }
}

impl std::error::Error for AsapError {}

/// Compute earliest start times for `order` (per-device op sequences),
/// respecting both per-device serialization and cross-op dataflow.
///
/// Returns an error if the per-device orders are inconsistent with the
/// dataflow (deadlock) or an op sits on the wrong device.
pub fn retime(
    order: &[Vec<CompOp>],
    placement: &Placement,
    costs: &Costs,
) -> Result<TimedSchedule, AsapError> {
    let n_stages = placement.n_stages();
    let v = placement.v;
    let n_dev = order.len();

    // Validate placement once up front.
    for (dev, ops) in order.iter().enumerate() {
        for op in ops {
            let want = placement.device(op.pipe, op.stage);
            if want != dev {
                return Err(AsapError::Misplaced(*op, dev, want));
            }
        }
    }

    let total: usize = order.iter().map(|o| o.len()).sum();
    let mut done: HashMap<CompOp, u64> = HashMap::with_capacity(total);
    let mut cursor = vec![0usize; n_dev];
    let mut avail = vec![0u64; n_dev];
    let mut out: Vec<Vec<TimedOp>> = vec![Vec::new(); n_dev];
    let mut scheduled = 0usize;

    while scheduled < total {
        let mut progressed = false;
        for dev in 0..n_dev {
            // Drain every currently-executable op on this device before
            // moving on; a single sweep per outer loop is also correct but
            // this is faster.
            while cursor[dev] < order[dev].len() {
                let op = order[dev][cursor[dev]];
                let deps = deps_of(&op, n_stages);
                let mut ready_at = avail[dev];
                let mut ok = true;
                for dep in &deps {
                    match done.get(dep) {
                        Some(&end) => {
                            let lat = if placement.device(dep.pipe, dep.stage)
                                != placement.device(op.pipe, op.stage)
                            {
                                costs.comm_lat
                            } else {
                                0
                            };
                            ready_at = ready_at.max(end + lat);
                        }
                        None => {
                            ok = false;
                            break;
                        }
                    }
                }
                if !ok {
                    break;
                }
                let dur = costs.of(&op, v);
                let end = ready_at + dur;
                out[dev].push(TimedOp { op, start: ready_at, end });
                done.insert(op, end);
                avail[dev] = end;
                cursor[dev] += 1;
                scheduled += 1;
                progressed = true;
            }
        }
        if !progressed {
            let stuck: Vec<String> = (0..n_dev)
                .filter(|&d| cursor[d] < order[d].len())
                .map(|d| format!("d{}:{}", d, order[d][cursor[d]]))
                .collect();
            return Err(AsapError::Deadlock(stuck.join(", ")));
        }
    }

    let makespan = out
        .iter()
        .flat_map(|ops| ops.iter().map(|t| t.end))
        .max()
        .unwrap_or(0);
    Ok(TimedSchedule { devices: out, makespan })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::schedule::ir::Placement;

    fn chain_placement(d: usize) -> Placement {
        Placement::from_fn(d, 1, 1, |_p, s| s)
    }

    #[test]
    fn costs_chunking() {
        let c = Costs::default();
        assert_eq!(c.chunk_f(1), 12);
        assert_eq!(c.chunk_f(2), 6);
        assert_eq!(c.chunk_b(2), 12);
        assert_eq!(c.chunk_b(3), 8);
    }

    #[test]
    fn two_device_single_mb() {
        // F(s0)@d0, F(s1)@d1, B(s1)@d1, B(s0)@d0 — pure chain.
        let p = chain_placement(2);
        let order = vec![
            vec![CompOp::fwd(0, 0, 0), CompOp::bwd(0, 0, 0)],
            vec![CompOp::fwd(0, 1, 0), CompOp::bwd(0, 1, 0)],
        ];
        let t = retime(&order, &p, &Costs::default()).unwrap();
        // 12 + 12 + 24 + 24 = 72 makespan.
        assert_eq!(t.makespan, 72);
        assert_eq!(t.devices[0][0].start, 0);
        assert_eq!(t.devices[1][0].start, 12);
        assert_eq!(t.devices[1][1].start, 24);
        assert_eq!(t.devices[0][1].start, 48);
    }

    #[test]
    fn deadlock_detected() {
        // Device 0 wants B before its F dependency chain can complete:
        // B(s0) placed before F(s0) on the same device.
        let p = chain_placement(1);
        let order = vec![vec![CompOp::bwd(0, 0, 0), CompOp::fwd(0, 0, 0)]];
        assert!(matches!(
            retime(&order, &p, &Costs::default()),
            Err(AsapError::Deadlock(_))
        ));
    }

    #[test]
    fn misplaced_detected() {
        let p = chain_placement(2);
        let order = vec![vec![CompOp::fwd(0, 1, 0)], vec![]];
        assert!(matches!(
            retime(&order, &p, &Costs::default()),
            Err(AsapError::Misplaced(..))
        ));
    }

    #[test]
    fn comm_latency_shifts_downstream() {
        let p = chain_placement(2);
        let order = vec![vec![CompOp::fwd(0, 0, 0)], vec![CompOp::fwd(0, 1, 0)]];
        let mut c = Costs::default();
        c.comm_lat = 5;
        let t = retime(&order, &p, &c).unwrap();
        assert_eq!(t.devices[1][0].start, 17); // 12 + 5
    }

    #[test]
    fn bubble_accounting() {
        let p = chain_placement(2);
        let order = vec![
            vec![CompOp::fwd(0, 0, 0), CompOp::bwd(0, 0, 0)],
            vec![CompOp::fwd(0, 1, 0), CompOp::bwd(0, 1, 0)],
        ];
        let t = retime(&order, &p, &Costs::default()).unwrap();
        let busy = t.busy();
        assert_eq!(busy, vec![36, 36]);
        assert_eq!(t.bubbles(), vec![36, 36]);
        assert!((t.bubble_ratio() - 0.5).abs() < 1e-9);
    }
}
