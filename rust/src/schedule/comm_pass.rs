//! Communication insertion pass.
//!
//! Walks each device's compute order and inserts:
//!
//! * **P2P activation / gradient transfers** between consecutive stages on
//!   different devices (`SendAct`/`RecvAct`, `SendGrad`/`RecvGrad`);
//! * **local copies** when producer and consumer chunks are co-located —
//!   the V-shaped schedule's communication saving (paper Fig 4);
//! * **gradient all-reduce + optimizer** ops per model stage, either
//!   *eagerly* (right after the last local backward touching the stage —
//!   paper Fig 5b) or *lazily* (all at the end of local compute — Fig 5a,
//!   the `w/o E` ablation).

use super::ir::{CompOp, Instr, OpKind, Schedule, StageId, SyncPolicy};
use anyhow::{ensure, Result};
use std::collections::HashMap;

/// Insert communication/collective/optimizer instructions into
/// `schedule.device_ops`, consuming `compute_order` as the skeleton.
pub fn insert_comm(schedule: &mut Schedule) -> Result<()> {
    let placement = &schedule.placement;
    let n_stages = placement.n_stages();
    let d = placement.d;

    // Last backward index per (device, model stage) for eager sync
    // placement. With a split backward the weight grad only exists after
    // `W`, so `BackwardWeight` (not `BackwardInput`) is the stage's last
    // gradient-producing op.
    let mut last_bwd: HashMap<(usize, StageId), usize> = HashMap::new();
    for dev in 0..d {
        for (i, op) in schedule.compute_order[dev].iter().enumerate() {
            if matches!(op.kind, OpKind::Backward | OpKind::BackwardWeight) {
                last_bwd.insert((dev, op.stage), i);
            }
        }
    }

    let mut device_ops: Vec<Vec<Instr>> = Vec::with_capacity(d);
    for dev in 0..d {
        let comp = &schedule.compute_order[dev];
        let mut ops: Vec<Instr> = Vec::with_capacity(comp.len() * 3);
        // Stages whose eager all-reduce should fire after compute index i.
        let mut eager_at: HashMap<usize, Vec<StageId>> = HashMap::new();
        if schedule.cfg.sync == SyncPolicy::Eager {
            for (&(dv, stage), &i) in &last_bwd {
                if dv == dev {
                    eager_at.entry(i).or_default().push(stage);
                }
            }
        }
        for (i, op) in comp.iter().enumerate() {
            emit_pre(op, dev, n_stages, placement, &mut ops);
            ops.push(match op.kind {
                OpKind::Forward => Instr::Forward { pipe: op.pipe, stage: op.stage, mb: op.mb },
                OpKind::Backward => Instr::Backward { pipe: op.pipe, stage: op.stage, mb: op.mb },
                OpKind::BackwardInput => {
                    Instr::BackwardInput { pipe: op.pipe, stage: op.stage, mb: op.mb }
                }
                OpKind::BackwardWeight => {
                    Instr::BackwardWeight { pipe: op.pipe, stage: op.stage, mb: op.mb }
                }
            });
            emit_post(op, dev, n_stages, placement, &mut ops);
            if let Some(stages) = eager_at.get(&i) {
                let mut stages = stages.clone();
                stages.sort_unstable();
                for s in stages {
                    ops.push(Instr::AllReduceStart { stage: s });
                }
            }
        }
        // Held model stages, ascending.
        let mut held: Vec<StageId> = placement.chunks_on[dev].iter().map(|&(_, s)| s).collect();
        held.sort_unstable();
        held.dedup();
        if schedule.cfg.sync == SyncPolicy::Lazy {
            for &s in &held {
                ops.push(Instr::AllReduceStart { stage: s });
            }
        }
        for &s in &held {
            ops.push(Instr::AllReduceWait { stage: s });
            ops.push(Instr::OptimStep { stage: s });
        }
        device_ops.push(ops);
    }

    // Each held stage must have had at least one backward locally (otherwise
    // the device would all-reduce garbage).
    for dev in 0..d {
        for &(_, s) in &placement.chunks_on[dev] {
            ensure!(
                last_bwd.contains_key(&(dev, s)),
                "device {dev} holds stage {s} but never runs its backward"
            );
        }
    }

    schedule.device_ops = device_ops;
    Ok(())
}

/// Instructions required *before* a compute op: receive or locally copy its
/// input.
fn emit_pre(
    op: &CompOp,
    dev: usize,
    n_stages: usize,
    placement: &super::ir::Placement,
    ops: &mut Vec<Instr>,
) {
    match op.kind {
        OpKind::Forward => {
            if op.stage > 0 {
                let src = placement.device(op.pipe, op.stage - 1);
                if src != dev {
                    ops.push(Instr::RecvAct { from: src, pipe: op.pipe, stage: op.stage, mb: op.mb });
                } else {
                    ops.push(Instr::LocalCopyAct { pipe: op.pipe, stage: op.stage - 1, mb: op.mb });
                }
            }
        }
        // BackwardInput consumes the upstream gradient exactly like a fused
        // backward; BackwardWeight needs no input beyond its own Bi's pin.
        OpKind::Backward | OpKind::BackwardInput => {
            if op.stage + 1 < n_stages {
                let src = placement.device(op.pipe, op.stage + 1);
                if src != dev {
                    ops.push(Instr::RecvGrad { from: src, pipe: op.pipe, stage: op.stage, mb: op.mb });
                } else {
                    ops.push(Instr::LocalCopyGrad { pipe: op.pipe, stage: op.stage + 1, mb: op.mb });
                }
            }
        }
        OpKind::BackwardWeight => {}
    }
}

/// Instructions required *after* a compute op: send its output onward (only
/// when the consumer lives elsewhere; co-located consumers take the local
/// copy emitted on their side).
fn emit_post(
    op: &CompOp,
    dev: usize,
    n_stages: usize,
    placement: &super::ir::Placement,
    ops: &mut Vec<Instr>,
) {
    match op.kind {
        OpKind::Forward => {
            if op.stage + 1 < n_stages {
                let dst = placement.device(op.pipe, op.stage + 1);
                if dst != dev {
                    ops.push(Instr::SendAct { to: dst, pipe: op.pipe, stage: op.stage, mb: op.mb });
                }
            }
        }
        // The activation grad the upstream stage needs is produced by Bi
        // (split) or the fused backward; W produces nothing to send.
        OpKind::Backward | OpKind::BackwardInput => {
            if op.stage > 0 {
                let dst = placement.device(op.pipe, op.stage - 1);
                if dst != dev {
                    ops.push(Instr::SendGrad { to: dst, pipe: op.pipe, stage: op.stage, mb: op.mb });
                }
            }
        }
        OpKind::BackwardWeight => {}
    }
}

/// Count P2P messages sent per device (activations + gradients) — the
/// quantity Table 6 prices at `message_size / W_inter`.
pub fn p2p_send_counts(schedule: &Schedule) -> Vec<usize> {
    schedule
        .device_ops
        .iter()
        .map(|ops| {
            ops.iter()
                .filter(|i| matches!(i, Instr::SendAct { .. } | Instr::SendGrad { .. }))
                .count()
        })
        .collect()
}

/// Count local copies per device (the V-shape saving).
pub fn local_copy_counts(schedule: &Schedule) -> Vec<usize> {
    schedule
        .device_ops
        .iter()
        .map(|ops| {
            ops.iter()
                .filter(|i| matches!(i, Instr::LocalCopyAct { .. } | Instr::LocalCopyGrad { .. }))
                .count()
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::schedule::ir::{ScheduleConfig, ScheduleKind};
    use crate::schedule::{build, build_with_costs, Costs};

    #[test]
    fn v_shape_turns_comm_into_local_copies() {
        // Same compute geometry, different placement: the V-shaped schedule
        // must strictly reduce P2P sends vs the looping 1F1B-Int.
        let loops = build(&ScheduleConfig::new(ScheduleKind::Interleaved, 4, 4)).unwrap();
        let vshape = build(&ScheduleConfig::new(ScheduleKind::VShaped, 4, 4)).unwrap();
        let loop_sends: usize = p2p_send_counts(&loops).iter().sum();
        let v_sends: usize = p2p_send_counts(&vshape).iter().sum();
        let v_copies: usize = local_copy_counts(&vshape).iter().sum();
        assert!(v_sends < loop_sends, "V-shape did not reduce P2P ({v_sends} vs {loop_sends})");
        assert!(v_copies > 0);
        // The turn device hosts stage D-1 -> D hand-off: 1 fwd + 1 bwd copy
        // per micro-batch at each of the v-1 turns.
        assert_eq!(loop_sends - v_sends, v_copies);
    }

    #[test]
    fn dapple_send_counts_match_table6() {
        // DAPPLE: (2N + 2(D-1)) messages total... the paper counts per
        // *pipeline*: each of the D-1 boundaries carries N activations and
        // N gradients => 2N(D-1) sends in total.
        let d = 4;
        let n = 8;
        let s = build(&ScheduleConfig::new(ScheduleKind::Dapple, d, n)).unwrap();
        let sends: usize = p2p_send_counts(&s).iter().sum();
        assert_eq!(sends, 2 * n * (d - 1));
    }

    #[test]
    fn interleaved_doubles_p2p() {
        let d = 4;
        let n = 8;
        let s1 = build(&ScheduleConfig::new(ScheduleKind::Dapple, d, n)).unwrap();
        let s2 = build(&ScheduleConfig::new(ScheduleKind::Interleaved, d, n)).unwrap();
        let c1: usize = p2p_send_counts(&s1).iter().sum();
        let c2: usize = p2p_send_counts(&s2).iter().sum();
        // v=2 looping: 2vD-1 boundaries - none co-located => (2vD-... ) just
        // assert the paper's qualitative claim: about double.
        assert_eq!(c2, 2 * n * (2 * d - 1), "looping v=2 has 2vD-1 cross-device boundaries");
        assert!(c2 > 2 * c1, "interleaving should at least double P2P traffic");
    }

    #[test]
    fn every_held_stage_gets_allreduce_and_optim() {
        let s = build(&ScheduleConfig::new(ScheduleKind::BitPipe, 4, 4)).unwrap();
        for dev in 0..4 {
            let mut held: Vec<usize> =
                s.placement.chunks_on[dev].iter().map(|&(_, st)| st).collect();
            held.sort_unstable();
            for st in held {
                let starts = s.device_ops[dev]
                    .iter()
                    .filter(|i| matches!(i, Instr::AllReduceStart { stage } if *stage == st))
                    .count();
                let waits = s.device_ops[dev]
                    .iter()
                    .filter(|i| matches!(i, Instr::AllReduceWait { stage } if *stage == st))
                    .count();
                let optims = s.device_ops[dev]
                    .iter()
                    .filter(|i| matches!(i, Instr::OptimStep { stage } if *stage == st))
                    .count();
                assert_eq!((starts, waits, optims), (1, 1, 1), "dev {dev} stage {st}");
            }
        }
    }

    #[test]
    fn eager_sync_starts_before_lazy() {
        use crate::schedule::ir::SyncPolicy;
        let costs = Costs::default();
        let eager = build_with_costs(
            &ScheduleConfig::new(ScheduleKind::BitPipe, 4, 4).with_sync(SyncPolicy::Eager),
            &costs,
        )
        .unwrap();
        let lazy = build_with_costs(
            &ScheduleConfig::new(ScheduleKind::BitPipe, 4, 4).with_sync(SyncPolicy::Lazy),
            &costs,
        )
        .unwrap();
        // In the eager stream at least one AllReduceStart precedes some
        // compute op; in lazy none do.
        let first_ar = |ops: &[Instr]| {
            ops.iter().position(|i| matches!(i, Instr::AllReduceStart { .. })).unwrap()
        };
        let last_comp = |ops: &[Instr]| {
            ops.iter()
                .rposition(|i| matches!(i, Instr::Forward { .. } | Instr::Backward { .. }))
                .unwrap()
        };
        let eager_before = (0..4).any(|d| first_ar(&eager.device_ops[d]) < last_comp(&eager.device_ops[d]));
        let lazy_before = (0..4).any(|d| first_ar(&lazy.device_ops[d]) < last_comp(&lazy.device_ops[d]));
        assert!(eager_before, "eager sync should overlap compute");
        assert!(!lazy_before, "lazy sync must follow all compute");
    }
}
