//! Static schedule analyzer: proves deadlock-freedom, memory bounds, and
//! sync placement on the IR without running a simulator.
//!
//! BitPipe's fused bidirectional schedules are exactly where hand-written
//! and searched schedules go wrong — deadlocked send/recv cycles, FIFO tag
//! inversions, eager all-reduces launched late (§4.3), activation stashes
//! past the V-shape's bound. Before this module, every one of those was
//! discovered *dynamically*: the event engine hangs, or
//! [`super::analysis::peak_activation_stash`] measures after the fact.
//! [`lint`] finds them from the instruction streams alone:
//!
//! * **Deadlock** — the dependence structure ([`EdgeArena`]) is checked
//!   for permanently-parked nodes (unmatched receives, entry-stage
//!   receives, collectives a member never starts) and for genuine cycles,
//!   reported with the *shortest* offending instruction cycle as a
//!   witness instead of a simulator hang.
//! * **Memory** — liveness high-water per device (activation born at `F`,
//!   freed at the matching `B`; under a split backward the slot survives
//!   `Bi` as a weight-grad pin and frees only at `W`; the per-device
//!   program-order walk is exact, hence an upper bound on any execution),
//!   cross-checked against `analysis::peak_activation_stash` and the
//!   family's Table-2 ceiling.
//! * **Split-backward pairing** — every `Bi` must be followed by its
//!   matching `W` on the same device and chunk, dequeued FIFO
//!   (`bw-missing-weight`, `bw-unmatched-weight`).
//! * **Sync placement** — beyond `validate`'s ordering errors, the eager
//!   policy is checked *two-sided*: a start that could have fired directly
//!   after the last backward but is delayed past other work is a warning
//!   (the paper's eager-sync claim, Fig 5b).
//! * **FIFO hazards** — same-tag reorder ambiguity, sends nothing ever
//!   receives, each anchored at the concrete instruction.
//!
//! Diagnostics are severity-leveled ([`Severity`]): `Error` means the
//! schedule is wrong (and [`super::validate::validate`] fails), `Warn`
//! means legal-but-weaker-than-promised, `Info` carries derived facts.
//! The `bitpipe lint` CLI subcommand renders reports human-readable or as
//! one JSON object per schedule; `rust/tests/lint_equiv.rs` pins the
//! analyzer against actual execution, and the Python mirror
//! (`.claude/skills/verify/pymirror/verify_lint.py`) reproduces the JSON
//! byte for byte.

use super::analysis::{peak_activation_stash, stash_high_water_chunks};
use super::ir::{Instr, Schedule, ScheduleKind, SyncPolicy};
use super::{json_escape, validate, Diagnostic, Diagnostics, Severity, Site};
use crate::sim::{EdgeArena, ParkReason};
use std::collections::{BTreeMap, HashMap, VecDeque};

/// Result of statically analyzing one schedule.
#[derive(Debug)]
pub struct LintReport {
    /// All findings, sorted most-severe first (then code, site, message).
    pub diags: Vec<Diagnostic>,
    /// Per-device activation-stash high-water, in chunk units
    /// ([`stash_high_water_chunks`]).
    pub stash_high_water: Vec<u64>,
}

impl LintReport {
    /// (errors, warnings, infos).
    pub fn counts(&self) -> (usize, usize, usize) {
        let mut c = (0, 0, 0);
        for d in &self.diags {
            match d.severity {
                Severity::Error => c.0 += 1,
                Severity::Warn => c.1 += 1,
                Severity::Info => c.2 += 1,
            }
        }
        c
    }

    pub fn has_errors(&self) -> bool {
        self.counts().0 > 0
    }

    /// All diagnostics with the given code.
    pub fn with_code(&self, code: &str) -> Vec<&Diagnostic> {
        self.diags.iter().filter(|d| d.code == code).collect()
    }

    /// Human-readable report.
    pub fn render_human(&self, s: &Schedule) -> String {
        let cfg = &s.cfg;
        let mut out = format!(
            "lint: kind={} D={} N={} v={} sync={}\n",
            cfg.kind.name(),
            cfg.d,
            cfg.n,
            cfg.v,
            cfg.sync.name()
        );
        for d in &self.diags {
            out.push_str(&format!("  {d}\n"));
            for w in &d.witness {
                out.push_str(&format!("      -> {w}\n"));
            }
        }
        let (e, w, i) = self.counts();
        out.push_str(&format!(
            "summary: {e} error(s), {w} warning(s), {i} info; stash high-water {:?} chunks\n",
            self.stash_high_water
        ));
        out
    }

    /// Machine output: one JSON object (single line, deterministic field
    /// and diagnostic order, integer-only numbers). The Python mirror
    /// reproduces this byte for byte — keep the two in sync.
    pub fn to_json(&self, s: &Schedule) -> String {
        let cfg = &s.cfg;
        let mut out = format!(
            "{{\"schedule\":{{\"kind\":\"{}\",\"d\":{},\"n\":{},\"v\":{},\"sync\":\"{}\"}}",
            cfg.kind.name(),
            cfg.d,
            cfg.n,
            cfg.v,
            cfg.sync.name()
        );
        let (e, w, i) = self.counts();
        out.push_str(&format!(",\"counts\":{{\"error\":{e},\"warn\":{w},\"info\":{i}}}"));
        out.push_str(",\"stash_high_water\":[");
        for (k, hw) in self.stash_high_water.iter().enumerate() {
            if k > 0 {
                out.push(',');
            }
            out.push_str(&hw.to_string());
        }
        out.push_str("],\"diags\":[");
        for (k, d) in self.diags.iter().enumerate() {
            if k > 0 {
                out.push(',');
            }
            out.push_str(&diag_json(d));
        }
        out.push_str("]}");
        out
    }
}

fn opt_usize_json(v: Option<usize>) -> String {
    v.map_or_else(|| "null".to_string(), |x| x.to_string())
}

fn opt_str_json(s: &str) -> String {
    if s.is_empty() {
        "null".to_string()
    } else {
        format!("\"{}\"", json_escape(s))
    }
}

fn site_json(site: &Site) -> String {
    format!(
        "{{\"dev\":{},\"ix\":{},\"instr\":{}}}",
        opt_usize_json(site.device),
        opt_usize_json(site.index),
        opt_str_json(&site.instr)
    )
}

fn diag_json(d: &Diagnostic) -> String {
    let mut wit = String::from("[");
    for (i, s) in d.witness.iter().enumerate() {
        if i > 0 {
            wit.push(',');
        }
        wit.push_str(&site_json(s));
    }
    wit.push(']');
    format!(
        "{{\"sev\":\"{}\",\"code\":\"{}\",\"msg\":\"{}\",\"dev\":{},\"ix\":{},\"instr\":{},\"witness\":{}}}",
        d.severity.name(),
        d.code,
        json_escape(&d.message),
        opt_usize_json(d.site.device),
        opt_usize_json(d.site.index),
        opt_str_json(&d.site.instr),
        wit
    )
}

/// Run every analysis pass over `s` and return the sorted report.
pub fn lint(s: &Schedule) -> LintReport {
    let mut out = Diagnostics::new();
    validate::collect(s, &mut out);
    let stash = stash_high_water_chunks(s);
    lint_memory(s, &stash, &mut out);
    lint_bw_pairing(s, &mut out);
    lint_sync_placement(s, &mut out);
    lint_fifo(s, &mut out);
    lint_deadlock(s, &mut out);
    out.sort_for_report();
    LintReport { diags: out.into_vec(), stash_high_water: stash }
}

/// Upper bound on the per-device stash depth each family promises, in
/// chunk units (Table 2's activation column, ceiled to the loosest member
/// of each family so every legal generator output fits under it).
pub fn family_stash_ceiling(kind: ScheduleKind, d: usize, n: usize, v: usize) -> u64 {
    match kind {
        // GPipe stashes every micro-batch before draining.
        ScheduleKind::GPipe => (n * v) as u64,
        // GEMS: at most two concurrent micro-batches.
        ScheduleKind::Gems => (2 * v) as u64,
        // 1F1B: at most D in-flight micro-batches, one chunk each.
        ScheduleKind::Dapple => (d * v) as u64,
        // Megatron interleaved warmup: device r stashes up to
        // D*(v-1) + 2*(D-r) - 1 chunks, maximized at r=0 as D*(v+1)-1.
        ScheduleKind::Interleaved => (d * (v + 1)) as u64,
        // V-shaped greedy is capped at D*v in-flight micro-batches, and
        // each one can stash on a device once per chunk level it hosts
        // there (v=2 on the V placement), so 2*D*v bounds the stash.
        ScheduleKind::VShaped => (2 * d * v) as u64,
        // Bidirectional: two pipes can each stash up to their unidirectional
        // bound on a shared device (the generators stay well below; the
        // paper's Table-2 "D x M_a" bound is d*v chunks total, but the
        // N>D early-forward portfolio is ceilinged at 2*d*v by
        // construction, so that is the hard line the linter enforces).
        ScheduleKind::Chimera
        | ScheduleKind::MixPipe
        | ScheduleKind::BitPipe
        | ScheduleKind::BitPipeNoV => (2 * d * v) as u64,
        // Zero-bubble: device 0 holds up to D in-flight activations (1F1B
        // warmup cap) plus at most one weight-grad pin at a time — the
        // deferral queue is force-drained once deeper than D-1, so a full
        // queue never coexists with full warmup depth. Peak D+1 once
        // N > D (N caps it below that). The generator's measured
        // high-water reaches this exactly (pinned by
        // `zero_bubble_stash_matches_ceiling` in rust/tests/lint_equiv.rs).
        ScheduleKind::ZeroBubble => ((d + 1).min(n) * v) as u64,
    }
}

/// Memory pass: liveness high-water vs the family ceiling, a negative
/// stash (freeing what was never stashed), and the cross-check against
/// `analysis::peak_activation_stash` (compute-order walk).
fn lint_memory(s: &Schedule, stash: &[u64], out: &mut Diagnostics) {
    let cfg = &s.cfg;
    let ceiling = family_stash_ceiling(cfg.kind, cfg.d, cfg.n, cfg.v);

    // Negative stash: a Backward on a device that holds no live stash.
    for (dv, ops) in s.device_ops.iter().enumerate() {
        let mut depth = 0i64;
        for (ix, ins) in ops.iter().enumerate() {
            match ins {
                Instr::Forward { .. } => depth += 1,
                // A split backward's Bi is memory-neutral (stash slot
                // becomes a weight-grad pin); the fused B and the split W
                // both free a slot.
                Instr::Backward { .. } | Instr::BackwardWeight { .. } => {
                    depth -= 1;
                    if depth < 0 {
                        out.error(
                            "mem-negative-stash",
                            format!(
                                "device {dv}: {ins} frees an activation that was never stashed locally"
                            ),
                            Site::at(dv, ix, ins),
                        );
                        break;
                    }
                }
                _ => {}
            }
        }
    }

    // High-water fact + ceiling check.
    let (mut peak, mut peak_dev) = (0u64, 0usize);
    for (dv, &hw) in stash.iter().enumerate() {
        if hw > peak {
            peak = hw;
            peak_dev = dv;
        }
        if hw > ceiling {
            out.warn(
                "mem-ceiling-exceeded",
                format!(
                    "device {dv}: stash high-water {hw} chunk(s) exceeds the {} ceiling of {ceiling}",
                    cfg.kind.name()
                ),
                Site::device(dv),
            );
        }
    }
    out.info(
        "mem-high-water",
        format!(
            "static activation high-water: {peak} chunk(s) on device {peak_dev}; family ceiling {ceiling} chunk(s)"
        ),
        Site::device(peak_dev),
    );

    // Cross-check against the compute-order measurement (Table 2's
    // measured column). Skipped for stream-only (hand-built) schedules.
    if s.compute_order.iter().any(|o| !o.is_empty()) {
        let v = s.placement.v as f64;
        for (dv, ma) in peak_activation_stash(s).iter().enumerate() {
            let chunks = (ma * v).round() as u64;
            if chunks != stash[dv] {
                out.warn(
                    "mem-stash-mismatch",
                    format!(
                        "device {dv}: stream high-water {} chunk(s) != compute-order high-water {chunks}",
                        stash[dv]
                    ),
                    Site::device(dv),
                );
            }
        }
    }
}

/// Split-backward pairing pass: per device and (pipe, stage) chunk, `Bi`
/// enqueues its micro-batch and `W` must dequeue the FIFO head — the
/// `WeightGradStore` discipline. A `W` with no pending `Bi` on its chunk
/// (or out of FIFO order) is `bw-unmatched-weight`; a `Bi` never followed
/// by its `W` is `bw-missing-weight` (its pin would leak past the
/// iteration). Vacuous on fused-backward families.
fn lint_bw_pairing(s: &Schedule, out: &mut Diagnostics) {
    for (dv, ops) in s.device_ops.iter().enumerate() {
        let mut pending: BTreeMap<(usize, usize), VecDeque<(usize, usize)>> = BTreeMap::new();
        for (ix, ins) in ops.iter().enumerate() {
            match *ins {
                Instr::BackwardInput { pipe, stage, mb } => {
                    pending.entry((pipe, stage)).or_default().push_back((mb, ix));
                }
                Instr::BackwardWeight { pipe, stage, mb } => {
                    let q = pending.entry((pipe, stage)).or_default();
                    match q.front().copied() {
                        Some((m0, _)) if m0 == mb => {
                            q.pop_front();
                        }
                        Some((m0, bix)) => {
                            out.push(Diagnostic {
                                severity: Severity::Error,
                                code: "bw-unmatched-weight",
                                message: format!(
                                    "device {dv}: {ins} dequeues out of FIFO order; the oldest pending weight grad is mb {m0}"
                                ),
                                site: Site::at(dv, ix, ins),
                                witness: vec![Site::at(dv, bix, &ops[bix])],
                            });
                            // Absorb the matching Bi if it is queued at all,
                            // so one inversion reports once, not per op.
                            if let Some(p) = q.iter().position(|&(m, _)| m == mb) {
                                q.remove(p);
                            }
                        }
                        None => {
                            out.error(
                                "bw-unmatched-weight",
                                format!(
                                    "device {dv}: {ins} has no pending Bi on this device/chunk"
                                ),
                                Site::at(dv, ix, ins),
                            );
                        }
                    }
                }
                _ => {}
            }
        }
        for ((pipe, stage), q) in pending {
            for (mb, bix) in q {
                let ins = &ops[bix];
                out.error(
                    "bw-missing-weight",
                    format!(
                        "device {dv}: Bi{mb}(p{pipe},s{stage}) is never followed by its weight-grad W; its memory pin leaks past the iteration"
                    ),
                    Site::at(dv, bix, ins),
                );
            }
        }
    }
}

/// Sync-placement pass: out-of-range collective/optimizer stages, and the
/// two-sided eager check — between a stage's last backward and its
/// `AllReduceStart`, only sends and other starts may appear, otherwise
/// the start is later than it could legally be (`validate` only rejects
/// starts delayed past *compute*; this warning covers the rest of the
/// paper's §4.3 eager claim).
fn lint_sync_placement(s: &Schedule, out: &mut Diagnostics) {
    let n_stages = s.placement.n_stages();
    for (dv, ops) in s.device_ops.iter().enumerate() {
        let mut last_bwd: HashMap<usize, usize> = HashMap::new();
        let mut first_start: BTreeMap<usize, usize> = BTreeMap::new();
        for (ix, ins) in ops.iter().enumerate() {
            match *ins {
                // A split backward's weight grad is the last producer of the
                // stage's weight gradient, so it — not the Bi — anchors the
                // eager window, matching `validate`'s sync semantics.
                Instr::Backward { stage, .. } | Instr::BackwardWeight { stage, .. } => {
                    last_bwd.insert(stage, ix);
                }
                Instr::AllReduceStart { stage } => {
                    if stage >= n_stages {
                        out.error(
                            "allreduce-unknown-stage",
                            format!(
                                "device {dv}: AllReduceStart for stage {stage} outside the placement (n_stages {n_stages})"
                            ),
                            Site::at(dv, ix, ins),
                        );
                    } else {
                        first_start.entry(stage).or_insert(ix);
                    }
                }
                Instr::OptimStep { stage } if stage >= n_stages => {
                    out.warn(
                        "optim-unknown-stage",
                        format!(
                            "device {dv}: OptimStep for stage {stage} outside the placement (n_stages {n_stages})"
                        ),
                        Site::at(dv, ix, ins),
                    );
                }
                _ => {}
            }
        }
        if s.cfg.sync != SyncPolicy::Eager {
            continue;
        }
        for (&stage, &a) in &first_start {
            let Some(&b) = last_bwd.get(&stage) else { continue };
            if a <= b {
                continue; // start-before-backward is validate's error
            }
            let blocker = ops[b + 1..a].iter().enumerate().find(|(_, i)| {
                !matches!(
                    i,
                    Instr::SendAct { .. } | Instr::SendGrad { .. } | Instr::AllReduceStart { .. }
                )
            });
            if let Some((off, blk)) = blocker {
                let mut d = Diagnostic {
                    severity: Severity::Warn,
                    code: "eager-delayed-start",
                    message: format!(
                        "device {dv}: eager AllReduceStart s{stage} delayed past {blk}; it could fire directly after the last backward"
                    ),
                    site: Site::at(dv, a, &ops[a]),
                    witness: Vec::new(),
                };
                d.witness.push(Site::at(dv, b, &ops[b]));
                d.witness.push(Site::at(dv, b + 1 + off, blk));
                out.push(d);
            }
        }
    }
}

/// FIFO-hazard pass: per message tag, surplus sends are errors (data the
/// consumer never picks up; surplus *receives* park and surface from the
/// deadlock pass), and tags carrying two or more concurrent messages on
/// both sides are flagged — the runtime pairs them FIFO by program order,
/// which is a convention, not a declared dependence.
fn lint_fifo(s: &Schedule, out: &mut Diagnostics) {
    type Tag = (usize, usize, bool, usize, usize, usize);
    let mut tags: BTreeMap<Tag, (Vec<(usize, usize)>, Vec<(usize, usize)>)> = BTreeMap::new();
    for (dv, ops) in s.device_ops.iter().enumerate() {
        for (ix, ins) in ops.iter().enumerate() {
            match *ins {
                Instr::SendAct { to, pipe, stage, mb } => {
                    tags.entry((dv, to, false, pipe, stage, mb)).or_default().0.push((dv, ix));
                }
                Instr::SendGrad { to, pipe, stage, mb } => {
                    tags.entry((dv, to, true, pipe, stage, mb)).or_default().0.push((dv, ix));
                }
                Instr::RecvAct { from, pipe, stage, mb } if stage > 0 => {
                    tags.entry((from, dv, false, pipe, stage - 1, mb))
                        .or_default()
                        .1
                        .push((dv, ix));
                }
                Instr::RecvGrad { from, pipe, stage, mb } => {
                    tags.entry((from, dv, true, pipe, stage + 1, mb)).or_default().1.push((dv, ix));
                }
                _ => {}
            }
        }
    }
    for (tag, (snd, rcv)) in &tags {
        let (from, to, is_grad, pipe, stage, mb) = *tag;
        if snd.len() >= 2 && rcv.len() >= 2 {
            let payload = if is_grad { "grad" } else { "act" };
            let mut d = Diagnostic {
                severity: Severity::Warn,
                code: "fifo-reorder-ambiguity",
                message: format!(
                    "message tag ({from}->{to}, {payload}, pipe {pipe}, stage {stage}, mb {mb}) carries {} concurrent messages; pairing falls back to FIFO program order",
                    snd.len().min(rcv.len())
                ),
                site: site_of_stream(s, snd[0]),
                witness: Vec::new(),
            };
            for &p in snd.iter().chain(rcv.iter()) {
                d.witness.push(site_of_stream(s, p));
            }
            out.push(d);
        }
        for &(dv, ix) in &snd[rcv.len().min(snd.len())..] {
            let ins = &s.device_ops[dv][ix];
            out.error(
                "fifo-unpaired-send",
                format!("device {dv}: {ins} is never received"),
                Site::at(dv, ix, ins),
            );
        }
    }
}

fn site_of_stream(s: &Schedule, (dv, ix): (usize, usize)) -> Site {
    Site::at(dv, ix, &s.device_ops[dv][ix])
}

/// Deadlock pass: lowers the schedule to its dependence structure and
/// reports (1) permanently-parked nodes, (2) the shortest genuine
/// dependence cycle, (3) chain-only inconsistency (the DAG backend's
/// `DagUnsupported` fallback), plus the graph-size fact.
fn lint_deadlock(s: &Schedule, out: &mut Diagnostics) {
    let arena = EdgeArena::lower(s);
    out.info(
        "graph-summary",
        format!(
            "dependence graph: {} nodes ({} instructions, {} collective rounds), {} edges, {} paired messages",
            arena.n_nodes,
            arena.n_real,
            arena.n_nodes - arena.n_real,
            arena.edges.len(),
            arena.n_msgs
        ),
        Site::none(),
    );

    for &(node, reason) in &arena.parked {
        match reason {
            ParkReason::EntryStageRecv | ParkReason::UnmatchedRecv | ParkReason::OutOfRangeWait => {
                let (dv, ix) = arena.site_of(node).expect("parked instruction node");
                let ins = &s.device_ops[dv][ix];
                let why = match reason {
                    ParkReason::EntryStageRecv => "an entry-stage producer that cannot exist",
                    ParkReason::UnmatchedRecv => "a message no device ever sends",
                    _ => "a collective outside the placement",
                };
                out.error(
                    "deadlock-parked",
                    format!("device {dv}: {ins} waits for {why}"),
                    Site::at(dv, ix, ins),
                );
            }
            ParkReason::MissingMemberStart(g) => {
                let c = node as usize - arena.n_real;
                let (stage, round) = (arena.barrier_stage[c], arena.barrier_round[c]);
                // Anchor at the earliest waiter this parks, if any.
                let site = arena
                    .edges
                    .iter()
                    .filter(|&&(a, b)| a == node && (b as usize) < arena.n_real)
                    .map(|&(_, b)| b)
                    .min()
                    .and_then(|w| arena.site_of(w))
                    .map(|(dv, ix)| Site::at(dv, ix, &s.device_ops[dv][ix]))
                    .unwrap_or_else(Site::none);
                out.error(
                    "deadlock-parked",
                    format!(
                        "collective s{stage} round {round}: member device {g} never launches its AllReduceStart, parking every waiter"
                    ),
                    site,
                );
            }
        }
    }

    for &node in &arena.oversized_starts {
        let (dv, ix) = arena.site_of(node).expect("oversized start is an instruction");
        let ins = &s.device_ops[dv][ix];
        out.error(
            "allreduce-unknown-stage",
            format!("device {dv}: {ins} addresses a collective outside the placement"),
            Site::at(dv, ix, ins),
        );
    }

    // Genuine cycles: Kahn over real edges, parked nodes treated as
    // fireable so only true circular waits remain.
    let order = arena.toposort(false, false);
    if order.len() < arena.n_nodes {
        let cycle = shortest_cycle(&arena, &order);
        let sites: Vec<Site> = cycle.iter().map(|&n| arena_site(s, &arena, n)).collect();
        let site = sites.first().cloned().unwrap_or_else(Site::none);
        out.push(Diagnostic {
            severity: Severity::Error,
            code: "deadlock-cycle",
            message: format!(
                "dependence cycle of {} instructions: the schedule can never complete",
                cycle.len()
            ),
            site,
            witness: sites,
        });
    } else {
        let with_chains = arena.toposort(true, false);
        if with_chains.len() < arena.n_nodes {
            out.warn(
                "collective-order",
                "devices disagree on the serialization order of shared collectives; the DAG backend falls back to the event engine",
                Site::none(),
            );
        }
    }
}

fn arena_site(s: &Schedule, arena: &EdgeArena, node: u32) -> Site {
    match arena.site_of(node) {
        Some((dv, ix)) => Site::at(dv, ix, &s.device_ops[dv][ix]),
        None => {
            let c = node as usize - arena.n_real;
            Site {
                device: None,
                index: None,
                instr: format!(
                    "barrier(allreduce s{} round {})",
                    arena.barrier_stage[c], arena.barrier_round[c]
                ),
            }
        }
    }
}

/// Plain Kahn's algorithm (no chains, no parking) used for the reverse
/// trim of the cycle search.
fn kahn(n_nodes: usize, edges: &[(u32, u32)]) -> Vec<u32> {
    let mut indeg = vec![0u32; n_nodes];
    let mut succ: Vec<Vec<u32>> = vec![Vec::new(); n_nodes];
    for &(a, b) in edges {
        indeg[b as usize] += 1;
        succ[a as usize].push(b);
    }
    let mut ready: Vec<u32> =
        (0..n_nodes as u32).rev().filter(|&i| indeg[i as usize] == 0).collect();
    let mut order = Vec::with_capacity(n_nodes);
    while let Some(nid) = ready.pop() {
        order.push(nid);
        for &nx in &succ[nid as usize] {
            indeg[nx as usize] -= 1;
            if indeg[nx as usize] == 0 {
                ready.push(nx);
            }
        }
    }
    order
}

/// Shortest dependence cycle, as a node sequence (first node repeats
/// implicitly). `fwd_order` is the incomplete forward Kahn order.
///
/// Nodes missed by the forward sort are on or downstream of a cycle;
/// nodes missed by the *reverse* sort are on or upstream of one. The
/// intersection tightly over-approximates the cyclic region; a BFS from
/// each region node (ascending, capped) finds the globally shortest
/// cycle deterministically. Iterative throughout — no recursion, so
/// adversarial schedules cannot blow the stack.
fn shortest_cycle(arena: &EdgeArena, fwd_order: &[u32]) -> Vec<u32> {
    let n = arena.n_nodes;
    let mut in_region = vec![true; n];
    for &x in fwd_order {
        in_region[x as usize] = false;
    }
    let rev_edges: Vec<(u32, u32)> = arena.edges.iter().map(|&(a, b)| (b, a)).collect();
    for &x in &kahn(n, &rev_edges) {
        in_region[x as usize] = false;
    }
    let region: Vec<u32> = (0..n as u32).filter(|&i| in_region[i as usize]).collect();

    let mut succ: Vec<Vec<u32>> = vec![Vec::new(); n];
    for &(a, b) in &arena.edges {
        if in_region[a as usize] && in_region[b as usize] {
            succ[a as usize].push(b);
        }
    }

    let mut best: Vec<u32> = Vec::new();
    for &start in region.iter().take(256) {
        let mut parent: HashMap<u32, u32> = HashMap::new();
        let mut dist: HashMap<u32, usize> = HashMap::new();
        dist.insert(start, 0);
        let mut q = VecDeque::from([start]);
        let mut closes: Option<u32> = None;
        'bfs: while let Some(x) = q.pop_front() {
            let dx = dist[&x];
            if !best.is_empty() && dx + 1 >= best.len() {
                continue; // cannot beat the best cycle found so far
            }
            for &y in &succ[x as usize] {
                if y == start {
                    closes = Some(x);
                    break 'bfs;
                }
                if !dist.contains_key(&y) {
                    dist.insert(y, dx + 1);
                    parent.insert(y, x);
                    q.push_back(y);
                }
            }
        }
        if let Some(last) = closes {
            let mut path = vec![last];
            let mut cur = last;
            while cur != start {
                cur = parent[&cur];
                path.push(cur);
            }
            path.reverse();
            if best.is_empty() || path.len() < best.len() {
                best = path;
            }
            if best.len() == 2 {
                break;
            }
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::schedule::ir::ScheduleConfig;
    use crate::schedule::{build, placement_for};

    fn built(kind: ScheduleKind, d: usize, n: usize) -> Schedule {
        build(&ScheduleConfig::new(kind, d, n)).unwrap()
    }

    #[test]
    fn generated_families_are_lint_clean() {
        for kind in ScheduleKind::ALL {
            let s = built(kind, 4, 8);
            let r = lint(&s);
            let (e, w, _) = r.counts();
            assert_eq!((e, w), (0, 0), "{kind}: {:?}", r.diags);
        }
    }

    #[test]
    fn clean_report_has_graph_and_memory_facts() {
        let r = lint(&built(ScheduleKind::BitPipe, 4, 8));
        assert_eq!(r.with_code("graph-summary").len(), 1);
        assert_eq!(r.with_code("mem-high-water").len(), 1);
        assert_eq!(r.stash_high_water.len(), 4);
        assert!(r.stash_high_water.iter().any(|&p| p > 0));
    }

    #[test]
    fn high_water_matches_analysis_in_chunks() {
        for kind in ScheduleKind::ALL {
            let s = built(kind, 4, 8);
            let r = lint(&s);
            let v = s.placement.v as f64;
            for (dv, ma) in peak_activation_stash(&s).iter().enumerate() {
                assert_eq!(r.stash_high_water[dv], (ma * v).round() as u64, "{kind} dev {dv}");
            }
        }
    }

    #[test]
    fn dropped_send_parks_the_recv() {
        let mut s = built(ScheduleKind::Dapple, 4, 4);
        let ix = s.device_ops[0]
            .iter()
            .position(|i| matches!(i, Instr::SendAct { .. }))
            .unwrap();
        s.device_ops[0].remove(ix);
        let r = lint(&s);
        let parked = r.with_code("deadlock-parked");
        assert!(!parked.is_empty(), "{:?}", r.diags);
        assert!(parked[0].message.contains("no device ever sends"), "{}", parked[0].message);
        assert!(parked[0].site.instr.starts_with("RA"), "{}", parked[0].site.instr);
    }

    #[test]
    fn dropped_recv_is_an_unpaired_send() {
        let mut s = built(ScheduleKind::Dapple, 4, 4);
        let ix = s.device_ops[1]
            .iter()
            .position(|i| matches!(i, Instr::RecvAct { .. }))
            .unwrap();
        s.device_ops[1].remove(ix);
        let r = lint(&s);
        let unpaired = r.with_code("fifo-unpaired-send");
        assert_eq!(unpaired.len(), 1, "{:?}", r.diags);
        assert!(unpaired[0].site.instr.starts_with("SA"), "{}", unpaired[0].site.instr);
    }

    #[test]
    fn cycle_mutant_yields_shortest_witness() {
        // Hand-built two-device circular wait: each device receives before
        // it sends — the minimal deadlock.
        let placement = placement_for(ScheduleKind::Dapple, 2, 1);
        let cfg = ScheduleConfig::new(ScheduleKind::Dapple, 2, 2);
        let s = Schedule {
            cfg,
            placement,
            compute_order: vec![Vec::new(), Vec::new()],
            device_ops: vec![
                vec![
                    Instr::RecvGrad { from: 1, pipe: 0, stage: 0, mb: 0 },
                    Instr::SendAct { to: 1, pipe: 0, stage: 0, mb: 0 },
                ],
                vec![
                    Instr::RecvAct { from: 0, pipe: 0, stage: 1, mb: 0 },
                    Instr::SendGrad { to: 0, pipe: 0, stage: 1, mb: 0 },
                ],
            ],
            pipe_of_mb: vec![0, 0],
        };
        let r = lint(&s);
        let cyc = r.with_code("deadlock-cycle");
        assert_eq!(cyc.len(), 1, "{:?}", r.diags);
        assert_eq!(cyc[0].witness.len(), 4, "{:?}", cyc[0].witness);
        let instrs: Vec<&str> =
            cyc[0].witness.iter().map(|w| w.instr.split('(').next().unwrap()).collect();
        assert!(instrs.contains(&"RG0") || instrs.iter().any(|i| i.starts_with("RG")));
    }

    #[test]
    fn json_is_single_line_and_stable() {
        let s = built(ScheduleKind::Dapple, 4, 4);
        let r = lint(&s);
        let j = r.to_json(&s);
        assert!(!j.contains('\n'));
        assert!(j.starts_with("{\"schedule\":{\"kind\":\"dapple\",\"d\":4,\"n\":4,"));
        assert_eq!(j, lint(&s).to_json(&s), "lint output must be deterministic");
    }

    #[test]
    fn family_ceiling_bounds_every_generated_schedule() {
        for kind in ScheduleKind::ALL {
            for (d, n) in [(4usize, 4usize), (4, 8), (4, 16), (8, 8)] {
                let s = built(kind, d, n);
                let ceil = family_stash_ceiling(kind, d, n, s.placement.v);
                for (dv, &hw) in stash_high_water_chunks(&s).iter().enumerate() {
                    assert!(hw <= ceil, "{kind} D={d} N={n} dev {dv}: {hw} > {ceil}");
                }
            }
        }
    }
}
