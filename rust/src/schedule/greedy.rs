//! Greedy event-driven schedule generator.
//!
//! Produces per-device compute *orders* under a 1F1B-like policy: a device
//! always runs a ready backward if one exists, otherwise a ready forward
//! (depth-first through co-located consecutive chunks), subject to an
//! optional cap on in-flight activation stashes. Backward-as-soon-as-
//! possible is exactly the behaviour the paper's schedules are built from;
//! the cap is what distinguishes the memory-bounded scaling variants
//! (Chimera forward-doubling vs. BitPipe early-forwarding, Appendix B).
//!
//! The generator can schedule one pipeline replica in isolation (the merge
//! construction of Chimera/BitPipe: each pipe is scheduled independently,
//! then the two are fused) or several jointly (GEMS, whose cross-replica
//! gate needs both pipes in one pass).

use super::asap::{deps_of, Costs};
use super::ir::{CompOp, MicroBatch, OpKind, PipeId, Placement};
use std::collections::HashMap;

/// Policy knobs for the greedy generator.
#[derive(Clone, Copy, Default)]
pub struct GreedyPolicy<'a> {
    /// Maximum in-flight micro-batches *per pipe*: a micro-batch is in
    /// flight from its entry-stage forward until its entry-stage backward.
    /// Gating only injection keeps the generator deadlock-free (in-flight
    /// work can always drain); the cap is the knob distinguishing the
    /// memory-bounded scaling variants (Chimera forward-doubling caps at D,
    /// BitPipe early-forwarding at ~3(D-1)/4 per pipe, Appendix B).
    /// `None` = unbounded.
    pub inflight_cap: Option<usize>,
    /// Extra dependency edges, e.g. GEMS' "replica hand-off" gate.
    pub extra_deps: Option<&'a dyn Fn(&CompOp) -> Vec<CompOp>>,
}

impl std::fmt::Debug for GreedyPolicy<'_> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("GreedyPolicy")
            .field("inflight_cap", &self.inflight_cap)
            .field("extra_deps", &self.extra_deps.map(|_| "<fn>"))
            .finish()
    }
}

/// One scheduling job: a pipeline replica and the micro-batches it processes
/// (in injection order).
#[derive(Debug, Clone)]
pub struct PipeJob {
    pub pipe: PipeId,
    pub mbs: Vec<MicroBatch>,
}

/// Generate the compute order for `jobs` over `placement`.
///
/// Returns per-device op sequences (device index = physical device id).
/// Deterministic for fixed inputs.
pub fn greedy_order(
    placement: &Placement,
    jobs: &[PipeJob],
    policy: &GreedyPolicy,
    costs: &Costs,
) -> Vec<Vec<CompOp>> {
    let d = placement.d;
    let v = placement.v;
    let n_stages = placement.n_stages();

    // Frontier representation: for each (pipe, micro-batch) only the
    // lowest unscheduled forward stage and the highest unscheduled backward
    // stage can possibly be ready (their within-micro-batch chain deps
    // gate everything deeper), so candidate scans are O(#micro-batches)
    // instead of O(#remaining ops).
    let mut rank: HashMap<(PipeId, MicroBatch), usize> = HashMap::new();
    let mut fronts: Vec<(PipeId, MicroBatch)> = Vec::new();
    for job in jobs {
        for (i, &m) in job.mbs.iter().enumerate() {
            rank.insert((job.pipe, m), i);
            fronts.push((job.pipe, m));
        }
    }
    let total = fronts.len() * 2 * n_stages;
    // next forward stage (ascending) / next backward stage (descending,
    // n_stages = all done) per (pipe, mb).
    let mut next_f: HashMap<(PipeId, MicroBatch), usize> =
        fronts.iter().map(|&k| (k, 0usize)).collect();
    let mut next_b: HashMap<(PipeId, MicroBatch), usize> =
        fronts.iter().map(|&k| (k, n_stages)).collect();

    let max_pipe = jobs.iter().map(|j| j.pipe).max().unwrap_or(0);
    let mut done: HashMap<CompOp, u64> = HashMap::with_capacity(total);
    let mut avail = vec![0u64; d];
    let mut inflight = vec![0usize; max_pipe + 1];
    let mut last_op: Vec<Option<CompOp>> = vec![None; d];
    let mut order: Vec<Vec<CompOp>> = vec![Vec::new(); d];

    let mut scheduled = 0usize;
    while scheduled < total {
        let mut best: Option<(u64, usize, CompOp)> = None; // (start, dev, op)
        let mut consider = |op: CompOp,
                            best: &mut Option<(u64, usize, CompOp)>,
                            done: &HashMap<CompOp, u64>,
                            inflight: &[usize]| {
            let dev = placement.device(op.pipe, op.stage);
            let mut ready = avail[dev];
            let mut deps = deps_of(&op, n_stages);
            if let Some(f) = policy.extra_deps {
                deps.extend(f(&op));
            }
            for dep in &deps {
                match done.get(dep) {
                    Some(&e) => ready = ready.max(e),
                    None => return,
                }
            }
            if op.kind == OpKind::Forward && op.stage == 0 {
                if let Some(cap) = policy.inflight_cap {
                    if inflight[op.pipe] >= cap {
                        return;
                    }
                }
            }
            let cand = (ready, dev, op);
            *best = Some(match *best {
                None => cand,
                Some(cur) => pick(cur, cand, &last_op, &rank),
            });
        };
        for &(pipe, m) in &fronts {
            let nf = next_f[&(pipe, m)];
            if nf < n_stages {
                consider(CompOp::fwd(pipe, nf, m), &mut best, &done, &inflight);
            }
            let nb = next_b[&(pipe, m)];
            if nb > 0 {
                consider(CompOp::bwd(pipe, nb - 1, m), &mut best, &done, &inflight);
            }
        }
        let (start, dev, op) = best.expect("greedy stuck: no ready op (dependency bug)");
        let dur = costs.of(&op, v);
        done.insert(op, start + dur);
        avail[dev] = start + dur;
        if op.stage == 0 {
            match op.kind {
                OpKind::Forward => inflight[op.pipe] += 1,
                OpKind::Backward => inflight[op.pipe] = inflight[op.pipe].saturating_sub(1),
                // The greedy scheduler only frontiers fused F/B ops.
                _ => unreachable!("split backward in greedy order"),
            }
        }
        match op.kind {
            OpKind::Forward => *next_f.get_mut(&(op.pipe, op.mb)).unwrap() += 1,
            OpKind::Backward => *next_b.get_mut(&(op.pipe, op.mb)).unwrap() -= 1,
            _ => unreachable!("split backward in greedy order"),
        }
        last_op[dev] = Some(op);
        order[dev].push(op);
        scheduled += 1;
    }
    order
}

/// Deterministic candidate comparison. Returns the preferred of `a`, `b`.
fn pick(
    a: (u64, usize, CompOp),
    b: (u64, usize, CompOp),
    last_op: &[Option<CompOp>],
    rank: &HashMap<(PipeId, MicroBatch), usize>,
) -> (u64, usize, CompOp) {
    // Earliest feasible start wins (global event order).
    if a.0 != b.0 {
        return if a.0 < b.0 { a } else { b };
    }
    if a.1 == b.1 {
        let dev = a.1;
        // Backward-first: the 1F1B invariant.
        let (ak, bk) = (a.2.kind, b.2.kind);
        if ak != bk {
            return if ak == OpKind::Backward { a } else { b };
        }
        // Depth-first V-turn: continue the micro-batch we just produced
        // locally (consumer chunk co-located with the producer), in both
        // directions — forward s -> s+1 and backward s -> s-1.
        if let Some(prev) = last_op[dev] {
            let cont = |o: &CompOp| {
                o.kind == prev.kind
                    && o.pipe == prev.pipe
                    && o.mb == prev.mb
                    && match prev.kind {
                        OpKind::Forward => o.stage == prev.stage + 1,
                        OpKind::Backward => prev.stage == o.stage + 1,
                        _ => false,
                    }
            };
            let (ca, cb) = (cont(&a.2), cont(&b.2));
            if ca != cb {
                return if ca { a } else { b };
            }
        }
        // Earlier-injected micro-batch first; then lower stage for F /
        // higher stage for B (drain direction); then pipe id.
        let (ra, rb) = (rank[&(a.2.pipe, a.2.mb)], rank[&(b.2.pipe, b.2.mb)]);
        if ra != rb {
            return if ra < rb { a } else { b };
        }
        if a.2.stage != b.2.stage {
            let fwd = a.2.kind == OpKind::Forward;
            let a_first = if fwd { a.2.stage < b.2.stage } else { a.2.stage > b.2.stage };
            return if a_first { a } else { b };
        }
        if a.2.pipe != b.2.pipe {
            return if a.2.pipe < b.2.pipe { a } else { b };
        }
        return a;
    }
    // Different devices, same start: lower device id (deterministic).
    if a.1 < b.1 {
        a
    } else {
        b
    }
}

/// Convenience wrapper: schedule a single pipe.
pub fn greedy_pipe_order(
    placement: &Placement,
    pipe: PipeId,
    mbs: &[MicroBatch],
    policy: &GreedyPolicy,
    costs: &Costs,
) -> Vec<Vec<CompOp>> {
    greedy_order(placement, &[PipeJob { pipe, mbs: mbs.to_vec() }], policy, costs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::schedule::asap::retime;

    /// Straight chain placement, one stage per device.
    fn chain(d: usize) -> Placement {
        Placement::from_fn(d, 1, 1, |_p, s| s)
    }

    /// V-shaped placement for one pipe: stage s -> zig-zag device.
    fn vshape(d: usize, v: usize) -> Placement {
        Placement::from_fn(d, v, 1, |_p, s| {
            let round = s / d;
            let pos = s % d;
            if round % 2 == 0 {
                pos
            } else {
                d - 1 - pos
            }
        })
    }

    #[test]
    fn greedy_1f1b_geometry_matches_dapple_formula() {
        // Single pipe, v=1, N=D=4: greedy prefer-B == 1F1B; bubble per
        // device = (D-1)*(tf+tb) = 3*36 = 108 ticks; makespan = ideal+bubble
        // = N*(tf+tb) + 108 = 144+108 = 252.
        let p = chain(4);
        let mbs: Vec<usize> = (0..4).collect();
        let costs = Costs::default();
        let order = greedy_pipe_order(&p, 0, &mbs, &GreedyPolicy::default(), &costs);
        let t = retime(&order, &p, &costs).unwrap();
        assert_eq!(t.makespan, 252);
        for b in t.bubbles() {
            assert_eq!(b, 108);
        }
    }

    #[test]
    fn greedy_respects_inflight_cap() {
        let p = chain(2);
        let mbs: Vec<usize> = (0..6).collect();
        let costs = Costs::default();
        let policy = GreedyPolicy { inflight_cap: Some(2), ..Default::default() };
        let order = greedy_pipe_order(&p, 0, &mbs, &policy, &costs);
        for dev_ops in &order {
            let mut depth = 0i64;
            for op in dev_ops {
                match op.kind {
                    OpKind::Forward => depth += 1,
                    OpKind::Backward => depth -= 1,
                    _ => unreachable!("split backward in greedy order"),
                }
                assert!(depth <= 2, "cap violated: {op}");
            }
        }
    }

    #[test]
    fn greedy_vshape_local_turn_is_depth_first() {
        // D=2, v=2 V-shape: stages s0@d0 s1@d1 s2@d1 s3@d0. Device 1 should
        // continue mb0 through the local s1->s2 turn before starting mb1's s1.
        let p = vshape(2, 2);
        let mbs = vec![0, 1];
        let costs = Costs::default();
        let order = greedy_pipe_order(&p, 0, &mbs, &GreedyPolicy::default(), &costs);
        let d1 = &order[1];
        let i_s1m0 = d1.iter().position(|o| *o == CompOp::fwd(0, 1, 0)).unwrap();
        let i_s2m0 = d1.iter().position(|o| *o == CompOp::fwd(0, 2, 0)).unwrap();
        let i_s1m1 = d1.iter().position(|o| *o == CompOp::fwd(0, 1, 1)).unwrap();
        assert!(i_s1m0 < i_s2m0);
        assert!(i_s2m0 < i_s1m1, "expected depth-first V turn");
    }

    #[test]
    fn greedy_all_ops_scheduled_exactly_once() {
        let p = vshape(4, 2);
        let mbs = vec![0, 1, 2, 3];
        let costs = Costs::default();
        let order = greedy_pipe_order(&p, 0, &mbs, &GreedyPolicy::default(), &costs);
        let mut seen = std::collections::HashSet::new();
        for ops in &order {
            for op in ops {
                assert!(seen.insert(*op), "duplicate {op}");
            }
        }
        assert_eq!(seen.len(), 4 * 8 * 2);
    }

    #[test]
    fn greedy_extra_deps_gate() {
        // Gate forward of mb m on backward of mb m-1 at the entry stage —
        // forces fully serial execution of micro-batches.
        let p = chain(2);
        let mbs = vec![0usize, 1];
        let costs = Costs::default();
        let gate = |op: &CompOp| -> Vec<CompOp> {
            if op.kind == OpKind::Forward && op.stage == 0 && op.mb >= 1 {
                vec![CompOp::bwd(op.pipe, 0, op.mb - 1)]
            } else {
                vec![]
            }
        };
        let policy = GreedyPolicy { inflight_cap: None, extra_deps: Some(&gate) };
        let order = greedy_pipe_order(&p, 0, &mbs, &policy, &costs);
        let t = retime(&order, &p, &costs).unwrap();
        // Serial: each mb takes 2*(12+12+24+24)... actually one full
        // traversal is 12+12+24+24 = 72; two serial = 144.
        assert_eq!(t.makespan, 144);
    }
}
