//! Schedule validation: the hard invariants every generated schedule must
//! hold, expressed as the Error tier of the diagnostic framework.
//!
//! This module is the strict core of the static analyzer
//! ([`super::lint`]). Each invariant below is implemented as a
//! `collect_*` pass that pushes [`Diagnostic`]s (severity `Error`) into a
//! shared [`Diagnostics`] sink; [`collect`] runs them all, and the
//! classic [`validate`] entry point is a thin wrapper that fails with the
//! *first* error's message — so every pre-existing caller keeps its exact
//! `Result<()>` behavior while `bitpipe lint` sees the same findings with
//! sites and witnesses attached.
//!
//! The invariants, stated or implied by the paper:
//!
//! 1. **Completeness** (`sched-completeness`) — every (pipe, stage,
//!    micro-batch) chunk runs its forward exactly once, and exactly one
//!    backward *shape*: either the fused `B`, or the split pair `Bi` + `W`
//!    (both exactly once), on the device that hosts it.
//! 2. **Dataflow order** (`sched-local-order`, `retime`) — within each
//!    device stream, `B(s,m)` (or `Bi(s,m)`) after `F(s,m)` and `W(s,m)`
//!    after `Bi(s,m)`; globally the streams re-time without deadlock
//!    (checked by [`super::asap::retime`]).
//! 3. **Comm pairing** (`comm-pairing`) — every `SendAct`/`SendGrad` has
//!    exactly one matching `RecvAct`/`RecvGrad` on the destination device
//!    and vice versa; local copies only connect co-located chunks.
//! 4. **Synchronous semantics (flush)** (`sync-order`) — on each device,
//!    every `AllReduceStart{stage}` comes after the last local backward
//!    touching that stage, `AllReduceWait` after the start, `OptimStep`
//!    after the wait; exactly one of each per held stage per iteration.
//!    Eager policy additionally forbids delaying a start past further
//!    compute (the looser "delayed past non-compute work" case is the
//!    lint-level `eager-delayed-start` warning in [`super::lint`]).
//! 5. **No-conflict merge** (`retime`) — the fused bidirectional schedule
//!    never asks a device to run two compute ops in the same time slot
//!    (guaranteed by construction for even D; checked geometrically).
//!
//! To keep reports readable and `validate`'s first-error contract exact,
//! each pass stops at its first violation; the passes themselves all run,
//! so a lint report can carry one finding per invariant class. The
//! property-based tests in `rust/tests/prop_schedule.rs` drive this
//! module over randomly drawn configurations.

use super::asap::{retime, Costs};
use super::ir::{CompOp, Instr, OpKind, Schedule, SyncPolicy};
use super::{Diagnostic, Diagnostics, Severity, Site};
use anyhow::{bail, Result};
use std::collections::{BTreeMap, HashMap, HashSet};

/// Run every schedule invariant; returns the first violation as an error.
pub fn validate(schedule: &Schedule) -> Result<()> {
    let mut diags = Diagnostics::new();
    collect(schedule, &mut diags);
    match diags.first_error() {
        Some(d) => bail!("{}", d.message),
        None => Ok(()),
    }
}

/// Run every invariant pass, pushing findings into `out`. Each pass
/// reports at most its first violation (in scan order), so the first
/// error in insertion order is exactly what [`validate`] would fail with.
pub(crate) fn collect(s: &Schedule, out: &mut Diagnostics) {
    collect_completeness(s, out);
    collect_device_local_order(s, out);
    collect_comm_pairing(s, out);
    collect_sync_semantics(s, out);
    collect_retimes(s, out);
}

fn op_site(dev: usize, op: &CompOp) -> Site {
    Site { device: Some(dev), index: None, instr: op.to_string() }
}

/// Invariant 1: every chunk op exactly once, on its host device.
fn collect_completeness(s: &Schedule, out: &mut Diagnostics) {
    let p = &s.placement;
    let n_stages = p.n_stages();
    let mut seen: HashSet<CompOp> = HashSet::new();
    for (dev, ops) in s.compute_order.iter().enumerate() {
        for op in ops {
            if p.device(op.pipe, op.stage) != dev {
                out.error(
                    "sched-completeness",
                    format!(
                        "op {op} scheduled on device {dev}, placed on {}",
                        p.device(op.pipe, op.stage)
                    ),
                    op_site(dev, op),
                );
                return;
            }
            if !seen.insert(*op) {
                out.error("sched-completeness", format!("duplicate compute op {op}"), op_site(dev, op));
                return;
            }
        }
    }
    let missing = |out: &mut Diagnostics, op: CompOp| {
        out.error(
            "sched-completeness",
            format!("missing compute op {op}"),
            Site { device: None, index: None, instr: op.to_string() },
        );
    };
    for (m, &pipe) in s.pipe_of_mb.iter().enumerate() {
        for stage in 0..n_stages {
            let f = CompOp::fwd(pipe, stage, m);
            if !seen.remove(&f) {
                missing(out, f);
                return;
            }
            // Backward comes in one of two shapes: the fused B, or the
            // split Bi + W pair (both halves required).
            let b = CompOp::bwd(pipe, stage, m);
            if !seen.remove(&b) {
                let bi = CompOp::bwd_input(pipe, stage, m);
                let w = CompOp::bwd_weight(pipe, stage, m);
                let have_bi = seen.remove(&bi);
                let have_w = seen.remove(&w);
                match (have_bi, have_w) {
                    (true, true) => {}
                    (true, false) => {
                        missing(out, w);
                        return;
                    }
                    (false, true) => {
                        missing(out, bi);
                        return;
                    }
                    (false, false) => {
                        missing(out, b);
                        return;
                    }
                }
            }
        }
    }
    if !seen.is_empty() {
        out.error(
            "sched-completeness",
            format!("extra compute ops beyond the N micro-batches: {seen:?}"),
            Site::none(),
        );
    }
}

/// Invariant 2 (local part): on each device stream, B(s,m) / Bi(s,m)
/// after F(s,m), and W(s,m) after Bi(s,m).
fn collect_device_local_order(s: &Schedule, out: &mut Diagnostics) {
    for (dev, ops) in s.compute_order.iter().enumerate() {
        let mut pos: HashMap<CompOp, usize> = HashMap::new();
        for (i, op) in ops.iter().enumerate() {
            pos.insert(*op, i);
        }
        for op in ops {
            let dep = match op.kind {
                OpKind::Backward | OpKind::BackwardInput => {
                    CompOp::fwd(op.pipe, op.stage, op.mb)
                }
                OpKind::BackwardWeight => CompOp::bwd_input(op.pipe, op.stage, op.mb),
                OpKind::Forward => continue,
            };
            if let Some(&di) = pos.get(&dep) {
                if di >= pos[op] {
                    out.push(Diagnostic {
                        severity: Severity::Error,
                        code: "sched-local-order",
                        message: format!("device {dev}: {op} precedes its dependency {dep}"),
                        site: op_site(dev, op),
                        witness: vec![op_site(dev, &dep)],
                    });
                    return;
                }
            }
        }
    }
}

/// Invariant 3: sends and receives pair one-to-one across devices, local
/// copies connect co-located chunks only.
fn collect_comm_pairing(s: &Schedule, out: &mut Diagnostics) {
    let p = &s.placement;
    // (from, to, kind, pipe, stage, mb) -> count. kind: 0 act, 1 grad.
    // BTreeMap so the "unpaired" report is deterministic.
    let mut sends: BTreeMap<(usize, usize, u8, usize, usize, usize), i64> = BTreeMap::new();
    for (dev, ops) in s.device_ops.iter().enumerate() {
        for (ix, op) in ops.iter().enumerate() {
            match *op {
                Instr::SendAct { to, pipe, stage, mb } => {
                    *sends.entry((dev, to, 0, pipe, stage, mb)).or_default() += 1;
                }
                Instr::RecvAct { from, pipe, stage, mb } => {
                    // Receiver tags with its own (consumer) stage; the
                    // producer side used stage-1. Stage 0 has no producer —
                    // rejecting it here keeps the simulator's entry-stage
                    // guard (`sim::engine`) a dead-stream diagnostic rather
                    // than a reachable state.
                    if stage == 0 {
                        out.error(
                            "comm-pairing",
                            format!("device {dev}: RecvAct for entry stage (no producer exists)"),
                            Site::at(dev, ix, op),
                        );
                        return;
                    }
                    *sends.entry((from, dev, 0, pipe, stage - 1, mb)).or_default() -= 1;
                }
                Instr::SendGrad { to, pipe, stage, mb } => {
                    *sends.entry((dev, to, 1, pipe, stage, mb)).or_default() += 1;
                }
                Instr::RecvGrad { from, pipe, stage, mb } => {
                    // Receiver's stage s consumes grad produced by s+1; the
                    // exit stage has no downstream producer.
                    if stage + 1 >= p.n_stages() {
                        out.error(
                            "comm-pairing",
                            format!("device {dev}: RecvGrad for exit stage (no producer exists)"),
                            Site::at(dev, ix, op),
                        );
                        return;
                    }
                    *sends.entry((from, dev, 1, pipe, stage + 1, mb)).or_default() -= 1;
                }
                Instr::LocalCopyAct { pipe, stage, mb } => {
                    let _ = mb;
                    if stage + 1 >= p.n_stages() {
                        out.error(
                            "comm-pairing",
                            "LocalCopyAct from the last stage",
                            Site::at(dev, ix, op),
                        );
                        return;
                    }
                    if p.device(pipe, stage) != p.device(pipe, stage + 1) {
                        out.error(
                            "comm-pairing",
                            format!(
                                "LocalCopyAct between non-co-located stages {stage},{}",
                                stage + 1
                            ),
                            Site::at(dev, ix, op),
                        );
                        return;
                    }
                    if p.device(pipe, stage) != dev {
                        out.error(
                            "comm-pairing",
                            "LocalCopyAct on wrong device",
                            Site::at(dev, ix, op),
                        );
                        return;
                    }
                }
                Instr::LocalCopyGrad { pipe, stage, mb } => {
                    let _ = mb;
                    if stage == 0 {
                        out.error(
                            "comm-pairing",
                            "LocalCopyGrad from the entry stage",
                            Site::at(dev, ix, op),
                        );
                        return;
                    }
                    if p.device(pipe, stage) != p.device(pipe, stage - 1) {
                        out.error(
                            "comm-pairing",
                            "LocalCopyGrad between non-co-located stages",
                            Site::at(dev, ix, op),
                        );
                        return;
                    }
                    if p.device(pipe, stage) != dev {
                        out.error(
                            "comm-pairing",
                            "LocalCopyGrad on wrong device",
                            Site::at(dev, ix, op),
                        );
                        return;
                    }
                }
                _ => {}
            }
        }
    }
    for (k, v) in sends {
        if v != 0 {
            out.error(
                "comm-pairing",
                format!("unpaired P2P message {k:?} (imbalance {v})"),
                Site::none(),
            );
            return;
        }
    }
}

/// Invariant 4: flush semantics per device.
fn collect_sync_semantics(s: &Schedule, out: &mut Diagnostics) {
    for (dev, ops) in s.device_ops.iter().enumerate() {
        let mut held: Vec<usize> =
            s.placement.chunks_on[dev].iter().map(|&(_, st)| st).collect();
        held.sort_unstable();
        held.dedup();

        let mut last_bwd: HashMap<usize, usize> = HashMap::new();
        let mut ar_start: HashMap<usize, usize> = HashMap::new();
        let mut ar_wait: HashMap<usize, usize> = HashMap::new();
        let mut optim: HashMap<usize, usize> = HashMap::new();
        for (i, op) in ops.iter().enumerate() {
            match *op {
                // The stage's gradient is complete at the fused backward
                // or, for a split backward, only at the weight-grad W.
                Instr::Backward { stage, .. } | Instr::BackwardWeight { stage, .. } => {
                    last_bwd.insert(stage, i);
                }
                Instr::AllReduceStart { stage } => {
                    if ar_start.insert(stage, i).is_some() {
                        out.error(
                            "sync-order",
                            format!("device {dev}: duplicate AllReduceStart s{stage}"),
                            Site::at(dev, i, op),
                        );
                        return;
                    }
                }
                Instr::AllReduceWait { stage } => {
                    if ar_wait.insert(stage, i).is_some() {
                        out.error(
                            "sync-order",
                            format!("device {dev}: duplicate AllReduceWait s{stage}"),
                            Site::at(dev, i, op),
                        );
                        return;
                    }
                }
                Instr::OptimStep { stage } => {
                    if optim.insert(stage, i).is_some() {
                        out.error(
                            "sync-order",
                            format!("device {dev}: duplicate OptimStep s{stage}"),
                            Site::at(dev, i, op),
                        );
                        return;
                    }
                }
                _ => {}
            }
        }
        for &st in &held {
            let (Some(&b), Some(&a), Some(&w), Some(&o)) = (
                last_bwd.get(&st),
                ar_start.get(&st),
                ar_wait.get(&st),
                optim.get(&st),
            ) else {
                out.error(
                    "sync-order",
                    format!("device {dev}: stage {st} missing bwd/allreduce/optim"),
                    Site::device(dev),
                );
                return;
            };
            if b >= a {
                out.push(Diagnostic {
                    severity: Severity::Error,
                    code: "sync-order",
                    message: format!("device {dev}: AllReduceStart s{st} before last backward"),
                    site: Site::at(dev, a, &ops[a]),
                    witness: vec![Site::at(dev, b, &ops[b])],
                });
                return;
            }
            if a >= w {
                out.push(Diagnostic {
                    severity: Severity::Error,
                    code: "sync-order",
                    message: format!("device {dev}: AllReduceWait s{st} before its start"),
                    site: Site::at(dev, w, &ops[w]),
                    witness: vec![Site::at(dev, a, &ops[a])],
                });
                return;
            }
            if w >= o {
                out.push(Diagnostic {
                    severity: Severity::Error,
                    code: "sync-order",
                    message: format!("device {dev}: OptimStep s{st} before allreduce completion"),
                    site: Site::at(dev, o, &ops[o]),
                    witness: vec![Site::at(dev, w, &ops[w])],
                });
                return;
            }
            if s.cfg.sync == SyncPolicy::Eager {
                // Eager: start fires immediately after the last backward
                // touching the stage (possibly interleaved with other
                // stages' starts, but before any further compute op).
                let next_comp = ops[b + 1..]
                    .iter()
                    .position(Instr::is_compute)
                    .map_or(ops.len(), |k| b + 1 + k);
                if a >= next_comp {
                    out.push(Diagnostic {
                        severity: Severity::Error,
                        code: "sync-order",
                        message: format!(
                            "device {dev}: eager AllReduceStart s{st} delayed past compute"
                        ),
                        site: Site::at(dev, a, &ops[a]),
                        witness: vec![Site::at(dev, next_comp, &ops[next_comp])],
                    });
                    return;
                }
            }
        }
    }
}

/// Invariant 2 (global) + 5: streams re-time without deadlock; the merge
/// never stretches a device beyond serialized execution (conflict-free by
/// construction — retime would produce overlap-free intervals anyway, so
/// here we assert the op multiset per device fits the makespan).
fn collect_retimes(s: &Schedule, out: &mut Diagnostics) {
    let costs = Costs::default();
    let t = match retime(&s.compute_order, &s.placement, &costs) {
        Ok(t) => t,
        Err(e) => {
            out.error("retime", format!("retime failed: {e}"), Site::none());
            return;
        }
    };
    // Intervals on one device must not overlap (they cannot, by
    // construction of retime; this is a tripwire for retime regressions).
    for (dev, ops) in t.devices.iter().enumerate() {
        for w in ops.windows(2) {
            if w[0].end > w[1].start {
                out.error(
                    "retime",
                    format!("device {dev}: overlapping ops {} and {}", w[0].op, w[1].op),
                    Site::device(dev),
                );
                return;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::schedule::ir::{ScheduleConfig, ScheduleKind};
    use crate::schedule::{build, build_with_costs};

    fn first_msg(f: impl FnOnce(&mut Diagnostics)) -> Option<String> {
        let mut d = Diagnostics::new();
        f(&mut d);
        d.first_error().map(|e| e.message.clone())
    }

    #[test]
    fn all_kinds_validate_n_eq_d() {
        for kind in ScheduleKind::ALL {
            let s = build(&ScheduleConfig::new(kind, 4, 4)).unwrap();
            validate(&s).unwrap_or_else(|e| panic!("{kind}: {e}"));
        }
    }

    #[test]
    fn all_kinds_validate_n_eq_2d_and_4d() {
        for kind in ScheduleKind::ALL {
            for n in [8usize, 16] {
                let s = build(&ScheduleConfig::new(kind, 4, n)).unwrap();
                validate(&s).unwrap_or_else(|e| panic!("{kind} N={n}: {e}"));
            }
        }
    }

    #[test]
    fn validate_with_lazy_sync() {
        let s = build(
            &ScheduleConfig::new(ScheduleKind::BitPipe, 4, 8).with_sync(SyncPolicy::Lazy),
        )
        .unwrap();
        validate(&s).unwrap();
    }

    #[test]
    fn tampered_schedule_caught_missing_op() {
        let mut s = build(&ScheduleConfig::new(ScheduleKind::Dapple, 4, 4)).unwrap();
        s.compute_order[2].pop();
        let msg = first_msg(|d| collect_completeness(&s, d)).unwrap();
        assert!(msg.contains("missing compute op"), "{msg}");
    }

    #[test]
    fn tampered_schedule_caught_duplicate() {
        let mut s = build(&ScheduleConfig::new(ScheduleKind::Dapple, 4, 4)).unwrap();
        let op = s.compute_order[1][0];
        s.compute_order[1].push(op);
        let msg = first_msg(|d| collect_completeness(&s, d)).unwrap();
        assert!(msg.contains("duplicate compute op"), "{msg}");
    }

    #[test]
    fn entry_stage_recv_act_rejected() {
        // A stage-0 RecvAct has no producer; validation must reject it
        // (the simulator guards the same hazard as a deadlock report).
        let mut s = build(&ScheduleConfig::new(ScheduleKind::Dapple, 4, 4)).unwrap();
        s.device_ops[0].insert(0, Instr::RecvAct { from: 1, pipe: 0, stage: 0, mb: 0 });
        let msg = first_msg(|d| collect_comm_pairing(&s, d)).unwrap();
        assert!(msg.contains("entry stage"), "{msg}");
    }

    #[test]
    fn exit_stage_recv_grad_rejected() {
        let mut s = build(&ScheduleConfig::new(ScheduleKind::Dapple, 4, 4)).unwrap();
        let last = s.placement.n_stages() - 1;
        s.device_ops[0].insert(0, Instr::RecvGrad { from: 1, pipe: 0, stage: last, mb: 0 });
        let msg = first_msg(|d| collect_comm_pairing(&s, d)).unwrap();
        assert!(msg.contains("exit stage"), "{msg}");
    }

    #[test]
    fn tampered_stream_caught_unpaired_send() {
        let mut s = build(&ScheduleConfig::new(ScheduleKind::Dapple, 4, 4)).unwrap();
        // Remove a RecvAct from device 1.
        let idx = s.device_ops[1]
            .iter()
            .position(|i| matches!(i, Instr::RecvAct { .. }))
            .unwrap();
        s.device_ops[1].remove(idx);
        let msg = first_msg(|d| collect_comm_pairing(&s, d)).unwrap();
        assert!(msg.contains("unpaired P2P message"), "{msg}");
    }

    #[test]
    fn tampered_stream_caught_bwd_before_fwd() {
        let mut s = build(&ScheduleConfig::new(ScheduleKind::GPipe, 2, 2)).unwrap();
        // Swap the first forward and the last backward on device 0.
        let n = s.compute_order[0].len();
        s.compute_order[0].swap(0, n - 1);
        assert!(validate(&s).is_err());
    }

    #[test]
    fn eager_sync_checked_strictly() {
        let mut s = build_with_costs(
            &ScheduleConfig::new(ScheduleKind::BitPipe, 4, 4),
            &Costs::default(),
        )
        .unwrap();
        // Delay one eager AllReduceStart past the next compute op: invalid.
        let dev = 0;
        let i = s.device_ops[dev]
            .iter()
            .position(|i| matches!(i, Instr::AllReduceStart { .. }))
            .unwrap();
        let ar = s.device_ops[dev].remove(i);
        // Re-insert after the last compute op.
        let last_comp = s.device_ops[dev]
            .iter()
            .rposition(Instr::is_compute)
            .unwrap();
        if last_comp + 1 > i {
            s.device_ops[dev].insert(last_comp + 1, ar);
            let msg = first_msg(|d| collect_sync_semantics(&s, d)).unwrap();
            assert!(msg.contains("delayed past compute"), "{msg}");
        }
    }

    #[test]
    fn validate_first_error_matches_insertion_order() {
        // A missing compute op must surface as the completeness message
        // even though later passes (pairing, sync) would also complain.
        let mut s = build(&ScheduleConfig::new(ScheduleKind::Dapple, 4, 4)).unwrap();
        s.compute_order[2].pop();
        let e = validate(&s).unwrap_err().to_string();
        assert!(e.contains("missing compute op"), "{e}");
    }
}
