//! Schedule validation: the invariants every generated schedule must hold.
//!
//! These are the correctness rules stated or implied by the paper:
//!
//! 1. **Completeness** — every (pipe, stage, micro-batch) chunk runs its
//!    forward and backward exactly once, on the device that hosts it.
//! 2. **Dataflow order** — within each device stream, `F(s,m)` appears
//!    after its producer hand-off would be available, `B(s,m)` after
//!    `F(s,m)`; globally the streams re-time without deadlock (checked by
//!    [`super::asap::retime`]).
//! 3. **Comm pairing** — every `SendAct`/`SendGrad` has exactly one
//!    matching `RecvAct`/`RecvGrad` on the destination device and vice
//!    versa; local copies only connect co-located chunks.
//! 4. **Synchronous semantics (flush)** — on each device, every
//!    `AllReduceStart{stage}` comes after the last local backward touching
//!    that stage, `AllReduceWait` after the start, `OptimStep` after the
//!    wait; exactly one of each per held stage per iteration.
//! 5. **No-conflict merge** — the fused bidirectional schedule never asks
//!    a device to run two compute ops in the same time slot (guaranteed by
//!    construction for even D; checked geometrically here).
//!
//! The property-based tests in `rust/tests/prop_schedule.rs` drive this
//! module over randomly drawn configurations.

use super::asap::{retime, Costs};
use super::ir::{CompOp, Instr, OpKind, Schedule, SyncPolicy};
use anyhow::{bail, ensure, Result};
use std::collections::{HashMap, HashSet};

/// Run every schedule invariant; returns the first violation as an error.
pub fn validate(schedule: &Schedule) -> Result<()> {
    check_completeness(schedule)?;
    check_device_local_order(schedule)?;
    check_comm_pairing(schedule)?;
    check_sync_semantics(schedule)?;
    check_retimes(schedule)?;
    Ok(())
}

/// Invariant 1: every chunk op exactly once, on its host device.
fn check_completeness(s: &Schedule) -> Result<()> {
    let p = &s.placement;
    let n_stages = p.n_stages();
    let mut seen: HashSet<CompOp> = HashSet::new();
    for (dev, ops) in s.compute_order.iter().enumerate() {
        for op in ops {
            ensure!(
                p.device(op.pipe, op.stage) == dev,
                "op {op} scheduled on device {dev}, placed on {}",
                p.device(op.pipe, op.stage)
            );
            ensure!(seen.insert(*op), "duplicate compute op {op}");
        }
    }
    for (m, &pipe) in s.pipe_of_mb.iter().enumerate() {
        for stage in 0..n_stages {
            for kind in [OpKind::Forward, OpKind::Backward] {
                let op = CompOp { kind, pipe, stage, mb: m };
                ensure!(seen.remove(&op), "missing compute op {op}");
            }
        }
    }
    ensure!(seen.is_empty(), "extra compute ops beyond the N micro-batches: {:?}", seen);
    Ok(())
}

/// Invariant 2 (local part): on each device stream, B(s,m) after F(s,m);
/// local chunk chains in dataflow order.
fn check_device_local_order(s: &Schedule) -> Result<()> {
    for (dev, ops) in s.compute_order.iter().enumerate() {
        let mut pos: HashMap<CompOp, usize> = HashMap::new();
        for (i, op) in ops.iter().enumerate() {
            pos.insert(*op, i);
        }
        for op in ops {
            if op.kind == OpKind::Backward {
                let f = CompOp::fwd(op.pipe, op.stage, op.mb);
                if let Some(&fi) = pos.get(&f) {
                    ensure!(
                        fi < pos[op],
                        "device {dev}: {op} precedes its own forward {f}"
                    );
                }
            }
        }
    }
    Ok(())
}

/// Invariant 3: sends and receives pair one-to-one across devices, local
/// copies connect co-located chunks only.
fn check_comm_pairing(s: &Schedule) -> Result<()> {
    let p = &s.placement;
    // (from, to, kind, pipe, stage, mb) -> count. kind: 0 act, 1 grad.
    let mut sends: HashMap<(usize, usize, u8, usize, usize, usize), i64> = HashMap::new();
    for (dev, ops) in s.device_ops.iter().enumerate() {
        for op in ops {
            match *op {
                Instr::SendAct { to, pipe, stage, mb } => {
                    *sends.entry((dev, to, 0, pipe, stage, mb)).or_default() += 1;
                }
                Instr::RecvAct { from, pipe, stage, mb } => {
                    // Receiver tags with its own (consumer) stage; the
                    // producer side used stage-1. Stage 0 has no producer —
                    // rejecting it here keeps the simulator's entry-stage
                    // guard (`sim::engine`) a dead-stream diagnostic rather
                    // than a reachable state.
                    ensure!(
                        stage > 0,
                        "device {dev}: RecvAct for entry stage (no producer exists)"
                    );
                    *sends.entry((from, dev, 0, pipe, stage - 1, mb)).or_default() -= 1;
                }
                Instr::SendGrad { to, pipe, stage, mb } => {
                    *sends.entry((dev, to, 1, pipe, stage, mb)).or_default() += 1;
                }
                Instr::RecvGrad { from, pipe, stage, mb } => {
                    // Receiver's stage s consumes grad produced by s+1; the
                    // exit stage has no downstream producer.
                    ensure!(
                        stage + 1 < p.n_stages(),
                        "device {dev}: RecvGrad for exit stage (no producer exists)"
                    );
                    *sends.entry((from, dev, 1, pipe, stage + 1, mb)).or_default() -= 1;
                }
                Instr::LocalCopyAct { pipe, stage, mb } => {
                    let _ = mb;
                    ensure!(
                        stage + 1 < p.n_stages(),
                        "LocalCopyAct from the last stage"
                    );
                    ensure!(
                        p.device(pipe, stage) == p.device(pipe, stage + 1),
                        "LocalCopyAct between non-co-located stages {stage},{}",
                        stage + 1
                    );
                    ensure!(
                        p.device(pipe, stage) == dev,
                        "LocalCopyAct on wrong device"
                    );
                }
                Instr::LocalCopyGrad { pipe, stage, mb } => {
                    let _ = mb;
                    ensure!(stage > 0, "LocalCopyGrad from the entry stage");
                    ensure!(
                        p.device(pipe, stage) == p.device(pipe, stage - 1),
                        "LocalCopyGrad between non-co-located stages"
                    );
                    ensure!(
                        p.device(pipe, stage) == dev,
                        "LocalCopyGrad on wrong device"
                    );
                }
                _ => {}
            }
        }
    }
    for (k, v) in sends {
        ensure!(v == 0, "unpaired P2P message {k:?} (imbalance {v})");
    }
    Ok(())
}

/// Invariant 4: flush semantics per device.
fn check_sync_semantics(s: &Schedule) -> Result<()> {
    for (dev, ops) in s.device_ops.iter().enumerate() {
        let mut held: Vec<usize> =
            s.placement.chunks_on[dev].iter().map(|&(_, st)| st).collect();
        held.sort_unstable();
        held.dedup();

        let mut last_bwd: HashMap<usize, usize> = HashMap::new();
        let mut ar_start: HashMap<usize, usize> = HashMap::new();
        let mut ar_wait: HashMap<usize, usize> = HashMap::new();
        let mut optim: HashMap<usize, usize> = HashMap::new();
        for (i, op) in ops.iter().enumerate() {
            match *op {
                Instr::Backward { stage, .. } => {
                    last_bwd.insert(stage, i);
                }
                Instr::AllReduceStart { stage } => {
                    ensure!(
                        ar_start.insert(stage, i).is_none(),
                        "device {dev}: duplicate AllReduceStart s{stage}"
                    );
                }
                Instr::AllReduceWait { stage } => {
                    ensure!(
                        ar_wait.insert(stage, i).is_none(),
                        "device {dev}: duplicate AllReduceWait s{stage}"
                    );
                }
                Instr::OptimStep { stage } => {
                    ensure!(
                        optim.insert(stage, i).is_none(),
                        "device {dev}: duplicate OptimStep s{stage}"
                    );
                }
                _ => {}
            }
        }
        for &st in &held {
            let (Some(&b), Some(&a), Some(&w), Some(&o)) = (
                last_bwd.get(&st),
                ar_start.get(&st),
                ar_wait.get(&st),
                optim.get(&st),
            ) else {
                bail!("device {dev}: stage {st} missing bwd/allreduce/optim");
            };
            ensure!(b < a, "device {dev}: AllReduceStart s{st} before last backward");
            ensure!(a < w, "device {dev}: AllReduceWait s{st} before its start");
            ensure!(w < o, "device {dev}: OptimStep s{st} before allreduce completion");
            if s.cfg.sync == SyncPolicy::Eager {
                // Eager: start fires immediately after the last backward
                // touching the stage (possibly interleaved with other
                // stages' starts, but before any further compute op).
                let next_comp = ops[b + 1..]
                    .iter()
                    .position(|i| matches!(i, Instr::Forward { .. } | Instr::Backward { .. }))
                    .map(|k| b + 1 + k)
                    .unwrap_or(ops.len());
                ensure!(
                    a < next_comp,
                    "device {dev}: eager AllReduceStart s{st} delayed past compute"
                );
            }
        }
    }
    Ok(())
}

/// Invariant 2 (global) + 5: streams re-time without deadlock; the merge
/// never stretches a device beyond serialized execution (conflict-free by
/// construction — retime would produce overlap-free intervals anyway, so
/// here we assert the op multiset per device fits the makespan).
fn check_retimes(s: &Schedule) -> Result<()> {
    let costs = Costs::default();
    let t = retime(&s.compute_order, &s.placement, &costs)
        .map_err(|e| anyhow::anyhow!("retime failed: {e}"))?;
    // Intervals on one device must not overlap (they cannot, by
    // construction of retime; this is a tripwire for retime regressions).
    for (dev, ops) in t.devices.iter().enumerate() {
        for w in ops.windows(2) {
            ensure!(
                w[0].end <= w[1].start,
                "device {dev}: overlapping ops {} and {}",
                w[0].op,
                w[1].op
            );
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::schedule::ir::{ScheduleConfig, ScheduleKind};
    use crate::schedule::{build, build_with_costs};

    #[test]
    fn all_kinds_validate_n_eq_d() {
        for kind in ScheduleKind::ALL {
            let s = build(&ScheduleConfig::new(kind, 4, 4)).unwrap();
            validate(&s).unwrap_or_else(|e| panic!("{kind}: {e}"));
        }
    }

    #[test]
    fn all_kinds_validate_n_eq_2d_and_4d() {
        for kind in ScheduleKind::ALL {
            for n in [8usize, 16] {
                let s = build(&ScheduleConfig::new(kind, 4, n)).unwrap();
                validate(&s).unwrap_or_else(|e| panic!("{kind} N={n}: {e}"));
            }
        }
    }

    #[test]
    fn validate_with_lazy_sync() {
        let s = build(
            &ScheduleConfig::new(ScheduleKind::BitPipe, 4, 8).with_sync(SyncPolicy::Lazy),
        )
        .unwrap();
        validate(&s).unwrap();
    }

    #[test]
    fn tampered_schedule_caught_missing_op() {
        let mut s = build(&ScheduleConfig::new(ScheduleKind::Dapple, 4, 4)).unwrap();
        s.compute_order[2].pop();
        assert!(check_completeness(&s).is_err());
    }

    #[test]
    fn tampered_schedule_caught_duplicate() {
        let mut s = build(&ScheduleConfig::new(ScheduleKind::Dapple, 4, 4)).unwrap();
        let op = s.compute_order[1][0];
        s.compute_order[1].push(op);
        assert!(check_completeness(&s).is_err());
    }

    #[test]
    fn entry_stage_recv_act_rejected() {
        // A stage-0 RecvAct has no producer; validation must reject it
        // (the simulator guards the same hazard as a deadlock report).
        let mut s = build(&ScheduleConfig::new(ScheduleKind::Dapple, 4, 4)).unwrap();
        s.device_ops[0].insert(0, Instr::RecvAct { from: 1, pipe: 0, stage: 0, mb: 0 });
        let e = check_comm_pairing(&s).unwrap_err();
        assert!(e.to_string().contains("entry stage"), "{e}");
    }

    #[test]
    fn exit_stage_recv_grad_rejected() {
        let mut s = build(&ScheduleConfig::new(ScheduleKind::Dapple, 4, 4)).unwrap();
        let last = s.placement.n_stages() - 1;
        s.device_ops[0].insert(0, Instr::RecvGrad { from: 1, pipe: 0, stage: last, mb: 0 });
        let e = check_comm_pairing(&s).unwrap_err();
        assert!(e.to_string().contains("exit stage"), "{e}");
    }

    #[test]
    fn tampered_stream_caught_unpaired_send() {
        let mut s = build(&ScheduleConfig::new(ScheduleKind::Dapple, 4, 4)).unwrap();
        // Remove a RecvAct from device 1.
        let idx = s.device_ops[1]
            .iter()
            .position(|i| matches!(i, Instr::RecvAct { .. }))
            .unwrap();
        s.device_ops[1].remove(idx);
        assert!(check_comm_pairing(&s).is_err());
    }

    #[test]
    fn tampered_stream_caught_bwd_before_fwd() {
        let mut s = build(&ScheduleConfig::new(ScheduleKind::GPipe, 2, 2)).unwrap();
        // Swap the first forward and the last backward on device 0.
        let n = s.compute_order[0].len();
        s.compute_order[0].swap(0, n - 1);
        assert!(validate(&s).is_err());
    }

    #[test]
    fn eager_sync_checked_strictly() {
        let mut s = build_with_costs(
            &ScheduleConfig::new(ScheduleKind::BitPipe, 4, 4),
            &Costs::default(),
        )
        .unwrap();
        // Delay one eager AllReduceStart past the next compute op: invalid.
        let dev = 0;
        let i = s.device_ops[dev]
            .iter()
            .position(|i| matches!(i, Instr::AllReduceStart { .. }))
            .unwrap();
        let ar = s.device_ops[dev].remove(i);
        // Re-insert after the last compute op.
        let last_comp = s.device_ops[dev]
            .iter()
            .rposition(|i| matches!(i, Instr::Forward { .. } | Instr::Backward { .. }))
            .unwrap();
        if last_comp + 1 > i {
            s.device_ops[dev].insert(last_comp + 1, ar);
            assert!(check_sync_semantics(&s).is_err());
        }
    }
}
