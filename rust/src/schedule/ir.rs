//! Instruction IR for synchronous pipeline-parallel schedules.
//!
//! A *schedule* is, per device, an ordered stream of [`Instr`]s: compute ops
//! (forward / backward of a model chunk on one micro-batch), point-to-point
//! communication ops (send/recv of activations and gradients), local copies
//! (the V-shape payoff: producer and consumer chunk co-located), collective
//! gradient synchronization, and optimizer steps.
//!
//! Backward exists in two shapes. The *fused* [`Instr::Backward`] computes
//! both gradient halves in one op — every classic family uses it. The
//! *split* pair [`Instr::BackwardInput`] (activation gradient, `Bi`) and
//! [`Instr::BackwardWeight`] (weight gradient, `W`) decouples them so a
//! scheduler can defer weight-grad work into pipeline bubbles — the zero-
//! bubble discipline ([`ScheduleKind::ZeroBubble`]): `Bi` sits on the
//! critical path (it feeds the upstream stage), `W` only feeds the
//! optimizer and can run whenever its device is otherwise idle, FIFO per
//! (device, chunk). Every `Bi` must be followed by its matching `W` on the
//! same device before the iteration's collectives — the validator and
//! `schedule/lint.rs` enforce the pairing, and the memory model charges
//! the activation stash until `Bi` *and* a weight-grad pin until `W`
//! (see `sim/memory.rs`).
//!
//! The same IR drives three consumers:
//!   * the **analysis engine** (`analysis.rs`) — bubble ratio, peak memory,
//!     communication volume (paper Tables 2 and 6);
//!   * the **discrete-event simulator** (`crate::sim`) — virtual-time
//!     execution under a cluster cost model (paper Figs 8–11, Tables 4/5/7);
//!   * the **real training runtime** (`crate::train`) — threads-as-devices
//!     executing AOT-compiled XLA chunk executables.

use std::fmt;

/// Device index within one pipeline-parallel group, `0..D`.
pub type DeviceId = usize;
/// Model stage (chunk) index within one pipeline replica, `0..v*D`.
pub type StageId = usize;
/// Micro-batch index within one training iteration, `0..N` (global ids;
/// bidirectional schedules partition them between the two pipelines).
pub type MicroBatch = usize;
/// Pipeline replica index: `0` = down pipeline, `1` = up pipeline.
pub type PipeId = usize;

/// Compute op kind.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum OpKind {
    Forward,
    /// Fused backward: activation grad + weight grad in one op (classic
    /// families).
    Backward,
    /// Activation-grad half of a split backward (`Bi`): on the critical
    /// path, produces the gradient sent upstream.
    BackwardInput,
    /// Weight-grad half of a split backward (`W`): deferred off the
    /// critical path, dequeued FIFO per (device, chunk).
    BackwardWeight,
}

/// A single compute op: run chunk `stage` of pipeline replica `pipe` on
/// micro-batch `mb`, in the given direction.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct CompOp {
    pub kind: OpKind,
    pub pipe: PipeId,
    pub stage: StageId,
    pub mb: MicroBatch,
}

impl CompOp {
    pub fn fwd(pipe: PipeId, stage: StageId, mb: MicroBatch) -> Self {
        CompOp { kind: OpKind::Forward, pipe, stage, mb }
    }
    pub fn bwd(pipe: PipeId, stage: StageId, mb: MicroBatch) -> Self {
        CompOp { kind: OpKind::Backward, pipe, stage, mb }
    }
    /// Activation-grad half of a split backward.
    pub fn bwd_input(pipe: PipeId, stage: StageId, mb: MicroBatch) -> Self {
        CompOp { kind: OpKind::BackwardInput, pipe, stage, mb }
    }
    /// Weight-grad half of a split backward.
    pub fn bwd_weight(pipe: PipeId, stage: StageId, mb: MicroBatch) -> Self {
        CompOp { kind: OpKind::BackwardWeight, pipe, stage, mb }
    }
    pub fn is_fwd(&self) -> bool {
        self.kind == OpKind::Forward
    }
}

impl fmt::Display for CompOp {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let k = match self.kind {
            OpKind::Forward => "F",
            OpKind::Backward => "B",
            OpKind::BackwardInput => "Bi",
            OpKind::BackwardWeight => "W",
        };
        write!(f, "{}{}(p{},s{})", k, self.mb, self.pipe, self.stage)
    }
}

/// Full instruction set executed by one device.
///
/// P2P ops are tagged with the *consumer-side* chunk coordinates so the
/// runtime can match sends and receives out of order (tagged mailboxes).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Instr {
    /// Run chunk forward. Stashes the chunk input for the matching backward.
    Forward { pipe: PipeId, stage: StageId, mb: MicroBatch },
    /// Run chunk backward (consumes the stash; accumulates weight grads).
    Backward { pipe: PipeId, stage: StageId, mb: MicroBatch },
    /// Split backward, activation-grad half (`Bi`): produces the gradient
    /// for `stage - 1` but leaves the weight grad to a deferred
    /// [`Instr::BackwardWeight`]. The activation stash slot transitions to
    /// a weight-grad pin (net memory change: zero) until the matching `W`.
    BackwardInput { pipe: PipeId, stage: StageId, mb: MicroBatch },
    /// Split backward, weight-grad half (`W`): consumes the pin left by
    /// the matching [`Instr::BackwardInput`] (FIFO per device/chunk) and
    /// accumulates weight grads. No communication.
    BackwardWeight { pipe: PipeId, stage: StageId, mb: MicroBatch },
    /// Send the activation produced by local `stage` to the device holding
    /// `stage + 1` of the same pipe.
    SendAct { to: DeviceId, pipe: PipeId, stage: StageId, mb: MicroBatch },
    /// Receive the activation feeding local `stage` (produced by `stage-1`).
    RecvAct { from: DeviceId, pipe: PipeId, stage: StageId, mb: MicroBatch },
    /// Send the input-gradient produced by local `stage`'s backward to the
    /// device holding `stage - 1`.
    SendGrad { to: DeviceId, pipe: PipeId, stage: StageId, mb: MicroBatch },
    /// Receive the output-gradient feeding local `stage`'s backward
    /// (produced by `stage+1`'s backward).
    RecvGrad { from: DeviceId, pipe: PipeId, stage: StageId, mb: MicroBatch },
    /// Producer chunk `stage` and consumer chunk `stage+1` are co-located:
    /// forward hand-off is a local copy (no P2P). The V-shape optimization.
    LocalCopyAct { pipe: PipeId, stage: StageId, mb: MicroBatch },
    /// Same for the backward hand-off (`stage` -> `stage-1` gradient).
    LocalCopyGrad { pipe: PipeId, stage: StageId, mb: MicroBatch },
    /// Launch gradient all-reduce for model `stage` across all replicas of
    /// that stage (bidirectional twin + data-parallel group). Non-blocking.
    AllReduceStart { stage: StageId },
    /// Block until the all-reduce for `stage` completed.
    AllReduceWait { stage: StageId },
    /// Apply the optimizer update for local replica(s) of model `stage`.
    OptimStep { stage: StageId },
}

impl Instr {
    /// The compute op, if this is a Forward/Backward/BackwardInput/
    /// BackwardWeight.
    pub fn comp(&self) -> Option<CompOp> {
        match *self {
            Instr::Forward { pipe, stage, mb } => Some(CompOp::fwd(pipe, stage, mb)),
            Instr::Backward { pipe, stage, mb } => Some(CompOp::bwd(pipe, stage, mb)),
            Instr::BackwardInput { pipe, stage, mb } => Some(CompOp::bwd_input(pipe, stage, mb)),
            Instr::BackwardWeight { pipe, stage, mb } => Some(CompOp::bwd_weight(pipe, stage, mb)),
            _ => None,
        }
    }

    /// Is this a compute op (Forward/Backward/BackwardInput/BackwardWeight)?
    pub fn is_compute(&self) -> bool {
        matches!(
            self,
            Instr::Forward { .. }
                | Instr::Backward { .. }
                | Instr::BackwardInput { .. }
                | Instr::BackwardWeight { .. }
        )
    }
}

impl fmt::Display for Instr {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match *self {
            Instr::Forward { pipe, stage, mb } => write!(f, "F{}(p{},s{})", mb, pipe, stage),
            Instr::Backward { pipe, stage, mb } => write!(f, "B{}(p{},s{})", mb, pipe, stage),
            Instr::BackwardInput { pipe, stage, mb } => {
                write!(f, "Bi{}(p{},s{})", mb, pipe, stage)
            }
            Instr::BackwardWeight { pipe, stage, mb } => {
                write!(f, "W{}(p{},s{})", mb, pipe, stage)
            }
            Instr::SendAct { to, pipe, stage, mb } => {
                write!(f, "SA{}(p{},s{})->d{}", mb, pipe, stage, to)
            }
            Instr::RecvAct { from, pipe, stage, mb } => {
                write!(f, "RA{}(p{},s{})<-d{}", mb, pipe, stage, from)
            }
            Instr::SendGrad { to, pipe, stage, mb } => {
                write!(f, "SG{}(p{},s{})->d{}", mb, pipe, stage, to)
            }
            Instr::RecvGrad { from, pipe, stage, mb } => {
                write!(f, "RG{}(p{},s{})<-d{}", mb, pipe, stage, from)
            }
            Instr::LocalCopyAct { pipe, stage, mb } => write!(f, "LC{}(p{},s{})", mb, pipe, stage),
            Instr::LocalCopyGrad { pipe, stage, mb } => {
                write!(f, "LG{}(p{},s{})", mb, pipe, stage)
            }
            Instr::AllReduceStart { stage } => write!(f, "AR+s{}", stage),
            Instr::AllReduceWait { stage } => write!(f, "AR?s{}", stage),
            Instr::OptimStep { stage } => write!(f, "OPT s{}", stage),
        }
    }
}

/// Where each (pipe, stage) chunk lives, and the reverse map.
#[derive(Debug, Clone)]
pub struct Placement {
    /// Number of pipeline devices D.
    pub d: usize,
    /// Chunks per device per pipeline (paper's `v`; 1 for non-interleaved).
    pub v: usize,
    /// Number of pipeline replicas (1 unidirectional, 2 bidirectional).
    pub n_pipes: usize,
    /// `device_of[pipe][stage]` — the device executing that chunk.
    pub device_of: Vec<Vec<DeviceId>>,
    /// `chunks_on[device]` — (pipe, stage) chunks hosted by the device, in
    /// ascending (pipe, stage) order.
    pub chunks_on: Vec<Vec<(PipeId, StageId)>>,
}

impl Placement {
    /// Build from a per-pipe stage->device function.
    pub fn from_fn(
        d: usize,
        v: usize,
        n_pipes: usize,
        f: impl Fn(PipeId, StageId) -> DeviceId,
    ) -> Self {
        let n_stages = v * d;
        let mut device_of = vec![vec![0usize; n_stages]; n_pipes];
        let mut chunks_on = vec![Vec::new(); d];
        for p in 0..n_pipes {
            for s in 0..n_stages {
                let dev = f(p, s);
                assert!(dev < d, "placement out of range: pipe {p} stage {s} -> dev {dev}");
                device_of[p][s] = dev;
                chunks_on[dev].push((p, s));
            }
        }
        Placement { d, v, n_pipes, device_of, chunks_on }
    }

    /// Total stages per pipeline replica (`v * D`).
    pub fn n_stages(&self) -> usize {
        self.v * self.d
    }

    pub fn device(&self, pipe: PipeId, stage: StageId) -> DeviceId {
        self.device_of[pipe][stage]
    }

    /// Devices participating in the gradient all-reduce for model `stage`
    /// (one per pipeline replica holding that stage; deduplicated).
    pub fn allreduce_group(&self, stage: StageId) -> Vec<DeviceId> {
        let mut g: Vec<DeviceId> = (0..self.n_pipes).map(|p| self.device_of[p][stage]).collect();
        g.sort_unstable();
        g.dedup();
        g
    }
}

/// Which pipeline schedule; mirrors the paper's comparison set
/// (Figs 1, 2, 13; Tables 2, 6).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ScheduleKind {
    /// GPipe (Huang et al. 2019): all forwards, then all backwards.
    GPipe,
    /// DAPPLE / PipeDream-Flush 1F1B (Fan et al. 2021; Narayanan et al. 2021a).
    Dapple,
    /// 1F1B-Int, Megatron-LM interleaved looping schedule
    /// (Narayanan et al. 2021b), `v` chunks per device.
    Interleaved,
    /// GEMS (Jain et al. 2020): bidirectional, at most two concurrent
    /// micro-batches; memory-efficient, high bubble ratio.
    Gems,
    /// Chimera (Li & Hoefler 2021): two non-interleaved pipelines in
    /// opposite directions.
    Chimera,
    /// MixPipe (Zhang et al. 2023): bidirectional with regulated injection.
    MixPipe,
    /// BitPipe (this paper): two V-shaped interleaved pipelines fused.
    BitPipe,
    /// Ablation: BitPipe w/o V — looping (1F1B-Int) placement instead of
    /// the V-shape, still bidirectional (paper Table 5).
    BitPipeNoV,
    /// Single-pipeline V-shaped interleaved schedule (paper Fig 4b) —
    /// 1F1B-Int order with the V placement; used to isolate the local-copy
    /// benefit.
    VShaped,
    /// Zero-bubble-style 1F1B (Qi et al. 2023, ZB-H1 discipline): split
    /// backward — `Bi` on the critical path, weight-grad `W` deferred FIFO
    /// per device to fill the ramp-down bubbles. Unidirectional, v = 1.
    ZeroBubble,
}

impl ScheduleKind {
    pub const ALL: [ScheduleKind; 10] = [
        ScheduleKind::GPipe,
        ScheduleKind::Dapple,
        ScheduleKind::Interleaved,
        ScheduleKind::Gems,
        ScheduleKind::Chimera,
        ScheduleKind::MixPipe,
        ScheduleKind::BitPipe,
        ScheduleKind::BitPipeNoV,
        ScheduleKind::VShaped,
        ScheduleKind::ZeroBubble,
    ];

    /// The five headline approaches of the paper's evaluation.
    pub const PAPER_BASELINES: [ScheduleKind; 5] = [
        ScheduleKind::Dapple,
        ScheduleKind::Interleaved,
        ScheduleKind::Chimera,
        ScheduleKind::MixPipe,
        ScheduleKind::BitPipe,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            ScheduleKind::GPipe => "gpipe",
            ScheduleKind::Dapple => "dapple",
            ScheduleKind::Interleaved => "1f1b-int",
            ScheduleKind::Gems => "gems",
            ScheduleKind::Chimera => "chimera",
            ScheduleKind::MixPipe => "mixpipe",
            ScheduleKind::BitPipe => "bitpipe",
            ScheduleKind::BitPipeNoV => "bitpipe-no-v",
            ScheduleKind::VShaped => "v-shaped",
            ScheduleKind::ZeroBubble => "zero-bubble",
        }
    }

    pub fn parse(s: &str) -> Option<ScheduleKind> {
        Self::ALL.iter().copied().find(|k| k.name() == s)
    }

    /// Is this a bidirectional (two-replica) schedule?
    pub fn bidirectional(&self) -> bool {
        matches!(
            self,
            ScheduleKind::Gems
                | ScheduleKind::Chimera
                | ScheduleKind::MixPipe
                | ScheduleKind::BitPipe
                | ScheduleKind::BitPipeNoV
        )
    }

    /// Default chunks-per-device `v` (2 for interleaved family, else 1).
    pub fn default_v(&self) -> usize {
        match self {
            ScheduleKind::Interleaved
            | ScheduleKind::BitPipe
            | ScheduleKind::BitPipeNoV
            | ScheduleKind::VShaped => 2,
            _ => 1,
        }
    }
}

impl fmt::Display for ScheduleKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// When gradient all-reduce is launched relative to the backward passes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SyncPolicy {
    /// Launch each stage's all-reduce as soon as its last local backward
    /// completed, exploiting trailing bubbles (paper Fig 5b; the default).
    Eager,
    /// Synchronize every stage after all local compute (paper Fig 5a; the
    /// `w/o E` ablation of Table 5).
    Lazy,
}

impl SyncPolicy {
    pub fn name(self) -> &'static str {
        match self {
            SyncPolicy::Eager => "eager",
            SyncPolicy::Lazy => "lazy",
        }
    }
}

/// Parameters selecting and shaping a schedule.
#[derive(Debug, Clone, Copy)]
pub struct ScheduleConfig {
    pub kind: ScheduleKind,
    /// Pipeline devices D (even for bidirectional kinds).
    pub d: usize,
    /// Micro-batches per iteration N (paper: multiples of D).
    pub n: usize,
    /// Chunks per device per pipeline (paper's v; Appendix A generalization).
    pub v: usize,
    pub sync: SyncPolicy,
    /// Appendix B early-forwarding when N > D (BitPipe only): pull forwards
    /// of later basic units into the bubbles of earlier units.
    pub early_forward: bool,
}

impl ScheduleConfig {
    pub fn new(kind: ScheduleKind, d: usize, n: usize) -> Self {
        ScheduleConfig { kind, d, n, v: kind.default_v(), sync: SyncPolicy::Eager, early_forward: true }
    }

    pub fn with_v(mut self, v: usize) -> Self {
        self.v = v;
        self
    }

    pub fn with_sync(mut self, sync: SyncPolicy) -> Self {
        self.sync = sync;
        self
    }

    pub fn with_early_forward(mut self, ef: bool) -> Self {
        self.early_forward = ef;
        self
    }

    /// Total chunk-forwards (== chunk-backwards) in one iteration.
    pub fn total_chunk_ops(&self) -> usize {
        self.n * self.v * self.d
    }
}

/// A fully generated schedule: placement + per-device instruction streams.
#[derive(Debug, Clone)]
pub struct Schedule {
    pub cfg: ScheduleConfig,
    pub placement: Placement,
    /// Compute-only per-device order (the "what runs when" skeleton).
    pub compute_order: Vec<Vec<CompOp>>,
    /// Full instruction streams including comm/collective/optimizer ops,
    /// produced by `comm_pass`.
    pub device_ops: Vec<Vec<Instr>>,
    /// Which pipe each micro-batch is injected into.
    pub pipe_of_mb: Vec<PipeId>,
}

impl Schedule {
    /// Micro-batches processed by pipeline replica `p`, ascending.
    pub fn mbs_of_pipe(&self, p: PipeId) -> Vec<MicroBatch> {
        self.pipe_of_mb
            .iter()
            .enumerate()
            .filter(|&(_, &q)| q == p)
            .map(|(m, _)| m)
            .collect()
    }

    /// Number of devices.
    pub fn n_devices(&self) -> usize {
        self.placement.d
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn placement_from_fn_roundtrip() {
        // Looping placement, D=4 v=2: stage s -> device s % D.
        let p = Placement::from_fn(4, 2, 1, |_p, s| s % 4);
        assert_eq!(p.n_stages(), 8);
        assert_eq!(p.device(0, 5), 1);
        assert_eq!(p.chunks_on[1], vec![(0, 1), (0, 5)]);
    }

    #[test]
    fn allreduce_group_dedups() {
        // Bidirectional: down s->s%2, up s->1-(s%2) on D=2, v=1.
        let p = Placement::from_fn(2, 1, 2, |pipe, s| if pipe == 0 { s } else { 1 - s });
        assert_eq!(p.allreduce_group(0), vec![0, 1]);
        assert_eq!(p.allreduce_group(1), vec![0, 1]);
    }

    #[test]
    fn kind_parse_roundtrip() {
        for k in ScheduleKind::ALL {
            assert_eq!(ScheduleKind::parse(k.name()), Some(k));
        }
        assert_eq!(ScheduleKind::parse("nope"), None);
    }

    #[test]
    fn comp_op_display() {
        assert_eq!(CompOp::fwd(0, 3, 7).to_string(), "F7(p0,s3)");
        assert_eq!(CompOp::bwd(1, 0, 2).to_string(), "B2(p1,s0)");
    }
}
