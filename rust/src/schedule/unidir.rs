//! Canonical per-device compute orders for the unidirectional baselines:
//! GPipe, DAPPLE/1F1B (PipeDream-Flush), and Megatron-LM's interleaved
//! 1F1B (paper's "1F1B-Int"). These are explicit textbook constructions —
//! the exact patterns the papers specify — rather than emergent greedy
//! schedules, so the baseline geometry in our reproduction is beyond doubt.

use super::ir::{CompOp, MicroBatch, PipeId, Placement};

/// GPipe (Fig 1a): every device runs all N forwards in micro-batch order,
/// then all N backwards in reverse order (grads drain from the last
/// micro-batch computed).
pub fn gpipe_order(placement: &Placement, pipe: PipeId, mbs: &[MicroBatch]) -> Vec<Vec<CompOp>> {
    assert_eq!(placement.v, 1, "GPipe is non-interleaved");
    let d = placement.d;
    let mut order = vec![Vec::with_capacity(mbs.len() * 2); d];
    for dev in 0..d {
        let s = stage_of_device(placement, pipe, dev);
        for &m in mbs {
            order[dev].push(CompOp::fwd(pipe, s, m));
        }
        for &m in mbs.iter().rev() {
            order[dev].push(CompOp::bwd(pipe, s, m));
        }
    }
    order
}

/// DAPPLE / PipeDream-Flush 1F1B (Fig 1b): device at stage `d` warms up with
/// `min(D-1-d, N)` forwards, then strictly alternates F/B, then drains.
pub fn dapple_order(placement: &Placement, pipe: PipeId, mbs: &[MicroBatch]) -> Vec<Vec<CompOp>> {
    assert_eq!(placement.v, 1, "DAPPLE is non-interleaved");
    let d = placement.d;
    let n = mbs.len();
    let mut order = vec![Vec::with_capacity(n * 2); d];
    for dev in 0..d {
        let s = stage_of_device(placement, pipe, dev);
        // Position along the pipe (0 = first stage) decides the warmup.
        let pos = position_of_stage(placement, pipe, s);
        let w = (d - 1 - pos).min(n);
        for &m in &mbs[..w] {
            order[dev].push(CompOp::fwd(pipe, s, m));
        }
        for k in 0..(n - w) {
            order[dev].push(CompOp::fwd(pipe, s, mbs[w + k]));
            order[dev].push(CompOp::bwd(pipe, s, mbs[k]));
        }
        for &m in &mbs[n - w..] {
            order[dev].push(CompOp::bwd(pipe, s, m));
        }
    }
    order
}

/// Megatron-LM interleaved 1F1B with `v` chunks per device
/// (Narayanan et al. 2021b, the paper's 1F1B-Int baseline; Fig 2b).
///
/// Micro-batches are processed in groups of `g = min(D, n)`; within the
/// steady state each device alternates one-forward-one-backward over
/// "virtual micro-batches" (mb, chunk). `n % D == 0` is required when
/// `n > D` (Megatron's own restriction).
pub fn interleaved_order(
    placement: &Placement,
    pipe: PipeId,
    mbs: &[MicroBatch],
) -> Vec<Vec<CompOp>> {
    let d = placement.d;
    let v = placement.v;
    let n = mbs.len();
    assert!(v >= 1);
    assert!(
        n <= d || n % d == 0,
        "1F1B-Int requires N % D == 0 for N > D (got N={n}, D={d})"
    );
    let g = d.min(n);
    let total = n * v;

    // Virtual iteration k -> (chunk, micro-batch rank) for the forward
    // direction; the backward direction mirrors chunks.
    let fwd_at = |k: usize| -> (usize, usize) {
        let group = k / (g * v);
        let chunk = (k % (g * v)) / g;
        let mb_rank = group * g + k % g;
        (chunk, mb_rank)
    };
    let bwd_at = |k: usize| -> (usize, usize) {
        let group = k / (g * v);
        let chunk = v - 1 - (k % (g * v)) / g;
        let mb_rank = group * g + k % g;
        (chunk, mb_rank)
    };

    let mut order = vec![Vec::with_capacity(total * 2); d];
    for dev in 0..d {
        // Device position along the first chunk round of the pipe.
        let pos = position_of_first_round(placement, pipe, dev);
        let mut w = (d - 1 - pos) * 2 + (v - 1) * g;
        if w > total {
            w = total;
        }
        let seq = &mut order[dev];
        for k in 0..w {
            let (c, r) = fwd_at(k);
            seq.push(CompOp::fwd(pipe, stage_of_chunk(placement, pipe, dev, c), mbs[r]));
        }
        for i in 0..(total - w) {
            let (cf, rf) = fwd_at(w + i);
            seq.push(CompOp::fwd(pipe, stage_of_chunk(placement, pipe, dev, cf), mbs[rf]));
            let (cb, rb) = bwd_at(i);
            seq.push(CompOp::bwd(pipe, stage_of_chunk(placement, pipe, dev, cb), mbs[rb]));
        }
        for i in (total - w)..total {
            let (cb, rb) = bwd_at(i);
            seq.push(CompOp::bwd(pipe, stage_of_chunk(placement, pipe, dev, cb), mbs[rb]));
        }
    }
    order
}

/// The single stage a device holds in a non-interleaved pipe.
fn stage_of_device(placement: &Placement, pipe: PipeId, dev: usize) -> usize {
    let stages: Vec<usize> = placement.chunks_on[dev]
        .iter()
        .filter(|&&(p, _)| p == pipe)
        .map(|&(_, s)| s)
        .collect();
    assert_eq!(stages.len(), 1, "device {dev} holds {} stages of pipe {pipe}", stages.len());
    stages[0]
}

/// The `c`-th chunk (ascending stage id) a device holds for a pipe.
fn stage_of_chunk(placement: &Placement, pipe: PipeId, dev: usize, c: usize) -> usize {
    let mut stages: Vec<usize> = placement.chunks_on[dev]
        .iter()
        .filter(|&&(p, _)| p == pipe)
        .map(|&(_, s)| s)
        .collect();
    stages.sort_unstable();
    stages[c]
}

/// Pipeline position (0 = entry) of a non-interleaved stage.
fn position_of_stage(placement: &Placement, pipe: PipeId, stage: usize) -> usize {
    // Stage ids already run in dataflow order.
    let _ = placement;
    let _ = pipe;
    stage
}

/// Pipeline position of a device within the first chunk round (stages
/// `0..D` of the pipe): the index at which dataflow first reaches it.
fn position_of_first_round(placement: &Placement, pipe: PipeId, dev: usize) -> usize {
    for s in 0..placement.d {
        if placement.device(pipe, s) == dev {
            return s;
        }
    }
    unreachable!("device {dev} not in first round of pipe {pipe}")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::schedule::asap::{retime, Costs};

    fn chain(d: usize) -> Placement {
        Placement::from_fn(d, 1, 1, |_p, s| s)
    }

    fn looping(d: usize, v: usize) -> Placement {
        Placement::from_fn(d, v, 1, |_p, s| s % d)
    }

    #[test]
    fn gpipe_bubble_matches_formula() {
        // GPipe bubble ratio = (D-1)/(N+D-1) in both F and B phases; with
        // tb = 2tf the per-device bubble time is (D-1)*(tf+tb).
        for (d, n) in [(4usize, 4usize), (4, 8), (8, 8)] {
            let p = chain(d);
            let mbs: Vec<usize> = (0..n).collect();
            let order = gpipe_order(&p, 0, &mbs);
            let costs = Costs::default();
            let t = retime(&order, &p, &costs).unwrap();
            let ideal = (n as u64) * 36;
            assert_eq!(t.makespan, ideal + (d as u64 - 1) * 36, "D={d} N={n}");
        }
    }

    #[test]
    fn dapple_bubble_equals_gpipe_but_memory_capped() {
        // Same bubble as GPipe (Table 2), but in-flight stash on the first
        // device is capped at D, not N.
        for (d, n) in [(4usize, 8usize), (8, 16)] {
            let p = chain(d);
            let mbs: Vec<usize> = (0..n).collect();
            let order = dapple_order(&p, 0, &mbs);
            let costs = Costs::default();
            let t = retime(&order, &p, &costs).unwrap();
            assert_eq!(t.makespan, (n as u64) * 36 + (d as u64 - 1) * 36, "D={d} N={n}");
            // stash depth check on device 0
            let mut depth = 0i64;
            let mut peak = 0i64;
            for op in &order[0] {
                match op.kind {
                    crate::schedule::ir::OpKind::Forward => depth += 1,
                    crate::schedule::ir::OpKind::Backward => depth -= 1,
                    // dapple_order emits fused backwards only.
                    _ => unreachable!("unexpected split backward in 1F1B order"),
                }
                peak = peak.max(depth);
            }
            assert!(peak as usize <= d, "DAPPLE stash {peak} exceeds D={d}");
        }
    }

    #[test]
    fn dapple_last_device_strict_1f1b() {
        let p = chain(4);
        let mbs: Vec<usize> = (0..4).collect();
        let order = dapple_order(&p, 0, &mbs);
        let last = &order[3];
        // F0 B0 F1 B1 F2 B2 F3 B3
        for (i, op) in last.iter().enumerate() {
            assert_eq!(op.mb, i / 2);
            assert_eq!(op.is_fwd(), i % 2 == 0);
        }
    }

    #[test]
    fn interleaved_reduces_bubble_by_v() {
        // 1F1B-Int bubble per device = (D-1)*(tf+tb)/v (Narayanan 2021b).
        let costs = Costs::default();
        for (d, n, v) in [(4usize, 4usize, 2usize), (4, 8, 2), (2, 4, 2), (4, 4, 3)] {
            let p = looping(d, v);
            let mbs: Vec<usize> = (0..n).collect();
            let order = interleaved_order(&p, 0, &mbs);
            let t = retime(&order, &p, &costs).unwrap();
            let ideal = (n as u64) * 36; // per-device total work is v chunks * 36/v
            let bubble = (d as u64 - 1) * 36 / v as u64;
            assert_eq!(t.makespan, ideal + bubble, "D={d} N={n} v={v}");
        }
    }

    #[test]
    fn interleaved_op_multiset_complete() {
        let p = looping(4, 2);
        let mbs: Vec<usize> = (0..8).collect();
        let order = interleaved_order(&p, 0, &mbs);
        let mut fwd = 0;
        let mut bwd = 0;
        let mut seen = std::collections::HashSet::new();
        for ops in &order {
            for op in ops {
                assert!(seen.insert(*op), "duplicate {op}");
                if op.is_fwd() {
                    fwd += 1
                } else {
                    bwd += 1
                }
            }
        }
        assert_eq!(fwd, 8 * 8);
        assert_eq!(bwd, 8 * 8);
    }

    #[test]
    #[should_panic(expected = "1F1B-Int requires")]
    fn interleaved_rejects_ragged_n() {
        let p = looping(4, 2);
        let mbs: Vec<usize> = (0..6).collect();
        let _ = interleaved_order(&p, 0, &mbs);
    }
}
