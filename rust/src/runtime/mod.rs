//! PJRT runtime: loads the AOT artifacts produced by `python/compile/aot.py`
//! (HLO text + a `manifest.txt`) and executes them on the CPU PJRT client.
//!
//! HLO **text** is the interchange format: jax >= 0.5 serializes protos
//! with 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//! parser reassigns ids and round-trips cleanly (see
//! /opt/xla-example/README.md).
//!
//! `PjRtClient` is `Rc`-based (not `Send`), so each worker thread builds
//! its own [`Runtime`]; tensors cross threads as plain `Vec<f32>` and are
//! converted to literals at the executor boundary.

mod manifest;

pub use manifest::{ArtifactMeta, Manifest};

use anyhow::{Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// A compiled artifact ready to execute.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    /// Artifact name (manifest key), for diagnostics.
    pub name: String,
}

impl Executable {
    /// Execute with literal inputs; flattens the jax `return_tuple=True`
    /// tuple wrapper into the plain output list.
    pub fn run(&self, args: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let results = self
            .exe
            .execute::<xla::Literal>(args)
            .with_context(|| format!("executing artifact {}", self.name))?;
        let out = results[0][0]
            .to_literal_sync()
            .with_context(|| format!("fetching result of {}", self.name))?;
        out.to_tuple().with_context(|| format!("untupling result of {}", self.name))
    }

    /// Execute with pre-staged device buffers — the training hot path
    /// (parameter buffers are cached across micro-batches; only
    /// activations/tokens are re-staged per op).
    pub fn run_b(&self, args: &[&xla::PjRtBuffer]) -> Result<Vec<xla::Literal>> {
        let results = self
            .exe
            .execute_b(args)
            .with_context(|| format!("executing artifact {} (buffers)", self.name))?;
        let out = results[0][0]
            .to_literal_sync()
            .with_context(|| format!("fetching result of {}", self.name))?;
        out.to_tuple().with_context(|| format!("untupling result of {}", self.name))
    }

    /// Execute and return the single output as an f32 vector.
    pub fn run1_f32(&self, args: &[xla::Literal]) -> Result<Vec<f32>> {
        let outs = self.run(args)?;
        anyhow::ensure!(outs.len() == 1, "{}: expected 1 output, got {}", self.name, outs.len());
        to_f32_vec(&outs[0])
    }
}

/// Per-thread PJRT runtime with a compile cache.
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    cache: HashMap<String, std::rc::Rc<Executable>>,
    pub manifest: Manifest,
}

impl Runtime {
    /// Open the artifact directory (expects `manifest.txt` inside).
    pub fn open(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = Manifest::load(dir.join("manifest.txt"))?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime { client, dir, cache: HashMap::new(), manifest })
    }

    /// Load (or fetch from cache) an artifact by manifest name.
    pub fn load(&mut self, name: &str) -> Result<std::rc::Rc<Executable>> {
        if let Some(e) = self.cache.get(name) {
            return Ok(e.clone());
        }
        let meta = self
            .manifest
            .artifact(name)
            .with_context(|| format!("artifact {name} not in manifest"))?;
        let path = self.dir.join(&meta.file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 artifact path")?,
        )
        .with_context(|| format!("parsing HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling artifact {name}"))?;
        let e = std::rc::Rc::new(Executable { exe, name: name.to_string() });
        self.cache.insert(name.to_string(), e.clone());
        Ok(e)
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Stage an f32 host slice as a device buffer.
    pub fn buf_f32(&self, data: &[f32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        Ok(self.client.buffer_from_host_buffer(data, dims, None)?)
    }

    /// Stage an i32 host slice as a device buffer.
    pub fn buf_i32(&self, data: &[i32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        Ok(self.client.buffer_from_host_buffer(data, dims, None)?)
    }
}

/// Host `Vec<f32>` -> literal of the given shape.
pub fn f32_literal(data: &[f32], dims: &[i64]) -> Result<xla::Literal> {
    let n: i64 = dims.iter().product();
    anyhow::ensure!(n as usize == data.len(), "shape {dims:?} != len {}", data.len());
    Ok(xla::Literal::vec1(data).reshape(dims)?)
}

/// Host `Vec<i32>` (token ids) -> literal of the given shape.
pub fn i32_literal(data: &[i32], dims: &[i64]) -> Result<xla::Literal> {
    let n: i64 = dims.iter().product();
    anyhow::ensure!(n as usize == data.len(), "shape {dims:?} != len {}", data.len());
    Ok(xla::Literal::vec1(data).reshape(dims)?)
}

/// Literal -> host f32 vector.
pub fn to_f32_vec(lit: &xla::Literal) -> Result<Vec<f32>> {
    Ok(lit.to_vec::<f32>()?)
}

#[cfg(test)]
mod tests {
    use super::*;

    // Runtime tests that need real artifacts live in rust/tests/ (they
    // require `make artifacts` to have run). Here: pure host-side helpers.

    #[test]
    fn f32_literal_roundtrip() {
        let data = vec![1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0];
        let lit = f32_literal(&data, &[2, 3]).unwrap();
        assert_eq!(lit.element_count(), 6);
        assert_eq!(to_f32_vec(&lit).unwrap(), data);
    }

    #[test]
    fn literal_shape_mismatch_rejected() {
        assert!(f32_literal(&[1.0, 2.0], &[3]).is_err());
        assert!(i32_literal(&[1, 2, 3], &[2, 2]).is_err());
    }

    #[test]
    fn i32_literal_roundtrip() {
        let data = vec![5i32, 6, 7, 8];
        let lit = i32_literal(&data, &[4]).unwrap();
        assert_eq!(lit.to_vec::<i32>().unwrap(), data);
    }
}
