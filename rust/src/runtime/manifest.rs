//! Artifact manifest: the contract between `python/compile/aot.py` and the
//! rust runtime. Plain `key=value` lines (see [`crate::config::parse_kv`]):
//!
//! ```text
//! # model geometry
//! hidden=256
//! seq=128
//! batch=4
//! vocab=512
//! n_chunks=4
//! layers_per_chunk=2
//! # artifacts: artifact.<name>=<hlo file>
//! artifact.fwd_embed=fwd_embed.hlo.txt
//! # parameter vector lengths: params.<name>=<len>
//! params.embed=137216
//! ```

use crate::config::{parse_kv, KvExt};
use anyhow::{Context, Result};
use std::collections::HashMap;
use std::path::Path;

/// One artifact's manifest entry.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ArtifactMeta {
    pub name: String,
    pub file: String,
}

/// Parsed manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    /// Model preset name the artifacts were lowered for.
    pub model: String,
    /// Model geometry the artifacts were lowered for.
    pub hidden: usize,
    pub seq: usize,
    pub batch: usize,
    pub vocab: usize,
    pub heads: usize,
    /// Total pipeline chunks (v * D) the model was split into.
    pub n_chunks: usize,
    pub layers_per_chunk: usize,
    /// Composed-model loss on the AOT self-check batch (rust integration
    /// tests reproduce this through the artifacts).
    pub selfcheck_loss: f64,
    artifacts: HashMap<String, ArtifactMeta>,
    /// Flat parameter-vector length per chunk role.
    params: HashMap<String, usize>,
    /// Initial parameter vector file per stage index.
    init_files: HashMap<usize, String>,
}

impl Manifest {
    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let path = path.as_ref();
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading manifest {path:?} (run `make artifacts`?)"))?;
        Self::parse(&text)
    }

    pub fn parse(text: &str) -> Result<Self> {
        let kv = parse_kv(text)?;
        let mut artifacts = HashMap::new();
        let mut params = HashMap::new();
        let mut init_files = HashMap::new();
        for (k, v) in &kv {
            if let Some(name) = k.strip_prefix("artifact.") {
                artifacts.insert(
                    name.to_string(),
                    ArtifactMeta { name: name.to_string(), file: v.clone() },
                );
            } else if let Some(name) = k.strip_prefix("params.") {
                params.insert(
                    name.to_string(),
                    v.parse::<usize>().with_context(|| format!("params.{name}={v}"))?,
                );
            } else if let Some(stage) = k.strip_prefix("init.") {
                init_files.insert(
                    stage.parse::<usize>().with_context(|| format!("init.{stage}"))?,
                    v.clone(),
                );
            }
        }
        Ok(Manifest {
            model: kv.get_str("model", "custom"),
            hidden: kv.get_usize("hidden", 0)?,
            seq: kv.get_usize("seq", 0)?,
            batch: kv.get_usize("batch", 0)?,
            vocab: kv.get_usize("vocab", 0)?,
            heads: kv.get_usize("heads", 0)?,
            n_chunks: kv.get_usize("n_chunks", 0)?,
            layers_per_chunk: kv.get_usize("layers_per_chunk", 0)?,
            selfcheck_loss: kv.get_f64("selfcheck.loss", 0.0)?,
            artifacts,
            params,
            init_files,
        })
    }

    pub fn artifact(&self, name: &str) -> Option<&ArtifactMeta> {
        self.artifacts.get(name)
    }

    pub fn artifact_names(&self) -> Vec<&str> {
        let mut names: Vec<&str> = self.artifacts.keys().map(|s| s.as_str()).collect();
        names.sort_unstable();
        names
    }

    /// Flat parameter length of a chunk role (`embed`, `mid`, `head`).
    pub fn param_len(&self, role: &str) -> Option<usize> {
        self.params.get(role).copied()
    }

    /// Chunk role by global stage index: stage 0 embeds, the last stage
    /// computes the loss head, everything between is a mid chunk.
    pub fn role_of_stage(&self, stage: usize) -> &'static str {
        if stage == 0 {
            "embed"
        } else if stage + 1 == self.n_chunks {
            "head"
        } else {
            "mid"
        }
    }

    /// Initial parameter vector file for a stage (relative to the artifact
    /// directory).
    pub fn init_file(&self, stage: usize) -> Option<&str> {
        self.init_files.get(&stage).map(|s| s.as_str())
    }

    /// Activation element count of one inter-chunk tensor (B * S * H).
    pub fn act_len(&self) -> usize {
        self.batch * self.seq * self.hidden
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
model=gpt-tiny
hidden=256
seq=128
batch=4
vocab=512
heads=8
n_chunks=4
layers_per_chunk=2
artifact.fwd_embed=fwd_embed.hlo.txt
artifact.bwd_embed=bwd_embed.hlo.txt
params.embed=137216
params.mid=789504
init.0=init_stage0.bin
init.1=init_stage1.bin
selfcheck.loss=6.291064
";

    #[test]
    fn parse_roundtrip() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.hidden, 256);
        assert_eq!(m.n_chunks, 4);
        assert_eq!(m.heads, 8);
        assert_eq!(m.model, "gpt-tiny");
        assert_eq!(m.artifact("fwd_embed").unwrap().file, "fwd_embed.hlo.txt");
        assert_eq!(m.param_len("mid"), Some(789504));
        assert!(m.artifact("nope").is_none());
        assert_eq!(m.artifact_names(), vec!["bwd_embed", "fwd_embed"]);
        assert_eq!(m.init_file(1), Some("init_stage1.bin"));
        assert!(m.init_file(9).is_none());
        assert!((m.selfcheck_loss - 6.291064).abs() < 1e-9);
        assert_eq!(m.act_len(), 4 * 128 * 256);
    }

    #[test]
    fn roles_by_stage() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.role_of_stage(0), "embed");
        assert_eq!(m.role_of_stage(1), "mid");
        assert_eq!(m.role_of_stage(2), "mid");
        assert_eq!(m.role_of_stage(3), "head");
    }

    #[test]
    fn bad_params_rejected() {
        assert!(Manifest::parse("params.embed=abc").is_err());
    }
}
