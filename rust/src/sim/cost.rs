//! Analytical cost model: maps (model, parallel, cluster) configurations to
//! per-instruction times in seconds.
//!
//! * Compute — transformer FLOP counts (Megatron accounting) over the
//!   device's sustained FLOP rate; backward = 2x forward (paper premise).
//! * P2P — `message_size = dtype * B * S * H` bytes (paper Appendix C)
//!   over the link class between the two physical devices.
//! * All-reduce — ring algorithm: `2 (g-1)/g * bytes / bw_bottleneck`,
//!   where the group spans the bidirectional twin and the W data-parallel
//!   replicas; the bottleneck link class follows the Fig 6 mapping policy
//!   (the scalar formula every backend prices uncontended runs with).
//!   *Alongside* the scalar, the ring is lowered onto its actual physical
//!   path for the contention-aware engine: the group's member devices are
//!   enumerated under the mapping, ordered node-clustered
//!   ([`ClusterConfig::ring_path`]), and each directed hop becomes a
//!   [`RingHop`] carrying its true per-hop traffic. A ring is
//!   step-synchronized — all `g` hops move in lock-step for `2(g-1)`
//!   steps — so each hop occupies its pipe for the whole collective and
//!   the lowering prices each hop's solo work at the scalar duration:
//!   on an idle network the flows reproduce the scalar formula bit for
//!   bit, and any contended hop stretches the whole collective.

use crate::config::{ClusterConfig, LinkId, LinkKind, MappingPolicy, ModelConfig, ParallelConfig};
use crate::schedule::{placement_for, DeviceId, Placement, StageId};
use anyhow::{ensure, Result};

/// One P2P edge of the simulated pipeline group: the payload and the
/// physical pipe it travels on, rather than a precomputed scalar time.
/// This is what the contention-aware engine consumes — it needs to know
/// *which* transfers share a pipe ([`LinkId`]) and how much work each one
/// is (`bytes` at `bw`, plus `lat` once), so it can split bandwidth among
/// concurrent flows.
#[derive(Debug, Clone, Copy)]
pub struct P2pEdge {
    /// Message payload, bytes.
    pub bytes: u64,
    /// Wire latency, seconds.
    pub lat: f64,
    /// Full link bandwidth, bytes/s (shared under contention).
    pub bw: f64,
    /// Identity of the shared physical pipe.
    pub link: LinkId,
    /// Dense flat-arena indices of the shared resources the flow occupies
    /// ([`ClusterConfig::dense_resources_of`]; second slot
    /// [`crate::config::NO_RESOURCE`] for single-resource pipes) — the
    /// contention engine's per-flow key, precomputed so the hot path never
    /// maps a `LinkId` to resources again.
    pub res: (u32, u32),
    /// Data-parallel multiplicity (>= 1): how many of the W pipeline
    /// groups' *identical, synchronized* copies of this transfer land on
    /// the same physical pipe. The simulator executes one group
    /// (`crate::sim` module docs); under contention the other groups'
    /// symmetric traffic is priced by scaling this flow's work — m
    /// synchronized copies sharing one pipe each run at 1/m, which is
    /// exactly work x m for the copy we track.
    pub dp_copies: u32,
}

impl P2pEdge {
    /// Transfer time with the pipe to itself (no contention) — identical,
    /// operation for operation, to [`ClusterConfig::xfer_time`] so the
    /// contended engine degrades bit-for-bit to the fixed-duration model
    /// when a transfer never shares its link.
    pub fn solo_time(&self) -> f64 {
        self.lat + self.bytes as f64 / self.bw
    }
}

/// One directed hop of a collective ring, for the flow lowering: over the
/// whole collective the hop carries `2(g-1)` segments of `bytes/g`
/// (`bytes` here), and — because ring steps are lock-step across all
/// hops — it occupies its pipe for the collective's full scalar duration
/// (`work`, identical on every hop of a ring). The collective completes
/// when its last flow drains: exactly [`CostModel::allreduce_time`] on an
/// idle network, bit for bit, and later whenever any hop shares a wire.
#[derive(Debug, Clone, Copy)]
pub struct RingHop {
    /// Total bytes the hop moves across the collective's 2(g-1) steps.
    pub bytes: f64,
    /// Solo work of the hop's flow, seconds: the scalar collective
    /// duration (step-synchronized hops are busy for all of it).
    pub work: f64,
    /// Fixed wire-latency budget inside `work`, seconds: the `2(g-1)`
    /// per-step latencies of this hop's link class, clamped to `work`.
    /// Under contention the engine pays this part at wall rate (latency
    /// is not shared bandwidth) and fair-shares only the remainder.
    pub lat: f64,
    /// The directed pipe the hop occupies.
    pub link: LinkId,
    /// Dense flat-arena resource indices of the pipe (see
    /// [`P2pEdge::res`]).
    pub res: (u32, u32),
}

/// The (W, D, cluster)-dependent part of the P2P edge tables — link
/// classes, physical pipe identities, and data-parallel copy counts —
/// which is independent of the model and of B. Building it walks the
/// W x D² physical-device mapping, the most expensive piece of
/// [`CostModel`] construction; `grid_search` hoists one instance per
/// (W, D) and re-uses it across every B candidate.
#[derive(Debug, Clone)]
pub struct LinkTopology {
    w: usize,
    d: usize,
    /// Cluster fingerprint (device count, node width, mapping) — the
    /// inputs the pipe identities actually depend on — so a topology
    /// cannot silently be reused against a different cluster.
    cluster_key: (usize, usize, MappingPolicy),
    /// Per directed pipeline-device pair `[a * d + b]`.
    entries: Vec<(LinkKind, LinkId, u32)>,
}

/// The B-dependent slice of a [`CostModel`]: exactly the entries a sweep
/// move along the micro-batch axis changes. Everything else in the model —
/// gradient volumes, all-reduce scalars and ring lowerings, optimizer
/// times — depends only on (model, W, D, v, cluster) and survives a B
/// move untouched. Computed by [`LinkTopology::batch_pricing`], and the
/// single source of truth for these formulas: [`CostModel::with_topology`]
/// consumes it too, so the incremental paths
/// ([`super::dag::DagWeights::rebuild_for_batch_size`],
/// [`CostModel::rebatched`]) cannot drift from the full build — they are
/// bit-identical by construction, and pinned so by tests.
#[derive(Debug, Clone)]
pub struct BatchPricing {
    /// Forward time of one chunk on one micro-batch.
    pub chunk_fwd: f64,
    /// Backward time (2x forward, paper premise).
    pub chunk_bwd: f64,
    /// Activation-gradient (Bi) half of a split backward.
    pub chunk_bwd_input: f64,
    /// Weight-gradient (W) half; `input + weight == chunk_bwd`.
    pub chunk_bwd_weight: f64,
    /// Activation / gradient message bytes.
    pub msg_bytes: u64,
    /// Same-device HBM->HBM copy time.
    pub local_copy: f64,
    /// Solo P2P times `[a * d + b]` over the topology's pipes —
    /// operation-for-operation [`P2pEdge::solo_time`].
    pub p2p: Vec<f64>,
}

impl LinkTopology {
    fn cluster_key(cluster: &ClusterConfig) -> (usize, usize, MappingPolicy) {
        (cluster.n_devices, cluster.devices_per_node, cluster.mapping)
    }

    /// Enumerate the physical pipes of one simulated pipeline group of
    /// depth `d` among `w` data-parallel replicas on `cluster`.
    pub fn new(cluster: &ClusterConfig, w: usize, d: usize) -> Self {
        let w_groups = w.max(1);
        let physical =
            |g: usize, dev: usize| cluster.physical_device(cluster.mapping, g, dev, w_groups, d);
        let mut entries = Vec::with_capacity(d * d);
        for a in 0..d {
            for b in 0..d {
                let (pa, pb) = (physical(0, a), physical(0, b));
                let kind = cluster.link(pa, pb);
                let link = cluster.link_id(pa, pb);
                // Every pipeline group sends this message at the same
                // virtual time; count the groups whose copy shares this
                // physical pipe (always >= 1: group 0 itself).
                let dp_copies = (0..w_groups)
                    .filter(|&g| cluster.link_id(physical(g, a), physical(g, b)) == link)
                    .count() as u32;
                entries.push((kind, link, dp_copies));
            }
        }
        LinkTopology { w: w_groups, d, cluster_key: Self::cluster_key(cluster), entries }
    }

    /// Price the B-dependent entries of a cost model over this topology's
    /// pipes, without touching the B-independent tables (all-reduce rings,
    /// optimizer). Expression-for-expression the computation
    /// [`CostModel::with_topology`] performs — `with_topology` calls this —
    /// so an incremental rebuild from it is bit-identical to a full one.
    /// Same preconditions as `with_topology`: `self` must have been built
    /// for `cluster`, `parallel.w` and `parallel.d`.
    pub fn batch_pricing(
        &self,
        model: &ModelConfig,
        parallel: &ParallelConfig,
        cluster: &ClusterConfig,
    ) -> BatchPricing {
        assert_eq!(
            (self.w, self.d),
            (parallel.w.max(1), parallel.d),
            "link topology built for a different (W, D)"
        );
        assert_eq!(
            self.cluster_key,
            Self::cluster_key(cluster),
            "link topology built for a different cluster"
        );
        let chunks = parallel.v * parallel.d;
        // Layers per chunk (at least one; tiny models on deep pipelines
        // saturate at 1 layer per chunk).
        let layers_per_chunk = (model.n_layers + chunks - 1) / chunks;
        let fwd_flops = model.layer_fwd_flops(parallel.b) * layers_per_chunk as u64;
        // Small micro-batches under-utilize the device (occupancy/launch
        // bound) — the effect behind paper Fig 11(b)'s B sensitivity.
        let eff = cluster.mbs_efficiency(parallel.b);
        let chunk_fwd = fwd_flops as f64 / (cluster.flops * eff);
        let chunk_bwd = 2.0 * chunk_fwd;
        let msg_bytes = model.message_bytes(parallel.b);
        // Pipes are priced against their *overridden* bandwidth
        // ([`ClusterConfig::bw_over`]) so the incremental DAG re-cost path
        // sees the same degraded rates as the full edge tables; with all
        // multipliers at 1.0 this is IEEE-exactly the base rate.
        let p2p = self
            .entries
            .iter()
            .map(|&(kind, link, _)| cluster.lat(kind) + msg_bytes as f64 / cluster.bw_over(link))
            .collect();
        BatchPricing {
            chunk_fwd,
            chunk_bwd,
            chunk_bwd_input: 0.5 * chunk_bwd,
            chunk_bwd_weight: chunk_bwd - 0.5 * chunk_bwd,
            msg_bytes,
            local_copy: cluster.lat(LinkKind::Local)
                + msg_bytes as f64 / cluster.bw_scaled(LinkKind::Local),
            p2p,
        }
    }
}

/// Per-instruction costs in seconds for one simulated pipeline group.
#[derive(Debug, Clone)]
pub struct CostModel {
    /// Forward time of one chunk (stage) on one micro-batch.
    pub chunk_fwd: f64,
    /// Backward time of one chunk on one micro-batch.
    pub chunk_bwd: f64,
    /// Activation-gradient (Bi) half of a split backward — the even split
    /// the schedule IR's `chunk_bi` mirrors in tick units.
    pub chunk_bwd_input: f64,
    /// Weight-gradient (W) half of a split backward; `chunk_bwd_input +
    /// chunk_bwd_weight == chunk_bwd` so split and fused schedules price
    /// the same total backward work.
    pub chunk_bwd_weight: f64,
    /// Activation / gradient message bytes.
    pub msg_bytes: u64,
    /// Gradient bytes per *body* chunk's all-reduce (its transformer
    /// layers; entry/exit chunks add embedding/head bytes on top — see
    /// [`CostModel::allreduce_time`]).
    pub grad_bytes: u64,
    /// All-reduce group size g (bidirectional twins x W replicas).
    pub allreduce_group: usize,
    /// Bottleneck link for the all-reduce under the mapping policy.
    pub allreduce_link: LinkKind,
    /// Cluster parameters (bandwidth/latency tables).
    pub cluster: ClusterConfig,
    /// Pipeline-parallel sizes.
    pub d: usize,
    pub w: usize,
    /// Precomputed P2P edges (bytes + link identity), `[a * d + b]` — the
    /// simulator's hottest lookup, hoisted out of the per-message path.
    /// The single source of truth for P2P pricing: the fixed-duration
    /// engine reads [`P2pEdge::solo_time`], the contended engine the full
    /// edge.
    edges: Vec<P2pEdge>,
    /// Precomputed local-copy time.
    local_copy: f64,
    /// Precomputed per-stage all-reduce times. Entry and exit chunks carry
    /// the embedding / LM-head parameters on top of their transformer
    /// layers, so their gradient volume (and ring time) is heavier than a
    /// body chunk's. Each entry equals the slowest hop of the matching
    /// `ring` path (0 when there is no collective).
    allreduce: Vec<f64>,
    /// Precomputed per-stage ring lowering: the directed hops of each
    /// stage's collective over its physical members, for the contention-
    /// aware engine. Empty when the stage has no collective.
    ring: Vec<Vec<RingHop>>,
    /// Stages per pipeline replica (v * d), sizing `allreduce` and `optim`.
    n_stages: usize,
    /// Precomputed per-stage optimizer-step times (entry/exit chunks
    /// update embedding/LM-head parameters on top of their layers).
    optim: Vec<f64>,
    /// Body-chunk optimizer time, for out-of-range stages.
    optim_body: f64,
    /// Per-stage compute ratios from a layer profile
    /// ([`CostModel::with_layer_profile`]); empty means uniform splits.
    stage_scale: Vec<f64>,
    /// Per-pipeline-device compute multipliers, `[0, d)`: the *max* over
    /// the W data-parallel replicas of each slot's straggler factor —
    /// synchronous DP steps in lock-step, so the slowest replica gates.
    /// Empty when the cluster is compute-uniform.
    dev_mult: Vec<f64>,
    /// Fast-path flag: true when no device or stage carries a non-1.0
    /// compute factor. The pricing accessors then return the raw chunk
    /// fields with **no multiplication at all**, which is what makes the
    /// uniform case bit-identical to the pre-heterogeneity code.
    uniform_compute: bool,
}

impl CostModel {
    pub fn new(model: &ModelConfig, parallel: &ParallelConfig, cluster: &ClusterConfig) -> Self {
        let topo = LinkTopology::new(cluster, parallel.w, parallel.d);
        Self::with_topology(model, parallel, cluster, &topo)
    }

    /// [`CostModel::new`] with the (W, D, cluster)-dependent link tables
    /// precomputed — bit-identical output, used by `grid_search` to share
    /// one [`LinkTopology`] across all B candidates of a (W, D) point.
    /// `topo` must have been built for the same `cluster`, `parallel.w`
    /// and `parallel.d`.
    pub fn with_topology(
        model: &ModelConfig,
        parallel: &ParallelConfig,
        cluster: &ClusterConfig,
        topo: &LinkTopology,
    ) -> Self {
        // The B-dependent entries come from the shared pricing helper (it
        // also carries the (W, D, cluster) asserts); everything below is
        // the B-independent remainder.
        let bp = topo.batch_pricing(model, parallel, cluster);
        let chunks = parallel.v * parallel.d;
        let layers_per_chunk = (model.n_layers + chunks - 1) / chunks;
        let grad_bytes =
            model.params_per_layer() * layers_per_chunk as u64 * model.dtype_bytes as u64;

        // All-reduce group: both directions of the bidirectional pipe (if
        // any) times W replicas.
        let twins = if parallel.kind.bidirectional() { 2 } else { 1 };
        let group = twins * parallel.w;

        // Link class for the all-reduce ring (Fig 6): with the
        // ReplicasTogether mapping all replicas of a stage share a node as
        // long as the group fits; otherwise the ring spills onto IB.
        let allreduce_link = if group == 1 {
            LinkKind::Local
        } else {
            match cluster.mapping {
                MappingPolicy::ReplicasTogether if group <= cluster.devices_per_node => {
                    LinkKind::NvLink
                }
                _ => LinkKind::InfiniBand,
            }
        };

        let mut cm = CostModel {
            chunk_fwd: bp.chunk_fwd,
            chunk_bwd: bp.chunk_bwd,
            chunk_bwd_input: bp.chunk_bwd_input,
            chunk_bwd_weight: bp.chunk_bwd_weight,
            msg_bytes: bp.msg_bytes,
            grad_bytes,
            allreduce_group: group,
            allreduce_link,
            cluster: *cluster,
            d: parallel.d,
            w: parallel.w,
            edges: Vec::new(),
            local_copy: 0.0,
            allreduce: Vec::new(),
            ring: Vec::new(),
            n_stages: parallel.v * parallel.d,
            optim: Vec::new(),
            optim_body: 0.0,
            stage_scale: Vec::new(),
            dev_mult: Vec::new(),
            uniform_compute: true,
        };
        // Precompute the per-instruction tables once; the event-queue
        // engine and the grid-search sweep hit these on every message.
        // Link identities and DP copy counts come from the hoisted
        // topology; only the payload/lat/bw pricing is (model, B)-bound.
        cm.edges = topo
            .entries
            .iter()
            .map(|&(kind, link, dp_copies)| P2pEdge {
                bytes: cm.msg_bytes,
                lat: cm.cluster.lat(kind),
                // Effective (override-scaled) rate of this pipe; exactly
                // the base class rate when every multiplier is 1.0.
                bw: cm.cluster.bw_over(link),
                link,
                res: cm.cluster.dense_resources_of(link),
                dp_copies,
            })
            .collect();
        cm.local_copy = bp.local_copy;
        // Heterogeneous per-stage gradient volumes: the entry chunk carries
        // the token/position embeddings, the exit chunk its own LM-head
        // projection copy — both all-reduce more bytes than a body chunk.
        let embed_bytes = model.embedding_params() * model.dtype_bytes as u64;
        cm.allreduce = (0..cm.n_stages)
            .map(|stage| cm.ring_time(cm.grad_bytes_of(stage, embed_bytes)))
            .collect();
        // Lower each stage's collective onto its physical ring for the
        // contention-aware engine: the twin devices holding the stage
        // under the *canonical* placement of this schedule kind
        // (`placement_for` — identical to what the generator produces;
        // hand-built schedules with a divergent placement would get hops
        // on the canonical links, not theirs) times the W data-parallel
        // replicas, mapped to physical devices and ordered node-clustered.
        // Hops carry their true per-hop traffic and — ring steps being
        // lock-step — occupy their pipes for the stage's full scalar
        // duration, so a solo ring degrades to the scalar formula bit for
        // bit.
        if group > 1 {
            let placement = placement_for(parallel.kind, parallel.d, parallel.v);
            cm.ring = (0..cm.n_stages)
                .map(|stage| {
                    let members = cm.ring_members(&placement.allreduce_group(stage));
                    cm.ring_hops_over(
                        &cluster.ring_path(&members),
                        cm.grad_bytes_of(stage, embed_bytes),
                        cm.allreduce[stage],
                    )
                })
                .collect();
        } else {
            cm.ring = vec![Vec::new(); cm.n_stages];
        }
        let hbm_bw = cm.cluster.bw(LinkKind::Local);
        let optim_of = move |bytes: u64| bytes as f64 * 7.0 / hbm_bw;
        cm.optim = (0..cm.n_stages)
            .map(|stage| optim_of(cm.grad_bytes_of(stage, embed_bytes)))
            .collect();
        cm.optim_body = optim_of(cm.grad_bytes);
        // Per-device compute rows: only materialized when some device is a
        // straggler, so the uniform fast path never even allocates. Each
        // pipeline slot takes the slowest of its W replicas' factors —
        // synchronous data parallelism steps in lock-step.
        if !cluster.is_uniform_compute() {
            let w_groups = parallel.w.max(1);
            cm.dev_mult = (0..parallel.d)
                .map(|dev| {
                    (0..w_groups)
                        .map(|g| {
                            cluster.compute_mult(cluster.physical_device(
                                cluster.mapping,
                                g,
                                dev,
                                w_groups,
                                parallel.d,
                            ))
                        })
                        .fold(0.0f64, f64::max)
                })
                .collect();
            cm.uniform_compute = false;
        }
        cm
    }

    /// Re-split the per-stage costs along a measured layer profile:
    /// `profile[stage]` is the relative compute weight of that stage's
    /// layers (any positive scale; weights are normalized so their mean is
    /// 1). Scales each stage's compute chunks (via the pricing accessors),
    /// its all-reduce scalar + ring-hop work (heavier stages hold more
    /// parameters), and its optimizer step. An all-1.0 profile is exactly
    /// neutral bit-for-bit: the f64 sum of n ones is exact, so every ratio
    /// is exactly 1.0 and `uniform_compute` stays set. Note that *equal
    /// but non-1.0* weights may normalize to ratios a few ulps off 1.0 —
    /// the uniform-identity guarantee is about 1.0 entries, not about
    /// proportionality classes.
    pub fn with_layer_profile(mut self, profile: &[f64]) -> Result<Self> {
        ensure!(
            profile.len() == self.n_stages,
            "layer profile names {} stages, schedule has {}",
            profile.len(),
            self.n_stages
        );
        ensure!(
            profile.iter().all(|&p| p.is_finite() && p > 0.0),
            "layer profile weights must be positive and finite"
        );
        let sum: f64 = profile.iter().sum();
        let n = self.n_stages as f64;
        let ratios: Vec<f64> = profile.iter().map(|&p| p * n / sum).collect();
        for (stage, &r) in ratios.iter().enumerate() {
            self.allreduce[stage] *= r;
            self.optim[stage] *= r;
            // Ring hop work is pinned bit-for-bit to the stage scalar;
            // scaling both sides by the same ratio preserves the pin. The
            // latency budget cannot exceed the (possibly shrunken) work.
            for h in &mut self.ring[stage] {
                h.work *= r;
                h.lat = h.lat.min(h.work);
            }
        }
        if ratios.iter().any(|&r| r != 1.0) {
            self.uniform_compute = false;
        }
        self.stage_scale = ratios;
        Ok(self)
    }

    /// This model re-priced for a different micro-batch size B: recompute
    /// only the B-dependent entries ([`BatchPricing`]) and keep the
    /// B-independent tables — all-reduce scalars, ring lowerings,
    /// optimizer times, link identities — by clone. Bit-identical to a
    /// full [`CostModel::with_topology`] build at `parallel` (pinned in
    /// tests and by the contended-sweep differential); an order of
    /// magnitude cheaper because the ring/optimizer tables never rebuild.
    /// `self` must have been built for the same model, schedule kind, W, D,
    /// v, and cluster — only `parallel.b` may differ.
    pub fn rebatched(
        &self,
        model: &ModelConfig,
        parallel: &ParallelConfig,
        topo: &LinkTopology,
    ) -> Self {
        assert_eq!(
            (parallel.w, parallel.d),
            (self.w, self.d),
            "rebatched across a different (W, D)"
        );
        assert_eq!(parallel.v * parallel.d, self.n_stages, "rebatched across a different v");
        let twins = if parallel.kind.bidirectional() { 2 } else { 1 };
        assert_eq!(
            twins * parallel.w,
            self.allreduce_group,
            "rebatched across a different collective group"
        );
        let bp = topo.batch_pricing(model, parallel, &self.cluster);
        // Model consistency: the gradient volume is B-independent, so a
        // different model (or layer split) cannot slip through silently.
        let chunks = parallel.v * parallel.d;
        let layers_per_chunk = (model.n_layers + chunks - 1) / chunks;
        assert_eq!(
            model.params_per_layer() * layers_per_chunk as u64 * model.dtype_bytes as u64,
            self.grad_bytes,
            "rebatched against a different model"
        );
        let mut cm = self.clone();
        cm.chunk_fwd = bp.chunk_fwd;
        cm.chunk_bwd = bp.chunk_bwd;
        cm.chunk_bwd_input = bp.chunk_bwd_input;
        cm.chunk_bwd_weight = bp.chunk_bwd_weight;
        cm.msg_bytes = bp.msg_bytes;
        cm.local_copy = bp.local_copy;
        // Edges keep their pipe identities and DP copy counts; only the
        // payload changes (solo_time then reproduces bp.p2p bit for bit).
        for e in &mut cm.edges {
            e.bytes = bp.msg_bytes;
        }
        cm
    }

    /// Gradient bytes all-reduced for `stage`: a body chunk's transformer
    /// layers, plus the embedding (entry) or LM-head (exit) parameters.
    fn grad_bytes_of(&self, stage: StageId, embed_bytes: u64) -> u64 {
        let extra = if stage == 0 || stage + 1 == self.n_stages { embed_bytes } else { 0 };
        self.grad_bytes + extra
    }

    /// Physical device of pipeline-device `dev` in the simulated group
    /// (group 0) under the mapping policy.
    fn physical(&self, dev: DeviceId) -> usize {
        self.cluster.physical_device(self.cluster.mapping, 0, dev, self.w.max(1), self.d)
    }

    /// P2P transfer time between pipeline devices `a` and `b` — the edge's
    /// solo time (operation-for-operation [`ClusterConfig::xfer_time`]).
    pub fn p2p_time(&self, a: DeviceId, b: DeviceId) -> f64 {
        self.edges[a * self.d + b].solo_time()
    }

    /// P2P edge between pipeline devices `a` and `b`: payload bytes plus
    /// the physical pipe identity — the contention-aware engine's view
    /// (precomputed table lookup).
    pub fn p2p_edge(&self, a: DeviceId, b: DeviceId) -> P2pEdge {
        self.edges[a * self.d + b]
    }

    /// Local copy time (same device HBM->HBM; precomputed).
    pub fn local_copy_time(&self) -> f64 {
        self.local_copy
    }

    /// Ring all-reduce time for one stage's gradients (precomputed).
    /// Volumes are heterogeneous: the entry chunk (embeddings) and the
    /// exit chunk (LM head) are heavier than body chunks. Out-of-range
    /// stages (hand-built streams) price as a body chunk.
    pub fn allreduce_time(&self, stage: StageId) -> f64 {
        match self.allreduce.get(stage) {
            Some(&t) => t,
            None => self.ring_time(self.grad_bytes),
        }
    }

    /// The flow lowering of one stage's collective: the directed ring hops
    /// the contention-aware engine runs as concurrent flows. `None` when
    /// the stage has no collective (group of 1) or lies outside the
    /// schedule's stage range (such stages keep the scalar pricing).
    pub fn ring_hops(&self, stage: StageId) -> Option<&[RingHop]> {
        match self.ring.get(stage) {
            Some(hops) if !hops.is_empty() => Some(hops.as_slice()),
            _ => None,
        }
    }

    /// Physical devices of one all-reduce group: every member pipeline
    /// device times the W data-parallel replicas, under the mapping
    /// policy. Shared by the per-stage ring tables and the hand-built
    /// fallback so the two lowerings can never diverge.
    fn ring_members(&self, group: &[DeviceId]) -> Vec<usize> {
        let w_groups = self.w.max(1);
        group
            .iter()
            .flat_map(|&dev| {
                (0..w_groups).map(move |g| {
                    self.cluster.physical_device(self.cluster.mapping, g, dev, w_groups, self.d)
                })
            })
            .collect()
    }

    /// Ring lowering for a stage outside the precomputed table (hand-built
    /// streams): enumerate the ring over the member devices the *engine*
    /// resolved from its placement, priced at the body-chunk fallback
    /// scalar — so even out-of-range collectives serialize and contend on
    /// the wire under full contention instead of silently bypassing the
    /// comm queues. Members beyond the cost model's pipeline depth cannot
    /// be mapped to physical devices; such groups return no hops (the
    /// engine keeps the analytic scalar for them).
    pub fn fallback_ring_hops(&self, group: &[DeviceId]) -> Vec<RingHop> {
        let scalar = self.ring_time(self.grad_bytes);
        if scalar <= 0.0 || group.iter().any(|&dev| dev >= self.d) {
            return Vec::new();
        }
        let members = self.ring_members(group);
        self.ring_hops_over(&self.cluster.ring_path(&members), self.grad_bytes, scalar)
    }

    /// Lower a ring path over `bytes` gradient bytes into hops: true
    /// per-hop traffic exposed (`RingHop::bytes`; informational — pricing
    /// uses `work`), solo work pinned to the stage's `scalar` duration
    /// (lock-step ring steps keep every hop busy for all of it).
    fn ring_hops_over(&self, path: &[LinkId], bytes: u64, scalar: f64) -> Vec<RingHop> {
        let g = self.allreduce_group as f64;
        path.iter()
            .map(|&link| RingHop {
                bytes: 2.0 * (g - 1.0) * (bytes as f64 / g),
                work: scalar,
                // The hop pays its own link class's per-step latency once
                // per ring step; clamped so the latency budget can never
                // exceed the solo work (the scalar's bottleneck class may
                // be slower than this hop's).
                lat: (2.0 * (g - 1.0) * self.cluster.lat(link.kind)).min(scalar),
                link,
                res: self.cluster.dense_resources_of(link),
            })
            .collect()
    }

    /// Ring all-reduce time over `bytes` on the mapped bottleneck link.
    /// Class-level bandwidth multipliers apply (a degraded IB fabric slows
    /// IB-bottlenecked rings); per-pipe overrides do not — the scalar is
    /// one closed form shared by every hop, so only class-wide factors can
    /// price into it. Per-pipe degradation still bites under contention,
    /// where each hop is a real flow on its own pipe.
    fn ring_time(&self, bytes: u64) -> f64 {
        let g = self.allreduce_group as f64;
        if self.allreduce_group <= 1 {
            return 0.0;
        }
        let bw = self.cluster.bw_scaled(self.allreduce_link);
        let lat = self.cluster.lat(self.allreduce_link);
        // Ring: 2(g-1) steps, each moving bytes/g.
        2.0 * (g - 1.0) * (bytes as f64 / g / bw + lat)
    }

    /// Optimizer step time for `stage`: elementwise update over the
    /// chunk's params, modeled at HBM bandwidth (read grad+param+2 Adam
    /// moments, write 3; precomputed). Heterogeneous like the all-reduce:
    /// entry/exit chunks also update their embedding/LM-head parameters;
    /// out-of-range stages price as a body chunk.
    pub fn optim_time(&self, stage: StageId) -> f64 {
        match self.optim.get(stage) {
            Some(&t) => t,
            None => self.optim_body,
        }
    }

    /// True when no device or stage carries a non-1.0 compute factor —
    /// both backends then price compute from the raw chunk fields with no
    /// per-node scaling (the uniform bit-identity fast path).
    pub fn uniform_compute(&self) -> bool {
        self.uniform_compute
    }

    /// Combined compute-time factor of (`dev`, `stage`): the device's
    /// straggler multiplier times the stage's layer-profile ratio (each
    /// 1.0 when absent; out-of-range indices from hand-built streams price
    /// as 1.0). Only consulted on the heterogeneous path.
    pub fn compute_scale(&self, dev: DeviceId, stage: StageId) -> f64 {
        let d = self.dev_mult.get(dev).copied().unwrap_or(1.0);
        let s = self.stage_scale.get(stage).copied().unwrap_or(1.0);
        d * s
    }

    /// Forward time of one chunk on (`dev`, `stage`). Uniform clusters
    /// return the raw field — no multiplication — so the pre-heterogeneity
    /// arithmetic is preserved bit for bit.
    pub fn fwd_time(&self, dev: DeviceId, stage: StageId) -> f64 {
        if self.uniform_compute {
            self.chunk_fwd
        } else {
            self.chunk_fwd * self.compute_scale(dev, stage)
        }
    }

    /// Fused backward time of one chunk on (`dev`, `stage`).
    pub fn bwd_time(&self, dev: DeviceId, stage: StageId) -> f64 {
        if self.uniform_compute {
            self.chunk_bwd
        } else {
            self.chunk_bwd * self.compute_scale(dev, stage)
        }
    }

    /// Activation-gradient (Bi) time of a split backward on (`dev`, `stage`).
    pub fn bwd_input_time(&self, dev: DeviceId, stage: StageId) -> f64 {
        if self.uniform_compute {
            self.chunk_bwd_input
        } else {
            self.chunk_bwd_input * self.compute_scale(dev, stage)
        }
    }

    /// Weight-gradient (W) time of a split backward on (`dev`, `stage`).
    pub fn bwd_weight_time(&self, dev: DeviceId, stage: StageId) -> f64 {
        if self.uniform_compute {
            self.chunk_bwd_weight
        } else {
            self.chunk_bwd_weight * self.compute_scale(dev, stage)
        }
    }

    /// Whether the P2P link between two pipeline devices crosses nodes.
    pub fn p2p_link(&self, a: DeviceId, b: DeviceId, placement: &Placement) -> LinkKind {
        let _ = placement;
        self.cluster.link(self.physical(a), self.physical(b))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ParallelConfig, BERT_64};
    use crate::schedule::ScheduleKind;

    fn model_costs(kind: ScheduleKind, w: usize, d: usize) -> CostModel {
        let p = ParallelConfig::new(kind, w, d, 4, d.max(2));
        CostModel::new(&BERT_64, &p, &ClusterConfig::paper_testbed(w * d))
    }

    #[test]
    fn bwd_twice_fwd() {
        let c = model_costs(ScheduleKind::BitPipe, 1, 8);
        assert!((c.chunk_bwd - 2.0 * c.chunk_fwd).abs() < 1e-15);
    }

    #[test]
    fn interleaved_chunks_are_smaller() {
        let bit = model_costs(ScheduleKind::BitPipe, 1, 8); // v=2: 4 layers/chunk
        let dap = model_costs(ScheduleKind::Dapple, 1, 8); // v=1: 8 layers/chunk
        assert!(bit.chunk_fwd < dap.chunk_fwd);
        assert!((dap.chunk_fwd / bit.chunk_fwd - 2.0).abs() < 1e-9);
    }

    #[test]
    fn allreduce_group_and_link() {
        // W=1 unidirectional: no allreduce.
        let c = model_costs(ScheduleKind::Dapple, 1, 8);
        assert_eq!(c.allreduce_group, 1);
        assert_eq!(c.allreduce_time(0), 0.0);
        assert!(c.ring_hops(0).is_none());
        // W=1 bidirectional: twins only, NVLink group of 2.
        let c = model_costs(ScheduleKind::BitPipe, 1, 8);
        assert_eq!(c.allreduce_group, 2);
        assert_eq!(c.allreduce_link, LinkKind::NvLink);
        assert!(c.allreduce_time(0) > 0.0);
        // W=4 bidirectional: group of 8, still fits one node => NVLink.
        let c = model_costs(ScheduleKind::BitPipe, 4, 8);
        assert_eq!(c.allreduce_group, 8);
        assert_eq!(c.allreduce_link, LinkKind::NvLink);
        // W=8 bidirectional: group of 16 > 8/node => IB.
        let c = model_costs(ScheduleKind::BitPipe, 8, 4);
        assert_eq!(c.allreduce_link, LinkKind::InfiniBand);
    }

    #[test]
    fn ring_scales_sublinearly() {
        let c2 = model_costs(ScheduleKind::BitPipe, 1, 8);
        let c8 = model_costs(ScheduleKind::BitPipe, 4, 8);
        // Same per-stage bytes; larger group is slower but << 4x.
        let t2 = c2.allreduce_time(0);
        let t8 = c8.allreduce_time(0);
        assert!(t8 > t2);
        assert!(t8 < 2.0 * t2, "ring should scale ~(g-1)/g: {t2} vs {t8}");
    }

    #[test]
    fn ring_hops_lower_the_scalar_onto_real_pipes() {
        // The flow lowering: one hop per member of the node-clustered ring
        // over twins x W physical devices, every hop's solo work pinned to
        // the stage's scalar duration (lock-step ring steps), true per-hop
        // traffic exposed, and hop pipes matching the actual placement.
        for (w, d) in [(1usize, 8usize), (2, 8), (4, 8)] {
            let c = model_costs(ScheduleKind::BitPipe, w, d);
            for stage in 0..2 * d {
                let hops = c.ring_hops(stage).expect("bidirectional stages have rings");
                assert_eq!(hops.len(), 2 * w, "stage {stage}: one hop per member");
                for h in hops {
                    assert_eq!(
                        h.work.to_bits(),
                        c.allreduce_time(stage).to_bits(),
                        "W={w} stage {stage}: hop work must be the scalar"
                    );
                    assert!(h.bytes > 0.0);
                    assert_ne!(h.link.src, h.link.dst);
                }
            }
        }
        // W=2 on 16 devices: the twin sits in the other node, so the ring
        // genuinely crosses Infiniband pipes even though the *scalar*
        // bottleneck class follows the Fig 6 mapping heuristic — exactly
        // the traffic the contention engine now sees on the NICs.
        let c = model_costs(ScheduleKind::BitPipe, 2, 8);
        for stage in 0..16 {
            let hops = c.ring_hops(stage).unwrap();
            assert!(
                hops.iter().any(|h| h.link.kind == LinkKind::InfiniBand),
                "stage {stage}: twin ring should cross nodes"
            );
        }
        // Entry/exit rings carry more bytes than body rings.
        let body = c.ring_hops(1).unwrap()[0].bytes;
        assert!(c.ring_hops(0).unwrap()[0].bytes > body);
        assert!(c.ring_hops(15).unwrap()[0].bytes > body);
        // Out-of-range stages have no lowering (scalar fallback only).
        assert!(c.ring_hops(99).is_none());
    }

    #[test]
    fn allreduce_volumes_are_heterogeneous() {
        // Entry (embeddings) and exit (LM head) chunks all-reduce more
        // bytes than body chunks; body chunks are uniform.
        let c = model_costs(ScheduleKind::BitPipe, 4, 8); // 16 stages, group 8
        let body = c.allreduce_time(1);
        assert!(body > 0.0);
        for stage in 2..15 {
            assert_eq!(c.allreduce_time(stage).to_bits(), body.to_bits(), "stage {stage}");
        }
        assert!(c.allreduce_time(0) > body, "entry chunk should be heavier");
        assert!(c.allreduce_time(15) > body, "exit chunk should be heavier");
        // Out-of-range stages (hand-built streams) price as body chunks.
        assert_eq!(c.allreduce_time(99).to_bits(), body.to_bits());
        // The optimizer step is heterogeneous the same way: entry/exit
        // chunks also update their embedding/LM-head parameters.
        let optim_body = c.optim_time(1);
        assert!(optim_body > 0.0);
        assert!(c.optim_time(0) > optim_body);
        assert!(c.optim_time(15) > optim_body);
        assert_eq!(c.optim_time(99).to_bits(), optim_body.to_bits());
        // No collective at all => every stage's all-reduce is free, but
        // the optimizer still pays.
        let c1 = model_costs(ScheduleKind::Dapple, 1, 8);
        for stage in [0usize, 3, 7] {
            assert_eq!(c1.allreduce_time(stage), 0.0);
            assert!(c1.optim_time(stage) > 0.0);
        }
    }

    #[test]
    fn p2p_edges_key_the_right_pipes() {
        // W=2 ReplicasTogether: replica 1's copy of every cross-node hop
        // funnels onto the same node-pair IB pipe as replica 0's
        // (dp_copies = 2); NVLink hops use distinct device pairs
        // (dp_copies = 1).
        let c = model_costs(ScheduleKind::BitPipe, 2, 8);
        let mut shared = 0;
        for a in 0..8 {
            for b in 0..8 {
                let e = c.p2p_edge(a, b);
                assert_eq!(e.bytes, c.msg_bytes);
                assert_eq!(
                    e.link,
                    c.cluster.link_id(c.physical(a), c.physical(b)),
                    "({a},{b})"
                );
                assert_eq!(
                    e.res,
                    c.cluster.dense_resources_of(e.link),
                    "({a},{b}): stale dense resource indices"
                );
                match e.link.kind {
                    LinkKind::InfiniBand => {
                        assert_eq!(e.dp_copies, 2, "({a},{b})");
                        shared += 1;
                    }
                    _ => assert_eq!(e.dp_copies, 1, "({a},{b})"),
                }
            }
        }
        assert!(shared > 0, "expected cross-node edges under ReplicasTogether");
        // W=1: nothing to share with.
        let c1 = model_costs(ScheduleKind::BitPipe, 1, 8);
        for a in 0..8 {
            for b in 0..8 {
                assert_eq!(c1.p2p_edge(a, b).dp_copies, 1);
            }
        }
    }

    #[test]
    fn hoisted_topology_is_bit_identical() {
        // grid_search shares one LinkTopology across all B candidates of a
        // (W, D) point; the resulting models must match ::new exactly.
        let cluster = ClusterConfig::paper_testbed(16);
        let topo = LinkTopology::new(&cluster, 2, 8);
        for b in [1usize, 2, 4, 8] {
            let p = ParallelConfig::new(ScheduleKind::BitPipe, 2, 8, b, 8);
            let fresh = CostModel::new(&BERT_64, &p, &cluster);
            let hoisted = CostModel::with_topology(&BERT_64, &p, &cluster, &topo);
            assert_eq!(fresh.chunk_fwd.to_bits(), hoisted.chunk_fwd.to_bits());
            for a in 0..8 {
                for c in 0..8 {
                    let (x, y) = (fresh.p2p_edge(a, c), hoisted.p2p_edge(a, c));
                    assert_eq!(x.link, y.link);
                    assert_eq!(x.dp_copies, y.dp_copies);
                    assert_eq!(x.solo_time().to_bits(), y.solo_time().to_bits());
                }
            }
            for st in 0..16 {
                assert_eq!(
                    fresh.allreduce_time(st).to_bits(),
                    hoisted.allreduce_time(st).to_bits()
                );
                assert_eq!(fresh.optim_time(st).to_bits(), hoisted.optim_time(st).to_bits());
                let (a, b) = (fresh.ring_hops(st).unwrap(), hoisted.ring_hops(st).unwrap());
                assert_eq!(a.len(), b.len());
                for (x, y) in a.iter().zip(b) {
                    assert_eq!(x.link, y.link);
                    assert_eq!(x.work.to_bits(), y.work.to_bits());
                }
            }
        }
    }

    #[test]
    fn rebatched_matches_full_build_bitwise() {
        // The incremental B-move: recompute only the BatchPricing slice,
        // clone the rest — must be indistinguishable (exact f64 bits) from
        // building the model from scratch at the new B.
        let cluster = ClusterConfig::paper_testbed(16);
        let topo = LinkTopology::new(&cluster, 2, 8);
        let base_p = ParallelConfig::new(ScheduleKind::BitPipe, 2, 8, 1, 8);
        let base = CostModel::with_topology(&BERT_64, &base_p, &cluster, &topo);
        for b in [1usize, 2, 3, 4, 6, 8, 16] {
            let p = ParallelConfig::new(ScheduleKind::BitPipe, 2, 8, b, 8);
            let full = CostModel::with_topology(&BERT_64, &p, &cluster, &topo);
            let incr = base.rebatched(&BERT_64, &p, &topo);
            let bp = topo.batch_pricing(&BERT_64, &p, &cluster);
            assert_eq!(incr.chunk_fwd.to_bits(), full.chunk_fwd.to_bits(), "B={b}");
            assert_eq!(incr.chunk_bwd.to_bits(), full.chunk_bwd.to_bits());
            assert_eq!(incr.chunk_bwd_input.to_bits(), full.chunk_bwd_input.to_bits());
            assert_eq!(incr.chunk_bwd_weight.to_bits(), full.chunk_bwd_weight.to_bits());
            assert_eq!(incr.msg_bytes, full.msg_bytes);
            assert_eq!(incr.local_copy_time().to_bits(), full.local_copy_time().to_bits());
            assert_eq!(bp.local_copy.to_bits(), full.local_copy_time().to_bits());
            for x in 0..8 {
                for y in 0..8 {
                    assert_eq!(
                        incr.p2p_time(x, y).to_bits(),
                        full.p2p_time(x, y).to_bits(),
                        "B={b} ({x},{y})"
                    );
                    // The pricing vector is the same arithmetic as the
                    // edge's solo_time — the table the batched DAG
                    // re-cost consumes directly.
                    assert_eq!(
                        bp.p2p[x * 8 + y].to_bits(),
                        full.p2p_time(x, y).to_bits(),
                        "B={b} ({x},{y})"
                    );
                    let (e1, e2) = (incr.p2p_edge(x, y), full.p2p_edge(x, y));
                    assert_eq!(e1.link, e2.link);
                    assert_eq!(e1.dp_copies, e2.dp_copies);
                    assert_eq!(e1.bytes, e2.bytes);
                }
            }
            // B-independent tables survive the move bit for bit.
            for st in 0..16 {
                assert_eq!(incr.allreduce_time(st).to_bits(), full.allreduce_time(st).to_bits());
                assert_eq!(incr.optim_time(st).to_bits(), full.optim_time(st).to_bits());
                let (a, b2) = (incr.ring_hops(st).unwrap(), full.ring_hops(st).unwrap());
                assert_eq!(a.len(), b2.len());
                for (x, y) in a.iter().zip(b2) {
                    assert_eq!(x.link, y.link);
                    assert_eq!(x.work.to_bits(), y.work.to_bits());
                }
            }
        }
    }

    #[test]
    fn p2p_table_matches_direct_xfer() {
        // The precomputed table must be bit-identical to the direct path.
        let c = model_costs(ScheduleKind::BitPipe, 2, 8);
        for a in 0..8 {
            for b in 0..8 {
                let want = c.cluster.xfer_time(c.physical(a), c.physical(b), c.msg_bytes);
                assert_eq!(c.p2p_time(a, b).to_bits(), want.to_bits(), "({a},{b})");
            }
        }
        assert!(c.local_copy_time() > 0.0);
        assert!(c.optim_time(0) > 0.0);
    }

    #[test]
    fn straggler_scales_compute_not_wire() {
        let p = ParallelConfig::new(ScheduleKind::BitPipe, 2, 8, 4, 8);
        let cluster = ClusterConfig::paper_testbed(16);
        let base = CostModel::new(&BERT_64, &p, &cluster);
        assert!(base.uniform_compute());
        // Physical device 0 is (w=0, d=0) under ReplicasTogether; its twin
        // replica slot is physical 1 = (w=1, d=0). Slowing either gates
        // pipeline slot 0 (sync DP takes the max over replicas).
        let slow = CostModel::new(&BERT_64, &p, &cluster.with_straggler(1, 1.5).unwrap());
        assert!(!slow.uniform_compute());
        assert_eq!(slow.compute_scale(0, 0), 1.5);
        assert_eq!(slow.compute_scale(1, 0), 1.0);
        assert_eq!(slow.fwd_time(0, 0).to_bits(), (base.chunk_fwd * 1.5).to_bits());
        assert_eq!(slow.fwd_time(1, 0).to_bits(), base.chunk_fwd.to_bits());
        assert_eq!(slow.bwd_time(0, 0).to_bits(), (base.chunk_bwd * 1.5).to_bits());
        // Wire pricing untouched by compute stragglers.
        for a in 0..8 {
            for b in 0..8 {
                assert_eq!(slow.p2p_time(a, b).to_bits(), base.p2p_time(a, b).to_bits());
            }
        }
        for st in 0..16 {
            assert_eq!(slow.allreduce_time(st).to_bits(), base.allreduce_time(st).to_bits());
        }
    }

    #[test]
    fn link_overrides_reprice_edges_and_batch_pricing_together() {
        // A degraded link must show up identically in the edge tables and
        // the incremental BatchPricing path (the DAG re-cost consumes the
        // latter; divergence would split the backends).
        let p = ParallelConfig::new(ScheduleKind::BitPipe, 2, 8, 4, 8);
        let cluster = ClusterConfig::paper_testbed(16)
            .with_link_mult(LinkKind::InfiniBand, 0.5)
            .unwrap();
        let base = CostModel::new(&BERT_64, &p, &ClusterConfig::paper_testbed(16));
        let deg = CostModel::new(&BERT_64, &p, &cluster);
        let topo = LinkTopology::new(&cluster, 2, 8);
        let bp = topo.batch_pricing(&BERT_64, &p, &cluster);
        let mut slowed = 0;
        for a in 0..8 {
            for b in 0..8 {
                assert_eq!(
                    deg.p2p_time(a, b).to_bits(),
                    bp.p2p[a * 8 + b].to_bits(),
                    "({a},{b}): edges vs batch pricing"
                );
                if deg.p2p_edge(a, b).link.kind == LinkKind::InfiniBand {
                    assert!(deg.p2p_time(a, b) > base.p2p_time(a, b), "({a},{b})");
                    slowed += 1;
                } else {
                    assert_eq!(deg.p2p_time(a, b).to_bits(), base.p2p_time(a, b).to_bits());
                }
            }
        }
        assert!(slowed > 0);
        // Compute untouched by link degradation.
        assert!(deg.uniform_compute());
        assert_eq!(deg.chunk_fwd.to_bits(), base.chunk_fwd.to_bits());
    }

    #[test]
    fn layer_profile_rescales_stages_and_keeps_ring_pin() {
        let c = model_costs(ScheduleKind::BitPipe, 2, 8); // 16 stages
        let mut profile = vec![1.0; 16];
        profile[3] = 2.0;
        let heavy = c.clone().with_layer_profile(&profile).unwrap();
        assert!(!heavy.uniform_compute());
        let r3 = heavy.compute_scale(0, 3);
        assert!(r3 > 1.0 && heavy.compute_scale(0, 4) < 1.0, "mean-normalized ratios");
        assert_eq!(heavy.fwd_time(0, 3).to_bits(), (c.chunk_fwd * r3).to_bits());
        // All-reduce/optimizer follow the profile, and the hop-work ==
        // scalar bit-pin survives the scaling.
        assert!(heavy.allreduce_time(3) > c.allreduce_time(3));
        assert!(heavy.optim_time(3) > c.optim_time(3));
        for st in 0..16 {
            for h in heavy.ring_hops(st).unwrap() {
                assert_eq!(h.work.to_bits(), heavy.allreduce_time(st).to_bits());
                assert!(h.lat <= h.work);
            }
        }
        // All-1.0 profiles are exactly neutral.
        let neutral = c.clone().with_layer_profile(&[1.0; 16]).unwrap();
        assert!(neutral.uniform_compute());
        for st in 0..16 {
            assert_eq!(neutral.allreduce_time(st).to_bits(), c.allreduce_time(st).to_bits());
            assert_eq!(neutral.optim_time(st).to_bits(), c.optim_time(st).to_bits());
        }
        assert_eq!(neutral.fwd_time(0, 0).to_bits(), c.chunk_fwd.to_bits());
        // Wrong length / non-positive weights are rejected.
        assert!(c.clone().with_layer_profile(&[1.0; 3]).is_err());
        profile[3] = -1.0;
        assert!(c.clone().with_layer_profile(&profile).is_err());
    }

    #[test]
    fn ring_hops_carry_clamped_latency_budgets() {
        let c = model_costs(ScheduleKind::BitPipe, 2, 8);
        for st in 0..16 {
            for h in c.ring_hops(st).unwrap() {
                let g = c.allreduce_group as f64;
                let budget = 2.0 * (g - 1.0) * c.cluster.lat(h.link.kind);
                assert_eq!(h.lat.to_bits(), budget.min(h.work).to_bits());
                assert!(h.lat > 0.0 && h.lat <= h.work);
            }
        }
    }

    #[test]
    fn p2p_crosses_nodes_when_replicas_together() {
        // ReplicasTogether with W=2, D=8 on 16 devices: pipeline neighbours
        // d and d+1 sit 2 apart physically; half the hops cross nodes.
        let c = model_costs(ScheduleKind::BitPipe, 2, 8);
        let mut cross = 0;
        for dev in 0..7 {
            if c.cluster.link(c.physical(dev), c.physical(dev + 1)) == LinkKind::InfiniBand {
                cross += 1;
            }
        }
        assert!(cross > 0, "expected some inter-node P2P under ReplicasTogether");
    }
}
