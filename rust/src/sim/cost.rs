//! Analytical cost model: maps (model, parallel, cluster) configurations to
//! per-instruction times in seconds.
//!
//! * Compute — transformer FLOP counts (Megatron accounting) over the
//!   device's sustained FLOP rate; backward = 2x forward (paper premise).
//! * P2P — `message_size = dtype * B * S * H` bytes (paper Appendix C)
//!   over the link class between the two physical devices.
//! * All-reduce — ring algorithm: `2 (g-1)/g * bytes / bw_bottleneck`,
//!   where the group spans the bidirectional twin and the W data-parallel
//!   replicas; the bottleneck link depends on the Fig 6 mapping policy.

use crate::config::{ClusterConfig, LinkKind, MappingPolicy, ModelConfig, ParallelConfig};
use crate::schedule::{DeviceId, Placement, StageId};

/// Per-instruction costs in seconds for one simulated pipeline group.
#[derive(Debug, Clone)]
pub struct CostModel {
    /// Forward time of one chunk (stage) on one micro-batch.
    pub chunk_fwd: f64,
    /// Backward time of one chunk on one micro-batch.
    pub chunk_bwd: f64,
    /// Activation / gradient message bytes.
    pub msg_bytes: u64,
    /// Gradient bytes per *stage* all-reduce (one chunk's parameters).
    pub grad_bytes: u64,
    /// All-reduce group size g (bidirectional twins x W replicas).
    pub allreduce_group: usize,
    /// Bottleneck link for the all-reduce under the mapping policy.
    pub allreduce_link: LinkKind,
    /// Cluster parameters (bandwidth/latency tables).
    pub cluster: ClusterConfig,
    /// Pipeline-parallel sizes.
    pub d: usize,
    pub w: usize,
    /// Precomputed P2P times, `[a * d + b]` — the simulator's hottest
    /// lookup, hoisted out of the per-message path.
    p2p: Vec<f64>,
    /// Precomputed local-copy time.
    local_copy: f64,
    /// Precomputed per-stage all-reduce time (stage-independent today).
    allreduce: f64,
    /// Precomputed optimizer-step time.
    optim: f64,
}

impl CostModel {
    pub fn new(model: &ModelConfig, parallel: &ParallelConfig, cluster: &ClusterConfig) -> Self {
        let chunks = parallel.v * parallel.d;
        // Layers per chunk (at least one; tiny models on deep pipelines
        // saturate at 1 layer per chunk).
        let layers_per_chunk = (model.n_layers + chunks - 1) / chunks;
        let fwd_flops = model.layer_fwd_flops(parallel.b) * layers_per_chunk as u64;
        // Small micro-batches under-utilize the device (occupancy/launch
        // bound) — the effect behind paper Fig 11(b)'s B sensitivity.
        let eff = cluster.mbs_efficiency(parallel.b);
        let chunk_fwd = fwd_flops as f64 / (cluster.flops * eff);
        let chunk_bwd = 2.0 * chunk_fwd;
        let msg_bytes = model.message_bytes(parallel.b);
        let grad_bytes =
            model.params_per_layer() * layers_per_chunk as u64 * model.dtype_bytes as u64;

        // All-reduce group: both directions of the bidirectional pipe (if
        // any) times W replicas.
        let twins = if parallel.kind.bidirectional() { 2 } else { 1 };
        let group = twins * parallel.w;

        // Link class for the all-reduce ring (Fig 6): with the
        // ReplicasTogether mapping all replicas of a stage share a node as
        // long as the group fits; otherwise the ring spills onto IB.
        let allreduce_link = if group == 1 {
            LinkKind::Local
        } else {
            match cluster.mapping {
                MappingPolicy::ReplicasTogether if group <= cluster.devices_per_node => {
                    LinkKind::NvLink
                }
                _ => LinkKind::InfiniBand,
            }
        };

        let mut cm = CostModel {
            chunk_fwd,
            chunk_bwd,
            msg_bytes,
            grad_bytes,
            allreduce_group: group,
            allreduce_link,
            cluster: *cluster,
            d: parallel.d,
            w: parallel.w,
            p2p: Vec::new(),
            local_copy: 0.0,
            allreduce: 0.0,
            optim: 0.0,
        };
        // Precompute the per-instruction tables once; the event-queue
        // engine and the grid-search sweep hit these on every message.
        let d = cm.d;
        let mut p2p = vec![0.0f64; d * d];
        for a in 0..d {
            for b in 0..d {
                let (pa, pb) = (cm.physical(a), cm.physical(b));
                p2p[a * d + b] = cm.cluster.xfer_time(pa, pb, cm.msg_bytes);
            }
        }
        cm.p2p = p2p;
        cm.local_copy = cm.cluster.lat(LinkKind::Local)
            + cm.msg_bytes as f64 / cm.cluster.bw(LinkKind::Local);
        cm.allreduce = cm.compute_allreduce_time();
        cm.optim = cm.grad_bytes as f64 * 7.0 / cm.cluster.bw(LinkKind::Local);
        cm
    }

    /// Physical device of pipeline-device `dev` in the simulated group
    /// (group 0) under the mapping policy.
    fn physical(&self, dev: DeviceId) -> usize {
        self.cluster.physical_device(self.cluster.mapping, 0, dev, self.w.max(1), self.d)
    }

    /// P2P transfer time between pipeline devices `a` and `b`
    /// (precomputed table lookup).
    pub fn p2p_time(&self, a: DeviceId, b: DeviceId) -> f64 {
        self.p2p[a * self.d + b]
    }

    /// Local copy time (same device HBM->HBM; precomputed).
    pub fn local_copy_time(&self) -> f64 {
        self.local_copy
    }

    /// Ring all-reduce time for one stage's gradients (precomputed; the
    /// per-stage gradient volume is uniform today, so the stage id is
    /// accepted for future heterogeneous chunks but unused).
    pub fn allreduce_time(&self, _stage: StageId) -> f64 {
        self.allreduce
    }

    fn compute_allreduce_time(&self) -> f64 {
        let g = self.allreduce_group as f64;
        if self.allreduce_group <= 1 {
            return 0.0;
        }
        let bw = self.cluster.bw(self.allreduce_link);
        let lat = self.cluster.lat(self.allreduce_link);
        // Ring: 2(g-1) steps, each moving bytes/g.
        2.0 * (g - 1.0) * (self.grad_bytes as f64 / g / bw + lat)
    }

    /// Optimizer step time: elementwise update over the chunk's params,
    /// modeled at HBM bandwidth (read grad+param+2 Adam moments, write 3;
    /// precomputed).
    pub fn optim_time(&self) -> f64 {
        self.optim
    }

    /// Whether the P2P link between two pipeline devices crosses nodes.
    pub fn p2p_link(&self, a: DeviceId, b: DeviceId, placement: &Placement) -> LinkKind {
        let _ = placement;
        self.cluster.link(self.physical(a), self.physical(b))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ParallelConfig, BERT_64};
    use crate::schedule::ScheduleKind;

    fn model_costs(kind: ScheduleKind, w: usize, d: usize) -> CostModel {
        let p = ParallelConfig::new(kind, w, d, 4, d.max(2));
        CostModel::new(&BERT_64, &p, &ClusterConfig::paper_testbed(w * d))
    }

    #[test]
    fn bwd_twice_fwd() {
        let c = model_costs(ScheduleKind::BitPipe, 1, 8);
        assert!((c.chunk_bwd - 2.0 * c.chunk_fwd).abs() < 1e-15);
    }

    #[test]
    fn interleaved_chunks_are_smaller() {
        let bit = model_costs(ScheduleKind::BitPipe, 1, 8); // v=2: 4 layers/chunk
        let dap = model_costs(ScheduleKind::Dapple, 1, 8); // v=1: 8 layers/chunk
        assert!(bit.chunk_fwd < dap.chunk_fwd);
        assert!((dap.chunk_fwd / bit.chunk_fwd - 2.0).abs() < 1e-9);
    }

    #[test]
    fn allreduce_group_and_link() {
        // W=1 unidirectional: no allreduce.
        let c = model_costs(ScheduleKind::Dapple, 1, 8);
        assert_eq!(c.allreduce_group, 1);
        assert_eq!(c.allreduce_time(0), 0.0);
        // W=1 bidirectional: twins only, NVLink group of 2.
        let c = model_costs(ScheduleKind::BitPipe, 1, 8);
        assert_eq!(c.allreduce_group, 2);
        assert_eq!(c.allreduce_link, LinkKind::NvLink);
        assert!(c.allreduce_time(0) > 0.0);
        // W=4 bidirectional: group of 8, still fits one node => NVLink.
        let c = model_costs(ScheduleKind::BitPipe, 4, 8);
        assert_eq!(c.allreduce_group, 8);
        assert_eq!(c.allreduce_link, LinkKind::NvLink);
        // W=8 bidirectional: group of 16 > 8/node => IB.
        let c = model_costs(ScheduleKind::BitPipe, 8, 4);
        assert_eq!(c.allreduce_link, LinkKind::InfiniBand);
    }

    #[test]
    fn ring_scales_sublinearly() {
        let c2 = model_costs(ScheduleKind::BitPipe, 1, 8);
        let c8 = model_costs(ScheduleKind::BitPipe, 4, 8);
        // Same per-stage bytes; larger group is slower but << 4x.
        let t2 = c2.allreduce_time(0);
        let t8 = c8.allreduce_time(0);
        assert!(t8 > t2);
        assert!(t8 < 2.0 * t2, "ring should scale ~(g-1)/g: {t2} vs {t8}");
    }

    #[test]
    fn p2p_table_matches_direct_xfer() {
        // The precomputed table must be bit-identical to the direct path.
        let c = model_costs(ScheduleKind::BitPipe, 2, 8);
        for a in 0..8 {
            for b in 0..8 {
                let want = c.cluster.xfer_time(c.physical(a), c.physical(b), c.msg_bytes);
                assert_eq!(c.p2p_time(a, b).to_bits(), want.to_bits(), "({a},{b})");
            }
        }
        assert!(c.local_copy_time() > 0.0);
        assert!(c.optim_time() > 0.0);
    }

    #[test]
    fn p2p_crosses_nodes_when_replicas_together() {
        // ReplicasTogether with W=2, D=8 on 16 devices: pipeline neighbours
        // d and d+1 sit 2 apart physically; half the hops cross nodes.
        let c = model_costs(ScheduleKind::BitPipe, 2, 8);
        let mut cross = 0;
        for dev in 0..7 {
            if c.cluster.link(c.physical(dev), c.physical(dev + 1)) == LinkKind::InfiniBand {
                cross += 1;
            }
        }
        assert!(cross > 0, "expected some inter-node P2P under ReplicasTogether");
    }
}
