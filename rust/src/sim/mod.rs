//! Discrete-event cluster simulator.
//!
//! Executes the *same instruction streams* the real runtime runs
//! (`Schedule::device_ops`) under an analytical cost model of the paper's
//! testbed (A800 nodes, NVLink intra-node, 200 Gbps IB inter-node). All
//! paper-scale results (Figs 8–11, Tables 4, 5, 7) come from here; the
//! real threaded runtime (`crate::train`) validates the schedule logic at
//! small scale on actual XLA executables.
//!
//! Simplification that preserves behaviour: with data parallelism W > 1
//! every pipeline group executes an identical stream, so we simulate one
//! group of D devices and price the gradient all-reduce for its true group
//! size (W replicas x bidirectional twins) and link class (paper Fig 6
//! mapping policies). P2P never crosses groups; iteration time is
//! identical across groups.
//!
//! # Link contention
//!
//! By default transfers are fixed-duration (a link carries any number of
//! concurrent messages at full bandwidth) — fast, and bit-stable against
//! the legacy reference executor. Setting [`SimConfig::contention`] (CLI:
//! `bitpipe simulate --contention`) switches the engine to a flow-level
//! fair-share model over shared physical resources
//! ([`crate::config::ResourceId`]): per-device-pair NVLink paths inside a
//! node, and one egress + one ingress NIC per node for Infiniband
//! (default [`crate::config::IbModel::NodeNic`]; the legacy independent
//! node-pair pipes survive behind `IbModel::NodePair`). Concurrent flows
//! sharing a resource split its bandwidth, and in-flight completion times
//! are re-projected whenever a flow starts or ends — by default
//! *incrementally* (only the flows sharing a mutated resource are
//! touched, over a flat dense-index arena; [`NetworkImpl::Incremental`]),
//! with the PR-4 global-settlement walk kept as the differential oracle
//! behind [`NetworkImpl::Global`] / `SimConfig::network`. All-reduce
//! collectives ride the same wires: each (stage, round) collective lowers
//! into one flow per directed hop of its physical ring path
//! ([`CostModel::ring_hops`]), contending with P2P traffic and with other
//! rings — exactly the gradient synchronization BitPipe hides inside
//! pipeline bubbles, which a scalar formula could never see squeeze the
//! P2P flows it overlaps. Contended makespans are deterministic and never
//! below the uncontended makespan for the same schedule (a solo flow — or
//! a solo ring on an idle network — reproduces the fixed-duration pricing
//! bit for bit). The intermediate [`Contention::P2pOnly`] mode (P2P flows
//! contend, collectives stay scalar) is kept as the differential midpoint
//! the test battery pins: `uncontended <= p2p-only <= full`. See
//! `sim::engine`'s module docs for the mechanics.
//!
//! # Evaluation backends
//!
//! Two backends execute the instruction streams ([`Engine`]):
//!
//! * **Event** ([`crate::sim::engine`]) — the discrete-event queue above;
//!   required for contention, kept as the differential oracle.
//! * **Dag** ([`crate::sim::dag`]) — a schedule compiler that lowers the
//!   streams once into a flat dependence DAG and evaluates it with a
//!   weighted longest-path pass (no heap, no hashing). Bit-identical to
//!   the uncontended event engine (`rust/tests/dag_equiv.rs`), roughly an
//!   order of magnitude cheaper per evaluation, and re-costable: the DAG
//!   structure depends only on the schedule shape while the weights carry
//!   the (W, B, cluster) pricing, which is what makes the sweep layer's
//!   compile-once/re-cost-many cache ([`DagCache`]) possible.
//!
//! [`Engine::Auto`] (the default) picks Dag whenever `contention` is off.

mod cost;
mod dag;
mod engine;
mod gridsearch;
mod memory;

pub use cost::{BatchPricing, CostModel, LinkTopology, P2pEdge, RingHop};
pub use dag::{CompiledDag, DagUnsupported, DagWeights, EdgeArena, ParkReason};
pub use engine::{
    simulate_schedule, simulate_schedule_contended, simulate_schedule_faulted,
    simulate_schedule_iters, simulate_schedule_iters_contended, simulate_schedule_iters_faulted,
    simulate_schedule_iters_network, simulate_schedule_iters_with, simulate_schedule_network,
    simulate_schedule_with, Contention, DeviceTrace, MultiIterTrace, NetworkImpl, SimError,
    SimTrace,
};
/// Retired executor, compiled for differential tests only (unit tests,
/// or integration tests via the `reference-sim` dev-feature).
#[cfg(any(test, feature = "reference-sim"))]
pub use engine::simulate_schedule_reference;
pub use gridsearch::{
    grid_search, grid_search_batched, grid_search_cached, grid_search_contended_cached,
    grid_search_contended_serial, grid_search_on_cluster, grid_search_opts,
    grid_search_opts_baseline, grid_search_serial, resilience_sweep, resilience_sweep_serial,
    DagCache, GridPoint, GridSpace, ResiliencePoint, StreamCache, RECOST_LANES,
};
pub use memory::{memory_footprint, memory_footprint_from_counts, MemoryFootprint};

use crate::config::{ClusterConfig, FaultPlan, ModelConfig, ParallelConfig};
use crate::metrics::IterStats;
use crate::schedule::{self, Schedule};
use anyhow::{bail, ensure, Result};

/// Which evaluation backend executes the instruction streams.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Engine {
    /// Pick automatically: the DAG backend when `contention` is off, the
    /// event queue when it is on (the default).
    Auto,
    /// The discrete-event queue (`sim::engine`) — the only backend that
    /// prices link contention, and the differential oracle for the DAG.
    Event,
    /// The compiled dependence-DAG longest-path evaluator (`sim::dag`) —
    /// bit-identical to the event engine with `contention: false`, an
    /// order of magnitude cheaper per evaluation.
    Dag,
}

/// Everything needed for one simulated run.
#[derive(Debug, Clone, Copy)]
pub struct SimConfig {
    pub model: ModelConfig,
    pub parallel: ParallelConfig,
    pub cluster: ClusterConfig,
    /// Price link contention (flow-level fair-share bandwidth sharing of
    /// NVLink paths and per-node NICs, by P2P transfers *and* all-reduce
    /// ring flows — [`Contention::Full`]). Off by default: the
    /// fixed-duration engines are faster and bit-stable against the
    /// retired reference executor.
    pub contention: bool,
    /// Backend selection; [`Engine::Auto`] resolves to Dag without
    /// contention, Event with it.
    pub engine: Engine,
    /// Settlement strategy of the contended network (ignored without
    /// contention): [`NetworkImpl::Incremental`] by default, with
    /// [`NetworkImpl::Global`] kept as the differential oracle.
    pub network: NetworkImpl,
}

impl SimConfig {
    /// Fixed-duration (no-contention) configuration.
    pub fn new(model: ModelConfig, parallel: ParallelConfig, cluster: ClusterConfig) -> Self {
        SimConfig {
            model,
            parallel,
            cluster,
            contention: false,
            engine: Engine::Auto,
            network: NetworkImpl::default(),
        }
    }

    /// Toggle the flow-level link-contention model.
    pub fn with_contention(mut self, contention: bool) -> Self {
        self.contention = contention;
        self
    }

    /// Force a specific evaluation backend.
    pub fn with_engine(mut self, engine: Engine) -> Self {
        self.engine = engine;
        self
    }

    /// Pick the contended network's settlement strategy (no effect
    /// without contention).
    pub fn with_network(mut self, network: NetworkImpl) -> Self {
        self.network = network;
        self
    }

    /// Resolve `engine`/`contention` into the backend to run, rejecting
    /// the impossible combination.
    fn resolved_engine(&self) -> Result<Engine> {
        match (self.engine, self.contention) {
            (Engine::Auto, true) | (Engine::Event, _) => Ok(Engine::Event),
            (Engine::Auto, false) | (Engine::Dag, false) => Ok(Engine::Dag),
            (Engine::Dag, true) => {
                bail!("the DAG backend cannot price link contention; use the event engine")
            }
        }
    }
}

/// Simulation output for one training iteration.
#[derive(Debug, Clone)]
pub struct SimResult {
    /// End-to-end iteration time, seconds.
    pub iter_time: f64,
    /// Throughput, samples/s (paper's headline metric).
    pub throughput: f64,
    /// Per-device busy compute time, seconds.
    pub compute_time: Vec<f64>,
    /// Per-device time blocked on P2P receives, seconds.
    pub p2p_block_time: Vec<f64>,
    /// Per-device time blocked on the gradient all-reduce, seconds.
    pub allreduce_block_time: Vec<f64>,
    /// Bubble (idle) fraction over the iteration, mean across devices.
    pub bubble_fraction: f64,
    /// Per-device memory footprint.
    pub memory: MemoryFootprint,
}

impl SimResult {
    /// Peak memory across devices, bytes.
    pub fn peak_memory(&self) -> u64 {
        self.memory.total_peak().iter().copied().max().unwrap_or(0)
    }

    /// Does the run fit in device memory?
    pub fn fits(&self, cluster: &ClusterConfig) -> bool {
        self.peak_memory() <= cluster.mem_capacity
    }
}

/// Execute `iters` iterations of `sched` without contention on the
/// resolved backend. The DAG compiler's unsupported structures (never
/// produced by `comm_pass`) and unbalanced multi-iteration tags fall back
/// to the event engine, so the choice of backend is never observable in
/// the results — only in the wall clock.
pub(crate) fn run_streams(
    sched: &Schedule,
    costs: &CostModel,
    iters: usize,
    contention: bool,
    engine: Engine,
    network: NetworkImpl,
) -> Result<MultiIterTrace, SimError> {
    if engine == Engine::Dag {
        debug_assert!(!contention, "resolved_engine never picks Dag with contention");
        if let Ok(dag) = CompiledDag::compile(sched) {
            if iters == 1 || dag.multi_iter_safe() {
                return dag.evaluate(&dag.weights(costs), iters);
            }
        }
    }
    let mode = if contention { Contention::Full } else { Contention::Off };
    engine::simulate_schedule_iters_network(sched, costs, iters, mode, network)
}

/// Assemble a [`SimResult`] from a finished trace — shared by
/// [`simulate`] and the grid-search fast path so both produce bit-identical
/// derived metrics.
pub(crate) fn assemble_result(
    minibatch: usize,
    d: usize,
    devices: &[DeviceTrace],
    iter_time: f64,
    memory: MemoryFootprint,
) -> SimResult {
    let compute_time: Vec<f64> = (0..d).map(|i| devices[i].compute_busy).collect();
    let p2p_block_time: Vec<f64> = (0..d).map(|i| devices[i].recv_blocked).collect();
    let allreduce_block_time: Vec<f64> = (0..d).map(|i| devices[i].allreduce_blocked).collect();
    let bubble_fraction = if iter_time > 0.0 {
        compute_time.iter().map(|c| 1.0 - c / iter_time).sum::<f64>() / d as f64
    } else {
        0.0
    };
    SimResult {
        iter_time,
        throughput: minibatch as f64 / iter_time,
        compute_time,
        p2p_block_time,
        allreduce_block_time,
        bubble_fraction,
        memory,
    }
}

/// Build the schedule for `cfg` and simulate one iteration.
pub fn simulate(cfg: &SimConfig) -> Result<SimResult> {
    cfg.parallel.validate()?;
    cfg.cluster.validate()?;
    cfg.model.validate()?;
    let engine = cfg.resolved_engine()?;
    let sched: Schedule = schedule::build(&cfg.parallel.schedule())?;
    let costs = CostModel::new(&cfg.model, &cfg.parallel, &cfg.cluster);
    let trace = run_streams(&sched, &costs, 1, cfg.contention, engine, cfg.network)?;
    let memory = memory_footprint(&sched, &cfg.model, &cfg.parallel);
    Ok(assemble_result(
        cfg.parallel.minibatch_size(),
        sched.n_devices(),
        &trace.devices,
        trace.makespan,
        memory,
    ))
}

/// Multi-iteration simulation output: warmup + steady-state statistics.
///
/// The engine free-runs the instruction streams back-to-back (no global
/// barrier), so iteration `k+1`'s warmup forwards overlap iteration `k`'s
/// drain exactly like the threaded runtime; per-iteration times are
/// completion-to-completion intervals.
#[derive(Debug, Clone)]
pub struct MultiIterResult {
    /// Iterations simulated (>= 1).
    pub iters: usize,
    /// Leading iterations excluded from the steady-state stats.
    pub warmup: usize,
    /// Per-iteration wall time, seconds (`iters` entries).
    pub iter_times: Vec<f64>,
    /// Statistics over the post-warmup iterations.
    pub steady: IterStats,
    /// Steady-state throughput, samples/s (mini-batch / mean steady
    /// iteration time).
    pub steady_throughput: f64,
    /// Total virtual time of the whole run, seconds.
    pub total_time: f64,
}

/// Build the schedule for `cfg` and simulate `iters` training iterations,
/// reporting per-iteration and steady-state (post-`warmup`) timings.
pub fn simulate_iters(cfg: &SimConfig, iters: usize, warmup: usize) -> Result<MultiIterResult> {
    ensure!(iters >= 1, "need at least one iteration (got {iters})");
    ensure!(
        warmup < iters,
        "warmup ({warmup}) must leave at least one recorded iteration (iters {iters})"
    );
    cfg.parallel.validate()?;
    cfg.cluster.validate()?;
    cfg.model.validate()?;
    let engine = cfg.resolved_engine()?;
    let sched: Schedule = schedule::build(&cfg.parallel.schedule())?;
    let costs = CostModel::new(&cfg.model, &cfg.parallel, &cfg.cluster);
    let trace = run_streams(&sched, &costs, iters, cfg.contention, engine, cfg.network)?;
    let iter_times = trace.iter_times();
    let steady = IterStats::from_secs(&iter_times[warmup..]);
    let steady_throughput = steady.throughput(cfg.parallel.minibatch_size());
    Ok(MultiIterResult {
        iters,
        warmup,
        iter_times,
        steady,
        steady_throughput,
        total_time: trace.makespan,
    })
}

/// Build the schedule for `cfg` and simulate one iteration while
/// replaying `faults` (a [`FaultPlan`] of link-degradation windows,
/// device slow-downs, and stalls). An empty plan takes exactly the
/// [`simulate`] path — same backend resolution, bit-identical results. A
/// non-empty plan requires the event backend: [`Engine::Auto`] routes
/// there silently, [`Engine::Dag`] is rejected with a typed error (the
/// compiled DAG prices a fixed weight table and cannot replay
/// time-varying rates).
pub fn simulate_faulted(cfg: &SimConfig, faults: &FaultPlan) -> Result<SimResult> {
    if faults.is_empty() {
        return simulate(cfg);
    }
    cfg.parallel.validate()?;
    cfg.cluster.validate()?;
    cfg.model.validate()?;
    if cfg.engine == Engine::Dag {
        bail!("the DAG backend cannot replay fault plans; use the event engine");
    }
    let sched: Schedule = schedule::build(&cfg.parallel.schedule())?;
    faults.validate(sched.n_devices())?;
    let costs = CostModel::new(&cfg.model, &cfg.parallel, &cfg.cluster);
    let mode = if cfg.contention { Contention::Full } else { Contention::Off };
    let trace =
        engine::simulate_schedule_iters_faulted(&sched, &costs, 1, mode, cfg.network, faults)?;
    let memory = memory_footprint(&sched, &cfg.model, &cfg.parallel);
    Ok(assemble_result(
        cfg.parallel.minibatch_size(),
        sched.n_devices(),
        &trace.devices,
        trace.makespan,
        memory,
    ))
}

/// Multi-iteration variant of [`simulate_faulted`]: the fault clock is
/// global to the run (a window at t=2.0 lands in whichever iteration is
/// in flight then), so per-iteration times expose *which* iterations a
/// fault disturbs.
pub fn simulate_iters_faulted(
    cfg: &SimConfig,
    iters: usize,
    warmup: usize,
    faults: &FaultPlan,
) -> Result<MultiIterResult> {
    if faults.is_empty() {
        return simulate_iters(cfg, iters, warmup);
    }
    ensure!(iters >= 1, "need at least one iteration (got {iters})");
    ensure!(
        warmup < iters,
        "warmup ({warmup}) must leave at least one recorded iteration (iters {iters})"
    );
    cfg.parallel.validate()?;
    cfg.cluster.validate()?;
    cfg.model.validate()?;
    if cfg.engine == Engine::Dag {
        bail!("the DAG backend cannot replay fault plans; use the event engine");
    }
    let sched: Schedule = schedule::build(&cfg.parallel.schedule())?;
    faults.validate(sched.n_devices())?;
    let costs = CostModel::new(&cfg.model, &cfg.parallel, &cfg.cluster);
    let mode = if cfg.contention { Contention::Full } else { Contention::Off };
    let trace =
        engine::simulate_schedule_iters_faulted(&sched, &costs, iters, mode, cfg.network, faults)?;
    let iter_times = trace.iter_times();
    let steady = IterStats::from_secs(&iter_times[warmup..]);
    let steady_throughput = steady.throughput(cfg.parallel.minibatch_size());
    Ok(MultiIterResult {
        iters,
        warmup,
        iter_times,
        steady,
        steady_throughput,
        total_time: trace.makespan,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{BERT_64, GPT_96};
    use crate::schedule::ScheduleKind;

    fn sim(kind: ScheduleKind, w: usize, d: usize, b: usize, n: usize) -> SimResult {
        let cfg = SimConfig::new(
            BERT_64,
            ParallelConfig::new(kind, w, d, b, n),
            ClusterConfig::paper_testbed(w * d),
        );
        simulate(&cfg).unwrap()
    }

    #[test]
    fn bitpipe_beats_dapple_bert() {
        // Fig 9 headline: BitPipe > DAPPLE on 8 GPUs, pipeline-only.
        for n_mult in [1usize, 2, 4] {
            let n = 8 * n_mult;
            let bit = sim(ScheduleKind::BitPipe, 1, 8, 4, n);
            let dap = sim(ScheduleKind::Dapple, 1, 8, 4, n);
            assert!(
                bit.throughput > dap.throughput,
                "N={n}: BitPipe {} !> DAPPLE {}",
                bit.throughput,
                dap.throughput
            );
        }
    }

    #[test]
    fn bitpipe_beats_interleaved_and_chimera_at_n_eq_d() {
        let bit = sim(ScheduleKind::BitPipe, 1, 8, 4, 8);
        let int = sim(ScheduleKind::Interleaved, 1, 8, 4, 8);
        let chi = sim(ScheduleKind::Chimera, 1, 8, 4, 8);
        assert!(bit.throughput > int.throughput, "{} vs {}", bit.throughput, int.throughput);
        assert!(bit.throughput > chi.throughput, "{} vs {}", bit.throughput, chi.throughput);
    }

    #[test]
    fn gpt96_runs_and_orders_sanely() {
        let cfg = SimConfig::new(
            GPT_96,
            ParallelConfig::new(ScheduleKind::BitPipe, 1, 8, 1, 8),
            ClusterConfig::paper_testbed(8),
        );
        let bit = simulate(&cfg).unwrap();
        let cfg2 = SimConfig {
            parallel: ParallelConfig::new(ScheduleKind::Dapple, 1, 8, 1, 8),
            ..cfg
        };
        let dap = simulate(&cfg2).unwrap();
        assert!(bit.throughput > dap.throughput);
        // Sanity: GPT-96 B=1 iteration takes O(seconds) on the modeled
        // hardware, not micro- or kilo-seconds.
        assert!(bit.iter_time > 0.05 && bit.iter_time < 100.0, "{}", bit.iter_time);
    }

    #[test]
    fn bubble_fraction_close_to_formula() {
        use crate::schedule::analysis::bubble_ratio_formula;
        // Pure-compute check: zero-cost comm isolates schedule geometry.
        let model = BERT_64;
        let parallel = ParallelConfig::new(ScheduleKind::Dapple, 1, 8, 4, 8);
        let mut cluster = ClusterConfig::single_node(8);
        cluster.nvlink_bw = 1e15; // effectively free comm
        cluster.nvlink_lat = 0.0;
        let r = simulate(&SimConfig::new(model, parallel, cluster)).unwrap();
        let want = bubble_ratio_formula(ScheduleKind::Dapple, 8, 8, true);
        assert!(
            (r.bubble_fraction - want).abs() < 0.03,
            "bubble {} vs formula {want}",
            r.bubble_fraction
        );
    }

    #[test]
    fn memory_fits_bert_on_a800() {
        // Paper's B=4 BERT-64 setting fits in 80 GB.
        let r = sim(ScheduleKind::BitPipe, 1, 8, 4, 8);
        assert!(r.fits(&ClusterConfig::paper_testbed(8)), "peak {}", r.peak_memory());
    }

    #[test]
    fn contention_mode_never_speeds_up_an_iteration() {
        for kind in [ScheduleKind::Dapple, ScheduleKind::BitPipe] {
            let cfg = SimConfig::new(
                BERT_64,
                ParallelConfig::new(kind, 2, 8, 4, 16),
                ClusterConfig::paper_testbed(16),
            );
            let off = simulate(&cfg).unwrap();
            let on = simulate(&cfg.with_contention(true)).unwrap();
            assert!(
                on.iter_time >= off.iter_time - 1e-12,
                "{kind}: contended {} < uncontended {}",
                on.iter_time,
                off.iter_time
            );
            // Deterministic: a second contended run is bit-identical.
            let on2 = simulate(&cfg.with_contention(true)).unwrap();
            assert_eq!(on.iter_time.to_bits(), on2.iter_time.to_bits());
        }
    }

    #[test]
    fn multi_iteration_steady_state() {
        let cfg = SimConfig::new(
            BERT_64,
            ParallelConfig::new(ScheduleKind::BitPipe, 1, 8, 4, 8),
            ClusterConfig::paper_testbed(8),
        );
        let one = simulate(&cfg).unwrap();
        let r = simulate_iters(&cfg, 4, 1).unwrap();
        assert_eq!(r.iter_times.len(), 4);
        assert_eq!(r.steady.n, 3);
        assert!(r.iter_times.iter().all(|&t| t > 0.0));
        // Synchronous training: the steady-state iteration is close to the
        // single-shot makespan (iterations overlap only at the boundary).
        assert!(
            r.steady.mean >= 0.5 * one.iter_time && r.steady.mean <= 1.5 * one.iter_time,
            "steady {} vs single-shot {}",
            r.steady.mean,
            one.iter_time
        );
        assert!(r.steady_throughput > 0.0);
        let sum: f64 = r.iter_times.iter().sum();
        assert!((sum - r.total_time).abs() < 1e-9 * r.total_time.max(1e-12));
    }

    #[test]
    fn engine_selection_is_unobservable_in_results() {
        // Auto resolves to the DAG backend without contention; forcing the
        // event engine must produce bit-identical results.
        for kind in [ScheduleKind::Dapple, ScheduleKind::BitPipe] {
            let cfg = SimConfig::new(
                BERT_64,
                ParallelConfig::new(kind, 2, 8, 4, 16),
                ClusterConfig::paper_testbed(16),
            );
            let auto = simulate(&cfg).unwrap();
            let event = simulate(&cfg.with_engine(Engine::Event)).unwrap();
            let dag = simulate(&cfg.with_engine(Engine::Dag)).unwrap();
            for r in [&event, &dag] {
                assert_eq!(auto.iter_time.to_bits(), r.iter_time.to_bits(), "{kind}");
                assert_eq!(auto.throughput.to_bits(), r.throughput.to_bits(), "{kind}");
                assert_eq!(auto.bubble_fraction.to_bits(), r.bubble_fraction.to_bits());
                assert_eq!(auto.peak_memory(), r.peak_memory());
            }
            // Multi-iteration unrolling over the same arena, same story.
            let a = simulate_iters(&cfg, 3, 1).unwrap();
            let e = simulate_iters(&cfg.with_engine(Engine::Event), 3, 1).unwrap();
            for (x, y) in a.iter_times.iter().zip(&e.iter_times) {
                assert_eq!(x.to_bits(), y.to_bits(), "{kind}");
            }
        }
    }

    #[test]
    fn dag_engine_rejects_contention() {
        let cfg = SimConfig::new(
            BERT_64,
            ParallelConfig::new(ScheduleKind::BitPipe, 1, 4, 4, 4),
            ClusterConfig::paper_testbed(4),
        );
        let bad = cfg.with_contention(true).with_engine(Engine::Dag);
        assert!(simulate(&bad).is_err());
        assert!(simulate_iters(&bad, 2, 0).is_err());
        // Auto + contention silently routes to the event engine.
        assert!(simulate(&cfg.with_contention(true)).is_ok());
    }

    #[test]
    fn dag_engine_rejects_fault_plans() {
        let cfg = SimConfig::new(
            BERT_64,
            ParallelConfig::new(ScheduleKind::BitPipe, 1, 4, 4, 4),
            ClusterConfig::paper_testbed(4),
        );
        let plan = FaultPlan::parse("dev:0:stall@0.5+0.1").unwrap();
        let bad = cfg.with_engine(Engine::Dag);
        assert!(simulate_faulted(&bad, &plan).is_err());
        assert!(simulate_iters_faulted(&bad, 2, 0, &plan).is_err());
        // Auto + faults silently routes to the event engine.
        assert!(simulate_faulted(&cfg, &plan).is_ok());
        // An empty plan keeps the DAG fast path (and its results).
        assert!(simulate_faulted(&bad, &FaultPlan::empty()).is_ok());
    }

    #[test]
    fn empty_fault_plan_is_bit_identical_and_faults_never_speed_up() {
        let cfg = SimConfig::new(
            BERT_64,
            ParallelConfig::new(ScheduleKind::BitPipe, 1, 8, 4, 8),
            ClusterConfig::paper_testbed(8),
        );
        let base = simulate(&cfg).unwrap();
        let empty = simulate_faulted(&cfg, &FaultPlan::empty()).unwrap();
        assert_eq!(base.iter_time.to_bits(), empty.iter_time.to_bits());
        let plan = FaultPlan::parse("link:ib:0.25@0.0..10.0,dev:3:slow:2.0@0.0..10.0").unwrap();
        let hurt = simulate_faulted(&cfg, &plan).unwrap();
        assert!(
            hurt.iter_time >= base.iter_time,
            "faulted {} < healthy {}",
            hurt.iter_time,
            base.iter_time
        );
    }

    #[test]
    fn multi_iteration_rejects_bad_warmup() {
        let cfg = SimConfig::new(
            BERT_64,
            ParallelConfig::new(ScheduleKind::Dapple, 1, 4, 4, 4),
            ClusterConfig::paper_testbed(4),
        );
        assert!(simulate_iters(&cfg, 2, 2).is_err());
        assert!(simulate_iters(&cfg, 0, 0).is_err());
    }
}
