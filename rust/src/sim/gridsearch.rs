//! Grid search over (W, D, B) — the paper's Table 4 procedure: for a fixed
//! device count P and schedule, sweep the parameter space, drop layouts
//! that do not fit in device memory, and report the best-throughput
//! configuration.
//!
//! The sweep is embarrassingly parallel — every grid point builds and
//! simulates its own schedule — so [`grid_search`] fans the candidate list
//! out over scoped worker threads (an atomic work-stealing cursor; no
//! external thread pool). Candidate enumeration and the
//! `ClusterConfig::paper_testbed` construction are hoisted out of the
//! simulation loop. Results are deterministic: workers tag each point with
//! its candidate index, and the final ordering is a stable
//! descending-throughput sort over that canonical order, identical to the
//! serial baseline ([`grid_search_serial`], kept for benchmarking and
//! differential tests).

use super::{simulate, SimConfig, SimResult};
use crate::config::{ClusterConfig, ModelConfig, ParallelConfig};
use crate::schedule::ScheduleKind;
use anyhow::Result;
use std::sync::atomic::{AtomicUsize, Ordering};

/// The search space (paper Table 4 "Considered Values").
#[derive(Debug, Clone)]
pub struct GridSpace {
    pub w: Vec<usize>,
    pub d: Vec<usize>,
    pub b: Vec<usize>,
}

impl GridSpace {
    /// Paper Table 4, BERT-64 row.
    pub fn bert64() -> Self {
        GridSpace { w: vec![1, 2, 4, 8], d: vec![4, 8, 16], b: vec![1, 2, 4, 8] }
    }

    /// Paper Table 4, GPT-96 row.
    pub fn gpt96() -> Self {
        GridSpace { w: vec![1, 2, 4], d: vec![8, 16], b: vec![1, 2] }
    }
}

/// One evaluated grid point.
#[derive(Debug, Clone)]
pub struct GridPoint {
    pub parallel: ParallelConfig,
    pub result: SimResult,
}

/// Enumerate the feasible-by-arithmetic candidates of the sweep (the cheap
/// filters: device count, mini-batch divisibility, N >= D, validation).
fn candidates(
    kind: ScheduleKind,
    space: &GridSpace,
    n_devices: usize,
    minibatch: usize,
) -> Vec<ParallelConfig> {
    let mut out = Vec::new();
    for &w in &space.w {
        for &d in &space.d {
            if w * d != n_devices {
                continue;
            }
            for &b in &space.b {
                // Derive N from the fixed mini-batch: B-hat = B * N * W.
                if minibatch % (b * w) != 0 {
                    continue;
                }
                let n = minibatch / (b * w);
                if n < d || n % d != 0 {
                    continue; // paper requires N >= D, N % D == 0
                }
                let parallel = ParallelConfig::new(kind, w, d, b, n);
                if parallel.validate().is_err() {
                    continue;
                }
                out.push(parallel);
            }
        }
    }
    out
}

/// Simulate one candidate; `None` for layouts that fail to simulate or do
/// not fit in device memory (the paper's grid search drops these).
fn evaluate(
    model: &ModelConfig,
    cluster: &ClusterConfig,
    parallel: ParallelConfig,
    contention: bool,
) -> Option<GridPoint> {
    let cfg = SimConfig::new(*model, parallel, *cluster).with_contention(contention);
    let result = simulate(&cfg).ok()?;
    if !result.fits(cluster) {
        return None;
    }
    Some(GridPoint { parallel, result })
}

/// Stable descending-throughput order (candidate order breaks ties, so the
/// result is deterministic).
fn sort_points(points: &mut [GridPoint]) {
    points.sort_by(|a, b| {
        b.result
            .throughput
            .partial_cmp(&a.result.throughput)
            .expect("throughputs are finite")
    });
}

/// Sweep the space for one schedule on `n_devices` total devices with a
/// fixed mini-batch size `minibatch` (the paper holds B-hat fixed per GPU
/// count and model; N is derived as minibatch / (B*W), floored to a
/// multiple of D as the paper's N=D-default requires).
///
/// Returns all feasible points sorted by descending throughput. Grid
/// points are simulated concurrently on scoped threads.
pub fn grid_search(
    kind: ScheduleKind,
    model: &ModelConfig,
    space: &GridSpace,
    n_devices: usize,
    minibatch: usize,
) -> Result<Vec<GridPoint>> {
    grid_search_opts(kind, model, space, n_devices, minibatch, false)
}

/// [`grid_search`] with an explicit contention mode: `contention` true
/// prices every candidate under the flow-level link-sharing model (see
/// `sim::engine`), ranking layouts by their contended throughput — the
/// fidelity the Fig 6 mapping tradeoffs need.
pub fn grid_search_opts(
    kind: ScheduleKind,
    model: &ModelConfig,
    space: &GridSpace,
    n_devices: usize,
    minibatch: usize,
    contention: bool,
) -> Result<Vec<GridPoint>> {
    let cands = candidates(kind, space, n_devices, minibatch);
    let cluster = ClusterConfig::paper_testbed(n_devices);
    let threads = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
        .min(cands.len().max(1));
    if threads <= 1 || cands.len() <= 1 {
        let mut points: Vec<GridPoint> = cands
            .into_iter()
            .filter_map(|p| evaluate(model, &cluster, p, contention))
            .collect();
        sort_points(&mut points);
        return Ok(points);
    }

    let next = AtomicUsize::new(0);
    let mut indexed: Vec<(usize, GridPoint)> = std::thread::scope(|scope| {
        let mut handles = Vec::with_capacity(threads);
        for _ in 0..threads {
            let next = &next;
            let cands = &cands;
            let cluster = &cluster;
            handles.push(scope.spawn(move || {
                let mut found: Vec<(usize, GridPoint)> = Vec::new();
                loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= cands.len() {
                        break;
                    }
                    if let Some(point) = evaluate(model, cluster, cands[i], contention) {
                        found.push((i, point));
                    }
                }
                found
            }));
        }
        let mut all = Vec::new();
        for h in handles {
            all.extend(h.join().expect("grid-search worker panicked"));
        }
        all
    });

    // Canonical candidate order first, then the stable throughput sort —
    // byte-for-byte the serial result.
    indexed.sort_by_key(|&(i, _)| i);
    let mut points: Vec<GridPoint> = indexed.into_iter().map(|(_, p)| p).collect();
    sort_points(&mut points);
    Ok(points)
}

/// The single-threaded sweep — the pre-parallelization baseline, kept for
/// `benches/hotpath.rs` speedup measurements and differential tests.
pub fn grid_search_serial(
    kind: ScheduleKind,
    model: &ModelConfig,
    space: &GridSpace,
    n_devices: usize,
    minibatch: usize,
) -> Result<Vec<GridPoint>> {
    let cluster = ClusterConfig::paper_testbed(n_devices);
    let mut points: Vec<GridPoint> = candidates(kind, space, n_devices, minibatch)
        .into_iter()
        .filter_map(|p| evaluate(model, &cluster, p, false))
        .collect();
    sort_points(&mut points);
    Ok(points)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::BERT_64;

    #[test]
    fn finds_feasible_points_bert_32gpu() {
        let pts =
            grid_search(ScheduleKind::BitPipe, &BERT_64, &GridSpace::bert64(), 32, 128).unwrap();
        assert!(!pts.is_empty(), "no feasible configuration found");
        // Sorted descending.
        for w in pts.windows(2) {
            assert!(w[0].result.throughput >= w[1].result.throughput);
        }
        // Every point uses exactly 32 devices and the full mini-batch.
        for p in &pts {
            assert_eq!(p.parallel.total_devices(), 32);
            assert_eq!(p.parallel.minibatch_size(), 128);
        }
    }

    #[test]
    fn infeasible_layouts_skipped() {
        // Device count with no (w, d) product in the space.
        let pts =
            grid_search(ScheduleKind::BitPipe, &BERT_64, &GridSpace::bert64(), 24, 128).unwrap();
        assert!(pts.is_empty());
    }

    #[test]
    fn best_d_for_bitpipe_is_8_on_32gpus() {
        // Paper Table 7: D=8 is the sweet spot for BitPipe on 32 GPUs.
        let pts =
            grid_search(ScheduleKind::BitPipe, &BERT_64, &GridSpace::bert64(), 32, 128).unwrap();
        let best = &pts[0];
        assert_eq!(best.parallel.d, 8, "best D {} (throughput {})", best.parallel.d, best.result.throughput);
    }

    #[test]
    fn contended_sweep_covers_same_points_never_faster() {
        // Contention re-prices every layout but drops none (memory and
        // feasibility are unchanged), and no layout gets faster.
        let off = grid_search(ScheduleKind::BitPipe, &BERT_64, &GridSpace::bert64(), 16, 64)
            .unwrap();
        let on = grid_search_opts(
            ScheduleKind::BitPipe,
            &BERT_64,
            &GridSpace::bert64(),
            16,
            64,
            true,
        )
        .unwrap();
        assert_eq!(off.len(), on.len());
        assert!(!off.is_empty());
        for a in &on {
            let key = (a.parallel.w, a.parallel.d, a.parallel.b, a.parallel.n);
            let b = off
                .iter()
                .find(|p| (p.parallel.w, p.parallel.d, p.parallel.b, p.parallel.n) == key)
                .expect("point missing from uncontended sweep");
            assert!(
                a.result.throughput <= b.result.throughput + 1e-9,
                "{key:?}: contended {} > uncontended {}",
                a.result.throughput,
                b.result.throughput
            );
        }
    }

    #[test]
    fn parallel_sweep_matches_serial() {
        // Same points, same order, bit-identical throughputs.
        let par =
            grid_search(ScheduleKind::BitPipe, &BERT_64, &GridSpace::bert64(), 16, 64).unwrap();
        let ser = grid_search_serial(ScheduleKind::BitPipe, &BERT_64, &GridSpace::bert64(), 16, 64)
            .unwrap();
        assert_eq!(par.len(), ser.len());
        for (a, b) in par.iter().zip(&ser) {
            assert_eq!(
                (a.parallel.w, a.parallel.d, a.parallel.b, a.parallel.n),
                (b.parallel.w, b.parallel.d, b.parallel.b, b.parallel.n)
            );
            assert_eq!(a.result.throughput.to_bits(), b.result.throughput.to_bits());
        }
    }
}
