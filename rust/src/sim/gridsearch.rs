//! Grid search over (W, D, B) — the paper's Table 4 procedure: for a fixed
//! device count P and schedule, sweep the parameter space, drop layouts
//! that do not fit in device memory, and report the best-throughput
//! configuration.

use super::{simulate, SimConfig, SimResult};
use crate::config::{ClusterConfig, ModelConfig, ParallelConfig};
use crate::schedule::ScheduleKind;
use anyhow::Result;

/// The search space (paper Table 4 "Considered Values").
#[derive(Debug, Clone)]
pub struct GridSpace {
    pub w: Vec<usize>,
    pub d: Vec<usize>,
    pub b: Vec<usize>,
}

impl GridSpace {
    /// Paper Table 4, BERT-64 row.
    pub fn bert64() -> Self {
        GridSpace { w: vec![1, 2, 4, 8], d: vec![4, 8, 16], b: vec![1, 2, 4, 8] }
    }

    /// Paper Table 4, GPT-96 row.
    pub fn gpt96() -> Self {
        GridSpace { w: vec![1, 2, 4], d: vec![8, 16], b: vec![1, 2] }
    }
}

/// One evaluated grid point.
#[derive(Debug, Clone)]
pub struct GridPoint {
    pub parallel: ParallelConfig,
    pub result: SimResult,
}

/// Sweep the space for one schedule on `n_devices` total devices with a
/// fixed mini-batch size `minibatch` (the paper holds B-hat fixed per GPU
/// count and model; N is derived as minibatch / (B*W), floored to a
/// multiple of D as the paper's N=D-default requires).
///
/// Returns all feasible points sorted by descending throughput.
pub fn grid_search(
    kind: ScheduleKind,
    model: &ModelConfig,
    space: &GridSpace,
    n_devices: usize,
    minibatch: usize,
) -> Result<Vec<GridPoint>> {
    let mut points = Vec::new();
    for &w in &space.w {
        for &d in &space.d {
            if w * d != n_devices {
                continue;
            }
            for &b in &space.b {
                // Derive N from the fixed mini-batch: B-hat = B * N * W.
                if minibatch % (b * w) != 0 {
                    continue;
                }
                let n = minibatch / (b * w);
                if n < d || n % d != 0 {
                    continue; // paper requires N >= D, N % D == 0
                }
                let parallel = ParallelConfig::new(kind, w, d, b, n);
                if parallel.validate().is_err() {
                    continue;
                }
                let cluster = ClusterConfig::paper_testbed(n_devices);
                let cfg = SimConfig { model: *model, parallel, cluster };
                let Ok(result) = simulate(&cfg) else { continue };
                if !result.fits(&cluster) {
                    continue; // OOM — the paper's grid search drops these
                }
                points.push(GridPoint { parallel, result });
            }
        }
    }
    points.sort_by(|a, b| {
        b.result.throughput.partial_cmp(&a.result.throughput).unwrap()
    });
    Ok(points)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::BERT_64;

    #[test]
    fn finds_feasible_points_bert_32gpu() {
        let pts =
            grid_search(ScheduleKind::BitPipe, &BERT_64, &GridSpace::bert64(), 32, 128).unwrap();
        assert!(!pts.is_empty(), "no feasible configuration found");
        // Sorted descending.
        for w in pts.windows(2) {
            assert!(w[0].result.throughput >= w[1].result.throughput);
        }
        // Every point uses exactly 32 devices and the full mini-batch.
        for p in &pts {
            assert_eq!(p.parallel.total_devices(), 32);
            assert_eq!(p.parallel.minibatch_size(), 128);
        }
    }

    #[test]
    fn infeasible_layouts_skipped() {
        // Device count with no (w, d) product in the space.
        let pts =
            grid_search(ScheduleKind::BitPipe, &BERT_64, &GridSpace::bert64(), 24, 128).unwrap();
        assert!(pts.is_empty());
    }

    #[test]
    fn best_d_for_bitpipe_is_8_on_32gpus() {
        // Paper Table 7: D=8 is the sweet spot for BitPipe on 32 GPUs.
        let pts =
            grid_search(ScheduleKind::BitPipe, &BERT_64, &GridSpace::bert64(), 32, 128).unwrap();
        let best = &pts[0];
        assert_eq!(best.parallel.d, 8, "best D {} (throughput {})", best.parallel.d, best.result.throughput);
    }
}
