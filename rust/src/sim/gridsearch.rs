//! Grid search over (W, D, B) — the paper's Table 4 procedure: for a fixed
//! device count P and schedule, sweep the parameter space, drop layouts
//! that do not fit in device memory, and report the best-throughput
//! configuration.
//!
//! # Compile-once / re-cost-many
//!
//! The default sweep runs on the compiled-DAG backend (`sim::dag`): each
//! distinct schedule *structure* (kind, D, N, v, sync, early-forward) is
//! built and lowered once into a [`CompiledDag`] held in a [`DagCache`],
//! and every grid point sharing it — and every later sweep handed the same
//! cache, e.g. Table 4's per-GPU-count × per-model loops — re-prices the
//! borrowed DAG with a fresh weight table instead of rebuilding the
//! schedule and re-simulating. Schedule generation (BitPipe's Appendix-B
//! portfolio search in particular) dominates a cold sweep, so the cache
//! pays for itself the first time a structure repeats; a cold sweep
//! compiles its missing structures concurrently over scoped threads
//! before the (serial, deterministic) re-cost pass. [`CostModel`]
//! construction is hoisted the same way: the (W, D, cluster)-dependent
//! [`LinkTopology`] tables are built once per (W, D) and shared across
//! all B candidates.
//!
//! Results are deterministic and bit-identical to the event-engine serial
//! baseline ([`grid_search_serial`], kept for benchmarking and
//! differential tests): candidates evaluate in canonical order and the
//! final ordering is a stable descending-throughput sort.
//!
//! # Batched re-cost
//!
//! [`grid_search_batched`] runs a whole family of sweeps (Table 4's
//! GPU-count loop) in one pass: (sweep, candidate) pairs are grouped by
//! structure and each group is priced in lanes of [`RECOST_LANES`] weight
//! tables per topo walk ([`CompiledDag::evaluate_batch`] — SoA `[k]`-lane
//! time vectors, bit-identical per lane to a scalar walk), with
//! consecutive B-only moves re-priced by
//! [`DagWeights::rebuild_for_batch_size`] instead of a [`CostModel`]
//! reconstruction. Within a single sweep every candidate's structure is
//! unique (N is part of the key), so lanes only form *across* sweeps —
//! which is exactly the Table-4 shape. The contended path cannot
//! lane-batch its walk (flow interleaving is weight-dependent, so lanes
//! diverge), but applies the same trick to the weight rows: one full
//! [`CostModel`] per (W, D) run, [`CostModel::rebatched`] for every
//! B-move after it.
//!
//! Contended sweeps ([`grid_search_opts`] with `contention: true`) run
//! the event engine — the only backend that prices link sharing — but no
//! longer rebuild anything per point: a [`StreamCache`] mirrors the
//! [`DagCache`] at the instruction-stream level. Each distinct schedule
//! structure is generated, validated and lowered (message-slot
//! [`StreamTables`](super::engine::StreamTables)) exactly once — cold
//! structures precompile concurrently on scoped threads, like the
//! uncontended path — and every grid point (and every later sweep handed
//! the same cache, Table-4 style) re-prices the borrowed streams with a
//! fresh [`CostModel`] on the incremental-settlement network.
//! [`CostModel`] construction is hoisted here too: one [`LinkTopology`]
//! per (W, D), shared across the B candidates. Evaluation fans out over
//! scoped worker threads with an atomic work-stealing cursor; results
//! are collected in canonical candidate order, so the output is
//! bit-identical across thread counts ([`grid_search_contended_serial`]
//! pins it). The PR-4 path — rebuild every candidate's schedule and run
//! global settlement — survives as [`grid_search_opts_baseline`], the
//! benchable before/after for `cargo bench --bench hotpath`.
//!
//! Since the collectives landed on the wire, a contended sweep ranks
//! layouts under the full model: all-reduce ring flows squeeze the P2P
//! traffic they overlap, and per-node NIC aggregation penalizes layouts
//! that fan a node's traffic out to many peers.

use super::engine::{simulate_streams_lowered, StreamTables};
use super::{
    assemble_result, memory_footprint, memory_footprint_from_counts, run_streams, simulate,
    simulate_faulted, CompiledDag, Contention, CostModel, DagWeights, Engine, LinkTopology,
    NetworkImpl, SimConfig, SimResult,
};
use crate::config::{ClusterConfig, FaultPlan, ModelConfig, ParallelConfig};
use crate::schedule::{self, Schedule, ScheduleConfig, ScheduleKind, SyncPolicy};
use anyhow::Result;
use std::sync::atomic::{AtomicUsize, Ordering};

/// The search space (paper Table 4 "Considered Values").
#[derive(Debug, Clone)]
pub struct GridSpace {
    pub w: Vec<usize>,
    pub d: Vec<usize>,
    pub b: Vec<usize>,
}

impl GridSpace {
    /// Paper Table 4, BERT-64 row.
    pub fn bert64() -> Self {
        GridSpace { w: vec![1, 2, 4, 8], d: vec![4, 8, 16], b: vec![1, 2, 4, 8] }
    }

    /// Paper Table 4, GPT-96 row.
    pub fn gpt96() -> Self {
        GridSpace { w: vec![1, 2, 4], d: vec![8, 16], b: vec![1, 2] }
    }
}

/// One evaluated grid point.
#[derive(Debug, Clone)]
pub struct GridPoint {
    pub parallel: ParallelConfig,
    pub result: SimResult,
}

/// Schedule-structure identity: everything the compiled DAG depends on.
/// W, B and the cluster are deliberately absent — they only affect weights.
#[derive(Debug, Clone, Copy, PartialEq)]
struct StructKey {
    kind: ScheduleKind,
    d: usize,
    n: usize,
    v: usize,
    sync: SyncPolicy,
    early_forward: bool,
}

impl StructKey {
    fn of(cfg: &ScheduleConfig) -> Self {
        StructKey {
            kind: cfg.kind,
            d: cfg.d,
            n: cfg.n,
            v: cfg.v,
            sync: cfg.sync,
            early_forward: cfg.early_forward,
        }
    }
}

/// Cached lowering of one schedule structure.
#[derive(Debug)]
enum Compiled {
    /// The common case: re-weight and evaluate in one linear pass.
    Dag(CompiledDag),
    /// Structure the DAG compiler cannot serialize (never produced by
    /// `comm_pass`): keep the schedule, run the event engine per point.
    Event(Box<Schedule>),
    /// Schedule generation failed; every candidate of this structure skips.
    Failed,
}

/// Compile-once/re-cost-many cache for DAG-backed sweeps. One instance can
/// (and should) be shared across sweeps: Table 4's loops over GPU counts
/// and models revisit the same (kind, D, N) structures, and each hit skips
/// both the schedule build and the DAG lowering. Entries are structure
/// only — they never depend on W, B, the model, or the cluster.
#[derive(Debug, Default)]
pub struct DagCache {
    entries: Vec<(StructKey, Compiled)>,
}

impl DagCache {
    pub fn new() -> Self {
        DagCache { entries: Vec::new() }
    }

    /// Number of cached structures.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    fn contains(&self, key: &StructKey) -> bool {
        self.entries.iter().any(|(k, _)| k == key)
    }

    fn position(&self, key: &StructKey) -> Option<usize> {
        self.entries.iter().position(|(k, _)| k == key)
    }

    fn get_or_compile(&mut self, cfg: &ScheduleConfig) -> &Compiled {
        let key = StructKey::of(cfg);
        if let Some(pos) = self.entries.iter().position(|(k, _)| *k == key) {
            return &self.entries[pos].1;
        }
        self.entries.push((key, compile_structure(cfg)));
        &self.entries[self.entries.len() - 1].1
    }
}

/// Build + lower one schedule structure (the expensive, per-structure work).
fn compile_structure(cfg: &ScheduleConfig) -> Compiled {
    match schedule::build(cfg) {
        Ok(s) => match CompiledDag::compile(&s) {
            Ok(dag) => Compiled::Dag(dag),
            Err(_) => Compiled::Event(Box::new(s)),
        },
        Err(_) => Compiled::Failed,
    }
}

/// Compile `missing` structures into the cache in canonical order, fanning
/// the per-structure work (schedule generation dominates a cold sweep and
/// is embarrassingly parallel) out over scoped threads when there is more
/// than one. Results are deterministic and insertion follows the input
/// order, so the cache contents — and everything downstream — are
/// independent of thread scheduling, bit-identical to a serial compile.
fn precompile_into(cache: &mut DagCache, missing: &[ScheduleConfig]) {
    let threads = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
        .min(missing.len());
    if threads > 1 {
        // Capped work-stealing fan-out (same shape as the contended
        // sweep): one slot per core, an atomic cursor over the structures.
        let next = AtomicUsize::new(0);
        let mut compiled: Vec<(usize, Compiled)> = std::thread::scope(|scope| {
            let handles: Vec<_> = (0..threads)
                .map(|_| {
                    let next = &next;
                    scope.spawn(move || {
                        let mut out = Vec::new();
                        loop {
                            let i = next.fetch_add(1, Ordering::Relaxed);
                            if i >= missing.len() {
                                break;
                            }
                            out.push((i, compile_structure(&missing[i])));
                        }
                        out
                    })
                })
                .collect();
            handles
                .into_iter()
                .flat_map(|h| h.join().expect("structure-compile worker panicked"))
                .collect()
        });
        compiled.sort_by_key(|&(i, _)| i);
        for (i, comp) in compiled {
            cache.entries.push((StructKey::of(&missing[i]), comp));
        }
    } else {
        for scfg in missing {
            cache.entries.push((StructKey::of(scfg), compile_structure(scfg)));
        }
    }
}

/// Cached lowering of one schedule structure for *contended* evaluation:
/// the built streams plus their message-slot tables. Structure-only, like
/// a [`DagCache`] entry — (W, B, cluster) pricing happens per point.
#[derive(Debug)]
enum CompiledStream {
    Ready {
        sched: Box<Schedule>,
        tables: StreamTables,
    },
    /// Schedule generation failed; every candidate of this structure skips.
    Failed,
}

/// [`DagCache`]'s sibling for contended sweeps: compile-once /
/// re-price-many at the instruction-stream level. Each distinct schedule
/// structure is generated + validated + lowered ([`StreamTables`]) once;
/// every grid point sharing it — and every later sweep handed the same
/// cache, e.g. a Table-4-style loop over GPU counts and models — re-runs
/// the borrowed streams on the incremental-network event engine with a
/// fresh cost model. Entries never depend on W, B, the model, or the
/// cluster.
#[derive(Debug, Default)]
pub struct StreamCache {
    entries: Vec<(StructKey, CompiledStream)>,
}

impl StreamCache {
    pub fn new() -> Self {
        StreamCache { entries: Vec::new() }
    }

    /// Number of cached structures.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    fn contains(&self, key: &StructKey) -> bool {
        self.entries.iter().any(|(k, _)| k == key)
    }

    fn position(&self, key: &StructKey) -> Option<usize> {
        self.entries.iter().position(|(k, _)| k == key)
    }
}

/// Build + lower one schedule structure for contended evaluation.
fn compile_stream(cfg: &ScheduleConfig) -> CompiledStream {
    match schedule::build(cfg) {
        Ok(s) => {
            let tables = StreamTables::build(&s);
            CompiledStream::Ready { sched: Box::new(s), tables }
        }
        Err(_) => CompiledStream::Failed,
    }
}

/// Price one candidate against a cached stream structure: prebuilt cost
/// model (hoisted topology; incrementally re-batched along B runs),
/// cached schedule + message-slot tables, the incremental-settlement
/// network. Bit-identical to [`evaluate`] with `contention: true` and the
/// default [`NetworkImpl`] — generation is deterministic, so the cached
/// schedule is the one a rebuild would produce.
fn evaluate_stream(
    model: &ModelConfig,
    cluster: &ClusterConfig,
    parallel: ParallelConfig,
    compiled: &CompiledStream,
    costs: &CostModel,
) -> Option<GridPoint> {
    let CompiledStream::Ready { sched, tables } = compiled else {
        return None;
    };
    let trace = simulate_streams_lowered(
        sched,
        costs,
        1,
        Contention::Full,
        NetworkImpl::default(),
        tables,
    )
    .ok()?;
    let memory = memory_footprint(sched, model, &parallel);
    let result = assemble_result(
        parallel.minibatch_size(),
        sched.n_devices(),
        &trace.devices,
        trace.makespan,
        memory,
    );
    if !result.fits(cluster) {
        return None;
    }
    Some(GridPoint { parallel, result })
}

/// Enumerate the feasible-by-arithmetic candidates of the sweep (the cheap
/// filters: device count, mini-batch divisibility, N >= D, validation).
fn candidates(
    kind: ScheduleKind,
    space: &GridSpace,
    n_devices: usize,
    minibatch: usize,
) -> Vec<ParallelConfig> {
    let mut out = Vec::new();
    for &w in &space.w {
        for &d in &space.d {
            if w * d != n_devices {
                continue;
            }
            for &b in &space.b {
                // Derive N from the fixed mini-batch: B-hat = B * N * W.
                if minibatch % (b * w) != 0 {
                    continue;
                }
                let n = minibatch / (b * w);
                if n < d || n % d != 0 {
                    continue; // paper requires N >= D, N % D == 0
                }
                let parallel = ParallelConfig::new(kind, w, d, b, n);
                if parallel.validate().is_err() {
                    continue;
                }
                out.push(parallel);
            }
        }
    }
    out
}

/// Simulate one candidate on the event engine, rebuilding its schedule
/// from scratch; `None` for layouts that fail to simulate or do not fit
/// in device memory (the paper's grid search drops these). The serial
/// and PR-4-baseline paths go through here.
fn evaluate(
    model: &ModelConfig,
    cluster: &ClusterConfig,
    parallel: ParallelConfig,
    contention: bool,
    network: NetworkImpl,
) -> Option<GridPoint> {
    let cfg = SimConfig::new(*model, parallel, *cluster)
        .with_contention(contention)
        .with_engine(Engine::Event)
        .with_network(network);
    let result = simulate(&cfg).ok()?;
    if !result.fits(cluster) {
        return None;
    }
    Some(GridPoint { parallel, result })
}

/// Index of the hoisted topology for `(w, d)`, building it on first use.
fn topo_index(
    topos: &mut Vec<((usize, usize), LinkTopology)>,
    cluster: &ClusterConfig,
    w: usize,
    d: usize,
) -> usize {
    if let Some(i) = topos.iter().position(|&(k, _)| k == (w, d)) {
        return i;
    }
    topos.push(((w, d), LinkTopology::new(cluster, w, d)));
    topos.len() - 1
}

/// Evaluate one candidate against the structure cache: re-weight the
/// borrowed DAG and run the linear longest-path pass. Produces results
/// bit-identical to [`evaluate`] with `contention: false`.
fn evaluate_cached(
    model: &ModelConfig,
    cluster: &ClusterConfig,
    parallel: ParallelConfig,
    cache: &mut DagCache,
    topos: &mut Vec<((usize, usize), LinkTopology)>,
) -> Option<GridPoint> {
    let scfg = parallel.schedule();
    let ti = topo_index(topos, cluster, parallel.w, parallel.d);
    let result = match cache.get_or_compile(&scfg) {
        Compiled::Failed => return None,
        Compiled::Dag(dag) => {
            let costs = CostModel::with_topology(model, &parallel, cluster, &topos[ti].1);
            let trace = dag.evaluate(&dag.weights(&costs), 1).ok()?;
            let memory = memory_footprint_from_counts(
                dag.held_chunks(),
                dag.peak_stash(),
                model,
                &parallel,
            );
            assemble_result(
                parallel.minibatch_size(),
                dag.n_devices(),
                &trace.devices,
                trace.makespan,
                memory,
            )
        }
        Compiled::Event(s) => {
            return evaluate_event_point(model, cluster, parallel, s, &topos[ti].1);
        }
    };
    if !result.fits(cluster) {
        return None;
    }
    Some(GridPoint { parallel, result })
}

/// Stable descending-throughput order (candidate order breaks ties, so the
/// result is deterministic).
fn sort_points(points: &mut [GridPoint]) {
    points.sort_by(|a, b| {
        b.result
            .throughput
            .partial_cmp(&a.result.throughput)
            .expect("throughputs are finite")
    });
}

/// Sweep the space for one schedule on `n_devices` total devices with a
/// fixed mini-batch size `minibatch` (the paper holds B-hat fixed per GPU
/// count and model; N is derived as minibatch / (B*W), floored to a
/// multiple of D as the paper's N=D-default requires).
///
/// Returns all feasible points sorted by descending throughput. Runs on
/// the compiled-DAG backend with a sweep-local structure cache; results
/// are bit-identical to [`grid_search_serial`]'s event-engine baseline.
pub fn grid_search(
    kind: ScheduleKind,
    model: &ModelConfig,
    space: &GridSpace,
    n_devices: usize,
    minibatch: usize,
) -> Result<Vec<GridPoint>> {
    grid_search_cached(kind, model, space, n_devices, minibatch, &mut DagCache::new())
}

/// [`grid_search`] with a caller-owned [`DagCache`], the
/// compile-once/re-cost-many entry point: structures compiled for one
/// sweep are reused by every later sweep handed the same cache (Table 4
/// regenerates 24 sweeps from a couple dozen distinct structures).
pub fn grid_search_cached(
    kind: ScheduleKind,
    model: &ModelConfig,
    space: &GridSpace,
    n_devices: usize,
    minibatch: usize,
    cache: &mut DagCache,
) -> Result<Vec<GridPoint>> {
    let cluster = ClusterConfig::paper_testbed(n_devices);
    grid_search_on_cluster(kind, model, space, minibatch, &cluster, cache)
}

/// [`grid_search_cached`] on an explicit — possibly heterogeneous or
/// degraded — cluster: stragglers and link overrides price into the
/// weight tables (per-node compute scales on [`DagWeights`], overridden
/// link rates in the P2P block), while the compiled structures stay
/// cluster-independent, so one cache serves healthy and degraded sweeps
/// alike. With an all-neutral cluster this is bit-identical to
/// [`grid_search_cached`] (`rust/tests/hetero_identity.rs`).
pub fn grid_search_on_cluster(
    kind: ScheduleKind,
    model: &ModelConfig,
    space: &GridSpace,
    minibatch: usize,
    cluster: &ClusterConfig,
    cache: &mut DagCache,
) -> Result<Vec<GridPoint>> {
    let cands = candidates(kind, space, cluster.n_devices, minibatch);
    if cluster.validate().is_err() || model.validate().is_err() {
        return Ok(Vec::new()); // every point would fail exactly this way
    }
    // Pre-compile the structures this sweep still misses (canonical
    // candidate order, scoped-thread fan-out).
    let mut missing: Vec<ScheduleConfig> = Vec::new();
    for p in &cands {
        let scfg = p.schedule();
        let key = StructKey::of(&scfg);
        if !cache.contains(&key) && !missing.iter().any(|c| StructKey::of(c) == key) {
            missing.push(scfg);
        }
    }
    precompile_into(cache, &missing);
    let mut topos: Vec<((usize, usize), LinkTopology)> = Vec::new();
    let mut points: Vec<GridPoint> = cands
        .into_iter()
        .filter_map(|p| evaluate_cached(model, cluster, p, cache, &mut topos))
        .collect();
    sort_points(&mut points);
    Ok(points)
}

/// Lane width for the batched re-cost: candidates sharing a compiled
/// structure are priced in SoA lanes of at most this many weight tables
/// per topo walk ([`CompiledDag::evaluate_batch`]). A tail shorter than
/// this pads up to the next power of two (1, 2, 4, 8) by repeating its
/// last table, so walk widths come from a small fixed set.
pub const RECOST_LANES: usize = 8;

/// Index of the hoisted topology for `(n_devices, w, d)`, building it on
/// first use — the multi-sweep sibling of [`topo_index`] (sweeps differ in
/// device count, hence in cluster).
fn topo_index_for(
    topos: &mut Vec<((usize, usize, usize), LinkTopology)>,
    cluster: &ClusterConfig,
    n_devices: usize,
    w: usize,
    d: usize,
) -> usize {
    if let Some(i) = topos.iter().position(|&(k, _)| k == (n_devices, w, d)) {
        return i;
    }
    topos.push(((n_devices, w, d), LinkTopology::new(cluster, w, d)));
    topos.len() - 1
}

/// Price one candidate against a cached *event-fallback* structure (a
/// schedule the DAG compiler cannot serialize): the per-point event-engine
/// arm shared by [`evaluate_cached`] and the batched sweep.
fn evaluate_event_point(
    model: &ModelConfig,
    cluster: &ClusterConfig,
    parallel: ParallelConfig,
    s: &Schedule,
    topo: &LinkTopology,
) -> Option<GridPoint> {
    let costs = CostModel::with_topology(model, &parallel, cluster, topo);
    let trace = run_streams(s, &costs, 1, false, Engine::Event, NetworkImpl::default()).ok()?;
    let memory = memory_footprint(s, model, &parallel);
    let result = assemble_result(
        parallel.minibatch_size(),
        s.n_devices(),
        &trace.devices,
        trace.makespan,
        memory,
    );
    if !result.fits(cluster) {
        return None;
    }
    Some(GridPoint { parallel, result })
}

/// A whole *family* of sweeps in one pass — Table 4's loop over GPU counts
/// for one (kind, model) — returning one result vector per `(n_devices,
/// minibatch)` sweep, each bit-identical (points, order, tie-breaks) to a
/// solo [`grid_search_cached`] call with the same shared cache.
///
/// This is where the batched re-cost pays: within one sweep every
/// candidate has a *unique* structure (N = minibatch / (B·W) is part of
/// the structure key), but across sweeps the same (kind, D, N) structures
/// recur with different (W, B, cluster) pricings. The batched sweep
/// groups all (sweep, candidate) pairs by structure and prices each group
/// in lanes of [`RECOST_LANES`] weight tables per topo walk
/// ([`CompiledDag::evaluate_batch`]; tail lane padded to a power of two
/// by repeating its last table, padded outputs discarded). Consecutive
/// group members that differ only in B re-price by
/// [`DagWeights::rebuild_for_batch_size`] over the hoisted
/// [`LinkTopology`] instead of reconstructing a [`CostModel`]. Per-sweep
/// results are collected in canonical candidate order before the stable
/// throughput sort, so lane grouping cannot perturb the (time, point)
/// tie-break.
pub fn grid_search_batched(
    kind: ScheduleKind,
    model: &ModelConfig,
    space: &GridSpace,
    sweeps: &[(usize, usize)],
    cache: &mut DagCache,
) -> Result<Vec<Vec<GridPoint>>> {
    let model_ok = model.validate().is_ok();
    let mut clusters: Vec<ClusterConfig> = Vec::with_capacity(sweeps.len());
    let mut cands: Vec<Vec<ParallelConfig>> = Vec::with_capacity(sweeps.len());
    for &(n_devices, minibatch) in sweeps {
        let cluster = ClusterConfig::paper_testbed(n_devices);
        // An infeasible sweep yields an empty result, exactly like the
        // per-sweep entry points; the others proceed.
        let ok = model_ok && cluster.validate().is_ok();
        cands.push(if ok { candidates(kind, space, n_devices, minibatch) } else { Vec::new() });
        clusters.push(cluster);
    }
    // Compile the union of missing structures across all sweeps, in
    // canonical (sweep, candidate) order.
    let mut missing: Vec<ScheduleConfig> = Vec::new();
    for sweep in &cands {
        for p in sweep {
            let scfg = p.schedule();
            let key = StructKey::of(&scfg);
            if !cache.contains(&key) && !missing.iter().any(|c| StructKey::of(c) == key) {
                missing.push(scfg);
            }
        }
    }
    precompile_into(cache, &missing);
    // Group every (sweep, candidate) pair by structure: groups form in
    // first-appearance order, members stay in canonical order.
    let mut groups: Vec<(usize, Vec<(usize, usize)>)> = Vec::new();
    for (si, sweep) in cands.iter().enumerate() {
        for (ci, p) in sweep.iter().enumerate() {
            let key = StructKey::of(&p.schedule());
            let pos = cache.position(&key).expect("precompiled above");
            match groups.iter_mut().find(|(g, _)| *g == pos) {
                Some((_, members)) => members.push((si, ci)),
                None => groups.push((pos, vec![(si, ci)])),
            }
        }
    }
    let cache = &*cache;
    let mut topos: Vec<((usize, usize, usize), LinkTopology)> = Vec::new();
    let mut out: Vec<Vec<(usize, GridPoint)>> = vec![Vec::new(); sweeps.len()];
    for (pos, members) in &groups {
        match &cache.entries[*pos].1 {
            Compiled::Failed => {}
            Compiled::Event(s) => {
                // Event-fallback structures price per point — the walk is
                // not lane-batchable there.
                for &(si, ci) in members {
                    let p = cands[si][ci];
                    let ti = topo_index_for(&mut topos, &clusters[si], sweeps[si].0, p.w, p.d);
                    if let Some(point) =
                        evaluate_event_point(model, &clusters[si], p, s, &topos[ti].1)
                    {
                        out[si].push((ci, point));
                    }
                }
            }
            Compiled::Dag(dag) => {
                // Weight tables per member: a full CostModel build when
                // the (cluster, W) context changes, an incremental B-move
                // rebuild (bit-identical, far cheaper) when only B does.
                let mut tables: Vec<DagWeights> = Vec::with_capacity(members.len());
                let mut prev: Option<(usize, usize)> = None;
                for &(si, ci) in members {
                    let p = cands[si][ci];
                    let ti = topo_index_for(&mut topos, &clusters[si], sweeps[si].0, p.w, p.d);
                    let tab = if prev == Some((sweeps[si].0, p.w)) {
                        let mut t = tables.last().expect("prev member exists").clone();
                        t.rebuild_for_batch_size(&topos[ti].1.batch_pricing(
                            model,
                            &p,
                            &clusters[si],
                        ));
                        t
                    } else {
                        dag.weights(&CostModel::with_topology(
                            model,
                            &p,
                            &clusters[si],
                            &topos[ti].1,
                        ))
                    };
                    prev = Some((sweeps[si].0, p.w));
                    tables.push(tab);
                }
                // Walk the group in lanes; singleton chunks take the
                // scalar pass (no transpose overhead).
                let mut mi = 0usize;
                while mi < members.len() {
                    let chunk = (members.len() - mi).min(RECOST_LANES);
                    let traces = if chunk == 1 {
                        match dag.evaluate(&tables[mi], 1) {
                            Ok(t) => vec![t],
                            Err(_) => break, // stuck: every member fails alike
                        }
                    } else {
                        let width = chunk.next_power_of_two();
                        let lane: Vec<DagWeights> = tables[mi..mi + chunk]
                            .iter()
                            .cloned()
                            .chain(
                                std::iter::repeat_with(|| tables[mi + chunk - 1].clone())
                                    .take(width - chunk),
                            )
                            .collect();
                        match dag.evaluate_batch(&lane, 1) {
                            Ok(t) => t,
                            Err(_) => break,
                        }
                    };
                    for (j, trace) in traces.into_iter().take(chunk).enumerate() {
                        let (si, ci) = members[mi + j];
                        let p = cands[si][ci];
                        let memory = memory_footprint_from_counts(
                            dag.held_chunks(),
                            dag.peak_stash(),
                            model,
                            &p,
                        );
                        let result = assemble_result(
                            p.minibatch_size(),
                            dag.n_devices(),
                            &trace.devices,
                            trace.makespan,
                            memory,
                        );
                        if result.fits(&clusters[si]) {
                            out[si].push((ci, GridPoint { parallel: p, result }));
                        }
                    }
                    mi += chunk;
                }
            }
        }
    }
    // Per sweep: canonical candidate order first, then the stable
    // throughput sort — byte-for-byte the scalar-warm result.
    Ok(out
        .into_iter()
        .map(|mut found| {
            found.sort_by_key(|&(ci, _)| ci);
            let mut pts: Vec<GridPoint> = found.into_iter().map(|(_, p)| p).collect();
            sort_points(&mut pts);
            pts
        })
        .collect())
}

/// [`grid_search`] with an explicit contention mode: `contention` true
/// prices every candidate under the flow-level link-sharing model (see
/// `sim::engine`), ranking layouts by their contended throughput — the
/// fidelity the Fig 6 mapping tradeoffs need. Contended sweeps run the
/// event engine on the compile-once [`StreamCache`] fast path (sweep-local
/// cache); uncontended sweeps take the compiled-DAG path.
pub fn grid_search_opts(
    kind: ScheduleKind,
    model: &ModelConfig,
    space: &GridSpace,
    n_devices: usize,
    minibatch: usize,
    contention: bool,
) -> Result<Vec<GridPoint>> {
    if !contention {
        return grid_search(kind, model, space, n_devices, minibatch);
    }
    grid_search_contended_cached(kind, model, space, n_devices, minibatch, &mut StreamCache::new())
}

/// Contended sweep with a caller-owned [`StreamCache`] — the
/// compile-once/re-price-many entry point, mirroring
/// [`grid_search_cached`]: structures compiled for one sweep are reused
/// by every later sweep handed the same cache. Evaluation fans out over
/// scoped worker threads; output is bit-identical to
/// [`grid_search_contended_serial`] regardless of thread count.
pub fn grid_search_contended_cached(
    kind: ScheduleKind,
    model: &ModelConfig,
    space: &GridSpace,
    n_devices: usize,
    minibatch: usize,
    cache: &mut StreamCache,
) -> Result<Vec<GridPoint>> {
    let threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    grid_search_contended_impl(kind, model, space, n_devices, minibatch, cache, threads)
}

/// Single-threaded contended sweep on the [`StreamCache`] fast path —
/// the determinism anchor the thread-count-invariance test pins the
/// threaded sweep against.
pub fn grid_search_contended_serial(
    kind: ScheduleKind,
    model: &ModelConfig,
    space: &GridSpace,
    n_devices: usize,
    minibatch: usize,
) -> Result<Vec<GridPoint>> {
    grid_search_contended_impl(
        kind,
        model,
        space,
        n_devices,
        minibatch,
        &mut StreamCache::new(),
        1,
    )
}

fn grid_search_contended_impl(
    kind: ScheduleKind,
    model: &ModelConfig,
    space: &GridSpace,
    n_devices: usize,
    minibatch: usize,
    cache: &mut StreamCache,
    threads: usize,
) -> Result<Vec<GridPoint>> {
    let cands = candidates(kind, space, n_devices, minibatch);
    let cluster = ClusterConfig::paper_testbed(n_devices);
    if cluster.validate().is_err() || model.validate().is_err() {
        return Ok(Vec::new()); // every point would fail exactly this way
    }
    // Phase 1 — compile the structures this sweep still misses, in
    // canonical candidate order (schedule generation dominates a cold
    // sweep and is embarrassingly parallel; insertion order keeps the
    // cache independent of thread scheduling).
    let mut missing: Vec<ScheduleConfig> = Vec::new();
    for p in &cands {
        let scfg = p.schedule();
        let key = StructKey::of(&scfg);
        if !cache.contains(&key) && !missing.iter().any(|c| StructKey::of(c) == key) {
            missing.push(scfg);
        }
    }
    let compile_threads = threads.min(missing.len());
    if compile_threads > 1 {
        let next = AtomicUsize::new(0);
        let mut compiled: Vec<(usize, CompiledStream)> = std::thread::scope(|scope| {
            let handles: Vec<_> = (0..compile_threads)
                .map(|_| {
                    let next = &next;
                    let missing = &missing;
                    scope.spawn(move || {
                        let mut out = Vec::new();
                        loop {
                            let i = next.fetch_add(1, Ordering::Relaxed);
                            if i >= missing.len() {
                                break;
                            }
                            out.push((i, compile_stream(&missing[i])));
                        }
                        out
                    })
                })
                .collect();
            handles
                .into_iter()
                .flat_map(|h| h.join().expect("stream-compile worker panicked"))
                .collect()
        });
        compiled.sort_by_key(|&(i, _)| i);
        for (i, comp) in compiled {
            cache.entries.push((StructKey::of(&missing[i]), comp));
        }
    } else {
        for scfg in &missing {
            cache.entries.push((StructKey::of(scfg), compile_stream(scfg)));
        }
    }
    // Phase 2 — hoist the (W, D)-dependent pieces: one LinkTopology per
    // (W, D) shared across all B candidates (satellite of the DAG path's
    // hoisting, now on the contended path too), and the cache position of
    // every candidate's structure.
    let mut topos: Vec<((usize, usize), LinkTopology)> = Vec::new();
    let lookup: Vec<(usize, usize)> = cands
        .iter()
        .map(|p| {
            let key = StructKey::of(&p.schedule());
            let e = cache.position(&key).expect("compiled in phase 1");
            let t = topo_index(&mut topos, &cluster, p.w, p.d);
            (e, t)
        })
        .collect();
    // Phase 2.5 — per-candidate cost models, built serially with the lane
    // trick applied to the *weight rows*: the contended event walk itself
    // is weight-dependent (flow interleaving makes lanes diverge), so
    // evaluation stays per point, but the first candidate of each (W, D)
    // run builds one full model and every later candidate of the run — a
    // B-only move — re-prices it with [`CostModel::rebatched`], reusing
    // the ring/optimizer tables bitwise instead of rebuilding them.
    let mut cms: Vec<CostModel> = Vec::with_capacity(cands.len());
    let mut prev: Option<(usize, usize, usize)> = None;
    for (i, p) in cands.iter().enumerate() {
        let (_, t) = lookup[i];
        let cm = match prev {
            Some((w, d, j)) if (w, d) == (p.w, p.d) => cms[j].rebatched(model, p, &topos[t].1),
            _ => CostModel::with_topology(model, p, &cluster, &topos[t].1),
        };
        prev = Some((p.w, p.d, i));
        cms.push(cm);
    }
    // Phase 3 — price every candidate against its borrowed streams.
    let cache = &*cache;
    let eval_threads = threads.min(cands.len().max(1));
    let mut indexed: Vec<(usize, GridPoint)> = if eval_threads <= 1 || cands.len() <= 1 {
        cands
            .iter()
            .enumerate()
            .filter_map(|(i, &p)| {
                let (e, _) = lookup[i];
                evaluate_stream(model, &cluster, p, &cache.entries[e].1, &cms[i])
                    .map(|point| (i, point))
            })
            .collect()
    } else {
        let next = AtomicUsize::new(0);
        std::thread::scope(|scope| {
            let mut handles = Vec::with_capacity(eval_threads);
            for _ in 0..eval_threads {
                let next = &next;
                let cands = &cands;
                let cluster = &cluster;
                let lookup = &lookup;
                let cms = &cms;
                handles.push(scope.spawn(move || {
                    let mut found: Vec<(usize, GridPoint)> = Vec::new();
                    loop {
                        let i = next.fetch_add(1, Ordering::Relaxed);
                        if i >= cands.len() {
                            break;
                        }
                        let (e, _) = lookup[i];
                        let entry = &cache.entries[e].1;
                        if let Some(point) =
                            evaluate_stream(model, cluster, cands[i], entry, &cms[i])
                        {
                            found.push((i, point));
                        }
                    }
                    found
                }));
            }
            let mut all = Vec::new();
            for h in handles {
                all.extend(h.join().expect("grid-search worker panicked"));
            }
            all
        })
    };
    // Canonical candidate order first, then the stable throughput sort —
    // byte-for-byte the serial result.
    indexed.sort_by_key(|&(i, _)| i);
    let mut points: Vec<GridPoint> = indexed.into_iter().map(|(_, p)| p).collect();
    sort_points(&mut points);
    Ok(points)
}

/// The PR-4 contended sweep, kept benchable as the before/after baseline
/// for `cargo bench --bench hotpath`: every candidate rebuilds its
/// schedule from scratch (the Appendix-B portfolio search included) and
/// runs the event engine with [`NetworkImpl::Global`] settlement, fanned
/// out over scoped worker threads with an atomic work-stealing cursor.
pub fn grid_search_opts_baseline(
    kind: ScheduleKind,
    model: &ModelConfig,
    space: &GridSpace,
    n_devices: usize,
    minibatch: usize,
) -> Result<Vec<GridPoint>> {
    let cands = candidates(kind, space, n_devices, minibatch);
    let cluster = ClusterConfig::paper_testbed(n_devices);
    let threads = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
        .min(cands.len().max(1));
    if threads <= 1 || cands.len() <= 1 {
        let mut points: Vec<GridPoint> = cands
            .into_iter()
            .filter_map(|p| evaluate(model, &cluster, p, true, NetworkImpl::Global))
            .collect();
        sort_points(&mut points);
        return Ok(points);
    }

    let next = AtomicUsize::new(0);
    let mut indexed: Vec<(usize, GridPoint)> = std::thread::scope(|scope| {
        let mut handles = Vec::with_capacity(threads);
        for _ in 0..threads {
            let next = &next;
            let cands = &cands;
            let cluster = &cluster;
            handles.push(scope.spawn(move || {
                let mut found: Vec<(usize, GridPoint)> = Vec::new();
                loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= cands.len() {
                        break;
                    }
                    if let Some(point) =
                        evaluate(model, cluster, cands[i], true, NetworkImpl::Global)
                    {
                        found.push((i, point));
                    }
                }
                found
            }));
        }
        let mut all = Vec::new();
        for h in handles {
            all.extend(h.join().expect("grid-search worker panicked"));
        }
        all
    });

    indexed.sort_by_key(|&(i, _)| i);
    let mut points: Vec<GridPoint> = indexed.into_iter().map(|(_, p)| p).collect();
    sort_points(&mut points);
    Ok(points)
}

/// The single-threaded event-engine sweep — the pre-DAG baseline, kept for
/// `benches/hotpath.rs` speedup measurements and as the differential
/// oracle the DAG path must match bit for bit.
pub fn grid_search_serial(
    kind: ScheduleKind,
    model: &ModelConfig,
    space: &GridSpace,
    n_devices: usize,
    minibatch: usize,
) -> Result<Vec<GridPoint>> {
    let cluster = ClusterConfig::paper_testbed(n_devices);
    let mut points: Vec<GridPoint> = candidates(kind, space, n_devices, minibatch)
        .into_iter()
        .filter_map(|p| evaluate(model, &cluster, p, false, NetworkImpl::default()))
        .collect();
    sort_points(&mut points);
    Ok(points)
}

/// One point of a resilience sweep: a parallel layout run under the
/// seeded fault trace of the given intensity.
#[derive(Debug, Clone)]
pub struct ResiliencePoint {
    pub parallel: ParallelConfig,
    pub intensity: f64,
    /// The expanded trace the point replayed (empty at intensity 0).
    pub plan: FaultPlan,
    pub result: SimResult,
}

/// Sweep `layouts x intensities` under seeded fault traces: every point
/// replays `FaultPlan::random(seed, intensity, horizon, d)` — the *same*
/// trace for every layout sharing a D, so families are compared under
/// identical weather. Points fan out over scoped worker threads with an
/// atomic work-stealing cursor but are collected in canonical
/// (layout-major, intensity-minor) order, so the output is bit-identical
/// across thread counts; [`resilience_sweep_serial`] pins it.
pub fn resilience_sweep(
    model: &ModelConfig,
    cluster: &ClusterConfig,
    layouts: &[ParallelConfig],
    intensities: &[f64],
    seed: u64,
    horizon: f64,
) -> Result<Vec<ResiliencePoint>> {
    let cands = resilience_candidates(layouts, intensities, seed, horizon)?;
    let threads = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
        .min(cands.len().max(1));
    if threads <= 1 || cands.len() <= 1 {
        return cands
            .into_iter()
            .map(|(parallel, intensity, plan)| {
                resilience_point(model, cluster, parallel, intensity, plan)
            })
            .collect();
    }
    let next = AtomicUsize::new(0);
    let mut indexed: Vec<(usize, Result<ResiliencePoint>)> = std::thread::scope(|scope| {
        let mut handles = Vec::with_capacity(threads);
        for _ in 0..threads {
            let next = &next;
            let cands = &cands;
            handles.push(scope.spawn(move || {
                let mut found = Vec::new();
                loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= cands.len() {
                        break;
                    }
                    let (parallel, intensity, plan) = cands[i].clone();
                    found.push((i, resilience_point(model, cluster, parallel, intensity, plan)));
                }
                found
            }));
        }
        let mut all = Vec::new();
        for h in handles {
            all.extend(h.join().expect("resilience-sweep worker panicked"));
        }
        all
    });
    indexed.sort_by_key(|&(i, _)| i);
    indexed.into_iter().map(|(_, r)| r).collect()
}

/// Single-threaded [`resilience_sweep`] — the determinism oracle the
/// threaded path must match bit for bit.
pub fn resilience_sweep_serial(
    model: &ModelConfig,
    cluster: &ClusterConfig,
    layouts: &[ParallelConfig],
    intensities: &[f64],
    seed: u64,
    horizon: f64,
) -> Result<Vec<ResiliencePoint>> {
    resilience_candidates(layouts, intensities, seed, horizon)?
        .into_iter()
        .map(|(parallel, intensity, plan)| {
            resilience_point(model, cluster, parallel, intensity, plan)
        })
        .collect()
}

/// Expand the candidate list with its fault traces up front (layout-major,
/// intensity-minor — the canonical output order).
fn resilience_candidates(
    layouts: &[ParallelConfig],
    intensities: &[f64],
    seed: u64,
    horizon: f64,
) -> Result<Vec<(ParallelConfig, f64, FaultPlan)>> {
    let mut cands = Vec::with_capacity(layouts.len() * intensities.len());
    for &parallel in layouts {
        for &intensity in intensities {
            let plan = FaultPlan::random(seed, intensity, horizon, parallel.d)?;
            cands.push((parallel, intensity, plan));
        }
    }
    Ok(cands)
}

fn resilience_point(
    model: &ModelConfig,
    cluster: &ClusterConfig,
    parallel: ParallelConfig,
    intensity: f64,
    plan: FaultPlan,
) -> Result<ResiliencePoint> {
    let cfg = SimConfig::new(*model, parallel, *cluster);
    let result = simulate_faulted(&cfg, &plan)?;
    Ok(ResiliencePoint { parallel, intensity, plan, result })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::BERT_64;

    #[test]
    fn finds_feasible_points_bert_32gpu() {
        let pts =
            grid_search(ScheduleKind::BitPipe, &BERT_64, &GridSpace::bert64(), 32, 128).unwrap();
        assert!(!pts.is_empty(), "no feasible configuration found");
        // Sorted descending.
        for w in pts.windows(2) {
            assert!(w[0].result.throughput >= w[1].result.throughput);
        }
        // Every point uses exactly 32 devices and the full mini-batch.
        for p in &pts {
            assert_eq!(p.parallel.total_devices(), 32);
            assert_eq!(p.parallel.minibatch_size(), 128);
        }
    }

    #[test]
    fn resilience_sweep_is_thread_count_invariant_and_monotone() {
        let layouts = [
            ParallelConfig::new(ScheduleKind::BitPipe, 1, 4, 4, 4),
            ParallelConfig::new(ScheduleKind::Dapple, 1, 4, 4, 4),
        ];
        let intensities = [0.0, 0.5, 1.0];
        let cluster = ClusterConfig::paper_testbed(4);
        let par =
            resilience_sweep(&BERT_64, &cluster, &layouts, &intensities, 7, 4.0).unwrap();
        let ser =
            resilience_sweep_serial(&BERT_64, &cluster, &layouts, &intensities, 7, 4.0).unwrap();
        assert_eq!(par.len(), ser.len());
        assert_eq!(par.len(), layouts.len() * intensities.len());
        for (a, b) in par.iter().zip(&ser) {
            assert_eq!(a.result.iter_time.to_bits(), b.result.iter_time.to_bits());
            assert_eq!(a.plan, b.plan);
        }
        // Intensity 0 expands to an empty trace; higher intensity never
        // speeds a layout up (per-layout slices are intensity-ascending).
        for chunk in par.chunks(intensities.len()) {
            assert!(chunk[0].plan.is_empty());
            for w in chunk.windows(2) {
                assert!(
                    w[1].result.iter_time >= w[0].result.iter_time - 1e-12,
                    "intensity {} faster than {}",
                    w[1].intensity,
                    w[0].intensity
                );
            }
        }
    }

    #[test]
    fn infeasible_layouts_skipped() {
        // Device count with no (w, d) product in the space.
        let pts =
            grid_search(ScheduleKind::BitPipe, &BERT_64, &GridSpace::bert64(), 24, 128).unwrap();
        assert!(pts.is_empty());
    }

    #[test]
    fn best_d_for_bitpipe_is_8_on_32gpus() {
        // Paper Table 7: D=8 is the sweet spot for BitPipe on 32 GPUs.
        let pts =
            grid_search(ScheduleKind::BitPipe, &BERT_64, &GridSpace::bert64(), 32, 128).unwrap();
        let best = &pts[0];
        assert_eq!(best.parallel.d, 8, "best D {} (throughput {})", best.parallel.d, best.result.throughput);
    }

    #[test]
    fn contended_sweep_covers_same_points_never_faster() {
        // Contention re-prices every layout but drops none (memory and
        // feasibility are unchanged), and no layout gets faster.
        let off = grid_search(ScheduleKind::BitPipe, &BERT_64, &GridSpace::bert64(), 16, 64)
            .unwrap();
        let on = grid_search_opts(
            ScheduleKind::BitPipe,
            &BERT_64,
            &GridSpace::bert64(),
            16,
            64,
            true,
        )
        .unwrap();
        assert_eq!(off.len(), on.len());
        assert!(!off.is_empty());
        for a in &on {
            let key = (a.parallel.w, a.parallel.d, a.parallel.b, a.parallel.n);
            let b = off
                .iter()
                .find(|p| (p.parallel.w, p.parallel.d, p.parallel.b, p.parallel.n) == key)
                .expect("point missing from uncontended sweep");
            assert!(
                a.result.throughput <= b.result.throughput + 1e-9,
                "{key:?}: contended {} > uncontended {}",
                a.result.throughput,
                b.result.throughput
            );
        }
    }

    #[test]
    fn dag_sweep_matches_event_serial_bitwise() {
        // The compiled-DAG sweep (default path) against the event-engine
        // serial baseline: same points, same order, bit-identical numbers.
        for (gpus, minibatch) in [(16usize, 64usize), (32, 128)] {
            let dag = grid_search(
                ScheduleKind::BitPipe,
                &BERT_64,
                &GridSpace::bert64(),
                gpus,
                minibatch,
            )
            .unwrap();
            let ser = grid_search_serial(
                ScheduleKind::BitPipe,
                &BERT_64,
                &GridSpace::bert64(),
                gpus,
                minibatch,
            )
            .unwrap();
            assert_eq!(dag.len(), ser.len());
            assert!(!dag.is_empty());
            for (a, b) in dag.iter().zip(&ser) {
                assert_eq!(
                    (a.parallel.w, a.parallel.d, a.parallel.b, a.parallel.n),
                    (b.parallel.w, b.parallel.d, b.parallel.b, b.parallel.n)
                );
                assert_eq!(a.result.throughput.to_bits(), b.result.throughput.to_bits());
                assert_eq!(a.result.iter_time.to_bits(), b.result.iter_time.to_bits());
                assert_eq!(a.result.peak_memory(), b.result.peak_memory());
            }
        }
    }

    #[test]
    fn contended_cached_matches_per_point_rebuild() {
        // The StreamCache fast path must be unobservable in the results:
        // bit-identical to rebuilding and simulating every candidate from
        // scratch on the same (incremental) network.
        let space = GridSpace::bert64();
        let fast =
            grid_search_opts(ScheduleKind::BitPipe, &BERT_64, &space, 16, 64, true).unwrap();
        let cluster = ClusterConfig::paper_testbed(16);
        let mut slow: Vec<GridPoint> = candidates(ScheduleKind::BitPipe, &space, 16, 64)
            .into_iter()
            .filter_map(|p| evaluate(&BERT_64, &cluster, p, true, NetworkImpl::Incremental))
            .collect();
        sort_points(&mut slow);
        assert_eq!(fast.len(), slow.len());
        assert!(!fast.is_empty());
        for (a, b) in fast.iter().zip(&slow) {
            assert_eq!(
                (a.parallel.w, a.parallel.d, a.parallel.b, a.parallel.n),
                (b.parallel.w, b.parallel.d, b.parallel.b, b.parallel.n)
            );
            assert_eq!(a.result.throughput.to_bits(), b.result.throughput.to_bits());
            assert_eq!(a.result.iter_time.to_bits(), b.result.iter_time.to_bits());
            assert_eq!(a.result.peak_memory(), b.result.peak_memory());
        }
    }

    #[test]
    fn stream_cache_reuses_structures_across_sweeps() {
        // Contended twin of shared_cache_reuses_structures_across_sweeps:
        // a repeat sweep must be all cache hits and bit-identical.
        let mut cache = StreamCache::new();
        let space = GridSpace::bert64();
        let first = grid_search_contended_cached(
            ScheduleKind::BitPipe,
            &BERT_64,
            &space,
            16,
            64,
            &mut cache,
        )
        .unwrap();
        let after_first = cache.len();
        assert!(after_first > 0);
        let warm = grid_search_contended_cached(
            ScheduleKind::BitPipe,
            &BERT_64,
            &space,
            16,
            64,
            &mut cache,
        )
        .unwrap();
        assert_eq!(cache.len(), after_first, "repeat sweep must be all cache hits");
        assert_eq!(first.len(), warm.len());
        for (a, b) in first.iter().zip(&warm) {
            assert_eq!(a.result.throughput.to_bits(), b.result.throughput.to_bits());
        }
        // A different GPU count shares some (d, n) structures but not all.
        let _ = grid_search_contended_cached(
            ScheduleKind::BitPipe,
            &BERT_64,
            &space,
            32,
            128,
            &mut cache,
        )
        .unwrap();
        assert!(cache.len() > after_first);
    }

    #[test]
    fn degraded_sweep_neutral_identity_and_stragglers_only_slow() {
        // Neutral overrides through grid_search_on_cluster are bit-identical
        // to the plain sweep (sharing its cache), and a real straggler can
        // only lower a layout's throughput, never raise it.
        let space = GridSpace::bert64();
        let mut cache = DagCache::new();
        let base =
            grid_search_cached(ScheduleKind::BitPipe, &BERT_64, &space, 16, 64, &mut cache)
                .unwrap();
        assert!(!base.is_empty());
        let neutral = ClusterConfig::paper_testbed(16).with_straggler(0, 1.0).unwrap();
        let same = grid_search_on_cluster(
            ScheduleKind::BitPipe,
            &BERT_64,
            &space,
            64,
            &neutral,
            &mut cache,
        )
        .unwrap();
        assert_eq!(base.len(), same.len());
        for (a, b) in base.iter().zip(&same) {
            assert_eq!(a.result.throughput.to_bits(), b.result.throughput.to_bits());
            assert_eq!(a.result.iter_time.to_bits(), b.result.iter_time.to_bits());
        }
        let slow = ClusterConfig::paper_testbed(16).with_straggler(0, 1.5).unwrap();
        let degraded = grid_search_on_cluster(
            ScheduleKind::BitPipe,
            &BERT_64,
            &space,
            64,
            &slow,
            &mut cache,
        )
        .unwrap();
        assert_eq!(base.len(), degraded.len(), "stragglers change speed, not feasibility");
        for a in &degraded {
            let key = (a.parallel.w, a.parallel.d, a.parallel.b, a.parallel.n);
            let b = base
                .iter()
                .find(|p| (p.parallel.w, p.parallel.d, p.parallel.b, p.parallel.n) == key)
                .expect("point missing from healthy sweep");
            assert!(
                a.result.throughput <= b.result.throughput + 1e-9,
                "{key:?}: degraded {} > healthy {}",
                a.result.throughput,
                b.result.throughput
            );
        }
    }

    #[test]
    fn shared_cache_reuses_structures_across_sweeps() {
        // Two sweeps over overlapping structures: the second must add no
        // BitPipe (d, n) entries the first already compiled, and results
        // must be identical to a cold sweep.
        let mut cache = DagCache::new();
        let space = GridSpace::bert64();
        let first =
            grid_search_cached(ScheduleKind::BitPipe, &BERT_64, &space, 16, 64, &mut cache)
                .unwrap();
        let after_first = cache.len();
        assert!(after_first > 0);
        let warm =
            grid_search_cached(ScheduleKind::BitPipe, &BERT_64, &space, 16, 64, &mut cache)
                .unwrap();
        assert_eq!(cache.len(), after_first, "repeat sweep must be all cache hits");
        assert_eq!(first.len(), warm.len());
        for (a, b) in first.iter().zip(&warm) {
            assert_eq!(a.result.throughput.to_bits(), b.result.throughput.to_bits());
        }
        // A different GPU count shares some (d, n) structures but not all.
        let _ = grid_search_cached(ScheduleKind::BitPipe, &BERT_64, &space, 32, 128, &mut cache)
            .unwrap();
        assert!(cache.len() > after_first);
    }

    #[test]
    fn batched_multi_sweep_matches_scalar_and_serial_bitwise() {
        // The determinism contract: lane-grouped batched sweeps must be
        // unobservable in the results — identical points, full order
        // (tie-breaks included, since sort_points is a stable sort over
        // canonical candidate order), and exact f64 bits vs both the
        // scalar warm path (threaded precompile + per-point re-cost) and
        // the fully serial event-engine oracle. The duplicated sweep
        // forces same-B lane members; the mixed GPU counts force lanes
        // whose members differ in (W, cluster) and in B.
        let space = GridSpace::bert64();
        let sweeps = [(16usize, 64usize), (32, 128), (32, 128)];
        let mut bcache = DagCache::new();
        let batched =
            grid_search_batched(ScheduleKind::BitPipe, &BERT_64, &space, &sweeps, &mut bcache)
                .unwrap();
        assert_eq!(batched.len(), sweeps.len());
        let mut scache = DagCache::new();
        for (res, &(gpus, mb)) in batched.iter().zip(&sweeps) {
            let scalar =
                grid_search_cached(ScheduleKind::BitPipe, &BERT_64, &space, gpus, mb, &mut scache)
                    .unwrap();
            let serial =
                grid_search_serial(ScheduleKind::BitPipe, &BERT_64, &space, gpus, mb).unwrap();
            assert!(!res.is_empty());
            assert_eq!(res.len(), scalar.len());
            assert_eq!(res.len(), serial.len());
            for ((a, b), c) in res.iter().zip(&scalar).zip(&serial) {
                let key = |p: &GridPoint| {
                    (p.parallel.w, p.parallel.d, p.parallel.b, p.parallel.n)
                };
                assert_eq!(key(a), key(b), "argmin/order diverged from scalar warm path");
                assert_eq!(key(a), key(c), "argmin/order diverged from event serial");
                assert_eq!(a.result.throughput.to_bits(), b.result.throughput.to_bits());
                assert_eq!(a.result.throughput.to_bits(), c.result.throughput.to_bits());
                assert_eq!(a.result.iter_time.to_bits(), c.result.iter_time.to_bits());
                assert_eq!(a.result.peak_memory(), c.result.peak_memory());
            }
        }
        // Lanes really formed: structures shared across the three sweeps
        // were compiled once, not once per sweep.
        assert_eq!(bcache.len(), scache.len());
    }

    #[test]
    fn batched_sweep_skips_infeasible_sweeps() {
        // An infeasible sweep (no (w, d) product hits 24 devices) yields
        // an empty slot without disturbing its neighbours.
        let space = GridSpace::bert64();
        let sweeps = [(24usize, 128usize), (16, 64)];
        let mut cache = DagCache::new();
        let batched =
            grid_search_batched(ScheduleKind::BitPipe, &BERT_64, &space, &sweeps, &mut cache)
                .unwrap();
        assert!(batched[0].is_empty());
        assert!(!batched[1].is_empty());
        let solo = grid_search(ScheduleKind::BitPipe, &BERT_64, &space, 16, 64).unwrap();
        assert_eq!(batched[1].len(), solo.len());
        for (a, b) in batched[1].iter().zip(&solo) {
            assert_eq!(a.result.throughput.to_bits(), b.result.throughput.to_bits());
        }
    }
}
