//! Event-driven executor: runs `Schedule::device_ops` under a [`CostModel`]
//! in virtual time.
//!
//! Semantics (matching the real runtime in `crate::train`):
//!
//! * compute ops occupy the device for their full duration;
//! * sends are asynchronous (NCCL-style): the sender pays a negligible
//!   launch cost, the message arrives `xfer_time` later;
//! * receives block until the matching message arrived;
//! * `AllReduceStart` is asynchronous; the collective begins once every
//!   group member has launched it and completes `allreduce_time` later;
//!   `AllReduceWait` blocks until completion — eager launches therefore
//!   hide the collective inside pipeline bubbles (paper Fig 5);
//! * local copies and optimizer steps occupy the device briefly.

use super::cost::CostModel;
use crate::schedule::{Instr, Schedule, StageId};
use std::collections::HashMap;

/// Per-device accounting from a simulated iteration.
#[derive(Debug, Clone, Default)]
pub struct DeviceTrace {
    /// Device-local completion time of its last instruction.
    pub finish: f64,
    /// Seconds spent in forward/backward compute.
    pub compute_busy: f64,
    /// Seconds blocked waiting for P2P messages.
    pub recv_blocked: f64,
    /// Seconds blocked in `AllReduceWait`.
    pub allreduce_blocked: f64,
    /// P2P messages sent.
    pub sends: usize,
    /// Local copies performed.
    pub local_copies: usize,
}

/// Whole-iteration trace.
#[derive(Debug, Clone)]
pub struct SimTrace {
    pub devices: Vec<DeviceTrace>,
    /// Iteration makespan, seconds.
    pub makespan: f64,
}

/// Simulation failure: the instruction streams deadlocked (a recv whose
/// send never happens, or an all-reduce a member never launches).
#[derive(Debug, thiserror::Error)]
#[error("simulation deadlock at {stuck:?}")]
pub struct SimError {
    /// (device, instruction index, instruction) for every stuck device.
    pub stuck: Vec<(usize, usize, String)>,
}

/// Message key: (from, to, is_grad, pipe, producer_stage, mb).
type MsgKey = (usize, usize, bool, usize, usize, usize);

/// Run the instruction streams to completion in virtual time.
pub fn simulate_schedule(s: &Schedule, costs: &CostModel) -> Result<SimTrace, SimError> {
    let d = s.n_devices();
    let ops = &s.device_ops;
    assert!(!ops.is_empty(), "schedule has no device_ops; run comm_pass first");

    let mut cursor = vec![0usize; d];
    let mut now = vec![0.0f64; d];
    let mut trace = vec![DeviceTrace::default(); d];

    // In-flight messages: key -> arrival time.
    let mut msgs: HashMap<MsgKey, f64> = HashMap::new();
    // All-reduce state per stage: device -> launch time.
    let mut ar_started: HashMap<StageId, HashMap<usize, f64>> = HashMap::new();
    // Completed all-reduces: stage -> completion time.
    let mut ar_done: HashMap<StageId, f64> = HashMap::new();
    // Per-device collective engine (NCCL comm stream): concurrent
    // collectives sharing a device serialize on it. This is what makes
    // eager launches (paper Fig 5b) pay off — early collectives drain the
    // engine while compute continues; lazy launches queue at the end.
    let mut comm_free = vec![0.0f64; d];

    let total: usize = ops.iter().map(|o| o.len()).sum();
    let mut done_ops = 0usize;

    // Launch overhead for async ops (kernel/NCCL enqueue).
    const LAUNCH: f64 = 1.0e-6;

    while done_ops < total {
        let mut progressed = false;
        for dev in 0..d {
            while cursor[dev] < ops[dev].len() {
                let instr = &ops[dev][cursor[dev]];
                let mut advance = true;
                match *instr {
                    Instr::Forward { .. } => {
                        now[dev] += costs.chunk_fwd;
                        trace[dev].compute_busy += costs.chunk_fwd;
                    }
                    Instr::Backward { .. } => {
                        now[dev] += costs.chunk_bwd;
                        trace[dev].compute_busy += costs.chunk_bwd;
                    }
                    Instr::SendAct { to, pipe, stage, mb } => {
                        now[dev] += LAUNCH;
                        let arrival = now[dev] + costs.p2p_time(dev, to);
                        msgs.insert((dev, to, false, pipe, stage, mb), arrival);
                        trace[dev].sends += 1;
                    }
                    Instr::SendGrad { to, pipe, stage, mb } => {
                        now[dev] += LAUNCH;
                        let arrival = now[dev] + costs.p2p_time(dev, to);
                        msgs.insert((dev, to, true, pipe, stage, mb), arrival);
                        trace[dev].sends += 1;
                    }
                    Instr::RecvAct { from, pipe, stage, mb } => {
                        // Producer tagged with stage-1.
                        let key = (from, dev, false, pipe, stage - 1, mb);
                        match msgs.get(&key) {
                            Some(&arrival) => {
                                if arrival > now[dev] {
                                    trace[dev].recv_blocked += arrival - now[dev];
                                    now[dev] = arrival;
                                }
                                msgs.remove(&key);
                            }
                            None => advance = false,
                        }
                    }
                    Instr::RecvGrad { from, pipe, stage, mb } => {
                        let key = (from, dev, true, pipe, stage + 1, mb);
                        match msgs.get(&key) {
                            Some(&arrival) => {
                                if arrival > now[dev] {
                                    trace[dev].recv_blocked += arrival - now[dev];
                                    now[dev] = arrival;
                                }
                                msgs.remove(&key);
                            }
                            None => advance = false,
                        }
                    }
                    Instr::LocalCopyAct { .. } | Instr::LocalCopyGrad { .. } => {
                        now[dev] += costs.local_copy_time();
                        trace[dev].local_copies += 1;
                    }
                    Instr::AllReduceStart { stage } => {
                        now[dev] += LAUNCH;
                        let entry = ar_started.entry(stage).or_default();
                        entry.insert(dev, now[dev]);
                        let group = s.placement.allreduce_group(stage);
                        if group.iter().all(|g| entry.contains_key(g)) {
                            // Ready once every member launched; starts when
                            // every member's comm engine is free.
                            let launched =
                                group.iter().map(|g| entry[g]).fold(0.0f64, f64::max);
                            let engine =
                                group.iter().map(|g| comm_free[*g]).fold(0.0f64, f64::max);
                            let done =
                                launched.max(engine) + costs.allreduce_time(stage);
                            for &g in &group {
                                comm_free[g] = done;
                            }
                            ar_done.insert(stage, done);
                        }
                    }
                    Instr::AllReduceWait { stage } => match ar_done.get(&stage) {
                        Some(&t) => {
                            if t > now[dev] {
                                trace[dev].allreduce_blocked += t - now[dev];
                                now[dev] = t;
                            }
                        }
                        None => advance = false,
                    },
                    Instr::OptimStep { .. } => {
                        now[dev] += costs.optim_time();
                    }
                }
                if !advance {
                    break;
                }
                cursor[dev] += 1;
                done_ops += 1;
                progressed = true;
            }
        }
        if !progressed {
            let stuck = (0..d)
                .filter(|&dv| cursor[dv] < ops[dv].len())
                .map(|dv| (dv, cursor[dv], ops[dv][cursor[dv]].to_string()))
                .collect();
            return Err(SimError { stuck });
        }
    }

    for dev in 0..d {
        trace[dev].finish = now[dev];
    }
    let makespan = now.iter().cloned().fold(0.0, f64::max);
    Ok(SimTrace { devices: trace, makespan })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ClusterConfig, ParallelConfig, BERT_64};
    use crate::schedule::{build, ScheduleConfig, ScheduleKind, SyncPolicy};
    use crate::sim::CostModel;

    fn costs(kind: ScheduleKind, d: usize, n: usize) -> CostModel {
        let p = ParallelConfig::new(kind, 1, d, 4, n);
        CostModel::new(&BERT_64, &p, &ClusterConfig::paper_testbed(d))
    }

    fn run(kind: ScheduleKind, d: usize, n: usize) -> SimTrace {
        let s = build(&ScheduleConfig::new(kind, d, n)).unwrap();
        simulate_schedule(&s, &costs(kind, d, n)).unwrap()
    }

    #[test]
    fn all_kinds_simulate_clean() {
        for kind in ScheduleKind::ALL {
            for n in [4usize, 8] {
                let t = run(kind, 4, n);
                assert!(t.makespan > 0.0, "{kind} N={n}");
            }
        }
    }

    #[test]
    fn makespan_at_least_critical_path() {
        // Lower bound: every device must run its own compute serially.
        let kind = ScheduleKind::BitPipe;
        let c = costs(kind, 8, 8);
        let t = run(kind, 8, 8);
        for dev in &t.devices {
            assert!(t.makespan + 1e-12 >= dev.compute_busy);
        }
        // Ideal compute per device: N * v chunks fwd+bwd.
        let ideal = 8.0 * 2.0 * (c.chunk_fwd + c.chunk_bwd);
        assert!(t.makespan >= ideal, "{} < {ideal}", t.makespan);
    }

    #[test]
    fn eager_hides_allreduce_better_than_lazy() {
        // Table 5 w/o E: lazy sync exposes the collectives on the critical
        // path; eager hides them inside bubbles/compute. The effect is
        // large when the collective is expensive (data parallelism over
        // IB); on a single NVLink node the paper itself measures only ~1%.
        let kind = ScheduleKind::BitPipe;
        let eager = build(&ScheduleConfig::new(kind, 8, 8).with_sync(SyncPolicy::Eager)).unwrap();
        let lazy = build(&ScheduleConfig::new(kind, 8, 8).with_sync(SyncPolicy::Lazy)).unwrap();

        // Multi-node: W=4 data parallelism, allreduce group of 8 on IB.
        let p = ParallelConfig::new(kind, 4, 8, 4, 8);
        let mut cluster = ClusterConfig::paper_testbed(32);
        cluster.mapping = crate::config::MappingPolicy::PipesTogether; // allreduce on IB
        let c = CostModel::new(&BERT_64, &p, &cluster);
        let te = simulate_schedule(&eager, &c).unwrap();
        let tl = simulate_schedule(&lazy, &c).unwrap();
        assert!(
            te.makespan < tl.makespan,
            "multi-node: eager {} not faster than lazy {}",
            te.makespan,
            tl.makespan
        );

        // Single node: eager must never be slower (beyond launch noise).
        let c1 = costs(kind, 8, 8);
        let te1 = simulate_schedule(&eager, &c1).unwrap();
        let tl1 = simulate_schedule(&lazy, &c1).unwrap();
        assert!(
            te1.makespan <= tl1.makespan + 1e-4,
            "single-node: eager {} slower than lazy {}",
            te1.makespan,
            tl1.makespan
        );
    }

    #[test]
    fn v_shape_spends_less_time_on_p2p_than_looping() {
        let tv = run(ScheduleKind::VShaped, 4, 8);
        let tl = run(ScheduleKind::Interleaved, 4, 8);
        let sends_v: usize = tv.devices.iter().map(|d| d.sends).sum();
        let sends_l: usize = tl.devices.iter().map(|d| d.sends).sum();
        assert!(sends_v < sends_l);
        let copies_v: usize = tv.devices.iter().map(|d| d.local_copies).sum();
        assert!(copies_v > 0);
    }

    #[test]
    fn deadlock_reported_not_hung() {
        // Remove one send: the matching recv must deadlock, reported as Err.
        let kind = ScheduleKind::Dapple;
        let mut s = build(&ScheduleConfig::new(kind, 4, 4)).unwrap();
        let idx = s.device_ops[0]
            .iter()
            .position(|i| matches!(i, Instr::SendAct { .. }))
            .unwrap();
        s.device_ops[0].remove(idx);
        let e = simulate_schedule(&s, &costs(kind, 4, 4)).unwrap_err();
        assert!(!e.stuck.is_empty());
    }
}
