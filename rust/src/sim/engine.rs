//! Event-queue executor: runs `Schedule::device_ops` under a [`CostModel`]
//! in virtual time.
//!
//! # Execution model
//!
//! The engine is a discrete-event simulator. Each device is a sequential
//! executor with its own virtual clock; a [`BinaryHeap`] of ready events
//! decides which device runs next. A popped device executes instructions
//! and advances its clock until it either finishes its stream or *blocks*
//! (a receive whose message has not been sent yet, or an `AllReduceWait`
//! whose collective has not completed). Blocked devices leave the heap
//! entirely; the action that unblocks them (the matching send, the last
//! group member's `AllReduceStart`) pushes a wake event at the virtual
//! time the dependency resolves. When the heap drains with instructions
//! outstanding, the streams have deadlocked and [`SimError`] reports every
//! stuck device. The heap is ordered by `(time, device)` — a total,
//! deterministic tie-break — so repeated runs produce bit-identical traces.
//!
//! # Instruction semantics (matching the real runtime in `crate::train`)
//!
//! * compute ops occupy the device for their full duration;
//! * sends are asynchronous (NCCL-style): the sender pays a negligible
//!   launch cost, the message arrives `xfer_time` later. In-flight
//!   messages with the same tag queue **FIFO** (a `VecDeque` per
//!   [`MsgKey`]), so duplicate tags — e.g. the same (pipe, stage, mb)
//!   re-sent on a later iteration — pair with receives in send order
//!   instead of silently clobbering each other;
//! * receives block until the matching message arrived. A malformed
//!   entry-stage `RecvAct` (stage 0 has no producer) parks the device and
//!   is reported as a deadlock — never an arithmetic panic;
//! * `AllReduceStart` is asynchronous; the collective begins once every
//!   group member has launched it and completes `allreduce_time` later;
//!   `AllReduceWait` blocks until completion — eager launches therefore
//!   hide the collective inside pipeline bubbles (paper Fig 5). Collective
//!   state is keyed by **(stage, round)**, where each device counts its own
//!   starts/waits per stage, so multiple simulated iterations reuse stages
//!   without state collisions;
//! * concurrent collectives sharing a device serialize on its comm engine
//!   (`comm_free`); each collective is priced when its last member's start
//!   executes, so back-to-back launches queue behind one another;
//! * local copies and optimizer steps occupy the device briefly.
//!
//! # Multi-iteration runs
//!
//! [`simulate_schedule_iters`] executes the same per-device streams
//! back-to-back `iters` times with no global barrier: a device may begin
//! iteration `k+1` while others still finish `k`, exactly like the
//! threaded runtime. [`MultiIterTrace::iter_times`] yields per-iteration
//! wall times for warmup/steady-state analysis (see
//! [`crate::sim::simulate_iters`]).
//!
//! # Link contention (flow-level fair share)
//!
//! With contention on ([`simulate_schedule_with`] /
//! [`simulate_schedule_iters_with`], or the mode-explicit
//! [`simulate_schedule_contended`] variants), the network is a set of
//! shared *resources* ([`crate::config::ResourceId`]) instead of infinite
//! pipes: per-device-pair NVLink paths inside a node, and — under the
//! default [`crate::config::IbModel::NodeNic`] — one egress and one
//! ingress NIC per node, shared across *all* of that node's peers (the
//! legacy per-node-pair pipes survive behind `IbModel::NodePair`). Every
//! P2P message becomes a *flow* occupying the resource(s) of its pipe; an
//! inter-node flow occupies two (source egress NIC + destination ingress
//! NIC). A flow progresses at `1/k` of full rate, where `k` is the number
//! of flows on its most-loaded resource — the standard bottleneck-resource
//! fair-share model — and every flow start/finish *re-projects* the
//! completion times of the flows it shares a resource with. Re-projection
//! is implemented with versioned completion events: stale events
//! (superseded by a later re-projection) pop and are discarded; this is
//! what keeps multi-hop (two-resource) flows correct, since either
//! endpoint's churn can re-time them. A flow's work is its solo transfer
//! time (latency + bytes/bandwidth), of which only the bytes/bandwidth
//! part is fair-shared: the wire latency is a fixed term the flow pays
//! once at wall rate regardless of sharers (`Xfer::lat_left`). A flow
//! that never shares any of its resources completes at exactly the
//! fixed-duration engine's arrival time, bit for bit, and a shared flow
//! only ever finishes later —
//! contended makespans are therefore bounded below by uncontended ones
//! for the same schedule.
//!
//! ## Incremental settlement and the flat arena
//!
//! Settlement — charging each in-flight flow the wall time elapsed since
//! the last network event, divided by its share — comes in two
//! implementations ([`NetworkImpl`]):
//!
//! * [`NetworkImpl::Incremental`] (the default): every flow carries its
//!   own settle point and the share in effect since then. A flow start or
//!   finish settles and re-projects **only the flows sharing a mutated
//!   resource** — exactly the set whose share can have changed, since a
//!   flow's share is the max occupancy over its own resources and
//!   occupancy only moves when a flow enters or leaves one of them. Work
//!   per network event is O(sharers of the mutated resources), not
//!   O(all in-flight flows).
//! * [`NetworkImpl::Global`] — the PR-4 strategy: every network event
//!   advances every in-flight flow from one shared settle point. Kept as
//!   the differential oracle (`rust/tests/network_equiv.rs` pins
//!   incremental-vs-global agreement at <= 1e-9 relative on a dense
//!   schedule grid; the two differ only in floating-point *segment
//!   fusion* — incremental subtracts one fused `dt/k` where global
//!   subtracts the same interval in per-event slices, so results agree to
//!   rounding, not bitwise. Solo flows and solo rings are projected once
//!   at insertion in both strategies and stay **bit-identical** to the
//!   fixed-duration engine either way).
//!
//! Network state lives in a flat arena: [`crate::config::ResourceId`]s are
//! enumerated into dense indices (`ClusterConfig::resource_index`) at cost
//! -model build time, per-resource active-flow lists live in a
//! `Vec<Vec<usize>>` indexed by them, and message queues/waiters are
//! indexed by per-schedule message *slots* ([`StreamTables`] interns each
//! distinct message key once, outside the event loop), so the inner loop
//! performs no hashing at all. Scratch buffers for the affected-flow sets
//! are pooled on the network and reused across events.
//!
//! Under [`Contention::Full`] (what `SimConfig::contention` selects),
//! all-reduce collectives are lowered onto the wire too: when the last
//! group member launches a (stage, round) collective, its precomputed
//! ring path ([`CostModel::ring_hops`]) becomes one flow per directed
//! hop, each carrying the hop's whole-collective traffic
//! (`2(g-1) x bytes/g` plus latency per step). The collective completes
//! when its slowest hop drains — on an idle network exactly the scalar
//! `allreduce_time`, bit for bit — and contends for NVLink paths and NICs
//! with concurrent P2P flows and with other rings. Collectives sharing a
//! member device still serialize on its comm engine: per-device FIFO
//! queues launch a collective's flows only once it heads every member's
//! queue, the flow-world equivalent of the analytic `comm_free` chain.
//! [`Contention::P2pOnly`] keeps the PR-2 behaviour (collectives priced
//! by the scalar formula, serialized on `comm_free`) and exists as the
//! differential midpoint the test battery pins:
//! `uncontended <= p2p-only <= full` on every schedule.
//!
//! Two deliberate modeling choices, documented because they differ from a
//! textbook flow-level model:
//!
//! * The simulator executes one of the W data-parallel pipeline groups;
//!   the other groups' identical, synchronized transfers are priced by
//!   scaling each flow's work by `P2pEdge::dp_copies` (the number of
//!   group copies landing on the same pipe) — exact for lock-step
//!   replicas, which identical instruction streams are. (Collective ring
//!   flows need no such scaling: their rings already span all W
//!   replicas' physical devices.)
//! * A flow's `remaining` is still its full solo time (latency +
//!   bytes/bandwidth — for rings, the whole-collective scalar), but the
//!   wire-latency part is tracked separately (`Xfer::lat_left`) and
//!   drains at wall rate however many flows share the pipe; only the
//!   bytes part fair-shares. k sharers of one pipe therefore finish a
//!   transfer of latency `l` and byte-time `w` at `l + k x w`, not
//!   `k x (l + w)`: the historical *k x latency caveat* — each sharer
//!   paying ~k x latency — is **fixed**, anchored by the pinned k-sharer
//!   case in `rust/tests/network_equiv.rs`. Solo flows take the
//!   unsplit arithmetic path (share 1 keeps the original expressions
//!   verbatim), preserving the solo-flow/solo-ring bit-equality
//!   guarantees. Ring flows carry a per-hop latency budget of their
//!   2(g-1) per-step latencies, clamped to the hop's work
//!   ([`super::cost::RingHop::lat`]).
//!
//! Transfer starts are enqueued as heap events at their virtual send time
//! rather than applied immediately: a device may locally run far ahead of
//! its peers, and bandwidth sharing is only correct if the network
//! observes flow starts/finishes in global time order. Sends stay
//! asynchronous for the *sender* either way; collective flows enter at
//! the latest member launch time (or later, behind a queued predecessor).
//!
//! # Fault injection (time-varying degradation)
//!
//! [`simulate_schedule_iters_faulted`] replays a
//! [`crate::config::FaultPlan`] — an explicit, time-ordered trace of
//! `LinkDegrade` / `DeviceSlow` windows and `DeviceStall` events —
//! against the streams. Every window boundary is pushed onto the event
//! heap up front as a `Fault` event (rank 0: at equal times a boundary
//! applies before any transfer or compute observes it, and boundaries
//! apply in plan order). The semantics, pinned by `rust/tests/faults.rs`
//! and mirrored 1:1 in the pymirror:
//!
//! * **Link windows** scale a set of dense resources (resolved through
//!   [`CostModel::p2p_edge`], so class selectors like `ib` catch exactly
//!   the wires flows actually ride). At a boundary the affected rates are
//!   recomputed *from scratch* as the product of all active windows (never
//!   multiplied back out — fp-deterministic), then only the flows
//!   occupying an affected resource are settled at their old rate and
//!   re-projected at the new one, riding the PR-5 incremental-settlement
//!   and versioned re-projection machinery. A flow on a degraded resource
//!   progresses at `rate/k` — its effective share becomes `k / rate`, so a
//!   *solo* flow on a degraded link slows down too (latency still drains
//!   at wall rate; only byte-work is scaled). Fixed-duration transfers
//!   ([`Contention::Off`]) are priced at their dispatch-time rate — a
//!   window opening mid-flight does not re-time them (documented policy).
//!   Analytic collectives (`Off`/`P2pOnly`) are *not* fault-scaled;
//!   under [`Contention::Full`] ring flows ride the degraded wires
//!   naturally.
//! * **Compute windows** (`DeviceSlow`) multiply a device's op costs at
//!   dispatch: an op started before the window at full speed finishes at
//!   full speed; the first op dispatched inside the window pays the
//!   multiplier (the applies-at-next-dispatch policy — ops are atomic).
//! * **Stalls** pin a device clock forward: `now[dev] =
//!   max(now[dev], t + dur)` — a device idle past the stall is
//!   unaffected, a busy one loses exactly the overlap.
//!
//! An **empty plan attaches no fault state at all**: the engine's healthy
//! arithmetic is the pre-fault expressions verbatim, so empty-plan runs
//! are bit-identical to [`simulate_schedule_iters_network`] on every
//! mode and strategy. Plans only ever slow things down (degrade-only by
//! [`FaultPlan::validate`]), and a fixed plan is bitwise-deterministic
//! across repeated runs and thread counts — the trace is expanded before
//! the run and the event order is total.
//!
//! The pre-event-queue spin-loop executor survives as
//! `simulate_schedule_reference`, but only for differential testing: it
//! is compiled under `cfg(any(test, feature = "reference-sim"))` and is
//! no longer part of the release library surface. The property suite
//! (`rust/tests/engine_equiv.rs`, which enables the feature through the
//! dev-dependency self-reference) asserts makespan equivalence across
//! every schedule family.

use super::cost::CostModel;
use crate::config::{FaultEvent, FaultPlan, FaultTarget, NO_RESOURCE};
use crate::schedule::{Instr, Schedule, StageId};
use std::cmp::Ordering;
use std::collections::{BinaryHeap, HashMap, VecDeque};
use std::fmt;

/// Which settlement strategy the shared-resource network uses. The two
/// agree to floating-point rounding (<= 1e-9 relative, pinned by
/// `rust/tests/network_equiv.rs`) and are bit-identical on flows that
/// never share a resource; see the module docs.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum NetworkImpl {
    /// Per-resource incremental settlement (the default): a flow start or
    /// finish touches only the flows sharing a mutated resource.
    #[default]
    Incremental,
    /// PR-4 global settlement: every network event advances every
    /// in-flight flow. Kept as the differential oracle.
    Global,
}

/// Which traffic contends for shared link bandwidth in a simulated run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Contention {
    /// Fixed-duration transfers (the bit-stable legacy behaviour, and the
    /// `SimConfig::contention: false` default).
    Off,
    /// Only P2P transfers contend; collectives keep the scalar ring
    /// pricing serialized on `comm_free` (the PR-2 model). Kept as the
    /// differential midpoint the test battery pins between `Off` and
    /// `Full`.
    P2pOnly,
    /// P2P transfers *and* all-reduce ring flows contend (what
    /// `SimConfig::contention: true` selects).
    Full,
}

/// Per-device accounting from a simulated run.
#[derive(Debug, Clone, Default)]
pub struct DeviceTrace {
    /// Device-local completion time of its last instruction.
    pub finish: f64,
    /// Seconds spent in forward/backward compute.
    pub compute_busy: f64,
    /// Seconds blocked waiting for P2P messages.
    pub recv_blocked: f64,
    /// Seconds blocked in `AllReduceWait`.
    pub allreduce_blocked: f64,
    /// P2P messages sent.
    pub sends: usize,
    /// Local copies performed.
    pub local_copies: usize,
}

/// Whole-iteration trace.
#[derive(Debug, Clone)]
pub struct SimTrace {
    pub devices: Vec<DeviceTrace>,
    /// Iteration makespan, seconds.
    pub makespan: f64,
}

/// Multi-iteration trace from [`simulate_schedule_iters`].
#[derive(Debug, Clone)]
pub struct MultiIterTrace {
    /// Aggregate per-device accounting over the whole run.
    pub devices: Vec<DeviceTrace>,
    /// Completion time of each iteration: max across devices of the finish
    /// time of that iteration's last instruction.
    pub iter_finish: Vec<f64>,
    /// Total virtual time of the run (`iter_finish.last()`).
    pub makespan: f64,
}

impl MultiIterTrace {
    /// Per-iteration wall times (differences of [`Self::iter_finish`]).
    /// Iterations overlap at the boundary — a device may enter iteration
    /// `k+1` while a peer still drains `k` — so entry `k` measures the
    /// *completion-to-completion* interval, the paper's per-iteration time.
    pub fn iter_times(&self) -> Vec<f64> {
        let mut out = Vec::with_capacity(self.iter_finish.len());
        let mut prev = 0.0;
        for &t in &self.iter_finish {
            out.push(t - prev);
            prev = t;
        }
        out
    }
}

/// Simulation failure: the instruction streams deadlocked (a recv whose
/// send never happens, an all-reduce a member never launches, or a
/// malformed entry-stage receive).
#[derive(Debug)]
pub struct SimError {
    /// (device, instruction index within the iteration, instruction) for
    /// every stuck device.
    pub stuck: Vec<(usize, usize, String)>,
}

impl fmt::Display for SimError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "simulation deadlock at {:?}", self.stuck)
    }
}

impl std::error::Error for SimError {}

/// Message key: (from, to, is_grad, pipe, producer_stage, mb).
type MsgKey = (usize, usize, bool, usize, usize, usize);

/// Launch overhead for async ops (kernel/NCCL enqueue). Shared with the
/// compiled-DAG backend (`super::dag`), whose bit-equivalence guarantee
/// depends on pricing launches identically.
pub(crate) const LAUNCH: f64 = 1.0e-6;

/// "No message slot": non-message instructions, and the malformed
/// entry-stage `RecvAct` (stage 0 has no producer, so its key can never
/// match — the device parks and the run reports a deadlock).
pub(crate) const NO_SLOT: u32 = u32::MAX;

/// Structure-only lowering of a schedule's instruction streams: every
/// distinct message key interned into a dense *slot* so the engine's
/// message queues and waiter table are flat vectors instead of
/// `MsgKey`-keyed hash maps. Depends only on the streams — never on the
/// cost model — so the contended sweep's `StreamCache` builds it once per
/// schedule structure and re-uses it across every (W, B, cluster) grid
/// point.
#[derive(Debug, Clone)]
pub(crate) struct StreamTables {
    /// Per (device, instruction index): the message slot a send delivers
    /// to / a receive consumes from ([`NO_SLOT`] otherwise).
    slots: Vec<Vec<u32>>,
    /// Number of distinct message keys across the streams.
    n_slots: usize,
}

impl StreamTables {
    /// Intern every message key of `s.device_ops` (one hash per
    /// instruction, outside the event loop — the only hashing left on the
    /// simulation path).
    pub(crate) fn build(s: &Schedule) -> StreamTables {
        let mut intern: HashMap<MsgKey, u32> = HashMap::new();
        let mut slots = Vec::with_capacity(s.device_ops.len());
        for (dev, ops) in s.device_ops.iter().enumerate() {
            slots.push(
                ops.iter()
                    .map(|op| {
                        let key = match *op {
                            Instr::SendAct { to, pipe, stage, mb } => {
                                Some((dev, to, false, pipe, stage, mb))
                            }
                            Instr::SendGrad { to, pipe, stage, mb } => {
                                Some((dev, to, true, pipe, stage, mb))
                            }
                            // The producer tagged the message with
                            // stage-1; a stage-0 RecvAct has no producer.
                            Instr::RecvAct { from, pipe, stage, mb } => stage
                                .checked_sub(1)
                                .map(|producer| (from, dev, false, pipe, producer, mb)),
                            Instr::RecvGrad { from, pipe, stage, mb } => {
                                Some((from, dev, true, pipe, stage + 1, mb))
                            }
                            _ => None,
                        };
                        match key {
                            Some(k) => {
                                let next = intern.len() as u32;
                                *intern.entry(k).or_insert(next)
                            }
                            None => NO_SLOT,
                        }
                    })
                    .collect(),
            );
        }
        StreamTables { slots, n_slots: intern.len() }
    }
}

/// What a heap event does when it fires.
#[derive(Debug, Clone, Copy)]
enum EvKind {
    /// A fault-plan boundary (a degradation window opening or closing, or
    /// a stall landing). Carries the index into the engine's sorted
    /// boundary schedule; only pushed when a non-empty [`FaultPlan`] is
    /// attached, so fault-free heaps never contain one.
    Fault { idx: usize },
    /// A transfer's projected completion (contended mode). Carries the
    /// projection version; stale events are discarded on pop.
    XferDone { id: usize, version: u64 },
    /// A transfer enters its link (contended mode). Deferred to the heap
    /// so the network sees flow starts in global time order even when the
    /// sending device has locally run ahead.
    XferStart { id: usize },
    /// A device ready to run.
    Dev(usize),
}

impl EvKind {
    /// Total tie-break order at equal times: fault boundaries first (the
    /// network mutates before anything else observes the instant), then
    /// completions (messages become visible before devices resume), then
    /// flow starts, then devices in ascending id — the same device order
    /// the pre-contention engine used. Without fault events the *relative*
    /// order of the remaining kinds is unchanged, which is what keeps
    /// empty-plan runs bit-identical to the pre-fault engine.
    fn rank(&self) -> (u8, usize, u64) {
        match *self {
            EvKind::Fault { idx } => (0, idx, 0),
            EvKind::XferDone { id, version } => (1, id, version),
            EvKind::XferStart { id } => (2, id, 0),
            EvKind::Dev(dev) => (3, dev, 0),
        }
    }
}

/// A scheduled simulator event. Min-heap order by `(time, kind rank)` — a
/// total, deterministic tie-break that makes traces reproducible (virtual
/// times are always finite, so the `partial_cmp` below is total in
/// practice).
#[derive(Debug, Clone, Copy)]
struct Event {
    time: f64,
    kind: EvKind,
}

impl PartialEq for Event {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == Ordering::Equal
    }
}
impl Eq for Event {}

impl Ord for Event {
    fn cmp(&self, other: &Self) -> Ordering {
        // Reversed: BinaryHeap is a max-heap, we want earliest-first.
        other
            .time
            .partial_cmp(&self.time)
            .unwrap_or(Ordering::Equal)
            .then_with(|| other.kind.rank().cmp(&self.kind.rank()))
    }
}

impl PartialOrd for Event {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

/// What a flow's completion delivers.
#[derive(Debug, Clone, Copy)]
enum Payload {
    /// A P2P message: the slot of the FIFO it is delivered to.
    Msg(u32),
    /// One ring hop of the collective at this index in `Engine::colls`.
    Ring(usize),
}

/// One in-flight flow (contended mode).
#[derive(Debug, Clone, Copy)]
struct Xfer {
    payload: Payload,
    /// Dense flat-arena indices of the shared resources the flow
    /// occupies: an intra-node pipe, or — for inter-node traffic under
    /// NIC aggregation — the source node's egress NIC plus the
    /// destination node's ingress NIC ([`NO_RESOURCE`] when single).
    res: (u32, u32),
    /// Remaining work in *solo seconds* — the time the rest of the
    /// transfer would take alone (latency + bytes/bandwidth). The first
    /// `lat_left` of it is fixed wire latency draining at wall rate; the
    /// remainder is shared work draining at `1/k` with `k` flows on the
    /// flow's most-loaded resource. A never-shared flow reproduces the
    /// fixed-duration arrival bit for bit (its `k == 1` path keeps the
    /// pre-split arithmetic verbatim).
    remaining: f64,
    /// Unpaid wire-latency budget inside `remaining` (invariant:
    /// `lat_left <= remaining`). Latency is not shared bandwidth — it
    /// always drains at wall rate, which is exactly the latency-split
    /// fix: k sharers pay the latency once, not k times.
    lat_left: f64,
    /// Virtual time `remaining` was last settled at (incremental
    /// settlement; unused under [`NetworkImpl::Global`]).
    settled: f64,
    /// Fair share in effect since `settled` (>= 1; incremental).
    share: f64,
    /// Projection version; completion events carry the version they were
    /// projected under and are discarded if it has moved on.
    version: u64,
    done: bool,
}

/// The shared-resource network: progress-tracking fair-share bandwidth
/// over a flat arena of per-resource active-flow lists. Settlement
/// strategy per [`NetworkImpl`]; see the module docs.
#[derive(Debug)]
struct Network {
    imp: NetworkImpl,
    xfers: Vec<Xfer>,
    /// Active flow ids per dense resource index, in deterministic start
    /// order. Pre-sized from `ClusterConfig::n_resources`, grown on
    /// demand for out-of-range hand-built clusters.
    res: Vec<Vec<usize>>,
    /// In-flight flow ids in start order (the global settlement walk).
    active: Vec<usize>,
    /// Virtual time progress was last settled at (global).
    last: f64,
    /// Pooled scratch for the affected-flow set of one network event
    /// (sorted, deduped) — reused instead of allocating per reproject.
    scratch: Vec<usize>,
}

impl Network {
    fn new(imp: NetworkImpl, n_resources: usize) -> Network {
        Network {
            imp,
            xfers: Vec::new(),
            res: vec![Vec::new(); n_resources],
            active: Vec::new(),
            last: 0.0,
            scratch: Vec::new(),
        }
    }

    /// Occupancy of one dense resource (0 when never occupied).
    fn occ(res: &[Vec<usize>], r: u32) -> usize {
        res.get(r as usize).map_or(0, Vec::len)
    }

    /// Share count of a flow: occupancy of its most-loaded resource
    /// (>= 1, since an active flow occupies each of its resources).
    fn share_of(res: &[Vec<usize>], x: &Xfer) -> f64 {
        let mut k = Self::occ(res, x.res.0);
        if x.res.1 != NO_RESOURCE {
            k = k.max(Self::occ(res, x.res.1));
        }
        k.max(1) as f64
    }

    /// Effective share under fault-degraded link rates: a resource running
    /// at rate `r ∈ (0, 1]` stretches its flows' shared byte-work by
    /// `1/r`, so the flow behaves as `k / r_min` sharers of a healthy
    /// pipe — a solo flow on a half-rate link is `k_eff = 2`, draining its
    /// bytes at half speed while its wire latency still passes at wall
    /// rate ([`Self::drain`]'s `k > 1` branch). With no fault state
    /// (`rates` empty) or healthy rates this *is* [`Self::share_of`],
    /// expression for expression — the empty-plan bit-identity anchor.
    fn eff_share(res: &[Vec<usize>], rates: &[f64], x: &Xfer) -> f64 {
        let k = Self::share_of(res, x);
        if rates.is_empty() {
            return k;
        }
        let r = FaultRt::edge_rate(rates, x.res);
        if r < 1.0 {
            k / r
        } else {
            k
        }
    }

    fn slot(&mut self, r: u32) -> &mut Vec<usize> {
        let i = r as usize;
        if i >= self.res.len() {
            self.res.resize_with(i + 1, Vec::new);
        }
        &mut self.res[i]
    }

    fn occupy(&mut self, id: usize) {
        let (r1, r2) = self.xfers[id].res;
        self.slot(r1).push(id);
        if r2 != NO_RESOURCE {
            self.slot(r2).push(id);
        }
    }

    fn release(&mut self, id: usize) {
        let (r1, r2) = self.xfers[id].res;
        self.res[r1 as usize].retain(|&i| i != id);
        if r2 != NO_RESOURCE {
            self.res[r2 as usize].retain(|&i| i != id);
        }
    }

    /// Fill `scratch` with every active flow sharing a resource with
    /// `id` (including `id` itself while it occupies them), deduplicated
    /// in ascending id order.
    fn collect_sharers(&mut self, id: usize) {
        let Network { res, xfers, scratch, .. } = self;
        scratch.clear();
        let x = &xfers[id];
        if let Some(l) = res.get(x.res.0 as usize) {
            scratch.extend_from_slice(l);
        }
        if x.res.1 != NO_RESOURCE {
            if let Some(l) = res.get(x.res.1 as usize) {
                scratch.extend_from_slice(l);
            }
        }
        scratch.sort_unstable();
        scratch.dedup();
    }

    /// Drain `dt` wall seconds of progress from one flow at share `k`:
    /// the unpaid latency budget first, at wall rate (latency is not
    /// shared bandwidth), then the remaining shared work at `1/k`. The
    /// `k == 1` branch keeps the pre-latency-split expressions verbatim —
    /// f64 addition is not associative, so this is what preserves the
    /// solo-flow/solo-ring bit-equality anchors.
    fn drain(x: &mut Xfer, dt: f64, k: f64) {
        if k <= 1.0 {
            x.remaining = (x.remaining - dt / k).max(0.0);
            x.lat_left = (x.lat_left - dt).max(0.0);
        } else {
            let wall = x.lat_left.min(dt);
            x.lat_left -= wall;
            x.remaining = (x.remaining - wall - (dt - wall) / k).max(0.0);
        }
    }

    /// Projected completion of a flow at share `k` from time `t`: the
    /// latency budget passes at wall rate, the shared remainder at `1/k`.
    /// The `k == 1` arm is the pre-split expression verbatim (see
    /// [`Self::drain`]).
    fn project(x: &Xfer, t: f64, k: f64) -> f64 {
        if k <= 1.0 {
            t + x.remaining * k
        } else {
            t + x.lat_left + (x.remaining - x.lat_left) * k
        }
    }

    /// Global settlement: advance every in-flight flow from the shared
    /// settle point to `t` at its current fair share (fault-degraded
    /// rates included — `rates` is empty on fault-free runs).
    fn settle_global(&mut self, t: f64, rates: &[f64]) {
        if t > self.last {
            let dt = t - self.last;
            let Network { res, xfers, active, .. } = self;
            for &id in active.iter() {
                let k = Self::eff_share(res, rates, &xfers[id]);
                Self::drain(&mut xfers[id], dt, k);
            }
            self.last = t;
        }
    }

    /// Incremental settlement of one flow: charge it the wall time since
    /// its own settle point at the share in effect over that interval.
    fn settle_flow(x: &mut Xfer, t: f64) {
        if t > x.settled {
            let (dt, k) = (t - x.settled, x.share);
            Self::drain(x, dt, k);
        }
        x.settled = t;
    }

    /// Re-project the completion of every flow in `scratch` under the new
    /// share counts, bumping versions so older projections go stale.
    /// Under incremental settlement each touched flow is settled first
    /// and caches its new share; untouched flows keep their projections.
    fn reproject_scratch(&mut self, t: f64, heap: &mut BinaryHeap<Event>, rates: &[f64]) {
        let ids = std::mem::take(&mut self.scratch);
        let incremental = self.imp == NetworkImpl::Incremental;
        for &id in &ids {
            let k = Self::eff_share(&self.res, rates, &self.xfers[id]);
            let x = &mut self.xfers[id];
            if incremental {
                Self::settle_flow(x, t);
                x.share = k;
            }
            x.version += 1;
            heap.push(Event {
                time: Self::project(x, t, k),
                kind: EvKind::XferDone { id, version: x.version },
            });
        }
        self.scratch = ids;
    }

    /// Flow `id` enters the network at `t`: settle, occupy its resources,
    /// re-project everyone whose share the arrival can have changed.
    fn insert(&mut self, id: usize, t: f64, heap: &mut BinaryHeap<Event>, rates: &[f64]) {
        match self.imp {
            NetworkImpl::Global => self.settle_global(t, rates),
            NetworkImpl::Incremental => {
                // Nothing to settle yet: the new flow starts its own
                // clock here (dt = 0 in the reproject below).
                let x = &mut self.xfers[id];
                x.settled = t;
                x.share = 1.0;
            }
        }
        self.occupy(id);
        self.active.push(id);
        self.collect_sharers(id);
        self.reproject_scratch(t, heap, rates);
    }

    /// Flow `id` completes at `t`: settle, release its resources,
    /// re-project the remaining sharers.
    fn remove(&mut self, id: usize, t: f64, heap: &mut BinaryHeap<Event>, rates: &[f64]) {
        match self.imp {
            NetworkImpl::Global => self.settle_global(t, rates),
            NetworkImpl::Incremental => Self::settle_flow(&mut self.xfers[id], t),
        }
        self.xfers[id].done = true;
        self.release(id);
        self.active.retain(|&i| i != id);
        self.collect_sharers(id);
        self.reproject_scratch(t, heap, rates);
    }

    /// Fill `scratch` with every active flow occupying any of the dense
    /// resources in `affected` (sorted, deduped) — the set a fault
    /// boundary must settle and re-project, and nobody else: a rate
    /// change is invisible to flows whose resources it does not touch,
    /// exactly like an occupancy change (PR-5 incremental settlement).
    fn gather_occupants(&mut self, affected: &[u32]) {
        self.scratch.clear();
        for &r in affected {
            if let Some(l) = self.res.get(r as usize) {
                self.scratch.extend_from_slice(l);
            }
        }
        self.scratch.sort_unstable();
        self.scratch.dedup();
    }
}

/// One link-degradation fault, pre-resolved against the cost model: its
/// window, bandwidth multiplier, and the dense resources it degrades.
#[derive(Debug)]
struct LinkFault {
    mult: f64,
    t0: f64,
    t1: f64,
    /// Sorted dense resource indices the fault hits (the resources of the
    /// targeted pipeline-device pairs' pipes, both directions).
    res: Vec<u32>,
}

/// What one fault boundary does when its heap event fires.
#[derive(Debug, Clone, Copy)]
enum FaultBoundary {
    /// A link window opened or closed: recompute the rates of the
    /// resources link fault `ev` touches and re-project their occupants.
    Link { ev: usize },
    /// A compute window opened or closed: recompute device `dev`'s
    /// multiplier. Compute ops take it at their next *dispatch* — an op
    /// priced before the boundary keeps its price (documented policy,
    /// pinned by `rust/tests/faults.rs`).
    Slow { dev: usize },
    /// A stall landed: pin device `dev`'s clock to at least `until`.
    Stall { dev: usize, until: f64 },
}

/// Runtime fault state, attached to the engine only when a non-empty
/// [`FaultPlan`] is supplied — `None` leaves every historical code path
/// (and every heap content) untouched, which is the empty-plan
/// bit-identity guarantee `rust/tests/faults.rs` pins.
#[derive(Debug)]
struct FaultRt {
    links: Vec<LinkFault>,
    /// `(dev, mult, t0, t1)` per [`FaultEvent::DeviceSlow`], in plan
    /// order (the deterministic product order of overlapping windows).
    slows: Vec<(usize, f64, f64, f64)>,
    /// Boundary schedule, sorted by time (ties keep plan order); heap
    /// fault events carry indices into it.
    boundaries: Vec<(f64, FaultBoundary)>,
    /// Current rate of each dense resource, 1.0 healthy, ∈ (0, 1] —
    /// recomputed from scratch (never divided back out) at each link
    /// boundary so repeated crossings are bitwise reproducible.
    rates: Vec<f64>,
    /// Current compute multiplier per device (>= 1), recomputed at each
    /// slow boundary.
    dev_mult: Vec<f64>,
}

impl FaultRt {
    /// Resolve a validated plan against the cost model: link targets
    /// become dense resource sets (via the same [`CostModel::p2p_edge`]
    /// table the engine's flows use, so fault resources and flow
    /// resources can never disagree), and window edges become a sorted
    /// boundary schedule.
    fn new(plan: &FaultPlan, costs: &CostModel, d: usize) -> FaultRt {
        let mut links = Vec::new();
        let mut slows = Vec::new();
        let mut boundaries = Vec::new();
        for ev in &plan.events {
            match *ev {
                FaultEvent::LinkDegrade { target, mult, t_start, t_end } => {
                    let i = links.len();
                    links.push(LinkFault {
                        mult,
                        t0: t_start,
                        t1: t_end,
                        res: Self::link_resources(costs, d, target),
                    });
                    boundaries.push((t_start, FaultBoundary::Link { ev: i }));
                    boundaries.push((t_end, FaultBoundary::Link { ev: i }));
                }
                FaultEvent::DeviceSlow { dev, mult, t_start, t_end } => {
                    slows.push((dev, mult, t_start, t_end));
                    boundaries.push((t_start, FaultBoundary::Slow { dev }));
                    boundaries.push((t_end, FaultBoundary::Slow { dev }));
                }
                FaultEvent::DeviceStall { dev, t, dur } => {
                    boundaries.push((t, FaultBoundary::Stall { dev, until: t + dur }));
                }
            }
        }
        boundaries.sort_by(|a, b| a.0.total_cmp(&b.0));
        let n_res = links
            .iter()
            .flat_map(|l| l.res.iter())
            .map(|&r| r as usize + 1)
            .max()
            .unwrap_or(0)
            .max(costs.cluster.n_resources());
        FaultRt { links, slows, boundaries, rates: vec![1.0; n_res], dev_mult: vec![1.0; d] }
    }

    /// Dense resources of every pipe a [`FaultTarget`] names, resolved
    /// through the cost model's precomputed edge table over pipeline
    /// devices (both directions of each pair — links are full-duplex but
    /// a fault hits the hardware, not one direction).
    fn link_resources(costs: &CostModel, d: usize, target: FaultTarget) -> Vec<u32> {
        let mut out = Vec::new();
        let mut push_pair = |out: &mut Vec<u32>, a: usize, b: usize| {
            let res = costs.p2p_edge(a, b).res;
            out.push(res.0);
            if res.1 != NO_RESOURCE {
                out.push(res.1);
            }
        };
        match target {
            FaultTarget::LinkPair { a, b } => {
                push_pair(&mut out, a, b);
                push_pair(&mut out, b, a);
            }
            FaultTarget::LinkClass(kind) => {
                for a in 0..d {
                    for b in 0..d {
                        if a != b && costs.p2p_edge(a, b).link.kind == kind {
                            push_pair(&mut out, a, b);
                        }
                    }
                }
            }
        }
        out.sort_unstable();
        out.dedup();
        out
    }

    /// Current rate of the slower of a flow's resources (1.0 when the
    /// rates table is absent or the indices are out of range).
    fn edge_rate(rates: &[f64], res: (u32, u32)) -> f64 {
        let mut r = rates.get(res.0 as usize).copied().unwrap_or(1.0);
        if res.1 != NO_RESOURCE {
            r = r.min(rates.get(res.1 as usize).copied().unwrap_or(1.0));
        }
        r
    }

    /// Recompute the rates of the resources link fault `ev` touches as
    /// the product of every degradation active at `t` (window `[t0,
    /// t1)`), in plan order — always the same expression, so crossing the
    /// same boundary state twice yields bitwise-identical rates.
    fn recompute_link_rates(&mut self, ev: usize, t: f64) {
        for i in 0..self.links[ev].res.len() {
            let r = self.links[ev].res[i];
            let mut rate = 1.0;
            for lf in &self.links {
                if lf.t0 <= t && t < lf.t1 && lf.res.binary_search(&r).is_ok() {
                    rate *= lf.mult;
                }
            }
            self.rates[r as usize] = rate;
        }
    }

    /// Recompute device `dev`'s compute multiplier as the product of its
    /// degradation windows active at `t`, in plan order.
    fn recompute_dev_mult(&mut self, dev: usize, t: f64) {
        let mut mult = 1.0;
        for &(d2, m, t0, t1) in &self.slows {
            if d2 == dev && t0 <= t && t < t1 {
                mult *= m;
            }
        }
        self.dev_mult[dev] = mult;
    }

    /// Rates slice for share computations: empty when no fault state is
    /// attached (the fast path every fault-free run takes).
    fn rates_of(faults: &Option<FaultRt>) -> &[f64] {
        faults.as_ref().map_or(&[], |f| f.rates.as_slice())
    }
}

/// One collective being lowered to ring flows ([`Contention::Full`]).
#[derive(Debug)]
struct Coll {
    stage: StageId,
    round: usize,
    /// Latest member launch time: flows may not enter the wire before it.
    gate: f64,
    /// Member devices (simulated group) whose comm engines serialize it.
    members: Vec<usize>,
    /// The ring lowering to run; drained into flows at launch.
    hops: Vec<super::cost::RingHop>,
    /// Ring flows still in flight; completion of the last one completes
    /// the collective.
    flows_left: usize,
}

/// Per-(stage, round) collective state.
#[derive(Debug, Default)]
struct ArState {
    /// (device, launch time) per group member that has started.
    launched: Vec<(usize, f64)>,
    /// Completion time, once every member launched.
    done: Option<f64>,
    /// Devices parked in `AllReduceWait` on this round.
    waiters: Vec<usize>,
}

struct Engine<'a> {
    s: &'a Schedule,
    costs: &'a CostModel,
    /// Structure-only stream lowering (message slots); borrowed so the
    /// contended sweep's `StreamCache` can share one across grid points.
    tables: &'a StreamTables,
    iters: usize,
    /// Pre-resolved all-reduce groups per model stage.
    groups: Vec<Vec<usize>>,
    /// Stage count of the placement, sizing the flat collective tables.
    n_stages: usize,

    now: Vec<f64>,
    trace: Vec<DeviceTrace>,
    /// Current iteration per device.
    it: Vec<usize>,
    /// Instruction cursor within the current iteration per device.
    ix: Vec<usize>,

    /// In-flight messages: FIFO arrival-time queue per slot.
    msgs: Vec<VecDeque<f64>>,
    /// Device parked on a message slot (the key's `to` field — one
    /// waiter).
    msg_waiters: Vec<Option<usize>>,

    /// Collective state, `[stage][round]` (rounds grow on demand).
    ar: Vec<Vec<ArState>>,
    /// Rounds of `AllReduceStart{stage}` executed, `[dev * n_stages +
    /// stage]`.
    ar_started: Vec<usize>,
    /// Rounds of `AllReduceWait{stage}` completed, same layout.
    ar_waited: Vec<usize>,
    /// Per-device collective engine (NCCL comm stream): concurrent
    /// collectives sharing a device serialize on it. This is what makes
    /// eager launches (paper Fig 5b) pay off — early collectives drain the
    /// engine while compute continues; lazy launches queue at the end.
    comm_free: Vec<f64>,
    /// Contention mode; `Off` = fixed-duration transfers (the bit-stable
    /// legacy behaviour).
    mode: Contention,
    /// Shared-resource bandwidth model; `Some` iff `mode != Off`.
    net: Option<Network>,
    /// Collectives lowered to ring flows (`Contention::Full`).
    colls: Vec<Coll>,
    /// Collectives not yet launched, in creation order — the only ones a
    /// launch scan must visit (keeps launch work proportional to the
    /// in-flight backlog, not to every collective of the whole run).
    pending: Vec<usize>,
    /// Per-device FIFO of flow-lowered collectives awaiting/holding the
    /// comm engine: a collective launches its flows only once it heads
    /// every member's queue — the flow-world `comm_free` serialization.
    comm_q: Vec<VecDeque<usize>>,

    /// Fault-plan runtime state; `None` (every fault-free run, including
    /// empty plans) leaves all historical code paths untouched.
    faults: Option<FaultRt>,

    heap: BinaryHeap<Event>,
    remaining: usize,
    iter_finish: Vec<f64>,
}

impl<'a> Engine<'a> {
    fn new(
        s: &'a Schedule,
        costs: &'a CostModel,
        tables: &'a StreamTables,
        iters: usize,
        mode: Contention,
        network: NetworkImpl,
        faults: Option<&FaultPlan>,
    ) -> Engine<'a> {
        let d = s.n_devices();
        let per_iter: usize = s.device_ops.iter().map(|o| o.len()).sum();
        let n_stages = s.placement.n_stages();
        let groups = (0..n_stages).map(|st| s.placement.allreduce_group(st)).collect();
        Engine {
            s,
            costs,
            tables,
            iters,
            groups,
            n_stages,
            now: vec![0.0; d],
            trace: vec![DeviceTrace::default(); d],
            it: vec![0; d],
            ix: vec![0; d],
            msgs: vec![VecDeque::new(); tables.n_slots],
            msg_waiters: vec![None; tables.n_slots],
            ar: vec![Vec::new(); n_stages],
            ar_started: vec![0; d * n_stages],
            ar_waited: vec![0; d * n_stages],
            comm_free: vec![0.0; d],
            mode,
            net: (mode != Contention::Off)
                .then(|| Network::new(network, costs.cluster.n_resources())),
            colls: Vec::new(),
            pending: Vec::new(),
            comm_q: vec![VecDeque::new(); d],
            faults: faults.filter(|p| !p.is_empty()).map(|p| FaultRt::new(p, costs, d)),
            heap: BinaryHeap::new(),
            remaining: per_iter * iters,
            iter_finish: vec![0.0; iters],
        }
    }

    /// Collective state for `(stage, round)`, growing the round table on
    /// demand.
    fn ar_state(&mut self, stage: StageId, round: usize) -> &mut ArState {
        let v = &mut self.ar[stage];
        while v.len() <= round {
            v.push(ArState::default());
        }
        &mut v[round]
    }

    fn wake(&mut self, dev: usize, at: f64) {
        self.heap.push(Event { time: at.max(self.now[dev]), kind: EvKind::Dev(dev) });
    }

    /// Try to consume the head of a slot's FIFO; on miss, park the device.
    fn try_recv(&mut self, dev: usize, slot: u32) -> bool {
        match self.msgs[slot as usize].pop_front() {
            None => {
                self.msg_waiters[slot as usize] = Some(dev);
                false
            }
            Some(arrival) => {
                if arrival > self.now[dev] {
                    self.trace[dev].recv_blocked += arrival - self.now[dev];
                    self.now[dev] = arrival;
                }
                true
            }
        }
    }

    /// Async send: fixed-duration or contended, depending on mode. The
    /// sender pays `LAUNCH` either way and never blocks.
    fn send(&mut self, dev: usize, to: usize, slot: u32) {
        self.now[dev] += LAUNCH;
        self.trace[dev].sends += 1;
        if self.net.is_some() {
            self.send_contended(dev, to, slot);
            return;
        }
        let arrival = self.now[dev] + self.p2p_time_faulted(dev, to);
        self.msgs[slot as usize].push_back(arrival);
        if let Some(waiter) = self.msg_waiters[slot as usize].take() {
            self.wake(waiter, arrival);
        }
    }

    /// Fixed-duration P2P pricing under faults: the whole transfer is
    /// priced at the rate in effect at *dispatch* (the fixed-duration
    /// analogue of the applies-at-next-dispatch compute policy — there is
    /// no in-flight flow to re-project), with wire latency unscaled as in
    /// the contended model. Without fault state, or with this edge
    /// healthy, this is exactly [`CostModel::p2p_time`] — the historical
    /// expression, verbatim.
    fn p2p_time_faulted(&self, dev: usize, to: usize) -> f64 {
        if let Some(f) = &self.faults {
            let edge = self.costs.p2p_edge(dev, to);
            let r = FaultRt::edge_rate(&f.rates, edge.res);
            if r < 1.0 {
                return edge.lat + (edge.bytes as f64 / edge.bw) / r;
            }
        }
        self.costs.p2p_time(dev, to)
    }

    /// Contended send: register the flow and defer its wire entry to the
    /// heap, so the network observes starts in global time order. The
    /// message is delivered (and any parked receiver woken) only when the
    /// flow's completion event fires.
    fn send_contended(&mut self, dev: usize, to: usize, slot: u32) {
        let edge = self.costs.p2p_edge(dev, to);
        let net = self.net.as_mut().expect("contended send without a network");
        let id = net.xfers.len();
        // The other W-1 data-parallel groups send identical messages at
        // the same virtual time; `dp_copies` of them share this pipe, so
        // the tracked copy carries dp_copies x its *byte* work — the
        // replicas stream concurrently, so the wire latency is still paid
        // once, not per copy. With dp_copies == 1 the total is
        // `lat + (bytes/bw) * 1.0`, IEEE-exactly the edge's solo time,
        // preserving the solo-flow bit-equality guarantee.
        let byte_work = edge.bytes as f64 / edge.bw;
        net.xfers.push(Xfer {
            payload: Payload::Msg(slot),
            res: edge.res,
            remaining: edge.lat + byte_work * f64::from(edge.dp_copies),
            lat_left: edge.lat,
            settled: 0.0,
            share: 1.0,
            version: 0,
            done: false,
        });
        self.heap.push(Event { time: self.now[dev], kind: EvKind::XferStart { id } });
    }

    /// A flow enters the wire at time `t`: settle, occupy its resources,
    /// and re-project the flows it now shares with.
    fn on_xfer_start(&mut self, id: usize, t: f64) {
        let rates = FaultRt::rates_of(&self.faults);
        let net = self.net.as_mut().expect("transfer event without a network");
        net.insert(id, t, &mut self.heap, rates);
    }

    /// A flow's projected completion fires at time `t`. Stale projections
    /// (version moved on, or already done) are ignored; a current one
    /// releases the flow's resources, re-projects the remaining sharers,
    /// and delivers its payload — a P2P message, or one ring hop of a
    /// collective (whose last hop completes the collective).
    fn on_xfer_done(&mut self, id: usize, version: u64, t: f64) {
        let rates = FaultRt::rates_of(&self.faults);
        let net = self.net.as_mut().expect("transfer event without a network");
        let x = net.xfers[id];
        if x.done || x.version != version {
            return;
        }
        net.remove(id, t, &mut self.heap, rates);
        match x.payload {
            Payload::Msg(slot) => {
                self.msgs[slot as usize].push_back(t);
                if let Some(waiter) = self.msg_waiters[slot as usize].take() {
                    self.wake(waiter, t);
                }
            }
            Payload::Ring(c) => {
                self.colls[c].flows_left -= 1;
                if self.colls[c].flows_left == 0 {
                    self.complete_collective(c, t);
                }
            }
        }
    }

    /// A fault boundary fires at `t`. Link boundaries mutate the dense
    /// resource rates and re-settle/re-project *only* the flows occupying
    /// a mutated resource (riding the incremental-settlement machinery:
    /// under [`NetworkImpl::Incremental`] each touched flow settles its
    /// elapsed interval at its cached pre-boundary share before caching
    /// the new one; under [`NetworkImpl::Global`] everyone settles at the
    /// old rates first). Slow boundaries recompute the device multiplier,
    /// which compute ops read at their next dispatch. Stall boundaries
    /// pin the device clock forward — blocked devices keep the push
    /// because every wake maxes against `now`.
    fn on_fault(&mut self, idx: usize, t: f64) {
        let b = self.faults.as_ref().expect("fault event without fault state").boundaries[idx].1;
        match b {
            FaultBoundary::Stall { dev, until } => {
                if self.now[dev] < until {
                    self.now[dev] = until;
                }
            }
            FaultBoundary::Slow { dev } => {
                self.faults.as_mut().expect("fault state").recompute_dev_mult(dev, t);
            }
            FaultBoundary::Link { ev } => {
                if let (Some(net), Some(f)) = (self.net.as_mut(), self.faults.as_ref()) {
                    if net.imp == NetworkImpl::Global {
                        net.settle_global(t, &f.rates);
                    }
                }
                self.faults.as_mut().expect("fault state").recompute_link_rates(ev, t);
                if let (Some(net), Some(f)) = (self.net.as_mut(), self.faults.as_ref()) {
                    net.gather_occupants(&f.links[ev].res);
                    net.reproject_scratch(t, &mut self.heap, &f.rates);
                }
            }
        }
    }

    /// Scale a compute duration by the device's current fault multiplier.
    /// The policy is applies-at-next-dispatch: the multiplier in effect
    /// when the op is priced covers the whole op, even if a window opens
    /// or closes mid-op — and a device running locally ahead of a not-yet
    /// -fired boundary still uses the old multiplier (pinned by
    /// `rust/tests/faults.rs`). Fault-free runs skip the multiply
    /// entirely.
    #[inline]
    fn fault_scaled(&self, dev: usize, c: f64) -> f64 {
        match &self.faults {
            Some(f) if f.dev_mult[dev] != 1.0 => c * f.dev_mult[dev],
            _ => c,
        }
    }

    /// Launch every pending collective that now heads all of its members'
    /// comm queues: its ring flows enter the wire at the latest member
    /// launch time, or at `t` if a queued predecessor released the
    /// engines later than that.
    fn try_launch_collectives(&mut self, t: f64) {
        let mut i = 0;
        while i < self.pending.len() {
            let c = self.pending[i];
            let at_head = self.colls[c]
                .members
                .iter()
                .all(|&g| self.comm_q[g].front() == Some(&c));
            if !at_head {
                i += 1;
                continue;
            }
            self.pending.remove(i);
            let at = self.colls[c].gate.max(t);
            let hops = std::mem::take(&mut self.colls[c].hops);
            let net = self.net.as_mut().expect("collective flows without a network");
            for hop in &hops {
                let id = net.xfers.len();
                net.xfers.push(Xfer {
                    payload: Payload::Ring(c),
                    res: hop.res,
                    remaining: hop.work,
                    lat_left: hop.lat,
                    settled: 0.0,
                    share: 1.0,
                    version: 0,
                    done: false,
                });
                self.heap.push(Event { time: at, kind: EvKind::XferStart { id } });
            }
        }
    }

    /// The last ring flow of collective `c` drained at `t`: the collective
    /// is done — record it, free the member comm engines, wake the parked
    /// waiters, and let queued successors launch.
    fn complete_collective(&mut self, c: usize, t: f64) {
        let (stage, round) = (self.colls[c].stage, self.colls[c].round);
        let members = std::mem::take(&mut self.colls[c].members);
        for &g in &members {
            let head = self.comm_q[g].pop_front();
            debug_assert_eq!(head, Some(c), "comm queue out of order");
            // max: an analytic collective (unmappable hand-built group) may
            // have already pushed comm_free past this ring's completion.
            self.comm_free[g] = self.comm_free[g].max(t);
        }
        self.colls[c].members = members;
        let st = self.ar_state(stage, round);
        st.done = Some(t);
        let waiters = std::mem::take(&mut st.waiters);
        for w in waiters {
            self.heap.push(Event { time: t.max(self.now[w]), kind: EvKind::Dev(w) });
        }
        self.try_launch_collectives(t);
    }

    /// Record an `AllReduceStart`; on the last member, price the collective
    /// (analytically, or — under full contention — by lowering its ring
    /// onto the wire) and wake the parked waiters when its completion is
    /// already known.
    fn allreduce_start(&mut self, dev: usize, stage: StageId) {
        self.now[dev] += LAUNCH;
        let round = {
            let r = &mut self.ar_started[dev * self.n_stages + stage];
            let cur = *r;
            *r += 1;
            cur
        };
        if !self.groups[stage].contains(&dev) {
            return; // malformed stream: a non-member start never completes anything
        }
        let launch_t = self.now[dev];
        let group_len = self.groups[stage].len();
        let st = self.ar_state(stage, round);
        // A device starts each (stage, round) at most once: `ar_started`
        // advances the round on every start, so entries here are unique.
        debug_assert!(st.launched.iter().all(|&(g, _)| g != dev));
        st.launched.push((dev, launch_t));
        if st.launched.len() < group_len {
            return;
        }
        let launched = st.launched.iter().map(|&(_, t)| t).fold(0.0f64, f64::max);
        if self.mode == Contention::Full {
            // Flow lowering: completion is decided on the wire. Waiters
            // stay parked in `st.waiters` until the last ring flow drains.
            // Out-of-table stages (hand-built streams) get a fallback ring
            // over the engine's own group so every nonzero collective goes
            // through the same comm-queue serialization.
            let costs = self.costs;
            let hops: Vec<super::cost::RingHop> = match costs.ring_hops(stage) {
                Some(h) => h.to_vec(),
                None => costs.fallback_ring_hops(&self.groups[stage]),
            };
            if !hops.is_empty() {
                let members = self.groups[stage].clone();
                let c = self.colls.len();
                self.colls.push(Coll {
                    stage,
                    round,
                    gate: launched,
                    members: members.clone(),
                    flows_left: hops.len(),
                    hops,
                });
                for &g in &members {
                    self.comm_q[g].push_back(c);
                }
                self.pending.push(c);
                self.try_launch_collectives(launched);
                return;
            }
        }
        // Analytic pricing (contention off / P2P-only; zero-duration
        // collectives; unmappable hand-built groups). Known limit: under
        // Full, an unmappable group (a member device beyond the cost
        // model's pipeline depth — impossible for generated schedules)
        // prices against comm_free, which in-flight ring flows only write
        // at completion, so such a collective may overlap a ring on the
        // shared engine instead of queueing behind it.
        let waiters = std::mem::take(&mut self.ar_state(stage, round).waiters);
        let group = &self.groups[stage];
        let engine = group.iter().map(|&g| self.comm_free[g]).fold(0.0f64, f64::max);
        let done = launched.max(engine) + self.costs.allreduce_time(stage);
        for &g in group {
            self.comm_free[g] = done;
        }
        self.ar_state(stage, round).done = Some(done);
        for w in waiters {
            self.heap.push(Event { time: done.max(self.now[w]), kind: EvKind::Dev(w) });
        }
    }

    /// Run device `dev` until it blocks or finishes all iterations.
    fn run_device(&mut self, dev: usize) {
        let s = self.s;
        let ops: &[Instr] = &s.device_ops[dev];
        loop {
            if self.ix[dev] == ops.len() {
                let k = self.it[dev];
                if self.iter_finish[k] < self.now[dev] {
                    self.iter_finish[k] = self.now[dev];
                }
                self.it[dev] += 1;
                self.ix[dev] = 0;
                if self.it[dev] == self.iters {
                    self.trace[dev].finish = self.now[dev];
                    return;
                }
                continue;
            }
            // Compute is priced per (device, stage): stragglers and layer
            // profiles scale it; on uniform clusters the accessors return
            // the raw chunk fields (no multiplication), bit-identical to
            // the flat pricing this loop used before heterogeneity.
            match ops[self.ix[dev]] {
                Instr::Forward { stage, .. } => {
                    let c = self.fault_scaled(dev, self.costs.fwd_time(dev, stage));
                    self.now[dev] += c;
                    self.trace[dev].compute_busy += c;
                }
                Instr::Backward { stage, .. } => {
                    let c = self.fault_scaled(dev, self.costs.bwd_time(dev, stage));
                    self.now[dev] += c;
                    self.trace[dev].compute_busy += c;
                }
                Instr::BackwardInput { stage, .. } => {
                    let c = self.fault_scaled(dev, self.costs.bwd_input_time(dev, stage));
                    self.now[dev] += c;
                    self.trace[dev].compute_busy += c;
                }
                Instr::BackwardWeight { stage, .. } => {
                    let c = self.fault_scaled(dev, self.costs.bwd_weight_time(dev, stage));
                    self.now[dev] += c;
                    self.trace[dev].compute_busy += c;
                }
                Instr::SendAct { to, .. } | Instr::SendGrad { to, .. } => {
                    let slot = self.tables.slots[dev][self.ix[dev]];
                    self.send(dev, to, slot);
                }
                Instr::RecvAct { .. } => {
                    // The producer tagged the message with stage-1; a
                    // stage-0 RecvAct has no producer (its slot is
                    // NO_SLOT) — park the device so the run ends in a
                    // deadlock report, not a panic.
                    let slot = self.tables.slots[dev][self.ix[dev]];
                    if slot == NO_SLOT {
                        return;
                    }
                    if !self.try_recv(dev, slot) {
                        return;
                    }
                }
                Instr::RecvGrad { .. } => {
                    let slot = self.tables.slots[dev][self.ix[dev]];
                    if !self.try_recv(dev, slot) {
                        return;
                    }
                }
                Instr::LocalCopyAct { .. } | Instr::LocalCopyGrad { .. } => {
                    self.now[dev] += self.costs.local_copy_time();
                    self.trace[dev].local_copies += 1;
                }
                Instr::AllReduceStart { stage } => {
                    self.allreduce_start(dev, stage);
                }
                Instr::AllReduceWait { stage } => {
                    // A wait on a stage outside the placement can never
                    // complete: park the device (deadlock report), like
                    // the hash-keyed tables used to.
                    if stage >= self.n_stages {
                        return;
                    }
                    let round = self.ar_waited[dev * self.n_stages + stage];
                    match self.ar[stage].get(round).and_then(|st| st.done) {
                        Some(t) => {
                            self.ar_waited[dev * self.n_stages + stage] += 1;
                            if t > self.now[dev] {
                                self.trace[dev].allreduce_blocked += t - self.now[dev];
                                self.now[dev] = t;
                            }
                        }
                        None => {
                            self.ar_state(stage, round).waiters.push(dev);
                            return;
                        }
                    }
                }
                Instr::OptimStep { stage } => {
                    self.now[dev] += self.costs.optim_time(stage);
                }
            }
            self.ix[dev] += 1;
            self.remaining -= 1;
        }
    }

    fn run(mut self) -> Result<MultiIterTrace, SimError> {
        let d = self.s.n_devices();
        if let Some(f) = &self.faults {
            for (idx, &(t, _)) in f.boundaries.iter().enumerate() {
                self.heap.push(Event { time: t, kind: EvKind::Fault { idx } });
            }
        }
        for dev in 0..d {
            self.heap.push(Event { time: 0.0, kind: EvKind::Dev(dev) });
        }
        while let Some(ev) = self.heap.pop() {
            match ev.kind {
                EvKind::Dev(dev) => self.run_device(dev),
                EvKind::Fault { idx } => self.on_fault(idx, ev.time),
                EvKind::XferStart { id } => self.on_xfer_start(id, ev.time),
                EvKind::XferDone { id, version } => self.on_xfer_done(id, version, ev.time),
            }
        }
        if self.remaining > 0 {
            let stuck = (0..d)
                .filter(|&dv| self.it[dv] < self.iters)
                .map(|dv| {
                    (dv, self.ix[dv], self.s.device_ops[dv][self.ix[dv]].to_string())
                })
                .collect();
            return Err(SimError { stuck });
        }
        let makespan = self.iter_finish.last().copied().unwrap_or(0.0);
        Ok(MultiIterTrace { devices: self.trace, iter_finish: self.iter_finish, makespan })
    }
}

/// Run the instruction streams to completion in virtual time (one
/// iteration, fixed-duration transfers).
pub fn simulate_schedule(s: &Schedule, costs: &CostModel) -> Result<SimTrace, SimError> {
    simulate_schedule_with(s, costs, false)
}

/// Single-iteration run with an explicit contention flag: `contention`
/// true prices concurrent transfers *and* all-reduce ring flows at a fair
/// share of the wires they cross ([`Contention::Full`]; see the module
/// docs), false reproduces the fixed-duration engine bit for bit.
pub fn simulate_schedule_with(
    s: &Schedule,
    costs: &CostModel,
    contention: bool,
) -> Result<SimTrace, SimError> {
    let mode = if contention { Contention::Full } else { Contention::Off };
    simulate_schedule_contended(s, costs, mode)
}

/// Single-iteration run with the full three-way contention mode, exposing
/// [`Contention::P2pOnly`] — the PR-2 midpoint the differential battery
/// pins between `Off` and `Full`.
pub fn simulate_schedule_contended(
    s: &Schedule,
    costs: &CostModel,
    mode: Contention,
) -> Result<SimTrace, SimError> {
    let t = simulate_schedule_iters_contended(s, costs, 1, mode)?;
    Ok(SimTrace { devices: t.devices, makespan: t.makespan })
}

/// [`simulate_schedule_contended`] with an explicit settlement strategy —
/// the incremental-vs-global differential suite's entry point.
pub fn simulate_schedule_network(
    s: &Schedule,
    costs: &CostModel,
    mode: Contention,
    network: NetworkImpl,
) -> Result<SimTrace, SimError> {
    let t = simulate_schedule_iters_network(s, costs, 1, mode, network)?;
    Ok(SimTrace { devices: t.devices, makespan: t.makespan })
}

/// Run the instruction streams `iters` times back-to-back with no global
/// barrier between iterations (devices free-run into the next iteration,
/// like the threaded runtime). Message tags and collective rounds are
/// disambiguated across iterations by FIFO pairing and (stage, round)
/// keying respectively. Fixed-duration transfers.
pub fn simulate_schedule_iters(
    s: &Schedule,
    costs: &CostModel,
    iters: usize,
) -> Result<MultiIterTrace, SimError> {
    simulate_schedule_iters_with(s, costs, iters, false)
}

/// Multi-iteration run with an explicit contention flag (see
/// [`simulate_schedule_with`]).
pub fn simulate_schedule_iters_with(
    s: &Schedule,
    costs: &CostModel,
    iters: usize,
    contention: bool,
) -> Result<MultiIterTrace, SimError> {
    let mode = if contention { Contention::Full } else { Contention::Off };
    simulate_schedule_iters_contended(s, costs, iters, mode)
}

/// Multi-iteration run with the full three-way contention mode (see
/// [`simulate_schedule_contended`]).
pub fn simulate_schedule_iters_contended(
    s: &Schedule,
    costs: &CostModel,
    iters: usize,
    mode: Contention,
) -> Result<MultiIterTrace, SimError> {
    simulate_schedule_iters_network(s, costs, iters, mode, NetworkImpl::default())
}

/// Multi-iteration run with an explicit contention mode *and* settlement
/// strategy. The [`NetworkImpl::Global`] oracle and the default
/// incremental network agree to <= 1e-9 relative (bit-identical whenever
/// no flow ever shares a resource); `rust/tests/network_equiv.rs` pins
/// it.
pub fn simulate_schedule_iters_network(
    s: &Schedule,
    costs: &CostModel,
    iters: usize,
    mode: Contention,
    network: NetworkImpl,
) -> Result<MultiIterTrace, SimError> {
    let tables = StreamTables::build(s);
    simulate_streams_lowered(s, costs, iters, mode, network, &tables)
}

/// Single-iteration run replaying a [`FaultPlan`] (see
/// [`simulate_schedule_iters_faulted`]).
pub fn simulate_schedule_faulted(
    s: &Schedule,
    costs: &CostModel,
    mode: Contention,
    faults: &FaultPlan,
) -> Result<SimTrace, SimError> {
    let t = simulate_schedule_iters_faulted(s, costs, 1, mode, NetworkImpl::default(), faults)?;
    Ok(SimTrace { devices: t.devices, makespan: t.makespan })
}

/// Multi-iteration run replaying a [`FaultPlan`] against the streams:
/// link windows degrade dense resource rates (in-flight flows re-settled
/// and re-projected at each boundary; fixed-duration transfers priced at
/// the dispatch-time rate), compute windows multiply per-device op costs
/// at dispatch, and stalls pin device clocks forward. An empty plan is
/// bit-identical to [`simulate_schedule_iters_network`] on every mode —
/// the engine then attaches no fault state at all. The caller is expected
/// to have run [`FaultPlan::validate`]; the plan-aware `crate::sim`
/// entry points do.
pub fn simulate_schedule_iters_faulted(
    s: &Schedule,
    costs: &CostModel,
    iters: usize,
    mode: Contention,
    network: NetworkImpl,
    faults: &FaultPlan,
) -> Result<MultiIterTrace, SimError> {
    let tables = StreamTables::build(s);
    simulate_streams_faulted(s, costs, iters, mode, network, &tables, Some(faults))
}

/// The innermost entry point: run pre-lowered streams. The contended
/// sweep's `StreamCache` calls this directly with a cached
/// [`StreamTables`], skipping the per-run message-key interning; `tables`
/// must have been built from exactly this schedule's `device_ops`.
pub(crate) fn simulate_streams_lowered(
    s: &Schedule,
    costs: &CostModel,
    iters: usize,
    mode: Contention,
    network: NetworkImpl,
    tables: &StreamTables,
) -> Result<MultiIterTrace, SimError> {
    simulate_streams_faulted(s, costs, iters, mode, network, tables, None)
}

/// [`simulate_streams_lowered`] with an optional fault plan — the one
/// place an [`Engine`] is constructed.
pub(crate) fn simulate_streams_faulted(
    s: &Schedule,
    costs: &CostModel,
    iters: usize,
    mode: Contention,
    network: NetworkImpl,
    tables: &StreamTables,
    faults: Option<&FaultPlan>,
) -> Result<MultiIterTrace, SimError> {
    assert!(iters >= 1, "need at least one iteration");
    assert!(
        !s.device_ops.is_empty(),
        "schedule has no device_ops; run comm_pass first"
    );
    debug_assert_eq!(
        tables.slots.iter().map(Vec::len).collect::<Vec<_>>(),
        s.device_ops.iter().map(Vec::len).collect::<Vec<_>>(),
        "stream tables built from a different schedule"
    );
    Engine::new(s, costs, tables, iters, mode, network, faults).run()
}

/// The pre-event-queue executor: an O(D × total_ops) round-robin spin loop,
/// kept verbatim (modulo the entry-stage underflow guard) as the reference
/// semantics for differential tests. Single-iteration only — its
/// `HashMap<MsgKey, f64>` message store drops duplicate in-flight tags and
/// its per-stage `ar_done` map is single-shot, the two hazards the
/// event-queue engine exists to fix.
///
/// **Retired from the public surface** (ROADMAP open item): compiled only
/// for this crate's unit tests and — via the `reference-sim` feature the
/// dev-dependency self-reference in `Cargo.toml` turns on — for the
/// differential suites in `rust/tests/`. Release builds of the library
/// no longer carry it.
#[cfg(any(test, feature = "reference-sim"))]
pub fn simulate_schedule_reference(
    s: &Schedule,
    costs: &CostModel,
) -> Result<SimTrace, SimError> {
    let d = s.n_devices();
    let ops = &s.device_ops;
    assert!(!ops.is_empty(), "schedule has no device_ops; run comm_pass first");

    let mut cursor = vec![0usize; d];
    let mut now = vec![0.0f64; d];
    let mut trace = vec![DeviceTrace::default(); d];

    // In-flight messages: key -> arrival time (duplicates clobber!).
    let mut msgs: HashMap<MsgKey, f64> = HashMap::new();
    // All-reduce state per stage: device -> launch time.
    let mut ar_started: HashMap<StageId, HashMap<usize, f64>> = HashMap::new();
    // Completed all-reduces: stage -> completion time (single-shot!).
    let mut ar_done: HashMap<StageId, f64> = HashMap::new();
    let mut comm_free = vec![0.0f64; d];

    let total: usize = ops.iter().map(|o| o.len()).sum();
    let mut done_ops = 0usize;

    while done_ops < total {
        let mut progressed = false;
        for dev in 0..d {
            while cursor[dev] < ops[dev].len() {
                let instr = &ops[dev][cursor[dev]];
                let mut advance = true;
                match *instr {
                    Instr::Forward { .. } => {
                        now[dev] += costs.chunk_fwd;
                        trace[dev].compute_busy += costs.chunk_fwd;
                    }
                    Instr::Backward { .. } => {
                        now[dev] += costs.chunk_bwd;
                        trace[dev].compute_busy += costs.chunk_bwd;
                    }
                    Instr::BackwardInput { .. } => {
                        now[dev] += costs.chunk_bwd_input;
                        trace[dev].compute_busy += costs.chunk_bwd_input;
                    }
                    Instr::BackwardWeight { .. } => {
                        now[dev] += costs.chunk_bwd_weight;
                        trace[dev].compute_busy += costs.chunk_bwd_weight;
                    }
                    Instr::SendAct { to, pipe, stage, mb } => {
                        now[dev] += LAUNCH;
                        let arrival = now[dev] + costs.p2p_time(dev, to);
                        msgs.insert((dev, to, false, pipe, stage, mb), arrival);
                        trace[dev].sends += 1;
                    }
                    Instr::SendGrad { to, pipe, stage, mb } => {
                        now[dev] += LAUNCH;
                        let arrival = now[dev] + costs.p2p_time(dev, to);
                        msgs.insert((dev, to, true, pipe, stage, mb), arrival);
                        trace[dev].sends += 1;
                    }
                    Instr::RecvAct { from, pipe, stage, mb } => {
                        // Producer tagged with stage-1 (guarded: a stage-0
                        // RecvAct can never match and reports as deadlock).
                        let key = stage
                            .checked_sub(1)
                            .map(|producer| (from, dev, false, pipe, producer, mb));
                        match key.and_then(|k| msgs.get(&k).copied().map(|a| (k, a))) {
                            Some((k, arrival)) => {
                                if arrival > now[dev] {
                                    trace[dev].recv_blocked += arrival - now[dev];
                                    now[dev] = arrival;
                                }
                                msgs.remove(&k);
                            }
                            None => advance = false,
                        }
                    }
                    Instr::RecvGrad { from, pipe, stage, mb } => {
                        let key = (from, dev, true, pipe, stage + 1, mb);
                        match msgs.get(&key) {
                            Some(&arrival) => {
                                if arrival > now[dev] {
                                    trace[dev].recv_blocked += arrival - now[dev];
                                    now[dev] = arrival;
                                }
                                msgs.remove(&key);
                            }
                            None => advance = false,
                        }
                    }
                    Instr::LocalCopyAct { .. } | Instr::LocalCopyGrad { .. } => {
                        now[dev] += costs.local_copy_time();
                        trace[dev].local_copies += 1;
                    }
                    Instr::AllReduceStart { stage } => {
                        now[dev] += LAUNCH;
                        let entry = ar_started.entry(stage).or_default();
                        entry.insert(dev, now[dev]);
                        let group = s.placement.allreduce_group(stage);
                        if group.iter().all(|g| entry.contains_key(g)) {
                            let launched =
                                group.iter().map(|g| entry[g]).fold(0.0f64, f64::max);
                            let engine =
                                group.iter().map(|g| comm_free[*g]).fold(0.0f64, f64::max);
                            let done =
                                launched.max(engine) + costs.allreduce_time(stage);
                            for &g in &group {
                                comm_free[g] = done;
                            }
                            ar_done.insert(stage, done);
                        }
                    }
                    Instr::AllReduceWait { stage } => match ar_done.get(&stage) {
                        Some(&t) => {
                            if t > now[dev] {
                                trace[dev].allreduce_blocked += t - now[dev];
                                now[dev] = t;
                            }
                        }
                        None => advance = false,
                    },
                    Instr::OptimStep { stage } => {
                        now[dev] += costs.optim_time(stage);
                    }
                }
                if !advance {
                    break;
                }
                cursor[dev] += 1;
                done_ops += 1;
                progressed = true;
            }
        }
        if !progressed {
            let stuck = (0..d)
                .filter(|&dv| cursor[dv] < ops[dv].len())
                .map(|dv| (dv, cursor[dv], ops[dv][cursor[dv]].to_string()))
                .collect();
            return Err(SimError { stuck });
        }
    }

    for dev in 0..d {
        trace[dev].finish = now[dev];
    }
    let makespan = now.iter().cloned().fold(0.0, f64::max);
    Ok(SimTrace { devices: trace, makespan })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ClusterConfig, ParallelConfig, BERT_64};
    use crate::schedule::{
        build, placement_for, ScheduleConfig, ScheduleKind, SyncPolicy,
    };
    use crate::sim::CostModel;

    fn costs(kind: ScheduleKind, d: usize, n: usize) -> CostModel {
        let p = ParallelConfig::new(kind, 1, d, 4, n);
        CostModel::new(&BERT_64, &p, &ClusterConfig::paper_testbed(d))
    }

    fn run(kind: ScheduleKind, d: usize, n: usize) -> SimTrace {
        let s = build(&ScheduleConfig::new(kind, d, n)).unwrap();
        simulate_schedule(&s, &costs(kind, d, n)).unwrap()
    }

    #[test]
    fn all_kinds_simulate_clean() {
        for kind in ScheduleKind::ALL {
            for n in [4usize, 8] {
                let t = run(kind, 4, n);
                assert!(t.makespan > 0.0, "{kind} N={n}");
            }
        }
    }

    #[test]
    fn makespan_at_least_critical_path() {
        // Lower bound: every device must run its own compute serially.
        let kind = ScheduleKind::BitPipe;
        let c = costs(kind, 8, 8);
        let t = run(kind, 8, 8);
        for dev in &t.devices {
            assert!(t.makespan + 1e-12 >= dev.compute_busy);
        }
        // Ideal compute per device: N * v chunks fwd+bwd.
        let ideal = 8.0 * 2.0 * (c.chunk_fwd + c.chunk_bwd);
        assert!(t.makespan >= ideal, "{} < {ideal}", t.makespan);
    }

    #[test]
    fn eager_hides_allreduce_better_than_lazy() {
        // Table 5 w/o E: lazy sync exposes the collectives on the critical
        // path; eager hides them inside bubbles/compute. The effect is
        // large when the collective is expensive (data parallelism over
        // IB); on a single NVLink node the paper itself measures only ~1%.
        let kind = ScheduleKind::BitPipe;
        let eager = build(&ScheduleConfig::new(kind, 8, 8).with_sync(SyncPolicy::Eager)).unwrap();
        let lazy = build(&ScheduleConfig::new(kind, 8, 8).with_sync(SyncPolicy::Lazy)).unwrap();

        // Multi-node: W=4 data parallelism, allreduce group of 8 on IB.
        let p = ParallelConfig::new(kind, 4, 8, 4, 8);
        let mut cluster = ClusterConfig::paper_testbed(32);
        cluster.mapping = crate::config::MappingPolicy::PipesTogether; // allreduce on IB
        let c = CostModel::new(&BERT_64, &p, &cluster);
        let te = simulate_schedule(&eager, &c).unwrap();
        let tl = simulate_schedule(&lazy, &c).unwrap();
        assert!(
            te.makespan < tl.makespan,
            "multi-node: eager {} not faster than lazy {}",
            te.makespan,
            tl.makespan
        );

        // Single node: eager must never be slower (beyond launch noise).
        let c1 = costs(kind, 8, 8);
        let te1 = simulate_schedule(&eager, &c1).unwrap();
        let tl1 = simulate_schedule(&lazy, &c1).unwrap();
        assert!(
            te1.makespan <= tl1.makespan + 1e-4,
            "single-node: eager {} slower than lazy {}",
            te1.makespan,
            tl1.makespan
        );
    }

    #[test]
    fn v_shape_spends_less_time_on_p2p_than_looping() {
        let tv = run(ScheduleKind::VShaped, 4, 8);
        let tl = run(ScheduleKind::Interleaved, 4, 8);
        let sends_v: usize = tv.devices.iter().map(|d| d.sends).sum();
        let sends_l: usize = tl.devices.iter().map(|d| d.sends).sum();
        assert!(sends_v < sends_l);
        let copies_v: usize = tv.devices.iter().map(|d| d.local_copies).sum();
        assert!(copies_v > 0);
    }

    #[test]
    fn deadlock_reported_not_hung() {
        // Remove one send: the matching recv must deadlock, reported as Err.
        let kind = ScheduleKind::Dapple;
        let mut s = build(&ScheduleConfig::new(kind, 4, 4)).unwrap();
        let idx = s.device_ops[0]
            .iter()
            .position(|i| matches!(i, Instr::SendAct { .. }))
            .unwrap();
        s.device_ops[0].remove(idx);
        let e = simulate_schedule(&s, &costs(kind, 4, 4)).unwrap_err();
        assert!(!e.stuck.is_empty());
    }

    /// Hand-built two-device schedule sending the same tag twice.
    fn duplicate_send_schedule() -> Schedule {
        let placement = placement_for(ScheduleKind::Dapple, 2, 1);
        let cfg = ScheduleConfig::new(ScheduleKind::Dapple, 2, 2);
        let device_ops = vec![
            vec![
                Instr::SendAct { to: 1, pipe: 0, stage: 0, mb: 0 },
                Instr::SendAct { to: 1, pipe: 0, stage: 0, mb: 0 },
            ],
            vec![
                Instr::RecvAct { from: 0, pipe: 0, stage: 1, mb: 0 },
                Instr::RecvAct { from: 0, pipe: 0, stage: 1, mb: 0 },
            ],
        ];
        Schedule {
            cfg,
            placement,
            compute_order: vec![Vec::new(), Vec::new()],
            device_ops,
            pipe_of_mb: vec![0, 0],
        }
    }

    #[test]
    fn duplicate_sends_pair_fifo() {
        // Two in-flight messages under one tag: the FIFO engine pairs both
        // with their receives in send order; the reference executor's
        // HashMap clobbers the first arrival and deadlocks the second recv.
        let s = duplicate_send_schedule();
        let c = costs(ScheduleKind::Dapple, 2, 2);
        let t = simulate_schedule(&s, &c).unwrap();
        // Receiver consumed both; its finish is at least the second
        // message's arrival (two launches + transfer).
        assert_eq!(t.devices[0].sends, 2);
        assert!(t.devices[1].finish >= 2.0 * LAUNCH + c.p2p_time(0, 1));
        let e = simulate_schedule_reference(&s, &c).unwrap_err();
        assert!(!e.stuck.is_empty(), "reference should drop the duplicate and deadlock");
    }

    #[test]
    fn solo_transfer_contended_matches_fixed_duration_bitwise() {
        // A flow that never shares its link must complete at exactly the
        // fixed-duration arrival — the degradation guarantee the
        // differential suite relies on. (The bandwidth-*sharing* scenarios
        // live in rust/tests/contention.rs.)
        let placement = placement_for(ScheduleKind::Dapple, 4, 1);
        let cfg = ScheduleConfig::new(ScheduleKind::Dapple, 4, 4);
        let s = Schedule {
            cfg,
            placement,
            compute_order: vec![Vec::new(); 4],
            device_ops: vec![
                vec![Instr::SendAct { to: 2, pipe: 0, stage: 0, mb: 0 }],
                Vec::new(),
                vec![Instr::RecvAct { from: 0, pipe: 0, stage: 1, mb: 0 }],
                Vec::new(),
            ],
            pipe_of_mb: vec![0, 0, 0, 0],
        };
        let p = ParallelConfig::new(ScheduleKind::Dapple, 1, 4, 4, 4);
        let cluster = ClusterConfig { n_devices: 4, devices_per_node: 2, ..Default::default() };
        let c = CostModel::new(&BERT_64, &p, &cluster);
        let off = simulate_schedule(&s, &c).unwrap();
        let on = simulate_schedule_with(&s, &c, true).unwrap();
        assert_eq!(on.makespan.to_bits(), off.makespan.to_bits());
        for (a, b) in on.devices.iter().zip(&off.devices) {
            assert_eq!(a.finish.to_bits(), b.finish.to_bits());
            assert_eq!(a.recv_blocked.to_bits(), b.recv_blocked.to_bits());
        }
    }

    #[test]
    fn incremental_and_global_settlement_agree() {
        // Quick in-module sanity (the dense grid lives in
        // rust/tests/network_equiv.rs): on a real contended schedule the
        // default incremental network agrees with the global oracle to
        // f.p. rounding, and both are deterministic.
        let kind = ScheduleKind::BitPipe;
        let s = build(&ScheduleConfig::new(kind, 8, 16)).unwrap();
        let p = ParallelConfig::new(kind, 2, 8, 4, 16);
        let c = CostModel::new(&BERT_64, &p, &ClusterConfig::paper_testbed(16));
        for mode in [Contention::P2pOnly, Contention::Full] {
            let inc = simulate_schedule_network(&s, &c, mode, NetworkImpl::Incremental).unwrap();
            let glo = simulate_schedule_network(&s, &c, mode, NetworkImpl::Global).unwrap();
            let rel = (inc.makespan - glo.makespan).abs() / glo.makespan.max(1e-12);
            assert!(
                rel <= 1e-9,
                "{mode:?}: incremental {} vs global {} (rel {rel:.3e})",
                inc.makespan,
                glo.makespan
            );
            let inc2 = simulate_schedule_network(&s, &c, mode, NetworkImpl::Incremental).unwrap();
            assert_eq!(inc.makespan.to_bits(), inc2.makespan.to_bits());
        }
        // Default plumbing: the contended entry points run Incremental.
        let via_default = simulate_schedule_with(&s, &c, true).unwrap();
        let via_knob =
            simulate_schedule_network(&s, &c, Contention::Full, NetworkImpl::Incremental)
                .unwrap();
        assert_eq!(via_default.makespan.to_bits(), via_knob.makespan.to_bits());
    }

    #[test]
    fn entry_stage_recv_reports_deadlock_not_panic() {
        // A malformed stage-0 RecvAct must surface as SimError (debug
        // builds used to panic on the stage-1 underflow).
        let placement = placement_for(ScheduleKind::Dapple, 2, 1);
        let cfg = ScheduleConfig::new(ScheduleKind::Dapple, 2, 2);
        let s = Schedule {
            cfg,
            placement,
            compute_order: vec![Vec::new(), Vec::new()],
            device_ops: vec![
                vec![Instr::RecvAct { from: 1, pipe: 0, stage: 0, mb: 0 }],
                Vec::new(),
            ],
            pipe_of_mb: vec![0, 0],
        };
        let c = costs(ScheduleKind::Dapple, 2, 2);
        for result in [simulate_schedule(&s, &c), simulate_schedule_reference(&s, &c)] {
            let e = result.unwrap_err();
            assert_eq!(e.stuck.len(), 1);
            assert_eq!(e.stuck[0].0, 0);
        }
    }

    #[test]
    fn two_iterations_reuse_allreduce_state() {
        // The per-(stage, round) collective state must keep later
        // iterations' AllReduceWait honest instead of matching the first
        // iteration's completion. Lazy sync over an expensive IB collective
        // puts the full allreduce on every iteration's critical path, so a
        // stale (single-shot) completion would make iteration 2+ visibly
        // cheaper than iteration 1.
        let kind = ScheduleKind::BitPipe;
        let s = build(&ScheduleConfig::new(kind, 4, 4).with_sync(SyncPolicy::Lazy)).unwrap();
        let p = ParallelConfig::new(kind, 4, 4, 4, 4);
        let mut cluster = ClusterConfig::paper_testbed(16);
        cluster.mapping = crate::config::MappingPolicy::PipesTogether; // allreduce on IB
        let c = CostModel::new(&BERT_64, &p, &cluster);
        let one = simulate_schedule(&s, &c).unwrap();
        let multi = simulate_schedule_iters(&s, &c, 3).unwrap();
        assert_eq!(multi.iter_finish.len(), 3);
        let times = multi.iter_times();
        for (k, &t) in times.iter().enumerate().skip(1) {
            assert!(
                t >= 0.9 * times[0] && t <= 1.1 * times[0],
                "iteration {k} time {t} vs first {}",
                times[0]
            );
        }
        assert!(
            multi.makespan > 2.5 * one.makespan,
            "3-iteration makespan {} vs single {}",
            multi.makespan,
            one.makespan
        );
        // Aggregate accounting covers all iterations.
        let blocked: f64 = multi.devices.iter().map(|d| d.allreduce_blocked).sum();
        let blocked_one: f64 = one.devices.iter().map(|d| d.allreduce_blocked).sum();
        assert!(
            blocked > 2.0 * blocked_one,
            "multi-iter allreduce blocking {blocked} vs single {blocked_one}"
        );
    }

    #[test]
    fn repeated_runs_are_bit_identical() {
        let kind = ScheduleKind::BitPipe;
        let s = build(&ScheduleConfig::new(kind, 8, 16)).unwrap();
        let c = costs(kind, 8, 16);
        let a = simulate_schedule(&s, &c).unwrap();
        let b = simulate_schedule(&s, &c).unwrap();
        assert_eq!(a.makespan.to_bits(), b.makespan.to_bits());
        for (da, db) in a.devices.iter().zip(&b.devices) {
            assert_eq!(da.finish.to_bits(), db.finish.to_bits());
            assert_eq!(da.recv_blocked.to_bits(), db.recv_blocked.to_bits());
            assert_eq!(da.allreduce_blocked.to_bits(), db.allreduce_blocked.to_bits());
        }
    }

    #[test]
    fn matches_reference_executor_on_valid_schedules() {
        for kind in ScheduleKind::ALL {
            for n in [4usize, 8] {
                let s = build(&ScheduleConfig::new(kind, 4, n)).unwrap();
                let c = costs(kind, 4, n);
                let new = simulate_schedule(&s, &c).unwrap();
                let old = simulate_schedule_reference(&s, &c).unwrap();
                assert!(
                    (new.makespan - old.makespan).abs() <= 1e-9 * old.makespan.max(1e-12),
                    "{kind} N={n}: event-queue {} vs reference {}",
                    new.makespan,
                    old.makespan
                );
            }
        }
    }
}
