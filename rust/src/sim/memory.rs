//! Static memory accounting per device (paper Fig 8 and Table 2):
//! weights (+ grads + optimizer state) for every chunk a device holds, and
//! peak activation stash measured from the schedule's compute order.
//!
//! Liveness rule: a stash slot is born at each `F` and freed at the
//! matching fused `B`. Under a split backward, `Bi` (activation grad) is
//! memory-neutral — the slot transitions to a weight-grad pin that lives
//! until the matching deferred `W` frees it. Every stash walk in the
//! codebase (here, `schedule::analysis`, `schedule::lint`, the DAG
//! compiler's `peak_stash`, and the Python mirror) implements this same
//! single-counter rule: `F` +1, `B`/`W` −1, `Bi` 0.

use crate::config::{ModelConfig, ParallelConfig};
use crate::schedule::{OpKind, Schedule};

/// Per-device memory footprint, bytes.
#[derive(Debug, Clone)]
pub struct MemoryFootprint {
    /// Model weights held (both pipes for bidirectional schedules).
    pub weights: Vec<u64>,
    /// Gradient buffers (same layout as weights).
    pub grads: Vec<u64>,
    /// Optimizer state (Adam: fp32 master + two fp32 moments).
    pub optim: Vec<u64>,
    /// Peak activation stash over the iteration.
    pub activations: Vec<u64>,
}

impl MemoryFootprint {
    /// Total peak per device.
    pub fn total_peak(&self) -> Vec<u64> {
        (0..self.weights.len())
            .map(|i| self.weights[i] + self.grads[i] + self.optim[i] + self.activations[i])
            .collect()
    }

    /// Max-minus-min spread of the per-device totals (Fig 8's balance
    /// metric: narrower is better).
    pub fn spread(&self) -> u64 {
        let t = self.total_peak();
        let max = t.iter().copied().max().unwrap_or(0);
        let min = t.iter().copied().min().unwrap_or(0);
        max - min
    }

    /// Mean of per-device totals.
    pub fn mean(&self) -> f64 {
        let t = self.total_peak();
        if t.is_empty() {
            return 0.0;
        }
        t.iter().sum::<u64>() as f64 / t.len() as f64
    }
}

/// Compute the footprint of `schedule` for `model` under `parallel`.
pub fn memory_footprint(
    s: &Schedule,
    model: &ModelConfig,
    parallel: &ParallelConfig,
) -> MemoryFootprint {
    let d = s.n_devices();
    let held: Vec<u32> = s.placement.chunks_on.iter().map(|c| c.len() as u32).collect();
    // Peak stash in chunk units from the compute order.
    let mut peaks = vec![0u32; d];
    for dev in 0..d {
        let mut depth = 0i64;
        let mut peak = 0i64;
        for op in &s.compute_order[dev] {
            match op.kind {
                OpKind::Forward => depth += 1,
                OpKind::Backward | OpKind::BackwardWeight => depth -= 1,
                // Bi's stash slot survives as a weight-grad pin until W.
                OpKind::BackwardInput => {}
            }
            peak = peak.max(depth);
        }
        peaks[dev] = peak.max(0) as u32;
    }
    memory_footprint_from_counts(&held, &peaks, model, parallel)
}

/// Footprint from schedule-structure counts alone: `held_chunks[dev]` =
/// chunks hosted, `peak_stash[dev]` = peak activation stash depth in chunk
/// units. This is what the compiled-DAG grid path uses to re-cost memory
/// for a new (W, B) without rebuilding the `Schedule`; bit-identical to
/// [`memory_footprint`] on the schedule the counts came from.
pub fn memory_footprint_from_counts(
    held_chunks: &[u32],
    peak_stash: &[u32],
    model: &ModelConfig,
    parallel: &ParallelConfig,
) -> MemoryFootprint {
    let d = held_chunks.len();
    // Stages per pipeline replica (the placement's n_stages()).
    let chunks = (parallel.v * parallel.d).max(1);
    let layers_per_chunk = (model.n_layers + chunks - 1) / chunks;
    let chunk_param_bytes =
        model.params_per_layer() * layers_per_chunk as u64 * model.dtype_bytes as u64;
    // Adam on mixed precision: fp32 master + 2 fp32 moments = 12 bytes per
    // parameter regardless of compute dtype.
    let chunk_optim_bytes = model.params_per_layer() * layers_per_chunk as u64 * 12;
    let chunk_act_bytes = model.layer_activation_bytes(parallel.b) * layers_per_chunk as u64;

    let mut weights = vec![0u64; d];
    let mut grads = vec![0u64; d];
    let mut optim = vec![0u64; d];
    let mut activations = vec![0u64; d];
    for dev in 0..d {
        let held = held_chunks[dev] as u64;
        weights[dev] = held * chunk_param_bytes;
        grads[dev] = held * chunk_param_bytes;
        optim[dev] = held * chunk_optim_bytes;
        activations[dev] = peak_stash[dev] as u64 * chunk_act_bytes;
    }

    MemoryFootprint { weights, grads, optim, activations }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ParallelConfig, BERT_64};
    use crate::schedule::{build, ScheduleConfig, ScheduleKind};

    fn fp(kind: ScheduleKind, d: usize, n: usize, b: usize) -> MemoryFootprint {
        let s = build(&ScheduleConfig::new(kind, d, n)).unwrap();
        let p = ParallelConfig::new(kind, 1, d, b, n);
        memory_footprint(&s, &BERT_64, &p)
    }

    #[test]
    fn bidirectional_doubles_weights() {
        let dap = fp(ScheduleKind::Dapple, 8, 8, 4);
        let bit = fp(ScheduleKind::BitPipe, 8, 8, 4);
        // Every device: BitPipe holds 2x the weight bytes of DAPPLE
        // (2 pipes x v chunks of 1/v size each).
        for dev in 0..8 {
            assert_eq!(bit.weights[dev], 2 * dap.weights[dev], "dev {dev}");
        }
    }

    #[test]
    fn dapple_first_device_heaviest_activations() {
        // Fig 8a: DAPPLE's device 0 stashes D micro-batches, device D-1
        // stashes 1 — the most imbalanced profile.
        let dap = fp(ScheduleKind::Dapple, 8, 8, 4);
        assert!(dap.activations[0] > dap.activations[7]);
        assert_eq!(dap.activations[0], 8 * dap.activations[7]);
    }

    #[test]
    fn bitpipe_narrower_spread_than_dapple() {
        let dap = fp(ScheduleKind::Dapple, 8, 8, 4);
        let bit = fp(ScheduleKind::BitPipe, 8, 8, 4);
        assert!(
            bit.spread() < dap.spread(),
            "BitPipe spread {} !< DAPPLE {}",
            bit.spread(),
            dap.spread()
        );
    }

    #[test]
    fn gpipe_activations_grow_with_n() {
        let n8 = fp(ScheduleKind::GPipe, 4, 8, 4);
        let n16 = fp(ScheduleKind::GPipe, 4, 16, 4);
        assert!(n16.activations[0] > n8.activations[0]);
        // DAPPLE stays flat in N.
        let d8 = fp(ScheduleKind::Dapple, 4, 8, 4);
        let d16 = fp(ScheduleKind::Dapple, 4, 16, 4);
        assert_eq!(d8.activations[0], d16.activations[0]);
    }

    #[test]
    fn counts_based_footprint_matches_schedule_based() {
        // The DAG grid path re-costs memory from structure counts alone;
        // it must agree exactly with the schedule-walking computation.
        for (kind, d, n) in [
            (ScheduleKind::Dapple, 8usize, 8usize),
            (ScheduleKind::BitPipe, 4, 8),
            (ScheduleKind::Interleaved, 4, 16),
        ] {
            let s = build(&ScheduleConfig::new(kind, d, n)).unwrap();
            let p = ParallelConfig::new(kind, 2, d, 4, n);
            let want = memory_footprint(&s, &BERT_64, &p);
            let held: Vec<u32> =
                s.placement.chunks_on.iter().map(|c| c.len() as u32).collect();
            let peaks: Vec<u32> = s
                .compute_order
                .iter()
                .map(|ops| {
                    let (mut depth, mut peak) = (0i64, 0i64);
                    for op in ops {
                        depth += match op.kind {
                            OpKind::Forward => 1,
                            OpKind::Backward | OpKind::BackwardWeight => -1,
                            OpKind::BackwardInput => 0,
                        };
                        peak = peak.max(depth);
                    }
                    peak.max(0) as u32
                })
                .collect();
            let got = memory_footprint_from_counts(&held, &peaks, &BERT_64, &p);
            assert_eq!(got.total_peak(), want.total_peak(), "{kind}");
        }
    }

    #[test]
    fn totals_are_sums() {
        let bit = fp(ScheduleKind::BitPipe, 4, 4, 4);
        let t = bit.total_peak();
        for dev in 0..4 {
            assert_eq!(
                t[dev],
                bit.weights[dev] + bit.grads[dev] + bit.optim[dev] + bit.activations[dev]
            );
        }
        assert!(bit.mean() > 0.0);
    }
}
