//! Schedule compiler: lowers a [`Schedule`] into a flat, arena-indexed
//! dependence DAG evaluated by a weighted longest-path pass — the fast
//! uncontended backend behind [`crate::sim::Engine::Dag`].
//!
//! # Structure / weight split
//!
//! The compiled graph separates what depends on the *schedule* from what
//! depends on the *cost model*:
//!
//! * **Structure** — nodes (one per instruction, plus one synthetic
//!   barrier node per collective round), edges (intra-device program
//!   order, send→recv message edges paired FIFO per tag, member-start →
//!   barrier → wait edges, and per-device comm-engine chains between
//!   successive barriers), and one precomputed topological order. This
//!   depends only on the schedule shape (kind, D, N, v, sync,
//!   early-forward) — never on W, B, or the cluster.
//! * **Weights** — a small table ([`DagWeights`], `3 + D² + 2·stages`
//!   entries) holding per-class costs read from a [`CostModel`]. Each node
//!   carries a class index into this table.
//!
//! `grid_search` exploits the split with a compile-once/re-cost-many
//! cache: grid points (and whole sweeps) sharing a structure borrow the
//! same [`CompiledDag`] and pay only a table rebuild plus one linear
//! evaluation pass — no `BinaryHeap`, no hashing, no per-message
//! allocation. [`CompiledDag::evaluate_batch`] goes one step further and
//! prices k weight tables in a single traversal (SoA `[k]`-lane time
//! vectors, bit-identical per lane to a solo run), and
//! [`DagWeights::rebuild_for_batch_size`] makes the common sweep move —
//! only B changes — a handful of table writes instead of a [`CostModel`]
//! reconstruction.
//!
//! # Exact equivalence with the event engine
//!
//! With `contention: false` the event engine is deterministic dataflow:
//! every instruction's completion time is a max/+ function of its
//! predecessors' times. Evaluating the nodes in *any* topological order
//! with the same primitive operations therefore reproduces the engine's
//! virtual times **bit for bit** (`f64` max is exact; the per-device add
//! chains are replayed in program order). `rust/tests/dag_equiv.rs` pins
//! this across every schedule family, single- and multi-iteration.
//!
//! Collective serialization is the one place the engine's semantics are
//! order-sensitive: concurrent collectives sharing a device queue on its
//! comm engine in the order they are *priced*. For `comm_pass`-generated
//! streams that order coincides with per-device program order of the
//! `AllReduceStart`s (both existing executors agree on it — the
//! `engine_equiv` differential suite would catch a divergence), so the
//! compiler serializes barriers with per-device chain edges. If a
//! hand-built schedule orders starts inconsistently across devices the
//! chain edges form a cycle; the compiler detects this and returns
//! [`DagUnsupported`] so callers can fall back to the event engine
//! instead of reporting a false deadlock.
//!
//! # Multi-iteration unrolling
//!
//! `k` iterations evaluate as `k` passes over the *same* node arena: all
//! cross-iteration dependencies funnel through carried per-device state
//! (the device clock and the comm-engine chain), because message tags
//! pair within their own iteration and collective rounds restart each
//! iteration. This requires every message tag to have equal send/recv
//! counts per iteration (true for all generated schedules);
//! [`CompiledDag::multi_iter_safe`] reports whether the precondition
//! holds so callers can fall back otherwise.

use super::cost::{BatchPricing, CostModel};
use super::engine::{DeviceTrace, MultiIterTrace, SimError, LAUNCH};
use crate::schedule::{Instr, OpKind, Schedule};
use std::fmt;

/// Message key, identical to the event engine's FIFO tag:
/// (from, to, is_grad, pipe, producer_stage, mb).
type MsgKey = (usize, usize, bool, usize, usize, usize);

/// The schedule's structure cannot be expressed as a static DAG (devices
/// disagree on the serialization order of shared collectives). Fall back
/// to the event engine; never produced for `comm_pass`-generated streams.
#[derive(Debug)]
pub struct DagUnsupported(pub String);

impl fmt::Display for DagUnsupported {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "schedule not DAG-compilable: {}", self.0)
    }
}

impl std::error::Error for DagUnsupported {}

/// Node semantics; cost classes live in the parallel `wclass` array.
#[derive(Debug, Clone, Copy)]
enum NodeOp {
    /// Forward/Backward: busy time, counted as compute.
    Compute,
    /// Local HBM copy: busy time, counted in `local_copies`.
    LocalCopy,
    /// Optimizer step: busy time.
    Optim,
    /// Async send: pay `LAUNCH`, deposit arrival into `msg` slot.
    Send { msg: u32 },
    /// Receive: clock joins the matched arrival slot.
    Recv { msg: u32 },
    /// Non-member `AllReduceStart`: pays `LAUNCH` only (engine parity).
    Launch,
    /// Member `AllReduceStart`: pays `LAUNCH`, records its launch time.
    ArStart { coll: u32 },
    /// Synthetic pricing node: fires once all member starts (and the
    /// members' previous barriers) evaluated; computes the completion.
    Barrier { coll: u32 },
    /// `AllReduceWait`: clock joins the collective's completion.
    ArWait { coll: u32 },
}

/// A schedule lowered to a dependence DAG: structure only — re-costable
/// against any [`CostModel`] via [`CompiledDag::weights`].
#[derive(Debug, Clone)]
pub struct CompiledDag {
    d: usize,
    n_stages: usize,
    /// Per-node device (real nodes only; barriers hold `u32::MAX`).
    dev: Vec<u32>,
    op: Vec<NodeOp>,
    /// Per-node index into the weight table.
    wclass: Vec<u32>,
    /// Per-node model stage (compute nodes only; 0 elsewhere). Consulted
    /// when a weight table carries per-(device, stage) compute scales.
    stage: Vec<u32>,
    /// Complete topological order (empty when `stuck` is non-empty).
    topo: Vec<u32>,
    /// Collective member devices, flattened (`members_off` delimits).
    members: Vec<u32>,
    members_off: Vec<u32>,
    n_msgs: usize,
    n_colls: usize,
    n_wclasses: usize,
    /// Stages of `OptimStep`s beyond `n_stages` (hand-built streams);
    /// their costs append to the weight table after the fixed layout.
    extra_optim: Vec<usize>,
    /// Deadlocked (device, instruction index, instruction) triples — the
    /// schedule can never complete; evaluation reports them as the event
    /// engine would.
    stuck: Vec<(usize, usize, String)>,
    /// Every message tag has equal send/recv counts per iteration, the
    /// precondition for multi-iteration unrolling.
    multi_iter_safe: bool,
    /// Chunks held per device (memory re-costing without the `Schedule`).
    held_chunks: Vec<u32>,
    /// Peak activation-stash depth per device, in chunk units.
    peak_stash: Vec<u32>,
}

/// Weight-table layout offsets.
const W_FWD: u32 = 0;
const W_BWD: u32 = 1;
const W_COPY: u32 = 2;
const W_BI: u32 = 3;
const W_WGT: u32 = 4;
const W_P2P: u32 = 5;

/// Per-class costs for one (model, parallel, cluster) point, read by the
/// evaluation pass. Rebuilding this table is the *entire* cost of
/// re-pricing a borrowed [`CompiledDag`] for a new grid point.
#[derive(Debug, Clone)]
pub struct DagWeights {
    tab: Vec<f64>,
    /// Per-node compute-time multipliers for heterogeneous clusters /
    /// non-uniform layer profiles: entry `i` scales node `i`'s class cost
    /// (1.0 for non-compute nodes). `None` for uniform cost models — the
    /// evaluation passes then take the historical arithmetic verbatim, so
    /// the uniform case stays bit-identical (`rust/tests/hetero_identity.rs`).
    node_scale: Option<Vec<f64>>,
}

impl DagWeights {
    /// Re-price this table for a different micro-batch size: overwrite the
    /// B-dependent entries (compute classes, local copy, the D² P2P block)
    /// from `bp` and keep the optimizer / all-reduce tail, which is
    /// B-independent. Bit-identical to a full [`CompiledDag::weights`]
    /// rebuild at the new B (pinned in `rust/tests/dag_equiv.rs`), without
    /// reconstructing a [`CostModel`] — the common sweep move, priced
    /// straight off the hoisted [`super::LinkTopology`].
    ///
    /// `self` must have been built by `weights` for the same structure,
    /// model, W, and cluster, with only B differing, and `bp` by
    /// [`super::LinkTopology::batch_pricing`] over that structure's depth.
    /// Per-node compute scales (`node_scale`) are B-independent — the
    /// device/stage multipliers carry over unchanged.
    pub fn rebuild_for_batch_size(&mut self, bp: &BatchPricing) {
        let dd = bp.p2p.len();
        assert!(
            self.tab.len() >= W_P2P as usize + dd,
            "pricing built for a different pipeline depth"
        );
        self.tab[W_FWD as usize] = bp.chunk_fwd;
        self.tab[W_BWD as usize] = bp.chunk_bwd;
        self.tab[W_COPY as usize] = bp.local_copy;
        self.tab[W_BI as usize] = bp.chunk_bwd_input;
        self.tab[W_WGT as usize] = bp.chunk_bwd_weight;
        self.tab[W_P2P as usize..W_P2P as usize + dd].copy_from_slice(&bp.p2p);
    }

    /// The raw weight table (layout: 5 compute/copy classes, D² P2P block,
    /// per-stage optimizer then all-reduce entries, extra optimizer tail).
    /// Exposed for differential tests and the Python mirror.
    pub fn table(&self) -> &[f64] {
        &self.tab
    }

    /// Per-node compute scales, present only for heterogeneous cost
    /// models. Exposed for differential tests and the Python mirror.
    pub fn node_scale(&self) -> Option<&[f64]> {
        self.node_scale.as_deref()
    }
}

/// Transient per-collective info gathered while walking the streams.
struct CollBuild {
    stage: usize,
    starts: Vec<u32>,
    waits: Vec<u32>,
}

/// Collective id for (stage, round), creating rounds densely on demand.
fn coll_id(
    colls: &mut Vec<CollBuild>,
    coll_of: &mut [Vec<u32>],
    stage: usize,
    round: usize,
) -> u32 {
    while coll_of[stage].len() <= round {
        coll_of[stage].push(colls.len() as u32);
        colls.push(CollBuild { stage, starts: Vec::new(), waits: Vec::new() });
    }
    coll_of[stage][round]
}

impl CompiledDag {
    /// Lower `s` into a dependence DAG. Errors only when the collective
    /// serialization order is inconsistent across devices (impossible for
    /// `comm_pass` output) — callers should fall back to the event
    /// engine. Genuine deadlocks (an unmatched receive, a collective a
    /// member never starts) compile fine and surface from
    /// [`CompiledDag::evaluate`] exactly like the event engine.
    pub fn compile(s: &Schedule) -> Result<CompiledDag, DagUnsupported> {
        let d = s.n_devices();
        assert!(!s.device_ops.is_empty(), "schedule has no device_ops; run comm_pass first");
        let n_stages = s.placement.n_stages();
        let groups: Vec<Vec<usize>> =
            (0..n_stages).map(|st| s.placement.allreduce_group(st)).collect();

        // Arena layout: device streams back to back, barriers appended.
        let mut base = vec![0u32; d + 1];
        for dv in 0..d {
            base[dv + 1] = base[dv] + s.device_ops[dv].len() as u32;
        }
        let n_real = base[d] as usize;

        let mut dev = vec![u32::MAX; n_real];
        let mut op = Vec::with_capacity(n_real);
        let mut wclass = vec![0u32; n_real];
        let mut stage_of = vec![0u32; n_real];
        let w_optim_base = W_P2P + (d * d) as u32;
        let w_ar_base = w_optim_base + n_stages as u32;
        let w_extra_base = w_ar_base + n_stages as u32;
        let mut extra_optim: Vec<usize> = Vec::new();

        let mut sends: Vec<(MsgKey, u32)> = Vec::new();
        let mut recvs: Vec<(MsgKey, u32)> = Vec::new();
        // Nodes that can never fire (entry-stage RecvAct, oversized-stage
        // waits, unmatched receives): carry a permanent extra indegree.
        let mut extra_indeg = vec![0u32; n_real];

        let mut colls: Vec<CollBuild> = Vec::new();
        let mut coll_of: Vec<Vec<u32>> = vec![Vec::new(); n_stages];
        let mut start_round = vec![0u32; d * n_stages];
        let mut wait_round = vec![0u32; d * n_stages];
        // Per-device comm-engine chains: successive member-start colls.
        let mut chain_prev: Vec<Option<u32>> = vec![None; d];
        let mut chains: Vec<(u32, u32)> = Vec::new();

        for dv in 0..d {
            for (ix, ins) in s.device_ops[dv].iter().enumerate() {
                let id = base[dv] + ix as u32;
                dev[id as usize] = dv as u32;
                let node = match *ins {
                    Instr::Forward { stage, .. } => {
                        wclass[id as usize] = W_FWD;
                        stage_of[id as usize] = stage as u32;
                        NodeOp::Compute
                    }
                    Instr::Backward { stage, .. } => {
                        wclass[id as usize] = W_BWD;
                        stage_of[id as usize] = stage as u32;
                        NodeOp::Compute
                    }
                    Instr::BackwardInput { stage, .. } => {
                        wclass[id as usize] = W_BI;
                        stage_of[id as usize] = stage as u32;
                        NodeOp::Compute
                    }
                    Instr::BackwardWeight { stage, .. } => {
                        wclass[id as usize] = W_WGT;
                        stage_of[id as usize] = stage as u32;
                        NodeOp::Compute
                    }
                    Instr::LocalCopyAct { .. } | Instr::LocalCopyGrad { .. } => {
                        wclass[id as usize] = W_COPY;
                        NodeOp::LocalCopy
                    }
                    Instr::SendAct { to, pipe, stage, mb } => {
                        sends.push(((dv, to, false, pipe, stage, mb), id));
                        wclass[id as usize] = W_P2P + (dv * d + to) as u32;
                        NodeOp::Send { msg: u32::MAX }
                    }
                    Instr::SendGrad { to, pipe, stage, mb } => {
                        sends.push(((dv, to, true, pipe, stage, mb), id));
                        wclass[id as usize] = W_P2P + (dv * d + to) as u32;
                        NodeOp::Send { msg: u32::MAX }
                    }
                    Instr::RecvAct { from, pipe, stage, mb } => {
                        // Producer tagged with stage-1; a stage-0 RecvAct
                        // has no producer and parks forever (engine parity).
                        match stage.checked_sub(1) {
                            Some(p) => recvs.push(((from, dv, false, pipe, p, mb), id)),
                            None => extra_indeg[id as usize] += 1,
                        }
                        NodeOp::Recv { msg: u32::MAX }
                    }
                    Instr::RecvGrad { from, pipe, stage, mb } => {
                        recvs.push(((from, dv, true, pipe, stage + 1, mb), id));
                        NodeOp::Recv { msg: u32::MAX }
                    }
                    Instr::AllReduceStart { stage } => {
                        // Indexing mirrors the engine's `groups[stage]`
                        // panic on out-of-range hand-built stages.
                        let group = &groups[stage];
                        let r = &mut start_round[dv * n_stages + stage];
                        let round = *r as usize;
                        *r += 1;
                        if group.contains(&dv) {
                            let c = coll_id(&mut colls, &mut coll_of, stage, round);
                            colls[c as usize].starts.push(id);
                            if let Some(prev) = chain_prev[dv].replace(c) {
                                chains.push((prev, c));
                            }
                            NodeOp::ArStart { coll: c }
                        } else {
                            NodeOp::Launch
                        }
                    }
                    Instr::AllReduceWait { stage } => {
                        if stage >= n_stages {
                            // No such collective can ever complete.
                            extra_indeg[id as usize] += 1;
                            NodeOp::ArWait { coll: u32::MAX }
                        } else {
                            let r = &mut wait_round[dv * n_stages + stage];
                            let round = *r as usize;
                            *r += 1;
                            let c = coll_id(&mut colls, &mut coll_of, stage, round);
                            colls[c as usize].waits.push(id);
                            NodeOp::ArWait { coll: c }
                        }
                    }
                    Instr::OptimStep { stage } => {
                        wclass[id as usize] = if stage < n_stages {
                            w_optim_base + stage as u32
                        } else {
                            extra_optim.push(stage);
                            w_extra_base + (extra_optim.len() - 1) as u32
                        };
                        NodeOp::Optim
                    }
                };
                op.push(node);
            }
        }

        // Append one barrier node per collective.
        let n_colls = colls.len();
        let n_nodes = n_real + n_colls;
        let mut members: Vec<u32> = Vec::new();
        let mut members_off: Vec<u32> = Vec::with_capacity(n_colls + 1);
        members_off.push(0);
        dev.resize(n_nodes, u32::MAX);
        wclass.resize(n_nodes, 0);
        stage_of.resize(n_nodes, 0);
        extra_indeg.resize(n_nodes, 0);
        let bar = |c: u32| n_real as u32 + c;
        for (c, cb) in colls.iter().enumerate() {
            op.push(NodeOp::Barrier { coll: c as u32 });
            wclass[n_real + c] = w_ar_base + cb.stage as u32;
            members.extend(groups[cb.stage].iter().map(|&g| g as u32));
            members_off.push(members.len() as u32);
        }

        // Real dependence edges.
        let mut edges: Vec<(u32, u32)> = Vec::new();
        for dv in 0..d {
            for ix in 1..s.device_ops[dv].len() as u32 {
                edges.push((base[dv] + ix - 1, base[dv] + ix));
            }
        }
        // FIFO message pairing: j-th send of a tag feeds the j-th recv
        // (all sends of a tag come from one device, all recvs land on one,
        // so the arena-id order below is exactly program order).
        sends.sort_unstable();
        recvs.sort_unstable();
        let mut n_msgs = 0usize;
        let mut multi_iter_safe = true;
        let (mut si, mut ri) = (0usize, 0usize);
        while si < sends.len() || ri < recvs.len() {
            let key = match (sends.get(si), recvs.get(ri)) {
                (Some(&(sk, _)), Some(&(rk, _))) => sk.min(rk),
                (Some(&(sk, _)), None) => sk,
                (None, Some(&(rk, _))) => rk,
                (None, None) => unreachable!(),
            };
            let s0 = si;
            while si < sends.len() && sends[si].0 == key {
                si += 1;
            }
            let r0 = ri;
            while ri < recvs.len() && recvs[ri].0 == key {
                ri += 1;
            }
            let (sn, rn) = (si - s0, ri - r0);
            if sn != rn {
                multi_iter_safe = false;
            }
            for j in 0..sn.min(rn) {
                let (snode, rnode) = (sends[s0 + j].1, recvs[r0 + j].1);
                let m = n_msgs as u32;
                n_msgs += 1;
                if let NodeOp::Send { msg } = &mut op[snode as usize] {
                    *msg = m;
                }
                if let NodeOp::Recv { msg } = &mut op[rnode as usize] {
                    *msg = m;
                }
                edges.push((snode, rnode));
            }
            for &(_, rnode) in &recvs[r0 + sn.min(rn)..ri] {
                extra_indeg[rnode as usize] += 1; // recv whose send never happens
            }
        }
        // Sends no receive ever consumes still pay LAUNCH and deposit an
        // arrival somewhere; point them at a shared scratch slot so the
        // evaluation pass stays branch-free.
        for o in op.iter_mut() {
            if let NodeOp::Send { msg } = o {
                if *msg == u32::MAX {
                    *msg = n_msgs as u32;
                }
            }
        }
        // Collective edges: member starts feed the barrier (members that
        // never start leave a permanent indegree — the engine's deadlock),
        // the barrier feeds every wait.
        for (c, cb) in colls.iter().enumerate() {
            let b = bar(c as u32);
            for &snode in &cb.starts {
                edges.push((snode, b));
            }
            let group_len = (members_off[c + 1] - members_off[c]) as usize;
            extra_indeg[b as usize] += (group_len - cb.starts.len()) as u32;
            for &wnode in &cb.waits {
                edges.push((b, wnode));
            }
        }

        // Chain entries are collective ids; toposort consumes node-arena
        // ids, so map them onto the barrier nodes here.
        let chain_edges: Vec<(u32, u32)> =
            chains.iter().map(|&(a, b)| (bar(a), bar(b))).collect();
        let topo = toposort(n_nodes, &edges, Some(chain_edges.as_slice()), &extra_indeg);
        let (topo, stuck) = if topo.len() == n_nodes {
            (topo, Vec::new())
        } else {
            // Re-run on real deps only: the chains are a serialization
            // heuristic, not true dependencies, so they must not manufacture
            // deadlocks the engine would not have.
            let real = toposort(n_nodes, &edges, None, &extra_indeg);
            if real.len() == n_nodes {
                return Err(DagUnsupported(
                    "devices disagree on the serialization order of shared collectives"
                        .to_string(),
                ));
            }
            let mut reached = vec![false; n_nodes];
            for &nid in &real {
                reached[nid as usize] = true;
            }
            let mut stuck = Vec::new();
            for dv in 0..d {
                for ix in 0..s.device_ops[dv].len() {
                    if !reached[base[dv] as usize + ix] {
                        stuck.push((dv, ix, s.device_ops[dv][ix].to_string()));
                        break;
                    }
                }
            }
            (Vec::new(), stuck)
        };

        // Memory structure: chunks held and peak stash depth per device.
        let held_chunks: Vec<u32> =
            s.placement.chunks_on.iter().map(|c| c.len() as u32).collect();
        let peak_stash: Vec<u32> = s
            .compute_order
            .iter()
            .map(|ops| {
                let (mut depth, mut peak) = (0i64, 0i64);
                for o in ops {
                    depth += match o.kind {
                        OpKind::Forward => 1,
                        OpKind::Backward | OpKind::BackwardWeight => -1,
                        // Bi's stash slot survives as a weight-grad pin.
                        OpKind::BackwardInput => 0,
                    };
                    peak = peak.max(depth);
                }
                peak.max(0) as u32
            })
            .collect();

        Ok(CompiledDag {
            d,
            n_stages,
            dev,
            op,
            wclass,
            stage: stage_of,
            topo,
            members,
            members_off,
            n_msgs,
            n_colls,
            n_wclasses: w_extra_base as usize + extra_optim.len(),
            extra_optim,
            stuck,
            multi_iter_safe,
            held_chunks,
            peak_stash,
        })
    }

    /// Build the weight table pricing this structure under `costs`. This is
    /// the *entire* per-grid-point cost of reusing a compiled DAG.
    pub fn weights(&self, costs: &CostModel) -> DagWeights {
        assert_eq!(costs.d, self.d, "cost model built for a different pipeline depth");
        let d = self.d;
        let mut tab = vec![0.0f64; self.n_wclasses];
        tab[W_FWD as usize] = costs.chunk_fwd;
        tab[W_BWD as usize] = costs.chunk_bwd;
        tab[W_COPY as usize] = costs.local_copy_time();
        tab[W_BI as usize] = costs.chunk_bwd_input;
        tab[W_WGT as usize] = costs.chunk_bwd_weight;
        for a in 0..d {
            for b in 0..d {
                tab[W_P2P as usize + a * d + b] = costs.p2p_time(a, b);
            }
        }
        let ob = W_P2P as usize + d * d;
        let ab = ob + self.n_stages;
        for st in 0..self.n_stages {
            tab[ob + st] = costs.optim_time(st);
            tab[ab + st] = costs.allreduce_time(st);
        }
        let eb = ab + self.n_stages;
        for (i, &st) in self.extra_optim.iter().enumerate() {
            tab[eb + i] = costs.optim_time(st);
        }
        // Heterogeneous compute (stragglers / layer profiles): one scale
        // per node, priced once here so the evaluation passes stay a table
        // lookup plus one multiply. Uniform models skip the whole row.
        let node_scale = (!costs.uniform_compute()).then(|| {
            self.op
                .iter()
                .enumerate()
                .map(|(i, o)| match o {
                    NodeOp::Compute => {
                        costs.compute_scale(self.dev[i] as usize, self.stage[i] as usize)
                    }
                    _ => 1.0,
                })
                .collect()
        });
        DagWeights { tab, node_scale }
    }

    /// Weighted longest-path evaluation: one linear pass over the
    /// precomputed topological order per iteration — no heap, no hashing.
    /// Bit-identical to the uncontended event engine
    /// ([`super::engine::simulate_schedule_iters_with`] with
    /// `contention: false`) on every schedule this module can compile.
    pub fn evaluate(&self, w: &DagWeights, iters: usize) -> Result<MultiIterTrace, SimError> {
        assert!(iters >= 1, "need at least one iteration");
        assert!(
            iters == 1 || self.multi_iter_safe,
            "multi-iteration unrolling needs balanced per-iteration message tags; \
             use the event engine for this schedule"
        );
        assert_eq!(w.tab.len(), self.n_wclasses, "weights built for a different structure");
        if let Some(s) = &w.node_scale {
            assert_eq!(s.len(), self.op.len(), "compute scales built for a different structure");
        }
        if !self.stuck.is_empty() {
            return Err(SimError { stuck: self.stuck.clone() });
        }
        let d = self.d;
        let mut now = vec![0.0f64; d];
        let mut comm_free = vec![0.0f64; d];
        let mut trace = vec![DeviceTrace::default(); d];
        // +1: shared scratch slot for sends nothing ever receives.
        let mut slot = vec![0.0f64; self.n_msgs + 1];
        let mut launch_max = vec![0.0f64; self.n_colls];
        let mut done = vec![0.0f64; self.n_colls];
        let mut iter_finish = vec![0.0f64; iters];
        for finish in iter_finish.iter_mut() {
            launch_max.fill(0.0);
            for &nid in &self.topo {
                let i = nid as usize;
                match self.op[i] {
                    NodeOp::Compute => {
                        let dv = self.dev[i] as usize;
                        let mut c = w.tab[self.wclass[i] as usize];
                        if let Some(s) = &w.node_scale {
                            c *= s[i];
                        }
                        now[dv] += c;
                        trace[dv].compute_busy += c;
                    }
                    NodeOp::LocalCopy => {
                        let dv = self.dev[i] as usize;
                        now[dv] += w.tab[self.wclass[i] as usize];
                        trace[dv].local_copies += 1;
                    }
                    NodeOp::Optim => {
                        let dv = self.dev[i] as usize;
                        now[dv] += w.tab[self.wclass[i] as usize];
                    }
                    NodeOp::Send { msg } => {
                        let dv = self.dev[i] as usize;
                        now[dv] += LAUNCH;
                        trace[dv].sends += 1;
                        slot[msg as usize] = now[dv] + w.tab[self.wclass[i] as usize];
                    }
                    NodeOp::Recv { msg } => {
                        let dv = self.dev[i] as usize;
                        let arrival = slot[msg as usize];
                        if arrival > now[dv] {
                            trace[dv].recv_blocked += arrival - now[dv];
                            now[dv] = arrival;
                        }
                    }
                    NodeOp::Launch => {
                        now[self.dev[i] as usize] += LAUNCH;
                    }
                    NodeOp::ArStart { coll } => {
                        let dv = self.dev[i] as usize;
                        now[dv] += LAUNCH;
                        let lm = &mut launch_max[coll as usize];
                        if *lm < now[dv] {
                            *lm = now[dv];
                        }
                    }
                    NodeOp::Barrier { coll } => {
                        let c = coll as usize;
                        let (lo, hi) =
                            (self.members_off[c] as usize, self.members_off[c + 1] as usize);
                        let mut engine = 0.0f64;
                        for &g in &self.members[lo..hi] {
                            engine = engine.max(comm_free[g as usize]);
                        }
                        let t = launch_max[c].max(engine) + w.tab[self.wclass[i] as usize];
                        for &g in &self.members[lo..hi] {
                            comm_free[g as usize] = t;
                        }
                        done[c] = t;
                    }
                    NodeOp::ArWait { coll } => {
                        let dv = self.dev[i] as usize;
                        let t = done[coll as usize];
                        if t > now[dv] {
                            trace[dv].allreduce_blocked += t - now[dv];
                            now[dv] = t;
                        }
                    }
                }
            }
            for &t in &now {
                if *finish < t {
                    *finish = t;
                }
            }
        }
        for (dv, tr) in trace.iter_mut().enumerate() {
            tr.finish = now[dv];
        }
        let makespan = iter_finish.last().copied().unwrap_or(0.0);
        Ok(MultiIterTrace { devices: trace, iter_finish, makespan })
    }

    /// Batched re-cost: price `ws.len()` weight tables (k lanes) in **one**
    /// pass over the shared topological order per iteration, with
    /// structure-of-arrays `[k]`-lane time vectors — the same max/+
    /// primitives as [`CompiledDag::evaluate`] applied per lane, one arena
    /// traversal, lane-inner loops the compiler can vectorize. Each lane's
    /// result is **bit-identical** (exact f64) to a solo `evaluate` call
    /// with that table, including multi-iteration carried state: per lane,
    /// the f64 operation sequence is literally the scalar one. Pinned
    /// across the schedule-family grid in `rust/tests/dag_equiv.rs`.
    ///
    /// An empty batch returns no traces; a stuck structure fails the whole
    /// batch with the same [`SimError`] every lane would report solo.
    pub fn evaluate_batch(
        &self,
        ws: &[DagWeights],
        iters: usize,
    ) -> Result<Vec<MultiIterTrace>, SimError> {
        let k = ws.len();
        if k == 0 {
            return Ok(Vec::new());
        }
        assert!(iters >= 1, "need at least one iteration");
        assert!(
            iters == 1 || self.multi_iter_safe,
            "multi-iteration unrolling needs balanced per-iteration message tags; \
             use the event engine for this schedule"
        );
        for w in ws {
            assert_eq!(w.tab.len(), self.n_wclasses, "weights built for a different structure");
            if let Some(s) = &w.node_scale {
                assert_eq!(
                    s.len(),
                    self.op.len(),
                    "compute scales built for a different structure"
                );
            }
        }
        if !self.stuck.is_empty() {
            return Err(SimError { stuck: self.stuck.clone() });
        }
        let d = self.d;
        // Lane-major transpose of the weight tables: wtab[class * k + lane]
        // keeps one node's k prices contiguous for the lane-inner loops.
        let mut wtab = vec![0.0f64; self.n_wclasses * k];
        for (lane, w) in ws.iter().enumerate() {
            for (class, &c) in w.tab.iter().enumerate() {
                wtab[class * k + lane] = c;
            }
        }
        // SoA lane state, indexed [entity * k + lane].
        let mut now = vec![0.0f64; d * k];
        let mut comm_free = vec![0.0f64; d * k];
        let mut compute_busy = vec![0.0f64; d * k];
        let mut recv_blocked = vec![0.0f64; d * k];
        let mut ar_blocked = vec![0.0f64; d * k];
        // Send/copy counts are structural — identical in every lane — so
        // they are tallied once and replicated into each lane's trace.
        let mut sends = vec![0usize; d];
        let mut copies = vec![0usize; d];
        // +1: shared scratch slot for sends nothing ever receives.
        let mut slot = vec![0.0f64; (self.n_msgs + 1) * k];
        let mut launch_max = vec![0.0f64; self.n_colls * k];
        let mut done = vec![0.0f64; self.n_colls * k];
        let mut engine_buf = vec![0.0f64; k];
        let mut iter_finish = vec![vec![0.0f64; iters]; k];
        for it in 0..iters {
            launch_max.fill(0.0);
            for &nid in &self.topo {
                let i = nid as usize;
                match self.op[i] {
                    NodeOp::Compute => {
                        let base = self.dev[i] as usize * k;
                        let wb = self.wclass[i] as usize * k;
                        for (lane, w) in ws.iter().enumerate() {
                            let mut c = wtab[wb + lane];
                            if let Some(s) = &w.node_scale {
                                c *= s[i];
                            }
                            now[base + lane] += c;
                            compute_busy[base + lane] += c;
                        }
                    }
                    NodeOp::LocalCopy => {
                        let dv = self.dev[i] as usize;
                        let wb = self.wclass[i] as usize * k;
                        for lane in 0..k {
                            now[dv * k + lane] += wtab[wb + lane];
                        }
                        copies[dv] += 1;
                    }
                    NodeOp::Optim => {
                        let base = self.dev[i] as usize * k;
                        let wb = self.wclass[i] as usize * k;
                        for lane in 0..k {
                            now[base + lane] += wtab[wb + lane];
                        }
                    }
                    NodeOp::Send { msg } => {
                        let dv = self.dev[i] as usize;
                        let base = dv * k;
                        let wb = self.wclass[i] as usize * k;
                        let sb = msg as usize * k;
                        for lane in 0..k {
                            now[base + lane] += LAUNCH;
                            slot[sb + lane] = now[base + lane] + wtab[wb + lane];
                        }
                        sends[dv] += 1;
                    }
                    NodeOp::Recv { msg } => {
                        let base = self.dev[i] as usize * k;
                        let sb = msg as usize * k;
                        for lane in 0..k {
                            let arrival = slot[sb + lane];
                            if arrival > now[base + lane] {
                                recv_blocked[base + lane] += arrival - now[base + lane];
                                now[base + lane] = arrival;
                            }
                        }
                    }
                    NodeOp::Launch => {
                        let base = self.dev[i] as usize * k;
                        for lane in 0..k {
                            now[base + lane] += LAUNCH;
                        }
                    }
                    NodeOp::ArStart { coll } => {
                        let base = self.dev[i] as usize * k;
                        let lb = coll as usize * k;
                        for lane in 0..k {
                            now[base + lane] += LAUNCH;
                            if launch_max[lb + lane] < now[base + lane] {
                                launch_max[lb + lane] = now[base + lane];
                            }
                        }
                    }
                    NodeOp::Barrier { coll } => {
                        let c = coll as usize;
                        let (lo, hi) =
                            (self.members_off[c] as usize, self.members_off[c + 1] as usize);
                        // Member-outer / lane-inner keeps each lane's max
                        // accumulation in the scalar member order.
                        engine_buf.fill(0.0);
                        for &g in &self.members[lo..hi] {
                            let gb = g as usize * k;
                            for lane in 0..k {
                                engine_buf[lane] = engine_buf[lane].max(comm_free[gb + lane]);
                            }
                        }
                        let wb = self.wclass[i] as usize * k;
                        for lane in 0..k {
                            engine_buf[lane] =
                                launch_max[c * k + lane].max(engine_buf[lane]) + wtab[wb + lane];
                            done[c * k + lane] = engine_buf[lane];
                        }
                        for &g in &self.members[lo..hi] {
                            let gb = g as usize * k;
                            for lane in 0..k {
                                comm_free[gb + lane] = engine_buf[lane];
                            }
                        }
                    }
                    NodeOp::ArWait { coll } => {
                        let base = self.dev[i] as usize * k;
                        let db = coll as usize * k;
                        for lane in 0..k {
                            let t = done[db + lane];
                            if t > now[base + lane] {
                                ar_blocked[base + lane] += t - now[base + lane];
                                now[base + lane] = t;
                            }
                        }
                    }
                }
            }
            for (lane, ifin) in iter_finish.iter_mut().enumerate() {
                let finish = &mut ifin[it];
                for dv in 0..d {
                    let t = now[dv * k + lane];
                    if *finish < t {
                        *finish = t;
                    }
                }
            }
        }
        let out = iter_finish
            .into_iter()
            .enumerate()
            .map(|(lane, ifin)| {
                let devices = (0..d)
                    .map(|dv| DeviceTrace {
                        finish: now[dv * k + lane],
                        compute_busy: compute_busy[dv * k + lane],
                        recv_blocked: recv_blocked[dv * k + lane],
                        allreduce_blocked: ar_blocked[dv * k + lane],
                        sends: sends[dv],
                        local_copies: copies[dv],
                    })
                    .collect();
                let makespan = ifin.last().copied().unwrap_or(0.0);
                MultiIterTrace { devices, iter_finish: ifin, makespan }
            })
            .collect();
        Ok(out)
    }

    /// Pipeline depth the structure was compiled for.
    pub fn n_devices(&self) -> usize {
        self.d
    }

    /// Total arena nodes (instructions + collective barriers).
    pub fn n_nodes(&self) -> usize {
        self.op.len()
    }

    /// Whether multi-iteration unrolling is valid (balanced message tags).
    pub fn multi_iter_safe(&self) -> bool {
        self.multi_iter_safe
    }

    /// Chunks held per device — memory re-costing without the `Schedule`.
    pub fn held_chunks(&self) -> &[u32] {
        &self.held_chunks
    }

    /// Peak activation-stash depth per device, in chunk units.
    pub fn peak_stash(&self) -> &[u32] {
        &self.peak_stash
    }
}

/// Why a lowered node can never fire — the static image of the event
/// engine's parked states, reported by [`EdgeArena::lower`] so
/// `schedule::lint` can diagnose them without running anything.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ParkReason {
    /// `RecvAct` at the entry stage: no producer can exist.
    EntryStageRecv,
    /// Receive whose matching send never happens (FIFO tag imbalance).
    UnmatchedRecv,
    /// `AllReduceWait` for a stage outside the placement.
    OutOfRangeWait,
    /// Collective barrier missing a member's `AllReduceStart`; the field
    /// is the device that never launches it.
    MissingMemberStart(usize),
}

/// The dependence *structure* of a schedule, exposed for static analysis
/// (`schedule::lint`): the same lowering as [`CompiledDag::compile`] —
/// program-order edges, FIFO-paired send→recv edges, collective
/// member-start → barrier → wait edges, and the per-device comm-engine
/// serialization chains — but total (out-of-range collective stages are
/// recorded instead of panicking) and without weights or evaluation
/// state. Node ids share the compiled arena's layout: device streams back
/// to back (`base`), one synthetic barrier node per collective round
/// appended after `n_real`.
#[derive(Debug, Clone)]
pub struct EdgeArena {
    /// Pipeline devices.
    pub d: usize,
    /// Real (instruction) nodes; barrier nodes follow.
    pub n_real: usize,
    /// Total nodes including one barrier per collective round.
    pub n_nodes: usize,
    /// Device-stream offsets: device `dv`'s instruction `ix` is node
    /// `base[dv] + ix`; `base[d] == n_real`.
    pub base: Vec<u32>,
    /// Real dependence edges (program order, paired messages, collective
    /// start→barrier→wait).
    pub edges: Vec<(u32, u32)>,
    /// Per-device comm-engine serialization chains between successive
    /// barriers — a pricing heuristic, not true dependence; kept separate
    /// so a chain-only cycle is a fallback warning, not a deadlock.
    pub chain_edges: Vec<(u32, u32)>,
    /// Nodes that can never fire, with why. A barrier node may appear
    /// once per missing member.
    pub parked: Vec<(u32, ParkReason)>,
    /// Model stage per barrier node (index `node - n_real`).
    pub barrier_stage: Vec<usize>,
    /// Collective round per barrier node.
    pub barrier_round: Vec<usize>,
    /// FIFO-paired messages.
    pub n_msgs: usize,
    /// `AllReduceStart` nodes whose stage lies outside the placement —
    /// skipped during lowering ([`CompiledDag::compile`] panics on them).
    pub oversized_starts: Vec<u32>,
}

impl EdgeArena {
    /// Lower `s` into its dependence structure. Total: never panics and
    /// never errors — pathological streams surface as `parked` entries,
    /// `oversized_starts`, or cycles visible to [`EdgeArena::toposort`].
    pub fn lower(s: &Schedule) -> EdgeArena {
        let d = s.n_devices();
        let n_stages = s.placement.n_stages();
        let groups: Vec<Vec<usize>> =
            (0..n_stages).map(|st| s.placement.allreduce_group(st)).collect();

        let mut base = vec![0u32; d + 1];
        for dv in 0..d {
            base[dv + 1] = base[dv] + s.device_ops[dv].len() as u32;
        }
        let n_real = base[d] as usize;

        let mut sends: Vec<(MsgKey, u32)> = Vec::new();
        let mut recvs: Vec<(MsgKey, u32)> = Vec::new();
        let mut parked: Vec<(u32, ParkReason)> = Vec::new();
        let mut oversized_starts: Vec<u32> = Vec::new();

        let mut colls: Vec<CollBuild> = Vec::new();
        let mut coll_of: Vec<Vec<u32>> = vec![Vec::new(); n_stages];
        let mut start_round = vec![0u32; d * n_stages];
        let mut wait_round = vec![0u32; d * n_stages];
        let mut chain_prev: Vec<Option<u32>> = vec![None; d];
        let mut chains: Vec<(u32, u32)> = Vec::new();

        for dv in 0..d {
            for (ix, ins) in s.device_ops[dv].iter().enumerate() {
                let id = base[dv] + ix as u32;
                match *ins {
                    Instr::SendAct { to, pipe, stage, mb } => {
                        sends.push(((dv, to, false, pipe, stage, mb), id));
                    }
                    Instr::SendGrad { to, pipe, stage, mb } => {
                        sends.push(((dv, to, true, pipe, stage, mb), id));
                    }
                    Instr::RecvAct { from, pipe, stage, mb } => match stage.checked_sub(1) {
                        Some(p) => recvs.push(((from, dv, false, pipe, p, mb), id)),
                        None => parked.push((id, ParkReason::EntryStageRecv)),
                    },
                    Instr::RecvGrad { from, pipe, stage, mb } => {
                        recvs.push(((from, dv, true, pipe, stage + 1, mb), id));
                    }
                    Instr::AllReduceStart { stage } => {
                        if stage >= n_stages {
                            oversized_starts.push(id);
                        } else {
                            let r = &mut start_round[dv * n_stages + stage];
                            let round = *r as usize;
                            *r += 1;
                            if groups[stage].contains(&dv) {
                                let c = coll_id(&mut colls, &mut coll_of, stage, round);
                                colls[c as usize].starts.push(id);
                                if let Some(prev) = chain_prev[dv].replace(c) {
                                    chains.push((prev, c));
                                }
                            }
                        }
                    }
                    Instr::AllReduceWait { stage } => {
                        if stage >= n_stages {
                            parked.push((id, ParkReason::OutOfRangeWait));
                        } else {
                            let r = &mut wait_round[dv * n_stages + stage];
                            let round = *r as usize;
                            *r += 1;
                            let c = coll_id(&mut colls, &mut coll_of, stage, round);
                            colls[c as usize].waits.push(id);
                        }
                    }
                    _ => {}
                }
            }
        }

        let n_colls = colls.len();
        let n_nodes = n_real + n_colls;
        let bar = |c: u32| n_real as u32 + c;
        let mut barrier_stage = vec![0usize; n_colls];
        let mut barrier_round = vec![0usize; n_colls];
        for rounds in &coll_of {
            for (round, &c) in rounds.iter().enumerate() {
                barrier_stage[c as usize] = colls[c as usize].stage;
                barrier_round[c as usize] = round;
            }
        }

        let mut edges: Vec<(u32, u32)> = Vec::new();
        for dv in 0..d {
            for ix in 1..s.device_ops[dv].len() as u32 {
                edges.push((base[dv] + ix - 1, base[dv] + ix));
            }
        }
        // FIFO message pairing, identical to `compile`: j-th send of a tag
        // feeds the j-th recv; surplus receives park.
        sends.sort_unstable();
        recvs.sort_unstable();
        let mut n_msgs = 0usize;
        let (mut si, mut ri) = (0usize, 0usize);
        while si < sends.len() || ri < recvs.len() {
            let key = match (sends.get(si), recvs.get(ri)) {
                (Some(&(sk, _)), Some(&(rk, _))) => sk.min(rk),
                (Some(&(sk, _)), None) => sk,
                (None, Some(&(rk, _))) => rk,
                (None, None) => unreachable!(),
            };
            let s0 = si;
            while si < sends.len() && sends[si].0 == key {
                si += 1;
            }
            let r0 = ri;
            while ri < recvs.len() && recvs[ri].0 == key {
                ri += 1;
            }
            let paired = (si - s0).min(ri - r0);
            for j in 0..paired {
                edges.push((sends[s0 + j].1, recvs[r0 + j].1));
                n_msgs += 1;
            }
            for &(_, rnode) in &recvs[r0 + paired..ri] {
                parked.push((rnode, ParkReason::UnmatchedRecv));
            }
        }
        // Collective edges; members that never start park the barrier.
        for (c, cb) in colls.iter().enumerate() {
            let b = bar(c as u32);
            let mut started: Vec<usize> = cb
                .starts
                .iter()
                .map(|&snode| {
                    // Device of a real node via the stream offsets.
                    base.partition_point(|&off| off <= snode) - 1
                })
                .collect();
            started.sort_unstable();
            for &snode in &cb.starts {
                edges.push((snode, b));
            }
            for &g in &groups[cb.stage] {
                if started.binary_search(&g).is_err() {
                    parked.push((b, ParkReason::MissingMemberStart(g)));
                }
            }
            for &wnode in &cb.waits {
                edges.push((b, wnode));
            }
        }
        let chain_edges: Vec<(u32, u32)> =
            chains.iter().map(|&(a, b)| (bar(a), bar(b))).collect();
        parked.sort_unstable_by_key(|&(node, _)| node);

        EdgeArena {
            d,
            n_real,
            n_nodes,
            base,
            edges,
            chain_edges,
            parked,
            barrier_stage,
            barrier_round,
            n_msgs,
            oversized_starts,
        }
    }

    /// (device, instruction index) of a real node; `None` for barriers.
    pub fn site_of(&self, node: u32) -> Option<(usize, usize)> {
        if node as usize >= self.n_real {
            return None;
        }
        let dv = self.base.partition_point(|&off| off <= node) - 1;
        Some((dv, (node - self.base[dv]) as usize))
    }

    /// Kahn order over the arena. `with_chains` adds the collective
    /// serialization chains; `with_parked` gives parked nodes a permanent
    /// indegree (the engine's view). Shorter than `n_nodes` iff nodes are
    /// unreachable — through parking, or through a genuine cycle.
    pub fn toposort(&self, with_chains: bool, with_parked: bool) -> Vec<u32> {
        let mut extra = vec![0u32; self.n_nodes];
        if with_parked {
            for &(node, _) in &self.parked {
                extra[node as usize] += 1;
            }
        }
        toposort(
            self.n_nodes,
            &self.edges,
            with_chains.then_some(self.chain_edges.as_slice()),
            &extra,
        )
    }
}

/// Kahn's algorithm over the arena. `chains` (barrier serialization) are
/// optional so a failed sort can be retried on real dependencies alone.
/// `extra_indeg` entries are never satisfied — they park unmatchable nodes.
/// Returns the visit order; shorter than `n_nodes` iff nodes are stuck.
fn toposort(
    n_nodes: usize,
    edges: &[(u32, u32)],
    chains: Option<&[(u32, u32)]>,
    extra_indeg: &[u32],
) -> Vec<u32> {
    let chain_edges = chains.unwrap_or(&[]);
    let mut indeg: Vec<u32> = extra_indeg.to_vec();
    let mut succ_off = vec![0u32; n_nodes + 1];
    for &(a, b) in edges.iter().chain(chain_edges) {
        indeg[b as usize] += 1;
        succ_off[a as usize + 1] += 1;
    }
    for i in 0..n_nodes {
        succ_off[i + 1] += succ_off[i];
    }
    let mut succ = vec![0u32; edges.len() + chain_edges.len()];
    let mut cursor = succ_off.clone();
    for &(a, b) in edges.iter().chain(chain_edges) {
        succ[cursor[a as usize] as usize] = b;
        cursor[a as usize] += 1;
    }
    let mut order = Vec::with_capacity(n_nodes);
    let mut ready: Vec<u32> =
        (0..n_nodes as u32).rev().filter(|&i| indeg[i as usize] == 0).collect();
    while let Some(nid) = ready.pop() {
        order.push(nid);
        let (lo, hi) = (succ_off[nid as usize] as usize, succ_off[nid as usize + 1] as usize);
        for &nx in &succ[lo..hi] {
            indeg[nx as usize] -= 1;
            if indeg[nx as usize] == 0 {
                ready.push(nx);
            }
        }
    }
    order
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ClusterConfig, ParallelConfig, BERT_64};
    use crate::schedule::{build, placement_for, ScheduleConfig, ScheduleKind};
    use crate::sim::engine::{simulate_schedule, simulate_schedule_iters};

    fn costs(kind: ScheduleKind, d: usize, n: usize) -> CostModel {
        let p = ParallelConfig::new(kind, 1, d, 4, n);
        CostModel::new(&BERT_64, &p, &ClusterConfig::paper_testbed(d))
    }

    #[test]
    fn compiles_and_matches_event_engine_bitwise() {
        for kind in [ScheduleKind::Dapple, ScheduleKind::BitPipe] {
            let s = build(&ScheduleConfig::new(kind, 4, 8)).unwrap();
            let c = costs(kind, 4, 8);
            let dag = CompiledDag::compile(&s).unwrap();
            let t = dag.evaluate(&dag.weights(&c), 1).unwrap();
            let want = simulate_schedule(&s, &c).unwrap();
            assert_eq!(t.makespan.to_bits(), want.makespan.to_bits(), "{kind}");
            for (a, b) in t.devices.iter().zip(&want.devices) {
                assert_eq!(a.finish.to_bits(), b.finish.to_bits());
                assert_eq!(a.recv_blocked.to_bits(), b.recv_blocked.to_bits());
                assert_eq!((a.sends, a.local_copies), (b.sends, b.local_copies));
            }
        }
    }

    #[test]
    fn multi_iteration_unrolls_bitwise() {
        let kind = ScheduleKind::BitPipe;
        let s = build(&ScheduleConfig::new(kind, 4, 8)).unwrap();
        let c = costs(kind, 4, 8);
        let dag = CompiledDag::compile(&s).unwrap();
        assert!(dag.multi_iter_safe());
        let t = dag.evaluate(&dag.weights(&c), 3).unwrap();
        let want = simulate_schedule_iters(&s, &c, 3).unwrap();
        assert_eq!(t.iter_finish.len(), 3);
        for (a, b) in t.iter_finish.iter().zip(&want.iter_finish) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn batched_lanes_match_scalar_evaluate() {
        // Spot check of the lane contract (the full family x k x iters
        // battery lives in rust/tests/dag_equiv.rs): mixed-B lanes in one
        // walk, each bit-identical to its solo run, counters included.
        let kind = ScheduleKind::BitPipe;
        let s = build(&ScheduleConfig::new(kind, 4, 8)).unwrap();
        let dag = CompiledDag::compile(&s).unwrap();
        let cluster = ClusterConfig::paper_testbed(4);
        let ws: Vec<DagWeights> = [1usize, 2, 4, 8]
            .iter()
            .map(|&b| {
                let p = ParallelConfig::new(kind, 1, 4, b, 8);
                dag.weights(&CostModel::new(&BERT_64, &p, &cluster))
            })
            .collect();
        assert!(dag.evaluate_batch(&[], 1).unwrap().is_empty());
        let got = dag.evaluate_batch(&ws, 3).unwrap();
        assert_eq!(got.len(), ws.len());
        for (g, w) in got.iter().zip(&ws) {
            let want = dag.evaluate(w, 3).unwrap();
            assert_eq!(g.makespan.to_bits(), want.makespan.to_bits());
            for (a, b) in g.iter_finish.iter().zip(&want.iter_finish) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
            for (x, y) in g.devices.iter().zip(&want.devices) {
                assert_eq!(x.finish.to_bits(), y.finish.to_bits());
                assert_eq!(x.compute_busy.to_bits(), y.compute_busy.to_bits());
                assert_eq!(x.recv_blocked.to_bits(), y.recv_blocked.to_bits());
                assert_eq!(x.allreduce_blocked.to_bits(), y.allreduce_blocked.to_bits());
                assert_eq!((x.sends, x.local_copies), (y.sends, y.local_copies));
            }
        }
    }

    #[test]
    fn reweighting_changes_costs_not_structure() {
        let s = build(&ScheduleConfig::new(ScheduleKind::BitPipe, 4, 4)).unwrap();
        let dag = CompiledDag::compile(&s).unwrap();
        let c1 = costs(ScheduleKind::BitPipe, 4, 4);
        let p8 = ParallelConfig::new(ScheduleKind::BitPipe, 1, 4, 8, 4);
        let c8 = CostModel::new(&BERT_64, &p8, &ClusterConfig::paper_testbed(4));
        let t1 = dag.evaluate(&dag.weights(&c1), 1).unwrap();
        let t8 = dag.evaluate(&dag.weights(&c8), 1).unwrap();
        assert!(t8.makespan > t1.makespan, "B=8 must cost more than B=4");
        // Each re-cost still matches its own event-engine run bitwise.
        assert_eq!(
            t8.makespan.to_bits(),
            simulate_schedule(&s, &c8).unwrap().makespan.to_bits()
        );
    }

    #[test]
    fn deadlock_reported_like_the_engine() {
        let kind = ScheduleKind::Dapple;
        let mut s = build(&ScheduleConfig::new(kind, 4, 4)).unwrap();
        let idx = s.device_ops[0]
            .iter()
            .position(|i| matches!(i, Instr::SendAct { .. }))
            .unwrap();
        s.device_ops[0].remove(idx);
        let c = costs(kind, 4, 4);
        let dag = CompiledDag::compile(&s).unwrap();
        let e = dag.evaluate(&dag.weights(&c), 1).unwrap_err();
        let want = simulate_schedule(&s, &c).unwrap_err();
        let devs = |err: &SimError| {
            let mut v: Vec<usize> = err.stuck.iter().map(|&(dv, _, _)| dv).collect();
            v.sort_unstable();
            v
        };
        assert!(!e.stuck.is_empty());
        assert_eq!(devs(&e), devs(&want));
    }

    #[test]
    fn entry_stage_recv_is_stuck_not_panicking() {
        let placement = placement_for(ScheduleKind::Dapple, 2, 1);
        let cfg = ScheduleConfig::new(ScheduleKind::Dapple, 2, 2);
        let s = Schedule {
            cfg,
            placement,
            compute_order: vec![Vec::new(), Vec::new()],
            device_ops: vec![
                vec![Instr::RecvAct { from: 1, pipe: 0, stage: 0, mb: 0 }],
                Vec::new(),
            ],
            pipe_of_mb: vec![0, 0],
        };
        let dag = CompiledDag::compile(&s).unwrap();
        let c = costs(ScheduleKind::Dapple, 2, 2);
        let e = dag.evaluate(&dag.weights(&c), 1).unwrap_err();
        assert_eq!(e.stuck.len(), 1);
        assert_eq!(e.stuck[0].0, 0);
    }

    #[test]
    fn duplicate_tags_pair_fifo_and_flag_multi_iter() {
        // Two in-flight messages under one tag pair in send order (engine
        // parity); balanced tags stay multi-iteration safe.
        let placement = placement_for(ScheduleKind::Dapple, 2, 1);
        let cfg = ScheduleConfig::new(ScheduleKind::Dapple, 2, 2);
        let mut s = Schedule {
            cfg,
            placement,
            compute_order: vec![Vec::new(), Vec::new()],
            device_ops: vec![
                vec![
                    Instr::SendAct { to: 1, pipe: 0, stage: 0, mb: 0 },
                    Instr::SendAct { to: 1, pipe: 0, stage: 0, mb: 0 },
                ],
                vec![
                    Instr::RecvAct { from: 0, pipe: 0, stage: 1, mb: 0 },
                    Instr::RecvAct { from: 0, pipe: 0, stage: 1, mb: 0 },
                ],
            ],
            pipe_of_mb: vec![0, 0],
        };
        let c = costs(ScheduleKind::Dapple, 2, 2);
        let dag = CompiledDag::compile(&s).unwrap();
        assert!(dag.multi_iter_safe());
        let t = dag.evaluate(&dag.weights(&c), 1).unwrap();
        let want = simulate_schedule(&s, &c).unwrap();
        assert_eq!(t.makespan.to_bits(), want.makespan.to_bits());
        // Unbalanced tags: single-iteration still exact, multi-iteration
        // flagged off so callers fall back to the event engine.
        s.device_ops[1].pop();
        let dag = CompiledDag::compile(&s).unwrap();
        assert!(!dag.multi_iter_safe());
        let t = dag.evaluate(&dag.weights(&c), 1).unwrap();
        let want = simulate_schedule(&s, &c).unwrap();
        assert_eq!(t.makespan.to_bits(), want.makespan.to_bits());
    }

    #[test]
    fn heterogeneous_weights_match_event_engine_bitwise() {
        // A straggler produces a node_scale row; the scaled DAG must still
        // replay the event engine bit for bit, solo and batched (mixed
        // hetero/uniform lanes), and cost strictly more than uniform.
        let kind = ScheduleKind::BitPipe;
        let s = build(&ScheduleConfig::new(kind, 4, 8)).unwrap();
        let p = ParallelConfig::new(kind, 1, 4, 4, 8);
        let slow = ClusterConfig::paper_testbed(4).with_straggler(1, 1.5).unwrap();
        let ch = CostModel::new(&BERT_64, &p, &slow);
        let cu = CostModel::new(&BERT_64, &p, &ClusterConfig::paper_testbed(4));
        let dag = CompiledDag::compile(&s).unwrap();
        let wh = dag.weights(&ch);
        let wu = dag.weights(&cu);
        assert!(wh.node_scale().is_some());
        assert!(wu.node_scale().is_none());
        let t = dag.evaluate(&wh, 2).unwrap();
        let want = simulate_schedule_iters(&s, &ch, 2).unwrap();
        for (a, b) in t.iter_finish.iter().zip(&want.iter_finish) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        assert!(t.makespan > dag.evaluate(&wu, 2).unwrap().makespan);
        let got = dag.evaluate_batch(&[wh.clone(), wu.clone()], 2).unwrap();
        for (g, wi) in got.iter().zip([&wh, &wu]) {
            let solo = dag.evaluate(wi, 2).unwrap();
            assert_eq!(g.makespan.to_bits(), solo.makespan.to_bits());
            for (x, y) in g.devices.iter().zip(&solo.devices) {
                assert_eq!(x.finish.to_bits(), y.finish.to_bits());
                assert_eq!(x.compute_busy.to_bits(), y.compute_busy.to_bits());
            }
        }
    }

    #[test]
    fn memory_structure_matches_schedule() {
        let s = build(&ScheduleConfig::new(ScheduleKind::BitPipe, 4, 8)).unwrap();
        let dag = CompiledDag::compile(&s).unwrap();
        for dv in 0..4 {
            assert_eq!(dag.held_chunks()[dv] as usize, s.placement.chunks_on[dv].len());
        }
        assert!(dag.peak_stash().iter().any(|&p| p > 0));
    }
}
