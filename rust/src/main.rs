//! BitPipe command-line launcher.
//!
//! ```text
//! bitpipe schedule   --kind bitpipe --d 4 --n 8 [--v 2] [--sync eager|lazy]
//!                    [--csv] [--ticks-per-col T] [--stage-ids]
//! bitpipe simulate   --kind bitpipe --model bert-64 --w 1 --d 8 --b 4 --n 8
//!                    [--gpus P] [--mapping replicas|pipes] [--single-node]
//!                    [--iters N [--warmup K]] [--contention]
//!                    [--ib-model nic|pair] [--engine auto|event|dag]
//!                    [--network inc|global]
//!                    [--straggler DEV:MULT[,DEV:MULT...]]
//!                    [--link-override local|nvlink|ib:MULT or A-B:MULT[,...]]
//!                    [--fault SPEC[,SPEC...]] (repeatable; e.g.
//!                      link:ib:0.25@2.0..5.0  dev:3:slow:1.5@2.0..5.0
//!                      dev:3:stall@1.5+0.4)
//!                    [--fault-seed N [--fault-intensity I] [--fault-horizon T]]
//! bitpipe lint       [--kind bitpipe|all] [--d 4] [--n 8] [--v 2]
//!                    [--sync eager|lazy] [--json]
//! bitpipe eval-paper [--only table2,fig9,...] (default: all)
//! bitpipe train      --artifacts DIR --kind bitpipe --d 4 --n 8 --steps 50
//!                    [--dataset synthetic|corpus] [--lr 1e-3] [--seed 42]
//!                    [--log-every 10] [--sync eager|lazy]
//!                    [--save CKPT_DIR [--save-every K]] [--resume CKPT_DIR]
//! bitpipe inspect    --artifacts DIR [--artifact NAME]
//! ```
//!
//! All configuration is plain `--key value` flags (no external CLI crate);
//! `bitpipe help` prints the command list.

use anyhow::{bail, Context, Result};
use bitpipe::config::{
    ClusterConfig, FaultPlan, IbModel, LinkKind, MappingPolicy, ModelConfig, ParallelConfig,
};
use bitpipe::schedule::{self, timeline, Costs, ScheduleConfig, ScheduleKind, SyncPolicy};
use bitpipe::sim::{self, Engine, NetworkImpl, SimConfig};
use bitpipe::train::{self, DatasetKind, TrainConfig};
use std::collections::HashMap;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = dispatch(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn dispatch(args: &[String]) -> Result<()> {
    let Some(cmd) = args.first() else {
        print_usage();
        return Ok(());
    };
    let flags = parse_flags(&args[1..])?;
    match cmd.as_str() {
        "schedule" => cmd_schedule(&flags),
        "lint" => cmd_lint(&flags),
        "simulate" => cmd_simulate(&flags),
        "eval-paper" => cmd_eval_paper(&flags),
        "train" => cmd_train(&flags),
        "inspect" => cmd_inspect(&flags),
        "help" | "--help" | "-h" => {
            print_usage();
            Ok(())
        }
        other => bail!("unknown command {other:?}; run `bitpipe help`"),
    }
}

fn print_usage() {
    println!(
        "BitPipe — bidirectional interleaved pipeline parallelism (reproduction)\n\n\
         USAGE: bitpipe <command> [--flag value ...]\n\n\
         COMMANDS:\n  \
         schedule    render a pipeline schedule timeline + analytic report\n  \
         lint        statically analyze schedules: deadlocks, memory, sync\n  \
         simulate    simulate one training iteration on the modeled cluster\n  \
         eval-paper  regenerate the paper's tables and figures\n  \
         train       real training run over AOT artifacts (threads-as-devices)\n  \
         inspect     print an artifact directory's manifest\n  \
         help        this message\n\n\
         Schedule kinds: gpipe dapple 1f1b-int gems chimera mixpipe bitpipe\n\
         \x20                bitpipe-no-v v-shaped zero-bubble"
    );
}

/// `--key value` pairs (plus bare `--flag` booleans). A repeated flag
/// accumulates comma-joined, so `--fault A --fault B` equals
/// `--fault A,B` (every list-valued flag already splits on commas).
fn parse_flags(args: &[String]) -> Result<HashMap<String, String>> {
    let mut out: HashMap<String, String> = HashMap::new();
    let mut i = 0;
    while i < args.len() {
        let a = &args[i];
        let Some(key) = a.strip_prefix("--") else {
            bail!("expected --flag, got {a:?}");
        };
        let value = if i + 1 < args.len() && !args[i + 1].starts_with("--") {
            i += 2;
            args[i - 1].clone()
        } else {
            i += 1;
            "true".to_string()
        };
        match out.entry(key.to_string()) {
            std::collections::hash_map::Entry::Occupied(mut e) => {
                let joined = format!("{},{}", e.get(), value);
                e.insert(joined);
            }
            std::collections::hash_map::Entry::Vacant(e) => {
                e.insert(value);
            }
        }
    }
    Ok(out)
}

fn get<'a>(flags: &'a HashMap<String, String>, key: &str) -> Option<&'a str> {
    flags.get(key).map(|s| s.as_str())
}

fn get_usize(flags: &HashMap<String, String>, key: &str, default: usize) -> Result<usize> {
    match get(flags, key) {
        None => Ok(default),
        Some(v) => v.parse().with_context(|| format!("--{key} {v}: not an integer")),
    }
}

fn get_f64(flags: &HashMap<String, String>, key: &str, default: f64) -> Result<f64> {
    match get(flags, key) {
        None => Ok(default),
        Some(v) => v.parse().with_context(|| format!("--{key} {v}: not a number")),
    }
}

fn get_kind(flags: &HashMap<String, String>) -> Result<ScheduleKind> {
    let name = get(flags, "kind").unwrap_or("bitpipe");
    ScheduleKind::parse(name).with_context(|| format!("unknown schedule kind {name:?}"))
}

fn get_sync(flags: &HashMap<String, String>) -> Result<SyncPolicy> {
    match get(flags, "sync").unwrap_or("eager") {
        "eager" => Ok(SyncPolicy::Eager),
        "lazy" => Ok(SyncPolicy::Lazy),
        other => bail!("--sync must be eager|lazy, got {other:?}"),
    }
}

fn cmd_schedule(flags: &HashMap<String, String>) -> Result<()> {
    let kind = get_kind(flags)?;
    let d = get_usize(flags, "d", 4)?;
    let n = get_usize(flags, "n", d)?;
    let v = get_usize(flags, "v", kind.default_v())?;
    let cfg = ScheduleConfig::new(kind, d, n).with_v(v).with_sync(get_sync(flags)?);
    let s = schedule::build(&cfg)?;
    schedule::validate::validate(&s)?;

    if flags.contains_key("csv") {
        print!("{}", timeline::to_csv(&s, &Costs::default())?);
        return Ok(());
    }

    let opts = timeline::RenderOpts {
        ticks_per_col: get_usize(flags, "ticks-per-col", 1)? as u64,
        show_stage: flags.contains_key("stage-ids"),
    };
    println!("{}", timeline::render(&s, &Costs::default(), &opts)?);

    let r = schedule::analysis::report(&s, &Costs::default())?;
    println!(
        "kind={} D={} N={} v={}\n\
         bubble ratio: measured {:.4} (closed form {:.4})\n\
         weights memory: {:.0} x M_theta; activation stash: {:.1}..{:.1} x M_a\n\
         P2P messages: {} (formula {}); local copies: {} (formula {})\n\
         makespan: {} ticks",
        r.kind,
        r.d,
        r.n,
        r.v,
        r.bubble_ratio_measured,
        r.bubble_ratio_formula,
        r.weights_mem_measured_max,
        r.act_mem_measured.0,
        r.act_mem_measured.1,
        r.comm_measured.p2p_messages,
        r.comm_formula.p2p_messages,
        r.comm_measured.local_copies,
        r.comm_formula.local_copies,
        r.makespan,
    );
    Ok(())
}

/// Statically analyze one schedule (or `--kind all`): deadlock-freedom,
/// memory bounds, sync placement. Exit nonzero iff any Error diagnostic.
fn cmd_lint(flags: &HashMap<String, String>) -> Result<()> {
    let d = get_usize(flags, "d", 4)?;
    let n = get_usize(flags, "n", d)?;
    let sync = get_sync(flags)?;
    let json = flags.contains_key("json");
    let kinds: Vec<ScheduleKind> = match get(flags, "kind").unwrap_or("bitpipe") {
        "all" => ScheduleKind::ALL.to_vec(),
        name => vec![ScheduleKind::parse(name)
            .with_context(|| format!("unknown schedule kind {name:?}"))?],
    };
    let mut errors = 0usize;
    for kind in kinds {
        let v = get_usize(flags, "v", kind.default_v())?;
        let cfg = ScheduleConfig::new(kind, d, n).with_v(v).with_sync(sync);
        let s = schedule::build(&cfg)?;
        let report = schedule::lint(&s);
        if json {
            println!("{}", report.to_json(&s));
        } else {
            print!("{}", report.render_human(&s));
        }
        errors += report.counts().0;
    }
    if errors > 0 {
        bail!("lint found {errors} error(s)");
    }
    Ok(())
}

fn cmd_simulate(flags: &HashMap<String, String>) -> Result<()> {
    let kind = get_kind(flags)?;
    let model_name = get(flags, "model").unwrap_or("bert-64");
    let model = ModelConfig::by_name(model_name)
        .with_context(|| format!("unknown model {model_name:?}"))?;
    let w = get_usize(flags, "w", 1)?;
    let d = get_usize(flags, "d", 8)?;
    let b = get_usize(flags, "b", if model.name == "gpt-96" { 1 } else { 4 })?;
    let n = get_usize(flags, "n", d)?;
    let gpus = get_usize(flags, "gpus", w * d)?;

    let mut parallel = ParallelConfig::new(kind, w, d, b, n);
    parallel.sync = get_sync(flags)?;
    let mut cluster = if flags.contains_key("single-node") {
        ClusterConfig::single_node(gpus)
    } else {
        ClusterConfig::paper_testbed(gpus)
    };
    if let Some(m) = get(flags, "mapping") {
        cluster.mapping = match m {
            "replicas" => MappingPolicy::ReplicasTogether,
            "pipes" => MappingPolicy::PipesTogether,
            other => bail!("--mapping must be replicas|pipes, got {other:?}"),
        };
    }
    if let Some(m) = get(flags, "ib-model") {
        cluster.ib_model = match m {
            "nic" => IbModel::NodeNic,
            "pair" => IbModel::NodePair,
            other => bail!("--ib-model must be nic|pair, got {other:?}"),
        };
    }
    // Heterogeneity: slowed devices and degraded links (comma-separated).
    if let Some(spec) = get(flags, "straggler") {
        for part in spec.split(',') {
            let (dev, mult) = part
                .split_once(':')
                .with_context(|| format!("--straggler {part:?}: expected DEV:MULT"))?;
            let dev: usize =
                dev.parse().with_context(|| format!("--straggler {part:?}: bad device"))?;
            let mult: f64 =
                mult.parse().with_context(|| format!("--straggler {part:?}: bad multiplier"))?;
            cluster = cluster.with_straggler(dev, mult)?;
        }
    }
    if let Some(spec) = get(flags, "link-override") {
        for part in spec.split(',') {
            let (target, mult) = part
                .split_once(':')
                .with_context(|| format!("--link-override {part:?}: expected TARGET:MULT"))?;
            let mult: f64 = mult
                .parse()
                .with_context(|| format!("--link-override {part:?}: bad multiplier"))?;
            cluster = match target {
                "local" => cluster.with_link_mult(LinkKind::Local, mult)?,
                "nvlink" => cluster.with_link_mult(LinkKind::NvLink, mult)?,
                "ib" => cluster.with_link_mult(LinkKind::InfiniBand, mult)?,
                pair => {
                    let (a, b) = pair.split_once('-').with_context(|| {
                        format!("--link-override {part:?}: expected local|nvlink|ib or A-B")
                    })?;
                    let a: usize = a
                        .parse()
                        .with_context(|| format!("--link-override {part:?}: bad device"))?;
                    let b: usize = b
                        .parse()
                        .with_context(|| format!("--link-override {part:?}: bad device"))?;
                    cluster.with_link_override(a, b, mult)?
                }
            };
        }
    }
    let contention = flags.contains_key("contention");
    let engine = match get(flags, "engine").unwrap_or("auto") {
        "auto" => Engine::Auto,
        "event" => Engine::Event,
        "dag" => Engine::Dag,
        other => bail!("--engine must be auto|event|dag, got {other:?}"),
    };
    // Settlement strategy of the contended network: incremental (default)
    // or the global-settlement differential oracle.
    let network = match get(flags, "network").unwrap_or("inc") {
        "inc" => NetworkImpl::Incremental,
        "global" => NetworkImpl::Global,
        other => bail!("--network must be inc|global, got {other:?}"),
    };
    if get(flags, "network").is_some() && !contention {
        bail!("--network only applies with --contention");
    }
    // Fault injection: explicit `--fault` specs (repeatable or
    // comma-separated) plus an optional seeded trace, merged into one
    // time-ordered plan replayed by the event engine.
    let mut fault_events = Vec::new();
    if let Some(spec) = get(flags, "fault") {
        fault_events.extend(FaultPlan::parse(spec)?.events);
    }
    if let Some(seed) = get(flags, "fault-seed") {
        let seed: u64 =
            seed.parse().with_context(|| format!("--fault-seed {seed}: not an integer"))?;
        let intensity = get_f64(flags, "fault-intensity", 1.0)?;
        let horizon = get_f64(flags, "fault-horizon", 2.0)?;
        fault_events.extend(FaultPlan::random(seed, intensity, horizon, d)?.events);
    } else if flags.contains_key("fault-intensity") || flags.contains_key("fault-horizon") {
        bail!("--fault-intensity/--fault-horizon only apply with --fault-seed");
    }
    let faults = FaultPlan::from_events(fault_events);

    let cfg = SimConfig::new(model, parallel, cluster)
        .with_contention(contention)
        .with_engine(engine)
        .with_network(network);
    println!(
        "model={} kind={} W={w} D={d} B={b} N={n} (mini-batch {}){}{}{}",
        model.name,
        kind,
        parallel.minibatch_size(),
        if contention { " [link contention]" } else { "" },
        match engine {
            Engine::Auto => "",
            Engine::Event => " [event engine]",
            Engine::Dag => " [dag engine]",
        },
        if faults.is_empty() {
            String::new()
        } else {
            format!(" [{} fault event(s)]", faults.events.len())
        },
    );

    let iters = get_usize(flags, "iters", 1)?;
    if iters == 0 {
        bail!("--iters must be >= 1");
    }
    if iters == 1 && flags.contains_key("warmup") {
        bail!("--warmup only applies with --iters > 1");
    }
    if iters > 1 {
        // Multi-iteration run: per-iteration times + steady-state stats.
        let warmup = get_usize(flags, "warmup", 1.min(iters - 1))?;
        let mr = sim::simulate_iters_faulted(&cfg, iters, warmup, &faults)?;
        for (k, t) in mr.iter_times.iter().enumerate() {
            let label = if k < warmup { " (warmup)" } else { "" };
            println!("  iter {k}: {:.4} s{label}", t);
        }
        println!(
            "steady state ({} iters): mean {:.4} s, min {:.4} s, max {:.4} s",
            mr.steady.n, mr.steady.mean, mr.steady.min, mr.steady.max
        );
        println!("steady throughput: {:.2} samples/s", mr.steady_throughput);
        println!("total time:        {:.4} s", mr.total_time);
        return Ok(());
    }

    let r = sim::simulate_faulted(&cfg, &faults)?;
    println!("iteration time: {:.4} s", r.iter_time);
    println!("throughput:     {:.2} samples/s", r.throughput);
    println!("bubble frac:    {:.4}", r.bubble_fraction);
    println!(
        "peak memory:    {:.1} GiB ({})",
        r.peak_memory() as f64 / (1u64 << 30) as f64,
        if r.fits(&cluster) { "fits" } else { "OOM" },
    );
    for dev in 0..d {
        println!(
            "  dev {dev}: compute {:.4}s, p2p-blocked {:.4}s, allreduce-blocked {:.4}s",
            r.compute_time[dev], r.p2p_block_time[dev], r.allreduce_block_time[dev]
        );
    }
    Ok(())
}

fn cmd_eval_paper(flags: &HashMap<String, String>) -> Result<()> {
    let only = get(flags, "only").unwrap_or("all");
    for id in only.split(',') {
        for out in bitpipe::eval::run(id.trim())? {
            println!("{}", out.render());
        }
    }
    Ok(())
}

fn cmd_train(flags: &HashMap<String, String>) -> Result<()> {
    let artifacts = get(flags, "artifacts").unwrap_or("artifacts");
    let kind = get_kind(flags)?;
    let d = get_usize(flags, "d", 4)?;
    let n = get_usize(flags, "n", d)?;
    let mut cfg = TrainConfig::new(artifacts, kind, d, n);
    cfg.v = get_usize(flags, "v", kind.default_v())?;
    cfg.steps = get_usize(flags, "steps", 20)?;
    cfg.sync = get_sync(flags)?;
    cfg.seed = get_usize(flags, "seed", 42)? as u64;
    cfg.log_every = get_usize(flags, "log-every", 10)?;
    if let Some(lr) = get(flags, "lr") {
        cfg.adam.lr = lr.parse().with_context(|| format!("--lr {lr}"))?;
    }
    cfg.dataset = match get(flags, "dataset").unwrap_or("synthetic") {
        "synthetic" => DatasetKind::Synthetic,
        "corpus" => DatasetKind::Corpus,
        other => bail!("--dataset must be synthetic|corpus, got {other:?}"),
    };
    cfg.save_to = get(flags, "save").map(Into::into);
    cfg.save_every = get_usize(flags, "save-every", 0)?;
    if cfg.save_every > 0 && cfg.save_to.is_none() {
        bail!("--save-every only applies with --save");
    }
    cfg.resume_from = get(flags, "resume").map(Into::into);

    println!(
        "training: kind={} D={} N={} v={} steps={} dataset={:?} artifacts={}",
        kind, d, n, cfg.v, cfg.steps, cfg.dataset, artifacts
    );
    let report = train::run(&cfg)?;
    println!("\nloss curve:");
    for (i, loss) in report.losses.iter().enumerate() {
        println!("  iter {:4}  loss {:.4}", i + 1, loss);
    }
    let c = &report.counters;
    println!(
        "\ntotals: {:.1}s wall; {} fwd, {} bwd, {} P2P msgs ({:.1} MiB), {} local copies,\n\
         {} allreduces ({:.1} MiB), {} optimizer steps; peak stash {:?}",
        report.total_time,
        c.forwards,
        c.backwards,
        c.p2p_msgs,
        c.p2p_bytes as f64 / (1 << 20) as f64,
        c.local_copies,
        c.allreduces,
        c.allreduce_bytes as f64 / (1 << 20) as f64,
        c.optim_steps,
        report.peak_stash,
    );
    Ok(())
}

fn cmd_inspect(flags: &HashMap<String, String>) -> Result<()> {
    let dir = get(flags, "artifacts").unwrap_or("artifacts");
    let manifest = bitpipe::runtime::Manifest::load(format!("{dir}/manifest.txt"))?;
    // Single-artifact selector: print just that entry, or a proper error
    // naming the available artifacts instead of a panic.
    if let Some(name) = get(flags, "artifact") {
        let meta = manifest.artifact(name).with_context(|| {
            format!(
                "no artifact {name:?} in {dir}/manifest.txt; available: {}",
                manifest.artifact_names().join(" ")
            )
        })?;
        println!("artifact {name} -> {}", meta.file);
        return Ok(());
    }
    println!("artifact directory: {dir}");
    println!(
        "model={} hidden={} seq={} batch={} vocab={} heads={}",
        manifest.model, manifest.hidden, manifest.seq, manifest.batch, manifest.vocab,
        manifest.heads
    );
    println!(
        "n_chunks={} layers_per_chunk={} selfcheck_loss={:.4}",
        manifest.n_chunks, manifest.layers_per_chunk, manifest.selfcheck_loss
    );
    for role in ["embed", "mid", "head"] {
        println!("params.{role} = {} f32", manifest.param_len(role).unwrap_or(0));
    }
    for name in manifest.artifact_names() {
        let meta = manifest
            .artifact(name)
            .with_context(|| format!("manifest lists {name:?} but carries no entry for it"))?;
        println!("artifact {name} -> {}", meta.file);
    }
    for stage in 0..manifest.n_chunks {
        println!(
            "stage {stage}: role={} init={}",
            manifest.role_of_stage(stage),
            manifest.init_file(stage).unwrap_or("<missing>")
        );
    }
    Ok(())
}
