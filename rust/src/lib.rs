//! # BitPipe
//!
//! Production-grade reproduction of *BitPipe: Bidirectional Interleaved
//! Pipeline Parallelism for Accelerating Large Models Training*
//! (Wu, Chen, Yu, 2024) as a three-layer Rust + JAX + Pallas system:
//!
//! * **Layer 3 (this crate)** — the coordination contribution: schedule
//!   generation for BitPipe and all baselines (GPipe, DAPPLE, 1F1B-Int,
//!   GEMS, Chimera, MixPipe), a discrete-event cluster simulator that
//!   regenerates every table/figure of the paper, and a real threaded
//!   training runtime driving AOT-compiled XLA executables.
//! * **Layer 2 (python/compile/model.py)** — a chunked GPT transformer
//!   (embed / middle / head chunks) with explicit per-chunk forward and
//!   backward functions, AOT-lowered to HLO text once at build time.
//! * **Layer 1 (python/compile/kernels/)** — Pallas attention and fused
//!   ops kernels used inside every chunk (interpret mode on CPU).
//!
//! Python never runs at training time: the rust binary loads
//! `artifacts/*.hlo.txt` via PJRT and is self-contained.

pub mod config;
pub mod eval;
pub mod metrics;
pub mod schedule;
pub mod sim;
pub mod util;

// Heavier subsystems (PJRT runtime + threaded trainer) live behind modules
// that only examples/binaries exercising real execution need.
pub mod collective;
pub mod comm;
pub mod runtime;
pub mod train;
