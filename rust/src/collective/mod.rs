//! Gradient all-reduce over the mailbox fabric.
//!
//! Two algorithms:
//!
//! * [`ring_allreduce`] — the bandwidth-optimal ring (reduce-scatter +
//!   all-gather, `2(g-1)` steps moving `len/g` elements each), the
//!   algorithm NCCL uses and the one the simulator's cost model prices;
//! * [`naive_allreduce`] — gather-to-root + broadcast, the baseline the
//!   ablation benches compare against.
//!
//! All participants call the same function with the same `group` (sorted,
//! deduplicated device list) and their own `dev`; the call blocks until the
//! reduced vector is available. `epoch` disambiguates tag reuse across
//! iterations (and across the per-stage collectives of one iteration).

use crate::comm::{CommError, Fabric, Tag};
use anyhow::{ensure, Result};

/// Position of `dev` in `group`.
fn rank_of(dev: usize, group: &[usize]) -> Option<usize> {
    group.iter().position(|&g| g == dev)
}

/// Segment bounds for rank `r` of `g` ranks over `len` elements.
fn segment(len: usize, g: usize, r: usize) -> (usize, usize) {
    let base = len / g;
    let rem = len % g;
    let lo = r * base + r.min(rem);
    let hi = lo + base + usize::from(r < rem);
    (lo, hi)
}

/// Bandwidth-optimal ring all-reduce (sum). In-place on `data`.
pub fn ring_allreduce(
    fabric: &Fabric,
    dev: usize,
    group: &[usize],
    stage: usize,
    epoch: usize,
    data: &mut [f32],
) -> Result<()> {
    let g = group.len();
    ensure!(g >= 1, "empty group");
    let Some(rank) = rank_of(dev, group) else {
        anyhow::bail!("device {dev} not in group {group:?}")
    };
    if g == 1 {
        return Ok(());
    }
    let next = group[(rank + 1) % g];
    let prev = group[(rank + g - 1) % g];
    let len = data.len();

    // Tag scheme: class=Collective, pipe=epoch, stage=stage, mb=step.
    let tag = |from: usize, step: usize| -> Tag {
        let mut t = Tag::coll(from, stage, step);
        t.pipe = epoch;
        t
    };

    // Reduce-scatter: at step s, send segment (rank - s) and accumulate
    // segment (rank - s - 1) received from prev.
    for step in 0..g - 1 {
        let send_seg = (rank + g - step) % g;
        let (lo, hi) = segment(len, g, send_seg);
        fabric.send(next, tag(dev, step), data[lo..hi].to_vec()).map_err(comm_err)?;
        let recv_seg = (rank + g - step - 1) % g;
        let (lo, hi) = segment(len, g, recv_seg);
        let incoming = fabric.recv(dev, tag(prev, step)).map_err(comm_err)?;
        ensure!(incoming.len() == hi - lo, "fragment size mismatch");
        for (d, s) in data[lo..hi].iter_mut().zip(&incoming) {
            *d += s;
        }
    }
    // All-gather: circulate the fully-reduced segments.
    for step in 0..g - 1 {
        let send_seg = (rank + 1 + g - step) % g;
        let (lo, hi) = segment(len, g, send_seg);
        fabric
            .send(next, tag(dev, g - 1 + step), data[lo..hi].to_vec())
            .map_err(comm_err)?;
        let recv_seg = (rank + g - step) % g;
        let (lo, hi) = segment(len, g, recv_seg);
        let incoming = fabric.recv(dev, tag(prev, g - 1 + step)).map_err(comm_err)?;
        ensure!(incoming.len() == hi - lo, "fragment size mismatch");
        data[lo..hi].copy_from_slice(&incoming);
    }
    Ok(())
}

/// Naive all-reduce: everyone sends to the group root, the root reduces
/// and broadcasts. `2(g-1)` full-vector transfers through one node — the
/// bottleneck the ring avoids.
pub fn naive_allreduce(
    fabric: &Fabric,
    dev: usize,
    group: &[usize],
    stage: usize,
    epoch: usize,
    data: &mut [f32],
) -> Result<()> {
    let g = group.len();
    ensure!(g >= 1, "empty group");
    let Some(rank) = rank_of(dev, group) else {
        anyhow::bail!("device {dev} not in group {group:?}")
    };
    if g == 1 {
        return Ok(());
    }
    let root = group[0];
    let tag = |from: usize, step: usize| -> Tag {
        let mut t = Tag::coll(from, stage, step);
        t.pipe = epoch;
        t
    };
    if rank == 0 {
        for &peer in &group[1..] {
            let incoming = fabric.recv(dev, tag(peer, 0)).map_err(comm_err)?;
            ensure!(incoming.len() == data.len(), "size mismatch");
            for (d, s) in data.iter_mut().zip(&incoming) {
                *d += s;
            }
        }
        for &peer in &group[1..] {
            fabric.send(peer, tag(dev, 1), data.to_vec()).map_err(comm_err)?;
        }
    } else {
        fabric.send(root, tag(dev, 0), data.to_vec()).map_err(comm_err)?;
        let reduced = fabric.recv(dev, tag(root, 1)).map_err(comm_err)?;
        data.copy_from_slice(&reduced);
    }
    Ok(())
}

/// Eager pairwise-exchange all-reduce, split into a non-blocking *start*
/// and a blocking *wait* — the shape the schedule IR's
/// `AllReduceStart`/`AllReduceWait` ops require.
///
/// `start` posts the local contribution to every peer and never blocks, so
/// devices may launch their per-stage collectives in *any* order (eager
/// sync fires them from inside pipeline bubbles, and different devices
/// reach different stages' last backwards in different orders — a blocking
/// ring would deadlock there). `wait` receives the `g-1` peer
/// contributions and sums.
///
/// For the bidirectional twin groups of this paper (g = 2) the exchange
/// moves exactly the same bytes as the optimal ring; for larger g it
/// trades `(g-1)/g` extra bandwidth for deadlock-freedom.
pub fn exchange_start(
    fabric: &Fabric,
    dev: usize,
    group: &[usize],
    stage: usize,
    epoch: usize,
    data: &[f32],
) -> Result<()> {
    ensure!(rank_of(dev, group).is_some(), "device {dev} not in group {group:?}");
    for &peer in group {
        if peer == dev {
            continue;
        }
        let mut t = Tag::coll(dev, stage, usize::MAX); // step slot unused
        t.pipe = epoch;
        fabric.send(peer, t, data.to_vec()).map_err(comm_err)?;
    }
    Ok(())
}

/// Blocking completion of [`exchange_start`]: receives every peer's
/// contribution and accumulates into `data`.
pub fn exchange_wait(
    fabric: &Fabric,
    dev: usize,
    group: &[usize],
    stage: usize,
    epoch: usize,
    data: &mut [f32],
) -> Result<()> {
    ensure!(rank_of(dev, group).is_some(), "device {dev} not in group {group:?}");
    for &peer in group {
        if peer == dev {
            continue;
        }
        let mut t = Tag::coll(peer, stage, usize::MAX);
        t.pipe = epoch;
        let incoming = fabric.recv(dev, t).map_err(comm_err)?;
        ensure!(incoming.len() == data.len(), "size mismatch from {peer}");
        for (d, s) in data.iter_mut().zip(&incoming) {
            *d += s;
        }
    }
    Ok(())
}

fn comm_err(e: CommError) -> anyhow::Error {
    anyhow::anyhow!("collective transport: {e}")
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;

    fn run_allreduce(
        g: usize,
        len: usize,
        f: impl Fn(&Fabric, usize, &[usize], usize, usize, &mut [f32]) -> Result<()>
            + Send
            + Sync
            + Copy
            + 'static,
    ) -> Vec<Vec<f32>> {
        let fabric = Fabric::new(g);
        let group: Vec<usize> = (0..g).collect();
        let mut handles = Vec::new();
        for dev in 0..g {
            let fabric = fabric.clone();
            let group = group.clone();
            handles.push(thread::spawn(move || {
                // Device d contributes [d, d, ...] * (position+1 variation).
                let mut data: Vec<f32> =
                    (0..len).map(|i| (dev * len + i) as f32).collect();
                f(&fabric, dev, &group, 0, 0, &mut data).unwrap();
                data
            }));
        }
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    }

    fn expected(g: usize, len: usize) -> Vec<f32> {
        (0..len)
            .map(|i| (0..g).map(|d| (d * len + i) as f32).sum())
            .collect()
    }

    #[test]
    fn ring_matches_sum_various_sizes() {
        for g in [2usize, 3, 4, 8] {
            for len in [1usize, 7, 16, 1000] {
                if len < g {
                    continue;
                }
                let out = run_allreduce(g, len, ring_allreduce);
                let want = expected(g, len);
                for (dev, v) in out.iter().enumerate() {
                    assert_eq!(v, &want, "ring g={g} len={len} dev={dev}");
                }
            }
        }
    }

    #[test]
    fn ring_handles_len_not_divisible_by_group() {
        let out = run_allreduce(4, 10, ring_allreduce);
        let want = expected(4, 10);
        for v in out {
            assert_eq!(v, want);
        }
    }

    #[test]
    fn naive_matches_sum() {
        for g in [2usize, 4] {
            let out = run_allreduce(g, 64, naive_allreduce);
            let want = expected(g, 64);
            for v in out {
                assert_eq!(v, want);
            }
        }
    }

    #[test]
    fn single_member_noop() {
        let fabric = Fabric::new(1);
        let mut data = vec![3.0, 4.0];
        ring_allreduce(&fabric, 0, &[0], 0, 0, &mut data).unwrap();
        assert_eq!(data, vec![3.0, 4.0]);
    }

    #[test]
    fn non_member_rejected() {
        let fabric = Fabric::new(3);
        let mut data = vec![0.0];
        assert!(ring_allreduce(&fabric, 2, &[0, 1], 0, 0, &mut data).is_err());
    }

    #[test]
    fn concurrent_stages_do_not_cross() {
        // Two independent all-reduces (different stages) in flight on the
        // same fabric must not exchange fragments.
        let fabric = Fabric::new(2);
        let mut handles = Vec::new();
        for dev in 0..2usize {
            let fabric = fabric.clone();
            handles.push(thread::spawn(move || {
                let mut a: Vec<f32> = vec![1.0 + dev as f32; 8]; // stage 0
                let mut b: Vec<f32> = vec![10.0 + dev as f32; 8]; // stage 1
                // Interleave: start stage-0, then stage-1, on both devices.
                ring_allreduce(&fabric, dev, &[0, 1], 0, 0, &mut a).unwrap();
                ring_allreduce(&fabric, dev, &[0, 1], 1, 0, &mut b).unwrap();
                (a, b)
            }));
        }
        for h in handles {
            let (a, b) = h.join().unwrap();
            assert_eq!(a, vec![3.0; 8]);
            assert_eq!(b, vec![21.0; 8]);
        }
    }

    #[test]
    fn exchange_matches_sum_and_tolerates_opposite_order() {
        // Device 0 starts stage-0 then stage-1; device 1 starts stage-1
        // then stage-0. A blocking collective would deadlock; the eager
        // exchange must complete with correct sums.
        let fabric = Fabric::new(2);
        let mut handles = Vec::new();
        for dev in 0..2usize {
            let fabric = fabric.clone();
            handles.push(thread::spawn(move || {
                let mut a = vec![1.0 + dev as f32; 6];
                let mut b = vec![10.0 + dev as f32; 6];
                let order = if dev == 0 { [(0usize, 0usize), (1, 1)] } else { [(1, 1), (0, 0)] };
                for &(stage, _) in &order {
                    let d = if stage == 0 { &a } else { &b };
                    exchange_start(&fabric, dev, &[0, 1], stage, 0, d).unwrap();
                }
                for &(stage, _) in &order {
                    let d = if stage == 0 { &mut a } else { &mut b };
                    exchange_wait(&fabric, dev, &[0, 1], stage, 0, d).unwrap();
                }
                (a, b)
            }));
        }
        for h in handles {
            let (a, b) = h.join().unwrap();
            assert_eq!(a, vec![3.0; 6]);
            assert_eq!(b, vec![21.0; 6]);
        }
    }

    #[test]
    fn exchange_group_of_four() {
        let fabric = Fabric::new(4);
        let group: Vec<usize> = (0..4).collect();
        let mut handles = Vec::new();
        for dev in 0..4usize {
            let fabric = fabric.clone();
            let group = group.clone();
            handles.push(thread::spawn(move || {
                let mut d = vec![dev as f32; 5];
                exchange_start(&fabric, dev, &group, 2, 7, &d).unwrap();
                exchange_wait(&fabric, dev, &group, 2, 7, &mut d).unwrap();
                d
            }));
        }
        for h in handles {
            assert_eq!(h.join().unwrap(), vec![6.0; 5]); // 0+1+2+3
        }
    }

    #[test]
    fn segments_cover_exactly() {
        for len in [1usize, 5, 8, 17] {
            for g in [1usize, 2, 3, 5] {
                let mut covered = 0;
                for r in 0..g {
                    let (lo, hi) = segment(len, g, r);
                    assert!(lo <= hi && hi <= len);
                    covered += hi - lo;
                    if r > 0 {
                        assert_eq!(lo, segment(len, g, r - 1).1, "contiguous");
                    }
                }
                assert_eq!(covered, len);
            }
        }
    }
}
