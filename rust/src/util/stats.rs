//! Tiny statistics helpers for benchmarks and metrics.

/// Arithmetic mean; 0.0 for empty input.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population standard deviation; 0.0 for fewer than two samples.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Percentile (nearest-rank on a sorted copy); `p` in [0, 100].
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = ((p / 100.0) * (v.len() as f64 - 1.0)).round() as usize;
    v[rank.min(v.len() - 1)]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_stddev() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(mean(&[2.0, 4.0]), 3.0);
        assert_eq!(stddev(&[5.0]), 0.0);
        let s = stddev(&[2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0]);
        assert!((s - 2.0).abs() < 1e-12);
    }

    #[test]
    fn percentiles() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 50.0), 3.0);
        assert_eq!(percentile(&xs, 100.0), 5.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
    }
}
