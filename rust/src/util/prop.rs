//! Minimal property-testing harness (stand-in for `proptest`, which is not
//! vendored in this build environment). Supports generators over a PRNG,
//! a fixed case budget, and greedy shrinking of failing inputs.
//!
//! The schedule invariants in `rust/tests/prop_schedule.rs` are the main
//! client: configurations are drawn at random, validated, and failures are
//! shrunk to a minimal reproducer before panicking.

use super::prng::Prng;

/// A reusable value generator: draws from a PRNG, and knows how to shrink.
pub struct Gen<T> {
    /// Draw a fresh value.
    pub draw: Box<dyn Fn(&mut Prng) -> T>,
    /// Candidate smaller values (simplest first). Empty = atomic.
    pub shrink: Box<dyn Fn(&T) -> Vec<T>>,
}

impl<T: Clone + 'static> Gen<T> {
    /// Generator over explicit choices (shrinks toward the front).
    pub fn choice(xs: Vec<T>) -> Gen<T>
    where
        T: PartialEq,
    {
        let xs2 = xs.clone();
        Gen {
            draw: Box::new(move |r| r.choose(&xs).clone()),
            shrink: Box::new(move |v| {
                let pos = xs2.iter().position(|x| x == v).unwrap_or(0);
                xs2[..pos].to_vec()
            }),
        }
    }

    /// Map a generator (shrinking maps through).
    pub fn map<U: Clone + 'static>(
        self,
        f: impl Fn(T) -> U + Clone + 'static,
        unf: impl Fn(&U) -> T + 'static,
    ) -> Gen<U> {
        let f2 = f.clone();
        Gen {
            draw: Box::new(move |r| f((self.draw)(r))),
            shrink: Box::new(move |u| (self.shrink)(&unf(u)).into_iter().map(&f2).collect()),
        }
    }
}

/// Integers in `[lo, hi]`, shrinking toward `lo`.
pub fn usize_in(lo: usize, hi: usize) -> Gen<usize> {
    Gen {
        draw: Box::new(move |r| r.range(lo, hi + 1)),
        shrink: Box::new(move |&v| {
            let mut out = Vec::new();
            if v > lo {
                out.push(lo);
                let mid = lo + (v - lo) / 2;
                if mid != lo && mid != v {
                    out.push(mid);
                }
                if v - 1 != lo {
                    out.push(v - 1);
                }
            }
            out
        }),
    }
}

/// Run `cases` random checks of `prop` over values from `gen`; on failure,
/// shrink to a (locally) minimal counterexample and panic with it.
///
/// `prop` returns `Err(reason)` on failure.
pub fn forall<T: Clone + std::fmt::Debug + 'static>(
    seed: u64,
    cases: usize,
    gen: &Gen<T>,
    prop: impl Fn(&T) -> Result<(), String>,
) {
    let mut rng = Prng::new(seed);
    for case in 0..cases {
        let v = (gen.draw)(&mut rng);
        if let Err(first_err) = prop(&v) {
            // Greedy shrink.
            let mut cur = v;
            let mut err = first_err;
            let mut budget = 1000;
            'outer: while budget > 0 {
                for cand in (gen.shrink)(&cur) {
                    budget -= 1;
                    if let Err(e) = prop(&cand) {
                        cur = cand;
                        err = e;
                        continue 'outer;
                    }
                    if budget == 0 {
                        break;
                    }
                }
                break;
            }
            panic!(
                "property failed (case {case}, seed {seed})\n  minimal input: {cur:?}\n  error: {err}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        forall(1, 200, &usize_in(0, 100), |&x| {
            if x <= 100 {
                Ok(())
            } else {
                Err("impossible".into())
            }
        });
    }

    #[test]
    fn failing_property_shrinks_to_boundary() {
        let result = std::panic::catch_unwind(|| {
            forall(2, 500, &usize_in(0, 1000), |&x| {
                if x < 50 {
                    Ok(())
                } else {
                    Err(format!("{x} too big"))
                }
            });
        });
        let msg = match result {
            Err(e) => *e.downcast::<String>().unwrap(),
            Ok(()) => panic!("property should have failed"),
        };
        // Greedy shrink should land on exactly the boundary value 50.
        assert!(msg.contains("minimal input: 50"), "shrink landed elsewhere: {msg}");
    }

    #[test]
    fn choice_generator_draws_members() {
        let g = Gen::choice(vec![2usize, 4, 8]);
        let mut r = Prng::new(5);
        for _ in 0..100 {
            let v = (g.draw)(&mut r);
            assert!([2, 4, 8].contains(&v));
        }
        assert_eq!((g.shrink)(&8), vec![2, 4]);
        assert!((g.shrink)(&2).is_empty());
    }
}
