//! Small shared utilities: a deterministic PRNG (no external `rand`), a
//! minimal property-testing harness (no external `proptest`), simple
//! statistics, and table formatting for the eval harness.

mod prng;
mod prop;
mod stats;
mod table;

pub use prng::Prng;
pub use prop::{forall, usize_in, Gen};
pub use stats::{mean, percentile, stddev};
pub use table::Table;
