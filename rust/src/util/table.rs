//! Fixed-width text table formatting for the paper-eval harness output.

/// Column-aligned text table builder.
#[derive(Debug, Default, Clone)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new<S: Into<String>>(header: Vec<S>) -> Self {
        Table { header: header.into_iter().map(Into::into).collect(), rows: Vec::new() }
    }

    pub fn row<S: Into<String>>(&mut self, cells: Vec<S>) -> &mut Self {
        let cells: Vec<String> = cells.into_iter().map(Into::into).collect();
        assert_eq!(cells.len(), self.header.len(), "row width mismatch");
        self.rows.push(cells);
        self
    }

    pub fn n_rows(&self) -> usize {
        self.rows.len()
    }

    /// Render with a separator under the header.
    pub fn render(&self) -> String {
        let ncols = self.header.len();
        let mut width = vec![0usize; ncols];
        for (i, h) in self.header.iter().enumerate() {
            width[i] = h.len();
        }
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                width[i] = width[i].max(c.len());
            }
        }
        let fmt_row = |cells: &[String]| -> String {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:>w$}", c, w = width[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        let mut out = fmt_row(&self.header);
        out.push('\n');
        out.push_str(&"-".repeat(width.iter().sum::<usize>() + 2 * (ncols - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new(vec!["name", "x"]);
        t.row(vec!["a", "1"]).row(vec!["longer", "22"]);
        let s = t.render();
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 4);
        // All rows same width.
        assert_eq!(lines[0].len(), lines[2].len());
        assert_eq!(lines[2].len(), lines[3].len());
    }

    #[test]
    #[should_panic(expected = "row width mismatch")]
    fn rejects_ragged_rows() {
        let mut t = Table::new(vec!["a", "b"]);
        t.row(vec!["only-one"]);
    }
}
