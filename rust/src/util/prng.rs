//! Deterministic 64-bit PRNG (xoshiro256**), plus convenience samplers.
//! Used by the data pipeline (synthetic batches), the simulator (jittered
//! cost models), and the property-test harness. No external dependencies.

/// xoshiro256** by Blackman & Vigna (public domain reference constants).
#[derive(Debug, Clone)]
pub struct Prng {
    s: [u64; 4],
}

impl Prng {
    /// Seed via SplitMix64 so any u64 seed gives a well-mixed state.
    pub fn new(seed: u64) -> Self {
        let mut x = seed;
        let mut next = || {
            x = x.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = x;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Prng { s: [next(), next(), next(), next()] }
    }

    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in `[0, n)`. Rejection-free (Lemire's multiply-shift).
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Uniform usize in `[lo, hi)`.
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(lo < hi);
        lo + self.below((hi - lo) as u64) as usize
    }

    /// Uniform f64 in `[0, 1)`.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = (self.f64()).max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Pick one element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.range(0, xs.len())]
    }

    /// Boolean with probability `p`.
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Prng::new(42);
        let mut b = Prng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        assert_ne!(Prng::new(1).next_u64(), Prng::new(2).next_u64());
    }

    #[test]
    fn below_in_range() {
        let mut r = Prng::new(7);
        for _ in 0..10_000 {
            assert!(r.below(13) < 13);
        }
    }

    #[test]
    fn f64_unit_interval_and_roughly_uniform() {
        let mut r = Prng::new(3);
        let mut sum = 0.0;
        const N: usize = 100_000;
        for _ in 0..N {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        let mean = sum / N as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Prng::new(9);
        const N: usize = 100_000;
        let xs: Vec<f64> = (0..N).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / N as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / N as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }
}
