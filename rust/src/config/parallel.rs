//! Parallelism configuration: the paper's Table 1 symbols (W, D, B, N)
//! plus schedule selection.

use crate::schedule::{ScheduleConfig, ScheduleKind, SyncPolicy};
use anyhow::{ensure, Result};

/// Full parallel layout for one run.
#[derive(Debug, Clone, Copy)]
pub struct ParallelConfig {
    /// Schedule kind (BitPipe or a baseline).
    pub kind: ScheduleKind,
    /// Replicated pipelines (data parallelism width), paper's W.
    pub w: usize,
    /// Pipeline devices per pipeline, paper's D.
    pub d: usize,
    /// Micro-batch size, paper's B.
    pub b: usize,
    /// Micro-batches per iteration per pipeline, paper's N.
    pub n: usize,
    /// Chunks per device per pipe (paper's v; Appendix A).
    pub v: usize,
    /// Gradient sync policy (eager = paper default, lazy = w/o E ablation).
    pub sync: SyncPolicy,
    /// Appendix B early forwarding for N > D.
    pub early_forward: bool,
}

impl ParallelConfig {
    pub fn new(kind: ScheduleKind, w: usize, d: usize, b: usize, n: usize) -> Self {
        ParallelConfig {
            kind,
            w,
            d,
            b,
            n,
            v: kind.default_v(),
            sync: SyncPolicy::Eager,
            early_forward: true,
        }
    }

    /// Total devices P = W * D (paper Table 1).
    pub fn total_devices(&self) -> usize {
        self.w * self.d
    }

    /// Mini-batch size B-hat = B * N * W (paper Table 1).
    pub fn minibatch_size(&self) -> usize {
        self.b * self.n * self.w
    }

    /// The schedule sub-config.
    pub fn schedule(&self) -> ScheduleConfig {
        ScheduleConfig::new(self.kind, self.d, self.n)
            .with_v(self.v)
            .with_sync(self.sync)
            .with_early_forward(self.early_forward)
    }

    pub fn validate(&self) -> Result<()> {
        ensure!(self.w >= 1, "W >= 1");
        ensure!(self.d >= 2, "D >= 2");
        ensure!(self.b >= 1, "B >= 1");
        ensure!(self.n >= 1, "N >= 1");
        if self.kind.bidirectional() {
            ensure!(self.d % 2 == 0, "bidirectional schedules need even D");
            ensure!(self.n % 2 == 0, "bidirectional schedules need even N");
        }
        if self.n > self.d {
            ensure!(self.n % self.d == 0, "N must be a multiple of D when N > D");
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn derived_quantities() {
        // Paper main-results setting: BERT-64, W=1, D=8, B=4, N=D => B-hat=32.
        let p = ParallelConfig::new(ScheduleKind::BitPipe, 1, 8, 4, 8);
        assert_eq!(p.total_devices(), 8);
        assert_eq!(p.minibatch_size(), 32);
        p.validate().unwrap();
    }

    #[test]
    fn rejects_bad_layouts() {
        assert!(ParallelConfig::new(ScheduleKind::BitPipe, 1, 7, 1, 8).validate().is_err());
        assert!(ParallelConfig::new(ScheduleKind::BitPipe, 1, 8, 1, 7).validate().is_err());
        assert!(ParallelConfig::new(ScheduleKind::Dapple, 1, 4, 1, 10).validate().is_err());
        assert!(ParallelConfig::new(ScheduleKind::Dapple, 0, 4, 1, 8).validate().is_err());
    }

    #[test]
    fn schedule_subconfig_carries_knobs() {
        let mut p = ParallelConfig::new(ScheduleKind::BitPipe, 2, 4, 1, 8);
        p.sync = SyncPolicy::Lazy;
        p.early_forward = false;
        let s = p.schedule();
        assert_eq!(s.kind, ScheduleKind::BitPipe);
        assert_eq!(s.sync, SyncPolicy::Lazy);
        assert!(!s.early_forward);
        assert_eq!(s.v, 2);
    }
}
