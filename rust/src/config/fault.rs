//! Fault injection: an explicit, time-ordered trace of degradation events
//! the event engine replays against a run, plus a seeded generator and a
//! checkpoint-restart recovery price model.
//!
//! A [`FaultPlan`] is *data*, not behaviour: every event carries absolute
//! virtual-time boundaries, so the same plan replayed against the same
//! schedule is bitwise deterministic — across repeated runs and across
//! sweep thread counts. [`FaultPlan::random`] expands a `(seed,
//! intensity)` pair into such an explicit trace; the candidate event
//! stream is drawn from the seed *independently of intensity*, and
//! intensity only (a) takes a longer prefix of that stream and (b) scales
//! severities monotonically, so a higher-intensity plan strictly dominates
//! a lower one event-for-event. Combined with the engine's degrade-only
//! semantics this makes faulted makespans monotone in intensity — the
//! invariant the resilience sweep and `rust/tests/faults.rs` pin.
//!
//! All faults are *degrade-only*: link rates multiply by `mult ∈ (0, 1]`,
//! device compute by `mult >= 1`, and a stall only pushes a device clock
//! forward. An empty plan is bit-identical to a fault-free run on every
//! backend and mode (the engine takes the historical code paths verbatim
//! when no fault state is attached).

use super::cluster::LinkKind;
use crate::util::prng::Prng;
use anyhow::{bail, ensure, Context, Result};

/// Which physical links a [`FaultEvent::LinkDegrade`] hits.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum FaultTarget {
    /// Every link of one interconnect class (e.g. all Infiniband NICs —
    /// the "flapping NIC fabric" scenario).
    LinkClass(LinkKind),
    /// The links between one device pair, both directions (an NVLink
    /// brownout, or the NIC path between two specific nodes).
    LinkPair { a: usize, b: usize },
}

/// One fault of a [`FaultPlan`]. Times are absolute virtual seconds of
/// the simulated run (the same clock the engine's heap runs on).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum FaultEvent {
    /// The targeted links run at `mult` of their healthy bandwidth over
    /// `[t_start, t_end)` (`mult ∈ (0, 1]`; wire latency is propagation
    /// delay and stays unscaled). Overlapping degradations of the same
    /// link multiply.
    LinkDegrade { target: FaultTarget, mult: f64, t_start: f64, t_end: f64 },
    /// Device `dev`'s compute runs `mult >= 1` times slower over
    /// `[t_start, t_end)`. Applies at each compute op's *dispatch*: an op
    /// priced before the boundary keeps its price (see the engine docs).
    DeviceSlow { dev: usize, mult: f64, t_start: f64, t_end: f64 },
    /// Device `dev` freezes at `t` for `dur` seconds: its clock is pinned
    /// to at least `t + dur`. Also the plan's proxy for a device
    /// *failure* — [`RecoveryModel`] prices checkpoint-restart at stall
    /// times ([`FaultPlan::stall_times`]).
    DeviceStall { dev: usize, t: f64, dur: f64 },
}

impl FaultEvent {
    /// First boundary time of the event.
    pub fn start(&self) -> f64 {
        match *self {
            FaultEvent::LinkDegrade { t_start, .. }
            | FaultEvent::DeviceSlow { t_start, .. } => t_start,
            FaultEvent::DeviceStall { t, .. } => t,
        }
    }

    /// Parse one CLI fault spec:
    ///
    /// * `link:ib:0.25@2.0..5.0` — all links of a class (`local`,
    ///   `nvlink`, `ib`) at 0.25x bandwidth over [2.0, 5.0)s
    /// * `link:0-1:0.5@1.0..2.0` — the device pair 0<->1
    /// * `dev:3:slow:1.5@2.0..5.0` — device 3 compute 1.5x slower
    /// * `dev:3:stall@1.5+0.4` — device 3 frozen at t=1.5s for 0.4s
    pub fn parse(spec: &str) -> Result<FaultEvent> {
        let err = || format!("bad fault spec {spec:?}");
        let (head, rest) = spec.split_once(':').with_context(err)?;
        match head {
            "link" => {
                let (sel, rest) = rest.split_once(':').with_context(err)?;
                let (mult, window) = rest.split_once('@').with_context(err)?;
                let (t0, t1) = window.split_once("..").with_context(err)?;
                let target = match sel {
                    "local" => FaultTarget::LinkClass(LinkKind::Local),
                    "nvlink" => FaultTarget::LinkClass(LinkKind::NvLink),
                    "ib" => FaultTarget::LinkClass(LinkKind::InfiniBand),
                    pair => {
                        let (a, b) = pair.split_once('-').with_context(err)?;
                        FaultTarget::LinkPair { a: a.parse()?, b: b.parse()? }
                    }
                };
                Ok(FaultEvent::LinkDegrade {
                    target,
                    mult: mult.parse()?,
                    t_start: t0.parse()?,
                    t_end: t1.parse()?,
                })
            }
            "dev" => {
                let (dev, rest) = rest.split_once(':').with_context(err)?;
                let dev: usize = dev.parse()?;
                if let Some(rest) = rest.strip_prefix("slow:") {
                    let (mult, window) = rest.split_once('@').with_context(err)?;
                    let (t0, t1) = window.split_once("..").with_context(err)?;
                    Ok(FaultEvent::DeviceSlow {
                        dev,
                        mult: mult.parse()?,
                        t_start: t0.parse()?,
                        t_end: t1.parse()?,
                    })
                } else if let Some(rest) = rest.strip_prefix("stall@") {
                    let (t, dur) = rest.split_once('+').with_context(err)?;
                    Ok(FaultEvent::DeviceStall { dev, t: t.parse()?, dur: dur.parse()? })
                } else {
                    bail!("{}: expected dev:<D>:slow:... or dev:<D>:stall@...", err())
                }
            }
            _ => bail!("{}: expected link:... or dev:...", err()),
        }
    }
}

/// An explicit, time-ordered trace of fault events for one simulated run.
/// Built directly, parsed from CLI specs ([`FaultPlan::parse`]), or
/// expanded from a seed ([`FaultPlan::random`]).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct FaultPlan {
    /// Events, ordered by start time (ties keep insertion order).
    pub events: Vec<FaultEvent>,
}

/// Cap on the seeded generator's candidate stream. Real transient-fault
/// scenarios name a handful of incidents per run, not a storm; the cap
/// also bounds the engine's per-boundary recompute work.
pub const MAX_RANDOM_FAULTS: usize = 16;

impl FaultPlan {
    /// A plan with no events — bit-identical to a fault-free run.
    pub fn empty() -> FaultPlan {
        FaultPlan::default()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Build from explicit events, sorting by start time (stable: equal
    /// starts keep the given order).
    pub fn from_events(mut events: Vec<FaultEvent>) -> FaultPlan {
        events.sort_by(|a, b| a.start().total_cmp(&b.start()));
        FaultPlan { events }
    }

    /// Parse a comma-separated list of CLI fault specs (see
    /// [`FaultEvent::parse`]).
    pub fn parse(specs: &str) -> Result<FaultPlan> {
        let mut events = Vec::new();
        for spec in specs.split(',').filter(|s| !s.trim().is_empty()) {
            events.push(FaultEvent::parse(spec.trim())?);
        }
        Ok(FaultPlan::from_events(events))
    }

    /// Expand `(seed, intensity)` into an explicit trace over
    /// `[0, horizon)` seconds on an `n_devices`-device cluster.
    ///
    /// Deterministic and *prefix-monotone in intensity*: the candidate
    /// stream (times, kinds, targets, base severities) is drawn from the
    /// seed alone; intensity selects a monotone prefix of it
    /// (`ceil(intensity · MAX_RANDOM_FAULTS)` events, capped) and scales
    /// each severity monotonically — link rate `1/(1 + intensity·s)`,
    /// compute mult `1 + intensity·s`, stall length `intensity·s·h/8`.
    /// `intensity = 0` is the empty plan.
    pub fn random(seed: u64, intensity: f64, horizon: f64, n_devices: usize) -> Result<FaultPlan> {
        ensure!(
            intensity.is_finite() && intensity >= 0.0,
            "fault intensity must be finite and >= 0 (got {intensity})"
        );
        ensure!(
            horizon.is_finite() && horizon > 0.0,
            "fault horizon must be finite and > 0 (got {horizon})"
        );
        ensure!(n_devices >= 1, "need at least one device");
        let mut rng = Prng::new(seed);
        // Fixed candidate stream: every draw happens regardless of
        // intensity, so two intensities share the exact same candidates.
        let mut candidates = Vec::with_capacity(MAX_RANDOM_FAULTS);
        for _ in 0..MAX_RANDOM_FAULTS {
            let t0 = rng.f64() * 0.9 * horizon;
            let t1 = (t0 + (0.05 + 0.25 * rng.f64()) * horizon).min(horizon);
            let kind = rng.below(3);
            let dev = rng.range(0, n_devices);
            let pair = rng.chance(0.5);
            let peer = rng.range(0, n_devices.max(2));
            let sev = 0.25 + 0.75 * rng.f64();
            candidates.push((t0, t1, kind, dev, pair, peer, sev));
        }
        let count = ((intensity * MAX_RANDOM_FAULTS as f64).ceil() as usize).min(MAX_RANDOM_FAULTS);
        let mut events = Vec::with_capacity(count);
        for &(t0, t1, kind, dev, pair, peer, sev) in candidates.iter().take(count) {
            events.push(match kind {
                0 => {
                    let target = if pair && n_devices >= 2 {
                        let b = if peer == dev { (peer + 1) % n_devices } else { peer };
                        FaultTarget::LinkPair { a: dev, b }
                    } else {
                        FaultTarget::LinkClass(LinkKind::InfiniBand)
                    };
                    FaultEvent::LinkDegrade {
                        target,
                        mult: 1.0 / (1.0 + intensity * sev),
                        t_start: t0,
                        t_end: t1,
                    }
                }
                1 => FaultEvent::DeviceSlow {
                    dev,
                    mult: 1.0 + intensity * sev,
                    t_start: t0,
                    t_end: t1,
                },
                _ => FaultEvent::DeviceStall {
                    dev,
                    t: t0,
                    dur: intensity * sev * horizon / 8.0,
                },
            });
        }
        Ok(FaultPlan::from_events(events))
    }

    /// Check every event against an `n_devices`-device cluster. The
    /// engine assumes a validated plan; [`crate::sim::simulate_faulted`]
    /// calls this on entry.
    pub fn validate(&self, n_devices: usize) -> Result<()> {
        for (i, ev) in self.events.iter().enumerate() {
            let check_dev = |dev: usize| -> Result<()> {
                ensure!(dev < n_devices, "fault {i}: device {dev} out of range (P={n_devices})");
                Ok(())
            };
            match *ev {
                FaultEvent::LinkDegrade { target, mult, t_start, t_end } => {
                    ensure!(
                        mult.is_finite() && mult > 0.0 && mult <= 1.0,
                        "fault {i}: link mult must be in (0, 1] (got {mult}) — faults degrade"
                    );
                    ensure!(
                        t_start.is_finite() && t_start >= 0.0 && t_end.is_finite(),
                        "fault {i}: window times must be finite and >= 0"
                    );
                    ensure!(t_end > t_start, "fault {i}: empty window [{t_start}, {t_end})");
                    if let FaultTarget::LinkPair { a, b } = target {
                        check_dev(a)?;
                        check_dev(b)?;
                        ensure!(a != b, "fault {i}: link pair {a}-{b} is not a link");
                    }
                }
                FaultEvent::DeviceSlow { dev, mult, t_start, t_end } => {
                    check_dev(dev)?;
                    ensure!(
                        mult.is_finite() && mult >= 1.0,
                        "fault {i}: slow mult must be >= 1 (got {mult}) — faults degrade"
                    );
                    ensure!(
                        t_start.is_finite() && t_start >= 0.0 && t_end.is_finite(),
                        "fault {i}: window times must be finite and >= 0"
                    );
                    ensure!(t_end > t_start, "fault {i}: empty window [{t_start}, {t_end})");
                }
                FaultEvent::DeviceStall { dev, t, dur } => {
                    check_dev(dev)?;
                    ensure!(
                        t.is_finite() && t >= 0.0 && dur.is_finite() && dur >= 0.0,
                        "fault {i}: stall needs finite t >= 0 and dur >= 0"
                    );
                }
            }
        }
        Ok(())
    }

    /// Start times of every [`FaultEvent::DeviceStall`] — the plan's
    /// device-failure proxies, which [`RecoveryModel::wall_clock`] prices
    /// as checkpoint-restart events.
    pub fn stall_times(&self) -> Vec<f64> {
        self.events
            .iter()
            .filter_map(|ev| match *ev {
                FaultEvent::DeviceStall { t, .. } => Some(t),
                _ => None,
            })
            .collect()
    }
}

/// Deterministic checkpoint-restart price model: periodic checkpoints tax
/// every interval, and each device failure rolls the run back to its last
/// completed checkpoint and pays a reload. Used by the resilience sweep
/// to report recovery overhead next to raw throughput-retained.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RecoveryModel {
    /// Useful-work seconds between checkpoints (> 0).
    pub ckpt_interval: f64,
    /// Seconds to write one checkpoint (>= 0).
    pub ckpt_cost: f64,
    /// Seconds to restart and reload the last checkpoint after a failure
    /// (>= 0).
    pub reload_cost: f64,
}

impl Default for RecoveryModel {
    /// Checkpoint every 10 iterations' worth of the golden-grid BERT
    /// iteration (~0.1 s each), 20% of an interval to write, half an
    /// interval to reload — round numbers in the regime the testbed's
    /// NVMe-vs-HBM bandwidth ratio implies.
    fn default() -> Self {
        RecoveryModel { ckpt_interval: 1.0, ckpt_cost: 0.2, reload_cost: 0.5 }
    }
}

impl RecoveryModel {
    /// Wall-clock seconds to complete `work` seconds of useful training
    /// given failures at the (wall-clock) times in `failures`. Closed
    /// form, deterministic: failures are sorted with `f64::total_cmp`,
    /// each one rolls progress back to the last checkpoint boundary and
    /// pays `reload_cost`; checkpointing itself stretches useful work by
    /// `(interval + ckpt_cost) / interval`. Failures landing after the
    /// run finishes (or during a reload) are ignored.
    pub fn wall_clock(&self, work: f64, failures: &[f64]) -> f64 {
        assert!(self.ckpt_interval > 0.0, "checkpoint interval must be > 0");
        let overhead = (self.ckpt_interval + self.ckpt_cost) / self.ckpt_interval;
        let mut sorted: Vec<f64> = failures.to_vec();
        sorted.sort_by(f64::total_cmp);
        let mut wall = 0.0;
        let mut progress = 0.0;
        for &fw in &sorted {
            if fw <= wall {
                continue; // struck during a reload / before the restart
            }
            if fw >= wall + (work - progress) * overhead {
                break; // the run finishes before this failure lands
            }
            progress += (fw - wall) / overhead;
            progress = (progress / self.ckpt_interval).floor() * self.ckpt_interval;
            wall = fw + self.reload_cost;
        }
        wall + (work - progress) * overhead
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_every_spec_shape() {
        let p = FaultPlan::parse(
            "link:ib:0.25@2.0..5.0,link:0-1:0.5@1.0..2.0,dev:3:slow:1.5@2.0..5.0,dev:3:stall@1.5+0.4",
        )
        .unwrap();
        assert_eq!(p.events.len(), 4);
        // from_events sorted by start time.
        assert_eq!(
            p.events[0],
            FaultEvent::LinkDegrade {
                target: FaultTarget::LinkPair { a: 0, b: 1 },
                mult: 0.5,
                t_start: 1.0,
                t_end: 2.0
            }
        );
        assert_eq!(p.events[1], FaultEvent::DeviceStall { dev: 3, t: 1.5, dur: 0.4 });
        assert!(matches!(
            p.events[2],
            FaultEvent::LinkDegrade { target: FaultTarget::LinkClass(LinkKind::InfiniBand), .. }
        ));
        p.validate(8).unwrap();
    }

    #[test]
    fn parse_rejects_garbage() {
        for bad in [
            "nope",
            "link:ib:0.25",
            "link:ib",
            "dev:3:stall@1.5",
            "dev:3:freeze@1.5+0.4",
            "link:0:0.5@1.0..2.0",
        ] {
            assert!(FaultEvent::parse(bad).is_err(), "{bad} parsed");
        }
    }

    #[test]
    fn validate_enforces_degrade_only() {
        let speedup = FaultPlan::from_events(vec![FaultEvent::LinkDegrade {
            target: FaultTarget::LinkClass(LinkKind::InfiniBand),
            mult: 1.5,
            t_start: 0.0,
            t_end: 1.0,
        }]);
        assert!(speedup.validate(4).is_err());
        let fast_dev = FaultPlan::from_events(vec![FaultEvent::DeviceSlow {
            dev: 0,
            mult: 0.5,
            t_start: 0.0,
            t_end: 1.0,
        }]);
        assert!(fast_dev.validate(4).is_err());
        let out_of_range =
            FaultPlan::from_events(vec![FaultEvent::DeviceStall { dev: 9, t: 0.0, dur: 1.0 }]);
        assert!(out_of_range.validate(4).is_err());
        let empty_window = FaultPlan::from_events(vec![FaultEvent::DeviceSlow {
            dev: 0,
            mult: 2.0,
            t_start: 1.0,
            t_end: 1.0,
        }]);
        assert!(empty_window.validate(4).is_err());
    }

    #[test]
    fn random_is_deterministic_and_prefix_monotone() {
        let a = FaultPlan::random(7, 0.5, 10.0, 8).unwrap();
        let b = FaultPlan::random(7, 0.5, 10.0, 8).unwrap();
        assert_eq!(a, b);
        assert!(FaultPlan::random(7, 0.0, 10.0, 8).unwrap().is_empty());
        // Higher intensity keeps every lower-intensity event's identity
        // (kind, target, window) and only worsens severities / appends.
        let lo = FaultPlan::random(7, 0.25, 10.0, 8).unwrap();
        let hi = FaultPlan::random(7, 1.0, 10.0, 8).unwrap();
        assert!(hi.events.len() >= lo.events.len());
        lo.validate(8).unwrap();
        hi.validate(8).unwrap();
        for ev in &lo.events {
            let start = ev.start();
            let twin = hi.events.iter().find(|h| h.start() == start).expect("prefix event kept");
            match (*ev, *twin) {
                (
                    FaultEvent::LinkDegrade { mult: m_lo, target: t_lo, .. },
                    FaultEvent::LinkDegrade { mult: m_hi, target: t_hi, .. },
                ) => {
                    assert_eq!(t_lo, t_hi);
                    assert!(m_hi <= m_lo);
                }
                (
                    FaultEvent::DeviceSlow { mult: m_lo, dev: d_lo, .. },
                    FaultEvent::DeviceSlow { mult: m_hi, dev: d_hi, .. },
                ) => {
                    assert_eq!(d_lo, d_hi);
                    assert!(m_hi >= m_lo);
                }
                (
                    FaultEvent::DeviceStall { dur: d_lo, .. },
                    FaultEvent::DeviceStall { dur: d_hi, .. },
                ) => assert!(d_hi >= d_lo),
                (a, b) => panic!("event kind changed with intensity: {a:?} vs {b:?}"),
            }
        }
    }

    #[test]
    fn random_different_seeds_differ() {
        let a = FaultPlan::random(1, 0.5, 10.0, 8).unwrap();
        let b = FaultPlan::random(2, 0.5, 10.0, 8).unwrap();
        assert_ne!(a, b);
    }

    #[test]
    fn recovery_no_failures_is_pure_checkpoint_tax() {
        let m = RecoveryModel { ckpt_interval: 1.0, ckpt_cost: 0.2, reload_cost: 0.5 };
        let t = m.wall_clock(10.0, &[]);
        assert!((t - 12.0).abs() < 1e-12, "{t}");
    }

    #[test]
    fn recovery_failure_rolls_back_to_boundary() {
        let m = RecoveryModel { ckpt_interval: 1.0, ckpt_cost: 0.0, reload_cost: 0.5 };
        // Failure at wall 2.5 (progress 2.5): roll back to 2.0, pay 0.5
        // reload, then 8.0 of work remain -> 2.5 + 0.5 + 8.0 = 11.0.
        let t = m.wall_clock(10.0, &[2.5]);
        assert!((t - 11.0).abs() < 1e-12, "{t}");
        // A failure after completion changes nothing.
        let t = m.wall_clock(10.0, &[99.0]);
        assert!((t - 10.0).abs() < 1e-12, "{t}");
    }

    #[test]
    fn recovery_more_failures_never_faster() {
        let m = RecoveryModel::default();
        let one = m.wall_clock(10.0, &[3.0]);
        let two = m.wall_clock(10.0, &[3.0, 7.0]);
        assert!(two >= one, "{two} < {one}");
        assert!(one >= m.wall_clock(10.0, &[]));
    }

    #[test]
    fn stall_times_are_the_failure_proxies() {
        let p = FaultPlan::parse("dev:0:stall@1.0+0.1,link:ib:0.5@0.0..1.0,dev:1:stall@3.0+0.1")
            .unwrap();
        assert_eq!(p.stall_times(), vec![1.0, 3.0]);
    }
}
