//! Configuration system: model dimensions (paper Table 3), parallelism
//! layout (W, D, B, N — paper Table 1's symbols), and cluster hardware
//! (paper's testbed: A800 nodes, NVLink intra-node, Infiniband inter-node).
//!
//! Configs are plain structs with named presets plus a tiny `key=value`
//! file/CLI parser (`parse_kv`) so the launcher needs no external crates.

mod cluster;
mod fault;
mod model;
mod parallel;

pub use cluster::{
    ClusterConfig, IbModel, LinkId, LinkKind, MappingPolicy, ResourceId, NO_RESOURCE,
};
pub use fault::{FaultEvent, FaultPlan, FaultTarget, RecoveryModel, MAX_RANDOM_FAULTS};
pub use model::{ModelConfig, BERT_64, GPT_96, GPT_TINY, GPT_SMALL};
pub use parallel::ParallelConfig;

use anyhow::{bail, Context, Result};
use std::collections::HashMap;

/// Parse `key=value` pairs (one per line in files; `--set k=v` on the CLI).
/// `#` starts a comment; blank lines ignored.
pub fn parse_kv(text: &str) -> Result<HashMap<String, String>> {
    let mut out = HashMap::new();
    for (lineno, raw) in text.lines().enumerate() {
        let line = raw.split('#').next().unwrap_or("").trim();
        if line.is_empty() {
            continue;
        }
        let Some((k, v)) = line.split_once('=') else {
            bail!("line {}: expected key=value, got {raw:?}", lineno + 1);
        };
        out.insert(k.trim().to_string(), v.trim().to_string());
    }
    Ok(out)
}

/// Typed lookup helpers over a parsed kv map.
pub trait KvExt {
    fn get_usize(&self, key: &str, default: usize) -> Result<usize>;
    fn get_f64(&self, key: &str, default: f64) -> Result<f64>;
    fn get_bool(&self, key: &str, default: bool) -> Result<bool>;
    fn get_str(&self, key: &str, default: &str) -> String;
}

impl KvExt for HashMap<String, String> {
    fn get_usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("{key}={v}: not an integer")),
        }
    }
    fn get_f64(&self, key: &str, default: f64) -> Result<f64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("{key}={v}: not a float")),
        }
    }
    fn get_bool(&self, key: &str, default: bool) -> Result<bool> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => match v.as_str() {
                "true" | "1" | "yes" => Ok(true),
                "false" | "0" | "no" => Ok(false),
                _ => bail!("{key}={v}: not a bool"),
            },
        }
    }
    fn get_str(&self, key: &str, default: &str) -> String {
        self.get(key).cloned().unwrap_or_else(|| default.to_string())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_kv_basics() {
        let m = parse_kv("a=1\n# comment\nb = two # trailing\n\nc=3.5").unwrap();
        assert_eq!(m.get_usize("a", 0).unwrap(), 1);
        assert_eq!(m.get_str("b", ""), "two");
        assert_eq!(m.get_f64("c", 0.0).unwrap(), 3.5);
        assert_eq!(m.get_usize("missing", 7).unwrap(), 7);
    }

    #[test]
    fn parse_kv_rejects_garbage() {
        assert!(parse_kv("not a pair").is_err());
        let m = parse_kv("x=abc").unwrap();
        assert!(m.get_usize("x", 0).is_err());
        assert!(m.get_bool("x", false).is_err());
    }

    #[test]
    fn bool_spellings() {
        let m = parse_kv("a=true\nb=0\nc=yes").unwrap();
        assert!(m.get_bool("a", false).unwrap());
        assert!(!m.get_bool("b", true).unwrap());
        assert!(m.get_bool("c", false).unwrap());
    }
}
