//! Transformer model configurations, including the paper's two benchmark
//! models (Table 3) and the small models used by the real training runtime.

use anyhow::{ensure, Result};

/// GPT/BERT-style transformer dimensions. Parameter and FLOP counts follow
//  the standard Megatron-LM accounting.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ModelConfig {
    pub name: &'static str,
    /// Transformer layers (paper Table 3 "# Layers").
    pub n_layers: usize,
    /// Attention heads.
    pub n_heads: usize,
    /// Hidden size H.
    pub hidden: usize,
    /// Sequence length S.
    pub seq_len: usize,
    /// Vocabulary size (Megatron GPT-2 BPE padded: 50304; BERT: 30592).
    pub vocab: usize,
    /// Bytes per parameter/activation element (2 = mixed precision).
    pub dtype_bytes: usize,
}

/// BERT-64 (5B): 64 layers, 64 heads, hidden 2560, seq 512 (paper Table 3).
pub const BERT_64: ModelConfig = ModelConfig {
    name: "bert-64",
    n_layers: 64,
    n_heads: 64,
    hidden: 2560,
    seq_len: 512,
    vocab: 30592,
    dtype_bytes: 2,
};

/// GPT-96 (11B): 96 layers, 32 heads, hidden 3072, seq 1024 (paper Table 3).
pub const GPT_96: ModelConfig = ModelConfig {
    name: "gpt-96",
    n_layers: 96,
    n_heads: 32,
    hidden: 3072,
    seq_len: 1024,
    vocab: 50304,
    dtype_bytes: 2,
};

/// Tiny GPT for the real end-to-end training example (~20M params):
/// 8 layers, hidden 256, seq 128 — matches python/compile/model.py.
pub const GPT_TINY: ModelConfig = ModelConfig {
    name: "gpt-tiny",
    n_layers: 8,
    n_heads: 8,
    hidden: 256,
    seq_len: 128,
    vocab: 512,
    dtype_bytes: 4,
};

/// ~100M-param GPT for the headline end-to-end run: 12 layers, hidden 768.
pub const GPT_SMALL: ModelConfig = ModelConfig {
    name: "gpt-small",
    n_layers: 12,
    n_heads: 12,
    hidden: 768,
    seq_len: 256,
    vocab: 2048,
    dtype_bytes: 4,
};

impl ModelConfig {
    pub fn by_name(name: &str) -> Option<ModelConfig> {
        [BERT_64, GPT_96, GPT_TINY, GPT_SMALL].into_iter().find(|m| m.name == name)
    }

    /// Per-layer parameter count: 12 H^2 + 13 H (attention + MLP + norms).
    pub fn params_per_layer(&self) -> u64 {
        let h = self.hidden as u64;
        12 * h * h + 13 * h
    }

    /// Embedding (+ untied head) parameters.
    pub fn embedding_params(&self) -> u64 {
        (self.vocab as u64 + self.seq_len as u64) * self.hidden as u64
    }

    /// Total parameters (embeddings counted once; LM head tied).
    pub fn total_params(&self) -> u64 {
        self.params_per_layer() * self.n_layers as u64 + self.embedding_params()
    }

    /// Forward FLOPs for one layer on a micro-batch of size `b`
    /// (Megatron accounting: 24 b s H^2 + 4 b s^2 H, x2 for fwd matmul
    /// multiply-add already included).
    pub fn layer_fwd_flops(&self, b: usize) -> u64 {
        let (bs, s, h) = (b as u64, self.seq_len as u64, self.hidden as u64);
        24 * bs * s * h * h + 4 * bs * s * s * h
    }

    /// Backward is ~2x forward (the paper's t_b = 2 t_f premise).
    pub fn layer_bwd_flops(&self, b: usize) -> u64 {
        2 * self.layer_fwd_flops(b)
    }

    /// Activation bytes stashed per layer per micro-batch (Megatron's
    /// s*b*h*(34 + 5*a*s/h) with selective recompute off).
    pub fn layer_activation_bytes(&self, b: usize) -> u64 {
        let (bs, s, h, a) = (
            b as u64,
            self.seq_len as u64,
            self.hidden as u64,
            self.n_heads as u64,
        );
        // 34sbh + 5 a s^2 b  (bytes, already in fp16 units for 2-byte dtypes)
        (34 * s * bs * h + 5 * a * s * s * bs) * self.dtype_bytes as u64 / 2
    }

    /// Bytes of one inter-stage activation message (paper Appendix C:
    /// message_size = 2 bytes * B * S * H for mixed precision).
    pub fn message_bytes(&self, b: usize) -> u64 {
        self.dtype_bytes as u64 * b as u64 * self.seq_len as u64 * self.hidden as u64
    }

    /// Validate the dimensions are self-consistent.
    pub fn validate(&self) -> Result<()> {
        ensure!(self.n_layers > 0, "n_layers must be positive");
        ensure!(self.hidden % self.n_heads == 0, "hidden must divide by heads");
        ensure!(self.dtype_bytes == 2 || self.dtype_bytes == 4, "dtype_bytes in {{2,4}}");
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table3_parameter_counts() {
        // Paper Table 3: BERT-64 is 5B, GPT-96 is 11B. Our accounting
        // should land within 10% of the headline numbers.
        let bert = BERT_64.total_params() as f64;
        assert!((bert - 5.0e9).abs() / 5.0e9 < 0.10, "BERT-64 params {bert:.3e}");
        let gpt = GPT_96.total_params() as f64;
        assert!((gpt - 11.0e9).abs() / 11.0e9 < 0.10, "GPT-96 params {gpt:.3e}");
    }

    #[test]
    fn tiny_model_is_small() {
        let p = GPT_TINY.total_params();
        assert!(p < 30_000_000, "gpt-tiny params {p}");
        let p = GPT_SMALL.total_params();
        assert!((50_000_000..200_000_000).contains(&p), "gpt-small params {p}");
    }

    #[test]
    fn all_presets_validate() {
        for m in [BERT_64, GPT_96, GPT_TINY, GPT_SMALL] {
            m.validate().unwrap();
            assert_eq!(ModelConfig::by_name(m.name), Some(m));
        }
        assert!(ModelConfig::by_name("nope").is_none());
    }

    #[test]
    fn bwd_is_twice_fwd() {
        assert_eq!(GPT_96.layer_bwd_flops(2), 2 * GPT_96.layer_fwd_flops(2));
    }

    #[test]
    fn message_bytes_formula() {
        // Appendix C: 2 B * S * H bytes for BERT-64 B=4.
        assert_eq!(BERT_64.message_bytes(4), 2 * 4 * 512 * 2560);
    }
}
