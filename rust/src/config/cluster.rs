//! Cluster hardware model: the paper's testbed (A800 80GB nodes, 8 GPUs
//! per node on NVLink, 200 Gbps HDR Infiniband between nodes) expressed as
//! bandwidth/latency parameters, plus the stage->device mapping policy of
//! paper Fig 6.

use anyhow::{ensure, Result};

/// Interconnect class between two devices.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum LinkKind {
    /// Same device (local copy).
    Local,
    /// Same server node (NVLink).
    NvLink,
    /// Across nodes (Infiniband).
    InfiniBand,
}

/// Identity of one *directed* physical pipe: concurrent transfers with the
/// same `LinkId` share its bandwidth (flow-level contention model). Links
/// are full-duplex, so the two directions of a pair are distinct pipes.
///
/// Endpoint granularity follows the hardware that actually serializes the
/// traffic:
///
/// * `Local`/`NvLink` — endpoints are *devices*: each directed device pair
///   has its own NVLink path (NVSwitch-style full bisection inside a node).
/// * `InfiniBand` — endpoints are *nodes*: every transfer between the same
///   node pair funnels through the same NIC-to-NIC path, which is exactly
///   where BitPipe's twin pipes contend under the Fig 6 mappings. How that
///   path maps onto shared hardware is refined by [`IbModel`]: under
///   [`IbModel::NodeNic`] (the default) it decomposes into the source
///   node's egress NIC plus the destination node's ingress NIC (see
///   [`ClusterConfig::resources_of`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct LinkId {
    pub kind: LinkKind,
    /// Source endpoint (device id for Local/NvLink, node id for IB).
    pub src: usize,
    /// Destination endpoint (device id for Local/NvLink, node id for IB).
    pub dst: usize,
}

/// How inter-node Infiniband capacity is shared between concurrent flows.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum IbModel {
    /// Per-node NIC aggregation (the default, and the faithful model for a
    /// one-HCA-per-node testbed): a node's egress NIC is **one** shared
    /// resource across *all* its peer nodes, and likewise its ingress NIC.
    /// A node fanning out to two different peers halves each flow's
    /// bandwidth even though the flows target distinct node pairs.
    NodeNic,
    /// The legacy PR-2 model, kept behind this knob for differential
    /// comparison: every directed node *pair* is an independent pipe, so
    /// fan-out to distinct peers does not contend.
    NodePair,
}

/// One shared network resource of the contention model. A flow occupies
/// one or two of these ([`ClusterConfig::resources_of`]); concurrent flows
/// sharing a resource split its bandwidth fair-share.
///
/// Resources also have a *dense* identity
/// ([`ClusterConfig::resource_index`]): the contention engine keeps its
/// per-resource state in a flat arena indexed by it, so the hot path never
/// hashes a `ResourceId`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ResourceId {
    /// A directed point-to-point pipe: a device-pair NVLink path, a local
    /// HBM copy engine, or (under [`IbModel::NodePair`]) a node-pair IB
    /// pipe.
    Pipe(LinkId),
    /// A node's egress NIC ([`IbModel::NodeNic`]).
    NicOut(usize),
    /// A node's ingress NIC ([`IbModel::NodeNic`]).
    NicIn(usize),
}

/// Sentinel for "no second resource" in a dense resource pair
/// ([`ClusterConfig::dense_resources_of`]).
pub const NO_RESOURCE: u32 = u32::MAX;

/// Capacity of the sparse heterogeneity override tables on
/// [`ClusterConfig`]. Fixed-size arrays keep the config `Copy` (it is
/// stored by value throughout the cost pipeline); real degradation
/// scenarios name a handful of stragglers or bad links, not a fleet.
pub const MAX_OVERRIDES: usize = 8;

/// Dense index of a link class into the per-kind multiplier table.
fn kind_index(kind: LinkKind) -> usize {
    match kind {
        LinkKind::Local => 0,
        LinkKind::NvLink => 1,
        LinkKind::InfiniBand => 2,
    }
}

/// How pipeline stages map onto physical devices (paper Fig 6).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MappingPolicy {
    /// BitPipe/Chimera mapping: all replicas of a stage in the same node —
    /// heavy allreduce on NVLink, light P2P on IB.
    ReplicasTogether,
    /// Naive mapping: each pipeline contiguous in a node — P2P on NVLink,
    /// allreduce on IB (the slow configuration Fig 6 argues against).
    PipesTogether,
}

/// Cluster hardware parameters. Defaults model the paper's testbed.
#[derive(Debug, Clone, Copy)]
pub struct ClusterConfig {
    /// Total devices P.
    pub n_devices: usize,
    /// Devices per server node.
    pub devices_per_node: usize,
    /// NVLink per-direction bandwidth, bytes/s (A800: 400 GB/s NVLink-4
    /// aggregate; effective p2p ~200 GB/s).
    pub nvlink_bw: f64,
    /// Infiniband bandwidth, bytes/s (200 Gbps HDR = 25 GB/s).
    pub ib_bw: f64,
    /// P2P latency (s) on NVLink.
    pub nvlink_lat: f64,
    /// P2P latency (s) on IB.
    pub ib_lat: f64,
    /// Per-device sustained compute, FLOP/s (A800 bf16 dense ~312 TFLOPs,
    /// ~45% achievable on transformer layers => 140 TFLOPs effective).
    pub flops: f64,
    /// Micro-batch size at which kernels reach half their peak efficiency
    /// (GPU kernels are launch/occupancy-bound at tiny B; paper Fig 11(b):
    /// "training throughput increases with the increase of B").
    pub b_half: f64,
    /// Device memory capacity, bytes (A800 80GB).
    pub mem_capacity: u64,
    /// Stage mapping policy.
    pub mapping: MappingPolicy,
    /// How concurrent IB flows share NIC hardware under contention.
    pub ib_model: IbModel,
    /// Sparse per-device compute-time multipliers (`(dev, mult)`; a 1.2x
    /// straggler takes 20% longer per chunk). Only the first
    /// `n_stragglers` entries are live; later entries for the same device
    /// shadow earlier ones. Populate via [`Self::with_straggler`].
    pub stragglers: [(u32, f64); MAX_OVERRIDES],
    /// Live prefix length of `stragglers`.
    pub n_stragglers: u8,
    /// Per-link-class bandwidth multipliers (indexed Local/NvLink/IB; a
    /// 0.5 on IB halves every IB link). Populate via
    /// [`Self::with_link_mult`].
    pub link_mult: [f64; 3],
    /// Sparse per-pipe bandwidth multipliers keyed by [`LinkId`] fields
    /// (`(kind, src, dst, mult)`), composing multiplicatively with the
    /// class-level `link_mult`. Only the first `n_link_overrides` entries
    /// are live; later entries for the same pipe shadow earlier ones.
    /// Populate via [`Self::with_link_override`].
    pub link_overrides: [(LinkKind, u32, u32, f64); MAX_OVERRIDES],
    /// Live prefix length of `link_overrides`.
    pub n_link_overrides: u8,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        ClusterConfig {
            n_devices: 8,
            devices_per_node: 8,
            nvlink_bw: 200.0e9,
            ib_bw: 25.0e9,
            nvlink_lat: 3.0e-6,
            ib_lat: 8.0e-6,
            flops: 140.0e12,
            b_half: 0.75,
            mem_capacity: 80 * (1 << 30),
            mapping: MappingPolicy::ReplicasTogether,
            ib_model: IbModel::NodeNic,
            stragglers: [(0, 1.0); MAX_OVERRIDES],
            n_stragglers: 0,
            link_mult: [1.0; 3],
            link_overrides: [(LinkKind::Local, 0, 0, 1.0); MAX_OVERRIDES],
            n_link_overrides: 0,
        }
    }
}

impl ClusterConfig {
    /// Paper testbed scaled to `n` devices (8 per node).
    pub fn paper_testbed(n: usize) -> Self {
        ClusterConfig { n_devices: n, ..Default::default() }
    }

    /// Single fully-NVLinked node (the ablation study's setting).
    pub fn single_node(n: usize) -> Self {
        ClusterConfig { n_devices: n, devices_per_node: n, ..Default::default() }
    }

    pub fn n_nodes(&self) -> usize {
        (self.n_devices + self.devices_per_node - 1) / self.devices_per_node
    }

    /// Node of a physical device id.
    pub fn node_of(&self, dev: usize) -> usize {
        dev / self.devices_per_node
    }

    /// Link class between two physical devices.
    pub fn link(&self, a: usize, b: usize) -> LinkKind {
        if a == b {
            LinkKind::Local
        } else if self.node_of(a) == self.node_of(b) {
            LinkKind::NvLink
        } else {
            LinkKind::InfiniBand
        }
    }

    /// Identity of the directed physical pipe carrying traffic from
    /// physical device `a` to physical device `b` — the shared-resource key
    /// of the contention model (see [`LinkId`] for endpoint granularity).
    pub fn link_id(&self, a: usize, b: usize) -> LinkId {
        let kind = self.link(a, b);
        match kind {
            LinkKind::Local | LinkKind::NvLink => LinkId { kind, src: a, dst: b },
            LinkKind::InfiniBand => {
                LinkId { kind, src: self.node_of(a), dst: self.node_of(b) }
            }
        }
    }

    /// The shared resources a flow on pipe `link` occupies under
    /// contention. Intra-node pipes are their own resource; an inter-node
    /// flow under [`IbModel::NodeNic`] rides *two* — the source node's
    /// egress NIC and the destination node's ingress NIC — so every flow
    /// leaving (or entering) a node contends with all of that node's other
    /// inter-node traffic in the same direction, whichever peer it targets.
    pub fn resources_of(&self, link: LinkId) -> (ResourceId, Option<ResourceId>) {
        match (link.kind, self.ib_model) {
            (LinkKind::InfiniBand, IbModel::NodeNic) => {
                (ResourceId::NicOut(link.src), Some(ResourceId::NicIn(link.dst)))
            }
            _ => (ResourceId::Pipe(link), None),
        }
    }

    /// Size of the dense resource arena for this cluster: every possible
    /// [`ResourceId`] maps to a distinct index below this bound
    /// ([`Self::resource_index`]). Device-pair pipes, node-pair IB pipes,
    /// and the two NIC directions per node each get their own range, so
    /// the count is `P² + N² + 2N` for P devices on N nodes — a few KiB of
    /// table even at cluster scale.
    pub fn n_resources(&self) -> usize {
        let p = self.n_devices;
        let n = self.n_nodes();
        p * p + n * n + 2 * n
    }

    /// Dense index of a resource in `[0, n_resources())` — injective over
    /// every resource this cluster can produce, so the contention engine
    /// can replace its `ResourceId`-keyed hash map with a flat arena.
    pub fn resource_index(&self, r: ResourceId) -> usize {
        let p = self.n_devices;
        let n = self.n_nodes();
        match r {
            ResourceId::Pipe(l) => match l.kind {
                // Device-pair endpoints (Local a == b included).
                LinkKind::Local | LinkKind::NvLink => l.src * p + l.dst,
                // Node-pair endpoints (IbModel::NodePair only).
                LinkKind::InfiniBand => p * p + l.src * n + l.dst,
            },
            ResourceId::NicOut(node) => p * p + n * n + node,
            ResourceId::NicIn(node) => p * p + n * n + n + node,
        }
    }

    /// [`Self::resources_of`] in dense form: the flat-arena indices a flow
    /// on `link` occupies, with [`NO_RESOURCE`] marking the absent second
    /// slot. This is what the engine stores per flow — pure arithmetic,
    /// no hashing.
    pub fn dense_resources_of(&self, link: LinkId) -> (u32, u32) {
        let (a, b) = self.resources_of(link);
        (
            self.resource_index(a) as u32,
            b.map_or(NO_RESOURCE, |r| self.resource_index(r) as u32),
        )
    }

    /// Enumerate the directed pipes a ring collective over `members`
    /// (physical device ids, assumed distinct) traverses. Members are
    /// ordered by `(node, device)` — the node-clustered order a topology-
    /// aware ring implementation uses, which crosses each inter-node
    /// boundary exactly once per direction — and the ring closes back on
    /// its first member. Fewer than two members means no wire traffic.
    pub fn ring_path(&self, members: &[usize]) -> Vec<LinkId> {
        if members.len() < 2 {
            return Vec::new();
        }
        let mut ordered: Vec<usize> = members.to_vec();
        ordered.sort_unstable_by_key(|&dev| (self.node_of(dev), dev));
        (0..ordered.len())
            .map(|i| self.link_id(ordered[i], ordered[(i + 1) % ordered.len()]))
            .collect()
    }

    /// Register a compute-time multiplier for physical device `dev`:
    /// every chunk on that device takes `mult`x as long (1.2 models a 20%
    /// straggler). Errors when the sparse table is full, the device is out
    /// of range, or the multiplier is not positive and finite.
    pub fn with_straggler(mut self, dev: usize, mult: f64) -> Result<Self> {
        ensure!(dev < self.n_devices, "straggler device {dev} out of range");
        ensure!(mult.is_finite() && mult > 0.0, "straggler multiplier must be positive");
        let n = self.n_stragglers as usize;
        ensure!(n < MAX_OVERRIDES, "at most {MAX_OVERRIDES} straggler entries");
        self.stragglers[n] = (dev as u32, mult);
        self.n_stragglers += 1;
        Ok(self)
    }

    /// Scale every link of class `kind` to `mult`x its base bandwidth
    /// (0.5 on `InfiniBand` models a degraded fabric at half rate).
    pub fn with_link_mult(mut self, kind: LinkKind, mult: f64) -> Result<Self> {
        ensure!(mult.is_finite() && mult > 0.0, "link multiplier must be positive");
        self.link_mult[kind_index(kind)] = mult;
        Ok(self)
    }

    /// Scale the directed pipe carrying device `a` -> device `b` traffic
    /// to `mult`x its (class-scaled) bandwidth — a single bad cable or
    /// NIC. The pair is resolved through [`Self::link_id`], so for IB the
    /// override covers the whole node pair, matching the pipe that
    /// actually serializes the traffic.
    pub fn with_link_override(mut self, a: usize, b: usize, mult: f64) -> Result<Self> {
        ensure!(a < self.n_devices && b < self.n_devices, "link endpoints out of range");
        ensure!(mult.is_finite() && mult > 0.0, "link multiplier must be positive");
        let n = self.n_link_overrides as usize;
        ensure!(n < MAX_OVERRIDES, "at most {MAX_OVERRIDES} link override entries");
        let l = self.link_id(a, b);
        self.link_overrides[n] = (l.kind, l.src as u32, l.dst as u32, mult);
        self.n_link_overrides += 1;
        Ok(self)
    }

    /// Compute-time multiplier of physical device `dev` (1.0 when no
    /// straggler entry names it; the most recent entry wins).
    pub fn compute_mult(&self, dev: usize) -> f64 {
        let live = &self.stragglers[..self.n_stragglers as usize];
        live.iter()
            .rev()
            .find(|&&(d, _)| d as usize == dev)
            .map_or(1.0, |&(_, m)| m)
    }

    /// Combined bandwidth multiplier of one directed pipe: the class-level
    /// factor times the most recent matching per-pipe override.
    pub fn link_mult_of(&self, link: LinkId) -> f64 {
        let class = self.link_mult[kind_index(link.kind)];
        let live = &self.link_overrides[..self.n_link_overrides as usize];
        let pair = live
            .iter()
            .rev()
            .find(|&&(k, s, d, _)| {
                k == link.kind && s as usize == link.src && d as usize == link.dst
            })
            .map_or(1.0, |&(_, _, _, m)| m);
        class * pair
    }

    /// Effective bandwidth of one directed pipe with every heterogeneity
    /// multiplier applied. With all multipliers at 1.0 this is IEEE-exactly
    /// [`Self::bw`] of the link class (x1.0 is exact), which is what keeps
    /// uniform configs bit-identical.
    pub fn bw_over(&self, link: LinkId) -> f64 {
        self.bw(link.kind) * self.link_mult_of(link)
    }

    /// Class-level scaled bandwidth (no per-pipe overrides) — what the
    /// collective ring *scalar* prices against: all hops of a ring share
    /// one closed-form time, so only the class-wide factor can apply.
    pub fn bw_scaled(&self, kind: LinkKind) -> f64 {
        self.bw(kind) * self.link_mult[kind_index(kind)]
    }

    /// True when no device carries a non-1.0 compute multiplier — the
    /// cost model skips per-device pricing rows entirely in this case.
    pub fn is_uniform_compute(&self) -> bool {
        self.stragglers[..self.n_stragglers as usize].iter().all(|&(_, m)| m == 1.0)
    }

    /// True when any link-class or per-pipe bandwidth multiplier differs
    /// from 1.0.
    pub fn has_link_overrides(&self) -> bool {
        self.link_mult.iter().any(|&m| m != 1.0)
            || self.link_overrides[..self.n_link_overrides as usize]
                .iter()
                .any(|&(_, _, _, m)| m != 1.0)
    }

    /// Bandwidth of a link class, bytes/s. Local copies are modeled at
    /// HBM copy bandwidth (fast but not free).
    pub fn bw(&self, kind: LinkKind) -> f64 {
        match kind {
            LinkKind::Local => 1.0e12,
            LinkKind::NvLink => self.nvlink_bw,
            LinkKind::InfiniBand => self.ib_bw,
        }
    }

    /// Latency of a link class, seconds.
    pub fn lat(&self, kind: LinkKind) -> f64 {
        match kind {
            LinkKind::Local => 0.5e-6,
            LinkKind::NvLink => self.nvlink_lat,
            LinkKind::InfiniBand => self.ib_lat,
        }
    }

    /// Fraction of peak FLOPs achieved at micro-batch size `b`
    /// (saturating occupancy curve b / (b + b_half)).
    pub fn mbs_efficiency(&self, b: usize) -> f64 {
        let b = b as f64;
        b / (b + self.b_half)
    }

    /// Time to move `bytes` over the link between devices `a` and `b`,
    /// with any heterogeneity overrides applied to the pipe's bandwidth
    /// (IEEE-exactly the base formula when every multiplier is 1.0).
    pub fn xfer_time(&self, a: usize, b: usize, bytes: u64) -> f64 {
        let l = self.link_id(a, b);
        self.lat(l.kind) + bytes as f64 / self.bw_over(l)
    }

    pub fn validate(&self) -> Result<()> {
        ensure!(self.n_devices >= 1, "need at least one device");
        ensure!(self.devices_per_node >= 1, "devices_per_node >= 1");
        ensure!(self.nvlink_bw > self.ib_bw, "NVLink must outpace IB");
        ensure!(self.flops > 0.0 && self.mem_capacity > 0, "positive compute/memory");
        ensure!(self.n_stragglers as usize <= MAX_OVERRIDES, "straggler table overrun");
        ensure!(self.n_link_overrides as usize <= MAX_OVERRIDES, "link table overrun");
        for &(dev, m) in &self.stragglers[..self.n_stragglers as usize] {
            ensure!((dev as usize) < self.n_devices, "straggler device {dev} out of range");
            ensure!(m.is_finite() && m > 0.0, "straggler multiplier must be positive");
        }
        for &m in &self.link_mult {
            ensure!(m.is_finite() && m > 0.0, "link multiplier must be positive");
        }
        for &(_, _, _, m) in &self.link_overrides[..self.n_link_overrides as usize] {
            ensure!(m.is_finite() && m > 0.0, "link multiplier must be positive");
        }
        Ok(())
    }

    /// Physical device id of (pipeline-group w, pipeline device d) under
    /// the mapping policy, for W pipeline replicas of depth D.
    ///
    /// * `ReplicasTogether` (Fig 6 right): device d of every replica w sits
    ///   in node d*W+w's slot — replicas of a stage share a node when
    ///   W <= devices_per_node.
    /// * `PipesTogether` (Fig 6 left): replica w occupies a contiguous
    ///   block of D slots.
    pub fn physical_device(&self, policy: MappingPolicy, w: usize, d: usize, n_w: usize, n_d: usize) -> usize {
        debug_assert!(w < n_w && d < n_d);
        match policy {
            MappingPolicy::ReplicasTogether => d * n_w + w,
            MappingPolicy::PipesTogether => w * n_d + d,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn link_classes() {
        let c = ClusterConfig::paper_testbed(16);
        assert_eq!(c.n_nodes(), 2);
        assert_eq!(c.link(0, 0), LinkKind::Local);
        assert_eq!(c.link(0, 7), LinkKind::NvLink);
        assert_eq!(c.link(0, 8), LinkKind::InfiniBand);
    }

    #[test]
    fn link_ids_identify_shared_pipes() {
        let c = ClusterConfig::paper_testbed(16);
        // Intra-node: each directed device pair is its own NVLink pipe.
        assert_eq!(
            c.link_id(0, 1),
            LinkId { kind: LinkKind::NvLink, src: 0, dst: 1 }
        );
        assert_ne!(c.link_id(0, 1), c.link_id(1, 0), "full duplex: directions distinct");
        assert_ne!(c.link_id(0, 1), c.link_id(0, 2));
        // Inter-node: all device pairs crossing the same node pair share
        // one directed IB pipe.
        assert_eq!(c.link_id(0, 8), c.link_id(1, 9));
        assert_eq!(
            c.link_id(0, 8),
            LinkId { kind: LinkKind::InfiniBand, src: 0, dst: 1 }
        );
        assert_ne!(c.link_id(0, 8), c.link_id(8, 0), "IB directions distinct");
        // Local copies stay per-device.
        assert_eq!(c.link_id(3, 3), LinkId { kind: LinkKind::Local, src: 3, dst: 3 });
    }

    #[test]
    fn resources_split_ib_into_nics_by_default() {
        let c = ClusterConfig::paper_testbed(16);
        // NVLink pipes are their own resource.
        let nv = c.link_id(0, 1);
        assert_eq!(c.resources_of(nv), (ResourceId::Pipe(nv), None));
        // IB flows ride the egress NIC of the source node and the ingress
        // NIC of the destination node.
        let ib = c.link_id(0, 8);
        assert_eq!(
            c.resources_of(ib),
            (ResourceId::NicOut(0), Some(ResourceId::NicIn(1)))
        );
        // Fan-out from one node to two different peers shares the egress
        // NIC — the aggregation the per-pair model misses.
        let c24 = ClusterConfig { n_devices: 24, ..c };
        let (out_a, in_a) = c24.resources_of(c24.link_id(0, 8));
        let (out_b, in_b) = c24.resources_of(c24.link_id(0, 16));
        assert_eq!(out_a, out_b, "one egress NIC per node");
        assert_ne!(in_a, in_b, "distinct peers keep distinct ingress NICs");
        // The legacy model keeps independent node-pair pipes.
        let legacy = ClusterConfig { ib_model: IbModel::NodePair, ..c24 };
        assert_eq!(
            legacy.resources_of(legacy.link_id(0, 8)),
            (ResourceId::Pipe(legacy.link_id(0, 8)), None)
        );
        assert_ne!(
            legacy.resources_of(legacy.link_id(0, 8)),
            legacy.resources_of(legacy.link_id(0, 16))
        );
    }

    #[test]
    fn dense_resource_indices_are_injective_and_bounded() {
        // Every resource either IB model can produce maps into
        // [0, n_resources()) with no collisions.
        for ib_model in [IbModel::NodeNic, IbModel::NodePair] {
            let c = ClusterConfig {
                n_devices: 24,
                devices_per_node: 8,
                ib_model,
                ..Default::default()
            };
            let mut seen = std::collections::HashMap::new();
            let mut insert = |r: ResourceId| {
                let i = c.resource_index(r);
                assert!(i < c.n_resources(), "{r:?} -> {i} out of bounds");
                if let Some(prev) = seen.insert(i, r) {
                    panic!("{r:?} and {prev:?} collide at {i}");
                }
            };
            for a in 0..c.n_devices {
                for b in 0..c.n_devices {
                    let l = c.link_id(a, b);
                    match c.resources_of(l) {
                        (r1, Some(r2)) => {
                            // NIC pairs repeat across device pairs; only
                            // record each once.
                            for r in [r1, r2] {
                                let i = c.resource_index(r);
                                assert!(i < c.n_resources());
                                if !seen.contains_key(&i) {
                                    insert(r);
                                } else {
                                    assert_eq!(seen[&i], r, "index {i} reused");
                                }
                            }
                        }
                        (r1, None) => {
                            let i = c.resource_index(r1);
                            if !seen.contains_key(&i) {
                                insert(r1);
                            } else {
                                assert_eq!(seen[&i], r1, "index {i} reused");
                            }
                        }
                    }
                }
            }
            // Dense pairs agree with the ResourceId path.
            let ib = c.link_id(0, 8);
            let (d1, d2) = c.dense_resources_of(ib);
            let (r1, r2) = c.resources_of(ib);
            assert_eq!(d1 as usize, c.resource_index(r1));
            match r2 {
                Some(r) => assert_eq!(d2 as usize, c.resource_index(r)),
                None => assert_eq!(d2, NO_RESOURCE),
            }
            let nv = c.link_id(0, 1);
            assert_eq!(c.dense_resources_of(nv).1, NO_RESOURCE);
        }
    }

    #[test]
    fn ring_paths_cluster_by_node() {
        let c = ClusterConfig::paper_testbed(16);
        // Two members: both directed pipes, once each.
        let path = c.ring_path(&[0, 7]);
        assert_eq!(path, vec![c.link_id(0, 7), c.link_id(7, 0)]);
        // Four members across two nodes, given out of order: the ring
        // clusters members by node, so exactly one IB hop per direction.
        let path = c.ring_path(&[9, 0, 8, 1]);
        assert_eq!(path.len(), 4);
        let ib_hops = path.iter().filter(|l| l.kind == LinkKind::InfiniBand).count();
        assert_eq!(ib_hops, 2, "node-clustered ring crosses IB once per direction");
        assert_eq!(
            path,
            vec![c.link_id(0, 1), c.link_id(1, 8), c.link_id(8, 9), c.link_id(9, 0)]
        );
        // Degenerate rings carry no wire traffic.
        assert!(c.ring_path(&[3]).is_empty());
        assert!(c.ring_path(&[]).is_empty());
    }

    #[test]
    fn xfer_times_ordered() {
        let c = ClusterConfig::default();
        let msg = 10 << 20;
        let local = c.xfer_time(0, 0, msg);
        let nv = c.xfer_time(0, 1, msg);
        let c16 = ClusterConfig::paper_testbed(16);
        let ib = c16.xfer_time(0, 8, msg);
        assert!(local < nv && nv < ib, "{local} {nv} {ib}");
    }

    #[test]
    fn mapping_policies() {
        let c = ClusterConfig::paper_testbed(16);
        // W=2 replicas, D=8: ReplicasTogether puts (w=0,d=0) and (w=1,d=0)
        // adjacent (same node); PipesTogether puts them 8 apart.
        let a = c.physical_device(MappingPolicy::ReplicasTogether, 0, 0, 2, 8);
        let b = c.physical_device(MappingPolicy::ReplicasTogether, 1, 0, 2, 8);
        assert_eq!(c.node_of(a), c.node_of(b));
        let a = c.physical_device(MappingPolicy::PipesTogether, 0, 3, 2, 8);
        let b = c.physical_device(MappingPolicy::PipesTogether, 1, 3, 2, 8);
        assert_ne!(c.node_of(a), c.node_of(b));
    }

    #[test]
    fn efficiency_curve_monotone() {
        let c = ClusterConfig::default();
        assert!(c.mbs_efficiency(1) < c.mbs_efficiency(2));
        assert!(c.mbs_efficiency(2) < c.mbs_efficiency(8));
        assert!(c.mbs_efficiency(64) > 0.95);
    }

    #[test]
    fn default_validates() {
        ClusterConfig::default().validate().unwrap();
        ClusterConfig::single_node(8).validate().unwrap();
    }

    #[test]
    fn straggler_and_link_overrides() {
        let c = ClusterConfig::paper_testbed(16)
            .with_straggler(3, 1.2)
            .unwrap()
            .with_link_mult(LinkKind::InfiniBand, 0.5)
            .unwrap()
            .with_link_override(0, 1, 0.25)
            .unwrap();
        c.validate().unwrap();
        assert_eq!(c.compute_mult(3), 1.2);
        assert_eq!(c.compute_mult(0), 1.0);
        assert!(!c.is_uniform_compute());
        assert!(c.has_link_overrides());
        // Class mult halves IB links; pair override quarters one NVLink pipe.
        let ib = c.link_id(0, 8);
        assert_eq!(c.bw_over(ib), c.bw(LinkKind::InfiniBand) * 0.5);
        let nv = c.link_id(0, 1);
        assert_eq!(c.bw_over(nv), c.bw(LinkKind::NvLink) * 0.25);
        // The untouched reverse direction keeps its base rate.
        assert_eq!(c.bw_over(c.link_id(1, 0)), c.bw(LinkKind::NvLink));
        // Later entries shadow earlier ones.
        let c = c.with_straggler(3, 2.0).unwrap();
        assert_eq!(c.compute_mult(3), 2.0);
    }

    #[test]
    fn uniform_overrides_are_exactly_neutral() {
        // All-1.0 heterogeneity must be IEEE-exactly the base rates: the
        // uniform-identity guarantee rides on x1.0 being exact.
        let c = ClusterConfig::paper_testbed(16)
            .with_straggler(0, 1.0)
            .unwrap()
            .with_link_mult(LinkKind::NvLink, 1.0)
            .unwrap()
            .with_link_override(0, 1, 1.0)
            .unwrap();
        assert!(c.is_uniform_compute());
        assert!(!c.has_link_overrides());
        let base = ClusterConfig::paper_testbed(16);
        for (a, b) in [(0usize, 1usize), (0, 8), (3, 3)] {
            let l = c.link_id(a, b);
            assert_eq!(c.bw_over(l).to_bits(), base.bw(l.kind).to_bits());
        }
        assert_eq!(c.compute_mult(0).to_bits(), 1.0f64.to_bits());
    }

    #[test]
    fn override_builders_reject_bad_input() {
        let c = ClusterConfig::paper_testbed(8);
        assert!(c.with_straggler(8, 1.5).is_err(), "device out of range");
        assert!(c.with_straggler(0, 0.0).is_err(), "zero multiplier");
        assert!(c.with_straggler(0, f64::NAN).is_err(), "NaN multiplier");
        assert!(c.with_link_mult(LinkKind::InfiniBand, -1.0).is_err());
        assert!(c.with_link_override(0, 9, 0.5).is_err(), "endpoint out of range");
        let mut full = c;
        for _ in 0..MAX_OVERRIDES {
            full = full.with_straggler(0, 1.1).unwrap();
        }
        assert!(full.with_straggler(0, 1.1).is_err(), "table full");
    }
}
