//! Runtime metrics: iteration timing, throughput (the paper's headline
//! samples/s metric), and communication counters. Lock-free-ish: counters
//! are plain atomics so the training hot loop never blocks on metrics.
//!
//! [`IterStats`] is the shared per-iteration summary used by both the real
//! runtime's [`IterationTimer`] and the simulator's multi-iteration API
//! (`crate::sim::simulate_iters`), so measured and simulated steady-state
//! numbers are reduced identically.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::{Duration, Instant};

/// Monotonic counters shared across worker threads.
#[derive(Debug, Default)]
pub struct Counters {
    /// Micro-batch forward passes executed.
    pub forwards: AtomicU64,
    /// Micro-batch backward passes executed.
    pub backwards: AtomicU64,
    /// P2P messages sent.
    pub p2p_msgs: AtomicU64,
    /// P2P bytes sent.
    pub p2p_bytes: AtomicU64,
    /// Local copies performed (V-shape path).
    pub local_copies: AtomicU64,
    /// All-reduce operations completed.
    pub allreduces: AtomicU64,
    /// All-reduce bytes moved (sum over steps).
    pub allreduce_bytes: AtomicU64,
    /// Optimizer steps applied.
    pub optim_steps: AtomicU64,
}

impl Counters {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add(&self, field: &AtomicU64, n: u64) {
        field.fetch_add(n, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> CountersSnapshot {
        CountersSnapshot {
            forwards: self.forwards.load(Ordering::Relaxed),
            backwards: self.backwards.load(Ordering::Relaxed),
            p2p_msgs: self.p2p_msgs.load(Ordering::Relaxed),
            p2p_bytes: self.p2p_bytes.load(Ordering::Relaxed),
            local_copies: self.local_copies.load(Ordering::Relaxed),
            allreduces: self.allreduces.load(Ordering::Relaxed),
            allreduce_bytes: self.allreduce_bytes.load(Ordering::Relaxed),
            optim_steps: self.optim_steps.load(Ordering::Relaxed),
        }
    }
}

/// Point-in-time copy of [`Counters`].
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CountersSnapshot {
    pub forwards: u64,
    pub backwards: u64,
    pub p2p_msgs: u64,
    pub p2p_bytes: u64,
    pub local_copies: u64,
    pub allreduces: u64,
    pub allreduce_bytes: u64,
    pub optim_steps: u64,
}

impl std::ops::Sub for CountersSnapshot {
    type Output = CountersSnapshot;
    fn sub(self, rhs: Self) -> Self {
        CountersSnapshot {
            forwards: self.forwards - rhs.forwards,
            backwards: self.backwards - rhs.backwards,
            p2p_msgs: self.p2p_msgs - rhs.p2p_msgs,
            p2p_bytes: self.p2p_bytes - rhs.p2p_bytes,
            local_copies: self.local_copies - rhs.local_copies,
            allreduces: self.allreduces - rhs.allreduces,
            allreduce_bytes: self.allreduce_bytes - rhs.allreduce_bytes,
            optim_steps: self.optim_steps - rhs.optim_steps,
        }
    }
}

/// Summary statistics over per-iteration durations, seconds.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct IterStats {
    /// Recorded iterations.
    pub n: usize,
    /// Mean iteration time.
    pub mean: f64,
    /// Population standard deviation.
    pub stddev: f64,
    /// Fastest iteration.
    pub min: f64,
    /// Slowest iteration.
    pub max: f64,
}

impl IterStats {
    /// Reduce a slice of per-iteration durations (empty slice -> zeros).
    pub fn from_secs(xs: &[f64]) -> IterStats {
        if xs.is_empty() {
            return IterStats::default();
        }
        IterStats {
            n: xs.len(),
            mean: crate::util::mean(xs),
            stddev: crate::util::stddev(xs),
            min: xs.iter().cloned().fold(f64::INFINITY, f64::min),
            max: xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max),
        }
    }

    /// Throughput in samples/s for a given per-iteration mini-batch.
    pub fn throughput(&self, minibatch: usize) -> f64 {
        if self.mean <= 0.0 {
            return 0.0;
        }
        minibatch as f64 / self.mean
    }
}

/// Per-iteration timing with warm-up skipping (the paper records after 100
/// warm-up iterations; our driver uses a configurable count).
#[derive(Debug)]
pub struct IterationTimer {
    warmup: usize,
    seen: usize,
    current: Option<Instant>,
    durations: Vec<Duration>,
}

impl IterationTimer {
    pub fn new(warmup: usize) -> Self {
        IterationTimer { warmup, seen: 0, current: None, durations: Vec::new() }
    }

    pub fn start(&mut self) {
        self.current = Some(Instant::now());
    }

    pub fn stop(&mut self) {
        let Some(t0) = self.current.take() else { return };
        self.seen += 1;
        if self.seen > self.warmup {
            self.durations.push(t0.elapsed());
        }
    }

    /// Recorded (post-warmup) iteration count.
    pub fn n_recorded(&self) -> usize {
        self.durations.len()
    }

    /// Mean recorded iteration time.
    pub fn mean(&self) -> Duration {
        if self.durations.is_empty() {
            return Duration::ZERO;
        }
        self.durations.iter().sum::<Duration>() / self.durations.len() as u32
    }

    /// Samples/s given the mini-batch size per iteration.
    pub fn throughput(&self, minibatch: usize) -> f64 {
        let m = self.mean();
        if m.is_zero() {
            return 0.0;
        }
        minibatch as f64 / m.as_secs_f64()
    }

    pub fn durations(&self) -> &[Duration] {
        &self.durations
    }

    /// Summary statistics over the recorded (post-warmup) iterations.
    pub fn stats(&self) -> IterStats {
        let secs: Vec<f64> = self.durations.iter().map(Duration::as_secs_f64).collect();
        IterStats::from_secs(&secs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_roundtrip() {
        let c = Counters::new();
        c.add(&c.forwards, 3);
        c.add(&c.p2p_bytes, 1024);
        let s = c.snapshot();
        assert_eq!(s.forwards, 3);
        assert_eq!(s.p2p_bytes, 1024);
        c.add(&c.forwards, 1);
        let d = c.snapshot() - s;
        assert_eq!(d.forwards, 1);
        assert_eq!(d.p2p_bytes, 0);
    }

    #[test]
    fn timer_skips_warmup() {
        let mut t = IterationTimer::new(2);
        for _ in 0..5 {
            t.start();
            std::thread::sleep(Duration::from_millis(1));
            t.stop();
        }
        assert_eq!(t.n_recorded(), 3);
        assert!(t.mean() >= Duration::from_millis(1));
        assert!(t.throughput(32) > 0.0);
    }

    #[test]
    fn timer_empty_safe() {
        let t = IterationTimer::new(0);
        assert_eq!(t.mean(), Duration::ZERO);
        assert_eq!(t.throughput(8), 0.0);
        assert_eq!(t.stats(), IterStats::default());
    }

    #[test]
    fn iter_stats_reduce() {
        let s = IterStats::from_secs(&[1.0, 2.0, 3.0]);
        assert_eq!(s.n, 3);
        assert!((s.mean - 2.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 3.0);
        assert!(s.stddev > 0.0);
        assert!((s.throughput(4) - 2.0).abs() < 1e-12);
        assert_eq!(IterStats::from_secs(&[]), IterStats::default());
        assert_eq!(IterStats::default().throughput(8), 0.0);
    }
}
