//! Training checkpoints: save/restore the full optimizer state so long
//! runs survive restarts and runs can be forked for ablations.
//!
//! Format: one directory per checkpoint —
//!
//! ```text
//! ckpt/
//!   meta.txt                 # key=value: iteration, n_chunks, adam step
//!   stage<k>.params.bin      # flat f32 LE
//!   stage<k>.m.bin           # Adam first moment
//!   stage<k>.v.bin           # Adam second moment
//! ```
//!
//! Both pipes' replicas of a stage are bit-identical by the synchronous
//! update invariant (validated in `e2e_train.rs`), so one copy per model
//! stage suffices; on restore every replica is seeded from it.

use super::optim::{Adam, AdamConfig};
use crate::config::{parse_kv, KvExt};
use anyhow::{ensure, Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// In-memory checkpoint: per model stage, (params, adam m, adam v).
#[derive(Debug, Clone, Default)]
pub struct Checkpoint {
    /// Completed training iterations.
    pub iteration: usize,
    /// Adam step count (same for every stage under synchronous updates).
    pub adam_step: u64,
    /// Per-stage state.
    pub stages: HashMap<usize, StageState>,
}

#[derive(Debug, Clone)]
pub struct StageState {
    pub params: Vec<f32>,
    pub m: Vec<f32>,
    pub v: Vec<f32>,
}

impl Checkpoint {
    /// Record one stage's state (replicas are identical; last write wins).
    pub fn put(&mut self, stage: usize, params: Vec<f32>, adam: &Adam) {
        let (m, v) = adam.moments();
        assert_eq!(params.len(), m.len(), "stage {stage}: params/optimizer length mismatch");
        self.adam_step = adam.step_count();
        self.stages.insert(stage, StageState { params, m: m.to_vec(), v: v.to_vec() });
    }

    /// Restore a stage: returns (params, rebuilt Adam).
    pub fn get(&self, stage: usize, cfg: AdamConfig) -> Option<(Vec<f32>, Adam)> {
        let s = self.stages.get(&stage)?;
        let adam = Adam::restore(cfg, s.m.clone(), s.v.clone(), self.adam_step);
        Some((s.params.clone(), adam))
    }

    /// Publish the checkpoint to `dir` atomically: the complete snapshot
    /// is staged in a scratch sibling directory and swapped into place,
    /// so a reader (or a restart after a crash mid-save) only ever
    /// observes a fully written checkpoint — never a torn iteration
    /// mixing old and new stage files. A previous snapshot at `dir`
    /// survives any failure before the final swap.
    pub fn save(&self, dir: impl AsRef<Path>) -> Result<()> {
        let dir = dir.as_ref();
        let tmp = scratch_path(dir, "tmp");
        let old = scratch_path(dir, "old");
        let _ = std::fs::remove_dir_all(&tmp);
        let _ = std::fs::remove_dir_all(&old);
        std::fs::create_dir_all(&tmp).with_context(|| format!("creating {tmp:?}"))?;
        let mut meta = format!(
            "iteration={}\nadam_step={}\nn_stages={}\n",
            self.iteration,
            self.adam_step,
            self.stages.len()
        );
        let mut stages: Vec<_> = self.stages.keys().copied().collect();
        stages.sort_unstable();
        for k in stages {
            let s = &self.stages[&k];
            write_f32(tmp.join(format!("stage{k}.params.bin")), &s.params)?;
            write_f32(tmp.join(format!("stage{k}.m.bin")), &s.m)?;
            write_f32(tmp.join(format!("stage{k}.v.bin")), &s.v)?;
            meta.push_str(&format!("stage.{k}={}\n", s.params.len()));
        }
        // meta.txt last even inside the scratch dir: a snapshot without
        // it is unambiguously incomplete.
        std::fs::write(tmp.join("meta.txt"), meta)?;
        if dir.exists() {
            std::fs::rename(dir, &old)
                .with_context(|| format!("retiring previous checkpoint {dir:?}"))?;
        }
        std::fs::rename(&tmp, dir)
            .with_context(|| format!("publishing checkpoint to {dir:?}"))?;
        let _ = std::fs::remove_dir_all(&old);
        Ok(())
    }

    pub fn load(dir: impl AsRef<Path>) -> Result<Checkpoint> {
        let dir = dir.as_ref();
        let meta = std::fs::read_to_string(dir.join("meta.txt"))
            .with_context(|| format!("reading checkpoint meta in {dir:?}"))?;
        let kv = parse_kv(&meta)?;
        let mut ckpt = Checkpoint {
            iteration: kv.get_usize("iteration", 0)?,
            adam_step: kv.get_usize("adam_step", 0)? as u64,
            stages: HashMap::new(),
        };
        for (key, val) in &kv {
            let Some(stage) = key.strip_prefix("stage.") else { continue };
            let stage: usize = stage.parse().with_context(|| format!("bad key {key}"))?;
            let len: usize = val.parse()?;
            let params = read_f32(dir.join(format!("stage{stage}.params.bin")))?;
            let m = read_f32(dir.join(format!("stage{stage}.m.bin")))?;
            let v = read_f32(dir.join(format!("stage{stage}.v.bin")))?;
            ensure!(
                params.len() == len && m.len() == len && v.len() == len,
                "stage {stage}: length mismatch (meta {len}, files {}/{}/{})",
                params.len(),
                m.len(),
                v.len()
            );
            ckpt.stages.insert(stage, StageState { params, m, v });
        }
        let want = kv.get_usize("n_stages", 0)?;
        ensure!(ckpt.stages.len() == want, "expected {want} stages, found {}", ckpt.stages.len());
        Ok(ckpt)
    }
}

/// Scratch sibling of `dir`: `ckpt` -> `ckpt.tmp` / `ckpt.old`.
fn scratch_path(dir: &Path, suffix: &str) -> PathBuf {
    let mut name = dir
        .file_name()
        .map(|s| s.to_os_string())
        .unwrap_or_else(|| std::ffi::OsString::from("ckpt"));
    name.push(format!(".{suffix}"));
    dir.with_file_name(name)
}

fn write_f32(path: impl AsRef<Path>, data: &[f32]) -> Result<()> {
    let bytes: Vec<u8> = data.iter().flat_map(|f| f.to_le_bytes()).collect();
    std::fs::write(path.as_ref(), bytes).with_context(|| format!("writing {:?}", path.as_ref()))
}

fn read_f32(path: impl AsRef<Path>) -> Result<Vec<f32>> {
    super::read_f32_file(path)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpdir(name: &str) -> std::path::PathBuf {
        let d = std::env::temp_dir().join("bitpipe_ckpt_tests").join(name);
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    #[test]
    fn roundtrip() {
        let mut adam = Adam::new(AdamConfig::default(), 4);
        let mut params = vec![1.0f32, 2.0, 3.0, 4.0];
        adam.step(&mut params, &[0.1, 0.2, 0.3, 0.4]);
        adam.step(&mut params, &[0.2, 0.1, 0.0, -0.1]);

        let mut ckpt = Checkpoint { iteration: 7, ..Default::default() };
        ckpt.put(0, params.clone(), &adam);
        ckpt.put(3, vec![9.0; 4], &adam);

        let dir = tmpdir("roundtrip");
        ckpt.save(&dir).unwrap();
        let back = Checkpoint::load(&dir).unwrap();
        assert_eq!(back.iteration, 7);
        assert_eq!(back.adam_step, 2);
        let (p, a) = back.get(0, AdamConfig::default()).unwrap();
        assert_eq!(p, params);
        assert_eq!(a.step_count(), 2);
        assert!(back.get(1, AdamConfig::default()).is_none());
    }

    #[test]
    fn restored_adam_continues_identically() {
        // Training with a restore mid-way must match uninterrupted training
        // bit-for-bit — the property that makes checkpoints trustworthy.
        let cfg = AdamConfig::default();
        let grads: Vec<Vec<f32>> = (0..6)
            .map(|t| (0..4).map(|i| ((t * 4 + i) as f32 * 0.37).sin()).collect())
            .collect();

        // Uninterrupted.
        let mut adam = Adam::new(cfg, 4);
        let mut p1 = vec![0.5f32; 4];
        for g in &grads {
            adam.step(&mut p1, g);
        }

        // Interrupted after 3 steps.
        let mut adam_a = Adam::new(cfg, 4);
        let mut p2 = vec![0.5f32; 4];
        for g in &grads[..3] {
            adam_a.step(&mut p2, g);
        }
        let mut ckpt = Checkpoint { iteration: 3, ..Default::default() };
        ckpt.put(0, p2.clone(), &adam_a);
        let dir = tmpdir("resume");
        ckpt.save(&dir).unwrap();
        let back = Checkpoint::load(&dir).unwrap();
        let (mut p3, mut adam_b) = back.get(0, cfg).unwrap();
        for g in &grads[3..] {
            adam_b.step(&mut p3, g);
        }
        assert_eq!(p1, p3, "resume diverged from uninterrupted run");
    }

    #[test]
    fn save_is_atomic_swap() {
        let dir = tmpdir("atomic");
        let adam = Adam::new(AdamConfig::default(), 2);
        let mut ckpt = Checkpoint { iteration: 1, ..Default::default() };
        ckpt.put(0, vec![1.0, 2.0], &adam);
        ckpt.save(&dir).unwrap();
        // Overwriting re-publishes in place and leaves no scratch dirs.
        ckpt.iteration = 2;
        ckpt.put(0, vec![3.0, 4.0], &adam);
        ckpt.save(&dir).unwrap();
        let back = Checkpoint::load(&dir).unwrap();
        assert_eq!(back.iteration, 2);
        assert_eq!(back.get(0, AdamConfig::default()).unwrap().0, vec![3.0, 4.0]);
        assert!(!scratch_path(&dir, "tmp").exists(), "scratch dir left behind");
        assert!(!scratch_path(&dir, "old").exists(), "retired dir left behind");
        // A torn scratch dir from a crashed save never shadows the
        // published snapshot and is cleaned up by the next save.
        std::fs::create_dir_all(scratch_path(&dir, "tmp")).unwrap();
        std::fs::write(scratch_path(&dir, "tmp").join("meta.txt"), "garbage").unwrap();
        ckpt.save(&dir).unwrap();
        assert!(Checkpoint::load(&dir).is_ok());
        assert!(!scratch_path(&dir, "tmp").exists());
    }

    #[test]
    fn corrupt_meta_rejected() {
        let dir = tmpdir("corrupt");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("meta.txt"), "iteration=1\nn_stages=2\n").unwrap();
        assert!(Checkpoint::load(&dir).is_err());
    }
}
