//! Adam optimizer over flat host parameter vectors.
//!
//! The AOT chunk executables take their parameters as one flat `f32[P]`
//! vector, so the optimizer is a plain elementwise update here in rust —
//! no Python anywhere near the training loop. Both devices holding a
//! replica of the same stage apply the identical update to the identical
//! reduced gradient, keeping the bidirectional replicas in sync without
//! any extra weight broadcast.

/// Adam hyper-parameters.
#[derive(Debug, Clone, Copy)]
pub struct AdamConfig {
    pub lr: f32,
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
    /// Decoupled weight decay (AdamW); 0 disables.
    pub weight_decay: f32,
    /// Gradient-norm clip; 0 disables.
    pub clip: f32,
}

impl Default for AdamConfig {
    fn default() -> Self {
        AdamConfig { lr: 1e-3, beta1: 0.9, beta2: 0.999, eps: 1e-8, weight_decay: 0.0, clip: 1.0 }
    }
}

/// Adam state for one flat parameter vector.
#[derive(Debug, Clone)]
pub struct Adam {
    cfg: AdamConfig,
    m: Vec<f32>,
    v: Vec<f32>,
    t: u64,
}

impl Adam {
    pub fn new(cfg: AdamConfig, n_params: usize) -> Self {
        Adam { cfg, m: vec![0.0; n_params], v: vec![0.0; n_params], t: 0 }
    }

    /// Rebuild from checkpointed moments (see `train::checkpoint`).
    pub fn restore(cfg: AdamConfig, m: Vec<f32>, v: Vec<f32>, t: u64) -> Self {
        assert_eq!(m.len(), v.len(), "moment length mismatch");
        Adam { cfg, m, v, t }
    }

    /// The (first, second) moment vectors, for checkpointing.
    pub fn moments(&self) -> (&[f32], &[f32]) {
        (&self.m, &self.v)
    }

    pub fn step_count(&self) -> u64 {
        self.t
    }

    /// One update: `params -= lr * mhat / (sqrt(vhat) + eps)`.
    ///
    /// `grad` is consumed as-is (caller normalizes by micro-batch count);
    /// clipping rescales by global norm when above `cfg.clip`.
    pub fn step(&mut self, params: &mut [f32], grad: &[f32]) {
        assert_eq!(params.len(), self.m.len(), "param length changed");
        assert_eq!(grad.len(), self.m.len(), "grad length mismatch");
        self.t += 1;

        let scale = if self.cfg.clip > 0.0 {
            let norm = grad.iter().map(|g| (*g as f64) * (*g as f64)).sum::<f64>().sqrt() as f32;
            if norm > self.cfg.clip {
                self.cfg.clip / norm
            } else {
                1.0
            }
        } else {
            1.0
        };

        let b1 = self.cfg.beta1;
        let b2 = self.cfg.beta2;
        let bc1 = 1.0 - b1.powi(self.t as i32);
        let bc2 = 1.0 - b2.powi(self.t as i32);
        let lr = self.cfg.lr;

        for i in 0..params.len() {
            let g = grad[i] * scale;
            self.m[i] = b1 * self.m[i] + (1.0 - b1) * g;
            self.v[i] = b2 * self.v[i] + (1.0 - b2) * g * g;
            let mhat = self.m[i] / bc1;
            let vhat = self.v[i] / bc2;
            let mut update = lr * mhat / (vhat.sqrt() + self.cfg.eps);
            if self.cfg.weight_decay > 0.0 {
                update += lr * self.cfg.weight_decay * params[i];
            }
            params[i] -= update;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn minimizes_quadratic() {
        // f(x) = sum((x - 3)^2); grad = 2(x - 3).
        let cfg = AdamConfig { lr: 0.1, clip: 0.0, ..Default::default() };
        let mut adam = Adam::new(cfg, 4);
        let mut x = vec![0.0f32; 4];
        for _ in 0..500 {
            let grad: Vec<f32> = x.iter().map(|xi| 2.0 * (xi - 3.0)).collect();
            adam.step(&mut x, &grad);
        }
        for xi in &x {
            assert!((xi - 3.0).abs() < 1e-2, "x = {x:?}");
        }
    }

    #[test]
    fn deterministic_across_replicas() {
        // Two replicas with identical state + grads stay bit-identical —
        // the property keeping bidirectional weight copies in sync.
        let mut a = Adam::new(AdamConfig::default(), 8);
        let mut b = Adam::new(AdamConfig::default(), 8);
        let mut xa = vec![1.0f32; 8];
        let mut xb = vec![1.0f32; 8];
        for t in 0..50 {
            let g: Vec<f32> = (0..8).map(|i| ((t * i) as f32).sin()).collect();
            a.step(&mut xa, &g);
            b.step(&mut xb, &g);
        }
        assert_eq!(xa, xb);
    }

    #[test]
    fn clipping_bounds_update() {
        let cfg = AdamConfig { lr: 1.0, clip: 1.0, ..Default::default() };
        let mut adam = Adam::new(cfg, 2);
        let mut x = vec![0.0f32; 2];
        // Huge gradient gets clipped to norm 1.
        adam.step(&mut x, &[1e6, 0.0]);
        assert!(x[0].abs() < 11.0, "update exploded: {x:?}");
    }

    #[test]
    fn first_step_bias_correction() {
        // After one step with grad g, update ≈ lr * sign(g) (bias-corrected).
        let cfg = AdamConfig { lr: 0.5, clip: 0.0, ..Default::default() };
        let mut adam = Adam::new(cfg, 1);
        let mut x = vec![0.0f32];
        adam.step(&mut x, &[0.3]);
        assert!((x[0] + 0.5).abs() < 1e-3, "x[0] = {}", x[0]);
    }

    #[test]
    #[should_panic(expected = "grad length mismatch")]
    fn length_mismatch_panics() {
        let mut adam = Adam::new(AdamConfig::default(), 2);
        let mut x = vec![0.0f32; 2];
        adam.step(&mut x, &[1.0]);
    }
}
