//! Real training runtime: threads-as-devices executing the *same*
//! instruction streams (`Schedule::device_ops`) the simulator prices, over
//! AOT-compiled XLA chunk executables.
//!
//! Each pipeline device is one OS thread owning:
//!
//! * its own PJRT CPU client + compiled chunk executables
//!   (`PjRtClient` is `Rc`-based, so never crosses threads);
//! * the parameters, gradient accumulators, and Adam state of every
//!   (pipe, stage) chunk placed on it;
//! * an activation stash — exactly one chunk *input* per in-flight
//!   micro-batch (backward artifacts recompute the chunk forward from it),
//!   which is the `M_a` accounting the paper's Table 2 uses.
//!
//! P2P activations/gradients move through the tagged-mailbox [`Fabric`];
//! the V-shaped schedule's co-located hand-offs stay device-local
//! (`LocalCopy*` never touches the fabric). Gradient synchronization uses
//! the eager exchange collective (`AllReduceStart` posts, `AllReduceWait`
//! sums), so devices may launch per-stage collectives in any order — the
//! property the eager sync of paper Fig 5(b) requires.
//!
//! Python never runs here: artifacts were lowered once by
//! `python/compile/aot.py`.

pub mod checkpoint;
pub mod data;
pub mod optim;

use crate::collective::{exchange_start, exchange_wait};
use crate::comm::{CommError, Fabric, Tag};
use crate::metrics::Counters;
use crate::runtime::{to_f32_vec, Executable, Runtime};
use crate::schedule::{
    self, Instr, PipeId, Schedule, ScheduleConfig, ScheduleKind, StageId, SyncPolicy,
};
use anyhow::{bail, ensure, Context, Result};
use data::Dataset;
use optim::{Adam, AdamConfig};
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::rc::Rc;
use std::sync::atomic::Ordering;
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// Which dataset the run draws from.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DatasetKind {
    /// Modular-affine synthetic sequences (learnable, no external data).
    Synthetic,
    /// Embedded tiny character-level corpus.
    Corpus,
}

/// Full configuration of a real training run.
#[derive(Debug, Clone)]
pub struct TrainConfig {
    /// Artifact directory (output of `make artifacts`).
    pub artifacts: PathBuf,
    /// Pipeline schedule selection.
    pub kind: ScheduleKind,
    /// Pipeline devices (threads).
    pub d: usize,
    /// Micro-batches per iteration.
    pub n: usize,
    /// Chunks per device per pipe.
    pub v: usize,
    pub sync: SyncPolicy,
    pub early_forward: bool,
    /// Training iterations.
    pub steps: usize,
    pub adam: AdamConfig,
    pub dataset: DatasetKind,
    pub seed: u64,
    /// Print a progress line every `log_every` iterations (0 = silent).
    pub log_every: usize,
    /// Save a checkpoint here after the final iteration (None = off).
    pub save_to: Option<PathBuf>,
    /// Also publish a complete snapshot to `save_to` every k iterations
    /// (0 = only at the end). Snapshots are atomic — an interrupted run
    /// always leaves a loadable checkpoint behind.
    pub save_every: usize,
    /// Resume parameters + optimizer state from this checkpoint.
    pub resume_from: Option<PathBuf>,
    /// Test hook: device `dev` fails at the start of iteration `iter`,
    /// exercising the poison/fail-fast path end to end.
    pub inject_fail: Option<(usize, usize)>,
    /// P2P receive timeout: how long a worker waits on the fabric before a
    /// schedule deadlock is reported as an error. Tests shrink this to a
    /// few seconds so a deadlock fails fast instead of hanging 30 s.
    pub recv_timeout: std::time::Duration,
}

impl TrainConfig {
    pub fn new(artifacts: impl AsRef<Path>, kind: ScheduleKind, d: usize, n: usize) -> Self {
        TrainConfig {
            artifacts: artifacts.as_ref().to_path_buf(),
            kind,
            d,
            n,
            v: kind.default_v(),
            sync: SyncPolicy::Eager,
            early_forward: true,
            steps: 20,
            adam: AdamConfig::default(),
            dataset: DatasetKind::Synthetic,
            seed: 42,
            log_every: 0,
            save_to: None,
            save_every: 0,
            resume_from: None,
            inject_fail: None,
            recv_timeout: crate::comm::RECV_TIMEOUT,
        }
    }

    fn schedule_config(&self) -> ScheduleConfig {
        ScheduleConfig::new(self.kind, self.d, self.n)
            .with_v(self.v)
            .with_sync(self.sync)
            .with_early_forward(self.early_forward)
    }
}

/// Outcome of a training run.
#[derive(Debug, Clone)]
pub struct TrainReport {
    /// Mean head loss per iteration.
    pub losses: Vec<f64>,
    /// Wall time per iteration, seconds (measured on device 0).
    pub iter_times: Vec<f64>,
    /// Total wall time, seconds.
    pub total_time: f64,
    /// Communication/compute counters over the whole run.
    pub counters: crate::metrics::CountersSnapshot,
    /// Peak activation-stash entries per device (chunk inputs).
    pub peak_stash: Vec<usize>,
}

impl TrainReport {
    /// Throughput in samples/s (micro-batch size from the manifest).
    pub fn throughput(&self, micro_batch: usize, n: usize) -> f64 {
        if self.total_time == 0.0 {
            return 0.0;
        }
        (self.losses.len() * n * micro_batch) as f64 / self.total_time
    }
}

/// Per-(pipe, stage) chunk state owned by one worker.
struct ChunkState {
    /// Flat parameters (mirrors the AOT init vector layout).
    params: Vec<f32>,
    /// Device-staged copy of `params`, invalidated by the optimizer step.
    /// Caching it saves one host->device copy of the full chunk per op —
    /// the dominant per-op overhead before the §Perf pass.
    params_buf: Option<xla::PjRtBuffer>,
    /// Gradient accumulator (sum over local micro-batches).
    grad: Vec<f32>,
    adam: Adam,
}

/// Stash entry: the chunk input needed by the backward.
enum Stash {
    Tokens(Vec<i32>),
    Act(Vec<f32>),
}

/// Poisons the fabric on drop unless disarmed: a worker that exits by
/// panic *or* error return wakes every peer blocked on `recv` promptly
/// ([`CommError::Poisoned`]) instead of leaving them to burn the full
/// receive timeout.
struct PoisonGuard {
    fabric: Fabric,
    dev: usize,
    armed: bool,
}

impl PoisonGuard {
    fn new(fabric: Fabric, dev: usize) -> Self {
        PoisonGuard { fabric, dev, armed: true }
    }
    fn disarm(mut self) {
        self.armed = false;
    }
}

impl Drop for PoisonGuard {
    fn drop(&mut self) {
        if self.armed {
            self.fabric.poison(self.dev);
        }
    }
}

/// Collects one complete parameter/optimizer snapshot per save boundary
/// from every worker and publishes it — atomically, via
/// [`checkpoint::Checkpoint::save`] — once the last worker has
/// contributed. Mid-run checkpoints therefore never mix iterations: each
/// worker contributes its own chunks exactly at its own iteration
/// boundary, and nothing is written until the snapshot is whole.
struct CheckpointSink {
    dir: PathBuf,
    n_workers: usize,
    /// iteration -> (accumulating snapshot, workers contributed).
    pending: Mutex<HashMap<usize, (checkpoint::Checkpoint, usize)>>,
    /// Highest iteration already published (free-running workers can
    /// complete an older boundary after a newer one; never regress).
    published: Mutex<usize>,
}

impl CheckpointSink {
    fn new(dir: PathBuf, n_workers: usize) -> Self {
        CheckpointSink {
            dir,
            n_workers,
            pending: Mutex::new(HashMap::new()),
            published: Mutex::new(0),
        }
    }

    /// Record one worker's chunks as of completed (global) iteration
    /// `iteration`; the contribution completing the snapshot publishes it.
    fn contribute(
        &self,
        iteration: usize,
        chunks: &HashMap<(PipeId, StageId), ChunkState>,
    ) -> Result<()> {
        let ready = {
            let mut pending = self.pending.lock().unwrap();
            let entry = pending
                .entry(iteration)
                .or_insert_with(|| (checkpoint::Checkpoint { iteration, ..Default::default() }, 0));
            for ((_, stage), chunk) in chunks {
                entry.0.put(*stage, chunk.params.clone(), &chunk.adam);
            }
            entry.1 += 1;
            if entry.1 == self.n_workers {
                pending.remove(&iteration).map(|(snap, _)| snap)
            } else {
                None
            }
        };
        if let Some(snap) = ready {
            let mut published = self.published.lock().unwrap();
            if iteration > *published {
                snap.save(&self.dir).with_context(|| {
                    format!("publishing mid-run checkpoint to {:?}", self.dir)
                })?;
                *published = iteration;
            }
        }
        Ok(())
    }
}

/// Run a real training job. Spawns `cfg.d` worker threads, each executing
/// its device's instruction stream for `cfg.steps` iterations.
pub fn run(cfg: &TrainConfig) -> Result<TrainReport> {
    let sched = schedule::build(&cfg.schedule_config())?;
    schedule::validate::validate(&sched).context("generated schedule failed validation")?;

    // Manifest sanity against the requested schedule shape.
    let manifest = crate::runtime::Manifest::load(cfg.artifacts.join("manifest.txt"))?;
    ensure!(
        manifest.n_chunks == cfg.v * cfg.d,
        "artifacts were lowered for {} chunks but schedule needs v*D = {} \
         (rebuild with `python -m compile.aot --n-chunks {}`)",
        manifest.n_chunks,
        cfg.v * cfg.d,
        cfg.v * cfg.d
    );

    let dataset: Arc<dyn Dataset> = match cfg.dataset {
        DatasetKind::Synthetic => Arc::new(data::SyntheticLm::new(
            manifest.batch,
            manifest.seq,
            manifest.vocab,
            cfg.seed,
        )),
        DatasetKind::Corpus => {
            ensure!(
                manifest.vocab >= 128,
                "corpus dataset needs vocab >= 128 (got {})",
                manifest.vocab
            );
            Arc::new(data::TinyCorpus::new(manifest.batch, manifest.seq, cfg.seed))
        }
    };

    let fabric = Fabric::with_timeout(cfg.d, cfg.recv_timeout);
    let counters = Arc::new(Counters::new());
    let losses: Arc<Mutex<Vec<(usize, f32)>>> = Arc::new(Mutex::new(Vec::new()));
    let iter_times: Arc<Mutex<Vec<f64>>> = Arc::new(Mutex::new(Vec::new()));
    let resume: Option<Arc<checkpoint::Checkpoint>> = match &cfg.resume_from {
        Some(dir) => {
            let c = checkpoint::Checkpoint::load(dir)
                .with_context(|| format!("resuming from {dir:?}"))?;
            ensure!(
                c.stages.len() == manifest.n_chunks,
                "checkpoint has {} stages, artifacts expect {}",
                c.stages.len(),
                manifest.n_chunks
            );
            Some(Arc::new(c))
        }
        None => None,
    };
    let base_iter = resume.as_ref().map_or(0, |c| c.iteration);
    let final_state: Arc<Mutex<checkpoint::Checkpoint>> =
        Arc::new(Mutex::new(checkpoint::Checkpoint::default()));
    let sink: Option<Arc<CheckpointSink>> = match (&cfg.save_to, cfg.save_every) {
        (Some(dir), k) if k > 0 => Some(Arc::new(CheckpointSink::new(dir.clone(), cfg.d))),
        _ => None,
    };
    let start = Instant::now();

    let peak_stash = std::thread::scope(|scope| -> Result<Vec<usize>> {
        let mut handles = Vec::new();
        for dev in 0..cfg.d {
            let sched = &sched;
            let cfg = &cfg;
            let fabric = fabric.clone();
            let counters = counters.clone();
            let losses = losses.clone();
            let iter_times = iter_times.clone();
            let dataset = dataset.clone();
            let resume = resume.clone();
            let final_state = final_state.clone();
            let sink = sink.clone();
            handles.push(scope.spawn(move || -> Result<usize> {
                // Any exit without disarming — a panic or an error return
                // — poisons the fabric so peers fail fast instead of
                // waiting out their receive timeout on a dead sender.
                let guard = PoisonGuard::new(fabric.clone(), dev);
                let mut w = Worker::new(
                    dev,
                    cfg,
                    sched,
                    fabric,
                    dataset,
                    counters,
                    losses.clone(),
                    resume.as_deref(),
                )?;
                w.base_iter = base_iter;
                for iter in 0..cfg.steps {
                    if cfg.inject_fail == Some((dev, iter)) {
                        bail!("injected failure on device {dev} at iteration {iter} (test hook)");
                    }
                    let t0 = Instant::now();
                    w.run_iteration(iter)
                        .with_context(|| format!("device {dev}, iteration {iter}"))?;
                    if dev == 0 {
                        iter_times.lock().unwrap().push(t0.elapsed().as_secs_f64());
                        if cfg.log_every > 0 && (iter + 1) % cfg.log_every == 0 {
                            let snap = losses.lock().unwrap();
                            let recent: Vec<f32> = snap
                                .iter()
                                .filter(|&&(i, _)| i == iter)
                                .map(|&(_, l)| l)
                                .collect();
                            let mean = if recent.is_empty() {
                                f32::NAN
                            } else {
                                recent.iter().sum::<f32>() / recent.len() as f32
                            };
                            eprintln!(
                                "iter {:4}  loss {:.4}  {:.2}s/it",
                                iter + 1,
                                mean,
                                t0.elapsed().as_secs_f64()
                            );
                        }
                    }
                    if let Some(sink) = &sink {
                        if (iter + 1) % cfg.save_every == 0 && iter + 1 < cfg.steps {
                            sink.contribute(base_iter + iter + 1, &w.chunks)?;
                        }
                    }
                    let _ = iter;
                }
                if cfg.save_to.is_some() {
                    let mut out = final_state.lock().unwrap();
                    for ((_, stage), chunk) in &w.chunks {
                        out.put(*stage, chunk.params.clone(), &chunk.adam);
                    }
                }
                guard.disarm();
                Ok(w.peak_stash)
            }));
        }
        // Surface the root cause, not the collateral: a dead worker
        // poisons the fabric, so every peer reports Poisoned — prefer the
        // one error that is *not* a poison echo.
        let mut peaks = Vec::new();
        let mut root: Option<anyhow::Error> = None;
        let mut collateral: Option<anyhow::Error> = None;
        for h in handles {
            match h.join() {
                Err(_) => {
                    if root.is_none() {
                        root = Some(anyhow::anyhow!("worker panicked"));
                    }
                }
                Ok(Ok(p)) => peaks.push(p),
                Ok(Err(e)) => {
                    let poisoned = e.chain().any(|c| {
                        matches!(c.downcast_ref::<CommError>(), Some(CommError::Poisoned { .. }))
                    });
                    if poisoned {
                        if collateral.is_none() {
                            collateral = Some(e);
                        }
                    } else if root.is_none() {
                        root = Some(e);
                    }
                }
            }
        }
        if let Some(e) = root.or(collateral) {
            return Err(e);
        }
        Ok(peaks)
    })?;

    let total_time = start.elapsed().as_secs_f64();

    if let Some(dir) = &cfg.save_to {
        let mut ckpt = final_state.lock().unwrap();
        ckpt.iteration = base_iter + cfg.steps;
        ckpt.save(dir).with_context(|| format!("saving checkpoint to {dir:?}"))?;
    }

    // Average losses per iteration.
    let raw = losses.lock().unwrap();
    let mut per_iter: Vec<(f64, usize)> = vec![(0.0, 0); cfg.steps];
    for &(iter, l) in raw.iter() {
        per_iter[iter].0 += l as f64;
        per_iter[iter].1 += 1;
    }
    let losses: Vec<f64> = per_iter
        .into_iter()
        .map(|(s, c)| if c > 0 { s / c as f64 } else { f64::NAN })
        .collect();
    ensure!(
        losses.iter().all(|l| l.is_finite()),
        "some iterations recorded no loss (head stage never ran?)"
    );

    let iter_times = iter_times.lock().unwrap().clone();
    Ok(TrainReport {
        losses,
        iter_times,
        total_time,
        counters: counters.snapshot(),
        peak_stash,
    })
}

/// One device's execution context.
struct Worker<'a> {
    dev: usize,
    cfg: &'a TrainConfig,
    sched: &'a Schedule,
    fabric: Fabric,
    dataset: Arc<dyn Dataset>,
    counters: Arc<Counters>,
    losses: Arc<Mutex<Vec<(usize, f32)>>>,

    manifest: crate::runtime::Manifest,
    /// Completed iterations in a resumed run: the dataset and message tags
    /// advance globally so resume is bit-exact with uninterrupted training.
    base_iter: usize,
    rt: Runtime,
    exes: HashMap<&'static str, Rc<Executable>>,
    chunks: HashMap<(PipeId, StageId), ChunkState>,

    // Per-iteration dataflow buffers, keyed by (pipe, stage, mb).
    inbox_act: HashMap<(usize, usize, usize), Vec<f32>>,
    outbox_act: HashMap<(usize, usize, usize), Vec<f32>>,
    inbox_grad: HashMap<(usize, usize, usize), Vec<f32>>,
    outbox_grad: HashMap<(usize, usize, usize), Vec<f32>>,
    stash: HashMap<(usize, usize, usize), Stash>,
    peak_stash: usize,
}

const EXE_NAMES: [&str; 6] =
    ["fwd_embed", "fwd_mid", "fwd_head", "bwd_embed", "bwd_mid", "bwd_head"];

impl<'a> Worker<'a> {
    #[allow(clippy::too_many_arguments)]
    fn new(
        dev: usize,
        cfg: &'a TrainConfig,
        sched: &'a Schedule,
        fabric: Fabric,
        dataset: Arc<dyn Dataset>,
        counters: Arc<Counters>,
        losses: Arc<Mutex<Vec<(usize, f32)>>>,
        resume: Option<&checkpoint::Checkpoint>,
    ) -> Result<Self> {
        let mut rt = Runtime::open(&cfg.artifacts)?;
        let manifest = rt.manifest.clone();

        let mut exes = HashMap::new();
        for name in EXE_NAMES {
            exes.insert(name, rt.load(name)?);
        }

        // Parameter state for every chunk this device hosts. Both pipes'
        // replicas of a stage start from the identical init vector (the
        // bidirectional twins are model replicas kept in sync by the
        // gradient exchange).
        let mut chunks = HashMap::new();
        for &(pipe, stage) in &sched.placement.chunks_on[dev] {
            let (params, adam) = match resume.and_then(|c| c.get(stage, cfg.adam)) {
                Some(state) => state,
                None => {
                    let file = manifest
                        .init_file(stage)
                        .with_context(|| format!("manifest missing init.{stage}"))?;
                    let params = read_f32_file(cfg.artifacts.join(file))?;
                    let adam = Adam::new(cfg.adam, params.len());
                    (params, adam)
                }
            };
            let role = manifest.role_of_stage(stage);
            let want = manifest
                .param_len(role)
                .with_context(|| format!("manifest missing params.{role}"))?;
            ensure!(
                params.len() == want,
                "stage {stage} parameter vector has {} f32s, manifest says {want}",
                params.len()
            );
            let grad = vec![0.0; params.len()];
            chunks.insert(
                (pipe, stage),
                ChunkState { params, params_buf: None, grad, adam },
            );
        }

        Ok(Worker {
            dev,
            cfg,
            sched,
            fabric,
            dataset,
            counters,
            losses,
            manifest,
            base_iter: 0,
            rt,
            exes,
            chunks,
            inbox_act: HashMap::new(),
            outbox_act: HashMap::new(),
            inbox_grad: HashMap::new(),
            outbox_grad: HashMap::new(),
            stash: HashMap::new(),
            peak_stash: 0,
        })
    }

    /// Message tag micro-batch id, unique across iterations so streams of
    /// consecutive iterations can overlap without tag collisions.
    fn tag_mb(&self, giter: usize, mb: usize) -> usize {
        giter * self.cfg.n + mb
    }

    fn run_iteration(&mut self, iter: usize) -> Result<()> {
        // Data and tags advance by the *global* iteration index so a
        // checkpoint-resumed run consumes exactly the batches the
        // uninterrupted run would have.
        let giter = self.base_iter + iter;
        for i in 0..self.sched.device_ops[self.dev].len() {
            let instr = self.sched.device_ops[self.dev][i];
            self.exec(iter, giter, &instr)
                .with_context(|| format!("instruction {i}: {instr}"))?;
        }
        // Dataflow buffers must drain completely each iteration: leftovers
        // mean the schedule and the runtime disagree.
        ensure!(self.stash.is_empty(), "stash not drained: {} entries", self.stash.len());
        ensure!(self.inbox_act.is_empty() && self.inbox_grad.is_empty(), "inbox not drained");
        ensure!(self.outbox_act.is_empty() && self.outbox_grad.is_empty(), "outbox not drained");
        Ok(())
    }

    fn exec(&mut self, iter: usize, giter: usize, instr: &Instr) -> Result<()> {
        match *instr {
            Instr::Forward { pipe, stage, mb } => self.forward(iter, giter, pipe, stage, mb),
            Instr::Backward { pipe, stage, mb } => self.backward(giter, pipe, stage, mb),
            // The reference runtime computes both halves of a split
            // backward at Bi (numerically identical to the fused op); the
            // deferred W is then a timing-only no-op here.
            Instr::BackwardInput { pipe, stage, mb } => self.backward(giter, pipe, stage, mb),
            Instr::BackwardWeight { .. } => Ok(()),
            Instr::SendAct { to, pipe, stage, mb } => {
                let payload = self
                    .outbox_act
                    .remove(&(pipe, stage, mb))
                    .with_context(|| format!("SendAct: no output for (p{pipe},s{stage},m{mb})"))?;
                self.counters.p2p_msgs.fetch_add(1, Ordering::Relaxed);
                self.counters
                    .p2p_bytes
                    .fetch_add((payload.len() * 4) as u64, Ordering::Relaxed);
                self.fabric
                    .send(to, Tag::act(self.dev, pipe, stage, self.tag_mb(giter, mb)), payload)?;
                Ok(())
            }
            Instr::RecvAct { from, pipe, stage, mb } => {
                let v = self
                    .fabric
                    .recv(self.dev, Tag::act(from, pipe, stage - 1, self.tag_mb(giter, mb)))?;
                self.inbox_act.insert((pipe, stage, mb), v);
                Ok(())
            }
            Instr::SendGrad { to, pipe, stage, mb } => {
                let payload = self
                    .outbox_grad
                    .remove(&(pipe, stage, mb))
                    .with_context(|| format!("SendGrad: no grad for (p{pipe},s{stage},m{mb})"))?;
                self.counters.p2p_msgs.fetch_add(1, Ordering::Relaxed);
                self.counters
                    .p2p_bytes
                    .fetch_add((payload.len() * 4) as u64, Ordering::Relaxed);
                self.fabric
                    .send(to, Tag::grad(self.dev, pipe, stage, self.tag_mb(giter, mb)), payload)?;
                Ok(())
            }
            Instr::RecvGrad { from, pipe, stage, mb } => {
                let v = self
                    .fabric
                    .recv(self.dev, Tag::grad(from, pipe, stage + 1, self.tag_mb(giter, mb)))?;
                self.inbox_grad.insert((pipe, stage, mb), v);
                Ok(())
            }
            Instr::LocalCopyAct { pipe, stage, mb } => {
                // Producer `stage` output becomes consumer `stage+1` input —
                // a move, not a copy (the V-shape saving in its purest form).
                let v = self
                    .outbox_act
                    .remove(&(pipe, stage, mb))
                    .with_context(|| format!("LocalCopyAct: no output (p{pipe},s{stage},m{mb})"))?;
                self.counters.local_copies.fetch_add(1, Ordering::Relaxed);
                self.inbox_act.insert((pipe, stage + 1, mb), v);
                Ok(())
            }
            Instr::LocalCopyGrad { pipe, stage, mb } => {
                let v = self
                    .outbox_grad
                    .remove(&(pipe, stage, mb))
                    .with_context(|| format!("LocalCopyGrad: no grad (p{pipe},s{stage},m{mb})"))?;
                self.counters.local_copies.fetch_add(1, Ordering::Relaxed);
                self.inbox_grad.insert((pipe, stage - 1, mb), v);
                Ok(())
            }
            Instr::AllReduceStart { stage } => {
                let group = self.sched.placement.allreduce_group(stage);
                if group.len() > 1 {
                    let chunk = self.local_chunk(stage)?;
                    exchange_start(&self.fabric, self.dev, &group, stage, giter, &chunk.grad)?;
                }
                Ok(())
            }
            Instr::AllReduceWait { stage } => {
                let group = self.sched.placement.allreduce_group(stage);
                if group.len() > 1 {
                    let dev = self.dev;
                    let fabric = self.fabric.clone();
                    let chunk = self.local_chunk_mut(stage)?;
                    exchange_wait(&fabric, dev, &group, stage, giter, &mut chunk.grad)?;
                    self.counters.allreduces.fetch_add(1, Ordering::Relaxed);
                    let bytes = (self.local_chunk(stage)?.grad.len() * 4) as u64;
                    self.counters.allreduce_bytes.fetch_add(bytes, Ordering::Relaxed);
                }
                Ok(())
            }
            Instr::OptimStep { stage } => {
                let n = self.cfg.n as f32;
                let chunk = self.local_chunk_mut(stage)?;
                // grad currently holds the *sum* over all N micro-batches
                // (local accumulation + cross-replica exchange); normalize
                // to the mini-batch mean before the update.
                let scaled: Vec<f32> = chunk.grad.iter().map(|g| g / n).collect();
                chunk.adam.step(&mut chunk.params, &scaled);
                chunk.grad.iter_mut().for_each(|g| *g = 0.0);
                chunk.params_buf = None; // re-stage on next use
                self.counters.optim_steps.fetch_add(1, Ordering::Relaxed);
                Ok(())
            }
        }
    }

    /// The single local replica of model `stage` (each device hosts a stage
    /// for at most one pipe — mirrored placements guarantee it for even D).
    fn local_chunk(&self, stage: StageId) -> Result<&ChunkState> {
        for p in 0..self.sched.placement.n_pipes {
            if let Some(c) = self.chunks.get(&(p, stage)) {
                return Ok(c);
            }
        }
        bail!("device {} holds no replica of stage {stage}", self.dev)
    }

    fn local_chunk_mut(&mut self, stage: StageId) -> Result<&mut ChunkState> {
        for p in 0..self.sched.placement.n_pipes {
            if self.chunks.contains_key(&(p, stage)) {
                return Ok(self.chunks.get_mut(&(p, stage)).unwrap());
            }
        }
        bail!("device {} holds no replica of stage {stage}", self.dev)
    }

    /// Ensure the chunk's parameters are staged on device (rebuilt only
    /// after an optimizer step invalidated the cache). Callers then borrow
    /// `self.chunks[..].params_buf` directly.
    fn ensure_params_buf(&mut self, pipe: usize, stage: usize) -> Result<()> {
        let chunk = self
            .chunks
            .get_mut(&(pipe, stage))
            .with_context(|| format!("no chunk state for (p{pipe},s{stage})"))?;
        if chunk.params_buf.is_none() {
            chunk.params_buf = Some(self.rt.buf_f32(&chunk.params, &[chunk.params.len()])?);
        }
        Ok(())
    }

    fn forward(
        &mut self,
        iter: usize,
        giter: usize,
        pipe: usize,
        stage: usize,
        mb: usize,
    ) -> Result<()> {
        let (b, s, h) =
            (self.manifest.batch, self.manifest.seq, self.manifest.hidden);
        let role = self.manifest.role_of_stage(stage);
        self.ensure_params_buf(pipe, stage)?;

        match role {
            "embed" => {
                let (tokens, _) = self.dataset.batch(giter, mb);
                let tok = self.rt.buf_i32(&tokens, &[b, s])?;
                let params = self.chunks[&(pipe, stage)].params_buf.as_ref().unwrap();
                let out = self.exes["fwd_embed"].run_b(&[&tok, params])?;
                let act = to_f32_vec(&out[0])?;
                self.outbox_act.insert((pipe, stage, mb), act);
                self.stash.insert((pipe, stage, mb), Stash::Tokens(tokens));
            }
            "mid" => {
                let x = self
                    .inbox_act
                    .remove(&(pipe, stage, mb))
                    .with_context(|| format!("no input act for (p{pipe},s{stage},m{mb})"))?;
                let x_buf = self.rt.buf_f32(&x, &[b, s, h])?;
                let params = self.chunks[&(pipe, stage)].params_buf.as_ref().unwrap();
                let out = self.exes["fwd_mid"].run_b(&[&x_buf, params])?;
                let act = to_f32_vec(&out[0])?;
                self.outbox_act.insert((pipe, stage, mb), act);
                self.stash.insert((pipe, stage, mb), Stash::Act(x));
            }
            "head" => {
                let x = self
                    .inbox_act
                    .remove(&(pipe, stage, mb))
                    .with_context(|| format!("no input act for head (p{pipe},m{mb})"))?;
                let (_, targets) = self.dataset.batch(giter, mb);
                let x_buf = self.rt.buf_f32(&x, &[b, s, h])?;
                let t_buf = self.rt.buf_i32(&targets, &[b, s])?;
                let params = self.chunks[&(pipe, stage)].params_buf.as_ref().unwrap();
                let out = self.exes["fwd_head"].run_b(&[&x_buf, &t_buf, params])?;
                let loss = to_f32_vec(&out[0])?[0];
                self.losses.lock().unwrap().push((iter, loss));
                self.stash.insert((pipe, stage, mb), Stash::Act(x));
            }
            other => bail!("unknown role {other}"),
        }
        self.peak_stash = self.peak_stash.max(self.stash.len());
        self.counters.forwards.fetch_add(1, Ordering::Relaxed);
        Ok(())
    }

    fn backward(&mut self, giter: usize, pipe: usize, stage: usize, mb: usize) -> Result<()> {
        let (b, s, h) =
            (self.manifest.batch, self.manifest.seq, self.manifest.hidden);
        let role = self.manifest.role_of_stage(stage);
        let stashed = self
            .stash
            .remove(&(pipe, stage, mb))
            .with_context(|| format!("no stash for (p{pipe},s{stage},m{mb})"))?;
        self.ensure_params_buf(pipe, stage)?;

        let (dx, dflat) = match role {
            "embed" => {
                let Stash::Tokens(tokens) = stashed else {
                    bail!("embed stash is not tokens")
                };
                let g = self
                    .inbox_grad
                    .remove(&(pipe, stage, mb))
                    .with_context(|| format!("no upstream grad for embed m{mb}"))?;
                let tok = self.rt.buf_i32(&tokens, &[b, s])?;
                let g_buf = self.rt.buf_f32(&g, &[b, s, h])?;
                let params = self.chunks[&(pipe, stage)].params_buf.as_ref().unwrap();
                let out = self.exes["bwd_embed"].run_b(&[&tok, &g_buf, params])?;
                (None, to_f32_vec(&out[0])?)
            }
            "mid" => {
                let Stash::Act(x) = stashed else { bail!("mid stash is not an activation") };
                let g = self
                    .inbox_grad
                    .remove(&(pipe, stage, mb))
                    .with_context(|| format!("no upstream grad for s{stage} m{mb}"))?;
                let x_buf = self.rt.buf_f32(&x, &[b, s, h])?;
                let g_buf = self.rt.buf_f32(&g, &[b, s, h])?;
                let params = self.chunks[&(pipe, stage)].params_buf.as_ref().unwrap();
                let out = self.exes["bwd_mid"].run_b(&[&x_buf, &g_buf, params])?;
                (Some(to_f32_vec(&out[0])?), to_f32_vec(&out[1])?)
            }
            "head" => {
                let Stash::Act(x) = stashed else { bail!("head stash is not an activation") };
                let (_, targets) = self.dataset.batch(giter, mb);
                let x_buf = self.rt.buf_f32(&x, &[b, s, h])?;
                let t_buf = self.rt.buf_i32(&targets, &[b, s])?;
                let params = self.chunks[&(pipe, stage)].params_buf.as_ref().unwrap();
                let out = self.exes["bwd_head"].run_b(&[&x_buf, &t_buf, params])?;
                // outputs: (loss, dx, dflat)
                (Some(to_f32_vec(&out[1])?), to_f32_vec(&out[2])?)
            }
            other => bail!("unknown role {other}"),
        };

        // Accumulate the weight gradient.
        let chunk = self.chunks.get_mut(&(pipe, stage)).unwrap();
        ensure!(dflat.len() == chunk.grad.len(), "dflat length mismatch");
        for (a, g) in chunk.grad.iter_mut().zip(&dflat) {
            *a += g;
        }

        // Input gradient flows to stage-1 (unless this is the entry chunk).
        if let Some(dx) = dx {
            if stage > 0 {
                self.outbox_grad.insert((pipe, stage, mb), dx);
            }
        }
        self.counters.backwards.fetch_add(1, Ordering::Relaxed);
        Ok(())
    }
}

/// Read a little-endian f32 binary file into a vector.
fn read_f32_file(path: impl AsRef<Path>) -> Result<Vec<f32>> {
    let path = path.as_ref();
    let bytes =
        std::fs::read(path).with_context(|| format!("reading init vector {path:?}"))?;
    ensure!(bytes.len() % 4 == 0, "{path:?}: length {} not a multiple of 4", bytes.len());
    Ok(bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    // Tests that execute real artifacts live in rust/tests/e2e_train.rs
    // (they need `make artifacts`). Here: pure host-side pieces.

    #[test]
    fn read_f32_roundtrip() {
        let dir = std::env::temp_dir().join("bitpipe_test_f32");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("x.bin");
        let data = [1.0f32, -2.5, 3.25];
        let bytes: Vec<u8> = data.iter().flat_map(|f| f.to_le_bytes()).collect();
        std::fs::write(&path, bytes).unwrap();
        assert_eq!(read_f32_file(&path).unwrap(), data);
    }

    #[test]
    fn read_f32_rejects_ragged() {
        let dir = std::env::temp_dir().join("bitpipe_test_f32");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.bin");
        std::fs::write(&path, [0u8; 7]).unwrap();
        assert!(read_f32_file(&path).is_err());
    }

    #[test]
    fn train_config_defaults() {
        let cfg = TrainConfig::new("/tmp/a", ScheduleKind::BitPipe, 4, 8);
        assert_eq!(cfg.v, 2);
        assert_eq!(cfg.sync, SyncPolicy::Eager);
        let sc = cfg.schedule_config();
        assert_eq!(sc.kind, ScheduleKind::BitPipe);
        assert_eq!(sc.d, 4);
        assert_eq!(sc.n, 8);
    }

    #[test]
    fn missing_artifacts_reported() {
        let cfg = TrainConfig::new("/nonexistent/dir", ScheduleKind::Dapple, 2, 2);
        let err = run(&cfg).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("manifest"), "unhelpful error: {msg}");
    }
}
