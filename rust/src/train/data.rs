//! Training data pipeline: synthetic sequence tasks and a tiny embedded
//! text corpus with a character-level tokenizer.
//!
//! Determinism contract: `batch(iter, mb)` is a pure function of the seed
//! and indices, so every worker thread can materialize the batch it needs
//! locally — no data distribution traffic competes with the pipeline's
//! P2P (matching how Megatron-style loaders shard deterministically).

use crate::util::Prng;

/// A (tokens, targets) pair, both `B * S` flattened row-major.
pub type Batch = (Vec<i32>, Vec<i32>);

/// Data source for language-model training.
pub trait Dataset: Send + Sync {
    /// Vocabulary size the stream draws from.
    fn vocab(&self) -> usize;
    /// The micro-batch for (iteration, micro-batch index).
    fn batch(&self, iter: usize, mb: usize) -> Batch;
}

/// Synthetic modular-affine sequences: `x[t+1] = (a * x[t] + b) mod V`,
/// with per-sequence random `a, b, x0`. Next-token prediction on these is
/// learnable (the model must infer `a, b` from context), so the loss curve
/// visibly drops — a real training signal without external data.
#[derive(Debug, Clone)]
pub struct SyntheticLm {
    pub batch_size: usize,
    pub seq_len: usize,
    pub vocab_size: usize,
    pub seed: u64,
}

impl SyntheticLm {
    pub fn new(batch_size: usize, seq_len: usize, vocab_size: usize, seed: u64) -> Self {
        assert!(vocab_size >= 4);
        SyntheticLm { batch_size, seq_len, vocab_size, seed }
    }
}

impl Dataset for SyntheticLm {
    fn vocab(&self) -> usize {
        self.vocab_size
    }

    fn batch(&self, iter: usize, mb: usize) -> Batch {
        let v = self.vocab_size as u64;
        let mut tokens = Vec::with_capacity(self.batch_size * self.seq_len);
        let mut targets = Vec::with_capacity(self.batch_size * self.seq_len);
        for row in 0..self.batch_size {
            let mut rng = Prng::new(
                self.seed
                    ^ (iter as u64).wrapping_mul(0x9E3779B97F4A7C15)
                    ^ (mb as u64).wrapping_mul(0xC2B2AE3D27D4EB4F)
                    ^ (row as u64).wrapping_mul(0x165667B19E3779F9),
            );
            // Odd multiplier keeps the orbit long.
            let a = 2 * rng.below(v / 2) + 1;
            let b = rng.below(v);
            let mut x = rng.below(v);
            for _ in 0..self.seq_len {
                tokens.push(x as i32);
                x = (a.wrapping_mul(x).wrapping_add(b)) % v;
                targets.push(x as i32);
            }
        }
        (tokens, targets)
    }
}

/// Character-level corpus over an embedded public-domain text sample.
/// Windows are drawn at deterministic pseudo-random offsets.
#[derive(Debug, Clone)]
pub struct TinyCorpus {
    pub batch_size: usize,
    pub seq_len: usize,
    pub seed: u64,
    data: Vec<i32>,
    vocab_size: usize,
}

/// Small embedded corpus (public-domain: Lincoln's Gettysburg Address plus
/// the US constitution preamble, repeated structure helps a tiny model).
const CORPUS: &str = "Four score and seven years ago our fathers brought forth on this \
continent, a new nation, conceived in Liberty, and dedicated to the proposition that \
all men are created equal. Now we are engaged in a great civil war, testing whether \
that nation, or any nation so conceived and so dedicated, can long endure. We are met \
on a great battle-field of that war. We have come to dedicate a portion of that field, \
as a final resting place for those who here gave their lives that that nation might \
live. It is altogether fitting and proper that we should do this. We the People of the \
United States, in Order to form a more perfect Union, establish Justice, insure \
domestic Tranquility, provide for the common defence, promote the general Welfare, and \
secure the Blessings of Liberty to ourselves and our Posterity, do ordain and \
establish this Constitution for the United States of America.";

impl TinyCorpus {
    pub fn new(batch_size: usize, seq_len: usize, seed: u64) -> Self {
        // Character vocabulary: bytes clamped to 7-bit printable range.
        let data: Vec<i32> = CORPUS.bytes().map(|b| (b & 0x7f) as i32).collect();
        assert!(data.len() > seq_len + 1, "corpus shorter than sequence length");
        TinyCorpus { batch_size, seq_len, seed, data, vocab_size: 128 }
    }

    pub fn corpus_len(&self) -> usize {
        self.data.len()
    }
}

impl Dataset for TinyCorpus {
    fn vocab(&self) -> usize {
        self.vocab_size
    }

    fn batch(&self, iter: usize, mb: usize) -> Batch {
        let mut tokens = Vec::with_capacity(self.batch_size * self.seq_len);
        let mut targets = Vec::with_capacity(self.batch_size * self.seq_len);
        let max_start = self.data.len() - self.seq_len - 1;
        for row in 0..self.batch_size {
            let mut rng = Prng::new(
                self.seed
                    ^ (iter as u64).wrapping_mul(0xD6E8FEB86659FD93)
                    ^ (mb as u64).wrapping_mul(0xA3B195354A39B70D)
                    ^ row as u64,
            );
            let start = rng.below(max_start as u64 + 1) as usize;
            tokens.extend_from_slice(&self.data[start..start + self.seq_len]);
            targets.extend_from_slice(&self.data[start + 1..start + self.seq_len + 1]);
        }
        (tokens, targets)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn synthetic_shapes_and_range() {
        let ds = SyntheticLm::new(4, 16, 64, 1);
        let (t, y) = ds.batch(0, 0);
        assert_eq!(t.len(), 64);
        assert_eq!(y.len(), 64);
        assert!(t.iter().all(|&x| (0..64).contains(&x)));
        assert!(y.iter().all(|&x| (0..64).contains(&x)));
    }

    #[test]
    fn synthetic_targets_shift_tokens() {
        let ds = SyntheticLm::new(2, 8, 32, 7);
        let (t, y) = ds.batch(3, 1);
        // Within a row: target[i] == token[i+1].
        for row in 0..2 {
            for i in 0..7 {
                assert_eq!(y[row * 8 + i], t[row * 8 + i + 1]);
            }
        }
    }

    #[test]
    fn synthetic_deterministic_but_varies() {
        let ds = SyntheticLm::new(2, 8, 32, 7);
        assert_eq!(ds.batch(0, 0), ds.batch(0, 0));
        assert_ne!(ds.batch(0, 0), ds.batch(0, 1));
        assert_ne!(ds.batch(0, 0), ds.batch(1, 0));
    }

    #[test]
    fn corpus_windows_valid() {
        let ds = TinyCorpus::new(2, 32, 5);
        let (t, y) = ds.batch(0, 0);
        assert_eq!(t.len(), 64);
        assert!(t.iter().all(|&x| (0..128).contains(&x)));
        for i in 0..31 {
            assert_eq!(y[i], t[i + 1]);
        }
    }

    #[test]
    fn corpus_deterministic() {
        let a = TinyCorpus::new(2, 16, 9);
        let b = TinyCorpus::new(2, 16, 9);
        assert_eq!(a.batch(4, 2), b.batch(4, 2));
    }
}
