//! Tagged-mailbox P2P transport for the threads-as-devices runtime.
//!
//! Design requirements coming from pipeline schedules:
//!
//! * **Eager sends** — a sender never blocks (the schedule relies on
//!   forward progress while the consumer is still computing);
//! * **Out-of-order receive by tag** — bidirectional schedules interleave
//!   messages of both pipes on one channel pair, and the consumer must be
//!   able to wait for *the specific* (pipe, stage, micro-batch) tensor it
//!   needs next, regardless of arrival order. A single FIFO would deadlock
//!   BitPipe's fused streams.
//!
//! Implementation: one mailbox per device, `Mutex<HashMap<Tag, queue>>`
//! plus a `Condvar`. Payloads are boxed `Vec<f32>` (activation/gradient
//! tensors) moved, never copied. Messages queued under the *same* tag are
//! delivered FIFO (a `VecDeque` per slot), mirroring the simulator's
//! in-order pairing of duplicate tags.
//!
//! # Fail-fast poisoning
//!
//! A worker that dies (panic, fatal error) would historically leave every
//! peer blocked on `recv` until the full receive timeout expired.
//! [`Fabric::poison`] is the fail-fast path: it marks the fabric poisoned
//! (first poisoner wins) and rings every mailbox's bell, so all blocked
//! receivers wake promptly with [`CommError::Poisoned`] naming the dead
//! worker. Messages already delivered still drain first — a receiver with
//! its tensor waiting takes it even on a poisoned fabric — but nobody
//! waits for data that can no longer arrive.

use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// Message tag: (from, class, pipe, producer stage, micro-batch).
///
/// `class` disambiguates traffic kinds sharing a mailbox:
/// activations, gradients, and collective fragments.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Tag {
    pub from: usize,
    pub class: MsgClass,
    pub pipe: usize,
    pub stage: usize,
    pub mb: usize,
}

/// Traffic class.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum MsgClass {
    Activation,
    Gradient,
    /// Ring all-reduce fragment; `mb` carries the ring step, `stage` the
    /// model stage being reduced.
    Collective,
    /// Control/loss reporting to the leader.
    Control,
}

/// One device's mailbox. Per-tag slots are FIFO queues: duplicate tags —
/// e.g. the same (pipe, stage, mb) re-sent on a later iteration — pair
/// with receives in send order instead of last-in-first-out.
#[derive(Debug, Default)]
struct Mailbox {
    slots: Mutex<HashMap<Tag, VecDeque<Vec<f32>>>>,
    bell: Condvar,
}

/// The full-cluster fabric: `D` mailboxes. Cloneable handle; clones share
/// the mailboxes, the poison flag, and the receive timeout.
#[derive(Debug, Clone)]
pub struct Fabric {
    boxes: Arc<Vec<Mailbox>>,
    /// Device id of the worker that poisoned the fabric;
    /// `usize::MAX` while healthy. First poisoner wins.
    poisoned: Arc<AtomicUsize>,
    /// How long a `recv` waits before reporting a deadlock.
    timeout: Duration,
}

/// Sentinel for the healthy (un-poisoned) fabric.
const HEALTHY: usize = usize::MAX;

/// Default receive timeout — converts schedule deadlocks into errors
/// instead of hangs (a schedule bug or a died peer would otherwise freeze
/// the run). Tests that want to fail fast build the fabric with
/// [`Fabric::with_timeout`].
pub const RECV_TIMEOUT: Duration = Duration::from_secs(30);

#[derive(Debug)]
pub enum CommError {
    /// Recv waited past the fabric's timeout (deadlock or dead peer);
    /// carries the waiting device, the tag it was blocked on, and how
    /// long it actually waited.
    Timeout { dev: usize, tag: Tag, elapsed: Duration },
    /// The fabric was poisoned (a worker died) while device `dev` was
    /// blocked waiting for `tag`; `by` names the dead worker.
    Poisoned { dev: usize, tag: Tag, by: usize },
    /// Device id outside the fabric.
    BadDevice(usize),
}

impl std::fmt::Display for CommError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CommError::Timeout { dev, tag, elapsed } => {
                write!(
                    f,
                    "recv timeout on device {dev} for tag {tag:?} after {:.3}s \
                     (deadlock or dead peer)",
                    elapsed.as_secs_f64()
                )
            }
            CommError::Poisoned { dev, tag, by } => {
                write!(
                    f,
                    "recv on device {dev} for tag {tag:?} aborted: \
                     fabric poisoned by worker {by} (peer died)"
                )
            }
            CommError::BadDevice(dev) => write!(f, "device id {dev} out of range"),
        }
    }
}

impl std::error::Error for CommError {}

impl Fabric {
    pub fn new(n_devices: usize) -> Self {
        Fabric::with_timeout(n_devices, RECV_TIMEOUT)
    }

    /// Fabric whose `recv` reports a deadlock after `timeout` instead of
    /// the default [`RECV_TIMEOUT`] — e2e tests use a few seconds so a
    /// schedule deadlock fails the suite fast.
    pub fn with_timeout(n_devices: usize, timeout: Duration) -> Self {
        Fabric {
            boxes: Arc::new((0..n_devices).map(|_| Mailbox::default()).collect()),
            poisoned: Arc::new(AtomicUsize::new(HEALTHY)),
            timeout,
        }
    }

    /// Mark the fabric poisoned on behalf of a dead worker `by` and wake
    /// every blocked receiver; they return [`CommError::Poisoned`]
    /// promptly instead of burning their full receive timeout. Idempotent
    /// — the first poisoner wins, later calls keep its identity.
    pub fn poison(&self, by: usize) {
        let _ = self.poisoned.compare_exchange(HEALTHY, by, Ordering::SeqCst, Ordering::SeqCst);
        // Ring every bell *under its mailbox lock*: a receiver that
        // checked the flag and is about to wait holds the lock until it
        // parks, so it cannot miss this notification.
        for mbox in self.boxes.iter() {
            let _guard = mbox.slots.lock().unwrap();
            mbox.bell.notify_all();
        }
    }

    /// Who poisoned the fabric, if anyone.
    pub fn poisoned_by(&self) -> Option<usize> {
        match self.poisoned.load(Ordering::SeqCst) {
            HEALTHY => None,
            by => Some(by),
        }
    }

    pub fn n_devices(&self) -> usize {
        self.boxes.len()
    }

    /// Deliver `payload` to device `to` under `tag`. Never blocks.
    pub fn send(&self, to: usize, tag: Tag, payload: Vec<f32>) -> Result<(), CommError> {
        let mbox = self.boxes.get(to).ok_or(CommError::BadDevice(to))?;
        let mut slots = mbox.slots.lock().unwrap();
        slots.entry(tag).or_default().push_back(payload);
        mbox.bell.notify_all();
        Ok(())
    }

    /// Block until a message under `tag` is available at device `dev`;
    /// removes and returns it (FIFO among same-tag messages). Delivered
    /// messages drain even on a poisoned fabric; only a receiver that
    /// would have to *wait* observes [`CommError::Poisoned`].
    pub fn recv(&self, dev: usize, tag: Tag) -> Result<Vec<f32>, CommError> {
        let mbox = self.boxes.get(dev).ok_or(CommError::BadDevice(dev))?;
        let start = Instant::now();
        let mut slots = mbox.slots.lock().unwrap();
        loop {
            if let Some(q) = slots.get_mut(&tag) {
                if let Some(payload) = q.pop_front() {
                    if q.is_empty() {
                        slots.remove(&tag);
                    }
                    return Ok(payload);
                }
            }
            if let Some(by) = self.poisoned_by() {
                return Err(CommError::Poisoned { dev, tag, by });
            }
            let elapsed = start.elapsed();
            let Some(remaining) = self.timeout.checked_sub(elapsed) else {
                return Err(CommError::Timeout { dev, tag, elapsed });
            };
            let (guard, timeout) = mbox.bell.wait_timeout(slots, remaining).unwrap();
            slots = guard;
            if timeout.timed_out() {
                return Err(CommError::Timeout { dev, tag, elapsed: start.elapsed() });
            }
        }
    }

    /// Non-blocking receive (FIFO among same-tag messages).
    pub fn try_recv(&self, dev: usize, tag: Tag) -> Result<Option<Vec<f32>>, CommError> {
        let mbox = self.boxes.get(dev).ok_or(CommError::BadDevice(dev))?;
        let mut slots = mbox.slots.lock().unwrap();
        Ok(slots.get_mut(&tag).and_then(|q| q.pop_front()))
    }

    /// Number of undelivered messages at a device (diagnostics).
    pub fn backlog(&self, dev: usize) -> usize {
        self.boxes[dev].slots.lock().unwrap().values().map(|q| q.len()).sum()
    }
}

/// Tag constructors used across the runtime.
impl Tag {
    pub fn act(from: usize, pipe: usize, stage: usize, mb: usize) -> Tag {
        Tag { from, class: MsgClass::Activation, pipe, stage, mb }
    }
    pub fn grad(from: usize, pipe: usize, stage: usize, mb: usize) -> Tag {
        Tag { from, class: MsgClass::Gradient, pipe, stage, mb }
    }
    pub fn coll(from: usize, stage: usize, step: usize) -> Tag {
        Tag { from, class: MsgClass::Collective, pipe: 0, stage, mb: step }
    }
    pub fn ctrl(from: usize, seq: usize) -> Tag {
        Tag { from, class: MsgClass::Control, pipe: 0, stage: 0, mb: seq }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;

    #[test]
    fn send_then_recv() {
        let f = Fabric::new(2);
        f.send(1, Tag::act(0, 0, 0, 0), vec![1.0, 2.0]).unwrap();
        let v = f.recv(1, Tag::act(0, 0, 0, 0)).unwrap();
        assert_eq!(v, vec![1.0, 2.0]);
    }

    #[test]
    fn out_of_order_by_tag() {
        // Receive mb=1 before mb=0 even though 0 was sent first.
        let f = Fabric::new(2);
        f.send(1, Tag::act(0, 0, 0, 0), vec![0.0]).unwrap();
        f.send(1, Tag::act(0, 0, 0, 1), vec![1.0]).unwrap();
        assert_eq!(f.recv(1, Tag::act(0, 0, 0, 1)).unwrap(), vec![1.0]);
        assert_eq!(f.recv(1, Tag::act(0, 0, 0, 0)).unwrap(), vec![0.0]);
    }

    #[test]
    fn same_tag_messages_deliver_fifo() {
        // Regression: the slot queues used to be a Vec popped from the
        // back, so two payloads under one tag came out LIFO — the opposite
        // of the simulator's FIFO pairing of duplicate tags.
        let f = Fabric::new(2);
        let tag = Tag::act(0, 0, 0, 0);
        f.send(1, tag, vec![1.0]).unwrap();
        f.send(1, tag, vec![2.0]).unwrap();
        assert_eq!(f.recv(1, tag).unwrap(), vec![1.0], "first in, first out");
        assert_eq!(f.recv(1, tag).unwrap(), vec![2.0]);
        // Same order through the non-blocking path.
        f.send(1, tag, vec![3.0]).unwrap();
        f.send(1, tag, vec![4.0]).unwrap();
        assert_eq!(f.try_recv(1, tag).unwrap().unwrap(), vec![3.0]);
        assert_eq!(f.try_recv(1, tag).unwrap().unwrap(), vec![4.0]);
    }

    #[test]
    fn custom_timeout_fails_fast() {
        let f = Fabric::with_timeout(1, Duration::from_millis(30));
        let t0 = std::time::Instant::now();
        let e = f.recv(0, Tag::act(0, 0, 0, 0)).unwrap_err();
        assert!(matches!(e, CommError::Timeout { dev: 0, .. }));
        assert!(
            t0.elapsed() < Duration::from_secs(5),
            "timeout did not honour the configured duration"
        );
    }

    #[test]
    fn poison_wakes_blocked_recv_fast() {
        // A blocked receiver on a fabric with a long timeout must fail
        // well under that timeout once a peer poisons it.
        let f = Fabric::with_timeout(2, Duration::from_secs(30));
        let f2 = f.clone();
        let h = thread::spawn(move || {
            let t0 = Instant::now();
            let e = f2.recv(0, Tag::act(1, 0, 0, 0)).unwrap_err();
            (e, t0.elapsed())
        });
        thread::sleep(Duration::from_millis(20));
        f.poison(1);
        let (e, waited) = h.join().unwrap();
        assert!(
            matches!(e, CommError::Poisoned { dev: 0, by: 1, .. }),
            "expected Poisoned, got {e}"
        );
        assert!(waited < Duration::from_secs(5), "poison took {waited:?} to propagate");
    }

    #[test]
    fn poison_first_wins_and_delivered_messages_drain() {
        let f = Fabric::new(2);
        let tag = Tag::act(0, 0, 0, 0);
        f.send(1, tag, vec![5.0]).unwrap();
        f.poison(0);
        f.poison(1); // later poisoner does not overwrite
        assert_eq!(f.poisoned_by(), Some(0));
        // Already-delivered data still drains...
        assert_eq!(f.recv(1, tag).unwrap(), vec![5.0]);
        // ...but a recv that would wait fails with the first poisoner.
        let e = f.recv(1, tag).unwrap_err();
        assert!(matches!(e, CommError::Poisoned { dev: 1, by: 0, .. }));
    }

    #[test]
    fn timeout_error_carries_context() {
        let f = Fabric::with_timeout(1, Duration::from_millis(30));
        let tag = Tag::grad(0, 1, 2, 3);
        match f.recv(0, tag).unwrap_err() {
            CommError::Timeout { dev, tag: t, elapsed } => {
                assert_eq!(dev, 0);
                assert_eq!(t, tag);
                assert!(elapsed >= Duration::from_millis(30));
            }
            other => panic!("expected Timeout, got {other}"),
        }
    }

    #[test]
    fn classes_do_not_collide() {
        let f = Fabric::new(2);
        f.send(1, Tag::act(0, 0, 3, 5), vec![1.0]).unwrap();
        f.send(1, Tag::grad(0, 0, 3, 5), vec![2.0]).unwrap();
        assert_eq!(f.recv(1, Tag::grad(0, 0, 3, 5)).unwrap(), vec![2.0]);
        assert_eq!(f.recv(1, Tag::act(0, 0, 3, 5)).unwrap(), vec![1.0]);
    }

    #[test]
    fn blocking_recv_wakes_on_send() {
        let f = Fabric::new(2);
        let f2 = f.clone();
        let h = thread::spawn(move || f2.recv(0, Tag::grad(1, 1, 2, 3)).unwrap());
        thread::sleep(Duration::from_millis(20));
        f.send(0, Tag::grad(1, 1, 2, 3), vec![7.0]).unwrap();
        assert_eq!(h.join().unwrap(), vec![7.0]);
    }

    #[test]
    fn try_recv_nonblocking() {
        let f = Fabric::new(1);
        assert!(f.try_recv(0, Tag::ctrl(0, 0)).unwrap().is_none());
        f.send(0, Tag::ctrl(0, 0), vec![9.0]).unwrap();
        assert_eq!(f.try_recv(0, Tag::ctrl(0, 0)).unwrap().unwrap(), vec![9.0]);
    }

    #[test]
    fn bad_device_rejected() {
        let f = Fabric::new(1);
        assert!(matches!(f.send(3, Tag::ctrl(0, 0), vec![]), Err(CommError::BadDevice(3))));
    }

    #[test]
    fn backlog_counts() {
        let f = Fabric::new(1);
        f.send(0, Tag::act(0, 0, 0, 0), vec![1.0]).unwrap();
        f.send(0, Tag::act(0, 0, 0, 1), vec![1.0]).unwrap();
        assert_eq!(f.backlog(0), 2);
    }

    #[test]
    fn many_threads_stress() {
        let f = Fabric::new(4);
        let mut handles = Vec::new();
        for dev in 0..4usize {
            let f = f.clone();
            handles.push(thread::spawn(move || {
                // Each device sends 100 messages to every other device and
                // receives 100 from each; tags by (from, mb).
                for peer in 0..4 {
                    if peer == dev {
                        continue;
                    }
                    for mb in 0..100 {
                        f.send(peer, Tag::act(dev, 0, 0, mb), vec![dev as f32, mb as f32])
                            .unwrap();
                    }
                }
                for peer in 0..4 {
                    if peer == dev {
                        continue;
                    }
                    // Receive in reverse order to exercise out-of-order.
                    for mb in (0..100).rev() {
                        let v = f.recv(dev, Tag::act(peer, 0, 0, mb)).unwrap();
                        assert_eq!(v, vec![peer as f32, mb as f32]);
                    }
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
    }
}
