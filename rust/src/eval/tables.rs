//! Table regeneration: the paper's analytic comparisons (Tables 2, 6), the
//! grid search (Table 4), the ablation (Table 5), the D sweep (Table 7),
//! and the appendix extension comparing the zero-bubble split-backward
//! family against the BitPipe portfolio (Table B).

use super::EvalOutput;
use crate::config::{ClusterConfig, ParallelConfig, RecoveryModel, BERT_64, GPT_96};
use crate::schedule::{self, analysis, Costs, ScheduleConfig, ScheduleKind, SyncPolicy};
use crate::sim::{self, GridSpace, SimConfig};
use crate::util::Table;
use anyhow::Result;
use std::fmt::Write as _;

/// Table 2: bubble ratio + memory, closed form vs measured.
pub fn table2() -> Result<EvalOutput> {
    let costs = Costs::default();
    let mut body = String::new();
    for (d, n) in [(8usize, 8usize), (8, 16)] {
        let mut t = Table::new(vec![
            "approach",
            "bubble (formula)",
            "bubble (measured)",
            "weights /M0",
            "act lo..hi (formula)",
            "act lo..hi (measured)",
        ]);
        for kind in [
            ScheduleKind::GPipe,
            ScheduleKind::Dapple,
            ScheduleKind::Interleaved,
            ScheduleKind::Chimera,
            ScheduleKind::BitPipe,
        ] {
            let s = schedule::build(&ScheduleConfig::new(kind, d, n))?;
            let r = analysis::report(&s, &costs)?;
            t.row(vec![
                kind.name().to_string(),
                format!("{:.3}", r.bubble_ratio_formula),
                format!("{:.3}", r.bubble_ratio_measured),
                format!("{:.0}", r.weights_mem_measured_max),
                format!("{:.1}..{:.1}", r.act_mem_formula.0, r.act_mem_formula.1),
                format!("{:.1}..{:.1}", r.act_mem_measured.0, r.act_mem_measured.1),
            ]);
        }
        let _ = writeln!(body, "D={d}, N={n}:\n{}", t.render());
    }
    body.push_str(
        "BitPipe has the lowest bubble ratio; bidirectional approaches hold 2x weights.\n\
         At N=D the activation ceilings match Table 2's closed forms; for N>D the fused\n\
         schedules trade extra stash (<= 2D x M_a, the family's scaling ceiling) for the\n\
         Appendix-B bubble level — see EXPERIMENTS.md §Deviations.\n",
    );
    Ok(EvalOutput { id: "table2", title: "Comparison of pipeline approaches", body })
}

/// Table 6 (appendix): communication overhead, closed form vs measured.
pub fn table6() -> Result<EvalOutput> {
    let mut body = String::new();
    for (d, n) in [(8usize, 8usize), (4, 8)] {
        let mut t = Table::new(vec![
            "approach",
            "P2P msgs (formula)",
            "P2P msgs (measured)",
            "local copies",
            "allreduce (M_grad)",
        ]);
        for kind in [
            ScheduleKind::Dapple,
            ScheduleKind::Interleaved,
            ScheduleKind::Chimera,
            ScheduleKind::BitPipe,
        ] {
            let s = schedule::build(&ScheduleConfig::new(kind, d, n))?;
            let f = analysis::comm_volume_formula(kind, d, n, kind.default_v());
            let m = analysis::comm_volume_measured(&s);
            t.row(vec![
                kind.name().to_string(),
                f.p2p_messages.to_string(),
                m.p2p_messages.to_string(),
                m.local_copies.to_string(),
                format!("{:.0}", m.allreduce_grads),
            ]);
        }
        let _ = writeln!(body, "D={d}, N={n}:\n{}", t.render());
    }
    body.push_str(
        "Interleaving doubles the P2P message count (2vD-1 boundaries); the V-shape claws\n\
         back 2N(v-1) transfers as local copies; bidirectional approaches add one gradient\n\
         allreduce (priced on NVLink under the Fig 6 mapping).\n",
    );
    Ok(EvalOutput { id: "table6", title: "Communication overhead", body })
}

/// Table 4: grid search over (W, D, B) per approach and GPU count.
pub fn table4() -> Result<EvalOutput> {
    let mut body = String::new();
    // One compile-once/re-cost-many cache across all 24 sweeps: the same
    // (kind, D, N) structures recur across GPU counts and models, so later
    // sweeps skip both schedule generation and DAG lowering.
    let mut cache = sim::DagCache::new();
    const GPUS: [usize; 3] = [8, 16, 32];
    const KINDS: [ScheduleKind; 4] = [
        ScheduleKind::Dapple,
        ScheduleKind::Interleaved,
        ScheduleKind::MixPipe,
        ScheduleKind::BitPipe,
    ];
    for (model, space, bhat_per8) in [
        (&BERT_64, GridSpace::bert64(), 32usize),
        (&GPT_96, GridSpace::gpt96(), 8usize),
    ] {
        // One batched call per (model, kind) prices the whole GPU-count
        // axis: the three sweeps share structures, so their grid points
        // re-cost in lanes of one DAG walk (`grid_search_batched`) —
        // bit-identical to the per-sweep scalar calls this replaces.
        let sweeps: Vec<(usize, usize)> = GPUS.iter().map(|&g| (g, bhat_per8 * g / 8)).collect();
        let mut best: Vec<Vec<Option<sim::GridPoint>>> = Vec::with_capacity(KINDS.len());
        for kind in KINDS {
            let per_sweep = sim::grid_search_batched(kind, model, &space, &sweeps, &mut cache)?;
            best.push(per_sweep.into_iter().map(|points| points.into_iter().next()).collect());
        }
        let mut t = Table::new(vec![
            "GPUs", "approach", "W", "D", "B", "N", "throughput",
        ]);
        for (gi, &gpus) in GPUS.iter().enumerate() {
            for (ki, kind) in KINDS.iter().enumerate() {
                if let Some(best) = &best[ki][gi] {
                    t.row(vec![
                        gpus.to_string(),
                        kind.name().to_string(),
                        best.parallel.w.to_string(),
                        best.parallel.d.to_string(),
                        best.parallel.b.to_string(),
                        best.parallel.n.to_string(),
                        format!("{:.2}", best.result.throughput),
                    ]);
                } else {
                    t.row(vec![
                        gpus.to_string(),
                        kind.name().to_string(),
                        "-".into(),
                        "-".into(),
                        "-".into(),
                        "-".into(),
                        "OOM".into(),
                    ]);
                }
            }
        }
        let _ = writeln!(body, "{} (B-hat = {}/8 GPUs):\n{}", model.name, bhat_per8, t.render());
    }
    body.push_str("Paper Table 4: grid-searched best configurations per approach.\n");
    Ok(EvalOutput { id: "table4", title: "Parameter search space and final choices", body })
}

/// Table 5: ablation — BitPipe vs w/o V (looping placement) vs w/o E
/// (lazy sync), BERT-64 on one NVLink node.
pub fn table5() -> Result<EvalOutput> {
    let mut t = Table::new(vec![
        "GPUs", "D", "B-hat", "w/o V", "w/o E", "BitPipe", "BitPipe steady", "contended",
    ]);
    for (gpus, d, bhats) in
        [(4usize, 4usize, [16usize, 32, 64]), (8, 8, [32, 64, 128])]
    {
        for bhat in bhats {
            let b = 4usize;
            let n = (bhat / b).max(d) / d * d;
            let mut cells = vec![gpus.to_string(), d.to_string(), bhat.to_string()];
            let cluster = ClusterConfig::single_node(gpus);
            for variant in ["no-v", "no-e", "full"] {
                let (kind, sync) = match variant {
                    "no-v" => (ScheduleKind::BitPipeNoV, SyncPolicy::Eager),
                    "no-e" => (ScheduleKind::BitPipe, SyncPolicy::Lazy),
                    _ => (ScheduleKind::BitPipe, SyncPolicy::Eager),
                };
                let mut parallel = ParallelConfig::new(kind, 1, d, b, n);
                parallel.sync = sync;
                let r = sim::simulate(&SimConfig::new(BERT_64, parallel, cluster))?;
                cells.push(format!("{:.2}", r.throughput));
            }
            // Steady-state throughput over 3 simulated iterations (1
            // warmup) — the measurement discipline the paper's testbed
            // numbers use (record after warm-up).
            let parallel = ParallelConfig::new(ScheduleKind::BitPipe, 1, d, b, n);
            let cfg = SimConfig::new(BERT_64, parallel, cluster);
            let mr = sim::simulate_iters(&cfg, 3, 1)?;
            cells.push(format!("{:.2}", mr.steady_throughput));
            // Same steady measurement with link contention on: concurrent
            // transfers sharing an NVLink path split its bandwidth.
            let mc = sim::simulate_iters(&cfg.with_contention(true), 3, 1)?;
            cells.push(format!("{:.2}", mc.steady_throughput));
            t.row(cells);
        }
    }
    let body = format!(
        "{}\nPaper Table 5 (throughput, samples/s, single NVLink node): full BitPipe wins;\n\
         both components contribute, with eager sync slightly ahead of the V-shape. The\n\
         steady column re-measures full BitPipe over 3 back-to-back iterations (1 warmup)\n\
         with the multi-iteration simulator; the contended column repeats it under the\n\
         full flow-level model (--contention), where the eagerly launched all-reduce\n\
         rings ride the same NVLink paths as the P2P traffic they overlap. On a fully\n\
         NVLinked node this costs little — the real penalty lives on the inter-node\n\
         NICs, where rings and activations funnel through one egress/ingress NIC per\n\
         node (fig6).\n",
        t.render()
    );
    Ok(EvalOutput { id: "table5", title: "Ablation study (w/o V, w/o E)", body })
}

/// Table 7 (appendix): performance tuning — D sweep on 32 GPUs.
pub fn table7() -> Result<EvalOutput> {
    let mut body = String::new();
    for (model, b, bhat, ds) in [
        (&BERT_64, 4usize, 128usize, vec![4usize, 8, 16]),
        (&GPT_96, 1, 32, vec![8usize, 16]),
    ] {
        let mut t = Table::new(vec!["D", "dapple", "1f1b-int", "mixpipe", "bitpipe"]);
        for d in ds {
            let w = 32 / d;
            let mut cells = vec![d.to_string()];
            for kind in [
                ScheduleKind::Dapple,
                ScheduleKind::Interleaved,
                ScheduleKind::MixPipe,
                ScheduleKind::BitPipe,
            ] {
                let n = (bhat / (b * w)).max(d) / d * d;
                let parallel = ParallelConfig::new(kind, w, d, b, n);
                let cluster = ClusterConfig::paper_testbed(32);
                match sim::simulate(&SimConfig::new(*model, parallel, cluster)) {
                    Ok(r) if r.fits(&cluster) => cells.push(format!("{:.2}", r.throughput)),
                    Ok(_) => cells.push("OOM".into()),
                    Err(_) => cells.push("-".into()),
                }
            }
            t.row(cells);
        }
        let _ = writeln!(body, "{} (32 GPUs, B-hat={bhat}):\n{}", model.name, t.render());
    }
    body.push_str(
        "Paper Table 7: D=8 is the best compromise between bubbles and communication.\n",
    );
    Ok(EvalOutput { id: "table7", title: "Performance tuning: pipeline size D", body })
}

/// Degradation sweep (extension, not in the paper): how much of each
/// schedule family's throughput survives a straggler. Device 0's compute
/// is slowed by a multiplier ([`ClusterConfig::with_straggler`]) and each
/// cell reports throughput retained relative to the healthy cluster —
/// the question PAPERS.md's heterogeneity planners ask of Tables 4/7.
pub fn degradation() -> Result<EvalOutput> {
    const MULTS: [f64; 5] = [1.0, 1.1, 1.2, 1.5, 2.0];
    let mut body = String::new();
    for d in [4usize, 8] {
        let n = 2 * d;
        let mut t = Table::new(vec![
            "approach", "healthy thr", "x1.1", "x1.2", "x1.5", "x2.0",
        ]);
        for kind in [
            ScheduleKind::Dapple,
            ScheduleKind::Interleaved,
            ScheduleKind::MixPipe,
            ScheduleKind::BitPipe,
        ] {
            let parallel = ParallelConfig::new(kind, 1, d, 4, n);
            let mut cells = vec![kind.name().to_string()];
            let mut healthy = f64::NAN;
            for (i, &m) in MULTS.iter().enumerate() {
                let cluster = ClusterConfig::paper_testbed(d).with_straggler(0, m)?;
                let r = sim::simulate(&SimConfig::new(BERT_64, parallel, cluster))?;
                if i == 0 {
                    healthy = r.throughput;
                    cells.push(format!("{healthy:.2}"));
                } else {
                    cells.push(format!("{:.1}%", 100.0 * r.throughput / healthy));
                }
            }
            t.row(cells);
        }
        let _ = writeln!(
            body,
            "BERT-64, D={d}, N={n}, B=4, W=1 (straggler on device 0):\n{}",
            t.render()
        );
    }
    body.push_str(
        "Throughput retained vs a 1.0x baseline as device 0 degrades. Pipelines step in\n\
         lock-step, so one straggler gates every family roughly by its compute share;\n\
         schedules with more bubble absorb slightly more of the slowdown.\n",
    );
    Ok(EvalOutput {
        id: "degradation",
        title: "Degradation sweep: throughput retained under a straggler",
        body,
    })
}

/// Resilience sweep (extension, not in the paper): how much throughput
/// each schedule family retains under seeded, time-varying fault traces
/// ([`crate::config::FaultPlan::random`]) of rising intensity — degraded
/// IB links, slowed devices, mid-iteration stalls — replayed by the event
/// engine's fault arm. All families at one D share the same seeded trace,
/// so columns compare like with like. The last column prices
/// checkpoint-restart ([`RecoveryModel`]) on the worst trace: its stalls
/// read as device failures over a ten-iteration run, each rolling progress
/// back to the last checkpoint boundary.
pub fn resilience() -> Result<EvalOutput> {
    const INTENSITIES: [f64; 5] = [0.0, 0.25, 0.5, 0.75, 1.0];
    const SEED: u64 = 42;
    const HORIZON: f64 = 2.0;
    let recovery = RecoveryModel::default();
    let mut body = String::new();
    for d in [4usize, 8] {
        let n = 2 * d;
        let layouts: Vec<ParallelConfig> = [
            ScheduleKind::Dapple,
            ScheduleKind::Interleaved,
            ScheduleKind::MixPipe,
            ScheduleKind::BitPipe,
            ScheduleKind::ZeroBubble,
        ]
        .into_iter()
        .map(|kind| ParallelConfig::new(kind, 1, d, 4, n))
        .collect();
        let cluster = ClusterConfig::paper_testbed(d);
        let points =
            sim::resilience_sweep(&BERT_64, &cluster, &layouts, &INTENSITIES, SEED, HORIZON)?;
        let mut t = Table::new(vec![
            "approach", "healthy thr", "i=0.25", "i=0.50", "i=0.75", "i=1.00", "w/ recovery",
        ]);
        for (li, layout) in layouts.iter().enumerate() {
            let chunk = &points[li * INTENSITIES.len()..(li + 1) * INTENSITIES.len()];
            let healthy = chunk[0].result.throughput;
            let mut cells = vec![layout.kind.name().to_string(), format!("{healthy:.2}")];
            for p in &chunk[1..] {
                cells.push(format!("{:.1}%", 100.0 * p.result.throughput / healthy));
            }
            let worst = chunk.last().expect("at least one intensity");
            let work = 10.0 * worst.result.iter_time;
            let wall = recovery.wall_clock(work, &worst.plan.stall_times());
            let thr = 10.0 * layout.minibatch_size() as f64 / wall;
            cells.push(format!("{:.1}%", 100.0 * thr / healthy));
            t.row(cells);
        }
        let _ = writeln!(
            body,
            "BERT-64, D={d}, N={n}, B=4, W=1 (seeded trace {SEED}, horizon {HORIZON:.1}s):\n{}",
            t.render()
        );
    }
    body.push_str(
        "Throughput retained vs the healthy run as the seeded fault trace intensifies.\n\
         Families with more bubble (DAPPLE) absorb early-window faults for free, while\n\
         BitPipe's doubled concurrency and zero-bubble's deferred W expose more of the\n\
         iteration to a mid-pipeline stall; the recovery column adds the checkpoint tax\n\
         and rollback-reload cost when the trace's stalls are read as failures.\n",
    );
    Ok(EvalOutput {
        id: "resilience",
        title: "Resilience sweep: throughput retained under fault traces",
        body,
    })
}

/// Table B (appendix extension, not in the paper): the zero-bubble split-
/// backward family against every BitPipe variant and the 1F1B baseline —
/// simulated throughput plus measured bubble ratio and peak stash, so the
/// bubble/memory trade of deferring W is visible next to bidirectionality.
pub fn tableb() -> Result<EvalOutput> {
    let costs = Costs::default();
    let mut body = String::new();
    for (d, n) in [(4usize, 8usize), (4, 16), (8, 16), (8, 32)] {
        let mut t = Table::new(vec![
            "approach",
            "throughput",
            "bubble (measured)",
            "peak stash (chunks)",
        ]);
        for kind in [
            ScheduleKind::Dapple,
            ScheduleKind::ZeroBubble,
            ScheduleKind::Chimera,
            ScheduleKind::MixPipe,
            ScheduleKind::BitPipeNoV,
            ScheduleKind::BitPipe,
        ] {
            let s = schedule::build(&ScheduleConfig::new(kind, d, n))?;
            let r = analysis::report(&s, &costs)?;
            let stash = analysis::stash_high_water_chunks(&s);
            let peak = stash.iter().copied().max().unwrap_or(0);
            let parallel = ParallelConfig::new(kind, 1, d, 4, n);
            let cluster = ClusterConfig::single_node(d);
            let thr = match sim::simulate(&SimConfig::new(BERT_64, parallel, cluster)) {
                Ok(res) => format!("{:.2}", res.throughput),
                Err(_) => "-".into(),
            };
            t.row(vec![
                kind.name().to_string(),
                thr,
                format!("{:.3}", r.bubble_ratio_measured),
                peak.to_string(),
            ]);
        }
        let _ = writeln!(body, "BERT-64, D={d}, N={n} (single NVLink node):\n{}", t.render());
    }
    body.push_str(
        "Zero-bubble fills the 1F1B ramp-down with deferred weight grads: lower bubble\n\
         than DAPPLE at the same wire traffic, paid for with up to D+1 chunks of stash\n\
         on device 0 (the Bi pins). BitPipe attacks the same bubble bidirectionally.\n",
    );
    Ok(EvalOutput { id: "tableb", title: "Zero-bubble vs the BitPipe portfolio", body })
}
