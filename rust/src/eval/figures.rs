//! Figure regeneration: schedule diagrams (Figs 1–3, 13), communication
//! studies (Figs 4–7), memory distributions (Fig 8), and throughput plots
//! (Figs 9–11) as text series.

use super::EvalOutput;
use crate::config::{
    ClusterConfig, MappingPolicy, ModelConfig, ParallelConfig, BERT_64, GPT_96,
};
use crate::schedule::{
    self, analysis, comm_pass, timeline, Costs, ScheduleConfig, ScheduleKind, SyncPolicy,
};
use crate::sim::{self, simulate_schedule, CostModel, SimConfig};
use crate::util::Table;
use anyhow::Result;
use std::fmt::Write as _;

fn render_kind(kind: ScheduleKind, d: usize, n: usize) -> Result<String> {
    let s = schedule::build(&ScheduleConfig::new(kind, d, n))?;
    let txt = timeline::render(&s, &Costs::default(), &timeline::RenderOpts::default())?;
    let costs = Costs::default();
    let t = schedule::retime(&s.compute_order, &s.placement, &costs)
        .map_err(|e| anyhow::anyhow!("{e}"))?;
    Ok(format!(
        "{kind} (D={d}, N={n}; makespan {} ticks, bubble ratio {:.3}):\n{txt}\n",
        t.makespan,
        t.bubble_ratio()
    ))
}

/// Fig 1: classic synchronous schedules — GPipe vs 1F1B, D=4, N=8.
pub fn fig1() -> Result<EvalOutput> {
    let mut body = String::new();
    for kind in [ScheduleKind::GPipe, ScheduleKind::Dapple] {
        body.push_str(&render_kind(kind, 4, 8)?);
    }
    body.push_str(
        "Same bubble overhead; 1F1B caps the in-flight stash at D (imbalanced across devices).\n",
    );
    Ok(EvalOutput { id: "fig1", title: "Classic synchronous pipeline schedules", body })
}

/// Fig 2: the approaches considered — DAPPLE, 1F1B-Int, Chimera, BitPipe
/// at D=4, N=4.
pub fn fig2() -> Result<EvalOutput> {
    let mut body = String::new();
    for kind in [
        ScheduleKind::Dapple,
        ScheduleKind::Interleaved,
        ScheduleKind::Chimera,
        ScheduleKind::BitPipe,
    ] {
        body.push_str(&render_kind(kind, 4, 4)?);
    }
    body.push_str("Digits = down pipe, letters/symbols = up pipe / second chunk round.\n");
    Ok(EvalOutput { id: "fig2", title: "Synchronous approaches considered (D=4, N=4)", body })
}

/// Fig 3: BitPipe's fused bidirectional V-shaped pipelines, D=4, N=4.
pub fn fig3() -> Result<EvalOutput> {
    let s = schedule::build(&ScheduleConfig::new(ScheduleKind::BitPipe, 4, 4))?;
    let mut body = render_kind(ScheduleKind::BitPipe, 4, 4)?;
    let placement = &s.placement;
    body.push_str("Chunk placement (down pipe): ");
    for st in 0..placement.n_stages() {
        let _ = write!(body, "s{}→P{} ", st + 1, placement.device(0, st) + 1);
    }
    body.push_str("\nChunk placement (up pipe):   ");
    for st in 0..placement.n_stages() {
        let _ = write!(body, "s{}→P{} ", st + 1, placement.device(1, st) + 1);
    }
    body.push('\n');
    Ok(EvalOutput { id: "fig3", title: "BitPipe bidirectional interleaved schedule", body })
}

/// Fig 12 (Appendix A): generalizing to more than 2D stages per pipeline
/// (v > 2) — smaller bubbles at the cost of proportionally more P2P.
pub fn fig12() -> Result<EvalOutput> {
    let costs = Costs::default();
    let mut t = Table::new(vec![
        "v", "D", "N", "bubble measured", "bubble formula(v=2)", "P2P msgs", "local copies",
    ]);
    for (d, n) in [(4usize, 4usize), (4, 8)] {
        for v in [2usize, 3, 4] {
            let cfg = ScheduleConfig::new(ScheduleKind::BitPipe, d, n).with_v(v);
            let s = schedule::build(&cfg)?;
            let r = analysis::report(&s, &costs)?;
            t.row(vec![
                v.to_string(),
                d.to_string(),
                n.to_string(),
                format!("{:.3}", r.bubble_ratio_measured),
                format!("{:.3}", r.bubble_ratio_formula),
                r.comm_measured.p2p_messages.to_string(),
                r.comm_measured.local_copies.to_string(),
            ]);
        }
    }
    let body = format!(
        "{}\nAppendix A: each extra chunk per device shrinks the per-op grain (bubble size\n\
         drops ~1/v) while P2P volume grows ~v; the paper defaults to v=2 and expects\n\
         v>2 to pay off only for larger future models. The local-copy count also grows\n\
         (v-1 turn points per pipe), partially offsetting the extra traffic.\n",
        t.render()
    );
    Ok(EvalOutput {
        id: "fig12",
        title: "Generalizing to more stages per pipeline (Appendix A)",
        body,
    })
}

/// Fig 13 (appendix): all five approaches side by side, D=4, N=8.
pub fn fig13() -> Result<EvalOutput> {
    let mut body = String::new();
    for kind in ScheduleKind::PAPER_BASELINES {
        body.push_str(&render_kind(kind, 4, 8)?);
    }
    Ok(EvalOutput { id: "fig13", title: "Five synchronous approaches (D=4, N=8)", body })
}

/// Fig 4: looping vs V-shaped interleaved placement — the local-copy win.
pub fn fig4() -> Result<EvalOutput> {
    let mut t = Table::new(vec![
        "placement", "D", "N", "P2P msgs", "local copies", "geom ticks", "sim iter (ms)",
    ]);
    let costs = Costs::default();
    for (d, n) in [(2usize, 2usize), (4, 4), (4, 8)] {
        for kind in [ScheduleKind::Interleaved, ScheduleKind::VShaped] {
            let s = schedule::build(&ScheduleConfig::new(kind, d, n))?;
            let p2p: usize = comm_pass::p2p_send_counts(&s).iter().sum();
            let copies: usize = comm_pass::local_copy_counts(&s).iter().sum();
            let span = schedule::retime(&s.compute_order, &s.placement, &costs)
                .map_err(|e| anyhow::anyhow!("{e}"))?
                .makespan;
            // Priced execution on a cluster whose links make P2P expensive
            // (the regime the V-shape targets: small chunks, slow fabric).
            let p = ParallelConfig::new(kind, 1, d, 1, n);
            let mut cluster = ClusterConfig::paper_testbed(d);
            cluster.nvlink_bw = 5.0e9; // activation-bound fabric
            cluster.nvlink_lat = 1.0e-4;
            let cm = CostModel::new(&BERT_64, &p, &cluster);
            let tr = simulate_schedule(&s, &cm).map_err(|e| anyhow::anyhow!("{e}"))?;
            t.row(vec![
                kind.name().to_string(),
                d.to_string(),
                n.to_string(),
                p2p.to_string(),
                copies.to_string(),
                span.to_string(),
                format!("{:.1}", tr.makespan * 1e3),
            ]);
        }
    }
    let body = format!(
        "{}\nThe V-shape converts every turn-device hand-off into a zero-P2P local copy\n\
         (-2N(v-1) transfers; confirmed by the real runtime's counters in\n\
         rust/tests/e2e_train.rs). As a *standalone* pipe our greedy V order carries a\n\
         small geometric deficit vs looping; the placement's payoff is inside BitPipe's\n\
         fused schedule, where the turn co-location is what lets the two pipes mesh\n\
         (fig3/fig9) — consistent with the paper, which deploys the V-shape only there.\n",
        t.render()
    );
    Ok(EvalOutput { id: "fig4", title: "Looping vs V-shaped interleaved schedule", body })
}

/// Fig 5: eager vs lazy (default) gradient synchronization overlap.
pub fn fig5() -> Result<EvalOutput> {
    let mut t = Table::new(vec!["cluster", "W", "sync", "iter time (s)", "ar-blocked mean (s)"]);
    for (w, nodes, map) in [
        (1usize, "single-node", MappingPolicy::ReplicasTogether),
        (4, "multi-node/IB", MappingPolicy::PipesTogether),
    ] {
        for sync in [SyncPolicy::Eager, SyncPolicy::Lazy] {
            let s = schedule::build(&ScheduleConfig::new(ScheduleKind::BitPipe, 8, 8)
                .with_sync(sync))?;
            let p = ParallelConfig::new(ScheduleKind::BitPipe, w, 8, 4, 8);
            let mut cluster = ClusterConfig::paper_testbed(8 * w);
            cluster.mapping = map;
            let cm = CostModel::new(&BERT_64, &p, &cluster);
            let tr = simulate_schedule(&s, &cm).map_err(|e| anyhow::anyhow!("{e}"))?;
            let blocked =
                tr.devices.iter().map(|d| d.allreduce_blocked).sum::<f64>() / 8.0;
            t.row(vec![
                nodes.to_string(),
                w.to_string(),
                format!("{sync:?}"),
                format!("{:.4}", tr.makespan),
                format!("{:.4}", blocked),
            ]);
        }
    }
    let body = format!(
        "{}\nEager launches drain each stage's collective inside pipeline bubbles; the gain is\n\
         large when the collective is expensive (IB) and ~neutral on one NVLink node — the\n\
         paper's own single-node ablation (Table 5) finds ~1%.\n",
        t.render()
    );
    Ok(EvalOutput { id: "fig5", title: "Eager gradient synchronization overlap", body })
}

/// Fig 6: device mapping — replicas-together (allreduce on NVLink) vs
/// pipes-together (allreduce on IB).
pub fn fig6() -> Result<EvalOutput> {
    let mut t = Table::new(vec![
        "mapping", "model", "W", "D", "throughput", "steady", "contended", "steady cont",
        "penalty",
    ]);
    for model in [&BERT_64, &GPT_96] {
        for map in [MappingPolicy::ReplicasTogether, MappingPolicy::PipesTogether] {
            let b = if model.name == "bert-64" { 4 } else { 1 };
            let parallel = ParallelConfig::new(ScheduleKind::BitPipe, 2, 8, b, 8);
            let mut cluster = ClusterConfig::paper_testbed(16);
            cluster.mapping = map;
            let cfg = SimConfig::new(*model, parallel, cluster);
            let r = sim::simulate(&cfg)?;
            let rc = sim::simulate(&cfg.with_contention(true))?;
            // Steady state over 4 back-to-back iterations (1 warmup): the
            // measurement discipline of the paper's testbed numbers, in
            // both contention modes.
            let ms = sim::simulate_iters(&cfg, 4, 1)?;
            let mc = sim::simulate_iters(&cfg.with_contention(true), 4, 1)?;
            t.row(vec![
                format!("{map:?}"),
                model.name.to_string(),
                "2".to_string(),
                "8".to_string(),
                format!("{:.2}", r.throughput),
                format!("{:.2}", ms.steady_throughput),
                format!("{:.2}", rc.throughput),
                format!("{:.2}", mc.steady_throughput),
                format!("{:.1}%", (1.0 - rc.throughput / r.throughput) * 100.0),
            ]);
        }
    }
    let body = format!(
        "{}\nReplicasTogether keeps each stage's data-parallel replicas in one node and pushes\n\
         only the small activation messages onto Infiniband (paper Fig 6's recommended\n\
         mapping); the bidirectional twin still all-reduces with its mirror device, so the\n\
         enumerated ring paths cross nodes either way and the mapping decides how much\n\
         company they have. The contended columns re-price each mapping with the full\n\
         flow-level model (--contention): P2P transfers and all-reduce ring flows share\n\
         NVLink paths and each node's egress/ingress NIC (one NIC per direction per node,\n\
         not per peer), so mappings that funnel gradient rings and activation traffic\n\
         through the same NICs pay the larger penalty. Steady columns measure 4\n\
         back-to-back iterations (1 warmup) with the multi-iteration simulator;\n\
         iterations overlap at the boundary, so steady throughput sits at or above the\n\
         single-shot number in both modes.\n",
        t.render()
    );
    Ok(EvalOutput { id: "fig6", title: "Device mapping for bidirectional pipelines", body })
}

/// Fig 7: scaling to N > D micro-batches — software-pipelined basic units.
pub fn fig7() -> Result<EvalOutput> {
    let costs = Costs::default();
    let mut t = Table::new(vec![
        "N", "makespan", "2x basic unit", "bubble ratio", "formula", "iter1 (ms)",
        "steady (ms)",
    ]);
    let d = 4usize;
    let unit = schedule::retime(
        &schedule::build(&ScheduleConfig::new(ScheduleKind::BitPipe, d, d))?.compute_order,
        &schedule::build(&ScheduleConfig::new(ScheduleKind::BitPipe, d, d))?.placement,
        &costs,
    )
    .map_err(|e| anyhow::anyhow!("{e}"))?
    .makespan;
    for k in [1usize, 2, 4] {
        let n = k * d;
        let s = schedule::build(&ScheduleConfig::new(ScheduleKind::BitPipe, d, n))?;
        let tr = schedule::retime(&s.compute_order, &s.placement, &costs)
            .map_err(|e| anyhow::anyhow!("{e}"))?;
        let formula =
            analysis::bubble_ratio_formula(ScheduleKind::BitPipe, d, n, true);
        // Priced steady state: 4 simulated iterations, first discarded —
        // successive iterations overlap at the boundary, so the steady
        // per-iteration time sits at or below the cold first iteration.
        let sim_cfg = SimConfig::new(
            BERT_64,
            ParallelConfig::new(ScheduleKind::BitPipe, 1, d, 4, n),
            ClusterConfig::paper_testbed(d),
        );
        let mr = sim::simulate_iters(&sim_cfg, 4, 1)?;
        t.row(vec![
            n.to_string(),
            tr.makespan.to_string(),
            (unit * k as u64).to_string(),
            format!("{:.3}", tr.bubble_ratio()),
            format!("{:.3}", formula),
            format!("{:.1}", mr.iter_times[0] * 1e3),
            format!("{:.1}", mr.steady.mean * 1e3),
        ]);
    }
    let body = format!(
        "{}\nConcatenated units overlap: the makespan grows by less than one full basic unit\n\
         per extra unit (trailing bubbles absorb the next unit's warmup forwards). The\n\
         priced columns come from the multi-iteration simulator (4 iterations, 1 warmup):\n\
         back-to-back iterations overlap the same way, so steady <= iter1.\n",
        t.render()
    );
    Ok(EvalOutput { id: "fig7", title: "Scaling to more micro-batches (N > D)", body })
}

/// Fig 8: per-device memory footprint distribution.
pub fn fig8() -> Result<EvalOutput> {
    let mut body = String::new();
    // (a) 8 GPUs, pipeline-only.
    for (model, b) in [(&BERT_64, 4usize), (&GPT_96, 1usize)] {
        let mut t = Table::new(vec![
            "approach", "min GiB", "max GiB", "mean GiB", "spread GiB",
        ]);
        for kind in [
            ScheduleKind::Dapple,
            ScheduleKind::Interleaved,
            ScheduleKind::Chimera,
            ScheduleKind::MixPipe,
            ScheduleKind::BitPipe,
        ] {
            let parallel = ParallelConfig::new(kind, 1, 8, b, 8);
            let cluster = ClusterConfig::paper_testbed(8);
            let r = sim::simulate(&SimConfig::new(*model, parallel, cluster))?;
            let totals = r.memory.total_peak();
            let gib = |x: u64| x as f64 / (1u64 << 30) as f64;
            let min = totals.iter().copied().min().unwrap_or(0);
            let max = totals.iter().copied().max().unwrap_or(0);
            t.row(vec![
                kind.name().to_string(),
                format!("{:.1}", gib(min)),
                format!("{:.1}", gib(max)),
                format!("{:.1}", r.memory.mean() / (1u64 << 30) as f64),
                format!("{:.1}", gib(r.memory.spread())),
            ]);
        }
        let _ = writeln!(body, "(a) 8 GPUs pipeline-only, {} B={b}:\n{}", model.name, t.render());
    }
    // (b) 32 GPUs, best configs (W from table 4-style layout).
    let mut t = Table::new(vec!["approach", "W", "D", "B", "min GiB", "max GiB", "spread GiB"]);
    for (kind, w, d, b) in [
        (ScheduleKind::Dapple, 4usize, 8usize, 2usize),
        (ScheduleKind::Interleaved, 8, 4, 2),
        (ScheduleKind::MixPipe, 4, 8, 4),
        (ScheduleKind::BitPipe, 4, 8, 4),
    ] {
        let parallel = ParallelConfig::new(kind, w, d, b, d);
        let cluster = ClusterConfig::paper_testbed(32);
        let r = sim::simulate(&SimConfig::new(BERT_64, parallel, cluster))?;
        let totals = r.memory.total_peak();
        let gib = |x: u64| x as f64 / (1u64 << 30) as f64;
        t.row(vec![
            kind.name().to_string(),
            w.to_string(),
            d.to_string(),
            b.to_string(),
            format!("{:.1}", gib(totals.iter().copied().min().unwrap_or(0))),
            format!("{:.1}", gib(totals.iter().copied().max().unwrap_or(0))),
            format!("{:.1}", gib(r.memory.spread())),
        ]);
    }
    let _ = writeln!(body, "(b) 32 GPUs, BERT-64 best configs:\n{}", t.render());
    body.push_str(
        "BitPipe: higher mean (two weight replicas) but the narrowest, most uniform spread;\n\
         DAPPLE/1F1B-Int put the deepest stash on the first-stage device (most imbalanced).\n",
    );
    Ok(EvalOutput { id: "fig8", title: "Memory footprint distributions", body })
}

/// Shared helper: simulated throughput of one configuration.
fn throughput(
    kind: ScheduleKind,
    model: &ModelConfig,
    w: usize,
    d: usize,
    b: usize,
    n: usize,
    devices: usize,
) -> Result<f64> {
    let parallel = ParallelConfig::new(kind, w, d, b, n);
    let cluster = ClusterConfig::paper_testbed(devices);
    Ok(sim::simulate(&SimConfig::new(*model, parallel, cluster))?.throughput)
}

/// Fig 9: throughput, pipeline parallelism only, 8 GPUs.
pub fn fig9() -> Result<EvalOutput> {
    let mut body = String::new();
    for (model, b) in [(&BERT_64, 4usize), (&GPT_96, 1usize)] {
        let mut t = Table::new(vec!["B-hat", "dapple", "1f1b-int", "chimera", "bitpipe", "best/bitpipe-x"]);
        for n in [8usize, 16, 32] {
            let mut cells = vec![format!("{}", b * n)];
            let mut best_baseline: f64 = 0.0;
            let mut bit = 0.0;
            for kind in [
                ScheduleKind::Dapple,
                ScheduleKind::Interleaved,
                ScheduleKind::Chimera,
                ScheduleKind::BitPipe,
            ] {
                let thr = throughput(kind, model, 1, 8, b, n, 8)?;
                if kind == ScheduleKind::BitPipe {
                    bit = thr;
                } else {
                    best_baseline = best_baseline.max(thr);
                }
                cells.push(format!("{thr:.2}"));
            }
            cells.push(format!("{:.2}x", bit / best_baseline));
            t.row(cells);
        }
        let _ = writeln!(body, "{} (W=1, D=8, B={b}):\n{}", model.name, t.render());
    }
    body.push_str(
        "Paper Fig 9: BitPipe beats DAPPLE/1F1B-Int/Chimera by 1.27x/1.12x/1.09x (BERT) and\n\
         1.15x/1.03x/1.09x (GPT) on average; the lead narrows as B-hat grows (more P2P).\n",
    );
    Ok(EvalOutput { id: "fig9", title: "Throughput, pipeline-only, 8 GPUs", body })
}

/// Fig 10: throughput combined with data parallelism at 8/16/32 GPUs.
pub fn fig10() -> Result<EvalOutput> {
    let mut body = String::new();
    for (model, b) in [(&BERT_64, 4usize), (&GPT_96, 1usize)] {
        let mut t =
            Table::new(vec!["GPUs", "dapple", "1f1b-int", "mixpipe", "bitpipe", "bitpipe/best-x"]);
        for gpus in [8usize, 16, 32] {
            let w = gpus / 8;
            let mut cells = vec![gpus.to_string()];
            let mut best_baseline: f64 = 0.0;
            let mut bit = 0.0;
            for kind in [
                ScheduleKind::Dapple,
                ScheduleKind::Interleaved,
                ScheduleKind::MixPipe,
                ScheduleKind::BitPipe,
            ] {
                let thr = throughput(kind, model, w, 8, b, 8, gpus)?;
                if kind == ScheduleKind::BitPipe {
                    bit = thr;
                } else {
                    best_baseline = best_baseline.max(thr);
                }
                cells.push(format!("{thr:.2}"));
            }
            cells.push(format!("{:.2}x", bit / best_baseline));
            t.row(cells);
        }
        let _ = writeln!(body, "{} (D=8, B={b}, N=D, W=GPUs/8):\n{}", model.name, t.render());
    }
    body.push_str(
        "Paper Fig 10: BitPipe outperforms at all scales (avg 1.28x/1.13x/1.06x over\n\
         DAPPLE/1F1B-Int/MixPipe on BERT); the lead shrinks with more nodes (IB share grows).\n",
    );
    Ok(EvalOutput { id: "fig10", title: "Throughput with data parallelism", body })
}

/// Fig 11: hyper-parameter study — D and B sensitivity on 32 GPUs.
pub fn fig11() -> Result<EvalOutput> {
    let mut body = String::new();
    // (a) pipeline size D with B-hat = 128 fixed.
    let mut t = Table::new(vec!["D", "W", "B", "N", "throughput"]);
    for d in [4usize, 8, 16] {
        let w = 32 / d;
        let b = 4usize;
        let n = (128 / (b * w)).max(d); // B-hat = B*N*W = 128
        let n = (n / d).max(1) * d;
        let thr = throughput(ScheduleKind::BitPipe, &BERT_64, w, d, b, n, 32)?;
        t.row(vec![
            d.to_string(),
            w.to_string(),
            b.to_string(),
            n.to_string(),
            format!("{thr:.2}"),
        ]);
    }
    let _ = writeln!(body, "(a) pipeline size D (BERT-64, 32 GPUs, B-hat=128):\n{}", t.render());
    // (b) micro-batch size B at D=8.
    let mut t = Table::new(vec!["B", "W", "N", "throughput"]);
    for b in [1usize, 2, 4] {
        let w = 4usize;
        let n = (128 / (b * w)).max(8) / 8 * 8;
        let thr = throughput(ScheduleKind::BitPipe, &BERT_64, w, 8, b, n, 32)?;
        t.row(vec![b.to_string(), w.to_string(), n.to_string(), format!("{thr:.2}")]);
    }
    let _ = writeln!(body, "(b) micro-batch size B (D=8):\n{}", t.render());
    body.push_str(
        "Paper Fig 11: D=8 is the sweet spot (bubbles vs communication); throughput rises\n\
         with B while memory and communication allow.\n",
    );
    Ok(EvalOutput { id: "fig11", title: "Hyper-parameter study (D, B)", body })
}
