//! Paper-evaluation harness: regenerates every table and figure of the
//! BitPipe paper from this reproduction's own engines.
//!
//! Each `fig*` / `table*` function returns an [`EvalOutput`] with the same
//! rows/series the paper reports; `run("all")` executes the full set. The
//! CLI (`bitpipe eval-paper`) and the benchmark harness
//! (`rust/benches/paper_tables.rs`) both dispatch through [`run`].
//!
//! Absolute numbers come from the discrete-event simulator under the
//! analytical A800-testbed cost model, so the *shape* (who wins, by what
//! factor, where crossovers fall) is the reproduction target, not the
//! paper's exact samples/s. EXPERIMENTS.md records paper-vs-measured for
//! every entry.

mod figures;
mod tables;

pub use figures::*;
pub use tables::*;

use anyhow::{bail, Result};

/// One regenerated paper artifact.
#[derive(Debug, Clone)]
pub struct EvalOutput {
    /// Paper artifact id, e.g. "table2", "fig9".
    pub id: &'static str,
    /// Human title matching the paper caption.
    pub title: &'static str,
    /// Rendered tables / series / notes.
    pub body: String,
}

impl EvalOutput {
    pub fn render(&self) -> String {
        format!("=== {} — {} ===\n{}", self.id, self.title, self.body)
    }
}

/// Every artifact id in paper order.
pub const ALL_IDS: [&str; 15] = [
    "fig1", "fig2", "fig3", "table2", "fig4", "fig5", "fig6", "fig7", "fig8", "fig9", "table4",
    "fig10", "table5", "fig11", "table6",
];

/// Extended set (appendix artifacts + repo extensions).
pub const EXTRA_IDS: [&str; 6] =
    ["fig12", "fig13", "table7", "tableb", "degradation", "resilience"];

/// Dispatch one artifact by id ("table2", "fig9", ... or "all").
pub fn run(id: &str) -> Result<Vec<EvalOutput>> {
    let one = |o: EvalOutput| Ok(vec![o]);
    match id {
        "fig1" => one(fig1()?),
        "fig2" => one(fig2()?),
        "fig3" => one(fig3()?),
        "fig4" => one(fig4()?),
        "fig5" => one(fig5()?),
        "fig6" => one(fig6()?),
        "fig7" => one(fig7()?),
        "fig8" => one(fig8()?),
        "fig9" => one(fig9()?),
        "fig10" => one(fig10()?),
        "fig11" => one(fig11()?),
        "fig12" => one(fig12()?),
        "fig13" => one(fig13()?),
        "table2" => one(table2()?),
        "table4" => one(table4()?),
        "table5" => one(table5()?),
        "table6" => one(table6()?),
        "table7" => one(table7()?),
        "tableb" => one(tableb()?),
        "degradation" => one(degradation()?),
        "resilience" => one(resilience()?),
        "all" => {
            let mut out = Vec::new();
            for id in ALL_IDS.iter().chain(EXTRA_IDS.iter()) {
                out.extend(run(id)?);
            }
            Ok(out)
        }
        other => bail!(
            "unknown artifact {other:?}; valid: {} all",
            ALL_IDS
                .iter()
                .chain(EXTRA_IDS.iter())
                .map(|s| s.to_string())
                .collect::<Vec<_>>()
                .join(" ")
        ),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_id_dispatches() {
        for id in ALL_IDS.iter().chain(EXTRA_IDS.iter()) {
            let out = run(id).unwrap_or_else(|e| panic!("{id}: {e}"));
            assert_eq!(out.len(), 1);
            assert!(!out[0].body.is_empty(), "{id}: empty body");
        }
    }

    #[test]
    fn unknown_id_rejected() {
        assert!(run("table99").is_err());
    }
}
