//! Vendored minimal stand-in for the `anyhow` crate.
//!
//! This build environment is hermetic (no crates.io access), so the subset
//! of `anyhow` the repository actually uses is implemented here with the
//! same names and semantics:
//!
//! * [`Error`] — a context-chain error value; `Display` shows the outermost
//!   context, `{:#}` joins the whole chain with `": "`, `Debug` renders an
//!   anyhow-style "Caused by:" listing.
//! * [`Result<T>`] — `Result<T, Error>` with the usual default parameter.
//! * [`Context`] — `.context(..)` / `.with_context(|| ..)` on both
//!   `Result<T, E>` (any `E: Into<Error>`) and `Option<T>`.
//! * [`anyhow!`], [`bail!`], [`ensure!`] — format-style constructors.
//! * `From<E> for Error` for every `E: std::error::Error + Send + Sync`,
//!   so `?` works on io/parse/domain errors, preserving the source chain.

use std::fmt;

/// Context-chain error value. The outermost context is entry 0; the root
/// cause is the last entry.
pub struct Error {
    chain: Vec<String>,
}

/// `anyhow::Result<T>` alias with the conventional default error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

impl Error {
    /// Construct from a displayable message (the `anyhow!` entry point).
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Wrap with an outer context layer.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The context chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }

    /// The innermost (root) message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(String::as_str).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            f.write_str(&self.chain.join(": "))
        } else {
            f.write_str(self.chain.first().map(String::as_str).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.chain.first().map(String::as_str).unwrap_or(""))?;
        if self.chain.len() > 1 {
            f.write_str("\n\nCaused by:")?;
            for cause in &self.chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

// `Error` deliberately does NOT implement `std::error::Error`, exactly like
// the real anyhow: that is what makes this blanket conversion coherent.
impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// `.context(..)` / `.with_context(|| ..)` on fallible values.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| {
            let err: Error = e.into();
            err.context(context)
        })
    }
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| {
            let err: Error = e.into();
            err.context(f())
        })
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string (or any displayable value).
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Return early with an [`anyhow!`] error.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an [`anyhow!`] error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<()> {
        std::fs::read("/definitely/not/a/file").map(|_| ()).context("reading config")?;
        Ok(())
    }

    #[test]
    fn context_chain_formats() {
        let err = io_fail().unwrap_err();
        assert_eq!(format!("{err}"), "reading config");
        let full = format!("{err:#}");
        assert!(full.starts_with("reading config: "), "{full}");
        let dbg = format!("{err:?}");
        assert!(dbg.contains("Caused by:"), "{dbg}");
    }

    #[test]
    fn option_context() {
        let none: Option<u8> = None;
        let err = none.with_context(|| format!("missing {}", 7)).unwrap_err();
        assert_eq!(err.to_string(), "missing 7");
    }

    #[test]
    fn macros() {
        fn f(x: usize) -> Result<usize> {
            ensure!(x < 10, "x too big: {x}");
            if x == 3 {
                bail!("three is right out ({})", x);
            }
            Ok(x)
        }
        assert_eq!(f(2).unwrap(), 2);
        assert_eq!(f(12).unwrap_err().to_string(), "x too big: 12");
        assert_eq!(f(3).unwrap_err().to_string(), "three is right out (3)");
        let e = anyhow!("plain");
        assert_eq!(e.root_cause(), "plain");
    }

    #[test]
    fn parse_errors_convert() {
        fn g(s: &str) -> Result<usize> {
            let v = s.parse::<usize>().with_context(|| format!("bad int {s:?}"))?;
            Ok(v)
        }
        assert_eq!(g("4").unwrap(), 4);
        let full = format!("{:#}", g("nope").unwrap_err());
        assert!(full.contains("bad int") && full.contains("invalid digit"), "{full}");
    }
}
