//! Vendored stub of the `xla` PJRT bindings.
//!
//! The real backend (xla_extension + PJRT CPU client) is an optional,
//! machine-specific install; this build environment does not ship it. The
//! coordinator only needs the *types* to compile — every run that would
//! actually execute an XLA artifact first loads `artifacts/manifest.txt`,
//! and the e2e tests skip when that directory is absent.
//!
//! Host-side [`Literal`] construction/inspection is implemented for real
//! (it is pure data plumbing and is unit-tested in `bitpipe::runtime`);
//! device-side entry points ([`PjRtClient::cpu`],
//! [`PjRtLoadedExecutable::execute`], ...) return a descriptive error.

use std::fmt;

/// Stub error: carries the operation that needed the missing backend.
#[derive(Debug)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable(what: &str) -> Error {
    Error(format!(
        "{what}: the XLA/PJRT backend is not available in this build \
         (vendored stub; install xla_extension and swap the real bindings in)"
    ))
}

/// Element storage for host literals (f32 tensors and i32 token ids —
/// the only dtypes the coordinator moves across the boundary).
#[derive(Debug, Clone)]
enum Elems {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

/// Host-side tensor literal: elements + shape.
#[derive(Debug, Clone)]
pub struct Literal {
    elems: Elems,
    dims: Vec<i64>,
}

/// Element types a [`Literal`] can hold.
pub trait NativeType: Copy + 'static {
    #[doc(hidden)]
    fn lit_from(data: &[Self]) -> Literal;
    #[doc(hidden)]
    fn lit_to(lit: &Literal) -> Result<Vec<Self>>;
}

impl NativeType for f32 {
    fn lit_from(data: &[Self]) -> Literal {
        Literal { elems: Elems::F32(data.to_vec()), dims: vec![data.len() as i64] }
    }
    fn lit_to(lit: &Literal) -> Result<Vec<Self>> {
        match &lit.elems {
            Elems::F32(v) => Ok(v.clone()),
            Elems::I32(_) => Err(Error("literal holds i32, asked for f32".into())),
        }
    }
}

impl NativeType for i32 {
    fn lit_from(data: &[Self]) -> Literal {
        Literal { elems: Elems::I32(data.to_vec()), dims: vec![data.len() as i64] }
    }
    fn lit_to(lit: &Literal) -> Result<Vec<Self>> {
        match &lit.elems {
            Elems::I32(v) => Ok(v.clone()),
            Elems::F32(_) => Err(Error("literal holds f32, asked for i32".into())),
        }
    }
}

impl Literal {
    /// Rank-1 literal over a host slice.
    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        T::lit_from(data)
    }

    /// Same elements, new shape (element counts must match).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let n: i64 = dims.iter().product();
        if n as usize != self.element_count() {
            return Err(Error(format!(
                "reshape {:?} -> {dims:?}: element count mismatch",
                self.dims
            )));
        }
        Ok(Literal { elems: self.elems.clone(), dims: dims.to_vec() })
    }

    pub fn element_count(&self) -> usize {
        match &self.elems {
            Elems::F32(v) => v.len(),
            Elems::I32(v) => v.len(),
        }
    }

    pub fn shape(&self) -> &[i64] {
        &self.dims
    }

    /// Copy the elements back to the host.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::lit_to(self)
    }

    /// Flatten a tuple literal (device results only; stub never holds one).
    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        Err(unavailable("Literal::to_tuple"))
    }
}

/// Parsed HLO module (opaque in the stub).
pub struct HloModuleProto {}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(unavailable("HloModuleProto::from_text_file"))
    }
}

/// XLA computation handle (opaque in the stub).
pub struct XlaComputation {}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation {}
    }
}

/// PJRT device buffer handle (opaque in the stub).
pub struct PjRtBuffer {}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("PjRtBuffer::to_literal_sync"))
    }
}

/// Compiled executable handle (opaque in the stub).
pub struct PjRtLoadedExecutable {}

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("PjRtLoadedExecutable::execute"))
    }
    pub fn execute_b(&self, _args: &[&PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("PjRtLoadedExecutable::execute_b"))
    }
}

/// PJRT client handle; construction reports the missing backend.
pub struct PjRtClient {}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable("PjRtClient::cpu"))
    }
    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("PjRtClient::compile"))
    }
    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }
    pub fn buffer_from_host_buffer<T: NativeType>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        Err(unavailable("PjRtClient::buffer_from_host_buffer"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_and_reshape() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]);
        assert_eq!(l.element_count(), 4);
        let r = l.reshape(&[2, 2]).unwrap();
        assert_eq!(r.shape(), &[2, 2]);
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(l.reshape(&[3]).is_err());
        assert!(l.to_vec::<i32>().is_err());
    }

    #[test]
    fn device_paths_report_missing_backend() {
        let e = PjRtClient::cpu().err().unwrap();
        assert!(e.to_string().contains("not available"));
        assert!(HloModuleProto::from_text_file("x.hlo").is_err());
    }
}
