//! Paper-table benchmark harness: times the regeneration of every table and
//! figure of the paper and prints the same rows the paper reports.
//!
//! ```bash
//! cargo bench --bench paper_tables            # everything
//! cargo bench --bench paper_tables -- fig9    # one artifact
//! ```
//!
//! (criterion is not vendored in this environment; this is a plain
//! `harness = false` binary with wall-clock timing.)

use std::time::Instant;

fn main() {
    // `cargo bench` appends harness flags like `--bench`; only a bare word
    // is treated as an artifact filter.
    let filter: Option<String> =
        std::env::args().skip(1).find(|a| !a.starts_with("--"));
    let ids: Vec<&str> = bitpipe::eval::ALL_IDS
        .iter()
        .chain(bitpipe::eval::EXTRA_IDS.iter())
        .copied()
        .filter(|id| filter.as_deref().map_or(true, |f| id.contains(&f)))
        .collect();
    if ids.is_empty() {
        eprintln!("no artifact matches filter {filter:?}");
        std::process::exit(1);
    }
    let t_all = Instant::now();
    for id in ids {
        let t0 = Instant::now();
        match bitpipe::eval::run(id) {
            Ok(outs) => {
                for out in outs {
                    println!("{}", out.render());
                }
                println!("[bench] {id} regenerated in {:?}\n", t0.elapsed());
            }
            Err(e) => {
                eprintln!("[bench] {id} FAILED: {e:#}");
                std::process::exit(1);
            }
        }
    }
    println!("[bench] full paper evaluation in {:?}", t_all.elapsed());
}
